//! End-to-end PBS latency and the batched key-reuse sweep: sequential
//! `pbs` vs `pbs_batch` at batch sizes {1, 4, 8, 16} x blind-rotation
//! pool threads {1, 2, 4}, with amortized Fourier-BSK bytes streamed per
//! PBS — the numbers behind EXPERIMENTS.md §Perf change 4 and §FFT.
//! Emits `BENCH_pbs.json` (ns/PBS + BSK bytes/PBS per batch size and
//! thread count, with the blocked-FFT selection recorded) so CI can
//! track the perf trajectory across PRs.

#[path = "harness.rs"]
mod harness;

use harness::{bench, section};
use taurus::params::{ParamSet, TEST1, TEST2};
use taurus::tfhe::fft::blocked_for_poly;
use taurus::tfhe::pbs::encrypt_message;
use taurus::tfhe::{make_lut_poly, PbsContext, SecretKeys, ServerKeys};
use taurus::util::json::{arr, num, obj, s, JsonValue};
use taurus::util::rng::Rng;

fn sweep_param_set(p: &'static ParamSet, rng: &mut Rng, rows: &mut Vec<JsonValue>) {
    let sk = SecretKeys::generate(p, rng);
    let keys = ServerKeys::generate(&sk, rng);
    let mut ctx = PbsContext::new(p);
    let lut = make_lut_poly(p, |m| m);
    // util::json has no bool; record the plan's schedule choice as 0/1.
    let blocked = if blocked_for_poly(p.big_n) { 1.0 } else { 0.0 };

    // Sequential baseline (batch the same count through one-at-a-time pbs
    // so per-PBS time is comparable at identical working sets).
    let ct = encrypt_message(3, &sk, rng);
    let seq = bench(&format!("pbs {} sequential (n={} N={})", p.name, p.n, p.big_n), 0.8, || {
        std::hint::black_box(ctx.pbs(&ct, &keys, &lut));
    });
    let seq_ns = seq.mean_s * 1e9;
    ctx.take_bsk_bytes_streamed();
    ctx.pbs(&ct, &keys, &lut);
    let seq_bsk = ctx.take_bsk_bytes_streamed() as f64;

    for threads in [1usize, 2, 4] {
        ctx.set_fft_threads(threads);
        for bsz in [1usize, 4, 8, 16] {
            let cts: Vec<_> =
                (0..bsz).map(|i| encrypt_message(i as u64 % 8, &sk, rng)).collect();
            // Exact per-batch BSK traffic, measured outside the timing loop.
            ctx.take_bsk_bytes_streamed();
            std::hint::black_box(ctx.pbs_batch(&cts, &keys, &lut));
            let bsk_per_pbs = ctx.take_bsk_bytes_streamed() as f64 / bsz as f64;
            let r = bench(&format!("  pbs_batch {} B={bsz} T={threads}", p.name), 0.6, || {
                std::hint::black_box(ctx.pbs_batch(&cts, &keys, &lut));
            });
            let ns_per_pbs = r.mean_s * 1e9 / bsz as f64;
            let speedup = seq_ns / ns_per_pbs;
            let reuse = seq_bsk / bsk_per_pbs;
            println!(
                "      {:>12.0} ns/PBS   {:>9.2}x vs seq   BSK {:>12.0} B/PBS (reuse {:>5.1}x)",
                ns_per_pbs, speedup, bsk_per_pbs, reuse
            );
            rows.push(obj(vec![
                ("params", s(p.name)),
                ("batch", num(bsz as f64)),
                ("threads", num(threads as f64)),
                ("blocked_fft", num(blocked)),
                ("ns_per_pbs", num(ns_per_pbs)),
                ("seq_ns_per_pbs", num(seq_ns)),
                ("speedup_vs_seq", num(speedup)),
                ("bsk_bytes_per_pbs", num(bsk_per_pbs)),
                ("bsk_reuse_factor", num(reuse)),
            ]));
        }
    }
    ctx.set_fft_threads(1);
}

fn main() {
    let mut rng = Rng::new(3);
    let mut rows: Vec<JsonValue> = Vec::new();

    section("native PBS: sequential vs batched blind rotation (key reuse)");
    for p in [&TEST1, &TEST2] {
        sweep_param_set(p, &mut rng, &mut rows);
    }

    let report = obj(vec![("bench", s("pbs")), ("results", arr(rows))]);
    let path = "BENCH_pbs.json";
    match std::fs::write(path, report.to_string() + "\n") {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }

    #[cfg(feature = "xla")]
    xla_section(&mut rng);
}

/// AOT XLA PBS (PJRT; needs `make artifacts` and the `xla` feature).
#[cfg(feature = "xla")]
fn xla_section(rng: &mut Rng) {
    section("AOT XLA PBS (PJRT; needs `make artifacts`)");
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
        let sk = SecretKeys::generate(&TEST1, rng);
        let keys = ServerKeys::generate(&sk, rng);
        let be = taurus::runtime::XlaPbsBackend::new(dir, &TEST1, &keys.bsk, &keys.ksk)
            .expect("backend");
        let lut = make_lut_poly(&TEST1, |m| m);
        let ct = encrypt_message(3, &sk, rng);
        bench("xla pbs test1", 2.0, || {
            std::hint::black_box(be.pbs(&ct, &lut).unwrap());
        });
        bench("  xla keyswitch only", 1.0, || {
            std::hint::black_box(be.keyswitch(&ct).unwrap());
        });
    } else {
        println!("skipped (no artifacts)");
    }
}
