//! End-to-end PBS latency: native Rust path at the functional-test sets
//! and (artifact-gated) the AOT XLA path — the numbers behind
//! EXPERIMENTS.md §Perf and the native-vs-XLA comparison.

#[path = "harness.rs"]
mod harness;

use harness::{bench, section};
use taurus::params::{TEST1, TEST2};
use taurus::tfhe::pbs::encrypt_message;
use taurus::tfhe::{make_lut_poly, PbsContext, SecretKeys, ServerKeys};
use taurus::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(3);

    section("native PBS (keyswitch + blind rotate + extract)");
    for p in [&TEST1, &TEST2] {
        let sk = SecretKeys::generate(p, &mut rng);
        let keys = ServerKeys::generate(&sk, &mut rng);
        let mut ctx = PbsContext::new(p);
        let lut = make_lut_poly(p, |m| m);
        let ct = encrypt_message(3, &sk, &mut rng);
        bench(&format!("pbs {} (n={} N={})", p.name, p.n, p.big_n), 1.0, || {
            std::hint::black_box(ctx.pbs(&ct, &keys, &lut));
        });
        let short = keys.ksk.keyswitch(&ct, p);
        bench(&format!("  keyswitch only {}", p.name), 0.4, || {
            std::hint::black_box(keys.ksk.keyswitch(&ct, p));
        });
        bench(&format!("  blind rotate only {}", p.name), 0.6, || {
            std::hint::black_box(ctx.blind_rotate(&short, &keys.bsk, &lut));
        });
    }

    section("AOT XLA PBS (PJRT; needs `make artifacts`)");
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
        let sk = SecretKeys::generate(&TEST1, &mut rng);
        let keys = ServerKeys::generate(&sk, &mut rng);
        let be = taurus::runtime::XlaPbsBackend::new(dir, &TEST1, &keys.bsk, &keys.ksk)
            .expect("backend");
        let lut = make_lut_poly(&TEST1, |m| m);
        let ct = encrypt_message(3, &sk, &mut rng);
        bench("xla pbs test1", 2.0, || {
            std::hint::black_box(be.pbs(&ct, &lut).unwrap());
        });
        bench("  xla keyswitch only", 1.0, || {
            std::hint::black_box(be.keyswitch(&ct).unwrap());
        });
    } else {
        println!("skipped (no artifacts)");
    }
}
