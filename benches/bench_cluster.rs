//! Sharded-serving sweep: the same workload pushed through a cluster at
//! shard counts {1, 2, 4} x every placement policy, emitting
//! `BENCH_cluster.json` (aggregate req/s, merged p50/p99, mean batch size
//! per shard, measured-vs-sim KS/PBS) so CI tracks shard scaling across
//! PRs alongside `BENCH_pbs.json` / `BENCH_schedule.json`.

#[path = "harness.rs"]
mod harness;

use std::sync::Arc;
use std::time::Duration;

use harness::section;
use taurus::arch::{simulate, TaurusConfig};
use taurus::cluster::{Cluster, ClusterOptions, PlacementPolicy};
use taurus::coordinator::CoordinatorOptions;
use taurus::ir::builder::ProgramBuilder;
use taurus::params::TEST1;
use taurus::tfhe::pbs::encrypt_message;
use taurus::tfhe::{SecretKeys, ServerKeys};
use taurus::util::json::{arr, num, obj, s, JsonValue};
use taurus::util::rng::Rng;

fn main() {
    // Serving shape with a KS-dedup opportunity: d = x + y fans out to two
    // LUTs (one shared key switch, 2 PBS per request).
    let mut b = ProgramBuilder::new("cluster-bench", TEST1.width);
    let x = b.input();
    let y = b.input();
    let d = b.add(x, y);
    let r0 = b.lut_fn(d, |m| (m + 1) % 16);
    let r1 = b.lut_fn(d, |m| m ^ 1);
    b.outputs(&[r0, r1]);
    let prog = b.finish();

    let mut rng = Rng::new(23);
    let sk = SecretKeys::generate(&TEST1, &mut rng);
    let keys = Arc::new(ServerKeys::generate(&sk, &mut rng));

    let requests = 96usize;
    let clients = 16u64;
    // This sweep runs with an unbounded admission queue; the field is
    // emitted per record (0 = unbounded) so trajectories stay
    // self-describing if a bounded variant is added.
    let queue_depth: Option<usize> = None;
    let cfg = TaurusConfig::default();
    let policies = [
        PlacementPolicy::RoundRobin,
        PlacementPolicy::LeastOutstanding,
        PlacementPolicy::ConsistentHash,
    ];

    section(&format!(
        "cluster shard sweep ({requests} requests, {clients} clients, 1 worker/shard, TEST1)"
    ));

    let mut rows: Vec<JsonValue> = Vec::new();
    let mut sim_ks_per_req = 0usize;
    for shards in [1usize, 2, 4] {
        for policy in policies {
            let mut cluster = Cluster::start(
                prog.clone(),
                keys.clone(),
                ClusterOptions {
                    shards,
                    policy,
                    queue_depth,
                    coordinator: CoordinatorOptions {
                        workers: 1,
                        batch_capacity: 8,
                        max_batch_wait: Duration::from_micros(500),
                        ..Default::default()
                    },
                    qos: None,
                },
            );
            let sim = simulate(cluster.plan(), &cfg);
            sim_ks_per_req = sim.ks_count;
            let t0 = std::time::Instant::now();
            let pending: Vec<_> = (0..requests)
                .map(|i| {
                    let inputs = vec![
                        encrypt_message((i % 6) as u64, &sk, &mut rng),
                        encrypt_message((i % 4) as u64, &sk, &mut rng),
                    ];
                    cluster.submit(i as u64 % clients, inputs).expect("submit")
                })
                .collect();
            for resp in &pending {
                let _ = resp.recv().expect("response");
            }
            let wall = t0.elapsed().as_secs_f64();
            drop(pending);

            let snap = cluster.snapshot();
            let per_shard = cluster.shard_snapshots();
            let req_per_s = requests as f64 / wall;
            let ks_ok = snap.ks_executed == (requests * sim.ks_count) as u64
                && snap.pbs_executed == requests * sim.pbs_count;
            println!(
                "shards={shards} policy={:<17} {:>8.1} req/s   p99 {:>7.2} ms   mean batch {:>5.2}   sim-check {}",
                policy.name(),
                req_per_s,
                snap.p99_latency_ms,
                snap.mean_batch_size,
                if ks_ok { "OK" } else { "MISMATCH" },
            );
            // Per-shard records repeat the sweep coordinates (policy,
            // shard count, queue depth): each row is self-describing
            // rather than implied by its position in the parent array.
            let shard_rows: Vec<JsonValue> = per_shard
                .iter()
                .enumerate()
                .map(|(i, sh)| {
                    obj(vec![
                        ("shard", num(i as f64)),
                        ("policy", s(policy.name())),
                        ("shards", num(shards as f64)),
                        ("queue_depth", num(queue_depth.unwrap_or(0) as f64)),
                        ("requests", num(sh.requests as f64)),
                        ("batches", num(sh.batches as f64)),
                        ("mean_batch_size", num(sh.mean_batch_size)),
                    ])
                })
                .collect();
            rows.push(obj(vec![
                ("shards", num(shards as f64)),
                ("policy", s(policy.name())),
                ("queue_depth", num(queue_depth.unwrap_or(0) as f64)),
                ("req_per_s", num(req_per_s)),
                ("p50_latency_ms", num(snap.p50_latency_ms)),
                ("p99_latency_ms", num(snap.p99_latency_ms)),
                ("mean_batch_size", num(snap.mean_batch_size)),
                ("ks_executed", num(snap.ks_executed as f64)),
                ("pbs_executed", num(snap.pbs_executed as f64)),
                ("bsk_bytes_per_pbs", num(snap.bsk_bytes_per_pbs)),
                ("sim_check_ok", JsonValue::Bool(ks_ok)),
                ("per_shard", arr(shard_rows)),
            ]));
            cluster.shutdown();
        }
    }

    let report = obj(vec![
        ("bench", s("cluster")),
        ("requests", num(requests as f64)),
        ("clients", num(clients as f64)),
        ("sim_ks_per_request", num(sim_ks_per_req as f64)),
        ("results", arr(rows)),
    ]);
    let path = "BENCH_cluster.json";
    match std::fs::write(path, report.to_string() + "\n") {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
}
