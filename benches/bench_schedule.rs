//! Plan-vs-naive execution sweep: the same fanout-rich program run by the
//! legacy node-walking engine and by the schedule-driven plan executor at
//! request-batch sizes {1, 4, 8}. Emits `BENCH_schedule.json` (ks_count,
//! pbs_count, bsk_bytes_per_pbs, wall time per request) so CI tracks the
//! schedule-execution trajectory across PRs alongside `BENCH_pbs.json`.

#[path = "harness.rs"]
mod harness;

use harness::{bench, section};
use taurus::compiler::{compile, CompileOpts, Engine, NativePbsBackend};
use taurus::ir::builder::ProgramBuilder;
use taurus::ir::Program;
use taurus::params::TEST1;
use taurus::tfhe::pbs::encrypt_message;
use taurus::tfhe::{LweCiphertext, SecretKeys, ServerKeys};
use taurus::util::json::{arr, num, obj, s, JsonValue};
use taurus::util::rng::Rng;

/// Fanout-rich serving shape: d = x + y fans out to F LUTs drawn from two
/// distinct tables (KS-dedup shares d's key switch; ACC-sharing fuses the
/// rotations into two sweeps), then a dependent reduction LUT level.
fn fanout_program(fanout: usize) -> Program {
    let mut b = ProgramBuilder::new("sched-bench", TEST1.width);
    let x = b.input();
    let y = b.input();
    let d = b.add(x, y);
    let luts: Vec<_> = (0..fanout)
        .map(|k| {
            if k % 2 == 0 {
                b.lut_fn(d, |m| (m + 1) % 16)
            } else {
                b.lut_fn(d, |m| m ^ 1)
            }
        })
        .collect();
    let sum = b.dot(luts, vec![1; fanout], 0);
    let r = b.lut_fn(sum, |m| m % 8);
    b.output(r);
    b.finish()
}

fn main() {
    let fanout = 8usize;
    let prog = fanout_program(fanout);
    let plan = compile(&prog, &TEST1, CompileOpts::default());

    let mut rng = Rng::new(7);
    let sk = SecretKeys::generate(&TEST1, &mut rng);
    let keys = ServerKeys::generate(&sk, &mut rng);

    section(&format!(
        "schedule-driven vs naive execution (fanout {fanout}, {} PBS, KS {} -> {})",
        plan.graph.pbs_count(),
        plan.ks_dedup.before,
        plan.ks_dedup.after
    ));

    let mut rows: Vec<JsonValue> = Vec::new();
    for bsz in [1usize, 4, 8] {
        let batch: Vec<Vec<LweCiphertext>> = (0..bsz)
            .map(|i| {
                vec![
                    encrypt_message(i as u64 % 4, &sk, &mut rng),
                    encrypt_message((i as u64 * 3) % 4, &sk, &mut rng),
                ]
            })
            .collect();

        let mut naive = Engine::new(NativePbsBackend::new(&keys));
        naive.take_exec_stats();
        std::hint::black_box(naive.run_batch(&prog, &batch));
        let nst = naive.take_exec_stats();
        let nr = bench(&format!("naive  run_batch B={bsz}"), 0.6, || {
            std::hint::black_box(naive.run_batch(&prog, &batch));
        });

        let mut planned = Engine::new(NativePbsBackend::new(&keys));
        planned.take_exec_stats();
        std::hint::black_box(planned.run_plan_batch(&plan, &batch));
        let pst = planned.take_exec_stats();
        let pr = bench(&format!("plan   run_plan_batch B={bsz}"), 0.6, || {
            std::hint::black_box(planned.run_plan_batch(&plan, &batch));
        });

        let per_req = |mean_s: f64| mean_s * 1e9 / bsz as f64;
        println!(
            "      B={bsz}: plan {:>5.2}x vs naive | KS/req {} vs {} | BSK B/PBS {:>10.0} vs {:>10.0}",
            nr.mean_s / pr.mean_s,
            pst.ks_ops / bsz as u64,
            nst.ks_ops / bsz as u64,
            pst.bsk_bytes_streamed as f64 / pst.pbs_ops as f64,
            nst.bsk_bytes_streamed as f64 / nst.pbs_ops as f64,
        );
        for (mode, st, r) in [("naive", &nst, &nr), ("plan", &pst, &pr)] {
            rows.push(obj(vec![
                ("mode", s(mode)),
                ("batch", num(bsz as f64)),
                ("ks_count", num(st.ks_ops as f64)),
                ("pbs_count", num(st.pbs_ops as f64)),
                ("br_calls", num(st.br_calls as f64)),
                (
                    "bsk_bytes_per_pbs",
                    num(st.bsk_bytes_streamed as f64 / st.pbs_ops.max(1) as f64),
                ),
                ("ns_per_request", num(per_req(r.mean_s))),
            ]));
        }
    }

    let report = obj(vec![
        ("bench", s("schedule")),
        ("ks_dedup_before", num(plan.ks_dedup.before as f64)),
        ("ks_dedup_after", num(plan.ks_dedup.after as f64)),
        ("results", arr(rows)),
    ]);
    let path = "BENCH_schedule.json";
    match std::fs::write(path, report.to_string() + "\n") {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
}
