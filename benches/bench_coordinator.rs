//! Serving-path bench: coordinator throughput/latency over the native
//! backend at several worker counts and batch capacities (the L3 hot path
//! of EXPERIMENTS.md §Perf).

#[path = "harness.rs"]
mod harness;

use std::sync::Arc;
use std::time::Duration;

use harness::section;
use taurus::coordinator::{BackendKind, Coordinator, CoordinatorOptions};
use taurus::ir::builder::ProgramBuilder;
use taurus::params::TEST1;
use taurus::tfhe::pbs::encrypt_message;
use taurus::tfhe::{SecretKeys, ServerKeys};
use taurus::util::rng::Rng;

fn main() {
    let mut b = ProgramBuilder::new("bench", TEST1.width);
    let x = b.input();
    let y = b.input();
    let d = b.dot(vec![x, y], vec![1, 1], 0);
    let r = b.lut_fn(d, |m| m ^ 1);
    b.output(r);
    let prog = b.finish();

    let mut rng = Rng::new(17);
    let sk = SecretKeys::generate(&TEST1, &mut rng);
    let keys = Arc::new(ServerKeys::generate(&sk, &mut rng));

    let full_bsk = keys.bsk.bytes() as f64;

    section("coordinator throughput (1 PBS/query, TEST1, native)");
    for workers in [1usize, 2, 4, 8] {
        let mut coord = Coordinator::start(
            prog.clone(),
            keys.clone(),
            CoordinatorOptions {
                workers,
                batch_capacity: 8,
                max_batch_wait: Duration::from_micros(200),
                backend: BackendKind::Native,
                ..Default::default()
            },
        );
        let n = 64 * workers;
        let t0 = std::time::Instant::now();
        let pending: Vec<_> = (0..n)
            .map(|i| {
                coord
                    .submit(vec![
                        encrypt_message((i % 6) as u64, &sk, &mut rng),
                        encrypt_message(1, &sk, &mut rng),
                    ])
                    .expect("submit")
            })
            .collect();
        for rx in &pending {
            let _ = rx.recv().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let snap = coord.metrics.snapshot();
        println!(
            "workers={workers:<2}  {:>7.1} req/s   p50 {:>8.2} ms   p99 {:>8.2} ms   mean batch {:.2}",
            n as f64 / wall,
            snap.p50_latency_ms,
            snap.p99_latency_ms,
            snap.mean_batch_size
        );
        coord.shutdown();
    }

    section("batch-capacity sweep (2 workers): fused sweeps amortize the BSK stream");
    for capacity in [1usize, 4, 8, 16] {
        let mut coord = Coordinator::start(
            prog.clone(),
            keys.clone(),
            CoordinatorOptions {
                workers: 2,
                batch_capacity: capacity,
                max_batch_wait: Duration::from_millis(2),
                backend: BackendKind::Native,
                ..Default::default()
            },
        );
        let n = 96;
        let t0 = std::time::Instant::now();
        let pending: Vec<_> = (0..n)
            .map(|i| {
                coord
                    .submit(vec![
                        encrypt_message((i % 6) as u64, &sk, &mut rng),
                        encrypt_message(1, &sk, &mut rng),
                    ])
                    .expect("submit")
            })
            .collect();
        for rx in &pending {
            let _ = rx.recv().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let snap = coord.metrics.snapshot();
        println!(
            "capacity={capacity:<3} {:>7.1} req/s   mean batch {:>5.2}   BSK {:>12.0} B/PBS ({:>5.2}x reuse vs full stream)",
            n as f64 / wall,
            snap.mean_batch_size,
            snap.bsk_bytes_per_pbs,
            full_bsk / snap.bsk_bytes_per_pbs.max(1.0),
        );
        coord.shutdown();
    }
}
