//! Fault-injection sweep: the cluster-serving workload pushed through a
//! 2-shard supervised cluster under seed-derived fault plans of increasing
//! intensity, emitting `BENCH_faults.json` (success rate, throughput under
//! faults, and the recovery counters — retries, redirects, respawns,
//! restarts, timeouts) so CI tracks robustness across PRs alongside
//! `BENCH_cluster.json`.

#[path = "harness.rs"]
mod harness;

use std::sync::Arc;
use std::time::Duration;

use harness::section;
use taurus::cluster::{
    Cluster, ClusterOptions, PlacementPolicy, StoreFactory, SupervisorOptions,
};
use taurus::coordinator::{BackendKind, CoordinatorOptions};
use taurus::ir::builder::ProgramBuilder;
use taurus::params::TEST1;
use taurus::runtime::faults::{FaultPlan, FaultSpec, FaultyStore};
use taurus::tenant::{KeyStore, StaticKeys};
use taurus::tfhe::pbs::encrypt_message;
use taurus::tfhe::{SecretKeys, ServerKeys};
use taurus::util::json::{arr, num, obj, s, JsonValue};
use taurus::util::rng::Rng;

/// Fault intensity levels swept per seed. Horizons are sized to the
/// ~12 batches a 48-request run produces at batch capacity 4 so the
/// scheduled faults actually fire.
fn spec_for(level: &str) -> FaultSpec {
    match level {
        "light" => FaultSpec {
            op_horizon: 12,
            panics: 1,
            delays: 1,
            delay: Duration::from_millis(5),
            resolve_horizon: 48,
            resolve_failures: 1,
        },
        "heavy" => FaultSpec {
            op_horizon: 12,
            panics: 4,
            delays: 2,
            delay: Duration::from_millis(10),
            resolve_horizon: 48,
            resolve_failures: 4,
        },
        _ => FaultSpec::none(),
    }
}

fn main() {
    // Same serving shape as bench_cluster: d = x + y fans out to two LUTs
    // (one shared key switch, 2 PBS per request).
    let mut b = ProgramBuilder::new("faults-bench", TEST1.width);
    let x = b.input();
    let y = b.input();
    let d = b.add(x, y);
    let r0 = b.lut_fn(d, |m| (m + 1) % 16);
    let r1 = b.lut_fn(d, |m| m ^ 1);
    b.outputs(&[r0, r1]);
    let prog = b.finish();

    let mut rng = Rng::new(29);
    let sk = SecretKeys::generate(&TEST1, &mut rng);
    let keys = Arc::new(ServerKeys::generate(&sk, &mut rng));

    let requests = 48usize;
    let shards = 2usize;
    let deadline = Duration::from_secs(30);
    let coord_opts = CoordinatorOptions {
        workers: 1,
        batch_capacity: 4,
        max_batch_wait: Duration::from_micros(500),
        ..Default::default()
    };

    section(&format!(
        "fault-injection sweep ({requests} requests, {shards} shards, TEST1)"
    ));

    let mut rows: Vec<JsonValue> = Vec::new();
    // (seed, intensity); seed 0/"none" is the fault-free baseline the
    // chaos rows are compared against.
    let mut scenarios: Vec<(u64, &str)> = vec![(0, "none")];
    for seed in 0u64..4 {
        scenarios.push((seed, "light"));
        scenarios.push((seed, "heavy"));
    }

    for (seed, level) in scenarios {
        let faults = Arc::new(FaultPlan::from_seed(seed, &spec_for(level)));
        let factory: StoreFactory = {
            let keys = keys.clone();
            let faults = faults.clone();
            Arc::new(move |_shard| {
                let inner = Arc::new(StaticKeys::new(keys.clone())) as Arc<dyn KeyStore>;
                Arc::new(FaultyStore::new(inner, faults.clone())) as Arc<dyn KeyStore>
            })
        };
        let mut coordinator = coord_opts.clone();
        if level != "none" {
            coordinator.backend = BackendKind::NativeChaos { faults: faults.clone() };
        }
        let mut cluster = Cluster::start_with_store_factory_supervised(
            prog.clone(),
            factory,
            ClusterOptions {
                shards,
                policy: PlacementPolicy::RoundRobin,
                queue_depth: None,
                coordinator,
                qos: None,
            },
            SupervisorOptions { max_retries: 2, restart_after_failures: 2, ..Default::default() },
        );

        let t0 = std::time::Instant::now();
        let mut ok = 0usize;
        let mut failed = 0usize;
        let mut pending = Vec::new();
        for i in 0..requests {
            let inputs = vec![
                encrypt_message((i % 6) as u64, &sk, &mut rng),
                encrypt_message((i % 4) as u64, &sk, &mut rng),
            ];
            match cluster.submit_with_deadline(i as u64 % 8, inputs, deadline) {
                Ok(r) => pending.push(r),
                Err(_) => failed += 1,
            }
        }
        for resp in &pending {
            match resp.wait() {
                Ok(_) => ok += 1,
                Err(_) => failed += 1,
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        drop(pending);

        let snap = cluster.snapshot();
        let inj = faults.injected();
        let terminated = ok + failed == requests;
        let success_rate = ok as f64 / requests as f64;
        println!(
            "seed={seed} intensity={level:<5} {:>8.1} req/s   success {:>5.1}%   retries {} redirects {} respawns {} restarts {} timeouts {}   {}",
            requests as f64 / wall,
            success_rate * 100.0,
            snap.request_retries,
            snap.request_redirects,
            snap.worker_respawns,
            snap.shard_restarts,
            snap.request_timeouts,
            if terminated { "all terminated" } else { "HANG" },
        );
        rows.push(obj(vec![
            ("seed", num(seed as f64)),
            ("intensity", s(level)),
            ("requests", num(requests as f64)),
            ("served", num(ok as f64)),
            ("failed_typed", num(failed as f64)),
            ("success_rate", num(success_rate)),
            ("all_terminated", JsonValue::Bool(terminated)),
            ("req_per_s", num(requests as f64 / wall)),
            ("p99_latency_ms", num(snap.p99_latency_ms)),
            ("injected_panics", num(inj.panics as f64)),
            ("injected_delays", num(inj.delays as f64)),
            ("injected_resolve_failures", num(inj.resolve_failures as f64)),
            ("exec_failures", num(snap.exec_failures as f64)),
            ("worker_respawns", num(snap.worker_respawns as f64)),
            ("request_retries", num(snap.request_retries as f64)),
            ("request_redirects", num(snap.request_redirects as f64)),
            ("shard_restarts", num(snap.shard_restarts as f64)),
            ("request_timeouts", num(snap.request_timeouts as f64)),
        ]));
        cluster.shutdown();
    }

    let report = obj(vec![
        ("bench", s("faults")),
        ("requests", num(requests as f64)),
        ("shards", num(shards as f64)),
        ("results", arr(rows)),
    ]);
    let path = "BENCH_faults.json";
    match std::fs::write(path, report.to_string() + "\n") {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
}
