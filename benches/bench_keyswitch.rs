//! LPU-side microbenches: key switching (the second most expensive TFHE
//! op, §II-B), sample extraction, and the linear ops of the LWE layer.

#[path = "harness.rs"]
mod harness;

use harness::{bench, section};
use taurus::params::{TEST1, TEST2};
use taurus::tfhe::fft::FftPlan;
use taurus::tfhe::glwe::GlweCiphertext;
use taurus::tfhe::ksk::Ksk;
use taurus::tfhe::lwe::LweCiphertext;
use taurus::tfhe::SecretKeys;
use taurus::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(5);
    section("key switching");
    for p in [&TEST1, &TEST2] {
        let sk = SecretKeys::generate(p, &mut rng);
        let ksk = Ksk::generate(&sk, &mut rng);
        let ct = LweCiphertext::encrypt(1 << 60, sk.long_lwe(), p.glwe_noise, &mut rng);
        bench(&format!("keyswitch {} (kN={} -> n={})", p.name, p.long_dim(), p.n), 0.6, || {
            std::hint::black_box(ksk.keyswitch(&ct, p));
        });
    }

    section("sample extract + linear ops (TEST2 long dimension)");
    let p = &TEST2;
    let sk = SecretKeys::generate(p, &mut rng);
    let plan = FftPlan::new(p.big_n);
    let msg = vec![0u64; p.big_n];
    let glwe = GlweCiphertext::encrypt(&msg, &sk, p.glwe_noise, &mut rng, &plan);
    bench("sample_extract", 0.3, || {
        std::hint::black_box(glwe.sample_extract(p));
    });
    let mut a = LweCiphertext::encrypt(1 << 60, sk.long_lwe(), p.glwe_noise, &mut rng);
    let b = LweCiphertext::encrypt(2 << 60, sk.long_lwe(), p.glwe_noise, &mut rng);
    bench("lwe add_assign (kN+1 u64)", 0.3, || {
        a.add_assign(std::hint::black_box(&b));
    });
    bench("lwe scalar_mul_assign", 0.3, || {
        a.scalar_mul_assign(std::hint::black_box(3));
    });
}
