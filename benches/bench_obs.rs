//! Observability overhead: the same PBS work with the obs hooks disabled
//! (the default — every hook is one relaxed atomic load) versus enabled
//! (clock reads + histogram records + flight-recorder spans), plus the
//! `Log2Histogram::record` micro-cost. The disabled-mode delta is the
//! number EXPERIMENTS.md §Observability quotes and CI tracks: it must
//! stay in the noise (<2% on batch-8 PBS). Emits `BENCH_obs.json`.

#[path = "harness.rs"]
mod harness;

use harness::{bench, section};
use taurus::compiler::{compile, Engine, NativePbsBackend};
use taurus::ir::builder::ProgramBuilder;
use taurus::obs;
use taurus::obs::hist::Log2Histogram;
use taurus::params::TEST1;
use taurus::tfhe::pbs::encrypt_message;
use taurus::tfhe::{make_lut_poly, LweCiphertext, PbsContext, SecretKeys, ServerKeys};
use taurus::util::json::{arr, num, obj, s, JsonValue};
use taurus::util::rng::Rng;

const BATCH: usize = 8;

fn main() {
    let mut rng = Rng::new(11);
    let sk = SecretKeys::generate(&TEST1, &mut rng);
    let keys = std::sync::Arc::new(ServerKeys::generate(&sk, &mut rng));
    let lut = make_lut_poly(&TEST1, |m| m);
    let cts: Vec<_> = (0..BATCH).map(|i| encrypt_message(i as u64 % 8, &sk, &mut rng)).collect();

    // The serving shape: two LUTs over one value (shared key switch) —
    // the same quickstart program `serve` runs, through the same
    // schedule-driven engine, so the enabled path exercises every stage
    // hook (KS/BR/SE timers, per-batch profiles, trace spans) and not
    // just the FFT meter.
    let mut b = ProgramBuilder::new("bench-obs", TEST1.width);
    let x = b.input();
    let y = b.input();
    let d = b.dot(vec![x, y], vec![2, 1], 1);
    let r = b.relu(d, 3);
    let sg = b.lut_fn(d, |m| u64::from(m > 3));
    b.outputs(&[r, sg]);
    let plan = compile(&b.finish(), &TEST1, 48usize);
    let batch: Vec<Vec<LweCiphertext>> = (0..BATCH)
        .map(|i| {
            vec![
                encrypt_message(i as u64 % 4, &sk, &mut rng),
                encrypt_message((i as u64 * 3) % 4, &sk, &mut rng),
            ]
        })
        .collect();

    let mut rows: Vec<JsonValue> = Vec::new();
    let mut ctx = PbsContext::new(&TEST1);
    let mut eng = Engine::new(NativePbsBackend::shared(keys.clone()));

    section("observability overhead: hooks disabled vs enabled");
    assert!(!obs::enabled(), "bench must start with obs disabled");
    let pbs_off = bench(&format!("pbs_batch TEST1 B={BATCH} obs OFF"), 0.8, || {
        std::hint::black_box(ctx.pbs_batch(&cts, &keys, &lut));
    });
    let plan_off = bench(&format!("run_plan_batch B={BATCH} obs OFF"), 0.8, || {
        std::hint::black_box(eng.run_plan_batch(&plan, &batch));
    });
    let _ = eng.take_exec_stats();

    obs::enable();
    let pbs_on = bench(&format!("pbs_batch TEST1 B={BATCH} obs ON"), 0.8, || {
        std::hint::black_box(ctx.pbs_batch(&cts, &keys, &lut));
    });
    let plan_on = bench(&format!("run_plan_batch B={BATCH} obs ON"), 0.8, || {
        std::hint::black_box(eng.run_plan_batch(&plan, &batch));
    });
    // Sanity: the enabled run actually recorded (one SE sample per PBS).
    let stage = eng.take_stage_times();
    assert!(stage.sample_extract.count() > 0, "enabled run must record stage samples");
    obs::disable();

    let pct = |on: f64, off: f64| (on - off) / off * 100.0;
    let pbs_overhead = pct(pbs_on.mean_s, pbs_off.mean_s);
    let plan_overhead = pct(plan_on.mean_s, plan_off.mean_s);
    println!("      pbs_batch enabled-hook overhead : {pbs_overhead:+.2}%");
    println!("      plan-engine enabled overhead    : {plan_overhead:+.2}%");

    section("Log2Histogram::record micro-cost");
    let mut h = Log2Histogram::new();
    let rec = bench("hist record x10000", 0.3, || {
        for i in 0..10_000u64 {
            h.record(i.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        }
        std::hint::black_box(&h);
    });
    let ns_per_record = rec.mean_s * 1e9 / 10_000.0;
    println!("      {ns_per_record:.2} ns/record");

    for (case, off, on, overhead) in [
        ("pbs_batch8", &pbs_off, &pbs_on, pbs_overhead),
        ("run_plan_batch8", &plan_off, &plan_on, plan_overhead),
    ] {
        rows.push(obj(vec![
            ("case", s(case)),
            ("batch", num(BATCH as f64)),
            ("off_ns", num(off.mean_s * 1e9)),
            ("on_ns", num(on.mean_s * 1e9)),
            ("enabled_overhead_pct", num(overhead)),
        ]));
    }
    rows.push(obj(vec![("case", s("hist_record")), ("ns_per_record", num(ns_per_record))]));

    let report = obj(vec![("bench", s("obs")), ("results", arr(rows))]);
    let path = "BENCH_obs.json";
    match std::fs::write(path, report.to_string() + "\n") {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
}
