//! Multi-tenant serving sweep: tenant count x key-cache capacity through
//! a 2-shard consistent-hash cluster with per-tenant seeded stores,
//! emitting `BENCH_tenants.json` (cache hit rate, evictions and
//! regenerations, keyed-batch splits, p50/p99 latency) so CI tracks the
//! cost of key residency pressure across PRs alongside
//! `BENCH_cluster.json`.
//!
//! The interesting regime is capacity < tenants: every request whose
//! session was evicted pays a full keygen at admission (the
//! "regeneration" counter), which is exactly the memory-bandwidth
//! economics the paper's per-client serving story trades against.

#[path = "harness.rs"]
mod harness;

use std::sync::Arc;
use std::time::Duration;

use harness::section;
use taurus::cluster::{Cluster, ClusterOptions, PlacementPolicy, StoreFactory};
use taurus::coordinator::CoordinatorOptions;
use taurus::ir::builder::ProgramBuilder;
use taurus::params::TEST1;
use taurus::tenant::{client_secret, tenant_seed, KeyStore, SeededTenantStore, SessionId};
use taurus::tfhe::pbs::encrypt_message;
use taurus::tfhe::SecretKeys;
use taurus::traffic::ZipfSampler;
use taurus::util::json::{arr, num, obj, s, JsonValue};
use taurus::util::rng::Rng;

/// Counter-exact simulator of `BoundedKeyCache`'s LRU for access traces
/// too large to pay real keygen on: a hit touches recency; a miss inserts
/// and evicts the least-recently-used entry past capacity; a miss for a
/// seed generated before is a regeneration; explicit removes don't happen
/// here. Cross-checked counter-for-counter against the real store in
/// `main` before the million-session rows are trusted.
struct LruSim {
    cap: usize,
    by_seed: std::collections::HashMap<u64, u64>,
    by_tick: std::collections::BTreeMap<u64, u64>,
    seen: std::collections::HashSet<u64>,
    tick: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
    regenerations: u64,
}

impl LruSim {
    fn new(cap: usize) -> Self {
        assert!(cap >= 1);
        Self {
            cap,
            by_seed: std::collections::HashMap::new(),
            by_tick: std::collections::BTreeMap::new(),
            seen: std::collections::HashSet::new(),
            tick: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
            regenerations: 0,
        }
    }

    fn touch(&mut self, seed: u64) {
        self.tick += 1;
        let tick = self.tick;
        if let Some(old) = self.by_seed.insert(seed, tick) {
            self.by_tick.remove(&old);
            self.by_tick.insert(tick, seed);
            self.hits += 1;
            return;
        }
        self.by_tick.insert(tick, seed);
        self.misses += 1;
        if !self.seen.insert(seed) {
            self.regenerations += 1;
        }
        while self.by_seed.len() > self.cap {
            let (&t, &victim) = self.by_tick.iter().next().expect("over capacity implies entries");
            self.by_tick.remove(&t);
            self.by_seed.remove(&victim);
            self.evictions += 1;
        }
    }

    fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total > 0 { self.hits as f64 / total as f64 } else { 0.0 }
    }
}

fn main() {
    // Serving shape with a KS-dedup opportunity: d = x + y fans out to two
    // LUTs (one shared key switch, 2 PBS per request).
    let mut b = ProgramBuilder::new("tenant-bench", TEST1.width);
    let x = b.input();
    let y = b.input();
    let d = b.add(x, y);
    let r0 = b.lut_fn(d, |m| (m + 1) % 16);
    let r1 = b.lut_fn(d, |m| m ^ 1);
    b.outputs(&[r0, r1]);
    let prog = b.finish();

    let master_seed = 0xBE7C_0001u64;
    let requests = 48usize;
    let shards = 2usize;

    section(&format!(
        "tenant sweep ({requests} requests, {shards} shards, consistent-hash, TEST1)"
    ));

    let mut rows: Vec<JsonValue> = Vec::new();
    for tenants in [1usize, 4, 8] {
        // Client-side secrets once per tenant count (cheap).
        let sks: Vec<SecretKeys> = (0..tenants as u64)
            .map(|t| client_secret(&TEST1, master_seed, SessionId(t)))
            .collect();
        for cache_cap in [2usize, 8] {
            let factory: StoreFactory = Arc::new(move |_shard| {
                Arc::new(SeededTenantStore::new(&TEST1, master_seed, cache_cap))
                    as Arc<dyn KeyStore>
            });
            let mut cluster = Cluster::start_with_store_factory(
                prog.clone(),
                factory,
                ClusterOptions {
                    shards,
                    policy: PlacementPolicy::ConsistentHash,
                    queue_depth: None,
                    coordinator: CoordinatorOptions {
                        workers: 1,
                        batch_capacity: 8,
                        max_batch_wait: Duration::from_micros(500),
                        ..Default::default()
                    },
                    qos: None,
                },
            );
            let mut rng = Rng::new(17);
            let t0 = std::time::Instant::now();
            let pending: Vec<_> = (0..requests)
                .map(|i| {
                    let t = i % tenants;
                    let inputs = vec![
                        encrypt_message((i % 6) as u64, &sks[t], &mut rng),
                        encrypt_message((i % 4) as u64, &sks[t], &mut rng),
                    ];
                    cluster.submit(SessionId(t as u64), inputs).expect("submit")
                })
                .collect();
            for resp in &pending {
                let _ = resp.recv().expect("response");
            }
            let wall = t0.elapsed().as_secs_f64();
            drop(pending);

            let snap = cluster.snapshot();
            let resolves = snap.key_hits + snap.key_misses;
            let hit_rate =
                if resolves > 0 { snap.key_hits as f64 / resolves as f64 } else { 0.0 };
            println!(
                "tenants={tenants} cap={cache_cap}  {:>8.1} req/s   p50 {:>7.2} ms   p99 {:>7.2} ms   hit-rate {:>5.2}   regens {:>3}   splits {:>3}",
                requests as f64 / wall,
                snap.p50_latency_ms,
                snap.p99_latency_ms,
                hit_rate,
                snap.key_regenerations,
                snap.keyed_batch_splits,
            );
            rows.push(obj(vec![
                ("tenants", num(tenants as f64)),
                ("cache_capacity", num(cache_cap as f64)),
                ("requests", num(requests as f64)),
                ("req_per_s", num(requests as f64 / wall)),
                ("p50_latency_ms", num(snap.p50_latency_ms)),
                ("p99_latency_ms", num(snap.p99_latency_ms)),
                ("key_hit_rate", num(hit_rate)),
                ("key_hits", num(snap.key_hits as f64)),
                ("key_misses", num(snap.key_misses as f64)),
                ("key_evictions", num(snap.key_evictions as f64)),
                ("key_regenerations", num(snap.key_regenerations as f64)),
                ("keys_resident", num(snap.key_resident as f64)),
                ("keyed_batch_splits", num(snap.keyed_batch_splits as f64)),
                ("mean_batch_size", num(snap.mean_batch_size)),
            ]));
            cluster.shutdown();
        }
    }

    // ---- simulator cross-check: replay one trace through the real store
    // AND the LRU simulator; every counter must agree before the
    // million-session rows below are trusted. Small on purpose — each
    // real miss pays a full TEST1 keygen.
    section("LRU simulator cross-check (real SeededTenantStore, 16 sessions, cap 4)");
    let check = {
        let store = SeededTenantStore::new(&TEST1, master_seed, 4);
        let mut sim = LruSim::new(4);
        let sampler = ZipfSampler::new(16, 1.0);
        let mut rng = Rng::new(0xC05C);
        let draws = 120usize;
        for _ in 0..draws {
            let sess = SessionId(sampler.sample(&mut rng));
            let _ = store.resolve(sess);
            sim.touch(tenant_seed(master_seed, sess));
        }
        let st = store.stats();
        let ok = st.hits == sim.hits
            && st.misses == sim.misses
            && st.evictions == sim.evictions
            && st.regenerations == sim.regenerations;
        println!(
            "store hits/misses/evictions/regens {}/{}/{}/{}  sim {}/{}/{}/{}  -> {}",
            st.hits,
            st.misses,
            st.evictions,
            st.regenerations,
            sim.hits,
            sim.misses,
            sim.evictions,
            sim.regenerations,
            if ok { "EXACT" } else { "MISMATCH" },
        );
        assert!(ok, "LRU simulator diverged from BoundedKeyCache counters");
        obj(vec![
            ("draws", num(draws as f64)),
            ("hits", num(st.hits as f64)),
            ("misses", num(st.misses as f64)),
            ("evictions", num(st.evictions as f64)),
            ("regenerations", num(st.regenerations as f64)),
            ("exact", JsonValue::Bool(ok)),
        ])
    };

    // ---- 1M-session residency sweep: mint sessions, don't resolve keys.
    // A million real resolutions would spend the whole budget on keygen;
    // the capacity-vs-hit-rate curve only needs the access trace, so the
    // Zipf trace replays through the verified simulator at each capacity.
    let sessions = 1_000_000usize;
    let draws = 200_000usize;
    let capacities = [1_000usize, 10_000, 100_000];
    section(&format!(
        "million-session residency sweep ({draws} draws over {sessions} sessions, simulated LRU)"
    ));
    let mut session_rows: Vec<JsonValue> = Vec::new();
    for zipf_s in [0.8f64, 1.1] {
        // One trace per skew, shared by every capacity so the rows form a
        // curve over capacity alone.
        let sampler = ZipfSampler::new(sessions, zipf_s);
        let mut rng = Rng::new(0x51E5_5107);
        let trace: Vec<u64> = (0..draws)
            .map(|_| tenant_seed(master_seed, SessionId(sampler.sample(&mut rng))))
            .collect();
        let unique = trace.iter().collect::<std::collections::HashSet<_>>().len();
        for cap in capacities {
            let t0 = std::time::Instant::now();
            let mut sim = LruSim::new(cap);
            for &seed in &trace {
                sim.touch(seed);
            }
            println!(
                "s={zipf_s} cap={cap:>6}  hit-rate {:>5.3}   misses {:>6}   evictions {:>6}   regens {:>6}   ({} unique sessions, {:.0} ms)",
                sim.hit_rate(),
                sim.misses,
                sim.evictions,
                sim.regenerations,
                unique,
                t0.elapsed().as_secs_f64() * 1e3,
            );
            session_rows.push(obj(vec![
                ("zipf_s", num(zipf_s)),
                ("sessions", num(sessions as f64)),
                ("draws", num(draws as f64)),
                ("cache_capacity", num(cap as f64)),
                ("unique_sessions", num(unique as f64)),
                ("key_hit_rate", num(sim.hit_rate())),
                ("hits", num(sim.hits as f64)),
                ("misses", num(sim.misses as f64)),
                ("evictions", num(sim.evictions as f64)),
                ("regenerations", num(sim.regenerations as f64)),
            ]));
        }
    }

    let report = obj(vec![
        ("bench", s("tenants")),
        ("shards", num(shards as f64)),
        ("policy", s("consistent-hash")),
        ("results", arr(rows)),
        ("lru_sim_crosscheck", check),
        ("session_sweep", arr(session_rows)),
    ]);
    let path = "BENCH_tenants.json";
    match std::fs::write(path, report.to_string() + "\n") {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
}
