//! Multi-tenant serving sweep: tenant count x key-cache capacity through
//! a 2-shard consistent-hash cluster with per-tenant seeded stores,
//! emitting `BENCH_tenants.json` (cache hit rate, evictions and
//! regenerations, keyed-batch splits, p50/p99 latency) so CI tracks the
//! cost of key residency pressure across PRs alongside
//! `BENCH_cluster.json`.
//!
//! The interesting regime is capacity < tenants: every request whose
//! session was evicted pays a full keygen at admission (the
//! "regeneration" counter), which is exactly the memory-bandwidth
//! economics the paper's per-client serving story trades against.

#[path = "harness.rs"]
mod harness;

use std::sync::Arc;
use std::time::Duration;

use harness::section;
use taurus::cluster::{Cluster, ClusterOptions, PlacementPolicy, StoreFactory};
use taurus::coordinator::CoordinatorOptions;
use taurus::ir::builder::ProgramBuilder;
use taurus::params::TEST1;
use taurus::tenant::{client_secret, KeyStore, SeededTenantStore, SessionId};
use taurus::tfhe::pbs::encrypt_message;
use taurus::tfhe::SecretKeys;
use taurus::util::json::{arr, num, obj, s, JsonValue};
use taurus::util::rng::Rng;

fn main() {
    // Serving shape with a KS-dedup opportunity: d = x + y fans out to two
    // LUTs (one shared key switch, 2 PBS per request).
    let mut b = ProgramBuilder::new("tenant-bench", TEST1.width);
    let x = b.input();
    let y = b.input();
    let d = b.add(x, y);
    let r0 = b.lut_fn(d, |m| (m + 1) % 16);
    let r1 = b.lut_fn(d, |m| m ^ 1);
    b.outputs(&[r0, r1]);
    let prog = b.finish();

    let master_seed = 0xBE7C_0001u64;
    let requests = 48usize;
    let shards = 2usize;

    section(&format!(
        "tenant sweep ({requests} requests, {shards} shards, consistent-hash, TEST1)"
    ));

    let mut rows: Vec<JsonValue> = Vec::new();
    for tenants in [1usize, 4, 8] {
        // Client-side secrets once per tenant count (cheap).
        let sks: Vec<SecretKeys> = (0..tenants as u64)
            .map(|t| client_secret(&TEST1, master_seed, SessionId(t)))
            .collect();
        for cache_cap in [2usize, 8] {
            let factory: StoreFactory = Arc::new(move |_shard| {
                Arc::new(SeededTenantStore::new(&TEST1, master_seed, cache_cap))
                    as Arc<dyn KeyStore>
            });
            let mut cluster = Cluster::start_with_store_factory(
                prog.clone(),
                factory,
                ClusterOptions {
                    shards,
                    policy: PlacementPolicy::ConsistentHash,
                    queue_depth: None,
                    coordinator: CoordinatorOptions {
                        workers: 1,
                        batch_capacity: 8,
                        max_batch_wait: Duration::from_micros(500),
                        ..Default::default()
                    },
                },
            );
            let mut rng = Rng::new(17);
            let t0 = std::time::Instant::now();
            let pending: Vec<_> = (0..requests)
                .map(|i| {
                    let t = i % tenants;
                    let inputs = vec![
                        encrypt_message((i % 6) as u64, &sks[t], &mut rng),
                        encrypt_message((i % 4) as u64, &sks[t], &mut rng),
                    ];
                    cluster.submit(SessionId(t as u64), inputs).expect("submit")
                })
                .collect();
            for resp in &pending {
                let _ = resp.recv().expect("response");
            }
            let wall = t0.elapsed().as_secs_f64();
            drop(pending);

            let snap = cluster.snapshot();
            let resolves = snap.key_hits + snap.key_misses;
            let hit_rate =
                if resolves > 0 { snap.key_hits as f64 / resolves as f64 } else { 0.0 };
            println!(
                "tenants={tenants} cap={cache_cap}  {:>8.1} req/s   p50 {:>7.2} ms   p99 {:>7.2} ms   hit-rate {:>5.2}   regens {:>3}   splits {:>3}",
                requests as f64 / wall,
                snap.p50_latency_ms,
                snap.p99_latency_ms,
                hit_rate,
                snap.key_regenerations,
                snap.keyed_batch_splits,
            );
            rows.push(obj(vec![
                ("tenants", num(tenants as f64)),
                ("cache_capacity", num(cache_cap as f64)),
                ("requests", num(requests as f64)),
                ("req_per_s", num(requests as f64 / wall)),
                ("p50_latency_ms", num(snap.p50_latency_ms)),
                ("p99_latency_ms", num(snap.p99_latency_ms)),
                ("key_hit_rate", num(hit_rate)),
                ("key_hits", num(snap.key_hits as f64)),
                ("key_misses", num(snap.key_misses as f64)),
                ("key_evictions", num(snap.key_evictions as f64)),
                ("key_regenerations", num(snap.key_regenerations as f64)),
                ("keys_resident", num(snap.key_resident as f64)),
                ("keyed_batch_splits", num(snap.keyed_batch_splits as f64)),
                ("mean_batch_size", num(snap.mean_batch_size)),
            ]));
            cluster.shutdown();
        }
    }

    let report = obj(vec![
        ("bench", s("tenants")),
        ("shards", num(shards as f64)),
        ("policy", s("consistent-hash")),
        ("results", arr(rows)),
    ]);
    let path = "BENCH_tenants.json";
    match std::fs::write(path, report.to_string() + "\n") {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
}
