//! Wire-layer costs: frame codec throughput, key-upload bandwidth, and
//! the latency the TCP front end adds over in-process submission —
//! emitting `BENCH_wire.json` so CI tracks the serving boundary across
//! PRs alongside `BENCH_cluster.json`.
//!
//! Three measurements, all loopback (no network variance — this isolates
//! the protocol's own cost):
//!
//! - **frames/s** — encode+decode of a SUBMIT-sized frame (two TEST1
//!   ciphertexts), the per-request serialization tax.
//! - **key-upload MB/s** — streaming the TEST1 server keys (~9.5 MB, see
//!   EXPERIMENTS.md §Widths) at two chunk sizes; chunking trades frame
//!   count against transient buffer size, not bandwidth.
//! - **added latency** — wire submit (socket + codec + waiter thread)
//!   minus in-process `Cluster::submit` on the very same cluster.

#[path = "harness.rs"]
mod harness;

use std::sync::Arc;

use harness::{bench, section};
use taurus::cluster::{Cluster, ClusterOptions, PlacementPolicy, StoreFactory};
use taurus::coordinator::CoordinatorOptions;
use taurus::ir::builder::ProgramBuilder;
use taurus::params::TEST1;
use taurus::tenant::{KeyStore, SeededTenantStore, SessionId};
use taurus::tfhe::keycache;
use taurus::tfhe::pbs::encrypt_message;
use taurus::util::json::{arr, num, obj, s, JsonValue};
use taurus::util::rng::Rng;
use taurus::wire::codec::{put_u64, write_ciphertexts};
use taurus::wire::proto::{read_frame, write_frame, TAG_SUBMIT};
use taurus::wire::{Client, WireServer, WireServerOptions};

fn main() {
    // The serving quickstart program: d = 2x + y + 1 fanning out to two
    // LUTs (KS-dedup live), same artifact `taurus serve` compiles.
    let mut b = ProgramBuilder::new("wire-bench", TEST1.width);
    let x = b.input();
    let y = b.input();
    let d = b.dot(vec![x, y], vec![2, 1], 1);
    let r = b.relu(d, 3);
    let sg = b.lut_fn(d, |m| u64::from(m > 3));
    b.outputs(&[r, sg]);
    let prog = b.finish();

    let master_seed = 0xB44C_0001u64;
    let factory: StoreFactory = Arc::new(move |_shard| {
        Arc::new(SeededTenantStore::new(&TEST1, master_seed, 4)) as Arc<dyn KeyStore>
    });
    let cluster = Arc::new(Cluster::start_with_store_factory(
        prog,
        factory,
        ClusterOptions {
            shards: 1,
            policy: PlacementPolicy::RoundRobin,
            queue_depth: None,
            coordinator: CoordinatorOptions { workers: 1, ..Default::default() },
            qos: None,
        },
    ));
    let mut server =
        WireServer::start(cluster.clone(), "127.0.0.1:0", WireServerOptions::default())
            .expect("bind loopback listener");

    // The client's own keys (distinct from the stores' master seed), as
    // in the remote_client example.
    let keys = keycache::get(&TEST1, 0xBE9C_11E7);
    let mut client = Client::connect(server.local_addr()).expect("connect");
    let session = SessionId(7);
    let mut rng = Rng::new(1);
    let inputs =
        vec![encrypt_message(1, &keys.sk, &mut rng), encrypt_message(2, &keys.sk, &mut rng)];

    section("frame codec (SUBMIT-sized frames, in memory, TEST1)");
    let mut body = Vec::new();
    put_u64(&mut body, 1); // id
    put_u64(&mut body, session.0);
    put_u64(&mut body, 0); // no deadline
    write_ciphertexts(&mut body, &inputs);
    let frame_bytes = 4 + 1 + body.len();
    let r_frame = bench("frame encode+decode roundtrip", 1.0, || {
        let mut buf = Vec::with_capacity(5 + body.len());
        write_frame(&mut buf, TAG_SUBMIT, &body).expect("write");
        let f = read_frame(&mut buf.as_slice()).expect("read").expect("one frame");
        assert_eq!(f.tag, TAG_SUBMIT);
    });
    let frames_per_s = 1.0 / r_frame.mean_s;
    println!("frame size {frame_bytes} B -> {frames_per_s:.0} frames/s");

    section("key upload over loopback (TEST1, ~9.5 MB per set)");
    let upload_mb = (TEST1.bsk_bytes() + TEST1.ksk_bytes()) as f64 / (1024.0 * 1024.0);
    let mut upload_rows: Vec<JsonValue> = Vec::new();
    for chunk_bytes in [256usize << 10, 2 << 20] {
        let r = bench(&format!("key upload chunk={}KiB", chunk_bytes >> 10), 1.5, || {
            client.upload_keys_chunked(session, &keys.server, chunk_bytes).expect("upload");
        });
        let mb_per_s = upload_mb / r.mean_s;
        println!("  -> {upload_mb:.1} MB at {mb_per_s:.0} MB/s");
        upload_rows.push(obj(vec![
            ("chunk_bytes", num(chunk_bytes as f64)),
            ("upload_mb", num(upload_mb)),
            ("mean_s", num(r.mean_s)),
            ("mb_per_s", num(mb_per_s)),
        ]));
    }

    section("submit latency: wire vs in-process (same cluster, same keys)");
    let r_local = bench("in-process submit+recv", 2.0, || {
        let outs = cluster
            .submit(session, inputs.clone())
            .expect("submit")
            .recv()
            .expect("response");
        assert_eq!(outs.len(), 2);
    });
    let r_wire = bench("wire submit (socket + codec + waiter)", 2.0, || {
        let outs = client.submit(session, &inputs).expect("remote submit");
        assert_eq!(outs.len(), 2);
    });
    let added_ms = (r_wire.mean_s - r_local.mean_s) * 1e3;
    println!(
        "added latency: {added_ms:.3} ms over {:.3} ms in-process ({:+.1}%)",
        r_local.mean_s * 1e3,
        100.0 * (r_wire.mean_s / r_local.mean_s - 1.0),
    );

    let report = obj(vec![
        ("bench", s("wire")),
        ("param", s(TEST1.name)),
        ("frame_bytes", num(frame_bytes as f64)),
        ("frames_per_s", num(frames_per_s)),
        ("key_upload", arr(upload_rows)),
        (
            "submit",
            obj(vec![
                ("in_process_mean_ms", num(r_local.mean_s * 1e3)),
                ("wire_mean_ms", num(r_wire.mean_s * 1e3)),
                ("wire_min_ms", num(r_wire.min_s * 1e3)),
                ("added_latency_ms", num(added_ms)),
            ]),
        ),
    ]);
    let path = "BENCH_wire.json";
    match std::fs::write(path, report.to_string() + "\n") {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }

    drop(client);
    server.shutdown();
    if let Ok(mut c) = Arc::try_unwrap(cluster) {
        c.shutdown();
    }
}
