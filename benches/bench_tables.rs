//! End-to-end benches over the paper's evaluation: times the regeneration
//! of every table/figure (one criterion-style target per paper artifact)
//! and prints the resulting speedup columns, so `cargo bench` reproduces
//! the evaluation section in one shot.

#[path = "harness.rs"]
mod harness;

use harness::{bench, section};
use taurus::arch::TaurusConfig;
use taurus::eval;

fn main() {
    let cfg = TaurusConfig::default();

    section("table/figure regeneration (model evaluation)");
    for id in ["1", "3", "6", "13a", "13b"] {
        bench(&format!("eval {id} (cheap analytic)"), 0.2, || {
            std::hint::black_box(eval::run_one(id, &cfg).unwrap());
        });
    }
    for id in ["2", "4", "14", "15", "16", "obs5", "dedup", "ablation"] {
        bench(&format!("eval {id} (workload sims)"), 0.0, || {
            std::hint::black_box(eval::run_one(id, &cfg).unwrap());
        });
    }

    section("resulting headline numbers");
    let t2 = eval::run_one("2", &cfg).unwrap();
    println!("{}", t2.render());
    let t4 = eval::run_one("4", &cfg).unwrap();
    println!("{}", t4.render());
}
