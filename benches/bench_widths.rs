//! Width sweep over the functional parameter sets {3, 5, 8, 10} bits:
//! keygen wall clock (monolithic vs 4-worker chunked), key material
//! bytes, PBS latency, amortized Fourier-BSK bytes per PBS at batch 8,
//! and the batch-8 blind-rotation thread sweep {1, 2, 4} (with the
//! per-set blocked-FFT selection recorded). Emits `BENCH_widths.json` so
//! CI tracks how the wide-width functional path costs evolve across PRs
//! (EXPERIMENTS.md §Widths and §FFT).

#[path = "harness.rs"]
mod harness;

use std::time::Instant;

use harness::{bench, section};
use taurus::params::FUNCTIONAL_SETS;
use taurus::tfhe::fft::blocked_for_poly;
use taurus::tfhe::keygen::KeygenOptions;
use taurus::tfhe::pbs::encrypt_message;
use taurus::tfhe::{make_lut_poly, PbsContext, SecretKeys, ServerKeys};
use taurus::util::json::{arr, num, obj, s, JsonValue};
use taurus::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(41);
    let mut rows: Vec<JsonValue> = Vec::new();

    section("width sweep: keygen + PBS across the functional sets");
    for p in FUNCTIONAL_SETS {
        let sk = SecretKeys::generate(p, &mut rng);

        // Keygen is seconds-scale at the wide widths, so time single shots
        // rather than harness iterations.
        let t0 = Instant::now();
        let keys = ServerKeys::generate_seeded(&sk, 7, &KeygenOptions::monolithic());
        let keygen_ms = t0.elapsed().as_secs_f64() * 1e3;
        let t0 = Instant::now();
        let keys_par = ServerKeys::generate_seeded(&sk, 7, &KeygenOptions::with_workers(4));
        let keygen_par_ms = t0.elapsed().as_secs_f64() * 1e3;
        let bsk_bytes = keys.bsk.bytes();
        let ksk_bytes = keys.ksk.bytes();
        println!(
            "{:<8} width {:>2}  keygen {:>9.0} ms (1 worker) {:>9.0} ms (4 workers)   \
             fourier BSK {:>6.1} MB   KSK {:>6.1} MB",
            p.name,
            p.width,
            keygen_ms,
            keygen_par_ms,
            bsk_bytes as f64 / 1e6,
            ksk_bytes as f64 / 1e6,
        );
        drop(keys_par);

        let mut ctx = PbsContext::new(p);
        let lut = make_lut_poly(p, |m| m);
        let ct = encrypt_message(3, &sk, &mut rng);
        let r = bench(&format!("  pbs {} (n={} N={})", p.name, p.n, p.big_n), 0.6, || {
            std::hint::black_box(ctx.pbs(&ct, &keys, &lut));
        });
        let pbs_ms = r.mean_s * 1e3;

        // Amortized BSK traffic at batch 8 (the key-reuse lever the wide
        // sets lean on hardest — their per-PBS key material is largest).
        let bsz = 8usize;
        let cts: Vec<_> =
            (0..bsz).map(|i| encrypt_message(i as u64 % 8, &sk, &mut rng)).collect();
        ctx.take_bsk_bytes_streamed();
        std::hint::black_box(ctx.pbs_batch(&cts, &keys, &lut));
        let bsk_per_pbs = ctx.take_bsk_bytes_streamed() as f64 / bsz as f64;
        println!(
            "      {:>9.1} ms/PBS   BSK/PBS at batch {bsz}: {:>6.1} MB ({:.1}x reuse)",
            pbs_ms,
            bsk_per_pbs / 1e6,
            bsk_bytes as f64 / bsk_per_pbs.max(1.0),
        );

        rows.push(obj(vec![
            ("params", s(p.name)),
            ("width", num(p.width as f64)),
            ("keygen_ms", num(keygen_ms)),
            ("keygen_ms_4workers", num(keygen_par_ms)),
            ("fourier_bsk_bytes", num(bsk_bytes as f64)),
            ("ksk_bytes", num(ksk_bytes as f64)),
            ("pbs_ms", num(pbs_ms)),
            ("bsk_bytes_per_pbs_batch8", num(bsk_per_pbs)),
        ]));

        // Blind-rotation thread sweep at batch 8: wall clock only — the
        // output bits are invariant by construction (the conformance
        // suite pins that), so these rows record the scaling, they don't
        // assert it. util::json has no bool; blocked_fft is 0/1.
        let blocked = if blocked_for_poly(p.big_n) { 1.0 } else { 0.0 };
        let mut t1_ns = 0.0f64;
        for threads in [1usize, 2, 4] {
            ctx.set_fft_threads(threads);
            let r = bench(&format!("  pbs_batch {} B={bsz} T={threads}", p.name), 0.5, || {
                std::hint::black_box(ctx.pbs_batch(&cts, &keys, &lut));
            });
            let ns_per_pbs = r.mean_s * 1e9 / bsz as f64;
            if threads == 1 {
                t1_ns = ns_per_pbs;
            }
            let speedup = t1_ns / ns_per_pbs.max(1e-9);
            println!(
                "      threads {threads}: {:>12.0} ns/PBS at batch {bsz}  ({:.2}x vs 1 thread, {} fft)",
                ns_per_pbs,
                speedup,
                if blocked == 1.0 { "blocked" } else { "monolithic" },
            );
            rows.push(obj(vec![
                ("params", s(p.name)),
                ("width", num(p.width as f64)),
                ("threads", num(threads as f64)),
                ("blocked_fft", num(blocked)),
                ("ns_per_pbs_batch8", num(ns_per_pbs)),
                ("speedup_vs_t1", num(speedup)),
            ]));
        }
        ctx.set_fft_threads(1);
    }

    let report = obj(vec![("bench", s("widths")), ("results", arr(rows))]);
    let path = "BENCH_widths.json";
    match std::fs::write(path, report.to_string() + "\n") {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
}
