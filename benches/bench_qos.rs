//! QoS sweep: seed-deterministic Zipf traffic pushed through a 2-shard
//! seeded-tenant cluster with weighted-fair admission ON vs OFF, at
//! uniform vs heavy-skew popularity, emitting `BENCH_qos.json` (Jain's
//! fairness index over per-tenant service and latency, cold-tenant p99,
//! throttle/rejection counts) so CI tracks multi-tenant isolation across
//! PRs alongside `BENCH_tenants.json`.
//!
//! The row to read: zipf_s=1.2 with QoS off lets the hot tenant's burst
//! queue ahead of everyone (latency Jain's index sags); the same trace
//! with token buckets + DRR keeps cold tenants' p99 flat and pushes the
//! excess into typed `Throttled` rejections instead of queue delay.
//! EXPERIMENTS.md §Traffic records the interpretation.

#[path = "harness.rs"]
mod harness;

use std::sync::Arc;
use std::time::Duration;

use harness::section;
use taurus::cluster::{Cluster, ClusterError, ClusterOptions, PlacementPolicy, StoreFactory};
use taurus::coordinator::CoordinatorOptions;
use taurus::ir::builder::ProgramBuilder;
use taurus::params::TEST1;
use taurus::tenant::{client_secret, KeyStore, SeededTenantStore, SessionId};
use taurus::tfhe::pbs::encrypt_message;
use taurus::tfhe::SecretKeys;
use taurus::traffic::{LoadPlan, LoadSpec, QosOptions, TokenBucketSpec};
use taurus::util::json::{arr, num, obj, s, JsonValue};
use taurus::util::rng::Rng;
use taurus::util::stats::jains_index;

fn main() {
    // Serving shape with a KS-dedup opportunity: d = x + y fans out to two
    // LUTs (one shared key switch, 2 PBS per request).
    let mut b = ProgramBuilder::new("qos-bench", TEST1.width);
    let x = b.input();
    let y = b.input();
    let d = b.add(x, y);
    let r0 = b.lut_fn(d, |m| (m + 1) % 16);
    let r1 = b.lut_fn(d, |m| m ^ 1);
    b.outputs(&[r0, r1]);
    let prog = b.finish();

    let master_seed = 0xBE7C_0905u64;
    let tenants = 8usize;
    let events = 64usize;
    let shards = 2usize;
    // Per-tenant admission contract for the QoS-on legs: generous enough
    // that uniform traffic sails through, tight enough that the zipf-1.2
    // hot tenant's burst hits the bucket.
    let rate_per_s = 100.0f64;
    let burst = 4.0f64;

    let sks: Vec<SecretKeys> = (0..tenants as u64)
        .map(|t| client_secret(&TEST1, master_seed, SessionId(t)))
        .collect();

    section(&format!(
        "qos sweep ({events} zipf arrivals, {tenants} tenants, {shards} shards, paced to the load plan, TEST1)"
    ));

    let mut rows: Vec<JsonValue> = Vec::new();
    for zipf_s in [0.0f64, 1.2] {
        // Same trace for the on/off pair: the comparison isolates the
        // admission policy, not the draw.
        let plan = LoadPlan::from_seed(
            0x51E5_0905,
            &LoadSpec { tenants, zipf_s, events, ..Default::default() },
        );
        for qos_on in [false, true] {
            let qos = qos_on.then(|| QosOptions {
                bucket: Some(TokenBucketSpec::new(rate_per_s, burst)),
                tenant_queue_depth: 16,
                ..QosOptions::default()
            });
            let factory: StoreFactory = Arc::new(move |_shard| {
                Arc::new(SeededTenantStore::new(&TEST1, master_seed, tenants))
                    as Arc<dyn KeyStore>
            });
            let mut cluster = Cluster::start_with_store_factory(
                prog.clone(),
                factory,
                ClusterOptions {
                    shards,
                    policy: PlacementPolicy::ConsistentHash,
                    queue_depth: None,
                    coordinator: CoordinatorOptions {
                        workers: 1,
                        batch_capacity: 8,
                        max_batch_wait: Duration::from_micros(500),
                        ..Default::default()
                    },
                    qos,
                },
            );
            let mut rng = Rng::new(31);
            let t0 = std::time::Instant::now();
            let mut pending = Vec::new();
            let mut throttled = 0usize;
            let mut queue_full = 0usize;
            for (i, ev) in plan.events().iter().enumerate() {
                // Pace to the plan: buckets refill in wall time, so the
                // trace must reach the cluster at its scheduled offsets.
                let elapsed = t0.elapsed();
                if ev.at > elapsed {
                    std::thread::sleep(ev.at - elapsed);
                }
                let t = ev.session.0 as usize;
                let inputs = vec![
                    encrypt_message((i % 6) as u64, &sks[t], &mut rng),
                    encrypt_message((i % 4) as u64, &sks[t], &mut rng),
                ];
                match cluster.submit(ev.session, inputs) {
                    Ok(r) => pending.push(r),
                    Err(ClusterError::Throttled) => throttled += 1,
                    Err(ClusterError::TenantQueueFull) => queue_full += 1,
                    Err(e) => panic!("submit failed: {e}"),
                }
            }
            for resp in &pending {
                let _ = resp.recv().expect("response");
            }
            let wall = t0.elapsed().as_secs_f64();
            let served = pending.len();
            drop(pending);

            let snap = cluster.snapshot();
            // Fairness over what each tenant got: served-request share and
            // mean latency. Latency Jain's index is the starvation signal
            // — a hot tenant monopolizing the queue drags everyone else's
            // mean up unevenly.
            let served_per_tenant: Vec<f64> =
                snap.session_requests.values().map(|&n| n as f64).collect();
            let mean_latency_per_tenant: Vec<f64> = snap
                .session_latency_ms
                .values()
                .filter(|v| !v.is_empty())
                .map(|v| v.iter().sum::<f64>() / v.len() as f64)
                .collect();
            let served_jain = jains_index(&served_per_tenant);
            let latency_jain = jains_index(&mean_latency_per_tenant);
            // The coldest tenant still served: its p99 is the isolation
            // headline (does someone else's burst cost ME tail latency?).
            let cold_p99 = snap
                .session_requests
                .iter()
                .min_by_key(|(_, &n)| n)
                .and_then(|(&sess, _)| snap.tenant_p99_ms(sess))
                .unwrap_or(0.0);
            println!(
                "s={zipf_s:<3} qos={:<3}  served {served:>2}/{events}  throttled {throttled:>2}  queue-full {queue_full:>2}  jain(served) {served_jain:>5.3}  jain(latency) {latency_jain:>5.3}  cold-p99 {cold_p99:>7.2} ms",
                if qos_on { "on" } else { "off" },
            );
            rows.push(obj(vec![
                ("zipf_s", num(zipf_s)),
                ("qos", JsonValue::Bool(qos_on)),
                ("offered", num(events as f64)),
                ("served", num(served as f64)),
                ("throttled", num(throttled as f64)),
                ("queue_full", num(queue_full as f64)),
                ("qos_throttled_counter", num(snap.qos_throttled as f64)),
                ("qos_queue_rejections_counter", num(snap.qos_queue_rejections as f64)),
                ("jain_served", num(served_jain)),
                ("jain_mean_latency", num(latency_jain)),
                ("cold_tenant_p99_ms", num(cold_p99)),
                ("p99_latency_ms", num(snap.p99_latency_ms)),
                ("req_per_s", num(served as f64 / wall)),
            ]));
            cluster.shutdown();
        }
    }

    let report = obj(vec![
        ("bench", s("qos")),
        ("tenants", num(tenants as f64)),
        ("events", num(events as f64)),
        ("shards", num(shards as f64)),
        ("bucket_rate_per_s", num(rate_per_s)),
        ("bucket_burst", num(burst)),
        ("results", arr(rows)),
    ]);
    let path = "BENCH_qos.json";
    match std::fs::write(path, report.to_string() + "\n") {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
}
