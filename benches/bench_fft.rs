//! L3 hot-path microbench: the negacyclic FFT (the operation the paper's
//! FFT-A/FFT-B clusters accelerate) across the polynomial degrees of the
//! evaluation parameter sets, plus the external product built on it.

#[path = "harness.rs"]
mod harness;

use harness::{bench, section};
use taurus::params::TEST1;
use taurus::tfhe::fft::{C64, FftPlan};
use taurus::tfhe::ggsw::{external_product_add, ExtProdScratch};
use taurus::tfhe::glwe::GlweCiphertext;
use taurus::tfhe::bsk::encrypt_ggsw;
use taurus::tfhe::SecretKeys;
use taurus::util::rng::Rng;

fn main() {
    section("negacyclic FFT forward+inverse (per polynomial)");
    let mut rng = Rng::new(1);
    for log_n in [9usize, 11, 12, 15, 16] {
        let n = 1 << log_n;
        let plan = FftPlan::new(n);
        let p: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
        let mut f = vec![C64::default(); n / 2];
        let mut out = vec![0u64; n];
        let r = bench(&format!("fft fwd+inv N=2^{log_n}"), 0.4, || {
            plan.forward_negacyclic_torus(&p, &mut f);
            plan.inverse_negacyclic_add_torus(&mut f, &mut out);
        });
        // FLOP estimate: 2 * 5 * (N/2) log2(N/2) per direction.
        let flops = 2.0 * 5.0 * (n as f64 / 2.0) * ((n / 2) as f64).log2();
        println!(
            "{:<46}   -> {:.2} GFLOP/s",
            "", flops / r.min_s / 1e9
        );
    }

    section("external product (GGSW box GLWE), TEST1");
    let sk = SecretKeys::generate(&TEST1, &mut rng);
    let plan = FftPlan::new(TEST1.big_n);
    let g = encrypt_ggsw(1, &sk, &mut rng, &plan);
    let glwe_in: Vec<u64> = (0..(TEST1.k + 1) * TEST1.big_n).map(|_| rng.next_u64()).collect();
    let mut acc = GlweCiphertext::zero(TEST1.k, TEST1.big_n);
    let mut scratch = ExtProdScratch::new(&TEST1);
    bench("external_product N=512 l=3", 0.5, || {
        external_product_add(&plan, &TEST1, &g, &glwe_in, &mut acc, &mut scratch);
    });
}
