//! Shared micro-bench harness (criterion is not in the offline registry).
//! Reports mean/min wall-clock per iteration after a warmup, adapting the
//! iteration count to the cost of the workload.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_s: f64,
    pub min_s: f64,
}

/// Run `f` until ~`budget_s` of wall clock is spent (min 3 iterations),
/// after one warmup call. Returns timing stats and prints a row.
pub fn bench<F: FnMut()>(name: &str, budget_s: f64, mut f: F) -> BenchResult {
    f(); // warmup
    let mut times = Vec::new();
    let start = Instant::now();
    while start.elapsed().as_secs_f64() < budget_s || times.len() < 3 {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
        if times.len() >= 10_000 {
            break;
        }
    }
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let fmt = |s: f64| {
        if s >= 1.0 {
            format!("{s:.3} s")
        } else if s >= 1e-3 {
            format!("{:.3} ms", s * 1e3)
        } else {
            format!("{:.3} us", s * 1e6)
        }
    };
    println!(
        "{name:<44} {:>6} iters   mean {:>12}   min {:>12}",
        times.len(),
        fmt(mean),
        fmt(min)
    );
    BenchResult { name: name.into(), iters: times.len() as u64, mean_s: mean, min_s: min }
}

/// Print a section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}
