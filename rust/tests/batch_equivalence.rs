//! Batched blind rotation must be a pure performance transform: `pbs_batch`
//! decrypts identically to sequential `pbs` at every batch size, the
//! coordinator's fused sweeps keep serving correctly (round-robin intact,
//! `inflight` drained), and the measured key-reuse traffic agrees with the
//! `arch` bandwidth model.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use taurus::arch::memory;
use taurus::arch::TaurusConfig;
use taurus::coordinator::{BackendKind, Coordinator, CoordinatorOptions};
use taurus::ir::builder::ProgramBuilder;
use taurus::ir::interp;
use taurus::params::TEST1;
use taurus::tfhe::pbs::{decrypt_message, encrypt_message};
use taurus::tfhe::{make_lut_poly, PbsContext, SecretKeys, ServerKeys};
use taurus::util::prop::check;
use taurus::util::rng::Rng;

/// Shared fixture: keygen once (dominates test time).
struct Fixture {
    sk: SecretKeys,
    keys: ServerKeys,
}

fn fixture() -> &'static Fixture {
    use std::sync::OnceLock;
    static F: OnceLock<Fixture> = OnceLock::new();
    F.get_or_init(|| {
        let mut rng = Rng::new(0xBA7C);
        let sk = SecretKeys::generate(&TEST1, &mut rng);
        let keys = ServerKeys::generate(&sk, &mut rng);
        Fixture { sk, keys }
    })
}

#[test]
fn prop_pbs_batch_decrypts_identically_to_sequential() {
    let f = fixture();
    let mut ctx = PbsContext::new(&TEST1);
    check("pbs_batch_equivalence", 3, |rng| {
        let table: Vec<u64> = (0..16).map(|_| rng.below(16)).collect();
        let t2 = table.clone();
        let lut = make_lut_poly(&TEST1, move |m| t2[m as usize]);
        for bsz in [1usize, 3, 8] {
            let msgs: Vec<u64> = (0..bsz).map(|_| rng.below(8)).collect();
            let cts: Vec<_> = msgs.iter().map(|&m| encrypt_message(m, &f.sk, rng)).collect();
            let batched = ctx.pbs_batch(&cts, &f.keys, &lut);
            for (b, (m, out)) in msgs.iter().zip(&batched).enumerate() {
                let seq = ctx.pbs(&cts[b], &f.keys, &lut);
                let got_batch = decrypt_message(out, &f.sk);
                let got_seq = decrypt_message(&seq, &f.sk);
                let exp = table[*m as usize] % 16;
                if got_batch != exp || got_seq != exp {
                    return Err(format!(
                        "bsz={bsz} b={b} m={m}: batch {got_batch} seq {got_seq} exp {exp}"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn batch_key_reuse_matches_arch_bandwidth_model() {
    // The native pipeline counts the Fourier-BSK bytes its blind rotations
    // actually stream; the arch memory model predicts the same quantity
    // for a single-cluster machine whose round-robin depth covers the
    // batch. They must agree (the measured side may come in slightly
    // under: keys whose rotation amounts are all zero are skipped, which
    // happens with probability ~1/2N per mask element).
    let f = fixture();
    let mut ctx = PbsContext::new(&TEST1);
    let lut = make_lut_poly(&TEST1, |m| m);
    let mut rng = Rng::new(5150);
    let bsz = 8usize;
    let cts: Vec<_> = (0..bsz).map(|i| encrypt_message(i as u64 % 8, &f.sk, &mut rng)).collect();

    ctx.take_bsk_bytes_streamed();
    let _ = ctx.pbs_batch(&cts, &f.keys, &lut);
    let measured_per_pbs = ctx.take_bsk_bytes_streamed() as f64 / bsz as f64;

    let mut cfg = TaurusConfig::default();
    cfg.clusters = 1;
    cfg.rr_ciphertexts = bsz;
    cfg.complex_bytes = 16; // native pipeline stores f64 re + f64 im
    let model_per_pbs = memory::amortized_bsk_bytes_per_pbs(&TEST1, &cfg, bsz);
    assert!(
        measured_per_pbs <= model_per_pbs * 1.0001,
        "measured {measured_per_pbs} exceeds model {model_per_pbs}"
    );
    assert!(
        measured_per_pbs >= model_per_pbs * 0.90,
        "measured {measured_per_pbs} far below model {model_per_pbs}"
    );
    // And the in-memory key size agrees with the model's stream unit.
    assert_eq!(f.keys.bsk.bytes() as u64, memory::bsk_stream_bytes(&TEST1, &cfg));
}

#[test]
fn coordinator_batched_sweeps_round_robin_and_drain() {
    let mut rng = Rng::new(4242);
    let sk = SecretKeys::generate(&TEST1, &mut rng);
    let keys = Arc::new(ServerKeys::generate(&sk, &mut rng));
    let keys2 = keys.clone();
    let mut b = ProgramBuilder::new("batch-serve", TEST1.width);
    let x = b.input();
    let y = b.input();
    let s = b.add(x, y);
    let r = b.lut_fn(s, |m| (m * 5 + 2) % 16);
    b.output(r);
    let prog = b.finish();

    let mut coord = Coordinator::start(
        prog.clone(),
        keys,
        CoordinatorOptions {
            workers: 3,
            batch_capacity: 4,
            max_batch_wait: Duration::from_millis(2),
            backend: BackendKind::Native,
            ..Default::default()
        },
    );
    let queries: Vec<(u64, u64)> = (0..12).map(|i| (i % 5, (i * 7) % 5)).collect();
    let mut pending = Vec::new();
    for &(mx, my) in &queries {
        let inputs =
            vec![encrypt_message(mx, &sk, &mut rng), encrypt_message(my, &sk, &mut rng)];
        pending.push(coord.submit(inputs).expect("submit"));
    }
    for (rx, &(mx, my)) in pending.iter().zip(&queries) {
        let outs = rx.recv().expect("response");
        let exp = interp::eval(&prog, &[mx, my]);
        assert_eq!(decrypt_message(&outs[0], &sk), exp[0], "query ({mx},{my})");
    }
    let snap = coord.metrics.snapshot();
    assert_eq!(snap.requests, 12);
    assert_eq!(snap.pbs_executed, 12 * prog.pbs_count());
    assert!(snap.batches >= 3, "work round-robined over several batches");
    assert_eq!(coord.inflight.load(Ordering::SeqCst), 0, "inflight drained");
    // Fused sweeps never stream more than one full BSK per PBS.
    assert!(snap.bsk_bytes_streamed > 0);
    assert!(snap.bsk_bytes_per_pbs <= keys2.bsk.bytes() as f64 + 1.0);
    coord.shutdown();
}
