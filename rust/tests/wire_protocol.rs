//! Wire-protocol integration suite: codec round-trips at every paper
//! width, hostile-input behavior, and loopback serving end-to-end.
//!
//! Three layers under test:
//!
//! 1. **Codec** — ciphertext and chunked server-key serialization must be
//!    a *bitwise* identity at every functional width {3, 5, 8, 10}
//!    (property tests over synthetic random planes — no keygen needed, so
//!    the wide shapes stay cheap), and every malformed input must fail
//!    typed: truncated buffers, bad versions, hostile length prefixes.
//! 2. **Protocol/server** — garbage frames answer `BadRequest` and never
//!    kill the listener; a fresh client connects and serves right after.
//! 3. **End-to-end** — a client uploads its own keys (material the
//!    server's seeded stores canNOT derive), submits over TCP, and the
//!    remote ciphertexts are bitwise identical to in-process
//!    `Cluster::submit` of the same inputs. The uploaded keys stay
//!    pinned under LRU pressure (`key_regenerations == 0`) and serve
//!    from EVERY shard (round-robin routing over the cross-shard
//!    register broadcast). `StaticKeys` clusters reject uploads typed
//!    (`RegisterUnsupported`) and keep serving on the same connection.
//!
//! Case counts honor `PROP_CASES` (CI's wire job runs 2).

use std::io::Read;
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use taurus::cluster::{Cluster, ClusterOptions, PlacementPolicy, StoreFactory};
use taurus::coordinator::CoordinatorOptions;
use taurus::ir::builder::ProgramBuilder;
use taurus::ir::interp;
use taurus::ir::Program;
use taurus::params::{ParamSet, FUNCTIONAL_SETS, TEST1};
use taurus::tenant::{client_secret, KeyStore, SeededTenantStore, SessionId};
use taurus::tfhe::keycache;
use taurus::tfhe::pbs::{decrypt_message, encrypt_message};
use taurus::tfhe::{
    server_keys_bitwise_eq, FourierBsk, FourierGgsw, Ksk, LweCiphertext, ServerKeys,
};
use taurus::util::prop;
use taurus::util::rng::Rng;
use taurus::wire::codec::{
    decode_server_keys, encode_server_keys, read_ciphertexts, write_ciphertexts, Reader,
};
use taurus::wire::proto::{read_frame, write_frame, TAG_ACK, TAG_HELLO};
use taurus::wire::{Client, Status, WireError, WireServer, WireServerOptions};

/// Random `ServerKeys` at a parameter set's exact shapes — arbitrary bit
/// patterns (including non-finite f64s), because the codec must be a
/// bitwise transport, not a numeric one. No keygen: WIDE10 planes fill
/// in milliseconds instead of minutes.
fn synthetic_keys(p: &'static ParamSet, rng: &mut Rng) -> ServerKeys {
    let plane = p.ggsw_rows() * (p.k + 1) * p.half_n();
    let ggsw = (0..p.n)
        .map(|_| FourierGgsw {
            re: (0..plane).map(|_| f64::from_bits(rng.next_u64())).collect(),
            im: (0..plane).map(|_| f64::from_bits(rng.next_u64())).collect(),
            rows: p.ggsw_rows(),
            k1: p.k + 1,
            nh: p.half_n(),
        })
        .collect();
    let ksk_len = p.long_dim() * p.ks_level * (p.n + 1);
    ServerKeys {
        params: p.clone(),
        bsk: FourierBsk { ggsw },
        ksk: Ksk {
            data: (0..ksk_len).map(|_| rng.next_u64()).collect(),
            long_dim: p.long_dim(),
            level: p.ks_level,
            short_len: p.n + 1,
        },
    }
}

#[test]
fn ciphertext_batches_roundtrip_bitwise_at_every_width() {
    for p in FUNCTIONAL_SETS {
        prop::check(&format!("wire_ct_roundtrip_{}", p.name), 2, |rng| {
            let count = 1 + rng.below_usize(3);
            let cts: Vec<LweCiphertext> = (0..count)
                .map(|_| LweCiphertext {
                    data: (0..p.long_dim() + 1).map(|_| rng.next_u64()).collect(),
                })
                .collect();
            let mut buf = Vec::new();
            write_ciphertexts(&mut buf, &cts);
            let mut r = Reader::new(&buf);
            let back = read_ciphertexts(&mut r).map_err(|e| e.to_string())?;
            r.expect_eof().map_err(|e| e.to_string())?;
            if back != cts {
                return Err(format!("{}: decoded batch differs", p.name));
            }
            Ok(())
        });
    }
}

#[test]
fn server_keys_roundtrip_bitwise_at_every_width() {
    // One synthetic key set per functional width, streamed at a chunk
    // size that forces many chunks of both kinds, reassembled, and
    // compared with the same bitwise oracle keygen determinism uses.
    for p in FUNCTIONAL_SETS {
        prop::check(&format!("wire_keys_roundtrip_{}", p.name), 1, |rng| {
            let keys = synthetic_keys(p, rng);
            let chunk_bytes = (p.bsk_bytes() / 7).max(1024);
            let blob = encode_server_keys(&keys, chunk_bytes);
            let back = decode_server_keys(&blob).map_err(|e| e.to_string())?;
            if !server_keys_bitwise_eq(&keys, &back) {
                return Err(format!("{}: reassembled keys differ bitwise", p.name));
            }
            Ok(())
        });
    }
}

#[test]
fn malformed_key_blobs_fail_typed_never_panic() {
    let mut rng = Rng::new(0xBAD_B10B);
    let keys = synthetic_keys(&TEST1, &mut rng);
    let blob = encode_server_keys(&keys, 64 << 10);

    // Truncation anywhere — inside the header, inside a chunk — is a
    // typed decode error, never a panic or a wild allocation.
    for cut in [3, blob.len() / 2, blob.len() - 1] {
        match decode_server_keys(&blob[..cut]) {
            Err(WireError::Malformed(_)) => {}
            other => panic!("truncated at {cut}: wanted Malformed, got {other:?}"),
        }
    }

    // Future codec version: typed, with the offending byte reported.
    let mut vbad = blob.clone();
    vbad[4] = 9; // version byte follows the 4-byte magic
    match decode_server_keys(&vbad) {
        Err(WireError::UnsupportedVersion { got: 9 }) => {}
        other => panic!("wanted UnsupportedVersion, got {other:?}"),
    }

    // Unknown parameter-set name: shapes cannot be derived, typed error.
    let mut nbad = blob.clone();
    nbad[6] ^= 0x55; // inside the short param name
    assert!(matches!(decode_server_keys(&nbad), Err(WireError::Malformed(_))));

    // Trailing garbage after the last chunk is malformed, not ignored.
    let mut tbad = blob.clone();
    tbad.extend_from_slice(&[0xAA; 7]);
    assert!(matches!(decode_server_keys(&tbad), Err(WireError::Malformed(_))));
}

/// The `taurus serve` quickstart program at TEST1 width: fanout
/// d = 2x + y + 1 into relu(d) and sign(d), KS-dedup live.
fn demo_program() -> Program {
    let mut b = ProgramBuilder::new("wire-demo", TEST1.width);
    let x = b.input();
    let y = b.input();
    let d = b.dot(vec![x, y], vec![2, 1], 1);
    let r = b.relu(d, 3);
    let s = b.lut_fn(d, |m| u64::from(m > 3));
    b.outputs(&[r, s]);
    b.finish()
}

const MASTER_SEED: u64 = 0x5EED_0911;

fn start_tenant_cluster(shards: usize, cache_cap: usize) -> (WireServer, Arc<Cluster>) {
    let factory: StoreFactory = Arc::new(move |_shard| {
        Arc::new(SeededTenantStore::new(&TEST1, MASTER_SEED, cache_cap)) as Arc<dyn KeyStore>
    });
    let cluster = Arc::new(Cluster::start_with_store_factory(
        demo_program(),
        factory,
        ClusterOptions {
            shards,
            // Round-robin: every shard must serve the uploaded session,
            // which only works if registration broadcast cluster-wide.
            policy: PlacementPolicy::RoundRobin,
            queue_depth: None,
            coordinator: CoordinatorOptions { workers: 1, ..Default::default() },
            qos: None,
        },
    ));
    let server = WireServer::start(cluster.clone(), "127.0.0.1:0", WireServerOptions::default())
        .expect("bind loopback listener");
    (server, cluster)
}

fn shutdown(mut server: WireServer, cluster: Arc<Cluster>) {
    server.shutdown();
    if let Ok(mut c) = Arc::try_unwrap(cluster) {
        c.shutdown();
    }
}

#[test]
fn loopback_uploaded_keys_serve_bitwise_and_stay_pinned() {
    let (server, cluster) = start_tenant_cluster(2, 2);
    let prog = demo_program();
    let mut client = Client::connect(server.local_addr()).expect("connect");
    assert_eq!(client.params().name, TEST1.name, "handshake names the served set");

    // Client-held keys under a seed the server's stores don't know: if
    // resolve ever regenerated this session from MASTER_SEED, every
    // decryption below would be garbage.
    let keys = keycache::get(&TEST1, 0xAB5EED);
    let session = SessionId(99);
    client.upload_keys(session, &keys.server).expect("upload");

    let mut rng = Rng::new(0x77F1);
    let run = |client: &mut Client, rng: &mut Rng, i: u64| {
        let (mx, my) = (i % 4, (i * 3) % 4);
        let expected = interp::eval(&prog, &[mx, my]);
        let inputs =
            vec![encrypt_message(mx, &keys.sk, rng), encrypt_message(my, &keys.sk, rng)];
        let remote = client.submit(session, &inputs).expect("remote submit");
        let local = cluster
            .submit(session, inputs.clone())
            .expect("in-process submit")
            .recv()
            .expect("in-process response");
        assert!(remote == local, "request {i}: remote differs bitwise from in-process");
        let got: Vec<u64> = remote.iter().map(|c| decrypt_message(c, &keys.sk)).collect();
        assert_eq!(got, expected, "request {i}: decrypt != interpreter");
    };
    for i in 0..4 {
        run(&mut client, &mut rng, i);
    }

    // LRU pressure: distinct seeded tenants flood the cap-2 caches. The
    // pinned uploaded entry must survive on every shard.
    for t in 0..3u64 {
        let sk = client_secret(&TEST1, MASTER_SEED, SessionId(t));
        let q = [t % 4, (t + 1) % 4];
        let expected = interp::eval(&prog, &q);
        let inputs: Vec<LweCiphertext> =
            q.iter().map(|&m| encrypt_message(m, &sk, &mut rng)).collect();
        let outs = client.submit(SessionId(t), &inputs).expect("seeded submit");
        let got: Vec<u64> = outs.iter().map(|c| decrypt_message(c, &sk)).collect();
        assert_eq!(got, expected, "seeded tenant {t} serves correctly alongside uploads");
    }
    run(&mut client, &mut rng, 7); // the uploaded session still decrypts after the flood

    let snap = cluster.snapshot();
    assert_eq!(snap.key_regenerations, 0, "uploaded keys must never be silently regenerated");
    assert!(snap.key_pinned >= 2, "both shard stores pin the uploaded entry");
    let per_shard = cluster.shard_snapshots();
    assert!(
        per_shard.iter().all(|s| s.requests > 0),
        "round-robin exercised every shard's copy of the uploaded keys"
    );
    shutdown(server, cluster);
}

#[test]
fn static_cluster_rejects_uploads_typed_and_keeps_serving() {
    // `StaticKeys::register` panics in-process by contract; from the
    // network the same attempt must be a typed status instead, and the
    // connection must stay usable.
    let keys = keycache::get(&TEST1, 0x57A7);
    let cluster = Arc::new(Cluster::start(
        demo_program(),
        keys.server.clone(),
        ClusterOptions {
            shards: 1,
            policy: PlacementPolicy::RoundRobin,
            queue_depth: None,
            coordinator: CoordinatorOptions { workers: 1, ..Default::default() },
            qos: None,
        },
    ));
    let server = WireServer::start(cluster.clone(), "127.0.0.1:0", WireServerOptions::default())
        .expect("bind");
    let mut client = Client::connect(server.local_addr()).expect("connect");

    match client.upload_keys(SessionId(5), &keys.server) {
        Err(WireError::Rejected { status: Status::RegisterUnsupported, .. }) => {}
        other => panic!("wanted typed RegisterUnsupported, got {other:?}"),
    }

    // Same connection, right after the rejection: submits still serve.
    let prog = demo_program();
    let mut rng = Rng::new(0x1D1E);
    let (mx, my) = (2, 3);
    let expected = interp::eval(&prog, &[mx, my]);
    let inputs =
        vec![encrypt_message(mx, &keys.sk, &mut rng), encrypt_message(my, &keys.sk, &mut rng)];
    let outs = client.submit(SessionId(0), &inputs).expect("submit after rejection");
    let got: Vec<u64> = outs.iter().map(|c| decrypt_message(c, &keys.sk)).collect();
    assert_eq!(got, expected);
    shutdown(server, cluster);
}

/// Read one frame off a raw socket with a read deadline, so a server bug
/// fails the test instead of hanging it.
fn read_ack(stream: &mut TcpStream) -> (Status, String) {
    stream.set_read_timeout(Some(Duration::from_secs(10))).expect("read timeout");
    let frame = read_frame(stream).expect("frame").expect("server answered before closing");
    assert_eq!(frame.tag, TAG_ACK, "hostile input is answered with an ACK");
    let mut r = Reader::new(&frame.body);
    let _id = r.u64().expect("ack id");
    let status = Status::from_u8(r.u8().expect("status byte")).expect("defined status");
    let reason = r.string().expect("reason");
    (status, reason)
}

#[test]
fn hostile_frames_answer_typed_and_server_survives() {
    let (server, cluster) = start_tenant_cluster(1, 4);
    let addr = server.local_addr();

    // (a) Hostile length prefix: rejected before allocation, answered
    // BadRequest, connection closed.
    let mut s = TcpStream::connect(addr).expect("connect raw");
    std::io::Write::write_all(&mut s, &u32::MAX.to_le_bytes()).expect("write prefix");
    let (status, reason) = read_ack(&mut s);
    assert_eq!(status, Status::BadRequest);
    assert!(reason.contains("exceeds bound"), "reason names the bound: {reason}");
    let mut rest = Vec::new();
    assert_eq!(s.read_to_end(&mut rest).unwrap_or(0), 0, "server closed the connection");

    // (b) HELLO with a version from the future: typed UnsupportedVersion.
    let mut s = TcpStream::connect(addr).expect("connect raw");
    write_frame(&mut s, TAG_HELLO, &[99]).expect("write hello");
    let (status, _) = read_ack(&mut s);
    assert_eq!(status, Status::UnsupportedVersion);

    // (c) Unknown tag: typed BadRequest, then close.
    let mut s = TcpStream::connect(addr).expect("connect raw");
    write_frame(&mut s, 200, &[1, 2, 3]).expect("write junk tag");
    let (status, _) = read_ack(&mut s);
    assert_eq!(status, Status::BadRequest);

    // (d) Mid-frame hangup: no answer owed; the server must just reap it.
    let mut s = TcpStream::connect(addr).expect("connect raw");
    std::io::Write::write_all(&mut s, &[7u8, 0]).expect("write partial prefix");
    drop(s);

    // After all of that, the listener still serves real clients.
    let keys = keycache::get(&TEST1, 0xAB5EED);
    let mut client = Client::connect(addr).expect("reconnect");
    client.upload_keys(SessionId(3), &keys.server).expect("upload still works");
    let prog = demo_program();
    let mut rng = Rng::new(0xFACE);
    let inputs =
        vec![encrypt_message(1, &keys.sk, &mut rng), encrypt_message(2, &keys.sk, &mut rng)];
    let expected = interp::eval(&prog, &[1, 2]);
    let outs = client.submit(SessionId(3), &inputs).expect("submit");
    let got: Vec<u64> = outs.iter().map(|c| decrypt_message(c, &keys.sk)).collect();
    assert_eq!(got, expected, "server survives hostile connections unharmed");
    shutdown(server, cluster);
}

#[test]
fn oversized_upload_name_unknown_param_rejected_over_wire() {
    // KEY_BEGIN naming a parameter set the server doesn't serve: the
    // client-side header writer won't produce one, so drive the frame by
    // hand — the server must answer typed (Malformed decodes as
    // BadRequest) without accepting any chunk.
    let (server, cluster) = start_tenant_cluster(1, 4);
    let mut s = TcpStream::connect(server.local_addr()).expect("connect raw");
    // Handshake first, like a real client.
    write_frame(&mut s, TAG_HELLO, &[taurus::wire::proto::PROTO_VERSION]).expect("hello");
    s.set_read_timeout(Some(Duration::from_secs(10))).expect("timeout");
    let hello_ok = read_frame(&mut s).expect("frame").expect("hello ok");
    assert_eq!(hello_ok.tag, taurus::wire::proto::TAG_HELLO_OK);
    // KEY_BEGIN with a corrupted header (bad magic).
    let mut body = Vec::new();
    taurus::wire::codec::put_u64(&mut body, 1); // id
    taurus::wire::codec::put_u64(&mut body, 9); // session
    body.extend_from_slice(b"JUNKJUNK");
    write_frame(&mut s, taurus::wire::proto::TAG_KEY_BEGIN, &body).expect("key begin");
    let (status, _) = read_ack(&mut s);
    assert_eq!(status, Status::BadRequest);
    shutdown(server, cluster);
}
