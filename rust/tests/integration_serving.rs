//! Integration: the serving coordinator over both backends, checking
//! functional correctness, metrics accounting, and failure behaviour.

use std::sync::Arc;
use std::time::Duration;

use taurus::coordinator::{BackendKind, Coordinator, CoordinatorOptions, RequestError};
use taurus::ir::builder::ProgramBuilder;
use taurus::ir::{interp, Program};
use taurus::params::TEST1;
use taurus::tfhe::pbs::{decrypt_message, encrypt_message};
use taurus::tfhe::{SecretKeys, ServerKeys};
use taurus::util::rng::Rng;

fn demo_program() -> Program {
    let mut b = ProgramBuilder::new("demo", TEST1.width);
    let x = b.input();
    let y = b.input();
    let d = b.dot(vec![x, y], vec![1, 2], 0);
    let r = b.lut_fn(d, |m| (m + 1) % 16);
    b.output(r);
    b.finish()
}

fn run_requests(backend: BackendKind, workers: usize, n: usize) {
    let mut rng = Rng::new(99);
    let sk = SecretKeys::generate(&TEST1, &mut rng);
    let keys = Arc::new(ServerKeys::generate(&sk, &mut rng));
    let prog = demo_program();
    let mut coord = Coordinator::start(
        prog.clone(),
        keys,
        CoordinatorOptions {
            workers,
            batch_capacity: 4,
            max_batch_wait: Duration::from_millis(1),
            backend,
            ..Default::default()
        },
    );
    let mut pending = Vec::new();
    let mut expected = Vec::new();
    for i in 0..n {
        let q = [(i % 5) as u64, ((i * 2) % 5) as u64];
        expected.push(interp::eval(&prog, &q)[0]);
        let cts = vec![encrypt_message(q[0], &sk, &mut rng), encrypt_message(q[1], &sk, &mut rng)];
        pending.push(coord.submit(cts).expect("submit"));
    }
    for (rx, exp) in pending.iter().zip(&expected) {
        let outs = rx.recv().expect("response");
        assert_eq!(decrypt_message(&outs[0], &sk), *exp);
    }
    let snap = coord.metrics.snapshot();
    assert_eq!(snap.requests, n);
    assert_eq!(snap.pbs_executed, n * prog.pbs_count());
    // Schedule-driven serving: measured KS = deduplicated plan KS/request.
    assert_eq!(snap.ks_executed, (n * coord.plan().ks_dedup.after) as u64);
    assert!(snap.p99_latency_ms >= snap.p50_latency_ms);
    coord.shutdown();
}

#[test]
fn native_backend_serves_correctly() {
    run_requests(BackendKind::Native, 2, 10);
}

#[test]
fn xla_backend_serves_correctly() {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    run_requests(BackendKind::Xla { artifacts_dir: dir.into() }, 1, 4);
}

#[test]
fn single_worker_preserves_order_per_client() {
    // With one worker and batch capacity 1, responses arrive in
    // submission order.
    let mut rng = Rng::new(123);
    let sk = SecretKeys::generate(&TEST1, &mut rng);
    let keys = Arc::new(ServerKeys::generate(&sk, &mut rng));
    let prog = demo_program();
    let mut coord = Coordinator::start(
        prog.clone(),
        keys,
        CoordinatorOptions {
            workers: 1,
            batch_capacity: 1,
            max_batch_wait: Duration::from_millis(0),
            backend: BackendKind::Native,
            ..Default::default()
        },
    );
    let rxs: Vec<_> = (0..5u64)
        .map(|i| {
            coord
                .submit(vec![
                    encrypt_message(i % 4, &sk, &mut rng),
                    encrypt_message(1, &sk, &mut rng),
                ])
                .expect("submit")
        })
        .collect();
    for (i, rx) in rxs.iter().enumerate() {
        let outs = rx.recv().unwrap();
        let exp = interp::eval(&prog, &[(i as u64) % 4, 1])[0];
        assert_eq!(decrypt_message(&outs[0], &sk), exp, "request {i}");
    }
    coord.shutdown();
}

#[test]
fn killed_coordinator_fails_every_waiter_with_typed_error() {
    // A shard dying mid-flight must surface a typed error to every
    // waiter — never a hang. Deadlines guard the test itself: even a
    // regression that drops response channels resolves within 10s.
    let mut rng = Rng::new(55);
    let sk = SecretKeys::generate(&TEST1, &mut rng);
    let keys = Arc::new(ServerKeys::generate(&sk, &mut rng));
    let prog = demo_program();
    let mut coord = Coordinator::start(
        prog,
        keys,
        CoordinatorOptions {
            workers: 1,
            batch_capacity: 2,
            // Long collect window: the queue is still full when the kill
            // lands, so some requests are typed-failed by the draining
            // worker rather than served.
            max_batch_wait: Duration::from_millis(50),
            backend: BackendKind::Native,
            ..Default::default()
        },
    );
    let waiters: Vec<_> = (0..6u64)
        .map(|i| {
            coord
                .submit_with_deadline(
                    vec![
                        encrypt_message(i % 4, &sk, &mut rng),
                        encrypt_message(1, &sk, &mut rng),
                    ],
                    Duration::from_secs(10),
                )
                .expect("submit")
        })
        .collect();
    coord.kill();
    for (i, t) in waiters.iter().enumerate() {
        match t.wait() {
            // Requests already executing when the kill landed may finish.
            Ok(_) => {}
            Err(RequestError::ShardLost) => {}
            Err(other) => panic!("waiter {i}: expected ShardLost or success, got {other:?}"),
        }
    }
    assert_eq!(
        coord.inflight.load(std::sync::atomic::Ordering::SeqCst),
        0,
        "every request was accounted for, served or failed"
    );
}

#[test]
fn dropped_client_does_not_poison_workers() {
    let mut rng = Rng::new(7);
    let sk = SecretKeys::generate(&TEST1, &mut rng);
    let keys = Arc::new(ServerKeys::generate(&sk, &mut rng));
    let prog = demo_program();
    let mut coord = Coordinator::start(prog.clone(), keys, Default::default());
    // Submit and immediately drop the receiver.
    {
        let _ = coord
            .submit(vec![
                encrypt_message(1, &sk, &mut rng),
                encrypt_message(2, &sk, &mut rng),
            ])
            .expect("submit");
    }
    // A subsequent request must still be served.
    let rx = coord
        .submit(vec![
            encrypt_message(2, &sk, &mut rng),
            encrypt_message(2, &sk, &mut rng),
        ])
        .expect("submit");
    let outs = rx.recv().expect("served after dropped client");
    let exp = interp::eval(&prog, &[2, 2])[0];
    assert_eq!(decrypt_message(&outs[0], &sk), exp);
    coord.shutdown();
}
