//! Property-based tests over the TFHE substrate and compiler invariants
//! (mini property harness: `taurus::util::prop`).

use taurus::compiler::{self, compile, PrimKind};
use taurus::ir::builder::ProgramBuilder;
use taurus::ir::{interp, LutTable};
use taurus::params::TEST1;
use taurus::tfhe::pbs::{decrypt_message, encrypt_message};
use taurus::tfhe::{make_lut_poly, PbsContext, SecretKeys, ServerKeys};
use taurus::util::prop::check;
use taurus::util::rng::Rng;

/// Shared fixture: keygen once (dominates test time).
struct Fixture {
    sk: SecretKeys,
    keys: ServerKeys,
}

fn fixture() -> &'static Fixture {
    use std::sync::OnceLock;
    static F: OnceLock<Fixture> = OnceLock::new();
    F.get_or_init(|| {
        let mut rng = Rng::new(0xF1);
        let sk = SecretKeys::generate(&TEST1, &mut rng);
        let keys = ServerKeys::generate(&sk, &mut rng);
        Fixture { sk, keys }
    })
}

#[test]
fn prop_pbs_evaluates_random_luts() {
    let f = fixture();
    let mut ctx = PbsContext::new(&TEST1);
    check("pbs_random_lut", 12, |rng| {
        // Random table over the half-space (messages 0..8 with padding).
        let table: Vec<u64> = (0..16).map(|_| rng.below(16)).collect();
        let t2 = table.clone();
        let lut = make_lut_poly(&TEST1, move |m| t2[m as usize]);
        let m = rng.below(8);
        let ct = encrypt_message(m, &f.sk, rng);
        let out = ctx.pbs(&ct, &f.keys, &lut);
        let got = decrypt_message(&out, &f.sk);
        let exp = table[m as usize] % 16;
        if got != exp {
            return Err(format!("m={m} got {got} exp {exp}"));
        }
        Ok(())
    });
}

#[test]
fn prop_linear_ops_homomorphic() {
    let f = fixture();
    check("linear_homomorphism", 25, |rng| {
        let (a, b) = (rng.below(8), rng.below(8));
        let c = (rng.below(5) as i64) - 2;
        let mut ct = encrypt_message(a, &f.sk, rng);
        let ct_b = encrypt_message(b, &f.sk, rng);
        ct.add_assign(&ct_b);
        ct.scalar_mul_assign(c);
        let exp = (((a + b) as i64 * c).rem_euclid(16)) as u64;
        let got = decrypt_message(&ct, &f.sk);
        if got != exp {
            return Err(format!("({a}+{b})*{c}: got {got} exp {exp}"));
        }
        Ok(())
    });
}

#[test]
fn prop_random_programs_encrypted_equals_plaintext() {
    // Generate random small programs; encrypted execution must equal the
    // plaintext interpreter on random inputs.
    let f = fixture();
    check("random_program_equivalence", 6, |rng| {
        let mut b = ProgramBuilder::new("rand", TEST1.width);
        let mut vals = b.inputs(2 + rng.below_usize(3));
        let n_inputs = vals.len();
        for _ in 0..(3 + rng.below_usize(5)) {
            let pick = |rng: &mut Rng, vals: &Vec<usize>| vals[rng.below_usize(vals.len())];
            let v = match rng.below(4) {
                0 => {
                    let (x, y) = (pick(rng, &vals), pick(rng, &vals));
                    b.add(x, y)
                }
                1 => {
                    let x = pick(rng, &vals);
                    b.mul_plain(x, (rng.below(3) as i64) + 1)
                }
                2 => {
                    let x = pick(rng, &vals);
                    let off = rng.below(8);
                    b.lut_fn(x, move |m| (m + off) % 16)
                }
                _ => {
                    let (x, y) = (pick(rng, &vals), pick(rng, &vals));
                    b.dot(vec![x, y], vec![1, -1], rng.below(4))
                }
            };
            vals.push(v);
        }
        b.output(*vals.last().unwrap());
        let prog = b.finish();
        let inputs: Vec<u64> = (0..n_inputs).map(|_| rng.below(8)).collect();
        let cts: Vec<_> = inputs.iter().map(|&m| encrypt_message(m, &f.sk, rng)).collect();
        let mut eng = compiler::Engine::new(compiler::NativePbsBackend::new(&f.keys));
        let got: Vec<u64> =
            eng.run(&prog, &cts).iter().map(|c| decrypt_message(c, &f.sk)).collect();
        let exp = interp::eval(&prog, &inputs);
        if got != exp {
            return Err(format!(
                "prog pbs={} inputs={inputs:?}: {got:?} != {exp:?}",
                prog.pbs_count()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_ks_dedup_preserves_schedule_feasibility() {
    // Compiler invariant: after KS-dedup, every BR still has exactly one
    // KS dep, the graph stays topologically ordered, and the batch
    // schedule covers every BR exactly once.
    check("dedup_schedule_invariants", 10, |rng| {
        let mut b = ProgramBuilder::new("rand", 3);
        let xs = b.inputs(1 + rng.below_usize(4));
        let mut frontier = xs.clone();
        for _ in 0..(1 + rng.below_usize(3)) {
            let mut next = vec![];
            for &v in &frontier {
                let fanout = 1 + rng.below_usize(3);
                for k in 0..fanout {
                    next.push(b.lut_fn(v, move |m| (m + k as u64) % 16));
                }
            }
            frontier = next;
        }
        b.output(*frontier.last().unwrap());
        let prog = b.finish();
        let c = compile(&prog, &TEST1, 48usize);
        c.graph.validate().map_err(|e| e.to_string())?;
        // Every BR has exactly one KS dep.
        for op in &c.graph.ops {
            if PrimKind::is_blind_rotate(&op.kind) {
                let ks_deps = op
                    .deps
                    .iter()
                    .filter(|&&d| PrimKind::is_keyswitch(&c.graph.ops[d].kind))
                    .count();
                if ks_deps != 1 {
                    return Err(format!("BR {} has {ks_deps} KS deps", op.id));
                }
            }
        }
        // Schedule covers every BR exactly once.
        let mut seen = std::collections::HashSet::new();
        for batch in &c.schedule.batches {
            if batch.br_ops.len() > 48 {
                return Err("batch overflow".into());
            }
            for &br in &batch.br_ops {
                if !seen.insert(br) {
                    return Err(format!("BR {br} scheduled twice"));
                }
            }
        }
        if seen.len() != c.graph.pbs_count() {
            return Err(format!("scheduled {} of {} BRs", seen.len(), c.graph.pbs_count()));
        }
        // Dedup never increases KS count and never changes BR count.
        if c.ks_dedup.after > c.ks_dedup.before {
            return Err("dedup increased KS count".into());
        }
        Ok(())
    });
}

#[test]
fn prop_lut_table_negacyclic_semantics_match_engine() {
    // The interpreter's negacyclic LUT model is exactly what PBS computes,
    // including past the padding bit.
    let f = fixture();
    let mut ctx = PbsContext::new(&TEST1);
    check("negacyclic_interp_vs_engine", 8, |rng| {
        let off = rng.below(8);
        let table = LutTable::from_fn(3, move |m| (3 * m + off) % 16);
        let tv = table.values.clone();
        let lut = make_lut_poly(&TEST1, move |m| tv[m as usize]);
        let m = rng.below(16); // deliberately allow padding-bit overflow
        let ct = encrypt_message(m, &f.sk, rng);
        let out = ctx.pbs(&ct, &f.keys, &lut);
        let got = decrypt_message(&out, &f.sk);
        // Plaintext model:
        let prog = {
            let mut b = ProgramBuilder::new("one", 3);
            let x = b.input();
            let y = b.lut(x, table.clone());
            b.output(y);
            b.finish()
        };
        let exp = interp::eval(&prog, &[m])[0];
        if got != exp {
            return Err(format!("m={m}: engine {got} vs interp {exp}"));
        }
        Ok(())
    });
}
