//! Chaos suite: deterministic fault injection against the serving stack.
//!
//! Every test drives a seed-derived `FaultPlan` (worker panics, latency
//! spikes, resolve failures at scheduled operation indices) through the
//! coordinator/cluster supervision machinery and asserts the recovery
//! contract: every request TERMINATES (output or typed error, never a
//! hang), successful outputs are bitwise-identical to fault-free serving,
//! measured counters stay exact over served requests, and a disarmed
//! plan serves clean again.
//!
//! The soak sweeps the seeds in `CHAOS_SEEDS` (whitespace-separated,
//! default "0 1"); CI runs it over seeds 0..=3.

use std::sync::Arc;
use std::time::Duration;

use taurus::cluster::{
    Cluster, ClusterOptions, PlacementPolicy, StoreFactory, SupervisorOptions,
};
use taurus::coordinator::{
    BackendKind, Coordinator, CoordinatorOptions, RequestError,
};
use taurus::ir::builder::ProgramBuilder;
use taurus::ir::{interp, Program};
use taurus::params::TEST1;
use taurus::runtime::faults::{FaultPlan, FaultSpec, FaultyStore};
use taurus::tenant::{KeyStore, StaticKeys};
use taurus::tfhe::pbs::{decrypt_message, encrypt_message};
use taurus::tfhe::{LweCiphertext, SecretKeys, ServerKeys};
use taurus::util::rng::Rng;

/// Fanout program (1 shared KS, 2 PBS per request) so the KS-dedup
/// exactness invariant is non-trivial under faults.
fn fan_program() -> Program {
    let mut b = ProgramBuilder::new("chaos-fan", TEST1.width);
    let x = b.input();
    let y = b.input();
    let d = b.add(x, y);
    let r0 = b.lut_fn(d, |m| (m + 1) % 8);
    let r1 = b.lut_fn(d, |m| m ^ 1);
    b.outputs(&[r0, r1]);
    b.finish()
}

fn chaos_coordinator_options(faults: &Arc<FaultPlan>) -> CoordinatorOptions {
    CoordinatorOptions {
        workers: 1,
        batch_capacity: 1,
        max_batch_wait: Duration::from_millis(1),
        backend: BackendKind::NativeChaos { faults: faults.clone() },
        ..Default::default()
    }
}

/// A factory producing `FaultyStore`-wrapped `StaticKeys` per shard: the
/// injected resolve failures exercise the cluster's redirect path while
/// key material stays shared (so outputs are comparable bitwise).
fn faulty_static_factory(keys: Arc<ServerKeys>, faults: Arc<FaultPlan>) -> StoreFactory {
    Arc::new(move |_shard| {
        let inner = Arc::new(StaticKeys::new(keys.clone())) as Arc<dyn KeyStore>;
        Arc::new(FaultyStore::new(inner, faults.clone())) as Arc<dyn KeyStore>
    })
}

fn chaos_seeds() -> Vec<u64> {
    std::env::var("CHAOS_SEEDS")
        .unwrap_or_else(|_| "0 1".into())
        .split_whitespace()
        .map(|s| s.parse().expect("CHAOS_SEEDS must be whitespace-separated u64s"))
        .collect()
}

#[test]
fn worker_panic_fails_only_its_batch_and_respawns() {
    let mut rng = Rng::new(31);
    let sk = SecretKeys::generate(&TEST1, &mut rng);
    let keys = Arc::new(ServerKeys::generate(&sk, &mut rng));
    let prog = fan_program();
    // Blind-rotate op 0 panics; everything after runs clean.
    let faults = Arc::new(FaultPlan::from_seed(
        3,
        &FaultSpec { op_horizon: 1, panics: 1, ..FaultSpec::none() },
    ));
    let mut coord = Coordinator::start(prog.clone(), keys, chaos_coordinator_options(&faults));

    // First request: its batch hits the scheduled panic — typed failure,
    // not a hang, not a dead worker.
    let enc = |rng: &mut Rng| {
        vec![encrypt_message(2, &sk, rng), encrypt_message(3, &sk, rng)]
    };
    let t = coord.submit(enc(&mut rng)).expect("submit");
    match t.wait() {
        Err(RequestError::ExecFailed { reason }) => {
            assert!(reason.contains("injected backend fault"), "got: {reason}")
        }
        other => panic!("expected ExecFailed, got {other:?}"),
    }

    // Second request: the worker respawned its engine in place and serves
    // correctly.
    let t = coord.submit(enc(&mut rng)).expect("submit");
    let outs = t.wait().expect("served after respawn");
    let exp = interp::eval(&prog, &[2, 3]);
    let got: Vec<u64> = outs.iter().map(|c| decrypt_message(c, &sk)).collect();
    assert_eq!(got, exp);

    let snap = coord.metrics.snapshot();
    assert_eq!(snap.exec_failures, 1, "exactly the scheduled batch failed");
    assert_eq!(snap.failed_requests, 1);
    assert_eq!(snap.worker_respawns, 1);
    assert_eq!(snap.requests, 1, "only the successful request is recorded");
    assert_eq!(snap.batches, 1, "failed batches never enter the measured counters");
    assert_eq!(faults.injected().panics, 1);
    coord.shutdown();
}

#[test]
fn deadline_releases_admission_capacity() {
    let mut rng = Rng::new(32);
    let sk = SecretKeys::generate(&TEST1, &mut rng);
    let keys = Arc::new(ServerKeys::generate(&sk, &mut rng));
    let prog = fan_program();
    // Op 0 sleeps well past the deadline; no panics.
    let faults = Arc::new(FaultPlan::from_seed(
        5,
        &FaultSpec {
            op_horizon: 1,
            delays: 1,
            delay: Duration::from_millis(400),
            ..FaultSpec::none()
        },
    ));
    let mut cluster = Cluster::start_with_store_factory_supervised(
        prog,
        faulty_static_factory(keys, faults.clone()),
        ClusterOptions {
            shards: 1,
            policy: PlacementPolicy::RoundRobin,
            queue_depth: Some(1),
            coordinator: chaos_coordinator_options(&faults),
            qos: None,
        },
        SupervisorOptions::default(),
    );
    let enc = |rng: &mut Rng| {
        vec![encrypt_message(1, &sk, rng), encrypt_message(2, &sk, rng)]
    };
    let slow = cluster
        .submit_with_deadline(0u64, enc(&mut rng), Duration::from_millis(25))
        .expect("admitted");
    assert_eq!(cluster.outstanding(), 1);
    assert_eq!(slow.wait(), Err(RequestError::RequestTimeout));
    // The expired wait released the admission slot even though the
    // response handle is still alive and the shard is still grinding.
    assert_eq!(cluster.outstanding(), 0, "timeout must free the admission slot");
    let next = cluster.submit(1u64, enc(&mut rng)).expect("slot is free again");
    let _ = next.wait().expect("clean request serves normally");
    drop(next);
    drop(slow);
    let snap = cluster.snapshot();
    assert!(snap.request_timeouts >= 1, "the timeout was counted: {:?}", snap.request_timeouts);
    assert_eq!(faults.injected().delays, 1);
    cluster.shutdown();
}

#[test]
fn failed_batch_retries_on_healthy_shard_and_original_restarts() {
    let mut rng = Rng::new(33);
    let sk = SecretKeys::generate(&TEST1, &mut rng);
    let keys = Arc::new(ServerKeys::generate(&sk, &mut rng));
    let prog = fan_program();
    // Exactly one scheduled panic; quarantine after a single failure so
    // the restart path fires deterministically.
    let faults = Arc::new(FaultPlan::from_seed(
        7,
        &FaultSpec { op_horizon: 1, panics: 1, ..FaultSpec::none() },
    ));
    let mut cluster = Cluster::start_with_store_factory_supervised(
        prog.clone(),
        faulty_static_factory(keys, faults.clone()),
        ClusterOptions {
            shards: 2,
            policy: PlacementPolicy::RoundRobin,
            queue_depth: None,
            coordinator: chaos_coordinator_options(&faults),
            qos: None,
        },
        SupervisorOptions { max_retries: 2, restart_after_failures: 1, ..Default::default() },
    );
    let queries: Vec<[u64; 2]> = (0..6).map(|i| [i % 6, (i * 2) % 6]).collect();
    let pend: Vec<_> = queries
        .iter()
        .enumerate()
        .map(|(i, q)| {
            let cts = vec![
                encrypt_message(q[0], &sk, &mut rng),
                encrypt_message(q[1], &sk, &mut rng),
            ];
            cluster
                .submit_with_deadline(i as u64, cts, Duration::from_secs(30))
                .expect("submit")
        })
        .collect();
    // EVERY request succeeds: the one whose batch panicked was re-dispatched
    // to the healthy shard by the supervisor, transparently to the client.
    for (q, r) in queries.iter().zip(&pend) {
        let outs = r.wait().expect("retried to completion");
        let got: Vec<u64> = outs.iter().map(|c| decrypt_message(c, &sk)).collect();
        assert_eq!(got, interp::eval(&prog, q), "query {q:?}");
    }
    drop(pend);
    let snap = cluster.snapshot();
    assert_eq!(snap.exec_failures, 1);
    assert!(snap.request_retries >= 1, "the failed request was re-dispatched");
    assert!(snap.shard_restarts >= 1, "one failure crossed the quarantine threshold");
    assert_eq!(snap.requests, queries.len(), "every request served exactly once");
    cluster.shutdown();
}

#[test]
fn resolve_failure_redirects_to_another_shard() {
    let mut rng = Rng::new(34);
    let sk = SecretKeys::generate(&TEST1, &mut rng);
    let keys = Arc::new(ServerKeys::generate(&sk, &mut rng));
    let prog = fan_program();
    // Resolve call 0 fails; no backend faults at all.
    let faults = Arc::new(FaultPlan::from_seed(
        11,
        &FaultSpec { resolve_horizon: 1, resolve_failures: 1, ..FaultSpec::none() },
    ));
    let mut cluster = Cluster::start_with_store_factory_supervised(
        prog.clone(),
        faulty_static_factory(keys, faults.clone()),
        ClusterOptions {
            shards: 2,
            policy: PlacementPolicy::RoundRobin,
            queue_depth: None,
            coordinator: chaos_coordinator_options(&faults),
            qos: None,
        },
        SupervisorOptions::default(),
    );
    // First submit: the routed shard's store fails the scheduled resolve;
    // admission redirects to the other shard, whose resolve succeeds.
    let cts = vec![encrypt_message(2, &sk, &mut rng), encrypt_message(1, &sk, &mut rng)];
    let r = cluster.submit(0u64, cts).expect("redirected, not rejected");
    assert_eq!(r.shard, 1, "round-robin placed shard 0; the redirect landed on 1");
    let outs = r.recv().expect("served");
    let got: Vec<u64> = outs.iter().map(|c| decrypt_message(c, &sk)).collect();
    assert_eq!(got, interp::eval(&prog, &[2, 1]));
    drop(r);
    assert_eq!(faults.injected().resolve_failures, 1);
    assert!(cluster.snapshot().request_redirects >= 1);
    cluster.shutdown();
}

#[test]
fn chaos_composes_with_the_fft_worker_pool() {
    // Fault injection fires on the coordinator worker thread that
    // DISPATCHES the blind-rotation pool (`FaultyBackend` injects before
    // delegating), so an injected delay or panic must never leave a
    // column join waiting on the pool: every request still terminates,
    // and surviving outputs stay bitwise-identical to fault-free
    // single-threaded serving (thread-count invariance under chaos).
    let mut rng = Rng::new(36);
    let sk = SecretKeys::generate(&TEST1, &mut rng);
    let keys = Arc::new(ServerKeys::generate(&sk, &mut rng));
    let prog = fan_program();
    let n = 12usize;
    let queries: Vec<[u64; 2]> = (0..n as u64).map(|i| [i % 6, (i * 5) % 6]).collect();
    let encrypted: Vec<Vec<LweCiphertext>> = queries
        .iter()
        .map(|q| {
            vec![encrypt_message(q[0], &sk, &mut rng), encrypt_message(q[1], &sk, &mut rng)]
        })
        .collect();

    // Fault-free, sequential-FFT reference bits.
    let reference: Vec<Vec<LweCiphertext>> = {
        let mut coord = Coordinator::start(
            prog.clone(),
            keys.clone(),
            CoordinatorOptions {
                workers: 1,
                batch_capacity: 1,
                max_batch_wait: Duration::from_millis(1),
                ..Default::default()
            },
        );
        let pend: Vec<_> =
            encrypted.iter().map(|cts| coord.submit(cts.clone()).expect("submit")).collect();
        let outs = pend.iter().map(|t| t.wait().expect("reference")).collect();
        coord.shutdown();
        outs
    };

    // Chaos + pool: delays and one panic against a 4-thread backend with
    // real multi-request batches (capacity 4 keeps the pool's planar
    // sweep engaged).
    let faults = Arc::new(FaultPlan::from_seed(
        9,
        &FaultSpec {
            op_horizon: 6,
            panics: 1,
            delays: 2,
            delay: Duration::from_millis(15),
            ..FaultSpec::none()
        },
    ));
    let mut coord = Coordinator::start(
        prog.clone(),
        keys,
        CoordinatorOptions {
            batch_capacity: 4,
            fft_threads: 4,
            ..chaos_coordinator_options(&faults)
        },
    );
    let pend: Vec<_> = encrypted
        .iter()
        .enumerate()
        .map(|(i, cts)| {
            (i, coord.submit_with_deadline(cts.clone(), Duration::from_secs(30)).expect("submit"))
        })
        .collect();
    let mut ok = 0usize;
    let mut failed = 0usize;
    for (i, t) in &pend {
        match t.wait() {
            Ok(outs) => {
                assert_eq!(
                    outs, reference[*i],
                    "request {i}: 4-thread chaos serving changed output bits"
                );
                ok += 1;
            }
            Err(err) => {
                println!("request {i} failed typed under chaos: {err}");
                failed += 1;
            }
        }
    }
    drop(pend);
    assert_eq!(ok + failed, n, "every request terminated (no pool join deadlock)");
    assert!(ok >= 1, "the single scheduled panic cannot fail every batch");
    assert_eq!(faults.injected().panics, 1);

    // Disarmed, the same 4-thread coordinator serves the identical stream
    // clean and bitwise fault-free.
    faults.disarm();
    let pend: Vec<_> = encrypted
        .iter()
        .enumerate()
        .map(|(i, cts)| (i, coord.submit(cts.clone()).expect("post-recovery submit")))
        .collect();
    for (i, t) in &pend {
        let outs = t.wait().unwrap_or_else(|e| panic!("post-recovery request {i}: {e}"));
        assert_eq!(outs, reference[*i], "post-recovery output {i} must be bitwise fault-free");
    }
    drop(pend);
    coord.shutdown();
}

/// The soak: for each seed, serve a request stream through a cluster under
/// an armed fault plan, then disarm and serve it again. Asserts the full
/// robustness contract per seed.
#[test]
fn chaos_soak_every_request_terminates_and_recovers_bitwise() {
    let mut rng = Rng::new(35);
    let sk = SecretKeys::generate(&TEST1, &mut rng);
    let keys = Arc::new(ServerKeys::generate(&sk, &mut rng));
    let prog = fan_program();
    let n = 24usize;
    let queries: Vec<[u64; 2]> = (0..n as u64).map(|i| [i % 6, (i * 3) % 6]).collect();
    let encrypted: Vec<Vec<LweCiphertext>> = queries
        .iter()
        .map(|q| {
            vec![encrypt_message(q[0], &sk, &mut rng), encrypt_message(q[1], &sk, &mut rng)]
        })
        .collect();

    // Fault-free reference outputs (deterministic plan execution: any
    // fault-free serving of these ciphertexts yields exactly these bits).
    let reference: Vec<Vec<LweCiphertext>> = {
        let mut coord = Coordinator::start(
            prog.clone(),
            keys.clone(),
            CoordinatorOptions {
                workers: 1,
                batch_capacity: 1,
                max_batch_wait: Duration::from_millis(1),
                ..Default::default()
            },
        );
        let pend: Vec<_> =
            encrypted.iter().map(|cts| coord.submit(cts.clone()).expect("submit")).collect();
        let outs = pend.iter().map(|t| t.wait().expect("reference")).collect();
        coord.shutdown();
        outs
    };

    for seed in chaos_seeds() {
        let faults = Arc::new(FaultPlan::from_seed(
            seed,
            &FaultSpec {
                op_horizon: 8,
                panics: 3,
                delays: 1,
                delay: Duration::from_millis(10),
                resolve_horizon: 8,
                resolve_failures: 2,
            },
        ));
        let mut cluster = Cluster::start_with_store_factory_supervised(
            prog.clone(),
            faulty_static_factory(keys.clone(), faults.clone()),
            ClusterOptions {
                shards: 2,
                policy: PlacementPolicy::RoundRobin,
                queue_depth: None,
                coordinator: chaos_coordinator_options(&faults),
                qos: None,
            },
            SupervisorOptions { max_retries: 2, restart_after_failures: 2, ..Default::default() },
        );

        // Chaos phase: submit everything under a generous deadline. Every
        // request must TERMINATE — served or a typed error — and every
        // served output must be bitwise-identical to fault-free serving.
        let mut ok = 0usize;
        let mut failed = 0usize;
        let mut pend = Vec::new();
        for (i, cts) in encrypted.iter().enumerate() {
            match cluster.submit_with_deadline(i as u64, cts.clone(), Duration::from_secs(30)) {
                Ok(r) => pend.push((i, r)),
                // An injected resolve failure can reject at admission when
                // the redirect's resolve is also scheduled to fail: a
                // typed, terminating outcome.
                Err(e) => {
                    println!("seed {seed}: request {i} rejected at admission: {e}");
                    failed += 1;
                }
            }
        }
        for (i, r) in &pend {
            match r.wait() {
                Ok(outs) => {
                    assert_eq!(
                        outs, reference[*i],
                        "seed {seed}: served output {i} must be bitwise fault-free"
                    );
                    ok += 1;
                }
                Err(err) => {
                    println!("seed {seed}: request {i} failed typed: {err}");
                    failed += 1;
                }
            }
        }
        drop(pend);
        assert_eq!(ok + failed, n, "seed {seed}: every request terminated");

        // Exactness: only served requests enter the measured counters, and
        // the measured-vs-plan invariant holds over exactly those.
        let snap = cluster.snapshot();
        assert_eq!(snap.requests, ok, "seed {seed}: served == client-observed successes");
        assert_eq!(
            snap.ks_executed,
            (ok * cluster.plan().ks_dedup.after) as u64,
            "seed {seed}: KS exactness over served requests"
        );
        assert_eq!(
            snap.pbs_executed,
            ok * prog.pbs_count(),
            "seed {seed}: PBS exactness over served requests"
        );
        let inj = faults.injected();
        assert_eq!(
            snap.exec_failures, inj.panics,
            "seed {seed}: each injected panic failed exactly one batch"
        );
        if inj.panics > 0 {
            assert!(snap.worker_respawns >= 1, "seed {seed}: panics imply respawns");
        }

        // Recovery phase: disarm and serve the identical stream again —
        // all successes, bitwise-identical to the fault-free reference.
        faults.disarm();
        let pend: Vec<_> = encrypted
            .iter()
            .enumerate()
            .map(|(i, cts)| {
                (i, cluster.submit(i as u64, cts.clone()).expect("post-recovery submit"))
            })
            .collect();
        for (i, r) in &pend {
            let outs = r.wait().unwrap_or_else(|e| {
                panic!("seed {seed}: post-recovery request {i} must serve cleanly: {e}")
            });
            assert_eq!(
                outs, reference[*i],
                "seed {seed}: post-recovery output {i} must be bitwise fault-free"
            );
        }
        drop(pend);
        cluster.shutdown();
        println!(
            "seed {seed}: {ok} served / {failed} typed-failed during chaos; injected {:?}; recovery clean",
            inj
        );
    }
}
