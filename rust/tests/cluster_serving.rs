//! Integration: the sharded serving cluster — replication correctness
//! (bitwise vs a single coordinator), placement policies, admission-queue
//! backpressure, graceful drain, and merged-metrics accounting
//! cross-checked against `arch::sim`.

use std::sync::Arc;
use std::time::Duration;

use taurus::arch::{simulate, TaurusConfig};
use taurus::cluster::{Cluster, ClusterError, ClusterOptions, PlacementPolicy, ReshardError};
use taurus::coordinator::{Coordinator, CoordinatorOptions};
use taurus::ir::builder::ProgramBuilder;
use taurus::ir::{interp, Program};
use taurus::params::TEST1;
use taurus::tfhe::pbs::{decrypt_message, encrypt_message};
use taurus::tfhe::{LweCiphertext, SecretKeys, ServerKeys};
use taurus::util::rng::Rng;

/// A randomized 3-input program: one fanout layer of dot -> LUT with
/// rng-drawn weights/biases/tables, then a reduction LUT. Deterministic
/// given the rng seed.
fn randomized_program(rng: &mut Rng) -> Program {
    let width = TEST1.width;
    let dom = 1u64 << width;
    let mut b = ProgramBuilder::new("cluster-rand", width);
    let xs = b.inputs(3);
    let mut mids = Vec::new();
    for _ in 0..3 {
        let w: Vec<i64> = (0..3).map(|_| 1 + rng.below(2) as i64).collect();
        let bias = rng.below(4);
        let d = b.dot(xs.clone(), w, bias);
        let table: Vec<u64> = (0..dom).map(|_| rng.below(dom)).collect();
        mids.push(b.lut_fn(d, move |m| table[(m % dom) as usize]));
    }
    let s = b.dot(mids.clone(), vec![1, 1, 1], 0);
    let table: Vec<u64> = (0..dom).map(|_| rng.below(dom)).collect();
    let out = b.lut_fn(s, move |m| table[(m % dom) as usize]);
    b.outputs(&[mids[0], out]);
    b.finish()
}

/// Cheap 1-PBS program for routing/backpressure tests.
fn tiny_program() -> Program {
    let mut b = ProgramBuilder::new("tiny", TEST1.width);
    let x = b.input();
    let y = b.lut_fn(x, |m| (m + 1) % 8);
    b.output(y);
    b.finish()
}

fn test_coordinator_options() -> CoordinatorOptions {
    CoordinatorOptions {
        workers: 1,
        batch_capacity: 4,
        max_batch_wait: Duration::from_millis(1),
        ..Default::default()
    }
}

#[test]
fn four_shard_cluster_matches_single_coordinator_bitwise() {
    let mut rng = Rng::new(4242);
    let sk = SecretKeys::generate(&TEST1, &mut rng);
    let keys = Arc::new(ServerKeys::generate(&sk, &mut rng));
    let prog = randomized_program(&mut rng);
    let n = 8usize;
    let queries: Vec<Vec<u64>> =
        (0..n).map(|_| (0..3).map(|_| rng.below(6)).collect()).collect();
    let encrypted: Vec<Vec<LweCiphertext>> = queries
        .iter()
        .map(|q| q.iter().map(|&m| encrypt_message(m, &sk, &mut rng)).collect())
        .collect();

    // Reference: one coordinator over the same ciphertexts.
    let mut single = Coordinator::start(prog.clone(), keys.clone(), test_coordinator_options());
    let pend: Vec<_> =
        encrypted.iter().map(|cts| single.submit(cts.clone()).expect("submit")).collect();
    let single_outs: Vec<Vec<LweCiphertext>> =
        pend.iter().map(|rx| rx.recv().expect("response")).collect();
    single.shutdown();

    // 4 shards, replicated keys, one shared compiled artifact.
    let mut cluster = Cluster::start(
        prog.clone(),
        keys,
        ClusterOptions {
            shards: 4,
            policy: PlacementPolicy::RoundRobin,
            queue_depth: None,
            coordinator: test_coordinator_options(),
            qos: None,
        },
    );
    let pend: Vec<_> = encrypted
        .iter()
        .enumerate()
        .map(|(i, cts)| cluster.submit(i as u64, cts.clone()).expect("submit"))
        .collect();
    let cluster_outs: Vec<Vec<LweCiphertext>> =
        pend.iter().map(|r| r.recv().expect("response")).collect();
    drop(pend);

    // Bitwise: the same plan over the same keys and inputs yields the
    // identical output ciphertexts no matter which shard (or dynamic
    // batch) served the request.
    assert_eq!(single_outs, cluster_outs, "cluster must replicate the engine exactly");
    // And both decrypt to the interpreter's answers.
    for (q, outs) in queries.iter().zip(&cluster_outs) {
        let got: Vec<u64> = outs.iter().map(|c| decrypt_message(c, &sk)).collect();
        assert_eq!(got, interp::eval(&prog, q), "query {q:?}");
    }
    // Round-robin actually spread the work: 8 requests over 4 shards.
    let per: Vec<usize> = cluster.shard_snapshots().iter().map(|s| s.requests).collect();
    assert_eq!(per, vec![2, 2, 2, 2], "round-robin spread");
    cluster.shutdown();
}

#[test]
fn consistent_hash_routes_a_client_to_one_shard() {
    let mut rng = Rng::new(77);
    let sk = SecretKeys::generate(&TEST1, &mut rng);
    let keys = Arc::new(ServerKeys::generate(&sk, &mut rng));
    let mut cluster = Cluster::start(
        tiny_program(),
        keys,
        ClusterOptions {
            shards: 4,
            policy: PlacementPolicy::ConsistentHash,
            queue_depth: None,
            coordinator: test_coordinator_options(),
            qos: None,
        },
    );
    let n = 10usize;
    let client_id = 777u64;
    let pend: Vec<_> = (0..n)
        .map(|i| {
            let cts = vec![encrypt_message((i % 6) as u64, &sk, &mut rng)];
            cluster.submit(client_id, cts).expect("submit")
        })
        .collect();
    let home = pend[0].shard;
    for resp in &pend {
        assert_eq!(resp.shard, home, "client {client_id} must stay on shard {home}");
        let _ = resp.recv().expect("response");
    }
    drop(pend);
    let per: Vec<usize> = cluster.shard_snapshots().iter().map(|s| s.requests).collect();
    assert_eq!(per[home], n, "all requests landed on the client's home shard");
    assert_eq!(per.iter().sum::<usize>(), n, "and nowhere else: {per:?}");
    cluster.shutdown();
}

#[test]
fn cluster_full_backpressure_fires_at_depth() {
    let mut rng = Rng::new(78);
    let sk = SecretKeys::generate(&TEST1, &mut rng);
    let keys = Arc::new(ServerKeys::generate(&sk, &mut rng));
    let depth = 3usize;
    let mut cluster = Cluster::start(
        tiny_program(),
        keys,
        ClusterOptions {
            shards: 2,
            policy: PlacementPolicy::RoundRobin,
            queue_depth: Some(depth),
            coordinator: test_coordinator_options(),
            qos: None,
        },
    );
    let enc = |rng: &mut Rng| vec![encrypt_message(1, &sk, rng)];
    // Admission slots are held by the response handles, so backpressure
    // is deterministic regardless of worker timing.
    let mut held: Vec<_> =
        (0..depth).map(|i| cluster.submit(i as u64, enc(&mut rng)).expect("admitted")).collect();
    assert_eq!(cluster.outstanding(), depth);
    assert_eq!(
        cluster.submit(9, enc(&mut rng)).unwrap_err(),
        ClusterError::ClusterFull,
        "admission queue at depth must shed load"
    );
    // Draining one response frees its slot.
    let r = held.pop().unwrap();
    let _ = r.recv().expect("response");
    drop(r);
    let readmitted = cluster.submit(9, enc(&mut rng)).expect("slot freed after drop");
    let _ = readmitted.recv().expect("response");
    drop(readmitted);
    for r in held.drain(..) {
        let _ = r.recv().expect("response");
        drop(r);
    }
    assert_eq!(cluster.outstanding(), 0);
    // Graceful shutdown stops admissions.
    cluster.shutdown();
    assert_eq!(cluster.submit(1, enc(&mut rng)).unwrap_err(), ClusterError::Stopped);
}

#[test]
fn shutdown_drains_already_admitted_requests() {
    let mut rng = Rng::new(79);
    let sk = SecretKeys::generate(&TEST1, &mut rng);
    let keys = Arc::new(ServerKeys::generate(&sk, &mut rng));
    let prog = tiny_program();
    let mut cluster = Cluster::start(
        prog.clone(),
        keys,
        ClusterOptions {
            shards: 2,
            policy: PlacementPolicy::LeastOutstanding,
            queue_depth: None,
            coordinator: test_coordinator_options(),
            qos: None,
        },
    );
    let pend: Vec<_> = (0..4u64)
        .map(|i| {
            let cts = vec![encrypt_message(i % 6, &sk, &mut rng)];
            (i % 6, cluster.submit(i, cts).expect("submit"))
        })
        .collect();
    // Drain: stop admissions, flush every shard's batcher, join workers —
    // every already-admitted request still gets its answer.
    cluster.shutdown();
    for (m, resp) in &pend {
        let outs = resp.recv().expect("drained response");
        assert_eq!(decrypt_message(&outs[0], &sk), interp::eval(&prog, &[*m])[0]);
    }
}

#[test]
fn reshard_growth_past_fixed_keys_is_a_typed_error_not_a_panic() {
    let mut rng = Rng::new(81);
    let sk = SecretKeys::generate(&TEST1, &mut rng);
    let prog = tiny_program();
    // Two fixed per-shard key sets (same secret: outputs stay decryptable
    // under one client key while the stores are genuinely distinct).
    let shard_keys =
        vec![Arc::new(ServerKeys::generate(&sk, &mut rng)), Arc::new(ServerKeys::generate(&sk, &mut rng))];
    let mut cluster = Cluster::start_with_shard_keys(
        prog.clone(),
        shard_keys,
        ClusterOptions {
            shards: 2,
            policy: PlacementPolicy::RoundRobin,
            queue_depth: None,
            coordinator: test_coordinator_options(),
            qos: None,
        },
    );
    // Growing past the 2 provided key sets cannot mint material: typed
    // error, and the cluster is left exactly as it was.
    assert_eq!(
        cluster.reshard(3).unwrap_err(),
        ReshardError::FixedStores { provided: 2, requested: 3 },
    );
    assert_eq!(cluster.shard_count(), 2, "failed reshard must not touch the topology");
    // Still serving: the error path never drained or stopped anything.
    let m = 3u64;
    let r = cluster.submit(1u64, vec![encrypt_message(m, &sk, &mut rng)]).expect("still accepting");
    let outs = r.recv().expect("response");
    assert_eq!(decrypt_message(&outs[0], &sk), interp::eval(&prog, &[m])[0]);
    drop(r);
    // Shrinking within the provided stores still works.
    let report = cluster.reshard(1).expect("shrink within fixed stores");
    assert_eq!((report.old_shards, report.new_shards), (2, 1));
    let r = cluster.submit(2u64, vec![encrypt_message(m, &sk, &mut rng)]).expect("post-shrink");
    let _ = r.recv().expect("response");
    drop(r);
    cluster.shutdown();
}

#[test]
fn snapshot_sums_shards_and_cross_checks_sim() {
    let mut rng = Rng::new(80);
    let sk = SecretKeys::generate(&TEST1, &mut rng);
    let keys = Arc::new(ServerKeys::generate(&sk, &mut rng));
    // Fanout shape so KS-dedup is visible in the cross-check: d = x + y
    // feeds two LUTs (1 shared KS, 2 PBS per request).
    let mut b = ProgramBuilder::new("fan", TEST1.width);
    let x = b.input();
    let y = b.input();
    let d = b.add(x, y);
    let r0 = b.lut_fn(d, |m| (m + 1) % 8);
    let r1 = b.lut_fn(d, |m| m ^ 1);
    b.outputs(&[r0, r1]);
    let prog = b.finish();

    let n = 9usize;
    let mut cluster = Cluster::start(
        prog.clone(),
        keys,
        ClusterOptions {
            shards: 3,
            policy: PlacementPolicy::RoundRobin,
            queue_depth: None,
            coordinator: test_coordinator_options(),
            qos: None,
        },
    );
    let pend: Vec<_> = (0..n)
        .map(|i| {
            let cts = vec![
                encrypt_message((i % 6) as u64, &sk, &mut rng),
                encrypt_message((i % 4) as u64, &sk, &mut rng),
            ];
            cluster.submit(i as u64, cts).expect("submit")
        })
        .collect();
    for resp in &pend {
        let _ = resp.recv().expect("response");
    }
    drop(pend);

    let per = cluster.shard_snapshots();
    let merged = cluster.snapshot();
    assert_eq!(merged.requests, per.iter().map(|s| s.requests).sum::<usize>());
    assert_eq!(merged.requests, n);
    assert_eq!(merged.batches, per.iter().map(|s| s.batches).sum::<usize>());
    assert_eq!(merged.pbs_executed, per.iter().map(|s| s.pbs_executed).sum::<usize>());
    assert_eq!(merged.ks_executed, per.iter().map(|s| s.ks_executed).sum::<u64>());
    assert_eq!(
        merged.bsk_bytes_streamed,
        per.iter().map(|s| s.bsk_bytes_streamed).sum::<u64>()
    );
    assert_eq!(
        merged.latency_samples_ms.len(),
        n,
        "merged snapshot carries every shard's raw samples"
    );

    // The very same artifact costed by the arch model: aggregate measured
    // counters = per-request sim costs x requests, regardless of shards.
    let sim = simulate(cluster.plan(), &TaurusConfig::default());
    assert_eq!(cluster.plan().ks_dedup.after, sim.ks_count, "model costs the deduped KS set");
    assert_eq!(merged.ks_executed, (n * sim.ks_count) as u64);
    assert_eq!(merged.pbs_executed, n * sim.pbs_count);
    cluster.shutdown();
}
