//! Artifacts parse + compile on the PJRT CPU client (full execution is
//! covered by `pbs_xla_vs_native.rs` once keys are generated natively).
//! Requires the `xla` feature (PJRT is unavailable in the offline image).
#![cfg(feature = "xla")]

use taurus::runtime::XlaEngine;

#[test]
fn artifacts_compile() {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let mut eng = XlaEngine::new(dir).expect("engine");
    for (name, tag) in [("blind_rotate", "test1"), ("keyswitch", "test1")] {
        eng.executable(name, tag).unwrap_or_else(|e| panic!("{name}:{tag}: {e:?}"));
    }
}
