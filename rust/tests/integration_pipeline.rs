//! Integration: frontend IR -> compiler -> (a) functional engine,
//! (b) architecture simulator — the full compile-execute-evaluate path on
//! one program, plus cross-workload compiler sanity.

use taurus::arch::{simulate, TaurusConfig};
use taurus::arch::xpu::{simulate_xpu, XpuConfig};
use taurus::baselines::{cpu_model, EPYC_7R13};
use taurus::compiler::{compile, Engine, NativePbsBackend};
use taurus::ir::builder::ProgramBuilder;
use taurus::ir::interp;
use taurus::params::{GPT2, TEST1};
use taurus::tfhe::pbs::{decrypt_message, encrypt_message};
use taurus::tfhe::{SecretKeys, ServerKeys};
use taurus::util::rng::Rng;
use taurus::workloads;

#[test]
fn full_pipeline_on_one_program() {
    // A program with every op kind.
    let mut b = ProgramBuilder::new("pipeline", TEST1.width);
    let x = b.input();
    let y = b.input();
    let u = b.input(); // bivariate operands must stay below 2^(w/2) = 2
    let v = b.input();
    let s = b.add(x, y);
    let d = b.dot(vec![s, x], vec![2, -1], 1);
    let l1 = b.lut_fn(d, |m| (m + 5) % 16);
    let l2 = b.lut_fn(d, |m| m ^ 3); // fanout: shares the KS with l1
    let t = b.sub(l1, l2);
    let biv = b.biv_lut_fn(u, v, |a, bb| a.max(bb));
    let out = b.add(t, biv);
    b.output(out);
    let prog = b.finish();

    // Compile: KS-dedup must fire on the fanout.
    let cfg = TaurusConfig::default();
    let c = compile(&prog, &TEST1, cfg.batch_capacity());
    assert_eq!(c.ks_dedup.before, 3);
    assert_eq!(c.ks_dedup.after, 2);

    // Functional execution == plaintext interpreter.
    let mut rng = Rng::new(77);
    let sk = SecretKeys::generate(&TEST1, &mut rng);
    let keys = ServerKeys::generate(&sk, &mut rng);
    let mut eng = Engine::new(NativePbsBackend::new(&keys));
    for (mx, my, mu, mv) in [(1u64, 2u64, 1u64, 0u64), (3, 3, 0, 1), (0, 7, 1, 1)] {
        let cts = vec![
            encrypt_message(mx, &sk, &mut rng),
            encrypt_message(my, &sk, &mut rng),
            encrypt_message(mu, &sk, &mut rng),
            encrypt_message(mv, &sk, &mut rng),
        ];
        let got: Vec<u64> =
            eng.run(&prog, &cts).iter().map(|ct| decrypt_message(ct, &sk)).collect();
        assert_eq!(got, interp::eval(&prog, &[mx, my, mu, mv]), "({mx},{my},{mu},{mv})");
    }

    // Simulation: nonzero time, sane utilization, all PBS accounted.
    let r = simulate(&c, &cfg);
    assert_eq!(r.pbs_count, prog.pbs_count());
    assert!(r.seconds > 0.0);
    assert!(r.utilization > 0.0 && r.utilization <= 1.0);
}

#[test]
fn table2_shape_taurus_beats_cpu_and_xpu_everywhere() {
    // Cross-workload pipeline check at the paper parameter sets (skip the
    // 12-head build to keep CI time sane).
    let cfg = TaurusConfig::default();
    let xc = XpuConfig::default();
    for w in workloads::all() {
        if w.name.contains("12-head") {
            continue;
        }
        let prog = (w.build)(1);
        let c = compile(&prog, w.params, cfg.batch_capacity());
        let taurus = simulate(&c, &cfg).seconds;
        let cpu = cpu_model::program_seconds(&c, &EPYC_7R13);
        let xpu = simulate_xpu(&c, &xc).seconds;
        assert!(cpu / taurus > 100.0, "{}: cpu speedup {}", w.name, cpu / taurus);
        let sp = xpu / taurus;
        assert!(sp > 2.0 && sp < 12.0, "{}: xpu speedup {sp}", w.name);
        // Within ~3x of the paper's absolute Taurus milliseconds.
        let ratio = (taurus * 1e3) / w.paper_taurus_ms;
        assert!(ratio > 0.3 && ratio < 3.0, "{}: taurus {}ms vs paper {}ms", w.name, taurus * 1e3, w.paper_taurus_ms);
    }
}

#[test]
fn gpt2_workload_runs_functionally_at_test_scale() {
    // The GPT-2 generator's structure (dots + LUT stages) must execute
    // correctly when built tiny at the test parameter set.
    use taurus::ir::LutTable;
    let mut b = ProgramBuilder::new("gpt2-tiny", TEST1.width);
    let tables: Vec<LutTable> = vec![
        LutTable::from_fn(3, |m| (m + 1) / 2),
        LutTable::from_fn(3, |m| m.saturating_sub(1)),
    ];
    let mut stream = b.inputs(4);
    for lvl in 0..3 {
        let mixed: Vec<_> = (0..4)
            .map(|j| {
                let ins = vec![stream[j], stream[(j + 1) % 4]];
                b.dot(ins, vec![1, 1], 0)
            })
            .collect();
        stream = mixed.iter().map(|&v| b.lut(v, tables[lvl % 2].clone())).collect();
    }
    let out = b.dot(stream, vec![1, 1, 1, 1], 0);
    b.output(out);
    let prog = b.finish();

    let mut rng = Rng::new(88);
    let sk = SecretKeys::generate(&TEST1, &mut rng);
    let keys = ServerKeys::generate(&sk, &mut rng);
    let mut eng = Engine::new(NativePbsBackend::new(&keys));
    let inputs = [1u64, 2, 0, 3];
    let cts: Vec<_> = inputs.iter().map(|&m| encrypt_message(m, &sk, &mut rng)).collect();
    let got: Vec<u64> = eng.run(&prog, &cts).iter().map(|c| decrypt_message(c, &sk)).collect();
    assert_eq!(got, interp::eval(&prog, &inputs));
}

#[test]
fn simulator_scaling_sanity() {
    // More clusters -> faster (parallel workload); fewer -> slower.
    let w = workloads::by_name("GPT2").unwrap();
    let prog = (w.build)(1);
    let mut cfg = TaurusConfig::default();
    let c = compile(&prog, &GPT2, cfg.batch_capacity());
    let t4 = simulate(&c, &cfg).seconds;
    cfg.clusters = 8;
    let c8 = compile(&prog, &GPT2, cfg.batch_capacity());
    let t8 = simulate(&c8, &cfg).seconds;
    assert!(t8 < t4, "8 clusters {t8} vs 4 {t4}");
    cfg.clusters = 2;
    let c2 = compile(&prog, &GPT2, cfg.batch_capacity());
    let t2 = simulate(&c2, &cfg).seconds;
    assert!(t2 > t4, "2 clusters {t2} vs 4 {t4}");
}
