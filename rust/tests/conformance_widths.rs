//! Wide-width conformance: the 8/10-bit parameter sets run for real.
//!
//! One width-parametric harness (`eval::conformance`) drives randomized
//! LUT/linear programs through the plaintext interpreter, the
//! schedule-driven engine, and a 2-shard cluster at every functional
//! width {3, 5, 8, 10}, asserting bitwise agreement, measured-vs-modeled
//! KS/PBS counts, and decrypted noise inside the `compiler::noise`
//! prediction. Case counts honor `PROP_CASES` (CI runs 2; use
//! `PROP_CASES=50` for a local soak — see `util::prop`).
//!
//! Keygen at these sizes is the suite's fixed cost, so keys are seeded,
//! chunked, and cached (`tfhe::keycache`); the determinism regression
//! below is what makes that cache sound.

use std::sync::Arc;

use taurus::eval::conformance::{self, KEY_SEED, WIDTHS};
use taurus::params;
use taurus::tfhe::keycache;
use taurus::tfhe::keygen::{server_keys_bitwise_eq, KeygenOptions};
use taurus::tfhe::pbs::encrypt_message;
use taurus::tfhe::{make_lut_poly, PbsContext, ServerKeys};
use taurus::util::rng::Rng;

/// Default cases per width when PROP_CASES is unset: one case keeps the
/// plain `cargo test -q` tier-1 run affordable at the wide widths; CI's
/// dedicated `widths` job runs PROP_CASES=2 so the dedicated lane buys
/// strictly more coverage than the tier-1 smoke.
const DEFAULT_CASES: u64 = 1;

fn run(width: usize) {
    let r = conformance::run_width(width, DEFAULT_CASES);
    println!(
        "conformance width {width} ({}): {} cases, predicted margin >= {:.1} sigma, \
         worst measured output error {:.2} predicted sigmas",
        r.param_name, r.cases, r.min_predicted_margin_sigmas, r.max_measured_err_sigmas
    );
}

#[test]
fn conformance_width_3() {
    run(3);
}

#[test]
fn conformance_width_5() {
    run(5);
}

#[test]
fn conformance_width_8() {
    run(8);
}

#[test]
fn conformance_width_10() {
    run(10);
}

#[test]
fn keygen_determinism_chunked_equals_monolithic_at_every_width() {
    // Same seed -> bitwise-identical ServerKeys across (a) the monolithic
    // path, (b) small-chunk sequential generation, and (c) the cached
    // entry, which is generated with chunking AND multiple workers
    // (tfhe::keycache) — i.e. 1 vs N generation workers agree too.
    for width in WIDTHS {
        let p = params::select_for_width(width);
        let cached = keycache::get(p, KEY_SEED);
        let seed = keycache::server_seed(KEY_SEED);
        let mono = ServerKeys::generate_seeded(&cached.sk, seed, &KeygenOptions::monolithic());
        assert!(
            server_keys_bitwise_eq(&mono, &cached.server),
            "{}: cached (chunked, multi-worker) keys != monolithic keys",
            p.name
        );
        let chunked = ServerKeys::generate_seeded(
            &cached.sk,
            seed,
            &KeygenOptions { chunk: 7, workers: 2 },
        );
        assert!(
            server_keys_bitwise_eq(&mono, &chunked),
            "{}: chunk-7/2-worker keys != monolithic keys",
            p.name
        );
    }
}

#[test]
fn blind_rotation_bitwise_invariant_across_thread_counts_at_widths() {
    // The ISSUE-7 tentpole invariant at the paper widths: splitting a
    // blind rotation's batch columns over a worker pool is a pure
    // scheduling choice. Same keys, same batch -> the same GLWE bits and
    // the same BSK-traffic accounting at every thread count (including
    // counts above the column count, which clamp).
    for width in [3usize, 8, 10] {
        let p = params::select_for_width(width);
        let keys = keycache::get(p, KEY_SEED);
        let mut rng = Rng::new(0x5EED ^ width as u64);
        let lut = make_lut_poly(p, |m| m);
        let msgs: Vec<u64> = (0..4u64).map(|i| i % (1u64 << width)).collect();
        let cts: Vec<_> = msgs.iter().map(|&m| encrypt_message(m, &keys.sk, &mut rng)).collect();

        let mut base_ctx = PbsContext::new(p);
        let shorts: Vec<_> = cts.iter().map(|ct| base_ctx.keyswitch(ct, &keys.server)).collect();
        let base = base_ctx.blind_rotate_batch(&shorts, &keys.server.bsk, &lut);
        let base_bytes = base_ctx.take_bsk_bytes_streamed();

        for threads in [2usize, 4, 8] {
            let mut ctx = PbsContext::with_threads(p, threads);
            let got = ctx.blind_rotate_batch(&shorts, &keys.server.bsk, &lut);
            assert!(
                got == base,
                "{}: {threads}-thread blind rotation changed output bits",
                p.name
            );
            assert_eq!(
                ctx.take_bsk_bytes_streamed(),
                base_bytes,
                "{}: {threads}-thread sweep changed BSK accounting",
                p.name
            );
        }
    }
}

#[test]
fn keycache_shares_one_generation_per_width() {
    for width in WIDTHS {
        let p = params::select_for_width(width);
        let a = keycache::get(p, KEY_SEED);
        let b = keycache::get(p, KEY_SEED);
        assert!(Arc::ptr_eq(&a, &b), "{}: cache must hand out one shared entry", p.name);
        assert!(Arc::ptr_eq(&a.server, &b.server));
    }
}
