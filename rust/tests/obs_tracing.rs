//! Observability integration: trace propagation through the serving
//! stack.
//!
//! Three contracts, each over real cluster serving:
//! - **Disabled invisibility**: with the hooks off (the default), serving
//!   records nothing — no trace events, empty stage histograms, no batch
//!   profiles — and the output ciphertexts are bitwise-identical to an
//!   enabled run of the same encrypted stream (the hooks never perturb
//!   the computation).
//! - **Span-tree completeness under chaos**: every trace id minted at
//!   admission closes with exactly one async end and a terminal instant,
//!   even when the request's batch panics, its resolve fails, or it is
//!   rejected at admission — no orphaned spans, no double-closes.
//! - **Histogram ↔ counter reconciliation**: on fault-free serving the
//!   merged stage histogram counts equal the measured serving counters,
//!   and per-batch drift attribution against `arch::sim` is exact.
//!
//! The obs gate and the flight-recorder registry are process-global, so
//! every test in this file serializes on one lock and restores the
//! disabled state (panic-safe) before releasing it.

use std::sync::{Arc, Mutex, MutexGuard, OnceLock, PoisonError};
use std::time::Duration;

use taurus::arch::TaurusConfig;
use taurus::cluster::{
    Cluster, ClusterOptions, PlacementPolicy, StoreFactory, SupervisorOptions,
};
use taurus::coordinator::{BackendKind, CoordinatorOptions};
use taurus::ir::builder::ProgramBuilder;
use taurus::ir::{interp, Program};
use taurus::obs;
use taurus::obs::trace::EventKind;
use taurus::params::TEST1;
use taurus::runtime::faults::{FaultPlan, FaultSpec, FaultyStore};
use taurus::tenant::{KeyStore, StaticKeys};
use taurus::tfhe::pbs::{decrypt_message, encrypt_message};
use taurus::tfhe::{LweCiphertext, SecretKeys, ServerKeys};
use taurus::util::rng::Rng;

/// Request terminals recorded by `Ticket::wait` / the admission reject
/// path — every complete span tree ends in at least one of these.
const TERMINALS: &[&str] =
    &["served", "timeout", "shard_lost", "exec_failed", "resolve_failed", "rejected"];

fn obs_lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(PoisonError::into_inner)
}

/// Enables tracing for one test body and restores the disabled, empty
/// state on drop — panic-safe so a failing assert cannot leak an enabled
/// gate into the next test.
struct ObsOn;

impl ObsOn {
    fn new() -> Self {
        obs::trace::reset();
        obs::enable();
        ObsOn
    }
}

impl Drop for ObsOn {
    fn drop(&mut self) {
        obs::disable();
        obs::trace::reset();
    }
}

/// Fanout program: one shared KS, two PBS per request.
fn fan_program() -> Program {
    let mut b = ProgramBuilder::new("obs-fan", TEST1.width);
    let x = b.input();
    let y = b.input();
    let d = b.add(x, y);
    let r0 = b.lut_fn(d, |m| (m + 2) % 8);
    let r1 = b.lut_fn(d, |m| m ^ 3);
    b.outputs(&[r0, r1]);
    b.finish()
}

fn coord_options() -> CoordinatorOptions {
    CoordinatorOptions {
        workers: 1,
        batch_capacity: 2,
        max_batch_wait: Duration::from_millis(1),
        ..Default::default()
    }
}

fn cluster_options() -> ClusterOptions {
    ClusterOptions {
        shards: 2,
        policy: PlacementPolicy::RoundRobin,
        queue_depth: None,
        coordinator: coord_options(),
        qos: None,
    }
}

fn encrypt_stream(
    queries: &[[u64; 2]],
    sk: &SecretKeys,
    rng: &mut Rng,
) -> Vec<Vec<LweCiphertext>> {
    queries
        .iter()
        .map(|q| vec![encrypt_message(q[0], sk, rng), encrypt_message(q[1], sk, rng)])
        .collect()
}

fn serve_all(
    cluster: &mut Cluster,
    encrypted: &[Vec<LweCiphertext>],
) -> Vec<Vec<LweCiphertext>> {
    let pend: Vec<_> = encrypted
        .iter()
        .enumerate()
        .map(|(i, cts)| cluster.submit(i as u64, cts.clone()).expect("submit"))
        .collect();
    let outs = pend.iter().map(|r| r.wait().expect("served")).collect();
    drop(pend);
    outs
}

#[test]
fn disabled_tracing_is_invisible() {
    let _guard = obs_lock();
    assert!(!obs::enabled(), "obs must be disabled by default");
    obs::trace::reset();

    let mut rng = Rng::new(51);
    let sk = SecretKeys::generate(&TEST1, &mut rng);
    let keys = Arc::new(ServerKeys::generate(&sk, &mut rng));
    let prog = fan_program();
    let queries: Vec<[u64; 2]> = (0..8u64).map(|i| [i % 6, (i * 3) % 6]).collect();
    let encrypted = encrypt_stream(&queries, &sk, &mut rng);

    // Disabled pass: correct answers, zero observability residue.
    assert_eq!(obs::next_trace_id(), 0, "disabled mint must return the sentinel id");
    let mut cluster = Cluster::start(prog.clone(), keys.clone(), cluster_options());
    let disabled_outs = serve_all(&mut cluster, &encrypted);
    let snap = cluster.snapshot();
    cluster.shutdown();
    for (q, outs) in queries.iter().zip(&disabled_outs) {
        let got: Vec<u64> = outs.iter().map(|c| decrypt_message(c, &sk)).collect();
        assert_eq!(got, interp::eval(&prog, q), "query {q:?}");
    }
    assert_eq!(snap.requests, queries.len());
    for (name, h) in snap.stage.named() {
        assert!(h.is_empty(), "disabled serving must not record stage `{name}`");
    }
    assert!(snap.plan_batch_profiles.is_empty(), "disabled serving must not profile batches");
    assert!(obs::trace::drain().is_empty(), "disabled serving must not record trace events");

    // Enabled pass over the SAME ciphertexts: the hooks observe, they do
    // not perturb — output bits identical to the disabled pass.
    let _on = ObsOn::new();
    let mut cluster = Cluster::start(prog, keys, cluster_options());
    let enabled_outs = serve_all(&mut cluster, &encrypted);
    cluster.shutdown();
    assert_eq!(
        enabled_outs, disabled_outs,
        "tracing must be bitwise-invisible to served ciphertexts"
    );
}

#[test]
fn chaos_span_trees_are_complete() {
    let _guard = obs_lock();
    let _on = ObsOn::new();

    let mut rng = Rng::new(52);
    let sk = SecretKeys::generate(&TEST1, &mut rng);
    let keys = Arc::new(ServerKeys::generate(&sk, &mut rng));
    let prog = fan_program();
    let n = 12usize;
    let queries: Vec<[u64; 2]> = (0..n as u64).map(|i| [i % 6, (i * 5) % 6]).collect();
    let encrypted = encrypt_stream(&queries, &sk, &mut rng);

    // Panics, a latency spike, and resolve failures — the full terminal
    // vocabulary is reachable (served / exec_failed / resolve_failed /
    // rejected), and retries re-use the admission-minted id.
    let faults = Arc::new(FaultPlan::from_seed(
        1,
        &FaultSpec {
            op_horizon: 8,
            panics: 2,
            delays: 1,
            delay: Duration::from_millis(10),
            resolve_horizon: 8,
            resolve_failures: 2,
        },
    ));
    let factory: StoreFactory = {
        let (keys, faults) = (keys.clone(), faults.clone());
        Arc::new(move |_shard| {
            let inner = Arc::new(StaticKeys::new(keys.clone())) as Arc<dyn KeyStore>;
            Arc::new(FaultyStore::new(inner, faults.clone())) as Arc<dyn KeyStore>
        })
    };
    let mut cluster = Cluster::start_with_store_factory_supervised(
        prog,
        factory,
        ClusterOptions {
            coordinator: CoordinatorOptions {
                backend: BackendKind::NativeChaos { faults: faults.clone() },
                ..coord_options()
            },
            ..cluster_options()
        },
        SupervisorOptions { max_retries: 2, restart_after_failures: 2, ..Default::default() },
    );

    let mut admitted = 0usize;
    let mut rejected = 0usize;
    let mut pend = Vec::new();
    for (i, cts) in encrypted.iter().enumerate() {
        match cluster.submit_with_deadline(i as u64, cts.clone(), Duration::from_secs(30)) {
            Ok(r) => {
                admitted += 1;
                pend.push(r);
            }
            Err(_) => rejected += 1,
        }
    }
    // Every admitted request TERMINATES; each ticket is waited exactly
    // once (the wait records the terminal instant + async end).
    for r in &pend {
        let _ = r.wait();
    }
    drop(pend);
    cluster.shutdown();

    let events = obs::trace::drain();
    assert_eq!(obs::trace::dropped(), 0, "this stream fits the flight-recorder rings");
    let ids: std::collections::BTreeSet<u64> =
        events.iter().filter(|e| e.trace != 0).map(|e| e.trace).collect();
    assert!(
        ids.len() >= admitted && ids.len() <= admitted + rejected,
        "one trace id per submission: got {} ids for {admitted} admitted + {rejected} rejected",
        ids.len()
    );
    for id in &ids {
        let begins: Vec<_> = events
            .iter()
            .filter(|e| e.trace == *id && e.kind == EventKind::AsyncBegin)
            .collect();
        let ends: Vec<_> = events
            .iter()
            .filter(|e| e.trace == *id && e.kind == EventKind::AsyncEnd)
            .collect();
        assert_eq!(begins.len(), 1, "trace {id}: exactly one async begin");
        assert_eq!(ends.len(), 1, "trace {id}: exactly one async end (no double-close)");
        assert!(
            begins[0].ts_ns <= ends[0].ts_ns,
            "trace {id}: begin must precede end"
        );
        let terminal = events
            .iter()
            .any(|e| e.trace == *id && e.kind == EventKind::Instant && TERMINALS.contains(&e.name));
        assert!(terminal, "trace {id}: span tree must close with a terminal instant");
    }
    // No orphans: every request-scoped event belongs to a begun trace.
    for e in events.iter().filter(|e| e.trace != 0) {
        assert!(ids.contains(&e.trace), "orphan event {} for unknown trace {}", e.name, e.trace);
    }
    println!(
        "chaos span trees: {} traces ({admitted} admitted, {rejected} rejected), {} events, injected {:?}",
        ids.len(),
        events.len(),
        faults.injected()
    );
}

#[test]
fn fault_free_histograms_reconcile_with_counters() {
    let _guard = obs_lock();
    let _on = ObsOn::new();

    let mut rng = Rng::new(53);
    let sk = SecretKeys::generate(&TEST1, &mut rng);
    let keys = Arc::new(ServerKeys::generate(&sk, &mut rng));
    let prog = fan_program();
    let queries: Vec<[u64; 2]> = (0..10u64).map(|i| [i % 6, (i * 7) % 6]).collect();
    let encrypted = encrypt_stream(&queries, &sk, &mut rng);

    let mut cluster = Cluster::start(prog, keys, cluster_options());
    let _ = serve_all(&mut cluster, &encrypted);
    let snap = cluster.snapshot();
    let plan = cluster.plan();

    // Merged stage histogram counts equal the measured serving counters:
    // one queue sample per request, one KS sample per executed key
    // switch, one sample-extract sample per PBS.
    assert_eq!(snap.stage.queue.count(), snap.requests as u64, "queue samples == requests");
    assert_eq!(snap.stage.keyswitch.count(), snap.ks_executed, "KS samples == ks_executed");
    assert_eq!(
        snap.stage.sample_extract.count(),
        snap.pbs_executed as u64,
        "SE samples == pbs_executed"
    );
    assert!(snap.stage.blind_rotate.count() > 0, "blind-rotate stage recorded");
    assert!(snap.stage.fft.count() > 0, "FFT transform meter recorded");

    // Per-batch drift attribution is EXACT on the fault-free path.
    assert!(!snap.plan_batch_profiles.is_empty(), "enabled serving must profile batches");
    let predicted =
        taurus::arch::sim::batch_predictions(&plan.schedule, &plan.params, &TaurusConfig::default());
    let rows = taurus::obs::drift::attribute(&snap.plan_batch_profiles, &predicted);
    assert!(
        taurus::obs::drift::counts_exact(&rows),
        "fault-free drift attribution must match arch::sim exactly: {rows:?}"
    );
    let measured_ks: u64 = rows.iter().map(|r| r.measured_ks).sum();
    assert_eq!(measured_ks, snap.ks_executed, "profile KS totals reconcile with metrics");
    cluster.shutdown();
}
