//! QoS + traffic suite: weighted-fair admission, token-bucket throttling,
//! metrics-driven autoscaling, and loadgen determinism.
//!
//! The contract under test, per the traffic subsystem's design:
//!
//! - **No starvation**: one tenant offering 100x load cannot push other
//!   tenants' requests behind its backlog — DRR interleaves cold tenants
//!   at the quantum, so they complete while the hot queue is still long.
//! - **Throttle exactness**: a tenant's admitted requests never exceed
//!   bucket capacity + rate x elapsed; every excess submit fails typed
//!   (`Throttled`), and the counter matches the client's observation.
//! - **Autoscaler**: a burst reshards the cluster up and idleness brings
//!   it back down, with zero lost or double-executed requests (the drain
//!   semantics of `reshard` carry through the control loop).
//! - **QoS off**: serving output stays bitwise-identical to the plain
//!   coordinator path and every new counter reads 0.
//! - **Loadgen**: schedules are a pure function of the seed, identical
//!   across minting thread counts; Zipf empirical frequencies match the
//!   analytic pmf within tolerance.
//! - **Composition**: faults + throttling + autoscaling together still
//!   terminate every request typed (seeds from `CHAOS_SEEDS`).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use taurus::cluster::{
    Cluster, ClusterError, ClusterOptions, PlacementPolicy, StoreFactory, SupervisorOptions,
};
use taurus::coordinator::{BackendKind, Coordinator, CoordinatorOptions};
use taurus::ir::builder::ProgramBuilder;
use taurus::ir::{interp, Program};
use taurus::params::TEST1;
use taurus::runtime::faults::{FaultPlan, FaultSpec, FaultyStore};
use taurus::tenant::{KeyStore, StaticKeys};
use taurus::tfhe::pbs::{decrypt_message, encrypt_message};
use taurus::tfhe::{LweCiphertext, SecretKeys, ServerKeys};
use taurus::traffic::{
    ArrivalDraw, AutoscaleOptions, AutoscaledCluster, LoadEvent, LoadPlan, LoadSpec, QosOptions,
    TokenBucketSpec, ZipfSampler,
};
use taurus::util::rng::Rng;

/// Cheapest serving shape (1 PBS per request) so backlog-building tests
/// can push 100+ requests without dominating the suite's budget.
fn lut_program() -> Program {
    let mut b = ProgramBuilder::new("qos-lut", TEST1.width);
    let x = b.input();
    let o = b.lut_fn(x, |m| (m + 1) % 8);
    b.output(o);
    b.finish()
}

/// Fanout program (1 shared KS, 2 PBS) for the bitwise-identity test —
/// the same shape the chaos suite compares against.
fn fan_program() -> Program {
    let mut b = ProgramBuilder::new("qos-fan", TEST1.width);
    let x = b.input();
    let y = b.input();
    let d = b.add(x, y);
    let r0 = b.lut_fn(d, |m| (m + 1) % 8);
    let r1 = b.lut_fn(d, |m| m ^ 1);
    b.outputs(&[r0, r1]);
    b.finish()
}

fn static_factory(keys: Arc<ServerKeys>) -> StoreFactory {
    Arc::new(move |_shard| Arc::new(StaticKeys::new(keys.clone())) as Arc<dyn KeyStore>)
}

fn chaos_seeds() -> Vec<u64> {
    std::env::var("CHAOS_SEEDS")
        .unwrap_or_else(|_| "0 1".into())
        .split_whitespace()
        .map(|s| s.parse().expect("CHAOS_SEEDS must be whitespace-separated u64s"))
        .collect()
}

#[test]
fn hot_tenant_cannot_starve_cold_tenants() {
    let mut rng = Rng::new(41);
    let sk = SecretKeys::generate(&TEST1, &mut rng);
    let keys = Arc::new(ServerKeys::generate(&sk, &mut rng));
    let prog = lut_program();
    let hot = 100usize;
    let cold_tenants = 2usize;
    let mut cluster = Cluster::start_with_store_factory(
        prog,
        static_factory(keys),
        ClusterOptions {
            shards: 1,
            policy: PlacementPolicy::RoundRobin,
            // Two admission permits: service is the bottleneck, so the
            // fair queue holds the backlog where DRR ordering matters.
            queue_depth: Some(2),
            coordinator: CoordinatorOptions {
                workers: 1,
                batch_capacity: 1,
                max_batch_wait: Duration::from_millis(1),
                ..Default::default()
            },
            qos: Some(QosOptions {
                tenant_queue_depth: hot + 8,
                ..QosOptions::default()
            }),
        },
    );

    // Pre-encrypt everything so the submission burst is tight: the hot
    // tenant's 100 requests are all queued before the cold tenants ask.
    let hot_inputs: Vec<Vec<LweCiphertext>> =
        (0..hot).map(|i| vec![encrypt_message((i % 6) as u64, &sk, &mut rng)]).collect();
    let cold_inputs: Vec<Vec<LweCiphertext>> =
        (0..cold_tenants).map(|t| vec![encrypt_message(t as u64, &sk, &mut rng)]).collect();

    // QoS submits enqueue and return immediately, so the hot backlog is
    // fully formed before the cold tenants ask.
    let mut submissions = Vec::new();
    for cts in hot_inputs {
        let r = cluster.submit(0u64, cts).expect("hot tenant admits (no bucket armed)");
        submissions.push((0u64, r));
    }
    for (t, cts) in cold_inputs.into_iter().enumerate() {
        let sess = (t + 1) as u64;
        let r = cluster.submit(sess, cts).expect("cold tenant admits");
        submissions.push((sess, r));
    }

    // One waiter thread per response, each dropping its handle as soon as
    // it completes: permits are held by live handles, so prompt drops are
    // what lets the two admission slots cycle through the backlog. The
    // shared counter records cluster-wide completion order.
    let order = Arc::new(AtomicUsize::new(0));
    let waiters: Vec<_> = submissions
        .into_iter()
        .map(|(sess, resp)| {
            let order = order.clone();
            std::thread::spawn(move || {
                let _ = resp.recv().expect("served");
                (sess, order.fetch_add(1, Ordering::SeqCst))
            })
        })
        .collect();
    let completions: Vec<(u64, usize)> =
        waiters.into_iter().map(|h| h.join().expect("waiter thread")).collect();

    // Cold tenants offered 1 request each against a 100-deep hot backlog
    // (100x load). DRR serves each lane one quantum per round, so both
    // cold requests complete within a few service slots — not after the
    // hot queue drains (FIFO would complete them at positions 101..102).
    for (sess, k) in &completions {
        if *sess != 0 {
            assert!(
                *k <= 25,
                "cold tenant {sess} completed at position {k} of {} — starved behind the \
                 hot backlog",
                hot + cold_tenants,
            );
        }
    }
    assert_eq!(completions.len(), hot + cold_tenants);
    let snap = cluster.snapshot();
    assert_eq!(snap.requests, hot + cold_tenants, "every admitted request served exactly once");
    assert_eq!(snap.qos_throttled, 0, "no bucket armed, so nothing throttles");
    assert_eq!(snap.qos_queue_rejections, 0);
    // Satellite: per-tenant latency reservoirs surface for every session
    // that served — the fairness report reads p99 from here.
    for t in 0..=cold_tenants as u64 {
        assert!(
            snap.tenant_p99_ms(t).is_some(),
            "session {t} must have latency samples in the per-tenant reservoir"
        );
    }
    cluster.shutdown();
}

#[test]
fn token_bucket_throttling_is_exact() {
    let mut rng = Rng::new(42);
    let sk = SecretKeys::generate(&TEST1, &mut rng);
    let keys = Arc::new(ServerKeys::generate(&sk, &mut rng));
    let rate = 50.0f64;
    let burst = 5.0f64;
    let mut cluster = Cluster::start_with_store_factory(
        lut_program(),
        static_factory(keys),
        ClusterOptions {
            shards: 1,
            policy: PlacementPolicy::RoundRobin,
            queue_depth: None,
            coordinator: CoordinatorOptions {
                workers: 1,
                batch_capacity: 8,
                max_batch_wait: Duration::from_micros(500),
                ..Default::default()
            },
            qos: Some(QosOptions {
                bucket: Some(TokenBucketSpec::new(rate, burst)),
                tenant_queue_depth: 64,
                ..QosOptions::default()
            }),
        },
    );

    let n = 40usize;
    let inputs: Vec<Vec<LweCiphertext>> =
        (0..n).map(|i| vec![encrypt_message((i % 6) as u64, &sk, &mut rng)]).collect();
    let t0 = Instant::now();
    let mut admitted = Vec::new();
    let mut throttled = 0usize;
    for cts in inputs {
        match cluster.submit(7u64, cts) {
            Ok(r) => admitted.push(r),
            Err(ClusterError::Throttled) => throttled += 1,
            Err(e) => panic!("only Throttled is expected here: {e}"),
        }
    }
    // The exactness bound: tokens available over the window are the
    // initial burst plus rate x elapsed (measured AFTER the last submit,
    // so it upper-bounds every refill the bucket saw; +1 absorbs the
    // token in flight at the boundary).
    let elapsed = t0.elapsed().as_secs_f64();
    let bound = burst + rate * elapsed + 1.0;
    assert!(
        (admitted.len() as f64) <= bound,
        "admitted {} exceeds the token-bucket bound {bound:.2} (elapsed {elapsed:.4}s)",
        admitted.len(),
    );
    assert!(
        admitted.len() >= burst as usize,
        "the bucket starts full: at least the burst is admitted ({} < {burst})",
        admitted.len(),
    );
    assert_eq!(admitted.len() + throttled, n, "every submit terminated typed");

    for r in &admitted {
        let _ = r.recv().expect("admitted requests serve normally");
    }
    let served = admitted.len();
    drop(admitted);
    let snap = cluster.snapshot();
    assert_eq!(snap.qos_throttled, throttled as u64, "counter matches client-observed throttles");
    assert_eq!(snap.qos_queue_rejections, 0);
    assert_eq!(snap.requests, served);
    cluster.shutdown();
}

#[test]
fn autoscaler_reshards_up_and_down_without_losing_requests() {
    let mut rng = Rng::new(43);
    let sk = SecretKeys::generate(&TEST1, &mut rng);
    let keys = Arc::new(ServerKeys::generate(&sk, &mut rng));
    let prog = lut_program();
    let cluster = Cluster::start_with_store_factory(
        prog.clone(),
        static_factory(keys),
        ClusterOptions {
            shards: 1,
            policy: PlacementPolicy::RoundRobin,
            queue_depth: None,
            coordinator: CoordinatorOptions {
                workers: 1,
                batch_capacity: 1,
                max_batch_wait: Duration::from_millis(1),
                ..Default::default()
            },
            qos: None,
        },
    );
    let mut auto_cluster = AutoscaledCluster::start(
        cluster,
        AutoscaleOptions {
            min_shards: 1,
            max_shards: 3,
            high_watermark: 4.0,
            low_watermark: 1.0,
            hysteresis: 1,
            cooldown_polls: 1,
            poll: Duration::from_millis(2),
            ..Default::default()
        },
    );

    // The burst: 64 single-PBS requests land at once on one slow shard,
    // so the controller sees backlog-per-shard far above the high
    // watermark within a poll or two.
    let n = 64usize;
    let queries: Vec<u64> = (0..n as u64).map(|i| i % 6).collect();
    let encrypted: Vec<Vec<LweCiphertext>> =
        queries.iter().map(|&q| vec![encrypt_message(q, &sk, &mut rng)]).collect();
    let pend: Vec<_> = encrypted
        .into_iter()
        .enumerate()
        .map(|(i, cts)| (i, auto_cluster.submit(i as u64 % 8, cts).expect("submit")))
        .collect();
    // Zero lost, zero double-executed: every response arrives exactly
    // once and decrypts to the interpreter's answer, across however many
    // reshards fired mid-burst (reshard drains admitted work first).
    for (i, r) in &pend {
        let outs = r.recv().expect("served across reshards");
        let got: Vec<u64> = outs.iter().map(|c| decrypt_message(c, &sk)).collect();
        assert_eq!(got, interp::eval(&prog, &[queries[*i]]), "request {i}");
    }
    drop(pend);

    // Convergence: scaled up under the burst, back down to min when
    // idle. Poll with a generous deadline — the control loop's cadence
    // is milliseconds, the bound is seconds.
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let (ups, downs) = auto_cluster.scale_events();
        if ups >= 1 && downs >= 1 && auto_cluster.shard_count() == 1 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "autoscaler must scale up under the burst and settle back to min when idle \
             (ups {ups}, downs {downs}, shards {})",
            auto_cluster.shard_count(),
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    let snap = auto_cluster.snapshot();
    assert_eq!(
        snap.requests, n,
        "zero lost or double-executed requests across the reshard cycle"
    );
    assert!(snap.autoscale_ups >= 1 && snap.autoscale_downs >= 1);

    // No oscillation at rest: an idle cluster pinned at min_shards emits
    // no further scale events (low watermark + min bound = Hold).
    let settled = auto_cluster.scale_events();
    std::thread::sleep(Duration::from_millis(60));
    assert_eq!(
        auto_cluster.scale_events(),
        settled,
        "idle cluster must not flap between shard counts"
    );
    assert_eq!(auto_cluster.shard_count(), 1);
    auto_cluster.shutdown();
}

#[test]
fn qos_off_serving_is_bitwise_identical_with_zero_new_counters() {
    let mut rng = Rng::new(44);
    let sk = SecretKeys::generate(&TEST1, &mut rng);
    let keys = Arc::new(ServerKeys::generate(&sk, &mut rng));
    let prog = fan_program();
    let n = 12usize;
    let encrypted: Vec<Vec<LweCiphertext>> = (0..n as u64)
        .map(|i| {
            vec![
                encrypt_message(i % 6, &sk, &mut rng),
                encrypt_message((i * 3) % 6, &sk, &mut rng),
            ]
        })
        .collect();

    // Pre-PR path: a bare coordinator, no cluster, no QoS anywhere.
    let reference: Vec<Vec<LweCiphertext>> = {
        let mut coord = Coordinator::start(
            prog.clone(),
            keys.clone(),
            CoordinatorOptions {
                workers: 1,
                batch_capacity: 1,
                max_batch_wait: Duration::from_millis(1),
                ..Default::default()
            },
        );
        let pend: Vec<_> =
            encrypted.iter().map(|cts| coord.submit(cts.clone()).expect("submit")).collect();
        let outs = pend.iter().map(|t| t.wait().expect("reference")).collect();
        coord.shutdown();
        outs
    };

    // QoS-off cluster serving of the identical ciphertexts.
    let mut cluster = Cluster::start_with_store_factory(
        prog,
        static_factory(keys),
        ClusterOptions {
            shards: 2,
            policy: PlacementPolicy::RoundRobin,
            queue_depth: None,
            coordinator: CoordinatorOptions {
                workers: 1,
                batch_capacity: 1,
                max_batch_wait: Duration::from_millis(1),
                ..Default::default()
            },
            qos: None,
        },
    );
    let pend: Vec<_> = encrypted
        .iter()
        .enumerate()
        .map(|(i, cts)| (i, cluster.submit(i as u64, cts.clone()).expect("submit")))
        .collect();
    for (i, r) in &pend {
        let outs = r.recv().expect("served");
        assert_eq!(
            outs, reference[*i],
            "request {i}: QoS-off cluster output must be bitwise-identical to the plain \
             coordinator path"
        );
    }
    drop(pend);
    let snap = cluster.snapshot();
    assert_eq!(snap.qos_throttled, 0, "QoS off: throttle counter must read 0");
    assert_eq!(snap.qos_queue_rejections, 0, "QoS off: rejection counter must read 0");
    assert_eq!(snap.autoscale_ups, 0, "no autoscaler: scale-up counter must read 0");
    assert_eq!(snap.autoscale_downs, 0, "no autoscaler: scale-down counter must read 0");
    assert_eq!(snap.requests, n);
    cluster.shutdown();
}

/// Regression (wire-server client-disconnect path): a caller that DROPS a
/// `ClusterResponse` without ever waiting must still release its
/// admission permit — and, on the QoS path, its per-tenant queue slot.
#[test]
fn dropping_a_response_without_waiting_frees_permit_and_queue_slot() {
    let mut rng = Rng::new(45);
    let sk = SecretKeys::generate(&TEST1, &mut rng);
    let keys = Arc::new(ServerKeys::generate(&sk, &mut rng));
    let prog = lut_program();
    let enc = |rng: &mut Rng| vec![encrypt_message(3, &sk, rng)];

    // --- Direct path: one admission permit, held by the response handle.
    let mut cluster = Cluster::start_with_store_factory(
        prog.clone(),
        static_factory(keys.clone()),
        ClusterOptions {
            shards: 1,
            policy: PlacementPolicy::RoundRobin,
            queue_depth: Some(1),
            coordinator: CoordinatorOptions { workers: 1, ..Default::default() },
            qos: None,
        },
    );
    let held = cluster.submit(0u64, enc(&mut rng)).expect("admit");
    assert_eq!(cluster.outstanding(), 1);
    assert!(
        matches!(cluster.submit(1u64, enc(&mut rng)), Err(ClusterError::ClusterFull)),
        "the single permit is held"
    );
    drop(held); // never waited
    assert_eq!(cluster.outstanding(), 0, "dropping an unawaited response frees its permit");
    let next = cluster.submit(1u64, enc(&mut rng)).expect("slot is free again");
    let _ = next.recv().expect("serves normally");
    drop(next);
    cluster.shutdown();

    // --- QoS path: one permit AND a 1-deep per-tenant queue. Pipeline:
    // a plug request holds the permit, one job sits in the dispatcher's
    // hand waiting for it, one fills the tenant FIFO, and the next
    // rejects typed. Dropping the unawaited handles must free both the
    // permit chain and the queue slot.
    let mut cluster = Cluster::start_with_store_factory(
        prog,
        static_factory(keys),
        ClusterOptions {
            shards: 1,
            policy: PlacementPolicy::RoundRobin,
            queue_depth: Some(1),
            coordinator: CoordinatorOptions {
                workers: 1,
                batch_capacity: 1,
                max_batch_wait: Duration::from_millis(1),
                ..Default::default()
            },
            qos: Some(QosOptions { tenant_queue_depth: 1, ..QosOptions::default() }),
        },
    );
    let plug = cluster.submit(9u64, enc(&mut rng)).expect("plug queues");
    // Wait until the dispatcher picked the plug up and claimed the single
    // permit (its job left the fair queue). The live `plug` handle keeps
    // that permit held even after its service completes, so from here the
    // dispatcher is deterministically starved of permits.
    let spin_deadline = Instant::now() + Duration::from_secs(10);
    while cluster.fair_queue_len() > 0 || cluster.outstanding() < 1 {
        assert!(Instant::now() < spin_deadline, "plug must dispatch");
        std::thread::yield_now();
    }
    let in_hand = cluster.submit(5u64, enc(&mut rng)).expect("queues behind the plug");
    // The dispatcher pops this job immediately and blocks waiting for the
    // permit — it leaves the FIFO even though it cannot dispatch.
    let spin_deadline = Instant::now() + Duration::from_secs(10);
    while cluster.fair_queue_len() > 0 {
        assert!(Instant::now() < spin_deadline, "dispatcher must take the job in hand");
        std::thread::yield_now();
    }
    let queued = cluster.submit(5u64, enc(&mut rng)).expect("fills the tenant FIFO");
    // Depth-1 tenant FIFO with one job in the dispatcher's hand and the
    // permit pinned by the plug: deterministically full.
    match cluster.submit(5u64, enc(&mut rng)) {
        Err(ClusterError::TenantQueueFull) => {}
        Ok(_) => panic!("a full 1-deep tenant FIFO must reject"),
        Err(e) => panic!("unexpected admission error: {e}"),
    }

    // Client disconnect: drop every unawaited handle, then release the
    // plug. Cancelled jobs are skipped by the dispatcher, freeing the
    // queue slots; dropped handles free their permits.
    drop(in_hand);
    drop(queued);
    let _ = plug.recv().expect("plug serves");
    drop(plug);
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        // A fresh submit for the same tenant must eventually be admitted
        // AND served — proof the queue slot and permit both came back.
        match cluster.submit(5u64, enc(&mut rng)) {
            Ok(r) => {
                let _ = r.recv().expect("fresh request serves after the disconnects");
                drop(r);
                break;
            }
            Err(ClusterError::TenantQueueFull) => {
                assert!(
                    Instant::now() < deadline,
                    "cancelled jobs must vacate the tenant queue"
                );
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) => panic!("unexpected admission error: {e}"),
        }
    }
    // Everything drains: no leaked permits from the dropped handles.
    let deadline = Instant::now() + Duration::from_secs(10);
    while cluster.outstanding() > 0 || cluster.fair_queue_len() > 0 {
        assert!(Instant::now() < deadline, "dropped responses must release every permit");
        std::thread::sleep(Duration::from_millis(2));
    }
    cluster.shutdown();
}

#[test]
fn loadgen_schedule_is_identical_across_thread_counts() {
    let spec = LoadSpec {
        tenants: 16,
        zipf_s: 1.1,
        events: 256,
        keep: 0.9,
        ..LoadSpec::default()
    };
    let seed = 0xD15C_0C0Du64;
    let sequential = LoadPlan::from_seed(seed, &spec);
    assert!(!sequential.events().is_empty());

    for threads in [2usize, 5, 8] {
        // Mint per-index draws in disjoint chunks on real threads —
        // index-addressable forking means chunk boundaries and thread
        // interleavings cannot change a single draw.
        let chunk = spec.events.div_ceil(threads);
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let spec = spec.clone();
                std::thread::spawn(move || {
                    let sampler = ZipfSampler::new(spec.tenants, spec.zipf_s);
                    let lo = t * chunk;
                    let hi = ((t + 1) * chunk).min(spec.events);
                    (lo..hi)
                        .map(|i| LoadPlan::draw(&sampler, seed, &spec, i as u64))
                        .collect::<Vec<ArrivalDraw>>()
                })
            })
            .collect();
        let draws: Vec<ArrivalDraw> =
            handles.into_iter().flat_map(|h| h.join().expect("mint thread")).collect();
        assert_eq!(draws.len(), spec.events);

        // Reassemble exactly as `from_seed` does and compare bitwise.
        let mut at = Duration::ZERO;
        let mut events = Vec::new();
        for (i, d) in draws.iter().enumerate() {
            if spec.burst_len > 0 && i > 0 && i % spec.burst_len == 0 {
                at += spec.off_gap;
            }
            at += d.gap;
            if d.kept {
                events.push(LoadEvent { at, session: d.session });
            }
        }
        assert_eq!(
            events.as_slice(),
            sequential.events(),
            "{threads}-thread mint must produce the identical schedule"
        );
    }
}

#[test]
fn zipf_empirical_frequencies_match_analytic_pmf() {
    let tenants = 32usize;
    let z = ZipfSampler::new(tenants, 1.0);
    let mut rng = Rng::new(0x21BF);
    let n = 200_000u64;
    let mut counts = vec![0u64; tenants];
    for _ in 0..n {
        counts[z.sample(&mut rng) as usize] += 1;
    }
    assert_eq!(counts.iter().sum::<u64>(), n);
    // Head ranks carry enough mass for a tight relative check; the tail
    // gets an absolute tolerance (few-hundred-count bins are noisy).
    for r in 0..tenants {
        let emp = counts[r] as f64 / n as f64;
        let ana = z.pmf(r);
        if r < 8 {
            assert!(
                (emp - ana).abs() / ana < 0.10,
                "rank {r}: empirical {emp:.5} vs analytic {ana:.5}"
            );
        } else {
            assert!(
                (emp - ana).abs() < 0.005,
                "rank {r}: empirical {emp:.5} vs analytic {ana:.5}"
            );
        }
    }
}

/// Chaos composition: deterministic faults + token buckets + fair
/// queueing + the autoscaler, all armed at once. The only contract that
/// survives composition is the strongest one: every request TERMINATES —
/// served (decrypting to the interpreter's answer), throttled, rejected,
/// or failed typed — and the throttle counter stays exact.
#[test]
fn chaos_composition_faults_throttling_autoscale_all_terminate() {
    let mut rng = Rng::new(46);
    let sk = SecretKeys::generate(&TEST1, &mut rng);
    let keys = Arc::new(ServerKeys::generate(&sk, &mut rng));
    let prog = fan_program();
    let n = 24usize;
    let queries: Vec<[u64; 2]> = (0..n as u64).map(|i| [i % 6, (i * 3) % 6]).collect();

    for seed in chaos_seeds() {
        let faults = Arc::new(FaultPlan::from_seed(
            seed,
            &FaultSpec {
                op_horizon: 8,
                panics: 2,
                delays: 1,
                delay: Duration::from_millis(10),
                resolve_horizon: 8,
                resolve_failures: 2,
            },
        ));
        let factory: StoreFactory = {
            let keys = keys.clone();
            let faults = faults.clone();
            Arc::new(move |_shard| {
                let inner = Arc::new(StaticKeys::new(keys.clone())) as Arc<dyn KeyStore>;
                Arc::new(FaultyStore::new(inner, faults.clone())) as Arc<dyn KeyStore>
            })
        };
        let cluster = Cluster::start_with_store_factory_supervised(
            prog.clone(),
            factory,
            ClusterOptions {
                shards: 1,
                policy: PlacementPolicy::RoundRobin,
                queue_depth: Some(4),
                coordinator: CoordinatorOptions {
                    workers: 1,
                    batch_capacity: 1,
                    max_batch_wait: Duration::from_millis(1),
                    backend: BackendKind::NativeChaos { faults: faults.clone() },
                    ..Default::default()
                },
                qos: Some(QosOptions {
                    bucket: Some(TokenBucketSpec::new(200.0, 8.0)),
                    tenant_queue_depth: 8,
                    ..QosOptions::default()
                }),
            },
            SupervisorOptions { max_retries: 2, restart_after_failures: 2, ..Default::default() },
        );
        let mut auto_cluster = AutoscaledCluster::start(
            cluster,
            AutoscaleOptions {
                min_shards: 1,
                max_shards: 2,
                high_watermark: 3.0,
                low_watermark: 0.5,
                hysteresis: 2,
                cooldown_polls: 2,
                poll: Duration::from_millis(5),
                ..Default::default()
            },
        );

        let mut pend = Vec::new();
        let mut throttled = 0usize;
        let mut rejected = 0usize;
        for (i, q) in queries.iter().enumerate() {
            let cts = vec![
                encrypt_message(q[0], &sk, &mut rng),
                encrypt_message(q[1], &sk, &mut rng),
            ];
            // Hot tenant 0 takes 3 of every 4 requests; the bucket and
            // FIFO bite it first.
            let session = if i % 4 == 3 { 1u64 } else { 0u64 };
            match auto_cluster.submit_with_deadline(session, cts, Duration::from_secs(30)) {
                Ok(r) => pend.push((i, r)),
                Err(ClusterError::Throttled) => throttled += 1,
                Err(ClusterError::TenantQueueFull) => rejected += 1,
                Err(e) => {
                    println!("seed {seed}: request {i} rejected at admission: {e}");
                    rejected += 1;
                }
            }
        }
        // Consume handles as they resolve: the admission permit rides the
        // live handle, so dropping each response promptly is what keeps
        // the 4-deep permit pool cycling through the queued backlog.
        let mut ok = 0usize;
        let mut failed = 0usize;
        for (i, r) in pend {
            match r.wait() {
                Ok(outs) => {
                    let got: Vec<u64> = outs.iter().map(|c| decrypt_message(c, &sk)).collect();
                    assert_eq!(
                        got,
                        interp::eval(&prog, &queries[i]),
                        "seed {seed}: request {i} served wrong bits under composition"
                    );
                    ok += 1;
                }
                Err(err) => {
                    println!("seed {seed}: request {i} failed typed: {err}");
                    failed += 1;
                }
            }
        }
        assert_eq!(
            ok + failed + throttled + rejected,
            n,
            "seed {seed}: every request terminated (served/failed/throttled/rejected)"
        );
        let snap = auto_cluster.snapshot();
        assert_eq!(
            snap.qos_throttled, throttled as u64,
            "seed {seed}: throttle counter stays exact under chaos"
        );
        assert_eq!(snap.requests, ok, "seed {seed}: served == client-observed successes");
        auto_cluster.shutdown();
        println!(
            "seed {seed}: {ok} served / {failed} typed-failed / {throttled} throttled / \
             {rejected} rejected; injected {:?}",
            faults.injected()
        );
    }
}
