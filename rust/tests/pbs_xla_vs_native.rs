//! End-to-end equivalence: the AOT XLA path (JAX+Pallas artifacts executed
//! via PJRT) and the native Rust TFHE path must evaluate the same LUTs on
//! the same ciphertexts — the core integration proof of the three-layer
//! architecture. Requires the `xla` feature.
#![cfg(feature = "xla")]

use taurus::params::TEST1;
use taurus::runtime::XlaPbsBackend;
use taurus::tfhe::pbs::{decrypt_message, encrypt_message};
use taurus::tfhe::{make_lut_poly, PbsContext, SecretKeys, ServerKeys};
use taurus::util::rng::Rng;

fn artifacts_dir() -> Option<String> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
        Some(dir.to_string())
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

#[test]
fn xla_and_native_pbs_agree() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rng = Rng::new(42);
    let sk = SecretKeys::generate(&TEST1, &mut rng);
    let keys = ServerKeys::generate(&sk, &mut rng);
    let backend = XlaPbsBackend::new(&dir, &TEST1, &keys.bsk, &keys.ksk).expect("backend");
    let mut ctx = PbsContext::new(&TEST1);

    let f = |m: u64| (3 * m + 1) % 16;
    let lut = make_lut_poly(&TEST1, f);
    for m in 0..8u64 {
        let ct = encrypt_message(m, &sk, &mut rng);
        let native = ctx.pbs(&ct, &keys, &lut);
        let xla_out = backend.pbs(&ct, &lut).expect("xla pbs");
        let dm_native = decrypt_message(&native, &sk);
        let dm_xla = decrypt_message(&xla_out, &sk);
        assert_eq!(dm_native, f(m), "native m={m}");
        assert_eq!(dm_xla, f(m), "xla m={m}");
    }
}

#[test]
fn xla_keyswitch_matches_native_bitexact() {
    // Key switching is pure integer arithmetic: the XLA path must agree
    // with the native path to the bit.
    let Some(dir) = artifacts_dir() else { return };
    let mut rng = Rng::new(7);
    let sk = SecretKeys::generate(&TEST1, &mut rng);
    let keys = ServerKeys::generate(&sk, &mut rng);
    let backend = XlaPbsBackend::new(&dir, &TEST1, &keys.bsk, &keys.ksk).expect("backend");
    for m in [0u64, 5, 7] {
        let ct = encrypt_message(m, &sk, &mut rng);
        let native = keys.ksk.keyswitch(&ct, &TEST1);
        let via_xla = backend.keyswitch(&ct).expect("ks");
        assert_eq!(native.data, via_xla.data, "m={m}");
    }
}

#[test]
fn xla_blind_rotate_phase_matches_native() {
    // Blind rotation goes through f64 FFTs on both sides (different FFT
    // implementations), so compare decrypted phases, not bits.
    let Some(dir) = artifacts_dir() else { return };
    let mut rng = Rng::new(9);
    let sk = SecretKeys::generate(&TEST1, &mut rng);
    let keys = ServerKeys::generate(&sk, &mut rng);
    let backend = XlaPbsBackend::new(&dir, &TEST1, &keys.bsk, &keys.ksk).expect("backend");
    let mut ctx = PbsContext::new(&TEST1);
    let lut = make_lut_poly(&TEST1, |m| m);
    let ct = encrypt_message(3, &sk, &mut rng);
    let short = keys.ksk.keyswitch(&ct, &TEST1);

    let native_acc = ctx.blind_rotate(&short, &keys.bsk, &lut);
    let xla_flat = backend.blind_rotate(&short, &lut).expect("br");
    assert_eq!(xla_flat.len(), native_acc.data.len());
    let xla_acc = taurus::tfhe::GlweCiphertext {
        data: xla_flat,
        k: TEST1.k,
        big_n: TEST1.big_n,
    };
    use taurus::tfhe::fft::FftPlan;
    let plan = FftPlan::new(TEST1.big_n);
    let ph_native = native_acc.decrypt_phase(&sk, &plan);
    let ph_xla = xla_acc.decrypt_phase(&sk, &plan);
    for (a, b) in ph_native.iter().zip(&ph_xla) {
        let d = taurus::tfhe::torus::torus_distance(*a, *b);
        assert!(d < 2.0f64.powi(-14), "phase divergence {d}");
    }
}
