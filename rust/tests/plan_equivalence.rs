//! The compiled plan is THE executable artifact: schedule-driven
//! execution must decrypt identically to both the plaintext interpreter
//! and the legacy node-walking engine over randomized programs (fanout,
//! chains, bivariate LUTs) at batch sizes {1, 3, 8}, and its measured
//! KS/PBS counts must equal what the compiler reports and what
//! `arch::sim` costs for the very same plan.

use taurus::arch::{simulate, TaurusConfig};
use taurus::compiler::{compile, CompileOpts, Engine, NativePbsBackend};
use taurus::ir::builder::ProgramBuilder;
use taurus::ir::interp;
use taurus::params::TEST1;
use taurus::tfhe::pbs::{decrypt_message, encrypt_message};
use taurus::tfhe::{LweCiphertext, SecretKeys, ServerKeys};
use taurus::util::prop::check;
use taurus::util::rng::Rng;

/// Shared fixture: keygen once (dominates test time).
struct Fixture {
    sk: SecretKeys,
    keys: ServerKeys,
}

fn fixture() -> &'static Fixture {
    use std::sync::OnceLock;
    static F: OnceLock<Fixture> = OnceLock::new();
    F.get_or_init(|| {
        let mut rng = Rng::new(0x9A7);
        let sk = SecretKeys::generate(&TEST1, &mut rng);
        let keys = ServerKeys::generate(&sk, &mut rng);
        Fixture { sk, keys }
    })
}

/// Random program over width 3: two bivariate operands (kept in {0,1}),
/// free inputs, and a mix of linear ops / LUTs with natural fanout (every
/// op picks operands from all earlier values) plus one bivariate LUT.
fn random_program(rng: &mut Rng) -> (taurus::ir::Program, usize) {
    let mut b = ProgramBuilder::new("rand-plan", TEST1.width);
    let bx = b.input(); // bivariate operands (values < 2^(w/2) = 2)
    let by = b.input();
    let mut vals = vec![bx, by];
    vals.extend(b.inputs(1 + rng.below_usize(2)));
    let n_inputs = vals.len();
    let g = b.biv_lut_fn(bx, by, |a, bb| a ^ bb);
    vals.push(g);
    for _ in 0..(3 + rng.below_usize(5)) {
        let pick = |rng: &mut Rng, vals: &Vec<usize>| vals[rng.below_usize(vals.len())];
        let v = match rng.below(5) {
            0 => {
                let (x, y) = (pick(rng, &vals), pick(rng, &vals));
                b.add(x, y)
            }
            1 => {
                let x = pick(rng, &vals);
                b.mul_plain(x, (rng.below(3) as i64) + 1)
            }
            2 | 3 => {
                // LUTs twice as likely: drives fanout + chains of PBS.
                let x = pick(rng, &vals);
                let off = rng.below(8);
                b.lut_fn(x, move |m| (m + off) % 16)
            }
            _ => {
                let (x, y) = (pick(rng, &vals), pick(rng, &vals));
                b.dot(vec![x, y], vec![1, -1], rng.below(4))
            }
        };
        vals.push(v);
    }
    b.output(*vals.last().unwrap());
    (b.finish(), n_inputs)
}

#[test]
fn prop_plan_equals_interp_equals_legacy_across_batch_sizes() {
    let f = fixture();
    check("plan_exec_equivalence", 4, |rng| {
        let (prog, n_inputs) = random_program(rng);
        let plan = compile(&prog, &TEST1, CompileOpts::default());
        for &nb in &[1usize, 3, 8] {
            // Per-request plaintext queries; bivariate operands in {0,1}.
            let queries: Vec<Vec<u64>> = (0..nb)
                .map(|_| {
                    (0..n_inputs)
                        .map(|i| if i < 2 { rng.below(2) } else { rng.below(8) })
                        .collect()
                })
                .collect();
            let batch: Vec<Vec<LweCiphertext>> = queries
                .iter()
                .map(|q| q.iter().map(|&m| encrypt_message(m, &f.sk, rng)).collect())
                .collect();

            let mut eng = Engine::new(NativePbsBackend::new(&f.keys));
            let plan_outs = eng.run_plan_batch(&plan, &batch);
            let st = eng.take_exec_stats();
            let mut legacy = Engine::new(NativePbsBackend::new(&f.keys));
            for (q, query) in queries.iter().enumerate() {
                let exp = interp::eval(&prog, query);
                let got: Vec<u64> =
                    plan_outs[q].iter().map(|c| decrypt_message(c, &f.sk)).collect();
                if got != exp {
                    return Err(format!(
                        "plan nb={nb} q={q} inputs={query:?}: {got:?} != {exp:?}"
                    ));
                }
                let leg: Vec<u64> = legacy
                    .run(&prog, &batch[q])
                    .iter()
                    .map(|c| decrypt_message(c, &f.sk))
                    .collect();
                if leg != exp {
                    return Err(format!(
                        "legacy nb={nb} q={q} inputs={query:?}: {leg:?} != {exp:?}"
                    ));
                }
            }
            // Measured-vs-model: plan execution performs exactly the
            // deduplicated KS set per request and every scheduled BR.
            let want_ks = (plan.ks_dedup.after * nb) as u64;
            if st.ks_ops != want_ks {
                return Err(format!(
                    "nb={nb}: measured KS {} != dedup after x nb {want_ks}",
                    st.ks_ops
                ));
            }
            let want_pbs = (plan.graph.pbs_count() * nb) as u64;
            if st.pbs_ops != want_pbs {
                return Err(format!(
                    "nb={nb}: measured PBS {} != plan x nb {want_pbs}",
                    st.pbs_ops
                ));
            }
            // Legacy pays the pre-dedup KS count.
            let lst = legacy.take_exec_stats();
            if lst.ks_ops != (plan.ks_dedup.before * nb) as u64 {
                return Err(format!(
                    "nb={nb}: legacy KS {} != before x nb {}",
                    lst.ks_ops,
                    plan.ks_dedup.before * nb
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn fanout_workload_one_keyswitch_and_sim_crosscheck() {
    // Acceptance shape: N LUTs on one value -> the plan path performs
    // exactly 1 KS where the legacy path performs N, decrypts identically
    // to interp, and measured PBS/KS equal arch::sim's costed counts for
    // the same CompiledPlan.
    let f = fixture();
    let mut rng = Rng::new(0xFA0);
    let n = 5usize;
    let mut b = ProgramBuilder::new("fanout", TEST1.width);
    let x = b.input();
    for k in 0..n as u64 {
        let y = b.lut_fn(x, move |m| (m + k) % 16);
        b.output(y);
    }
    let prog = b.finish();
    let plan = compile(&prog, &TEST1, CompileOpts::default());
    assert_eq!((plan.ks_dedup.before, plan.ks_dedup.after), (n, 1));

    let m = 4u64;
    let cts = vec![encrypt_message(m, &f.sk, &mut rng)];
    let mut eng = Engine::new(NativePbsBackend::new(&f.keys));
    let outs = eng.run_plan(&plan, &cts);
    let got: Vec<u64> = outs.iter().map(|c| decrypt_message(c, &f.sk)).collect();
    assert_eq!(got, interp::eval(&prog, &[m]));
    let st = eng.take_exec_stats();
    assert_eq!(st.ks_ops, 1, "exactly one key switch for the whole fanout");
    assert_eq!(st.pbs_ops, n as u64);

    let mut legacy = Engine::new(NativePbsBackend::new(&f.keys));
    let outs2 = legacy.run(&prog, &cts);
    assert_eq!(
        outs2.iter().map(|c| decrypt_message(c, &f.sk)).collect::<Vec<_>>(),
        interp::eval(&prog, &[m])
    );
    assert_eq!(legacy.take_exec_stats().ks_ops, n as u64, "legacy pays N");

    // The same artifact, costed: model == measured.
    let r = simulate(&plan, &TaurusConfig::default());
    assert_eq!(r.ks_count as u64, st.ks_ops);
    assert_eq!(r.pbs_count as u64, st.pbs_ops);
    assert_eq!(plan.schedule.total_ks(), plan.ks_dedup.after);
}
