//! Integration: the multi-tenant session API — per-tenant correctness
//! over a sharded cluster, single-tenant (`StaticKeys`) bitwise
//! compatibility with the pre-session API, and live reshard with
//! key-cache migration.

use std::sync::Arc;
use std::time::Duration;

use taurus::arch::{simulate, TaurusConfig};
use taurus::cluster::{Cluster, ClusterOptions, PlacementPolicy, Router, StoreFactory};
use taurus::compiler::{compile, CompileOpts, Engine, NativePbsBackend};
use taurus::coordinator::CoordinatorOptions;
use taurus::eval::conformance::random_program_for;
use taurus::ir::builder::ProgramBuilder;
use taurus::ir::{interp, Program};
use taurus::params::TEST1;
use taurus::tenant::{client_secret, KeyStore, SeededTenantStore, SessionId, StaticKeys};
use taurus::tfhe::pbs::{decrypt_message, encrypt_message};
use taurus::tfhe::{keycache, server_keys_bitwise_eq, LweCiphertext, SecretKeys};
use taurus::util::rng::Rng;

/// Fanout shape so KS-dedup is visible in the sim cross-check: d = x + y
/// feeds two LUTs (1 shared KS, 2 PBS per request).
fn fanout_program() -> Program {
    let mut b = ProgramBuilder::new("tenant-fan", TEST1.width);
    let x = b.input();
    let y = b.input();
    let d = b.add(x, y);
    let r0 = b.lut_fn(d, |m| (m + 1) % 8);
    let r1 = b.lut_fn(d, |m| m ^ 1);
    b.outputs(&[r0, r1]);
    b.finish()
}

fn shard_options() -> CoordinatorOptions {
    CoordinatorOptions {
        workers: 1,
        batch_capacity: 4,
        max_batch_wait: Duration::from_millis(1),
        ..Default::default()
    }
}

fn seeded_factory(master_seed: u64, capacity: usize) -> StoreFactory {
    Arc::new(move |_shard| {
        Arc::new(SeededTenantStore::new(&TEST1, master_seed, capacity)) as Arc<dyn KeyStore>
    })
}

#[test]
fn eight_sessions_on_four_shards_decrypt_under_their_own_keys() {
    let master_seed = 0x8E55;
    let sessions = 8u64;
    let requests_per_session = 2usize;
    let prog = fanout_program();
    // Capacity: every session plus the two probe resolves below fit with
    // room to spare, so no eviction muddies the counters.
    let mut cluster = Cluster::start_with_store_factory(
        prog.clone(),
        seeded_factory(master_seed, sessions as usize + 2),
        ClusterOptions {
            shards: 4,
            policy: PlacementPolicy::ConsistentHash,
            queue_depth: None,
            coordinator: shard_options(),
            qos: None,
        },
    );
    let sim = simulate(cluster.plan(), &TaurusConfig::default());

    // Every session's keys are genuinely distinct material.
    let s0 = cluster.stores()[0].resolve(SessionId(0));
    let s1 = cluster.stores()[0].resolve(SessionId(1));
    assert!(
        !server_keys_bitwise_eq(&s0.keys, &s1.keys),
        "tenants must not share key bits"
    );

    let mut rng = Rng::new(88);
    let sks: Vec<SecretKeys> =
        (0..sessions).map(|t| client_secret(&TEST1, master_seed, SessionId(t))).collect();
    // Interleave sessions so shards see mixed-tenant traffic.
    let mut pending = Vec::new();
    for round in 0..requests_per_session {
        for t in 0..sessions {
            let (x, y) = ((t + round as u64) % 6, (t * 3 + round as u64) % 6);
            let inputs = vec![
                encrypt_message(x, &sks[t as usize], &mut rng),
                encrypt_message(y, &sks[t as usize], &mut rng),
            ];
            let resp = cluster.submit(SessionId(t), inputs).expect("submit");
            pending.push((t, x, y, resp));
        }
    }
    for (t, x, y, resp) in &pending {
        let outs = resp.recv().expect("response");
        let exp = interp::eval(&prog, &[*x, *y]);
        let got: Vec<u64> =
            outs.iter().map(|c| decrypt_message(c, &sks[*t as usize])).collect();
        assert_eq!(got, exp, "session {t} query ({x},{y}) under its own key");
    }
    drop(pending);

    let n = sessions as usize * requests_per_session;
    let merged = cluster.snapshot();
    let per_shard = cluster.shard_snapshots();
    // Per-tenant metrics sum to cluster totals.
    assert_eq!(merged.requests, n);
    assert_eq!(merged.session_requests.len(), sessions as usize);
    for t in 0..sessions {
        assert_eq!(
            merged.session_requests.get(&t),
            Some(&(requests_per_session as u64)),
            "session {t} request count"
        );
    }
    assert_eq!(merged.session_requests.values().sum::<u64>() as usize, merged.requests);
    assert_eq!(merged.requests, per_shard.iter().map(|s| s.requests).sum::<usize>());
    // Measured KS/PBS still equal requests x the arch model's costs —
    // multi-tenancy changes key bindings, never the op counts.
    assert_eq!(merged.ks_executed, (n * sim.ks_count) as u64);
    assert_eq!(merged.pbs_executed, n * sim.pbs_count);
    // Consistent hash pinned each session to one shard, so each tenant's
    // keys were generated exactly once cluster-wide — plus one extra miss
    // per probe resolve above whose session is NOT homed on shard 0 (the
    // probe then warmed a store the router never routes it to).
    let ring = Router::new(PlacementPolicy::ConsistentHash, 4);
    let probes_off_home =
        [0u64, 1].iter().filter(|&&s| ring.place(s, Vec::new) != 0).count() as u64;
    assert_eq!(
        merged.key_misses,
        sessions + probes_off_home,
        "one keygen per session (+probes off their home shard)"
    );
    assert_eq!(merged.key_evictions, 0);
    assert_eq!(merged.key_regenerations, 0);
    assert_eq!(merged.key_resident as u64, sessions + probes_off_home);
    cluster.shutdown();
}

#[test]
fn static_keys_compat_is_bitwise_identical_on_randomized_program() {
    // The single-tenant compat path (StaticKeys wrapper) must produce the
    // SAME ciphertext bits as (a) the engine run directly and (b) an
    // explicit-store cluster, on the randomized conformance program.
    let mut rng = Rng::new(0xC0417);
    let (prog, _report, input_domain) = random_program_for(&mut rng, &TEST1);
    let keys = keycache::get(&TEST1, 0x7A95);
    let plan = compile(&prog, &TEST1, CompileOpts::default());

    let n = 6usize;
    let queries: Vec<Vec<u64>> =
        (0..n).map(|_| (0..2).map(|_| rng.below(input_domain)).collect()).collect();
    let batch: Vec<Vec<LweCiphertext>> = queries
        .iter()
        .map(|q| q.iter().map(|&m| encrypt_message(m, &keys.sk, &mut rng)).collect())
        .collect();

    // Reference: the schedule-driven engine over the same plan and keys.
    let mut eng = Engine::new(NativePbsBackend::new(&keys.server));
    let reference = eng.run_plan_batch(&plan, &batch);

    let run_cluster = |mk: &dyn Fn() -> Cluster| -> Vec<Vec<LweCiphertext>> {
        let mut cluster = mk();
        let pend: Vec<_> = batch
            .iter()
            .enumerate()
            .map(|(i, cts)| cluster.submit(i as u64, cts.clone()).expect("submit"))
            .collect();
        let outs = pend.iter().map(|r| r.recv().expect("response")).collect();
        drop(pend);
        cluster.shutdown();
        outs
    };

    let opts = || ClusterOptions {
        shards: 2,
        policy: PlacementPolicy::RoundRobin,
        queue_depth: None,
        coordinator: CoordinatorOptions {
            workers: 1,
            batch_capacity: 3,
            max_batch_wait: Duration::from_millis(1),
            ..Default::default()
        },
        qos: None,
    };
    // Compat constructor: Arc<ServerKeys> wrapped in StaticKeys inside.
    let compat = run_cluster(&|| Cluster::start(prog.clone(), keys.server.clone(), opts()));
    // Explicit store form of the same thing.
    let explicit = run_cluster(&|| {
        let stores: Vec<Arc<dyn KeyStore>> = (0..2)
            .map(|_| Arc::new(StaticKeys::new(keys.server.clone())) as Arc<dyn KeyStore>)
            .collect();
        Cluster::start_with_stores(prog.clone(), stores, opts())
    });
    assert_eq!(compat, reference, "compat cluster must equal the engine bitwise");
    assert_eq!(explicit, reference, "explicit StaticKeys cluster must equal the engine bitwise");
    // And the answers are right.
    for (q, outs) in queries.iter().zip(&reference) {
        let got: Vec<u64> = outs.iter().map(|c| decrypt_message(c, &keys.sk)).collect();
        assert_eq!(got, interp::eval(&prog, q), "query {q:?}");
    }
}

#[test]
fn reshard_migrates_ring_delta_drains_inflight_and_preserves_outputs() {
    let master_seed = 0x4E58;
    let sessions = 8u64;
    let (old_shards, new_shards) = (3usize, 4usize);
    let prog = fanout_program();
    let opts = || ClusterOptions {
        shards: old_shards,
        policy: PlacementPolicy::ConsistentHash,
        queue_depth: None,
        coordinator: shard_options(),
        qos: None,
    };
    let mut cluster = Cluster::start_with_store_factory(
        prog.clone(),
        seeded_factory(master_seed, sessions as usize),
        opts(),
    );

    let mut rng = Rng::new(77);
    let sks: Vec<SecretKeys> =
        (0..sessions).map(|t| client_secret(&TEST1, master_seed, SessionId(t))).collect();
    let enc = |t: u64, x: u64, y: u64, rng: &mut Rng| -> Vec<LweCiphertext> {
        vec![
            encrypt_message(x, &sks[t as usize], rng),
            encrypt_message(y, &sks[t as usize], rng),
        ]
    };

    // Warm every session's keys onto its home shard.
    let warm: Vec<_> = (0..sessions)
        .map(|t| (t, cluster.submit(SessionId(t), enc(t, t % 6, (t * 3) % 6, &mut rng)).unwrap()))
        .collect();
    for (t, resp) in &warm {
        let outs = resp.recv().expect("warm response");
        let exp = interp::eval(&prog, &[t % 6, (t * 3) % 6]);
        let got: Vec<u64> =
            outs.iter().map(|c| decrypt_message(c, &sks[*t as usize])).collect();
        assert_eq!(got, exp);
    }
    drop(warm);

    // Submit WITHOUT receiving: these must drain through the reshard.
    let inflight: Vec<_> = (0..sessions)
        .map(|t| {
            (t, cluster.submit(SessionId(t), enc(t, (t + 1) % 6, t % 6, &mut rng)).unwrap())
        })
        .collect();

    // The ring's own prediction of who moves (the ownership delta).
    let r_old = Router::new(PlacementPolicy::ConsistentHash, old_shards);
    let r_new = Router::new(PlacementPolicy::ConsistentHash, new_shards);
    let expected_moves = (0..sessions)
        .filter(|&t| r_old.place(t, Vec::new) != r_new.place(t, Vec::new))
        .count();

    let report = cluster.reshard(new_shards).expect("factory-backed cluster reshards freely");
    assert_eq!(report.old_shards, old_shards);
    assert_eq!(report.new_shards, new_shards);
    assert_eq!(report.resident_before as u64, sessions, "all sessions were warm");
    assert_eq!(
        report.resident_after as u64, sessions,
        "ample capacity: no migrated entry was displaced"
    );
    assert_eq!(
        report.migrated, expected_moves,
        "migration must match the consistent-hash ownership delta exactly"
    );
    // Mostly-stable, measured on the ring itself over a large population
    // (the warm 8 sessions are too few to bound a fraction): growing one
    // shard must re-home well under half the key space.
    let moved_of_1000 = (0..1000u64)
        .filter(|&s| r_old.place(s, Vec::new) != r_new.place(s, Vec::new))
        .count();
    assert!(
        moved_of_1000 < 500,
        "ring not mostly-stable: {moved_of_1000}/1000 sessions re-homed {old_shards}->{new_shards}"
    );

    // Nothing admitted before the reshard was lost or duplicated: the
    // drained responses arrive exactly once, correct.
    for (t, resp) in &inflight {
        let outs = resp.recv().expect("drained across reshard");
        let exp = interp::eval(&prog, &[(t + 1) % 6, t % 6]);
        let got: Vec<u64> =
            outs.iter().map(|c| decrypt_message(c, &sks[*t as usize])).collect();
        assert_eq!(got, exp, "in-flight request of session {t} survived the drain");
    }
    drop(inflight);

    // Migration preserved the cached material: post-reshard resolves are
    // hits, never regenerations.
    let pre_regen = cluster.snapshot().key_regenerations;
    assert_eq!(pre_regen, 0, "migration must not regenerate");

    // Post-reshard outputs are bitwise-equal to a FRESH cluster started
    // at the new shard count (same master seed, same program): reshard
    // converges to exactly the state a cold start would reach.
    let queries: Vec<(u64, u64, u64)> =
        (0..sessions).map(|t| (t, (t * 5 + 1) % 6, (t * 7 + 2) % 6)).collect();
    let encrypted: Vec<Vec<LweCiphertext>> =
        queries.iter().map(|&(t, x, y)| enc(t, x, y, &mut rng)).collect();

    let submit_all = |cluster: &Cluster| -> Vec<Vec<LweCiphertext>> {
        let pend: Vec<_> = queries
            .iter()
            .zip(&encrypted)
            .map(|(&(t, _, _), cts)| cluster.submit(SessionId(t), cts.clone()).expect("submit"))
            .collect();
        pend.iter().map(|r| r.recv().expect("response")).collect()
    };
    let resharded_outs = submit_all(&cluster);
    let mut fresh = Cluster::start_with_store_factory(
        prog.clone(),
        seeded_factory(master_seed, sessions as usize),
        ClusterOptions { shards: new_shards, ..opts() },
    );
    let fresh_outs = submit_all(&fresh);
    assert_eq!(
        resharded_outs, fresh_outs,
        "resharded cluster must be bitwise-identical to a fresh cluster at {new_shards} shards"
    );
    for (&(t, x, y), outs) in queries.iter().zip(&resharded_outs) {
        let got: Vec<u64> =
            outs.iter().map(|c| decrypt_message(c, &sks[t as usize])).collect();
        assert_eq!(got, interp::eval(&prog, &[x, y]), "session {t} ({x},{y})");
    }
    fresh.shutdown();

    // Lifetime accounting across the reshard: every admitted request is
    // counted exactly once (warm + inflight + post-reshard), and the ops
    // cross-check still holds against the shared plan's sim costs.
    let merged = cluster.snapshot();
    let total = 3 * sessions as usize;
    assert_eq!(merged.requests, total, "no request lost or double-executed");
    assert_eq!(merged.session_requests.values().sum::<u64>(), 3 * sessions);
    let sim = simulate(cluster.plan(), &TaurusConfig::default());
    assert_eq!(merged.ks_executed, (total * sim.ks_count) as u64);
    assert_eq!(merged.pbs_executed, total * sim.pbs_count);
    // Migration carried the cached material with the ring: the cluster
    // paid exactly one keygen per session over its whole life — a
    // re-homed session resolving post-reshard is a hit on the migrated
    // entry, not a fresh miss on its new shard.
    assert_eq!(merged.key_misses, sessions, "reshard must not cost new keygens");
    assert_eq!(merged.key_regenerations, 0, "no keygen was ever repeated");
    assert_eq!(merged.key_resident as u64, sessions, "no entry lost in migration");
    cluster.shutdown();
}
