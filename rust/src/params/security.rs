//! LWE security-frontier model (paper Fig. 6).
//!
//! The paper runs the Lattice Estimator [Albrecht et al.] to chart, for
//! each LWE dimension n, the largest noise stddev sigma that still gives
//! 128-bit security, and overlays the parameter sets chosen per bit width.
//!
//! We reproduce the *shape* of that frontier with the standard log-linear
//! hardness model used for parameter scripts: for ternary/binary secrets
//! and modulus q, the best-known primal/dual lattice attacks give a
//! security level approximately
//! `lambda ~= a * n / log2(q / sigma_abs) + b`
//! with (a, b) fit to published TFHE-rs 128-bit parameter points
//! (DESIGN.md §Substitutions). This is a calibrated model, not an attack
//! estimator — exactly like the paper, which consumed the estimator's
//! output as a curve.

/// Published 128-bit anchor points (n, sigma as fraction of the torus)
/// from TFHE-rs / Concrete parameter sets over q = 2^64.
pub const ANCHORS_128: [(usize, f64); 4] = [
    (630, 3.0e-5),
    (742, 7.07e-6),
    (866, 9.5e-7),
    (1024, 5.2e-8),
];

/// Fit of `lambda = a * n / log2(q/sigma) + b` to the anchors.
fn fitted_coeffs() -> (f64, f64) {
    // Least squares on x = n / log2(q/sigma), y = 128.
    // With all anchors at lambda = 128, fit a through the mean and use a
    // small measured intercept from the estimator literature (b ~ 14).
    // sigma here is torus-relative, so sigma_abs = sigma * 2^64 and
    // log2(q/sigma_abs) = -log2(sigma).
    let b = 14.0;
    let mut num = 0.0;
    let mut den = 0.0;
    for (n, sigma) in ANCHORS_128 {
        let x = n as f64 / (-(sigma.log2()));
        num += (128.0 - b) * x;
        den += x * x;
    }
    (num / den, b)
}

/// Estimated security level (bits) for LWE dimension `n` and torus-relative
/// noise stddev `sigma`.
pub fn security_level(n: usize, sigma: f64) -> f64 {
    let (a, b) = fitted_coeffs();
    let log_ratio = -(sigma.log2()); // log2(q / sigma_abs)
    debug_assert!(log_ratio > 0.0, "sigma must be < 1 (torus-relative)");
    a * n as f64 / log_ratio + b
}

/// Smallest torus-relative sigma that keeps `n` at >= `target` bits
/// (the red frontier line of Fig. 6).
pub fn min_sigma_for_security(n: usize, target: f64) -> f64 {
    let (a, b) = fitted_coeffs();
    // target = a*n/log_ratio + b  =>  log_ratio = a*n/(target-b)
    let log_ratio = a * n as f64 / (target - b);
    2f64.powf(-log_ratio)
}

/// Required LWE dimension for a given sigma at `target` bits.
pub fn min_n_for_security(sigma: f64, target: f64) -> usize {
    let (a, b) = fitted_coeffs();
    let log_ratio = -(sigma.log2());
    ((target - b) * log_ratio / a).ceil() as usize
}

/// Fig. 6 also marks the parameter set chosen per message width: wider
/// messages need smaller relative noise (for decryption correctness,
/// footnote 6) and therefore larger n on the frontier. The correctness
/// constraint: the post-PBS noise plus mod-switch noise must stay below
/// the decision boundary 2^-(width+2) with failure < 2^-40 (~6.4 sigma).
pub fn width_frontier_point(width: usize, target: f64) -> (usize, f64) {
    // Noise budget: boundary / 6.4, split across contributions; the
    // dominant fresh-ciphertext share is ~1/4 of the budget.
    let boundary = 2f64.powi(-(width as i32) - 2);
    let sigma = boundary / 6.4 / 4.0;
    let n = min_n_for_security(sigma, target);
    (n, sigma)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anchors_sit_near_128() {
        for (n, sigma) in ANCHORS_128 {
            let lvl = security_level(n, sigma);
            assert!((lvl - 128.0).abs() < 10.0, "n={n} level={lvl}");
        }
    }

    #[test]
    fn frontier_monotonic_in_n() {
        // Larger n tolerates smaller sigma at fixed security:
        let s1 = min_sigma_for_security(600, 128.0);
        let s2 = min_sigma_for_security(900, 128.0);
        let s3 = min_sigma_for_security(1200, 128.0);
        assert!(s1 > s2 && s2 > s3, "{s1} {s2} {s3}");
    }

    #[test]
    fn security_increases_with_n_and_sigma() {
        assert!(security_level(800, 1e-6) > security_level(700, 1e-6));
        assert!(security_level(800, 1e-5) > security_level(800, 1e-6));
    }

    #[test]
    fn wider_width_needs_larger_n() {
        // The paper's key interplay (Fig. 6): supporting more bits forces a
        // larger dimension at the same security level.
        let (n4, s4) = width_frontier_point(4, 128.0);
        let (n8, s8) = width_frontier_point(8, 128.0);
        let (n10, s10) = width_frontier_point(10, 128.0);
        assert!(n4 < n8 && n8 < n10, "{n4} {n8} {n10}");
        assert!(s4 > s8 && s8 > s10);
    }

    #[test]
    fn paper_sets_are_roughly_on_frontier() {
        for p in crate::params::PAPER_SETS {
            let lvl = security_level(p.n, p.lwe_noise);
            assert!(lvl > 100.0, "{}: level {lvl}", p.name);
        }
    }

    #[test]
    fn roundtrip_n_sigma() {
        let sigma = min_sigma_for_security(850, 128.0);
        let n = min_n_for_security(sigma, 128.0);
        assert!((n as i64 - 850).abs() <= 1, "n={n}");
    }
}
