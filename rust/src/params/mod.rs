//! TFHE parameter sets.
//!
//! Mirrors `python/compile/params.py` exactly (the AOT artifacts bake these
//! shapes in) and adds the paper's Table II evaluation parameter sets plus
//! the security-frontier model of Fig. 6.

pub mod security;

/// A full multi-bit TFHE parameter set. Conventions are documented in
/// `python/compile/params.py` and DESIGN.md; torus modulus is always 2^64.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamSet {
    pub name: &'static str,
    /// LWE (short) dimension n.
    pub n: usize,
    /// GLWE polynomial degree N (power of two).
    pub big_n: usize,
    /// GLWE dimension k.
    pub k: usize,
    /// PBS gadget decomposition: base 2^bsk_base_log, bsk_level digits.
    pub bsk_base_log: usize,
    pub bsk_level: usize,
    /// Key-switch gadget decomposition.
    pub ks_base_log: usize,
    pub ks_level: usize,
    /// Message width in bits (excluding the padding bit).
    pub width: usize,
    /// Noise stddevs as fractions of the torus.
    pub lwe_noise: f64,
    pub glwe_noise: f64,
}

impl ParamSet {
    pub const fn half_n(&self) -> usize {
        self.big_n / 2
    }

    /// Long (extracted) LWE dimension k*N.
    pub const fn long_dim(&self) -> usize {
        self.k * self.big_n
    }

    /// Message space including the padding bit.
    pub const fn plaintext_modulus(&self) -> u64 {
        1u64 << (self.width + 1)
    }

    /// Encoding scale: message m is encoded as m * delta.
    pub const fn delta(&self) -> u64 {
        1u64 << (64 - self.width - 1)
    }

    /// GGSW rows: (k+1) * bsk_level.
    pub const fn ggsw_rows(&self) -> usize {
        (self.k + 1) * self.bsk_level
    }

    /// Size of one ciphertext at rest (long LWE), bytes.
    pub const fn lwe_bytes(&self) -> usize {
        (self.long_dim() + 1) * 8
    }

    /// Size of the bootstrapping key, bytes (torus domain).
    pub const fn bsk_bytes(&self) -> usize {
        self.n * self.ggsw_rows() * (self.k + 1) * self.big_n * 8
    }

    /// Size of the key-switching key, bytes.
    pub const fn ksk_bytes(&self) -> usize {
        self.long_dim() * self.ks_level * (self.n + 1) * 8
    }

    /// Size of one GLWE accumulator, bytes.
    pub const fn glwe_bytes(&self) -> usize {
        (self.k + 1) * self.big_n * 8
    }

    /// Complex BSK multiplications streamed per blind rotation (the
    /// paper's unit in §IV-A: each BRU performs 512 per cycle).
    pub const fn bsk_mults_per_pbs(&self) -> u64 {
        (self.n * self.ggsw_rows() * (self.k + 1) * self.half_n()) as u64
    }
}

/// Fast functional-test set — must match python TEST1 bit-for-bit.
pub const TEST1: ParamSet = ParamSet {
    name: "test1",
    n: 128,
    big_n: 512,
    k: 1,
    bsk_base_log: 8,
    bsk_level: 3,
    ks_base_log: 4,
    ks_level: 6,
    width: 3,
    lwe_noise: 2.9802322387695312e-8,  // 2^-25
    glwe_noise: 9.094947017729282e-13, // 2^-40
};

/// Wider functional-test set (python TEST2).
pub const TEST2: ParamSet = ParamSet {
    name: "test2",
    n: 256,
    big_n: 2048,
    k: 1,
    bsk_base_log: 12,
    bsk_level: 2,
    ks_base_log: 4,
    ks_level: 6,
    width: 5,
    lwe_noise: 9.313225746154785e-10,  // 2^-30
    glwe_noise: 2.842170943040401e-14, // 2^-45
};

// ---------------------------------------------------------------------------
// Paper Table II parameter sets: `Workload n, (N, k), Width`.
// Decomposition bases/levels follow Concrete-style choices for each width;
// noise follows the 128-bit security frontier (params::security).
// ---------------------------------------------------------------------------

pub const CNN20: ParamSet = ParamSet {
    name: "cnn20",
    n: 737,
    big_n: 2048,
    k: 1,
    bsk_base_log: 23,
    bsk_level: 1,
    ks_base_log: 4,
    ks_level: 6,
    width: 6,
    lwe_noise: 1.5e-6,
    glwe_noise: 3.2e-16,
};

pub const CNN50: ParamSet = ParamSet {
    name: "cnn50",
    n: 828,
    big_n: 4096,
    k: 1,
    bsk_base_log: 22,
    bsk_level: 1,
    ks_base_log: 4,
    ks_level: 4,
    width: 6,
    lwe_noise: 1.5e-6,
    glwe_noise: 2.2e-17,
};

pub const DECISION_TREE: ParamSet = ParamSet {
    name: "decision_tree",
    n: 1070,
    big_n: 65536,
    k: 1,
    bsk_base_log: 15,
    bsk_level: 2,
    ks_base_log: 4,
    ks_level: 6,
    width: 9,
    lwe_noise: 3.2e-8,
    glwe_noise: 2.2e-19,
};

pub const GPT2: ParamSet = ParamSet {
    name: "gpt2",
    n: 1003,
    big_n: 32768,
    k: 1,
    bsk_base_log: 15,
    bsk_level: 2,
    ks_base_log: 4,
    ks_level: 5,
    width: 6,
    lwe_noise: 2.7e-7,
    glwe_noise: 2.2e-19,
};

pub const GPT2_12HEAD: ParamSet = ParamSet {
    name: "gpt2_12head",
    n: 1009,
    big_n: 32768,
    k: 1,
    bsk_base_log: 15,
    bsk_level: 2,
    ks_base_log: 4,
    ks_level: 5,
    width: 6,
    lwe_noise: 2.5e-7,
    glwe_noise: 2.2e-19,
};

pub const KNN: ParamSet = ParamSet {
    name: "knn",
    n: 1058,
    big_n: 65536,
    k: 1,
    bsk_base_log: 15,
    bsk_level: 2,
    ks_base_log: 4,
    ks_level: 6,
    width: 9,
    lwe_noise: 3.2e-8,
    glwe_noise: 2.2e-19,
};

pub const XGBOOST: ParamSet = ParamSet {
    name: "xgboost",
    n: 1025,
    big_n: 32768,
    k: 1,
    bsk_base_log: 15,
    bsk_level: 2,
    ks_base_log: 4,
    ks_level: 5,
    width: 8,
    lwe_noise: 7.0e-8,
    glwe_noise: 2.2e-19,
};

// ---------------------------------------------------------------------------
// Wide-width functional sets: the paper's headline widths (8 and 10 bits)
// sized so the native backend can actually run them in TEST-scale CI.
//
// Like TEST1/TEST2 these are *functional* sets, not 128-bit-secure ones:
// the noise follows the security-frontier shape (wider width -> smaller
// relative noise, Fig. 6 / `security::width_frontier_point`) but n is kept
// small so a PBS stays sub-second. Sizing is driven by the same variance
// model `compiler::noise` checks at compile time: the binding term is the
// mod-switch floor sqrt((n+1)/12)/2N, which must clear the decision
// boundary 2^-(width+2) by >= ~6.5 sigma. The gadget keeps TEST2's
// moderate-base/two-level shape (2^12..2^13, level 2) rather than a
// single 2^23+ digit: the f64-FFT convolution noise of the external
// product grows with N^2 * B^2 (~ n*l*N^2*B^2 * 2^-106 variance), and at
// N = 16k/32k a single wide digit would put that error at the decision
// boundary itself, while two 12/13-bit digits keep it below 2^-23.
// ---------------------------------------------------------------------------

/// 8-bit functional set: boundary 2^-10, mod-switch floor ~1.0e-4, ~9.4
/// sigma on a LUT chain with KS + gadget noise included.
pub const WIDE8: ParamSet = ParamSet {
    name: "wide8",
    n: 128,
    big_n: 16384,
    k: 1,
    bsk_base_log: 12,
    bsk_level: 2,
    ks_base_log: 8,
    ks_level: 3,
    width: 8,
    lwe_noise: 9.313225746154785e-10,  // 2^-30
    glwe_noise: 3.552713678800501e-15, // 2^-48
};

/// 10-bit functional set: boundary 2^-12, mod-switch floor ~3.6e-5 (~6.7
/// sigma on a LUT chain — the tightest of the functional sets, mirroring
/// how the real frontier pinches at width 10).
pub const WIDE10: ParamSet = ParamSet {
    name: "wide10",
    n: 64,
    big_n: 32768,
    k: 1,
    bsk_base_log: 13,
    bsk_level: 2,
    ks_base_log: 8,
    ks_level: 3,
    width: 10,
    lwe_noise: 2.3283064365386963e-10, // 2^-32
    glwe_noise: 2.220446049250313e-16, // 2^-52
};

/// All paper evaluation sets (Table II order).
pub const PAPER_SETS: [&ParamSet; 7] =
    [&CNN20, &CNN50, &DECISION_TREE, &GPT2, &GPT2_12HEAD, &KNN, &XGBOOST];

/// Functional sets the native backend runs end-to-end in CI, one per
/// supported test width (the axis `eval::conformance` sweeps).
pub const FUNCTIONAL_SETS: [&ParamSet; 4] = [&TEST1, &TEST2, &WIDE8, &WIDE10];

/// Look up any named parameter set.
pub fn by_name(name: &str) -> Option<&'static ParamSet> {
    match name {
        "test1" => Some(&TEST1),
        "test2" => Some(&TEST2),
        "wide8" => Some(&WIDE8),
        "wide10" => Some(&WIDE10),
        "cnn20" => Some(&CNN20),
        "cnn50" => Some(&CNN50),
        "decision_tree" => Some(&DECISION_TREE),
        "gpt2" => Some(&GPT2),
        "gpt2_12head" => Some(&GPT2_12HEAD),
        "knn" => Some(&KNN),
        "xgboost" => Some(&XGBOOST),
        _ => None,
    }
}

/// Select a parameter set for a program bit width (compiler entry point).
/// Mirrors the paper's observation that wider widths force larger (n, N)
/// along the 128-bit frontier (Fig. 6). Widths 8-10 route to the WIDE
/// functional sets so the selection is backed by the executable
/// conformance suite (widths 6-7 still map to the Table II cost-model
/// sets); the paper tops out at 10 bits, so wider requests are an error
/// rather than a silent downgrade.
pub fn select_for_width(width: usize) -> &'static ParamSet {
    match width {
        0..=3 => &TEST1, // unit-test scale
        4..=5 => &TEST2,
        6 => &GPT2,
        7 => &GPT2_12HEAD,
        8 => &WIDE8,
        9 | 10 => &WIDE10,
        _ => panic!("no parameter set supports width {width} (Taurus supports up to 10 bits)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derived_quantities_test1() {
        assert_eq!(TEST1.half_n(), 256);
        assert_eq!(TEST1.long_dim(), 512);
        assert_eq!(TEST1.plaintext_modulus(), 16);
        assert_eq!(TEST1.delta(), 1 << 60);
        assert_eq!(TEST1.ggsw_rows(), 6);
    }

    #[test]
    fn paper_sets_match_table_ii() {
        assert_eq!(CNN20.n, 737);
        assert_eq!(CNN20.big_n, 2048);
        assert_eq!(DECISION_TREE.big_n, 65536);
        assert_eq!(DECISION_TREE.width, 9);
        assert_eq!(GPT2.n, 1003);
        for p in PAPER_SETS {
            assert_eq!(p.k, 1, "paper: wide-width TFHE sets k=1 (§III-B)");
            assert!(p.big_n.is_power_of_two());
        }
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(by_name("gpt2").unwrap().n, 1003);
        assert_eq!(by_name("wide8").unwrap().width, 8);
        assert_eq!(by_name("wide10").unwrap().width, 10);
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn width_to_set_table_is_pinned() {
        // The full routing table, width by width. 8/9/10 must land on the
        // executable WIDE sets (they used to fall through to the
        // simulation-only xgboost/decision_tree sets).
        let expect: [(usize, &str); 11] = [
            (0, "test1"),
            (1, "test1"),
            (2, "test1"),
            (3, "test1"),
            (4, "test2"),
            (5, "test2"),
            (6, "gpt2"),
            (7, "gpt2_12head"),
            (8, "wide8"),
            (9, "wide10"),
            (10, "wide10"),
        ];
        for (w, name) in expect {
            assert_eq!(select_for_width(w).name, name, "width {w}");
        }
        // Every functionally-backed route hands out a set that can hold
        // its width. (Width 7 is the pinned exception: it maps to the
        // Table II cost-model set gpt2_12head, whose own width is 6 —
        // nothing executable exists between the 5- and 8-bit sets.)
        for w in [0usize, 1, 2, 3, 4, 5, 6, 8, 9, 10] {
            assert!(select_for_width(w).width >= w, "width {w} set too narrow");
        }
        assert_eq!(select_for_width(7).width, 6, "pinned cost-model quirk");
    }

    #[test]
    #[should_panic(expected = "up to 10 bits")]
    fn width_11_is_rejected() {
        select_for_width(11);
    }

    #[test]
    fn functional_sets_cover_the_conformance_widths() {
        assert_eq!(
            FUNCTIONAL_SETS.map(|p| p.width),
            [3, 5, 8, 10],
            "one executable set per conformance width"
        );
        for p in FUNCTIONAL_SETS {
            assert!(p.big_n.is_power_of_two());
            // The LUT needs at least one polynomial slot per message value.
            assert!(2 * p.big_n >= p.plaintext_modulus() as usize, "{}", p.name);
            assert_eq!(by_name(p.name), Some(p));
        }
        // Wider width -> tighter relative noise, per the frontier shape.
        assert!(WIDE8.glwe_noise < TEST2.glwe_noise);
        assert!(WIDE10.glwe_noise < WIDE8.glwe_noise);
    }

    #[test]
    fn key_sizes_grow_with_width() {
        // The paper's §I claim: evaluation keys grow 4-60x with width.
        let small = CNN20.bsk_bytes() + CNN20.ksk_bytes();
        let big = DECISION_TREE.bsk_bytes() + DECISION_TREE.ksk_bytes();
        let ratio = big as f64 / small as f64;
        assert!(ratio > 4.0, "key growth ratio {ratio}");
    }
}
