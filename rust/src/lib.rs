//! # Taurus — multi-bit TFHE acceleration, reproduced as a full system
//!
//! This crate reproduces the system described in *"A Scalable Architecture
//! for Efficient Multi-bit Fully Homomorphic Encryption"* (Ma, Xu, Wills).
//! It contains:
//!
//! - [`tfhe`] — a from-scratch multi-bit TFHE library (LWE/GLWE/GGSW,
//!   programmable bootstrapping, key switching) — the cryptographic
//!   substrate and the functional CPU reference.
//! - [`params`] — parameter presets for every paper workload and the
//!   security-frontier model (paper Fig. 6).
//! - [`ir`] / [`compiler`] — an FHELinAlg-like integer tensor IR and the
//!   paper's compiler: lowering with keyswitch-first PBS, KS-dedup,
//!   ACC-dedup, and 48-ciphertext batch scheduling.
//! - [`arch`] — the Taurus accelerator cycle-level model (BRU/LPU clusters,
//!   heterogeneous FFT units, HBM bandwidth, buffers) plus the
//!   Morphling-style XPU baseline and the area/power model.
//! - [`baselines`] — calibrated CPU/GPU cost models and prior-ASIC data.
//! - [`workloads`] — generators for the paper's seven evaluation workloads.
//! - [`runtime`] — PJRT (XLA) execution of AOT-compiled JAX/Pallas
//!   artifacts from the Rust request path.
//! - [`tenant`] — the multi-tenant session layer: `SessionId`s resolved
//!   to per-client server keys through a `KeyStore` (single-key
//!   `StaticKeys` compat, or seeded per-tenant stores over a bounded LRU
//!   key cache).
//! - [`coordinator`] — a threaded FHE-inference serving frontend (router,
//!   dynamic batcher with per-key-set batch grouping, metrics).
//! - [`cluster`] — sharded serving above the coordinator: N replicated
//!   engine shards behind a placement router with a bounded shared
//!   admission queue, shard-local key stores with live reshard +
//!   cache migration, and merged metrics.
//! - [`traffic`] — traffic realism above the cluster: seed-deterministic
//!   Zipf/bursty load generation, per-tenant token-bucket + weighted-fair
//!   (deficit round-robin) QoS admission, and a metrics-driven autoscaler
//!   that reshards the cluster against watermarks.
//! - [`wire`] — the network front door: versioned binary serialization
//!   for ciphertexts and server keys (chunked streaming key upload), a
//!   framed length-prefixed TCP protocol over `std::net`, and the
//!   blocking `wire::Client` remote clients use to upload keys and
//!   submit encrypted work.
//! - [`eval`] — regenerates every table and figure of the paper.
//! - [`obs`] — zero-dependency observability: flight-recorder tracing,
//!   mergeable per-stage timing histograms, and cost-model drift
//!   attribution, all behind one atomic enabled-flag.

// Stylistic clippy lints the codebase deliberately trades away: the
// FFT/MAC kernels use explicit index arithmetic (needless_range_loop,
// many_single_char_names), C64 keeps inherent add/mul/sub for #[inline]
// control (should_implement_trait), and the channel fan-out uses an
// annotated unzip (type_complexity).
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::many_single_char_names,
    clippy::should_implement_trait,
    clippy::type_complexity,
    clippy::new_without_default,
    clippy::manual_memcpy,
    clippy::inherent_to_string,
    clippy::field_reassign_with_default
)]

pub mod util;
pub mod obs;
pub mod params;
pub mod tfhe;
pub mod ir;
pub mod compiler;
pub mod arch;
pub mod baselines;
pub mod workloads;
pub mod runtime;
pub mod tenant;
pub mod coordinator;
pub mod cluster;
pub mod traffic;
pub mod wire;
pub mod eval;
