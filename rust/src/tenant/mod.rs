//! Multi-tenant session layer: per-client server keys behind a
//! [`KeyStore`].
//!
//! The paper's serving story (§1, §6) assumes many clients offloading
//! encrypted work to one accelerator farm. That makes server-side key
//! material *per-tenant*: every client owns a distinct secret key, so the
//! server must hold one `ServerKeys` (BSK + KSK, tens of MB at the wide
//! widths — see EXPERIMENTS.md §Tenants) per active client, and key
//! residency — which tenants' keys are warm in a shard's memory — becomes
//! a first-class scheduling input, exactly why the cluster pins clients
//! to shards with consistent hashing.
//!
//! This module is the API for that:
//!
//! - [`SessionId`] names a client session; callers submit work *for a
//!   session*, never with a raw key arc.
//! - [`KeyStore`] resolves a session to a [`KeyHandle`] (the key set a
//!   request executes under) with a `register`/`evict` surface so caches
//!   can be migrated when the cluster reshards.
//! - [`StaticKeys`] wraps one `Arc<ServerKeys>` — the single-tenant
//!   compat path; every session resolves to the same handle, so batches
//!   never split and behavior is bit-identical to the pre-session API.
//! - [`SeededTenantStore`] derives per-tenant keys deterministically from
//!   a master seed (`tfhe::keygen` domain-separated forking) behind a
//!   bounded LRU ([`tfhe::keycache::BoundedKeyCache`]) with hit / miss /
//!   eviction / regeneration counters. The store retains only *server*
//!   material — tenant secret keys are derived transiently during keygen
//!   and dropped; clients (and tests) recover theirs via
//!   [`client_secret`].
//!
//! Down the pipeline, the coordinator's batcher groups collected requests
//! by key handle so `Engine::run_plan_batch` always executes one batch
//! under one key set, and `MetricsSnapshot` reports per-tenant request
//! counts plus the store's cache counters.

use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::params::ParamSet;
use crate::tfhe::keycache::{self, BoundedKeyCache};
use crate::tfhe::keygen::fork_seed;
use crate::tfhe::{SecretKeys, ServerKeys};

pub use crate::tfhe::keycache::CacheStats as KeyStoreStats;

/// Typed failure of [`KeyStore::register_uploaded`] — the client-upload
/// path must never panic an acceptor thread or silently accept keys a
/// store cannot serve, so rejection is a value the wire layer maps to a
/// protocol status code.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RegisterError {
    /// The store serves one fixed key set ([`StaticKeys`]) and cannot
    /// hold per-session uploaded material.
    Unsupported,
    /// The uploaded keys were generated under a different parameter set
    /// than the store serves.
    ParamMismatch { expected: &'static str, got: &'static str },
}

impl fmt::Display for RegisterError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RegisterError::Unsupported => write!(
                f,
                "store serves one global key set and does not accept per-session uploads"
            ),
            RegisterError::ParamMismatch { expected, got } => {
                write!(f, "uploaded keys use parameter set {got}, store serves {expected}")
            }
        }
    }
}

impl std::error::Error for RegisterError {}

/// A client session. Placement (consistent-hash affinity) and key
/// resolution both key off this id, so a session's requests land on the
/// shard where its server keys are resident.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SessionId(pub u64);

impl From<u64> for SessionId {
    fn from(v: u64) -> Self {
        Self(v)
    }
}

impl fmt::Display for SessionId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "session-{}", self.0)
    }
}

/// The key set one request executes under, resolved at submit time. The
/// `Arc` keeps the keys alive for the request's whole lifetime even if
/// the store evicts the entry meanwhile — in-flight work never loses its
/// keys. Batches are grouped by *pointer identity* ([`Self::same_keys`]):
/// two handles share an execution sub-batch only when they are literally
/// the same key material.
#[derive(Clone)]
pub struct KeyHandle {
    /// The session this handle was resolved for (metrics attribution).
    pub session: SessionId,
    /// The server keys the request executes under.
    pub keys: Arc<ServerKeys>,
}

impl KeyHandle {
    /// Whether two handles refer to the identical key material (pointer
    /// identity — the grouping predicate of the keyed batcher).
    pub fn same_keys(&self, other: &KeyHandle) -> bool {
        Arc::ptr_eq(&self.keys, &other.keys)
    }
}

impl fmt::Debug for KeyHandle {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("KeyHandle")
            .field("session", &self.session)
            .field("params", &self.keys.params.name)
            .finish()
    }
}

/// Server-side key resolution: session -> key handle, plus the
/// register/evict surface the cluster uses to migrate shard-local cache
/// entries on reshard. Implementations are shared across submitting
/// threads and workers (`Send + Sync`); `resolve` may generate keys on
/// first touch, so its cost lands at admission time, attributed to the
/// submitting tenant.
pub trait KeyStore: Send + Sync {
    /// Parameter set every resolved key set uses (one per store — the
    /// compiled plan is per-parameter-set).
    fn params(&self) -> &ParamSet;

    /// Whether every session resolves to ONE fixed key set for the
    /// store's whole lifetime. Backends that bake keys into device
    /// buffers (XLA) can only serve single-key stores; the coordinator
    /// rejects the combination at construction using this.
    fn is_single_key(&self) -> bool {
        false
    }

    /// Resolve a session's server keys, generating or fetching from cache
    /// as the implementation dictates.
    fn resolve(&self, session: SessionId) -> KeyHandle;

    /// Fallible resolve, used on the request-admission path so a store
    /// that cannot produce keys (backing fetch down, injected fault)
    /// sheds the one request instead of panicking the shard. Defaults to
    /// the infallible path; fallible stores override.
    fn try_resolve(&self, session: SessionId) -> Result<KeyHandle, String> {
        Ok(self.resolve(session))
    }

    /// Install externally supplied keys for a session (client-uploaded
    /// material, or an entry migrated from another shard's store).
    fn register(&self, session: SessionId, keys: Arc<ServerKeys>) -> KeyHandle;

    /// Whether this store can hold per-session client-uploaded key
    /// material. Admission paths (the wire protocol's key-upload
    /// handler) must check this *before* calling
    /// [`Self::register_uploaded`]; stores that answer `false` reject
    /// uploads typed instead of panicking.
    fn supports_register(&self) -> bool {
        false
    }

    /// Install **client-uploaded** keys for a session. Unlike
    /// [`Self::register`] (the trusted migration path) this validates
    /// and *pins* the material: the store may never regenerate it —
    /// uploaded keys are not derivable server-side — so eviction under
    /// capacity pressure skips the entry and a resolve that lost it
    /// fails typed rather than minting different bits.
    fn register_uploaded(
        &self,
        _session: SessionId,
        _keys: Arc<ServerKeys>,
    ) -> Result<KeyHandle, RegisterError> {
        Err(RegisterError::Unsupported)
    }

    /// Remove a session's entry (returning it, e.g. to hand to another
    /// shard's store during reshard migration). `None` when not resident.
    fn evict(&self, session: SessionId) -> Option<Arc<ServerKeys>>;

    /// Sessions whose keys are currently resident (empty for stores with
    /// no per-session state, like [`StaticKeys`]).
    fn resident(&self) -> Vec<SessionId>;

    /// Cache counters (hits/misses/evictions/regenerations/resident).
    fn stats(&self) -> KeyStoreStats;
}

/// Single-tenant compat store: wraps today's one `Arc<ServerKeys>`. Every
/// session resolves to the same handle, so the keyed batcher never splits
/// a batch and the serving path is bit-identical to the pre-session API.
pub struct StaticKeys {
    keys: Arc<ServerKeys>,
    resolves: AtomicU64,
}

impl StaticKeys {
    pub fn new(keys: Arc<ServerKeys>) -> Self {
        Self { keys, resolves: AtomicU64::new(0) }
    }

    /// The wrapped key set.
    pub fn keys(&self) -> &Arc<ServerKeys> {
        &self.keys
    }
}

impl KeyStore for StaticKeys {
    fn params(&self) -> &ParamSet {
        &self.keys.params
    }

    fn is_single_key(&self) -> bool {
        true
    }

    fn resolve(&self, session: SessionId) -> KeyHandle {
        self.resolves.fetch_add(1, Ordering::Relaxed);
        KeyHandle { session, keys: self.keys.clone() }
    }

    fn register(&self, _session: SessionId, _keys: Arc<ServerKeys>) -> KeyHandle {
        panic!("StaticKeys serves one global key set; per-session registration needs a SeededTenantStore")
    }

    fn evict(&self, _session: SessionId) -> Option<Arc<ServerKeys>> {
        None
    }

    fn resident(&self) -> Vec<SessionId> {
        Vec::new()
    }

    fn stats(&self) -> KeyStoreStats {
        KeyStoreStats {
            hits: self.resolves.load(Ordering::Relaxed),
            ..KeyStoreStats::default()
        }
    }
}

/// Domain tag separating tenant key streams from every other consumer of
/// [`fork_seed`] (keygen's BSK/KSK streams, the keycache's sk/ek split).
pub const DOMAIN_TENANT: u64 = 0x7E4A_A017;

/// The key-derivation seed of `session` under `master_seed`. Pure: a
/// tenant's keys are a function of `(params, master_seed, session)` alone,
/// so every shard's store — and a freshly built cluster — derives the
/// identical bits.
pub fn tenant_seed(master_seed: u64, session: SessionId) -> u64 {
    fork_seed(master_seed, DOMAIN_TENANT, session.0)
}

/// The client-side secret keys of a tenant session — what the client keeps
/// (and what tests use to encrypt/decrypt). The server-side store derives
/// these transiently during keygen and retains only the server material.
pub fn client_secret(p: &ParamSet, master_seed: u64, session: SessionId) -> SecretKeys {
    keycache::secret_keys_for(p, tenant_seed(master_seed, session))
}

/// Per-tenant seeded key store: derives each session's `ServerKeys`
/// deterministically from a master seed, cached in a bounded LRU
/// ([`BoundedKeyCache`]). Eviction under capacity pressure is counted, and
/// re-deriving a previously evicted tenant counts as a *regeneration* —
/// the cost signal that says the cache is too small for the working set.
pub struct SeededTenantStore {
    params: ParamSet,
    master_seed: u64,
    cache: BoundedKeyCache,
    /// seed -> session inverse map (sessions ever seen; `resident()`
    /// intersects this with the cache's live entries). Like the cache's
    /// regeneration ledger this grows 16 bytes per tenant ever resolved —
    /// bookkeeping, not key material; the MB-scale keys themselves stay
    /// capacity-bounded.
    sessions: Mutex<HashMap<u64, SessionId>>,
}

impl SeededTenantStore {
    /// `capacity` bounds resident key sets (>= 1); sizing guidance — keys
    /// per tenant by width — is in EXPERIMENTS.md §Tenants.
    pub fn new(p: &ParamSet, master_seed: u64, capacity: usize) -> Self {
        Self {
            params: p.clone(),
            master_seed,
            cache: BoundedKeyCache::new(capacity),
            sessions: Mutex::new(HashMap::new()),
        }
    }

    pub fn master_seed(&self) -> u64 {
        self.master_seed
    }

    pub fn capacity(&self) -> usize {
        self.cache.capacity()
    }

    fn seed_of(&self, session: SessionId) -> u64 {
        let seed = tenant_seed(self.master_seed, session);
        self.sessions.lock().expect("tenant store poisoned").insert(seed, session);
        seed
    }
}

impl KeyStore for SeededTenantStore {
    fn params(&self) -> &ParamSet {
        &self.params
    }

    fn resolve(&self, session: SessionId) -> KeyHandle {
        let seed = self.seed_of(session);
        KeyHandle { session, keys: self.cache.get(&self.params, seed) }
    }

    /// Admission-path resolve: a session whose client-uploaded keys are
    /// no longer resident fails typed
    /// ([`keycache::KeyCacheError::RegisteredEvicted`]) instead of
    /// silently re-deriving *different* keys from the master seed.
    fn try_resolve(&self, session: SessionId) -> Result<KeyHandle, String> {
        let seed = self.seed_of(session);
        self.cache
            .try_get(&self.params, seed)
            .map(|keys| KeyHandle { session, keys })
            .map_err(|e| e.to_string())
    }

    fn register(&self, session: SessionId, keys: Arc<ServerKeys>) -> KeyHandle {
        assert_eq!(
            keys.params.name, self.params.name,
            "registered keys must match the store's parameter set"
        );
        let seed = self.seed_of(session);
        self.cache.insert(&self.params, seed, keys.clone());
        KeyHandle { session, keys }
    }

    fn supports_register(&self) -> bool {
        true
    }

    fn register_uploaded(
        &self,
        session: SessionId,
        keys: Arc<ServerKeys>,
    ) -> Result<KeyHandle, RegisterError> {
        if keys.params.name != self.params.name {
            return Err(RegisterError::ParamMismatch {
                expected: self.params.name,
                got: keys.params.name,
            });
        }
        let seed = self.seed_of(session);
        self.cache.insert_pinned(&self.params, seed, keys.clone());
        Ok(KeyHandle { session, keys })
    }

    fn evict(&self, session: SessionId) -> Option<Arc<ServerKeys>> {
        self.cache.remove(tenant_seed(self.master_seed, session))
    }

    fn resident(&self) -> Vec<SessionId> {
        let map = self.sessions.lock().expect("tenant store poisoned");
        let mut out: Vec<SessionId> = self
            .cache
            .resident()
            .iter()
            .filter_map(|seed| map.get(seed).copied())
            .collect();
        out.sort_unstable();
        out
    }

    fn stats(&self) -> KeyStoreStats {
        self.cache.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::TEST1;
    use crate::tfhe::pbs::{decrypt_message, encrypt_message};
    use crate::tfhe::server_keys_bitwise_eq;
    use crate::util::rng::Rng;

    #[test]
    fn static_keys_resolve_is_the_same_arc_for_every_session() {
        let mut rng = Rng::new(61);
        let sk = SecretKeys::generate(&TEST1, &mut rng);
        let keys = Arc::new(ServerKeys::generate(&sk, &mut rng));
        let store = StaticKeys::new(keys.clone());
        let a = store.resolve(SessionId(1));
        let b = store.resolve(SessionId(999));
        assert!(a.same_keys(&b), "static store: one key set for all sessions");
        assert!(Arc::ptr_eq(&a.keys, &keys));
        assert!(store.resident().is_empty());
        assert!(store.evict(SessionId(1)).is_none());
        assert_eq!(store.stats().hits, 2);
        assert_eq!(store.stats().misses, 0);
    }

    #[test]
    fn seeded_store_derives_distinct_working_keys_per_session() {
        let store = SeededTenantStore::new(&TEST1, 0xA11CE, 4);
        let h0 = store.resolve(SessionId(0));
        let h1 = store.resolve(SessionId(1));
        assert!(!h0.same_keys(&h1), "sessions must get distinct key sets");
        assert!(
            !server_keys_bitwise_eq(&h0.keys, &h1.keys),
            "distinct sessions must derive distinct key bits"
        );
        // The derived server keys work with the matching client secret.
        let sk0 = client_secret(&TEST1, 0xA11CE, SessionId(0));
        let mut rng = Rng::new(7);
        let ct = encrypt_message(5, &sk0, &mut rng);
        let mut ctx = crate::tfhe::PbsContext::new(&TEST1);
        let lut = crate::tfhe::make_lut_poly(&TEST1, |m| (m + 1) % 16);
        let out = ctx.pbs(&ct, &h0.keys, &lut);
        assert_eq!(decrypt_message(&out, &sk0), 6);
        // Resolving again is a hit on the identical Arc.
        let again = store.resolve(SessionId(0));
        assert!(again.same_keys(&h0));
        let st = store.stats();
        assert_eq!((st.hits, st.misses, st.evictions), (1, 2, 0));
        assert_eq!(store.resident(), vec![SessionId(0), SessionId(1)]);
    }

    #[test]
    fn seeded_store_evicts_at_capacity_and_regenerates_identical_bits() {
        let store = SeededTenantStore::new(&TEST1, 0xBEE, 2);
        let h0 = store.resolve(SessionId(0));
        let _h1 = store.resolve(SessionId(1));
        // Third tenant evicts the LRU entry (session 0).
        let _h2 = store.resolve(SessionId(2));
        assert_eq!(store.resident(), vec![SessionId(1), SessionId(2)]);
        let st = store.stats();
        assert_eq!(st.evictions, 1);
        assert_eq!(st.regenerations, 0);
        // Re-deriving the evicted tenant is a counted regeneration — and
        // bitwise identical to the original derivation (the whole point of
        // seeded tenants: eviction costs time, never correctness).
        let h0b = store.resolve(SessionId(0));
        assert!(!h0b.same_keys(&h0), "regenerated entry is fresh material");
        assert!(server_keys_bitwise_eq(&h0.keys, &h0b.keys));
        let st = store.stats();
        assert_eq!(st.evictions, 2, "regenerating at capacity evicts again");
        assert_eq!(st.regenerations, 1);
        assert_eq!(st.resident, 2);
    }

    #[test]
    fn migration_register_preserves_arc_identity_across_stores() {
        // Two shard-local stores under one master seed: evicting from one
        // and registering into the other (what `Cluster::reshard` does)
        // moves the very same key material — the target's next resolve is
        // a hit on the migrated Arc, not a regeneration.
        let a = SeededTenantStore::new(&TEST1, 0xCAFE, 4);
        let b = SeededTenantStore::new(&TEST1, 0xCAFE, 4);
        let h = a.resolve(SessionId(7));
        let moved = a.evict(SessionId(7)).expect("resident entry");
        assert!(Arc::ptr_eq(&moved, &h.keys));
        assert!(a.resident().is_empty());
        b.register(SessionId(7), moved);
        let resolved = b.resolve(SessionId(7));
        assert!(resolved.same_keys(&h), "migrated entry must be reused, not regenerated");
        let st = b.stats();
        assert_eq!((st.hits, st.misses, st.regenerations), (1, 0, 0));
        assert_eq!(b.resident(), vec![SessionId(7)]);
    }

    #[test]
    fn static_keys_reject_uploads_typed_instead_of_panicking() {
        let mut rng = Rng::new(62);
        let sk = SecretKeys::generate(&TEST1, &mut rng);
        let keys = Arc::new(ServerKeys::generate(&sk, &mut rng));
        let store = StaticKeys::new(keys.clone());
        assert!(!store.supports_register(), "single-key stores cannot hold uploads");
        assert_eq!(
            store.register_uploaded(SessionId(1), keys).unwrap_err(),
            RegisterError::Unsupported
        );
    }

    #[test]
    fn uploaded_keys_are_pinned_and_never_silently_regenerated() {
        // The original bug: register() + LRU flood + resolve() handed
        // back keys re-derived from the master seed — different bits than
        // the client uploaded. register_uploaded pins the entry instead.
        let store = SeededTenantStore::new(&TEST1, 0xD00D, 2);
        assert!(store.supports_register());
        // "Client" keys: any material the master seed cannot re-derive.
        let uploaded = keycache::get(&TEST1, 0x5150).server.clone();
        let h = store.register_uploaded(SessionId(9), uploaded.clone()).expect("accepted");
        assert!(Arc::ptr_eq(&h.keys, &uploaded));

        // Flood past capacity with seeded tenants.
        for s in 0..4u64 {
            let _ = store.resolve(SessionId(s));
        }
        let resolved = store.try_resolve(SessionId(9)).expect("still resident");
        assert!(
            Arc::ptr_eq(&resolved.keys, &uploaded),
            "resolve must return the uploaded Arc, not a re-derivation"
        );
        let st = store.stats();
        assert_eq!(st.regenerations, 0, "no registered session ever regenerates");
        assert_eq!(st.pinned, 1);

        // After an explicit evict (migration gap) the resolve fails typed.
        let moved = store.evict(SessionId(9)).expect("movable");
        assert!(Arc::ptr_eq(&moved, &uploaded));
        let err = store.try_resolve(SessionId(9)).unwrap_err();
        assert!(err.contains("client-registered"), "typed refusal, got: {err}");
        assert_eq!(store.stats().regenerations, 0);

        // Migration re-import via the trusted path re-pins.
        store.register(SessionId(9), moved);
        let back = store.try_resolve(SessionId(9)).expect("re-imported");
        assert!(Arc::ptr_eq(&back.keys, &uploaded));
        assert_eq!(store.stats().pinned, 1);
    }

    #[test]
    fn register_uploaded_rejects_mismatched_params() {
        let store = SeededTenantStore::new(&TEST1, 0xD00D, 2);
        let wrong = keycache::get(&crate::params::TEST2, 0x77).server.clone();
        assert_eq!(
            store.register_uploaded(SessionId(3), wrong).unwrap_err(),
            RegisterError::ParamMismatch { expected: TEST1.name, got: crate::params::TEST2.name }
        );
    }

    #[test]
    fn tenant_seed_is_session_injective_in_practice() {
        let mut seen = std::collections::HashSet::new();
        for s in 0..4096u64 {
            assert!(seen.insert(tenant_seed(42, SessionId(s))), "seed collision at {s}");
        }
    }
}
