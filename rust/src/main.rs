//! `taurus` CLI — leader entrypoint.
//!
//! Subcommands:
//!   eval [--all | --exp <id>] [--out results] [--clusters N] [--rr N]
//!       regenerate the paper's tables/figures (ids: 1..4, 5, 6, 13a,
//!       13b, 14, 15, 16, obs5, dedup, ablation)
//!   run <workload> [--batch B]      simulate one Table II workload
//!   serve [--backend native|xla] [--shards S] [--policy P]
//!         [--queue-depth D] [--workers N] [--fft-threads F]
//!         [--requests R] [--tenants T] [--key-cache-cap C]
//!         [--loadgen [ZIPF_S] [--loadgen-seed SEED]]
//!         [--tenant-rate R [--tenant-burst B]] [--tenant-queue-depth D]
//!         [--autoscale [--autoscale-max M]]
//!         [--chaos [SEED]] [--trace FILE] [--metrics-interval SECS]
//!         [--listen ADDR [--listen-secs S]]
//!       start a sharded serving cluster (S coordinator shards behind a
//!       router; P in round-robin|least-outstanding|consistent-hash;
//!       D bounds the shared admission queue, 0 = unbounded) on the
//!       quickstart program and drive R encrypted requests through it.
//!       T >= 2 serves T seeded tenant sessions (distinct per-client
//!       server keys behind shard-local stores of capacity C, default
//!       consistent-hash placement so each tenant's keys stay warm on
//!       one shard); T <= 1 keeps the single-key StaticKeys path.
//!       F >= 2 splits each native blind rotation's batch columns over F
//!       pool threads per worker engine (bitwise-identical outputs, pure
//!       latency knob; ignored by the XLA backend).
//!       --loadgen replaces the uniform request stream with a
//!       seed-deterministic Zipf-popular bursty schedule over the T
//!       sessions (ZIPF_S is the popularity exponent, default 1.0; same
//!       --loadgen-seed, same trace), pacing submissions to the
//!       schedule's arrival times.
//!       --tenant-rate R arms per-tenant token buckets (R tokens/s,
//!       burst B) and the weighted-fair admission queue; over-rate
//!       tenants are rejected typed (throttled) instead of occupying the
//!       shared queue. --tenant-queue-depth D alone arms fair queueing
//!       without rate limits (D requests per tenant lane).
//!       --autoscale wraps the cluster in the metrics-driven autoscaler:
//!       a control loop reshards between 1 and M shards (default
//!       max(shards, 4)) as backlog crosses its watermarks. Incompatible
//!       with --listen.
//!       --chaos injects a deterministic seed-driven fault plan (worker
//!       panics, latency spikes, resolve failures) into the native
//!       backend and key stores, drives every request under a deadline,
//!       and reports what the supervision layer did about it.
//!       --listen ADDR binds the framed-TCP wire front end on ADDR and
//!       serves remote clients (see examples/remote_client.rs) instead of
//!       driving requests in-process; --listen-secs bounds the serving
//!       window so scripted runs terminate (0 = run until killed).
//!       --trace FILE turns the observability hooks on and writes the
//!       flight-recorder ring buffers as Chrome trace-event JSON; either
//!       of --trace/--metrics-interval also adds the per-stage latency
//!       and cost-model-drift tables to the report, and a metrics
//!       interval emits a metrics JSONL line at most every SECS seconds
//!       while the driver runs (plus one final line).
//!   validate-trace FILE             check a --trace export: JSON parses,
//!       per-thread spans nest, async begin/end pair per request id
//!   params                          print all parameter sets
//!   selftest                        native + XLA PBS smoke test

// `config_from` mutates a Default config field-by-field on purpose (the
// flags map 1:1 onto fields).
#![allow(clippy::field_reassign_with_default)]

use std::sync::Arc;

use taurus::bail;
use taurus::util::err::Result;

use taurus::arch::TaurusConfig;
use taurus::cluster::{
    Cluster, ClusterError, ClusterOptions, ClusterResponse, PlacementPolicy, StoreFactory,
};
use taurus::coordinator::{BackendKind, CoordinatorOptions};
use taurus::runtime::faults::{FaultPlan, FaultSpec, FaultyStore};
use taurus::tenant::{self, KeyStore, SeededTenantStore, SessionId, StaticKeys};
use taurus::traffic::{
    AutoscaleOptions, AutoscaledCluster, LoadPlan, LoadSpec, QosOptions, TokenBucketSpec,
};
use taurus::ir::builder::ProgramBuilder;
use taurus::params;
use taurus::tfhe::pbs::{decrypt_message, encrypt_message};
use taurus::tfhe::{make_lut_poly, PbsContext, SecretKeys, ServerKeys};
use taurus::util::rng::Rng;
use taurus::{baselines, compiler, eval, workloads};

struct Args {
    cmd: String,
    flags: Vec<(String, String)>,
    positional: Vec<String>,
}

fn parse_args() -> Args {
    let mut args = std::env::args().skip(1);
    let cmd = args.next().unwrap_or_else(|| "help".into());
    let mut flags = Vec::new();
    let mut positional = Vec::new();
    let rest: Vec<String> = args.collect();
    let mut i = 0;
    while i < rest.len() {
        if let Some(name) = rest[i].strip_prefix("--") {
            let val = if i + 1 < rest.len() && !rest[i + 1].starts_with("--") {
                i += 1;
                rest[i].clone()
            } else {
                "true".into()
            };
            flags.push((name.to_string(), val));
        } else {
            positional.push(rest[i].clone());
        }
        i += 1;
    }
    Args { cmd, flags, positional }
}

impl Args {
    fn flag(&self, name: &str) -> Option<&str> {
        self.flags.iter().rev().find(|(n, _)| n == name).map(|(_, v)| v.as_str())
    }

    fn usize_flag(&self, name: &str, default: usize) -> usize {
        self.flag(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

fn config_from(args: &Args) -> TaurusConfig {
    let mut cfg = TaurusConfig::default();
    cfg.clusters = args.usize_flag("clusters", cfg.clusters);
    cfg.rr_ciphertexts = args.usize_flag("rr", cfg.rr_ciphertexts);
    cfg.acc_buffer_kb = args.usize_flag("acc-kb", cfg.acc_buffer_kb);
    cfg
}

fn main() -> Result<()> {
    let args = parse_args();
    match args.cmd.as_str() {
        "eval" => cmd_eval(&args),
        "run" => cmd_run(&args),
        "serve" => cmd_serve(&args),
        "validate-trace" => cmd_validate_trace(&args),
        "params" => cmd_params(),
        "selftest" => cmd_selftest(&args),
        _ => {
            println!(
                "taurus — multi-bit TFHE acceleration stack (paper reproduction)\n\
                 usage: taurus <eval|run|serve|validate-trace|params|selftest> [flags]\n\
                 see rust/src/main.rs header for flags"
            );
            Ok(())
        }
    }
}

fn cmd_eval(args: &Args) -> Result<()> {
    let cfg = config_from(args);
    let out = std::path::PathBuf::from(args.flag("out").unwrap_or("results"));
    std::fs::create_dir_all(&out)?;
    if let Some(id) = args.flag("exp").or_else(|| args.flag("table")).or_else(|| args.flag("fig"))
    {
        match eval::run_one(id, &cfg) {
            Some(t) => {
                println!("{}", t.render());
                t.write_csv(out.join(format!("{id}.csv")))?;
            }
            None => bail!("unknown experiment id {id} (known: {:?})", eval::ALL_IDS),
        }
    } else {
        // --all (default)
        let report = eval::run_all(&cfg, &out);
        println!("{report}");
        println!("wrote CSVs + report.txt to {}", out.display());
    }
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let name = args.positional.first().map(String::as_str).unwrap_or("CNN-20 (PTQ)");
    let batch = args.usize_flag("batch", 1);
    let Some(w) = workloads::by_name(name) else {
        bail!(
            "unknown workload {name}; known: {:?}",
            workloads::all().iter().map(|w| w.name).collect::<Vec<_>>()
        )
    };
    let cfg = config_from(args);
    let prog = (w.build)(batch);
    let c = compiler::compile(&prog, w.params, cfg.batch_capacity());
    let r = taurus::arch::simulate(&c, &cfg);
    let cpu = baselines::cpu_model::program_seconds(&c, &baselines::EPYC_7R13);
    println!("workload       : {} (batch {batch})", w.name);
    println!("params         : {} n={} N={} width={}", w.params.name, w.params.n, w.params.big_n, w.params.width);
    println!("PBS count      : {}", prog.pbs_count());
    println!("PBS depth      : {}", prog.pbs_depth());
    println!("KS-dedup       : {} -> {} ({:.2}%)", c.ks_dedup.before, c.ks_dedup.after, c.ks_dedup.reduction_pct());
    println!("KS costed      : {} (= plan KS, model/measured cross-check)", r.ks_count);
    println!("ACC-dedup      : {:.2}% storage saved", c.acc_dedup.bytes_reduction_pct());
    println!("Taurus runtime : {:.3} ms (paper: {} ms)", r.seconds * 1e3, w.paper_taurus_ms);
    println!("utilization    : {:.1}%", r.utilization * 100.0);
    println!("avg/peak BW    : {:.0} / {:.0} GB/s", r.avg_bw_gbps, r.peak_bw_gbps);
    println!("CPU model      : {:.2} s (paper: {} s)  => {:.0}x", cpu, w.paper_cpu_s, cpu / r.seconds);
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let shards = args.usize_flag("shards", 2).max(1);
    let workers = args.usize_flag("workers", 2);
    let fft_threads = args.usize_flag("fft-threads", 1).max(1);
    let requests = args.usize_flag("requests", 16);
    let queue_depth = args.usize_flag("queue-depth", 0);
    let tenants = args.usize_flag("tenants", 1).max(1);
    let key_cache_cap = args.usize_flag("key-cache-cap", 4).max(1);
    let legacy_exec = args.flag("legacy-exec").is_some();
    // `--loadgen [ZIPF_S]`: replace the uniform driver stream with a
    // seed-deterministic Zipf/bursty schedule over the tenant sessions.
    let loadgen_s: Option<f64> = args
        .flag("loadgen")
        .map(|v| if v == "true" { 1.0 } else { v.parse().unwrap_or(1.0) });
    let loadgen_seed = args.usize_flag("loadgen-seed", 0x10AD) as u64;
    // `--tenant-rate R` arms per-tenant token buckets AND the fair queue;
    // `--tenant-queue-depth D` alone arms fair queueing without buckets.
    let tenant_rate: Option<f64> = args.flag("tenant-rate").and_then(|v| v.parse().ok());
    let tenant_burst: f64 = args
        .flag("tenant-burst")
        .and_then(|v| v.parse().ok())
        .unwrap_or(8.0);
    let qos_on = tenant_rate.is_some() || args.flag("tenant-queue-depth").is_some();
    let qos = qos_on.then(|| QosOptions {
        bucket: tenant_rate.map(|r| TokenBucketSpec::new(r, tenant_burst)),
        tenant_queue_depth: args.usize_flag("tenant-queue-depth", 64).max(1),
        ..QosOptions::default()
    });
    let autoscale = args.flag("autoscale").is_some();
    let autoscale_max = args.usize_flag("autoscale-max", shards.max(4)).max(shards);
    if autoscale && args.flag("listen").is_some() {
        bail!(
            "--autoscale cannot combine with --listen: the wire server pins one cluster \
             topology per connection acceptor (drive load in-process instead)"
        )
    }
    // `--trace FILE` and/or `--metrics-interval SECS` arm the
    // observability subsystem (flight-recorder tracing, stage histograms,
    // drift profiles). Without either, every hook stays a single relaxed
    // atomic load on the hot path.
    let trace_path: Option<String> =
        args.flag("trace").filter(|v| *v != "true").map(str::to_string);
    let metrics_interval = args.usize_flag("metrics-interval", 0);
    let obs_on = trace_path.is_some() || metrics_interval > 0;
    // Multi-tenant serving defaults to consistent-hash: sessions pin to
    // the shard where their keys are resident.
    let policy_name =
        args.flag("policy").unwrap_or(if tenants > 1 { "consistent-hash" } else { "round-robin" });
    let Some(policy) = PlacementPolicy::parse(policy_name) else {
        bail!("unknown policy {policy_name} (round-robin | least-outstanding | consistent-hash)")
    };
    // `--chaos` (optionally `--chaos SEED`) arms deterministic fault
    // injection: same seed, same faults, same op indices.
    let chaos_seed: Option<u64> = args.flag("chaos").map(|v| v.parse().unwrap_or(1));
    let faults = chaos_seed.map(|seed| {
        Arc::new(FaultPlan::from_seed(
            seed,
            &FaultSpec {
                op_horizon: (requests as u64).max(4) * 4,
                panics: (requests / 6).max(1),
                delays: 2,
                delay: std::time::Duration::from_millis(20),
                resolve_horizon: (requests as u64).max(4),
                resolve_failures: (requests / 8).max(1),
            },
        ))
    });
    let backend = match (args.flag("backend").unwrap_or("native"), &faults) {
        ("xla", None) => BackendKind::Xla { artifacts_dir: "artifacts".into() },
        ("xla", Some(_)) => bail!("--chaos wraps the native backend; it cannot combine with --backend xla"),
        (_, Some(f)) => BackendKind::NativeChaos { faults: f.clone() },
        (_, None) => BackendKind::Native,
    };
    if tenants > 1 && matches!(backend, BackendKind::Xla { .. }) {
        bail!(
            "--backend xla cannot serve --tenants {tenants}: the XLA backend bakes keys into \
             device buffers and cannot rebind per-tenant key sets (use the native backend, \
             or --tenants 1 for single-key XLA serving)"
        )
    }
    // Quickstart program with fanout: d = 2x + y + 1, then relu(d) and
    // sign(d) — two LUTs over one value, so the compiled plan shares d's
    // key switch (KS-dedup realized on the serving path).
    let mut b = ProgramBuilder::new("serve-demo", params::TEST1.width);
    let x = b.input();
    let y = b.input();
    let d = b.dot(vec![x, y], vec![2, 1], 1);
    let r = b.relu(d, 3);
    let s = b.lut_fn(d, |m| u64::from(m > 3));
    b.outputs(&[r, s]);
    let prog = b.finish();

    let opts = ClusterOptions {
        shards,
        policy,
        queue_depth: if queue_depth > 0 { Some(queue_depth) } else { None },
        coordinator: CoordinatorOptions {
            workers,
            backend,
            legacy_exec,
            fft_threads,
            ..Default::default()
        },
        qos,
    };
    let mut rng = Rng::new(2077);
    // Per-session client secrets: with seeded tenants each session keys
    // its own material; single-tenant keeps one key pair for everything.
    let master_seed = 0x7E4A_2077u64;
    let session_sk: Vec<SecretKeys> = if tenants > 1 {
        println!("tenant stores (TEST1): {tenants} sessions derive on first touch, cache cap {key_cache_cap}/shard");
        (0..tenants as u64)
            .map(|t| tenant::client_secret(&params::TEST1, master_seed, SessionId(t)))
            .collect()
    } else {
        println!("keygen (TEST1)...");
        vec![SecretKeys::generate(&params::TEST1, &mut rng)]
    };
    // With chaos armed, every shard-local store is wrapped in a
    // `FaultyStore` so scheduled resolve failures exercise the cluster's
    // redirect path too.
    let store_faults = faults.clone();
    let mut cluster = if tenants > 1 {
        let factory: StoreFactory = Arc::new(move |_shard| {
            let inner = Arc::new(SeededTenantStore::new(&params::TEST1, master_seed, key_cache_cap))
                as Arc<dyn KeyStore>;
            match &store_faults {
                Some(f) => Arc::new(FaultyStore::new(inner, f.clone())) as Arc<dyn KeyStore>,
                None => inner,
            }
        });
        Cluster::start_with_store_factory(prog.clone(), factory, opts)
    } else {
        let keys = Arc::new(ServerKeys::generate(&session_sk[0], &mut rng));
        match &faults {
            Some(f) => {
                let f = f.clone();
                let factory: StoreFactory = Arc::new(move |_shard| {
                    let inner = Arc::new(StaticKeys::new(keys.clone())) as Arc<dyn KeyStore>;
                    Arc::new(FaultyStore::new(inner, f.clone())) as Arc<dyn KeyStore>
                });
                Cluster::start_with_store_factory(prog.clone(), factory, opts)
            }
            None => Cluster::start(prog.clone(), keys, opts),
        }
    };
    // `--autoscale` wraps the cluster in the control loop; the driver
    // and report below run against the enum so both paths share them.
    let mut cluster = if autoscale {
        ServeCluster::Auto(AutoscaledCluster::start(
            cluster,
            AutoscaleOptions { min_shards: 1, max_shards: autoscale_max, ..Default::default() },
        ))
    } else {
        ServeCluster::Plain(cluster)
    };
    // Arm observability only now — after key generation — so keygen's
    // forward FFT transforms never pollute the fft_transform histogram.
    if obs_on {
        taurus::obs::enable();
    }
    let plan = cluster.plan();
    println!(
        "compiled plan  : {} PBS, KS-dedup {} -> {} ({:.1}%), {} batches ({}), shared by {} shards",
        plan.graph.pbs_count(),
        plan.ks_dedup.before,
        plan.ks_dedup.after,
        plan.ks_dedup.reduction_pct(),
        plan.schedule.batches.len(),
        if legacy_exec { "legacy node-walk executor" } else { "schedule-driven executor" },
        shards,
    );
    // `--listen ADDR` swaps the in-process driver for the wire front end:
    // bind a framed-TCP listener over this cluster and serve remote
    // clients instead of driving requests ourselves. `--listen-secs S`
    // bounds the serving window so scripted runs terminate.
    if let Some(listen) = args.flag("listen") {
        if listen == "true" {
            bail!("--listen needs a bind address (e.g. --listen 127.0.0.1:7171)")
        }
        let listen_secs = args.usize_flag("listen-secs", 0);
        let ServeCluster::Plain(cluster) = cluster else {
            unreachable!("--autoscale with --listen is rejected at flag parsing")
        };
        let cluster = Arc::new(cluster);
        let mut server = taurus::wire::WireServer::start(
            cluster.clone(),
            listen,
            taurus::wire::WireServerOptions::default(),
        )?;
        println!(
            "wire listener  : {} (protocol v{}, {} per-session key uploads)",
            server.local_addr(),
            taurus::wire::proto::PROTO_VERSION,
            if cluster.supports_register() { "accepts" } else { "rejects" },
        );
        if listen_secs == 0 {
            println!("serving until killed (pass --listen-secs S for a bounded window)");
            loop {
                std::thread::park();
            }
        }
        std::thread::sleep(std::time::Duration::from_secs(listen_secs as u64));
        server.shutdown();
        if let Ok(mut c) = Arc::try_unwrap(cluster) {
            c.shutdown();
        }
        return Ok(());
    }
    println!(
        "serving {requests} encrypted requests: {shards} shards x {workers} workers x {fft_threads} fft thread(s), {} routing, admission depth {}, {tenants} session(s)",
        policy.name(),
        if queue_depth > 0 { queue_depth.to_string() } else { "unbounded".into() },
    );
    // The driver's request schedule: (arrival offset, session, tenant
    // index). Default: uniform round-robin, no pacing. With --loadgen:
    // the seed-deterministic Zipf/bursty plan, paced to its arrivals.
    let schedule: Vec<(std::time::Duration, u64, usize)> = match loadgen_s {
        Some(s) => {
            let spec = LoadSpec {
                tenants: tenants.max(1),
                zipf_s: s,
                events: requests,
                ..Default::default()
            };
            let lp = LoadPlan::from_seed(loadgen_seed, &spec);
            println!(
                "loadgen        : zipf s={s} over {tenants} session(s), seed {loadgen_seed:#x}: {} kept arrival(s) spanning {:.1} ms",
                lp.events().len(),
                lp.events().last().map_or(0.0, |e| e.at.as_secs_f64() * 1e3),
            );
            lp.events()
                .iter()
                .map(|e| {
                    let t = if tenants > 1 { e.session.0 as usize } else { 0 };
                    (e.at, e.session.0, t)
                })
                .collect()
        }
        None => (0..requests)
            .map(|i| {
                let t = if tenants > 1 { i % tenants } else { 0 };
                let session = if tenants > 1 { t as u64 } else { (i as u64) % 4 };
                (std::time::Duration::ZERO, session, t)
            })
            .collect(),
    };
    // (response, expected, tenant index) — each response decrypts under
    // its own session's secret key.
    let mut pending: std::collections::VecDeque<(ClusterResponse, Vec<u64>, usize)> =
        std::collections::VecDeque::new();
    let chaos = faults.is_some();
    // Under chaos every request carries a deadline, so the driver
    // terminates no matter what the fault plan does.
    let chaos_deadline = std::time::Duration::from_secs(30);
    let mut correct = 0usize;
    let mut failed = 0usize;
    // Drain one pending response: a typed failure under chaos is counted,
    // anywhere else it aborts the run.
    let settle = |(r, e, pt): (ClusterResponse, Vec<u64>, usize),
                      correct: &mut usize,
                      failed: &mut usize|
     -> Result<()> {
        match r.recv() {
            Ok(outs) => {
                let got: Vec<u64> =
                    outs.iter().map(|c| decrypt_message(c, &session_sk[pt])).collect();
                *correct += usize::from(got == e);
                Ok(())
            }
            Err(err) if chaos => {
                *failed += 1;
                println!("request failed ({err})");
                Ok(())
            }
            Err(err) => Err(err.into()),
        }
    };
    let mut last_emit = std::time::Instant::now();
    let mut rejected = 0usize;
    let drive_start = std::time::Instant::now();
    for (i, &(at, session, t)) in schedule.iter().enumerate() {
        // Periodic metrics emission (JSONL, one self-contained object per
        // line) from the driver thread — an in-band poller, so it needs
        // no shared-cluster handle and stops with the run.
        if metrics_interval > 0 && last_emit.elapsed().as_secs() >= metrics_interval as u64 {
            println!("{}", metrics_jsonl(&cluster.snapshot()));
            last_emit = std::time::Instant::now();
        }
        // Loadgen pacing: offer each arrival at its scheduled offset so
        // bursts and quiet periods reach the cluster as bursts and quiet
        // periods, not one saturating stream.
        if loadgen_s.is_some() {
            let elapsed = drive_start.elapsed();
            if at > elapsed {
                std::thread::sleep(at - elapsed);
            }
        }
        let (mx, my) = ((i as u64) % 4, (i as u64 * 3) % 4);
        let exp = taurus::ir::interp::eval(&prog, &[mx, my]);
        // Single-submitter driver: admission slots are held by the pending
        // handles, so drain the oldest response whenever the queue is at
        // depth instead of bouncing off ClusterFull and re-cloning inputs.
        while queue_depth > 0 && cluster.outstanding() >= queue_depth {
            let Some(p) = pending.pop_front() else {
                bail!("admission queue full with nothing pending")
            };
            settle(p, &mut correct, &mut failed)?;
        }
        let sk = &session_sk[t];
        let inputs = vec![encrypt_message(mx, sk, &mut rng), encrypt_message(my, sk, &mut rng)];
        let submitted = if chaos {
            cluster.submit_with_deadline(session, inputs, chaos_deadline)
        } else {
            cluster.submit(session, inputs)
        };
        let resp = match submitted {
            Ok(r) => r,
            // QoS rejections are the rate limiter doing its job, not a
            // driver failure: count them and keep offering load.
            Err(e @ (ClusterError::Throttled | ClusterError::TenantQueueFull)) => {
                rejected += 1;
                if rejected <= 5 {
                    println!("request {i} (session {session}): {e}");
                }
                continue;
            }
            Err(e) if chaos => {
                println!("request {i}: rejected at admission ({e})");
                failed += 1;
                continue;
            }
            Err(e) => bail!("submit failed: {e}"),
        };
        pending.push_back((resp, exp, t));
    }
    while let Some(p) = pending.pop_front() {
        settle(p, &mut correct, &mut failed)?;
    }
    let snap = cluster.snapshot();
    let per_shard = cluster.shard_snapshots();
    if metrics_interval > 0 {
        // Final emission: short runs always produce at least one line.
        println!("{}", metrics_jsonl(&snap));
    }
    let offered = schedule.len();
    if rejected > 0 {
        println!("correct        : {correct}/{} admitted ({offered} offered, {rejected} rejected by QoS)", offered - rejected);
    } else {
        println!("correct        : {correct}/{offered}");
    }
    if let Some(f) = &faults {
        let inj = f.injected();
        println!(
            "chaos (seed {}): injected {} panics / {} delays / {} resolve failures; {failed} request(s) failed",
            f.seed(),
            inj.panics,
            inj.delays,
            inj.resolve_failures,
        );
        println!(
            "recovery       : {} batch failures, {} worker respawns, {} retries, {} redirects, {} shard restarts, {} timeouts",
            snap.exec_failures,
            snap.worker_respawns,
            snap.request_retries,
            snap.request_redirects,
            snap.shard_restarts,
            snap.request_timeouts,
        );
    }
    println!("throughput     : {:.1} req/s (aggregate)", snap.throughput_rps);
    println!("p50 / p99      : {:.2} / {:.2} ms (merged samples)", snap.p50_latency_ms, snap.p99_latency_ms);
    println!("mean batch size: {:.2} ({} batches)", snap.mean_batch_size, snap.batches);
    println!("PBS executed   : {}", snap.pbs_executed);
    println!(
        "KS executed    : {} (plan: {}/request; legacy would pay {}/request)",
        snap.ks_executed,
        cluster.plan().ks_dedup.after,
        cluster.plan().ks_dedup.before,
    );
    println!("BSK B/PBS      : {:.0} (pbs-weighted over shards)", snap.bsk_bytes_per_pbs);
    println!(
        "fft engine     : {} thread(s)/worker, {} transform schedule",
        snap.fft_threads,
        if snap.blocked_fft { "cache-blocked" } else { "monolithic" },
    );
    println!("per shard      : id  requests  batches  mean-batch      KS     PBS  keys-resident");
    for (i, s) in per_shard.iter().enumerate() {
        println!(
            "                 {i:<3} {:>8} {:>8} {:>10.2} {:>7} {:>7} {:>14}",
            s.requests, s.batches, s.mean_batch_size, s.ks_executed, s.pbs_executed, s.key_resident
        );
    }
    if obs_on {
        // Per-stage latency breakdown from the merged log2 histograms
        // (success-only, so counts reconcile with the counters above:
        // keyswitch == KS executed, sample_extract == PBS executed).
        println!("per stage      : stage            count       p50        p99");
        for (name, h) in snap.stage.named() {
            if h.is_empty() {
                continue;
            }
            println!(
                "                 {name:<14} {:>8} {:>8.3}ms {:>9.3}ms",
                h.count(),
                h.percentile(50.0) / 1e6,
                h.percentile(99.0) / 1e6,
            );
        }
    }
    if tenants > 1 {
        println!(
            "key caches     : {} hits / {} misses / {} evictions / {} regenerations, {} resident, {} keyed batch splits",
            snap.key_hits,
            snap.key_misses,
            snap.key_evictions,
            snap.key_regenerations,
            snap.key_resident,
            snap.keyed_batch_splits,
        );
        println!("per tenant     : session  requests   p99-ms");
        for (s, n) in &snap.session_requests {
            match snap.tenant_p99_ms(*s) {
                Some(p99) => println!("                 {s:<8} {n:>8} {p99:>8.2}"),
                None => println!("                 {s:<8} {n:>8}        -"),
            }
        }
    }
    if qos_on {
        println!(
            "qos            : {} throttled (token bucket), {} tenant-queue rejections",
            snap.qos_throttled, snap.qos_queue_rejections,
        );
    }
    if autoscale {
        println!(
            "autoscale      : {} scale-up(s), {} scale-down(s), final {} shard(s)",
            snap.autoscale_ups,
            snap.autoscale_downs,
            cluster.shard_count(),
        );
    }
    // The identical artifact costed by the arch model: aggregate measured
    // counters must equal per-request sim costs x requests, independent
    // of how many shards served them.
    let cfg = config_from(args);
    let sim = taurus::arch::simulate(&cluster.plan(), &cfg);
    if !legacy_exec {
        // Under chaos the invariant holds over SERVED requests (failed
        // attempts record nothing); fault-free, served == submitted.
        let served = snap.requests;
        let ks_ok = snap.ks_executed == (served * sim.ks_count) as u64;
        let pbs_ok = snap.pbs_executed == served * sim.pbs_count;
        println!(
            "sim cross-check: KS {} vs {} ({served} served x {}), PBS {} vs {} -> {}",
            snap.ks_executed,
            served * sim.ks_count,
            sim.ks_count,
            snap.pbs_executed,
            served * sim.pbs_count,
            if ks_ok && pbs_ok { "OK" } else { "MISMATCH" },
        );
        if obs_on && !snap.plan_batch_profiles.is_empty() {
            // Cost-model drift: measured per-schedule-batch stage work
            // against `arch::sim`'s per-batch predictions for the very
            // same artifact. KS/PBS counts must be exact on the
            // successfully-served (fault-free) subset; the bsk ratio
            // below 1.0 is the batching key-reuse the model prices
            // per-request, and the time ratio is the CPU-vs-accelerator
            // gap per batch.
            let preds = taurus::arch::sim::batch_predictions(
                &cluster.plan().schedule,
                &cluster.plan().params,
                &cfg,
            );
            let rows = taurus::obs::drift::attribute(&snap.plan_batch_profiles, &preds);
            println!(
                "drift          : batch  execs   reqs        KS meas=pred       PBS meas=pred  bsk-ratio  time-ratio"
            );
            for r in &rows {
                println!(
                    "                 {:<6} {:>5} {:>6} {:>9} {} {:<9} {:>9} {} {:<9} {:>9.3} {:>11.1}",
                    r.batch,
                    r.executions,
                    r.requests,
                    r.measured_ks,
                    if r.ks_exact { "=" } else { "!" },
                    r.predicted_ks,
                    r.measured_pbs,
                    if r.pbs_exact { "=" } else { "!" },
                    r.predicted_pbs,
                    r.bsk_ratio,
                    r.time_ratio,
                );
            }
            println!(
                "drift counts   : {}",
                if taurus::obs::drift::counts_exact(&rows) {
                    "exact (measured KS/PBS == sim on the served subset)"
                } else {
                    "MISMATCH (measured KS/PBS diverge from sim)"
                },
            );
        }
    }
    if let Some(path) = &trace_path {
        // Export the flight recorder: every thread's ring, merged and
        // timestamp-sorted, as Chrome trace-event JSON.
        let events = taurus::obs::trace::drain();
        let json = taurus::obs::trace::chrome_trace_json(&events);
        std::fs::write(path, json.to_string())?;
        println!(
            "trace          : wrote {} events to {path} ({} overwritten in-ring)",
            events.len(),
            taurus::obs::trace::dropped(),
        );
    }
    cluster.shutdown();
    Ok(())
}

/// The serve driver's cluster handle: either the plain [`Cluster`] or the
/// autoscaling wrapper. One delegating surface so the submit loop and the
/// report below are written once, not per mode.
enum ServeCluster {
    Plain(Cluster),
    Auto(AutoscaledCluster),
}

impl ServeCluster {
    fn submit(
        &self,
        session: u64,
        inputs: Vec<taurus::tfhe::LweCiphertext>,
    ) -> std::result::Result<ClusterResponse, ClusterError> {
        match self {
            ServeCluster::Plain(c) => c.submit(session, inputs),
            ServeCluster::Auto(a) => a.submit(session, inputs),
        }
    }

    fn submit_with_deadline(
        &self,
        session: u64,
        inputs: Vec<taurus::tfhe::LweCiphertext>,
        deadline: std::time::Duration,
    ) -> std::result::Result<ClusterResponse, ClusterError> {
        match self {
            ServeCluster::Plain(c) => c.submit_with_deadline(session, inputs, deadline),
            ServeCluster::Auto(a) => a.submit_with_deadline(session, inputs, deadline),
        }
    }

    fn outstanding(&self) -> usize {
        match self {
            ServeCluster::Plain(c) => c.outstanding(),
            ServeCluster::Auto(a) => a.outstanding(),
        }
    }

    fn snapshot(&self) -> taurus::coordinator::MetricsSnapshot {
        match self {
            ServeCluster::Plain(c) => c.snapshot(),
            ServeCluster::Auto(a) => a.snapshot(),
        }
    }

    fn shard_snapshots(&self) -> Vec<taurus::coordinator::MetricsSnapshot> {
        match self {
            ServeCluster::Plain(c) => c.shard_snapshots(),
            ServeCluster::Auto(a) => a.shard_snapshots(),
        }
    }

    fn shard_count(&self) -> usize {
        match self {
            ServeCluster::Plain(c) => c.shard_count(),
            ServeCluster::Auto(a) => a.shard_count(),
        }
    }

    /// The shared compiled plan. An owned `Arc` because the autoscaler's
    /// cluster lives behind a lock, so a borrow cannot escape it.
    fn plan(&self) -> Arc<compiler::CompiledPlan> {
        match self {
            ServeCluster::Plain(c) => c.plan_handle(),
            ServeCluster::Auto(a) => a.plan(),
        }
    }

    fn shutdown(&mut self) {
        match self {
            ServeCluster::Plain(c) => c.shutdown(),
            ServeCluster::Auto(a) => a.shutdown(),
        }
    }
}

/// One self-contained metrics JSONL line for `serve --metrics-interval`:
/// headline counters plus per-stage histogram count/p50/p99.
fn metrics_jsonl(snap: &taurus::coordinator::MetricsSnapshot) -> String {
    use taurus::util::json::{arr, num, obj, s};
    let stages: Vec<_> = snap
        .stage
        .named()
        .iter()
        .filter(|(_, h)| !h.is_empty())
        .map(|(name, h)| {
            obj(vec![
                ("stage", s(*name)),
                ("count", num(h.count() as f64)),
                ("p50_ms", num(h.percentile(50.0) / 1e6)),
                ("p99_ms", num(h.percentile(99.0) / 1e6)),
            ])
        })
        .collect();
    obj(vec![
        ("requests", num(snap.requests as f64)),
        ("batches", num(snap.batches as f64)),
        ("ks_executed", num(snap.ks_executed as f64)),
        ("pbs_executed", num(snap.pbs_executed as f64)),
        ("bsk_bytes_streamed", num(snap.bsk_bytes_streamed as f64)),
        ("p50_latency_ms", num(snap.p50_latency_ms)),
        ("p99_latency_ms", num(snap.p99_latency_ms)),
        ("throughput_rps", num(snap.throughput_rps)),
        ("exec_failures", num(snap.exec_failures as f64)),
        ("worker_respawns", num(snap.worker_respawns as f64)),
        ("request_timeouts", num(snap.request_timeouts as f64)),
        ("stages", arr(stages)),
    ])
    .to_string()
}

/// `validate-trace FILE`: structural checks over a `serve --trace` export.
/// Verifies the file parses as Chrome trace-event JSON, every event
/// carries the required fields, duration (`X`) spans nest properly within
/// each thread (no partial overlap), and async `b`/`e` events pair up
/// one-to-one per request id. CI runs this over the chaos-serve trace.
fn cmd_validate_trace(args: &Args) -> Result<()> {
    let Some(path) = args.positional.first() else {
        bail!("usage: taurus validate-trace FILE")
    };
    let text = std::fs::read_to_string(path)?;
    let json = taurus::util::json::JsonValue::parse(&text)?;
    let Some(events) = json.get("traceEvents").and_then(|e| e.as_array()) else {
        bail!("{path}: missing traceEvents array")
    };
    // (tid -> X spans as (start_us, end_us)), and b/e counts per id.
    let mut spans: std::collections::BTreeMap<u64, Vec<(f64, f64)>> = Default::default();
    let mut begins: std::collections::BTreeMap<u64, i64> = Default::default();
    let mut names = std::collections::BTreeSet::new();
    for (i, e) in events.iter().enumerate() {
        let name =
            e.get("name").and_then(|v| v.as_str()).ok_or_else(|| {
                taurus::anyhow!("{path}: event {i} has no name")
            })?;
        names.insert(name.to_string());
        let ph = e
            .get("ph")
            .and_then(|v| v.as_str())
            .ok_or_else(|| taurus::anyhow!("{path}: event {i} ({name}) has no ph"))?;
        let ts = e
            .get("ts")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| taurus::anyhow!("{path}: event {i} ({name}) has no ts"))?;
        let tid = e
            .get("tid")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| taurus::anyhow!("{path}: event {i} ({name}) has no tid"))?
            as u64;
        if e.get("pid").and_then(|v| v.as_f64()).is_none() {
            bail!("{path}: event {i} ({name}) has no pid");
        }
        match ph {
            "X" => {
                let dur = e.get("dur").and_then(|v| v.as_f64()).ok_or_else(|| {
                    taurus::anyhow!("{path}: X event {i} ({name}) has no dur")
                })?;
                spans.entry(tid).or_default().push((ts, ts + dur));
            }
            "i" => {}
            "b" | "e" => {
                let id = e.get("id").and_then(|v| v.as_f64()).ok_or_else(|| {
                    taurus::anyhow!("{path}: async event {i} ({name}) has no id")
                })? as u64;
                *begins.entry(id).or_insert(0) += if ph == "b" { 1 } else { -1 };
                if begins[&id] < 0 {
                    bail!("{path}: async id {id} ends before it begins (event {i})");
                }
            }
            other => bail!("{path}: event {i} ({name}) has unexpected ph {other:?}"),
        }
    }
    // Per-thread span nesting: sorted by start (wider first on ties), a
    // span must either start after every open span ends, or end inside
    // the innermost open one. Partial overlap on one thread means the
    // recorder emitted garbage.
    let eps = 1e-6;
    let mut checked = 0usize;
    for (tid, list) in spans.iter_mut() {
        list.sort_by(|a, b| {
            a.0.partial_cmp(&b.0)
                .unwrap()
                .then(b.1.partial_cmp(&a.1).unwrap())
        });
        let mut stack: Vec<f64> = Vec::new();
        for &(start, end) in list.iter() {
            while stack.last().is_some_and(|&open_end| open_end <= start + eps) {
                stack.pop();
            }
            if let Some(&open_end) = stack.last() {
                if end > open_end + eps {
                    bail!(
                        "{path}: tid {tid}: span [{start:.3}, {end:.3}]us partially \
                         overlaps an open span ending at {open_end:.3}us"
                    );
                }
            }
            stack.push(end);
            checked += 1;
        }
    }
    let unbalanced: Vec<u64> =
        begins.iter().filter(|(_, &n)| n != 0).map(|(&id, _)| id).collect();
    if !unbalanced.is_empty() {
        bail!("{path}: {} async request id(s) never ended: {unbalanced:?}", unbalanced.len());
    }
    println!(
        "{path}: OK — {} events, {} X spans nested across {} thread(s), {} async request id(s) balanced, names: {}",
        events.len(),
        checked,
        spans.len(),
        begins.len(),
        names.into_iter().collect::<Vec<_>>().join(","),
    );
    Ok(())
}

fn cmd_params() -> Result<()> {
    use taurus::util::table::Table;
    let mut t = Table::new(
        "Parameter sets",
        &["name", "n", "N", "k", "bsk B/l", "ks B/l", "width", "security bits"],
    );
    for p in [&params::TEST1, &params::TEST2]
        .into_iter()
        .chain(params::PAPER_SETS.into_iter())
    {
        t.row(vec![
            p.name.into(),
            p.n.to_string(),
            p.big_n.to_string(),
            p.k.to_string(),
            format!("2^{}/{}", p.bsk_base_log, p.bsk_level),
            format!("2^{}/{}", p.ks_base_log, p.ks_level),
            p.width.to_string(),
            format!("{:.0}", params::security::security_level(p.n, p.lwe_noise)),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_selftest(args: &Args) -> Result<()> {
    let mut rng = Rng::new(1);
    let sk = SecretKeys::generate(&params::TEST1, &mut rng);
    let keys = ServerKeys::generate(&sk, &mut rng);
    let mut ctx = PbsContext::new(&params::TEST1);
    let lut = make_lut_poly(&params::TEST1, |m| (m * m) % 16);
    let mut ok = true;
    for m in 0..8 {
        let ct = encrypt_message(m, &sk, &mut rng);
        let out = ctx.pbs(&ct, &keys, &lut);
        let got = decrypt_message(&out, &sk);
        if got != (m * m) % 16 {
            println!("native FAIL m={m} got {got}");
            ok = false;
        }
    }
    println!("native PBS: {}", if ok { "OK" } else { "FAIL" });
    #[cfg(feature = "xla")]
    {
        let artifacts = args.flag("artifacts").unwrap_or("artifacts");
        if std::path::Path::new(artifacts).join("manifest.json").exists() {
            let be =
                taurus::runtime::XlaPbsBackend::new(artifacts, &params::TEST1, &keys.bsk, &keys.ksk)?;
            let ct = encrypt_message(5, &sk, &mut rng);
            let out = be.pbs(&ct, &lut)?;
            let got = decrypt_message(&out, &sk);
            println!("xla PBS   : {}", if got == 9 { "OK" } else { "FAIL" });
        } else {
            println!("xla PBS   : skipped (run `make artifacts`)");
        }
    }
    #[cfg(not(feature = "xla"))]
    {
        let _ = args;
        println!("xla PBS   : skipped (built without the `xla` feature)");
    }
    Ok(())
}
