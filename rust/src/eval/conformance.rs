//! Width-parametric conformance harness: ONE randomized check driven over
//! every functional width {3, 5, 8, 10}, proving the whole stack — IR
//! interpreter, compiled-schedule engine, and sharded cluster — agrees at
//! the paper's headline widths, not just the narrow TEST sets.
//!
//! Per random program the harness asserts:
//!
//! 1. **Bitwise agreement** — `Engine::run_plan_batch` decodes to the
//!    plaintext interpreter's answers, and a 2-shard [`Cluster`] returns
//!    the *identical ciphertext bits* (same plan + same keys must yield
//!    the same bits no matter how requests are sharded or batched).
//! 2. **Measured == modeled counts** — the executor's `ExecStats` and the
//!    cluster's merged metrics both equal `requests x arch::sim`'s
//!    KS/PBS costs for the very same compiled plan.
//! 3. **Noise within model margins** — every output ciphertext's
//!    decrypted phase error stays inside the `compiler::noise` prediction
//!    (<= [`NOISE_SIGMA_GATE`] predicted-sigmas, and always inside the
//!    decision boundary).
//!
//! Programs are drawn by [`random_program`] and gated on a predicted
//! margin of [`MIN_MARGIN_SIGMAS`] so the suite never *knowingly* runs a
//! program the parameter set cannot support (that rejection path is how
//! e.g. a bivariate LUT over PBS outputs at width 10 — a genuine
//! out-of-budget shape — is excluded, mirroring what Concrete's optimizer
//! would refuse to compile).
//!
//! Keys come from [`crate::tfhe::keycache`], so a whole test binary pays
//! keygen once per width; case counts honor `PROP_CASES`
//! (`util::prop::cases`).

use std::time::Duration;

use crate::arch::{simulate, TaurusConfig};
use crate::cluster::{Cluster, ClusterOptions, PlacementPolicy};
use crate::compiler::{compile, noise, CompileOpts, Engine, EngineOptions, NativePbsBackend};
use crate::coordinator::CoordinatorOptions;
use crate::ir::builder::ProgramBuilder;
use crate::ir::{interp, LutTable, Program};
use crate::params::{self, ParamSet};
use crate::tfhe::encoding::encode;
use crate::tfhe::keycache;
use crate::tfhe::pbs::{decrypt_message, encrypt_message};
use crate::tfhe::torus::torus_distance;
use crate::tfhe::LweCiphertext;
use crate::util::prop;
use crate::util::rng::Rng;

/// The widths the functional path executes for real (one per
/// [`params::FUNCTIONAL_SETS`] entry).
pub const WIDTHS: [usize; 4] = [3, 5, 8, 10];

/// Seed of the shared per-width key-cache entries.
pub const KEY_SEED: u64 = 0x7A95;

/// Minimum predicted margin (in sigmas) a generated program must have
/// before it is run. tail(5.5) ~ 2^-25 per PBS — far beyond what a few
/// hundred CI bootstraps can trip over.
pub const MIN_MARGIN_SIGMAS: f64 = 5.5;

/// Measured per-output phase error must stay below this many *predicted*
/// sigmas. tail(7) ~ 1e-12 per sample under a correct model, so a trip
/// means the `compiler::noise` prediction is materially wrong, not bad
/// luck.
pub const NOISE_SIGMA_GATE: f64 = 7.0;

/// Encrypted requests per case (each runs through the plan engine once
/// and the 2-shard cluster once).
const REQUESTS: usize = 2;

/// A random LUT over the full padded message space.
fn rand_table(rng: &mut Rng, width: usize) -> LutTable {
    let pt = 1u64 << (width + 1);
    LutTable::new((0..pt).map(|_| rng.below(pt)).collect())
}

/// Draw a random two-level LUT/linear program at `width`: a linear mix
/// feeding a LUT layer (with KS-dedup fanout and a bivariate LUT on the
/// fresh inputs, each half the time), a combining reduction, a dependent
/// second-level LUT, and a loose linear tail — every primitive kind and
/// both schedule shapes (fanout + dependent level) in a handful of nodes.
///
/// Returns the program and its **input domain**: `2^width` normally, but
/// `2^(width/2)` when a bivariate LUT was drawn — the bivariate pack
/// `x * 2^(w/2) + y` is only a semantically valid g(x, y) lookup when
/// both operands stay below `2^(w/2)` (`ir::interp`'s documented
/// precondition), so those cases restrict the query range instead of
/// exercising the aliased-pack degenerate case.
pub fn random_program(rng: &mut Rng, width: usize) -> (Program, u64) {
    let mut b = ProgramBuilder::new(format!("conformance-w{width}"), width);
    let xs = b.inputs(2);
    let mix = match rng.below(3) {
        0 => b.add(xs[0], xs[1]),
        1 => {
            let w = vec![1, 1 + rng.below(2) as i64];
            let bias = rng.below(4);
            b.dot(xs.clone(), w, bias)
        }
        _ => {
            let t = b.mul_plain(xs[0], 1 + rng.below(2) as i64);
            b.add(t, xs[1])
        }
    };
    let mut mids = vec![b.lut(mix, rand_table(rng, width))];
    if rng.below(2) == 0 {
        // Fanout over the same source: the KS-dedup shape.
        mids.push(b.lut(mix, rand_table(rng, width)));
    }
    let mut input_domain = 1u64 << width;
    if rng.below(2) == 0 {
        // Bivariate LUT on the *fresh* inputs (a bivariate over PBS
        // outputs scales noise by 2^(w/2) and is rejected by the margin
        // gate at the wide widths). Valid packing needs sub-width inputs.
        mids.push(b.biv_lut(xs[0], xs[1], rand_table(rng, width)));
        input_domain = 1u64 << (width / 2);
    }
    let combined = if mids.len() == 1 {
        b.add_plain(mids[0], rng.below(4))
    } else {
        let w = vec![1i64; mids.len()];
        b.dot(mids.clone(), w, rng.below(4))
    };
    let l2 = b.lut(combined, rand_table(rng, width));
    let tail = b.add_plain(l2, rng.below(1u64 << width));
    b.outputs(&[tail, mids[0]]);
    (b.finish(), input_domain)
}

/// Draw until the noise model clears [`MIN_MARGIN_SIGMAS`]. Panics after
/// a bounded number of rejections: on a sane parameter set the gate
/// rejects only the known-out-of-budget shapes, so exhaustion means the
/// set itself no longer supports its width. Returns the program, its
/// noise report, and its valid input domain.
pub fn random_program_for(rng: &mut Rng, p: &ParamSet) -> (Program, noise::NoiseReport, u64) {
    for _ in 0..32 {
        let (prog, input_domain) = random_program(rng, p.width);
        let report = noise::analyze(&prog, p);
        if report.margin_sigmas >= MIN_MARGIN_SIGMAS {
            return (prog, report, input_domain);
        }
    }
    panic!(
        "parameter set {} cannot support width {} at {} sigma",
        p.name, p.width, MIN_MARGIN_SIGMAS
    );
}

/// What one width's conformance run measured (consumed by the test for
/// reporting; the run itself panics on any violation).
#[derive(Debug, Clone)]
pub struct WidthReport {
    pub width: usize,
    pub param_name: &'static str,
    pub cases: u64,
    /// Smallest predicted margin among the programs actually run.
    pub min_predicted_margin_sigmas: f64,
    /// Largest measured output error in units of the predicted sigma.
    pub max_measured_err_sigmas: f64,
}

/// Blind-rotation worker threads for both conformance paths, from the
/// `FFT_THREADS` env var (default 1). CI runs the suite at 1 and 4:
/// because the parallel sweep is bitwise-invariant, every assertion —
/// including Path 2's ciphertext-identity check — must hold unchanged at
/// any thread count.
pub fn fft_threads_from_env() -> usize {
    std::env::var("FFT_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(1)
        .max(1)
}

/// Per-shard coordinator config for the 2-shard conformance cluster.
fn shard_options() -> CoordinatorOptions {
    CoordinatorOptions {
        workers: 1,
        batch_capacity: REQUESTS,
        max_batch_wait: Duration::from_millis(1),
        fft_threads: fft_threads_from_env(),
        ..Default::default()
    }
}

/// Run the conformance property for one width. `default_cases` is the
/// case count when `PROP_CASES` is unset.
pub fn run_width(width: usize, default_cases: u64) -> WidthReport {
    let p = params::select_for_width(width);
    assert_eq!(p.width, width, "conformance widths must map to exact-width sets");
    let keys = keycache::get(p, KEY_SEED);
    // `OBS_TRACE=1` runs the whole suite with the observability hooks
    // live and adds exact reconciliation asserts: stage histogram counts
    // must equal the ExecStats/metrics counters, and the per-batch drift
    // attribution must match `arch::sim` exactly on this fault-free path.
    // Enabled AFTER keygen so the key material's forward transforms never
    // pollute the FFT stage histogram.
    let tracing = std::env::var("OBS_TRACE").map(|v| v == "1").unwrap_or(false);
    if tracing {
        crate::obs::enable();
    }
    let cfg = TaurusConfig::default();
    let mut min_margin = f64::INFINITY;
    let mut max_err_sigmas = 0.0f64;
    let cases = prop::cases(default_cases);
    prop::check(&format!("conformance_w{width}"), default_cases, |rng| {
        let (prog, report, input_domain) = random_program_for(rng, p);
        min_margin = min_margin.min(report.margin_sigmas);
        let plan = compile(&prog, p, CompileOpts::default());
        let sim = simulate(&plan, &cfg);

        // Encrypted requests + the plaintext oracle (inputs drawn from
        // the program's valid domain — sub-width when it packs a
        // bivariate LUT).
        let queries: Vec<Vec<u64>> = (0..REQUESTS)
            .map(|_| (0..2).map(|_| rng.below(input_domain)).collect())
            .collect();
        let expected: Vec<Vec<u64>> = queries.iter().map(|q| interp::eval(&prog, q)).collect();
        let batch: Vec<Vec<LweCiphertext>> = queries
            .iter()
            .map(|q| q.iter().map(|&m| encrypt_message(m, &keys.sk, rng)).collect())
            .collect();

        // --- Path 1: the schedule-driven engine over the compiled plan.
        let mut eng = Engine::new(NativePbsBackend::new_with(
            &keys.server,
            &EngineOptions { fft_threads: fft_threads_from_env() },
        ));
        let plan_outs = eng.run_plan_batch(&plan, &batch);
        for (q, (outs, exp)) in plan_outs.iter().zip(&expected).enumerate() {
            let got: Vec<u64> = outs.iter().map(|c| decrypt_message(c, &keys.sk)).collect();
            if got != *exp {
                return Err(format!("plan engine disagrees with interp on request {q}: {got:?} vs {exp:?}"));
            }
        }
        // Measured counts == the arch model's costs for the same plan.
        let st = eng.take_exec_stats();
        if st.ks_ops != (REQUESTS * sim.ks_count) as u64 {
            return Err(format!(
                "measured KS {} != {} requests x sim {}",
                st.ks_ops, REQUESTS, sim.ks_count
            ));
        }
        if st.pbs_ops != (REQUESTS * sim.pbs_count) as u64 {
            return Err(format!(
                "measured PBS {} != {} requests x sim {}",
                st.pbs_ops, REQUESTS, sim.pbs_count
            ));
        }
        if tracing {
            // Stage histogram totals must reconcile with the counters:
            // one keyswitch sample per KS op, one sample-extract sample
            // per PBS (every blind rotation extracts exactly once).
            let stage = eng.take_stage_times();
            if stage.keyswitch.count() != st.ks_ops {
                return Err(format!(
                    "keyswitch histogram holds {} samples, ExecStats counted {}",
                    stage.keyswitch.count(),
                    st.ks_ops
                ));
            }
            if stage.sample_extract.count() != st.pbs_ops {
                return Err(format!(
                    "sample-extract histogram holds {} samples, ExecStats counted {}",
                    stage.sample_extract.count(),
                    st.pbs_ops
                ));
            }
            // Per-schedule-batch drift attribution: on a fault-free run
            // the measured KS/PBS counts must match `arch::sim`'s
            // per-batch predictions exactly, batch by batch.
            let measured = eng.take_batch_profiles();
            let predicted = crate::arch::sim::batch_predictions(&plan.schedule, p, &cfg);
            let rows = crate::obs::drift::attribute(&measured, &predicted);
            if !crate::obs::drift::counts_exact(&rows) {
                return Err(format!(
                    "cost-model drift: measured per-batch KS/PBS diverge from sim: {rows:?}"
                ));
            }
        }

        // --- Noise: every output's decrypted phase error must sit inside
        // the model's prediction.
        let pred_std = report.worst_output_std.max(1e-12);
        for (q, (outs, exp)) in plan_outs.iter().zip(&expected).enumerate() {
            for (j, (ct, &m)) in outs.iter().zip(exp.iter()).enumerate() {
                let phase = ct.decrypt_phase(keys.sk.long_lwe());
                let err = torus_distance(phase, encode(m, p));
                if err > report.boundary {
                    return Err(format!(
                        "request {q} output {j}: error {err:.3e} past boundary {:.3e}",
                        report.boundary
                    ));
                }
                let sigmas = err / pred_std;
                max_err_sigmas = max_err_sigmas.max(sigmas);
                if sigmas > NOISE_SIGMA_GATE {
                    return Err(format!(
                        "request {q} output {j}: error {err:.3e} = {sigmas:.1} predicted sigmas \
                         (model std {pred_std:.3e}, gate {NOISE_SIGMA_GATE})"
                    ));
                }
            }
        }

        // --- Path 2: a 2-shard cluster over the same keys must return the
        // identical ciphertext bits, and its merged metrics must match the
        // model too.
        let mut cluster = Cluster::start(
            prog.clone(),
            keys.server.clone(),
            ClusterOptions {
                shards: 2,
                policy: PlacementPolicy::RoundRobin,
                queue_depth: None,
                coordinator: shard_options(),
                qos: None,
            },
        );
        let pend: Vec<_> = batch
            .iter()
            .enumerate()
            .map(|(i, cts)| cluster.submit(i as u64, cts.clone()))
            .collect::<Result<_, _>>()
            .map_err(|e| format!("cluster submit failed: {e}"))?;
        let cluster_outs: Vec<Vec<LweCiphertext>> = pend
            .iter()
            .map(|r| r.recv())
            .collect::<Result<_, _>>()
            .map_err(|_| "cluster response dropped".to_string())?;
        drop(pend);
        if cluster_outs != plan_outs {
            return Err("cluster output bits differ from the plan engine's".into());
        }
        let merged = cluster.snapshot();
        cluster.shutdown();
        if merged.requests != REQUESTS {
            return Err(format!("cluster served {} of {REQUESTS} requests", merged.requests));
        }
        if merged.ks_executed != (REQUESTS * sim.ks_count) as u64
            || merged.pbs_executed != REQUESTS * sim.pbs_count
        {
            return Err(format!(
                "cluster counters (ks {}, pbs {}) != {} requests x sim (ks {}, pbs {})",
                merged.ks_executed, merged.pbs_executed, REQUESTS, sim.ks_count, sim.pbs_count
            ));
        }
        if tracing {
            // The cluster drains worker stage timings into its merged
            // snapshot: the same histogram<->counter reconciliation must
            // hold across shards, and queue sampling is one per request.
            if merged.stage.keyswitch.count() != merged.ks_executed
                || merged.stage.sample_extract.count() != merged.pbs_executed as u64
                || merged.stage.queue.count() != merged.requests as u64
            {
                return Err(format!(
                    "cluster stage histograms (ks {}, se {}, queue {}) do not reconcile \
                     with counters (ks {}, pbs {}, requests {})",
                    merged.stage.keyswitch.count(),
                    merged.stage.sample_extract.count(),
                    merged.stage.queue.count(),
                    merged.ks_executed,
                    merged.pbs_executed,
                    merged.requests
                ));
            }
        }
        Ok(())
    });
    WidthReport {
        width,
        param_name: p.name,
        cases,
        min_predicted_margin_sigmas: min_margin,
        max_measured_err_sigmas: max_err_sigmas,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn widths_match_functional_sets() {
        assert_eq!(WIDTHS.map(|w| params::select_for_width(w).name), params::FUNCTIONAL_SETS.map(|p| p.name));
    }

    #[test]
    fn random_programs_have_conformant_shape() {
        let mut rng = Rng::new(5);
        for width in WIDTHS {
            let p = params::select_for_width(width);
            for _ in 0..10 {
                let (prog, report, input_domain) = random_program_for(&mut rng, p);
                prog.validate().unwrap();
                assert_eq!(prog.width, width);
                assert_eq!(prog.input_count(), 2);
                assert!(prog.pbs_count() >= 2, "at least one LUT per level");
                assert!(prog.pbs_depth() >= 2, "two dependent schedule levels");
                assert!(report.margin_sigmas >= MIN_MARGIN_SIGMAS);
                let has_biv =
                    prog.nodes.iter().any(|n| matches!(n, crate::ir::Op::BivLut { .. }));
                let expect_domain = if has_biv { 1u64 << (width / 2) } else { 1u64 << width };
                assert_eq!(input_domain, expect_domain, "bivariate cases restrict inputs");
            }
        }
    }

    #[test]
    fn wide_sets_clear_the_margin_gate_on_a_lut_chain() {
        // The static guarantee behind the whole suite: every functional
        // set supports its own width with room to spare on the canonical
        // chain shape (so `random_program_for` cannot exhaust its draws).
        for p in params::FUNCTIONAL_SETS {
            let mut b = ProgramBuilder::new("chain", p.width);
            let mut x = b.input();
            for _ in 0..3 {
                x = b.lut_fn(x, |m| m);
            }
            b.output(x);
            let report = noise::analyze(&b.finish(), p);
            assert!(
                report.margin_sigmas >= MIN_MARGIN_SIGMAS + 0.5,
                "{}: margin {} too tight for its own width",
                p.name,
                report.margin_sigmas
            );
        }
    }
}
