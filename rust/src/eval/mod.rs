//! Evaluation harness: regenerates every table and figure of the paper's
//! evaluation section (§VI). Each function returns a [`Table`] whose rows
//! mirror the paper's layout; `run_all` renders them to stdout and writes
//! CSVs under `results/`. EXPERIMENTS.md records paper-vs-measured.

pub mod conformance;

use std::path::Path;

use crate::arch::{area, bru, memory, sim, xpu, SyncStrategy, TaurusConfig};
use crate::baselines::{cpu_model, gpu_model, DUAL_A5000, DUAL_EPYC_9654, EPYC_7R13};
use crate::compiler::{self, compile};
use crate::params::{self, security};
use crate::util::stats;
use crate::util::table::{fnum, Table};
use crate::workloads;

fn ms(x: f64) -> String {
    fnum(x * 1e3)
}

/// Table I: area and power breakdown.
pub fn table1(cfg: &TaurusConfig) -> Table {
    let mut t = Table::new(
        "Table I — Area and power (TSMC N16 @ 1 GHz)",
        &["Component", "Area (mm^2)", "Power (W)"],
    );
    for c in area::components(cfg) {
        t.row(vec![c.name.to_string(), fnum(c.area_mm2), fnum(c.power_w)]);
    }
    let (ba, bp) = area::bru_subtotal(cfg);
    t.row(vec!["BRU (subtotal)".into(), fnum(ba), fnum(bp)]);
    let (a, p) = area::totals(cfg);
    t.row(vec!["Total".into(), fnum(a), fnum(p)]);
    t
}

/// Table II: wall-clock CPU / GPU / Taurus + speedups, with the paper's
/// numbers alongside.
pub fn table2(cfg: &TaurusConfig) -> Table {
    let mut t = Table::new(
        "Table II — Wall-clock execution time",
        &[
            "Workload",
            "CPU (s)",
            "GPU (s)",
            "Taurus (ms)",
            "vs CPU",
            "vs GPU",
            "paper CPU (s)",
            "paper GPU (s)",
            "paper Taurus (ms)",
        ],
    );
    for w in workloads::all() {
        let prog = (w.build)(1);
        let c = compile(&prog, w.params, cfg.batch_capacity());
        let taurus = sim::simulate(&c, cfg).seconds;
        let cpu = cpu_model::program_seconds(&c, &EPYC_7R13);
        let gpu = if gpu_model::fits(&c, &DUAL_A5000) {
            Some(gpu_model::program_seconds(&c, &DUAL_A5000))
        } else {
            None
        };
        t.row(vec![
            w.name.to_string(),
            fnum(cpu),
            gpu.map(fnum).unwrap_or_else(|| "OOM".into()),
            ms(taurus),
            format!("{}x", fnum(cpu / taurus)),
            gpu.map(|g| format!("{}x", fnum(g / taurus))).unwrap_or_else(|| "-".into()),
            fnum(w.paper_cpu_s),
            w.paper_gpu_s.map(fnum).unwrap_or_else(|| "OOM".into()),
            fnum(w.paper_taurus_ms),
        ]);
    }
    t
}

/// Table III: ASIC area comparison.
pub fn table3(cfg: &TaurusConfig) -> Table {
    let mut t = Table::new(
        "Table III — ASIC area comparison (16 nm scaled)",
        &["Accelerator", "Reported mm^2", "16nm mm^2", "PolyMult/area"],
    );
    for r in area::table3_rows(cfg) {
        t.row(vec![
            r.name.to_string(),
            fnum(r.reported_area_mm2),
            fnum(r.area_16nm_mm2),
            fnum(r.polymult_per_area),
        ]);
    }
    t
}

/// Table IV: Taurus vs the Morphling-XPU variant.
pub fn table4(cfg: &TaurusConfig) -> Table {
    let mut t = Table::new(
        "Table IV — Taurus vs extended-XPU variant",
        &["Workload", "Taurus_XPU (ms)", "Taurus (ms)", "Speedup", "paper speedup"],
    );
    let paper = [6.78, 6.82, 6.83, 6.80, 7.06, 3.20, 6.89];
    let xc = xpu::XpuConfig { base: cfg.clone(), ..Default::default() };
    for (w, paper_sp) in workloads::all().into_iter().zip(paper) {
        let prog = (w.build)(1);
        let c = compile(&prog, w.params, cfg.batch_capacity());
        let taurus = sim::simulate(&c, cfg).seconds;
        let xpu_s = xpu::simulate_xpu(&c, &xc).seconds;
        t.row(vec![
            w.name.to_string(),
            ms(xpu_s),
            ms(taurus),
            format!("{}x", fnum(xpu_s / taurus)),
            format!("{paper_sp}x"),
        ]);
    }
    t
}

/// Fig. 5: 6-bit addition across representations. `measured` values come
/// from actually running the three adders on the native TFHE library at
/// TEST1 scale (examples/integer_adder.rs reports the same numbers);
/// the modeled column scales to the paper's EPYC 7R13 parameter sets.
pub fn fig5() -> Table {
    use crate::ir::interp;
    use crate::tfhe::pbs::{decrypt_message, encrypt_message};
    use crate::tfhe::{SecretKeys, ServerKeys};
    use crate::util::rng::Rng;

    let mut t = Table::new(
        "Fig. 5 — 6-bit integer addition by representation",
        &["Representation", "PBS count", "measured (ms, TEST1-scale)", "modeled EPYC (ms)", "paper (ms)"],
    );
    let mut rng = Rng::new(55);
    let sk = SecretKeys::generate(&params::TEST1, &mut rng);
    let keys = ServerKeys::generate(&sk, &mut rng);

    // (program, inputs, modeled paper params, paper ms)
    let boolean = workloads::adder::boolean_ripple_carry_at(6, params::TEST1.width);
    let radix = workloads::adder::radix_split_adder(6);
    let wide = workloads::adder::wide_adder(params::TEST1.width);
    let bool_inputs: Vec<u64> = (0..6).map(|i| (11u64 >> i) & 1).chain((0..6).map(|i| (22u64 >> i) & 1)).collect();
    let cases: Vec<(&str, &crate::ir::Program, Vec<u64>, f64, f64)> = vec![
        // Boolean gates run at small Boolean-like params: model 11 ms/gate.
        ("Boolean (ripple-carry)", &boolean, bool_inputs, 27.0 * 11.0, 253.0),
        // 5-bit radix: one dependent PBS level at the 5-bit set (~47 ms).
        ("5-bit (radix split)", &radix, vec![3, 1, 6, 2], {
            let c = compile(&radix, &params::TEST2, 48usize);
            cpu_model::program_seconds(&c, &EPYC_7R13) * 1e3
        }, 47.0),
        ("8-bit (single add)", &wide, vec![40, 23], 0.008, 0.008),
    ];
    for (name, prog, inputs, modeled_ms, paper_ms) in cases {
        // Measured: run on the native engine at TEST1 scale when the
        // program's width fits (boolean adder is width 2; the radix/wide
        // adders at 6/8 bits report model numbers only), checking
        // functional correctness against the plaintext interpreter.
        let mut eng = compiler::Engine::new(compiler::NativePbsBackend::new(&keys));
        let mut measured = f64::NAN;
        if prog.width == params::TEST1.width {
            let cts: Vec<_> =
                inputs.iter().map(|&m| encrypt_message(m, &sk, &mut rng)).collect();
            let t0 = std::time::Instant::now();
            let outs = eng.run(prog, &cts);
            measured = t0.elapsed().as_secs_f64() * 1e3;
            let exp = interp::eval(prog, &inputs);
            let got: Vec<u64> = outs.iter().map(|c| decrypt_message(c, &sk)).collect();
            assert_eq!(got, exp, "{name} functional check");
        }
        t.row(vec![
            name.to_string(),
            format!("{}", prog.pbs_count()),
            if measured.is_nan() { "-".into() } else { fnum(measured) },
            fnum(modeled_ms),
            fnum(paper_ms),
        ]);
    }
    t
}

/// Fig. 6: the 128-bit security frontier and per-width parameter points.
pub fn fig6() -> Table {
    let mut t = Table::new(
        "Fig. 6 — 128-bit security frontier (n vs sigma) + width points",
        &["n", "min sigma (frontier)", "", "width", "(n, sigma) for width"],
    );
    let ns = [500usize, 600, 700, 800, 900, 1000, 1100, 1200];
    let widths = [1usize, 2, 4, 6, 8, 10];
    for i in 0..ns.len().max(widths.len()) {
        let (nc, sc) = if i < ns.len() {
            (ns[i].to_string(), format!("{:.3e}", security::min_sigma_for_security(ns[i], 128.0)))
        } else {
            (String::new(), String::new())
        };
        let (wc, pc) = if i < widths.len() {
            let (n, s) = security::width_frontier_point(widths[i], 128.0);
            (widths[i].to_string(), format!("({n}, {s:.3e})"))
        } else {
            (String::new(), String::new())
        };
        t.row(vec![nc, sc, String::new(), wc, pc]);
    }
    t
}

/// Fig. 13a: bandwidth requirement vs cluster count by traffic class.
pub fn fig13a() -> Table {
    let mut t = Table::new(
        "Fig. 13a — Bandwidth vs clusters (GPT-2 params, full batches)",
        &["clusters", "BSK GB/s", "KSK GB/s", "GLWE GB/s", "LWE GB/s", "total GB/s", "fits 819?"],
    );
    for clusters in [2usize, 3, 4, 5, 6, 7, 8] {
        let mut cfg = TaurusConfig::default();
        cfg.clusters = clusters;
        let p = &params::GPT2;
        let cts = cfg.batch_capacity();
        let traffic = memory::batch_traffic(p, &cfg, cts);
        let window_s = (cfg.rr_ciphertexts as f64 * bru::blind_rotate_cycles(p, &cfg))
            .max(traffic.total() as f64 / (cfg.hbm_bw_gbps * 1e9) / cfg.cycle_s())
            * cfg.cycle_s();
        let gbps = |b: u64| b as f64 / window_s / 1e9;
        let total = gbps(traffic.total());
        t.row(vec![
            clusters.to_string(),
            fnum(gbps(traffic.bsk)),
            fnum(gbps(traffic.ksk)),
            fnum(gbps(traffic.glwe)),
            fnum(gbps(traffic.lwe)),
            fnum(total),
            (total <= 819.0).to_string(),
        ]);
    }
    t
}

/// Fig. 13b: throughput / deficit / buffer vs round-robin ciphertexts.
pub fn fig13b() -> Table {
    let mut t = Table::new(
        "Fig. 13b — Round-robin ciphertexts sweep (GPT-2 params)",
        &["rr cts", "throughput (PBS/s)", "bw deficit?", "acc buffer need (KB)"],
    );
    let p = &params::GPT2;
    for rr in [2usize, 4, 6, 8, 10, 12, 16, 20, 24] {
        let mut cfg = TaurusConfig::default();
        cfg.rr_ciphertexts = rr;
        // Buffer sized to the sweep point (the figure couples them).
        let need_kb = rr * memory::acc_bytes_per_ct(p, &cfg) / 1024;
        cfg.acc_buffer_kb = need_kb;
        let tp = sim::steady_state_pbs_per_s(p, &cfg);
        let compute = rr as f64 * bru::blind_rotate_cycles(p, &cfg);
        let traffic = memory::batch_traffic(p, &cfg, cfg.batch_capacity());
        let mem = traffic.total() as f64 / (cfg.hbm_bw_gbps * 1e9) / cfg.cycle_s();
        t.row(vec![
            rr.to_string(),
            fnum(tp),
            (mem > compute).to_string(),
            need_kb.to_string(),
        ]);
    }
    t
}

/// Fig. 14: accumulator buffer size vs runtime + utilization.
pub fn fig14(cfg: &TaurusConfig) -> Table {
    let mut t = Table::new(
        "Fig. 14 — Accumulator buffer size sweep (runtime normalized to 9216 KB)",
        &["buffer KB", "GPT2 runtime x", "GPT2 util %", "DTree runtime x", "DTree util %"],
    );
    let mk = |w: &workloads::Workload, kb: usize| {
        let mut c = cfg.clone();
        c.acc_buffer_kb = kb;
        let prog = (w.build)(1);
        let comp = compile(&prog, w.params, c.batch_capacity());
        sim::simulate(&comp, &c)
    };
    let gpt2 = workloads::by_name("GPT2").unwrap();
    let dt = workloads::by_name("Decision Tree").unwrap();
    let base_g = mk(&gpt2, 9216).seconds;
    let base_d = mk(&dt, 9216).seconds;
    for kb in [2304usize, 4608, 6912, 8448, 9120, 9168, 9216, 12288, 18432] {
        let g = mk(&gpt2, kb);
        let d = mk(&dt, kb);
        t.row(vec![
            kb.to_string(),
            fnum(g.seconds / base_g),
            fnum(g.utilization * 100.0),
            fnum(d.seconds / base_d),
            fnum(d.utilization * 100.0),
        ]);
    }
    t
}

/// Fig. 15: cluster utilization vs input batch size.
pub fn fig15(cfg: &TaurusConfig) -> Table {
    let mut t = Table::new(
        "Fig. 15 — Utilization vs input batch size",
        &["batch", "KNN %", "DTree %", "XGBoost %", "CNN-20 %"],
    );
    let names = ["KNN", "Decision Tree", "XGBoost Reg", "CNN-20 (PTQ)"];
    for batch in [1usize, 2, 4, 8] {
        let mut row = vec![batch.to_string()];
        for n in names {
            let w = workloads::by_name(n).unwrap();
            let prog = (w.build)(batch);
            let c = compile(&prog, w.params, cfg.batch_capacity());
            let r = sim::simulate(&c, cfg);
            row.push(fnum(r.utilization * 100.0));
        }
        t.row(row);
    }
    t
}

/// Fig. 16: normalized speedup over EPYC 7R13 (log-scale data).
pub fn fig16(cfg: &TaurusConfig) -> Table {
    let mut t = Table::new(
        "Fig. 16 — Normalized speedup vs EPYC 7R13",
        &["Workload", "dual EPYC 9654", "Taurus"],
    );
    for w in workloads::all() {
        let prog = (w.build)(1);
        let c = compile(&prog, w.params, cfg.batch_capacity());
        let base = cpu_model::program_seconds(&c, &EPYC_7R13);
        let big = cpu_model::program_seconds(&c, &DUAL_EPYC_9654);
        let taurus = sim::simulate(&c, cfg).seconds;
        t.row(vec![
            w.name.to_string(),
            format!("{}x", fnum(base / big)),
            format!("{}x", fnum(base / taurus)),
        ]);
    }
    t
}

/// Observation 5: full vs grouped synchronization.
pub fn obs5(cfg: &TaurusConfig) -> Table {
    let mut t = Table::new(
        "Obs. 5 — Synchronization strategy (full vs 2 groups)",
        &["Workload", "speedup %", "peak BW full GB/s", "peak BW grouped GB/s"],
    );
    let mut speedups = vec![];
    for w in workloads::all() {
        let prog = (w.build)(1);
        let c = compile(&prog, w.params, cfg.batch_capacity());
        let full = sim::simulate(&c, cfg);
        let mut gcfg = cfg.clone();
        gcfg.sync = SyncStrategy::Grouped(2);
        // Grouped sync schedules per-group batches: the compiler balance-
        // splits each level across the two groups (capped at per-group
        // round-robin capacity).
        let max_width = cpu_model::level_widths(&c).into_iter().max().unwrap_or(1);
        let g_capacity = max_width.div_ceil(2).clamp(1, cfg.batch_capacity() / 2);
        let cg = compile(&prog, w.params, g_capacity);
        let grouped = sim::simulate(&cg, &gcfg);
        let sp = (full.seconds / grouped.seconds - 1.0) * 100.0;
        speedups.push(sp);
        t.row(vec![
            w.name.to_string(),
            fnum(sp),
            fnum(full.peak_bw_gbps),
            fnum(grouped.peak_bw_gbps),
        ]);
    }
    t.row(vec![
        "median / max".into(),
        format!("{} / {}", fnum(stats::median(&speedups)), fnum(stats::percentile(&speedups, 100.0))),
        String::new(),
        String::new(),
    ]);
    t
}

/// §V dedup statistics across workloads.
pub fn dedup(cfg: &TaurusConfig) -> Table {
    let mut t = Table::new(
        "§V — Compiler deduplication (paper: KS-dedup <=47.12%, ACC-dedup 91.54%)",
        &["Workload", "KS before", "KS after", "KS saved %", "ACC storage saved %"],
    );
    for w in workloads::all() {
        let prog = (w.build)(1);
        let c = compile(&prog, w.params, cfg.batch_capacity());
        t.row(vec![
            w.name.to_string(),
            c.ks_dedup.before.to_string(),
            c.ks_dedup.after.to_string(),
            fnum(c.ks_dedup.reduction_pct()),
            fnum(c.acc_dedup.bytes_reduction_pct()),
        ]);
    }
    t
}

/// Design-space ablation (DESIGN.md: dedup + round-robin contributions).
pub fn ablation(cfg: &TaurusConfig) -> Table {
    let mut t = Table::new(
        "Ablation — KS-dedup on/off (XGBoost, fanout-rich) and RR depth (GPT-2)",
        &["config", "KS ops", "Taurus (ms)"],
    );
    let w = workloads::by_name("XGBoost Reg").unwrap();
    let prog = (w.build)(1);
    for (name, dedup_on) in [("XGBoost with KS-dedup", true), ("XGBoost without KS-dedup", false)] {
        let opts = compiler::CompileOpts { batch_capacity: cfg.batch_capacity(), ks_dedup: dedup_on };
        let c = compiler::compile(&prog, w.params, opts);
        let r = sim::simulate(&c, cfg);
        t.row(vec![
            name.to_string(),
            c.graph.count(compiler::PrimKind::is_keyswitch).to_string(),
            ms(r.seconds),
        ]);
    }
    // Round-robin ablation: rr = 1 disables BSK reuse across ciphertexts
    // (the Taurus design principle of §III-B).
    let w = workloads::by_name("GPT2").unwrap();
    let prog = (w.build)(1);
    for (name, rr) in [("GPT-2 rr=12 (default)", 12usize), ("GPT-2 rr=1 (no BSK reuse)", 1)] {
        let mut c2 = cfg.clone();
        c2.rr_ciphertexts = rr;
        let c = compiler::compile(&prog, w.params, c2.batch_capacity());
        let r = sim::simulate(&c, &c2);
        t.row(vec![
            name.to_string(),
            c.graph.count(compiler::PrimKind::is_keyswitch).to_string(),
            ms(r.seconds),
        ]);
    }
    t
}

/// Run one experiment by id ("1".."4" tables, "5","6","13a".."16" figures,
/// "obs5", "dedup", "ablation"); None = unknown id.
pub fn run_one(id: &str, cfg: &TaurusConfig) -> Option<Table> {
    Some(match id {
        "1" | "t1" => table1(cfg),
        "2" | "t2" => table2(cfg),
        "3" | "t3" => table3(cfg),
        "4" | "t4" => table4(cfg),
        "5" | "fig5" => fig5(),
        "6" | "fig6" => fig6(),
        "13a" => fig13a(),
        "13b" => fig13b(),
        "14" => fig14(cfg),
        "15" => fig15(cfg),
        "16" => fig16(cfg),
        "obs5" => obs5(cfg),
        "dedup" => dedup(cfg),
        "ablation" => ablation(cfg),
        _ => return None,
    })
}

pub const ALL_IDS: [&str; 14] =
    ["1", "2", "3", "4", "5", "6", "13a", "13b", "14", "15", "16", "obs5", "dedup", "ablation"];

/// Regenerate everything; writes CSVs to `out_dir` and returns the report.
pub fn run_all(cfg: &TaurusConfig, out_dir: &Path) -> String {
    let mut report = String::new();
    for id in ALL_IDS {
        let t = run_one(id, cfg).unwrap();
        report.push_str(&t.render());
        report.push('\n');
        let fname = format!("{}.csv", id.replace(' ', "_"));
        let _ = t.write_csv(out_dir.join(fname));
    }
    let _ = std::fs::write(out_dir.join("report.txt"), &report);
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_experiments_produce_rows() {
        let cfg = TaurusConfig::default();
        for id in ["1", "3", "6", "13a", "13b"] {
            let t = run_one(id, &cfg).unwrap();
            assert!(!t.rows.is_empty(), "{id}");
        }
        assert!(run_one("nope", &cfg).is_none());
    }

    #[test]
    fn fig15_knn_reaches_75pct_at_batch_8() {
        // Observation 7 / Fig. 15 headline: "KNN reaching 75% utilization
        // at batch size 8", with utilization monotonically rising.
        let cfg = TaurusConfig::default();
        let w = workloads::by_name("KNN").unwrap();
        let mut last = 0.0;
        for batch in [1usize, 2, 4, 8] {
            let c = compile(&(w.build)(batch), w.params, cfg.batch_capacity());
            let u = sim::simulate(&c, &cfg).utilization;
            assert!(u >= last - 1e-9, "batch {batch}: util {u} dropped");
            last = u;
        }
        assert!(
            (0.65..0.9).contains(&last),
            "KNN batch-8 utilization {last} (paper: 75%)"
        );
    }

    #[test]
    fn table2_speedups_have_paper_shape() {
        // Taurus wins every row; the win is larger on high-bitwidth rows.
        let cfg = TaurusConfig::default();
        let mut speedups = std::collections::HashMap::new();
        for w in workloads::all() {
            if w.name.contains("12-head") {
                continue; // keep the test fast
            }
            let prog = (w.build)(1);
            let c = compile(&prog, w.params, cfg.batch_capacity());
            let taurus = sim::simulate(&c, &cfg).seconds;
            let cpu = cpu_model::program_seconds(&c, &EPYC_7R13);
            speedups.insert(w.name, cpu / taurus);
        }
        for (name, s) in &speedups {
            assert!(*s > 50.0, "{name}: speedup {s} too small");
            assert!(*s < 10000.0, "{name}: speedup {s} absurd");
        }
        assert!(
            speedups["XGBoost Reg"] > speedups["CNN-20 (PTQ)"],
            "high-width speedups dominate (paper: 2601x vs 331x)"
        );
    }
}
