//! Minimal `anyhow`-compatible error handling (the offline registry has no
//! `anyhow`; see the module doc in [`crate::util`]). Provides the subset
//! this crate uses: a string-message [`Error`], a defaulted [`Result`]
//! alias, the [`anyhow!`]/[`bail!`](crate::bail) macros, and a [`Context`]
//! extension trait for `Result`/`Option`.
//!
//! [`anyhow!`]: crate::anyhow

use std::fmt;

/// A boxed error message with optional context prefixes.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(m: impl fmt::Display) -> Self {
        Self { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// NB: `Error` deliberately does NOT implement `std::error::Error`, so this
// blanket conversion (which makes `?` work on io/parse/channel errors)
// cannot overlap the reflexive `From<Error> for Error`.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Self::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow::Context` equivalent: annotate errors with what was being done.
pub trait Context<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T>;
    fn with_context<D: fmt::Display, F: FnOnce() -> D>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{msg}: {e}")))
    }

    fn with_context<D: fmt::Display, F: FnOnce() -> D>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg))
    }

    fn with_context<D: fmt::Display, F: FnOnce() -> D>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Format an [`Error`] from a message, `format!`-style (goes through
/// `format_args!` so plain-literal calls don't trip clippy's
/// `useless_format` at every expansion site).
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::err::Error::msg(::std::fmt::format(::std::format_args!($($arg)*)))
    };
}

/// Early-return an error, `format!`-style.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parses(s: &str) -> Result<u32> {
        let n: u32 = s.parse()?; // From<ParseIntError> via the blanket impl
        if n > 100 {
            bail!("{n} too large");
        }
        Ok(n)
    }

    #[test]
    fn question_mark_and_bail() {
        assert_eq!(parses("7").unwrap(), 7);
        assert!(parses("x").is_err());
        assert_eq!(parses("200").unwrap_err().to_string(), "200 too large");
    }

    #[test]
    fn context_chains_messages() {
        let r: Result<()> = Err(crate::anyhow!("inner")).context("outer");
        assert_eq!(r.unwrap_err().to_string(), "outer: inner");
        let o: Option<u8> = None;
        assert_eq!(o.with_context(|| "missing").unwrap_err().to_string(), "missing");
    }
}
