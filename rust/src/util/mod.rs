//! Small dependency-free utilities.
//!
//! The build image has no network access and no usable cargo registry, so
//! the conventional crates (serde/rand/criterion/proptest/clap/anyhow) are
//! unavailable and the default build carries **zero** external
//! dependencies (the `xla` crate is opt-in via the `xla` feature). These
//! modules provide the minimal equivalents the rest of the crate needs;
//! see DESIGN.md §Substitutions.

pub mod err;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
