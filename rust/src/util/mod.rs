//! Small dependency-free utilities.
//!
//! The build image has no network access and its cargo registry cache only
//! contains the `xla` crate's dependency closure, so the conventional crates
//! (serde/rand/criterion/proptest/clap) are unavailable. These modules
//! provide the minimal equivalents the rest of the crate needs; see
//! DESIGN.md §Substitutions.

pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
