//! Tiny statistics helpers used by benches, the coordinator's metrics and
//! the eval harness.

use crate::util::rng::Rng;

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile with linear interpolation on a sorted copy (p in [0, 100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = (p / 100.0) * (v.len() as f64 - 1.0);
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    v[lo] * (1.0 - frac) + v[hi.min(v.len() - 1)] * frac
}

/// Geometric mean of strictly positive values.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Median.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Jain's fairness index: `(Σx)² / (n·Σx²)`, over per-tenant allocations
/// (throughputs, admitted counts, inverse latencies — any "bigger is
/// better" share). Ranges from `1/n` (one tenant gets everything) to
/// `1.0` (perfectly equal); scale-invariant, so absolute load level
/// doesn't matter. Empty or all-zero input reports 1.0 — nobody is being
/// treated unfairly when nothing is allocated.
pub fn jains_index(xs: &[f64]) -> f64 {
    let sum: f64 = xs.iter().sum();
    let sum_sq: f64 = xs.iter().map(|x| x * x).sum();
    if sum_sq == 0.0 {
        return 1.0;
    }
    (sum * sum) / (xs.len() as f64 * sum_sq)
}

/// A bounded, seed-deterministic uniform sample of an unbounded stream
/// (Algorithm R). Below the capacity it holds *every* pushed value in
/// arrival order — so consumers that merge/percentile over small runs see
/// exactly the raw samples — and past it each of the `seen` values has
/// equal probability `cap / seen` of being retained, in O(cap) memory.
/// Determinism comes from the owned [`Rng`]: same seed + same stream,
/// same retained sample.
#[derive(Debug, Clone)]
pub struct Reservoir {
    cap: usize,
    seen: u64,
    rng: Rng,
    samples: Vec<f64>,
}

impl Reservoir {
    pub fn new(cap: usize, seed: u64) -> Self {
        assert!(cap > 0, "reservoir capacity must be positive");
        Self { cap, seen: 0, rng: Rng::new(seed), samples: Vec::new() }
    }

    pub fn push(&mut self, v: f64) {
        self.seen += 1;
        if self.samples.len() < self.cap {
            self.samples.push(v);
            return;
        }
        let j = self.rng.below(self.seen);
        if (j as usize) < self.cap {
            self.samples[j as usize] = v;
        }
    }

    /// The retained sample (every value, in order, while below capacity).
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    /// Values pushed over the reservoir's lifetime (not the retained
    /// count — see [`Self::len`]).
    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// Retained values (== `seen` until the cap binds, then == cap).
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((stddev(&xs) - 1.118).abs() < 1e-3);
        assert_eq!(median(&xs), 2.5);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_is_zero() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn percentile_edge_cases() {
        // Empty slice: every percentile is 0.
        for p in [0.0, 50.0, 99.0, 100.0] {
            assert_eq!(percentile(&[], p), 0.0);
        }
        // Single sample: every percentile is that sample.
        for p in [0.0, 1.0, 50.0, 99.0, 100.0] {
            assert_eq!(percentile(&[42.0], p), 42.0);
        }
        // Duplicate-heavy input: interpolation stays on the plateau and
        // only the extreme tail reaches the outlier.
        let mut xs = vec![5.0; 99];
        xs.push(1000.0);
        assert_eq!(percentile(&xs, 0.0), 5.0);
        assert_eq!(percentile(&xs, 50.0), 5.0);
        assert_eq!(percentile(&xs, 98.0), 5.0);
        assert_eq!(percentile(&xs, 100.0), 1000.0);
        // Input order must not matter (sorted copy inside).
        let fwd = [3.0, 1.0, 2.0];
        let rev = [2.0, 1.0, 3.0];
        assert_eq!(percentile(&fwd, 50.0), 2.0);
        assert_eq!(percentile(&rev, 50.0), 2.0);
    }

    #[test]
    fn jains_index_spans_equal_to_one_hot() {
        assert_eq!(jains_index(&[]), 1.0);
        assert_eq!(jains_index(&[0.0, 0.0]), 1.0);
        assert!((jains_index(&[5.0, 5.0, 5.0, 5.0]) - 1.0).abs() < 1e-12);
        // One tenant hogging everything: J = 1/n.
        assert!((jains_index(&[12.0, 0.0, 0.0, 0.0]) - 0.25).abs() < 1e-12);
        // Scale invariance.
        let a = jains_index(&[1.0, 2.0, 3.0]);
        let b = jains_index(&[10.0, 20.0, 30.0]);
        assert!((a - b).abs() < 1e-12);
        // Mild skew sits strictly between the extremes.
        assert!(a > 1.0 / 3.0 && a < 1.0);
    }

    #[test]
    fn reservoir_exact_below_cap_and_bounded_above() {
        let mut r = Reservoir::new(8, 1);
        for i in 0..8 {
            r.push(i as f64);
        }
        let exact: Vec<f64> = (0..8).map(|i| i as f64).collect();
        assert_eq!(r.samples(), &exact[..], "below the cap the sample is the stream");
        for i in 8..1000 {
            r.push(i as f64);
        }
        assert_eq!(r.len(), 8, "capacity binds");
        assert_eq!(r.seen(), 1000);
        assert!(r.samples().iter().all(|&v| (0.0..1000.0).contains(&v)));
    }

    #[test]
    fn reservoir_is_seed_deterministic() {
        let mut a = Reservoir::new(16, 7);
        let mut b = Reservoir::new(16, 7);
        let mut c = Reservoir::new(16, 8);
        for i in 0..5000 {
            let v = (i * 37 % 101) as f64;
            a.push(v);
            b.push(v);
            c.push(v);
        }
        assert_eq!(a.samples(), b.samples(), "same seed, same retained sample");
        assert_ne!(a.samples(), c.samples(), "different seed draws differently");
    }
}
