//! Tiny statistics helpers used by benches, the coordinator's metrics and
//! the eval harness.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile with linear interpolation on a sorted copy (p in [0, 100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = (p / 100.0) * (v.len() as f64 - 1.0);
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    v[lo] * (1.0 - frac) + v[hi.min(v.len() - 1)] * frac
}

/// Geometric mean of strictly positive values.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Median.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((stddev(&xs) - 1.118).abs() < 1e-3);
        assert_eq!(median(&xs), 2.5);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_is_zero() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(geomean(&[]), 0.0);
    }
}
