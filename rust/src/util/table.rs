//! ASCII table rendering + CSV writing for the eval harness, matching the
//! row/column layout of the paper's tables so paper-vs-measured comparison
//! is eyeball-able.

use std::fmt::Write as _;
use std::path::Path;

/// A simple column-aligned table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Self {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "== {} ==", self.title);
        }
        let line = |out: &mut String, cells: &[String]| {
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(out, "{:<w$}", c, w = widths[i] + if i + 1 < ncol { 2 } else { 0 });
            }
            out.push('\n');
        };
        line(&mut out, &self.headers);
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        let _ = writeln!(out, "{}", "-".repeat(total));
        for r in &self.rows {
            line(&mut out, r);
        }
        out
    }

    /// Write as CSV (RFC-4180-ish quoting).
    pub fn write_csv(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        let mut s = String::new();
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') || c.contains('\n') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        s.push_str(&self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        s.push('\n');
        for r in &self.rows {
            s.push_str(&r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            s.push('\n');
        }
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, s)
    }
}

/// Format a float with engineering-friendly precision.
pub fn fnum(x: f64) -> String {
    if x == 0.0 {
        "0".to_string()
    } else if x.abs() >= 1000.0 {
        format!("{:.0}", x)
    } else if x.abs() >= 10.0 {
        format!("{:.2}", x)
    } else if x.abs() >= 0.01 {
        format!("{:.3}", x)
    } else {
        format!("{:.3e}", x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns() {
        let mut t = Table::new("T", &["a", "bbbb"]);
        t.row(vec!["xxxx".into(), "y".into()]);
        let r = t.render();
        assert!(r.contains("== T =="));
        assert!(r.lines().count() >= 3);
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn csv_quoting() {
        let mut t = Table::new("", &["a,b", "c"]);
        t.row(vec!["x\"y".into(), "z".into()]);
        let dir = std::env::temp_dir().join("taurus_csv_test");
        let p = dir.join("t.csv");
        t.write_csv(&p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert!(s.starts_with("\"a,b\",c\n"));
        assert!(s.contains("\"x\"\"y\",z"));
    }

    #[test]
    fn fnum_ranges() {
        assert_eq!(fnum(0.0), "0");
        assert_eq!(fnum(1234.5), "1234");
        assert_eq!(fnum(12.345), "12.35");
        assert_eq!(fnum(0.5), "0.500");
        assert!(fnum(1e-5).contains('e'));
    }
}
