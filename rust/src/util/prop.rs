//! Miniature property-testing harness (proptest is unavailable offline).
//!
//! `check(name, cases, |rng| ...)` runs a closure over `cases` seeded
//! inputs; on failure it reports the failing seed so the case can be
//! replayed deterministically with `replay(seed, f)`.

use super::rng::Rng;

/// Run `f` for `cases` random cases. `f` gets a fresh deterministic RNG per
/// case and returns `Err(msg)` to signal a counterexample.
pub fn check<F>(name: &str, cases: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    // Derive per-case seeds from the property name so adding properties
    // doesn't perturb others.
    let base = name.bytes().fold(0xcbf29ce484222325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100000001b3)
    });
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9e3779b97f4a7c15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property `{name}` failed on case {case} (replay seed {seed:#x}): {msg}");
        }
    }
}

/// Replay a single failing case by seed.
pub fn replay<F>(seed: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    if let Err(msg) = f(&mut rng) {
        panic!("replay {seed:#x} failed: {msg}");
    }
}

/// Assert two f64 slices are elementwise close.
pub fn assert_allclose(a: &[f64], b: &[f64], atol: f64, rtol: f64) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs();
        if (x - y).abs() > tol {
            return Err(format!("index {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("trivial", 25, |rng| {
            n += 1;
            let x = rng.below(100);
            if x < 100 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
        assert_eq!(n, 25);
    }

    #[test]
    #[should_panic(expected = "property `fails`")]
    fn failing_property_panics_with_seed() {
        check("fails", 5, |_| Err("nope".into()));
    }

    #[test]
    fn allclose_tolerances() {
        assert!(assert_allclose(&[1.0], &[1.0 + 1e-9], 1e-8, 0.0).is_ok());
        assert!(assert_allclose(&[1.0], &[1.1], 1e-8, 0.0).is_err());
        assert!(assert_allclose(&[100.0], &[100.5], 0.0, 0.01).is_ok());
        assert!(assert_allclose(&[1.0, 2.0], &[1.0], 0.1, 0.0).is_err());
    }
}
