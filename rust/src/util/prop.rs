//! Miniature property-testing harness (proptest is unavailable offline).
//!
//! `check(name, cases, |rng| ...)` runs a closure over `cases` seeded
//! inputs. Every case gets its own **forked** RNG: the per-case seed is
//! derived from the property name and the case index alone, so a case
//! consuming more or fewer draws never perturbs any other case, and
//! adding properties never reshuffles existing ones.
//!
//! ## Case-count override (`PROP_CASES`)
//!
//! The environment variable `PROP_CASES` overrides the requested case
//! count for every `check` in the process. This is how the wide-width
//! conformance suite stays cheap in CI but deep locally:
//!
//! ```text
//! PROP_CASES=2  cargo test -q --test conformance_widths   # CI budget
//! PROP_CASES=50 cargo test -q --test conformance_widths   # local soak
//! ```
//!
//! ## Replaying a failure
//!
//! On failure the panic message names the case index and its seed:
//!
//! ```text
//! property `conformance_w8` failed on case 37 (replay seed 0x9e3779...):
//! ```
//!
//! Re-run just that input by passing the printed seed to
//! [`replay`] from any test or scratch `#[test]` fn:
//!
//! ```ignore
//! util::prop::replay(0x9e3779_u64, |rng| my_property(rng));
//! ```
//!
//! The seed fully determines the case (same forked RNG stream), so the
//! reproduction is exact regardless of `PROP_CASES` or which other
//! properties ran.

use super::rng::Rng;

/// Effective case count: `PROP_CASES` (when set to a positive integer)
/// overrides the caller's default. See the module doc for the workflow.
pub fn cases(default: u64) -> u64 {
    cases_from(std::env::var("PROP_CASES").ok().as_deref(), default)
}

/// Pure core of [`cases`], split out for testability: parse an optional
/// `PROP_CASES` value, falling back to `default` when unset or invalid.
fn cases_from(env: Option<&str>, default: u64) -> u64 {
    env.and_then(|v| v.trim().parse::<u64>().ok())
        .filter(|&c| c > 0)
        .unwrap_or(default)
}

/// Run `f` for up to `requested` random cases (`PROP_CASES` overrides the
/// count, see module doc). `f` gets a fresh deterministic forked RNG per
/// case and returns `Err(msg)` to signal a counterexample.
pub fn check<F>(name: &str, requested: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    // Derive per-case seeds from the property name so adding properties
    // doesn't perturb others.
    let base = name.bytes().fold(0xcbf29ce484222325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100000001b3)
    });
    for case in 0..cases(requested) {
        let seed = base.wrapping_add(case.wrapping_mul(0x9e3779b97f4a7c15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property `{name}` failed on case {case} (replay seed {seed:#x}): {msg}");
        }
    }
}

/// Replay a single failing case by seed.
pub fn replay<F>(seed: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    if let Err(msg) = f(&mut rng) {
        panic!("replay {seed:#x} failed: {msg}");
    }
}

/// Assert two f64 slices are elementwise close.
pub fn assert_allclose(a: &[f64], b: &[f64], atol: f64, rtol: f64) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs();
        if (x - y).abs() > tol {
            return Err(format!("index {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let expected = cases(25); // honors a PROP_CASES override
        let mut n = 0;
        check("trivial", 25, |rng| {
            n += 1;
            let x = rng.below(100);
            if x < 100 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
        assert_eq!(n, expected);
    }

    #[test]
    #[should_panic(expected = "property `fails`")]
    fn failing_property_panics_with_seed() {
        check("fails", 5, |_| Err("nope".into()));
    }

    #[test]
    fn cases_override_parses_and_falls_back() {
        assert_eq!(cases_from(None, 25), 25);
        assert_eq!(cases_from(Some("2"), 25), 2);
        assert_eq!(cases_from(Some(" 50 "), 25), 50);
        // Invalid or zero values fall back to the default.
        assert_eq!(cases_from(Some("lots"), 25), 25);
        assert_eq!(cases_from(Some("0"), 25), 25);
        assert_eq!(cases_from(Some(""), 25), 25);
    }

    #[test]
    fn allclose_tolerances() {
        assert!(assert_allclose(&[1.0], &[1.0 + 1e-9], 1e-8, 0.0).is_ok());
        assert!(assert_allclose(&[1.0], &[1.1], 1e-8, 0.0).is_err());
        assert!(assert_allclose(&[100.0], &[100.5], 0.0, 0.01).is_ok());
        assert!(assert_allclose(&[1.0, 2.0], &[1.0], 0.1, 0.0).is_err());
    }
}
