//! Minimal JSON reader/writer (enough for artifact manifests and results
//! files; no serde in the offline registry).

use crate::util::err::Result;
use crate::{anyhow, bail};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum JsonValue {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Array(Vec<JsonValue>),
    Object(BTreeMap<String, JsonValue>),
}

impl JsonValue {
    pub fn parse(text: &str) -> Result<JsonValue> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        match self {
            JsonValue::Object(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[JsonValue]> {
        match self {
            JsonValue::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            JsonValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Serialize back to compact JSON text.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            JsonValue::Null => out.push_str("null"),
            JsonValue::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            JsonValue::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            JsonValue::Str(s) => write_json_string(s, out),
            JsonValue::Array(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            JsonValue::Object(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_json_string(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_json_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected `{}` at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, word: &str, v: JsonValue) -> Result<JsonValue> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<JsonValue> {
        self.ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(JsonValue::Str(self.string()?)),
            b't' => self.lit("true", JsonValue::Bool(true)),
            b'f' => self.lit("false", JsonValue::Bool(false)),
            b'n' => self.lit("null", JsonValue::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<JsonValue> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(JsonValue::Object(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(JsonValue::Object(m));
                }
                c => bail!("expected , or }} got `{}` at byte {}", c as char, self.i),
            }
        }
    }

    fn array(&mut self) -> Result<JsonValue> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(JsonValue::Array(a));
        }
        loop {
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(JsonValue::Array(a));
                }
                c => bail!("expected , or ] got `{}` at byte {}", c as char, self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // Collect the full UTF-8 sequence.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = if c >= 0xf0 {
                            4
                        } else if c >= 0xe0 {
                            3
                        } else {
                            2
                        };
                        let chunk = std::str::from_utf8(&self.b[start..start + len])?;
                        s.push_str(chunk);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<JsonValue> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(JsonValue::Num(s.parse::<f64>()?))
    }
}

/// Builder-style helpers for emitting result JSON.
pub fn obj(pairs: Vec<(&str, JsonValue)>) -> JsonValue {
    JsonValue::Object(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr(vals: Vec<JsonValue>) -> JsonValue {
    JsonValue::Array(vals)
}

pub fn num(n: f64) -> JsonValue {
    JsonValue::Num(n)
}

pub fn s(v: impl Into<String>) -> JsonValue {
    JsonValue::Str(v.into())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let text = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": 2.5}}"#;
        let v = JsonValue::parse(text).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("b").unwrap().as_array().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(2.5));
        let re = JsonValue::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn parse_nested_arrays_and_negatives() {
        let v = JsonValue::parse("[-1.5e3, [2, [3]]]").unwrap();
        let a = v.as_array().unwrap();
        assert_eq!(a[0].as_f64(), Some(-1500.0));
    }

    #[test]
    fn rejects_garbage() {
        assert!(JsonValue::parse("{oops}").is_err());
        assert!(JsonValue::parse("[1,]2").is_err());
    }

    #[test]
    fn unicode_string() {
        let v = JsonValue::parse(r#""café ≈""#).unwrap();
        assert_eq!(v.as_str(), Some("café ≈"));
    }
}
