//! Deterministic PRNG (xoshiro256++) + Gaussian sampling.
//!
//! NOT a CSPRNG — this reproduction studies noise *statistics* and system
//! behaviour, not real-world key secrecy (DESIGN.md §Substitutions). The
//! generator is seedable so every test/experiment is reproducible.

/// xoshiro256++ seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box-Muller output.
    spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the xoshiro state.
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()], spare: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let [s0, s1, s2, s3] = self.s;
        let result = s0.wrapping_add(s3).rotate_left(23).wrapping_add(s0);
        let t = s1 << 17;
        let mut s = [s0, s1, s2, s3];
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        self.s = s;
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, bound).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Lemire-style multiply-shift rejection.
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= lo.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform `usize` in [0, bound).
    #[inline]
    pub fn below_usize(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn gaussian(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (sin, cos) = (std::f64::consts::TAU * u2).sin_cos();
            self.spare = Some(r * sin);
            return r * cos;
        }
    }

    /// Torus-valued Gaussian noise: round(N(0, sigma) * 2^64) as wrapping u64,
    /// where `sigma` is the noise standard deviation as a fraction of the
    /// torus (i.e. in [0,1)).
    pub fn torus_gaussian(&mut self, sigma: f64) -> u64 {
        let x = self.gaussian() * sigma;
        // Map real -> torus by scaling to 2^64 and wrapping.
        let scaled = x * 18446744073709551616.0; // 2^64
        (scaled.round() as i64) as u64
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_nontrivial() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_eq!(va, vb);
        assert!(va.windows(2).any(|w| w[0] != w[1]));
        let mut c = Rng::new(43);
        assert_ne!(va[0], c.next_u64());
    }

    #[test]
    fn uniform_in_range_and_mean() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(9);
        for bound in [1u64, 2, 3, 10, 48, 1 << 40] {
            for _ in 0..200 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn torus_gaussian_small_sigma_stays_small() {
        let mut r = Rng::new(13);
        let sigma = 2.0f64.powi(-40);
        for _ in 0..1000 {
            let e = r.torus_gaussian(sigma) as i64;
            // |e| should be well below 2^30 for sigma = 2^-40 (2^24 * 64 sigma).
            assert!((e.unsigned_abs() as f64) < 2.0f64.powi(30));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
