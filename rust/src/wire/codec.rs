//! Versioned binary serialization for ciphertexts and server keys.
//!
//! Everything is little-endian, length-prefixed, and decoded through
//! [`Reader`] — a bounds-checked cursor whose every failure is a typed
//! [`WireError`], never a panic. `f64` planes travel as IEEE-754 bit
//! patterns (`to_bits`/`from_bits`), so encode→decode is **bitwise**
//! identity — the same oracle `tfhe::server_keys_bitwise_eq` uses.
//!
//! Key material is big (tens to hundreds of MB at the wide widths — see
//! EXPERIMENTS.md §Widths), so it never travels as one blob. A transfer
//! is a [key header](write_key_header) naming the parameter set, followed
//! by self-delimiting **chunks**:
//!
//! | kind | payload |
//! |------|---------|
//! | `0`  | BSK GGSW run: `start u32, count u32`, then `count` × (re plane, im plane) |
//! | `1`  | KSK row run: `start u32, count u32`, then `count × ks_level × (n+1)` words |
//!
//! Plane and row shapes are *derived from the named parameter set*, never
//! read from the wire, so a hostile chunk cannot cause an oversized
//! allocation: [`KeyAssembly`] pre-allocates the exact final layout once
//! and chunks only fill it. This is the row-granular layout
//! `ServerKeys::generate_seeded` produces, reused across the socket: the
//! sender walks its resident keys run by run, the receiver assembles
//! incrementally, and a WIDE10 key set is never resident twice on either
//! side.

use crate::params::{self, ParamSet};
use crate::tfhe::{FourierBsk, FourierGgsw, Ksk, LweCiphertext, ServerKeys};

use super::WireError;

/// Version byte of everything this module writes. Bump on any layout
/// change; decoders reject other versions typed
/// ([`WireError::UnsupportedVersion`]).
pub const CODEC_VERSION: u8 = 1;

/// Leading magic of a key-transfer header.
pub const KEY_MAGIC: [u8; 4] = *b"TAUK";

/// Hard bound on one ciphertext's word count (mask + body). The largest
/// shipped parameter set (WIDE10, k·N = 4096) sits orders of magnitude
/// below this; a hostile length prefix above it is rejected *before* any
/// allocation.
pub const MAX_CT_WORDS: usize = 1 << 20;

/// Default chunk payload target: large enough that a WIDE10 BSK moves in
/// ~100 frames, small enough that neither side buffers more than ~2 MiB
/// of transient chunk data (and every chunk fits [`super::MAX_FRAME`]).
pub const DEFAULT_CHUNK_BYTES: usize = 2 << 20;

// ---------------------------------------------------------------------------
// Primitives.
// ---------------------------------------------------------------------------

/// Bounds-checked little-endian cursor over a received buffer. All reads
/// fail typed on truncation; nothing here allocates from wire-controlled
/// lengths.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.remaining() < n {
            return Err(WireError::Malformed(format!(
                "truncated: wanted {n} bytes, {} left",
                self.remaining()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    pub fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    pub fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Fill a pre-allocated `u64` slice (KSK rows).
    pub fn fill_u64(&mut self, dst: &mut [u64]) -> Result<(), WireError> {
        let raw = self.take(dst.len() * 8)?;
        for (d, s) in dst.iter_mut().zip(raw.chunks_exact(8)) {
            *d = u64::from_le_bytes(s.try_into().expect("8 bytes"));
        }
        Ok(())
    }

    /// Fill a pre-allocated `f64` slice (Fourier planes), bitwise.
    pub fn fill_f64(&mut self, dst: &mut [f64]) -> Result<(), WireError> {
        let raw = self.take(dst.len() * 8)?;
        for (d, s) in dst.iter_mut().zip(raw.chunks_exact(8)) {
            *d = f64::from_bits(u64::from_le_bytes(s.try_into().expect("8 bytes")));
        }
        Ok(())
    }

    /// A length-prefixed short string (parameter-set names).
    pub fn short_str(&mut self) -> Result<String, WireError> {
        let len = self.u8()? as usize;
        let raw = self.take(len)?;
        String::from_utf8(raw.to_vec())
            .map_err(|_| WireError::Malformed("non-utf8 name".into()))
    }

    /// A u32-length-prefixed string (status reasons). The length is
    /// bounded by the frame the buffer came from; truncation fails typed.
    pub fn string(&mut self) -> Result<String, WireError> {
        let len = self.u32()? as usize;
        let raw = self.take(len)?;
        String::from_utf8(raw.to_vec())
            .map_err(|_| WireError::Malformed("non-utf8 string".into()))
    }

    /// Everything not yet consumed (a KEY_CHUNK frame's chunk payload).
    pub fn rest(&mut self) -> &'a [u8] {
        let s = &self.buf[self.pos..];
        self.pos = self.buf.len();
        s
    }

    /// Assert the buffer is fully consumed — trailing bytes are malformed
    /// input, not padding.
    pub fn expect_eof(&self) -> Result<(), WireError> {
        if self.remaining() != 0 {
            return Err(WireError::Malformed(format!(
                "{} trailing bytes after payload",
                self.remaining()
            )));
        }
        Ok(())
    }
}

pub fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_bits().to_le_bytes());
}

pub fn put_short_str(out: &mut Vec<u8>, s: &str) {
    debug_assert!(s.len() <= u8::MAX as usize, "short strings only");
    out.push(s.len() as u8);
    out.extend_from_slice(s.as_bytes());
}

pub fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

// ---------------------------------------------------------------------------
// Ciphertexts.
// ---------------------------------------------------------------------------

/// `word_count u32, words…` — the full LWE vector (mask + body).
pub fn write_ciphertext(out: &mut Vec<u8>, ct: &LweCiphertext) {
    put_u32(out, ct.data.len() as u32);
    for &w in &ct.data {
        put_u64(out, w);
    }
}

pub fn read_ciphertext(r: &mut Reader) -> Result<LweCiphertext, WireError> {
    let words = r.u32()? as usize;
    if words > MAX_CT_WORDS {
        return Err(WireError::TooLarge { len: words, max: MAX_CT_WORDS });
    }
    if words < 2 {
        return Err(WireError::Malformed(format!(
            "ciphertext of {words} words (needs at least one mask word and the body)"
        )));
    }
    let mut data = vec![0u64; words];
    r.fill_u64(&mut data)?;
    Ok(LweCiphertext { data })
}

/// `count u32`, then `count` ciphertexts.
pub fn write_ciphertexts(out: &mut Vec<u8>, cts: &[LweCiphertext]) {
    put_u32(out, cts.len() as u32);
    for ct in cts {
        write_ciphertext(out, ct);
    }
}

pub fn read_ciphertexts(r: &mut Reader) -> Result<Vec<LweCiphertext>, WireError> {
    let count = r.u32()? as usize;
    // No allocation from `count` alone: grown element by element, each
    // element bounded, and truncation fails on the first short read.
    let mut out = Vec::new();
    for _ in 0..count {
        out.push(read_ciphertext(r)?);
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Key transfer: header + chunks.
// ---------------------------------------------------------------------------

/// Shape of one key transfer, derived from a parameter set. Both sides
/// compute it from the set named in the header; the redundant copy *on*
/// the wire is validated against the derivation, so a header claiming
/// `test1` with WIDE8 shapes is malformed, not trusted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct KeyShape {
    /// BSK GGSW count (= n).
    ggsws: usize,
    /// f64s per GGSW plane: rows × (k+1) × N/2.
    plane_len: usize,
    /// KSK row count (= k·N).
    ksk_rows: usize,
    /// Words per KSK row: ks_level × (n+1).
    ksk_row_len: usize,
}

impl KeyShape {
    fn of(p: &ParamSet) -> Self {
        Self {
            ggsws: p.n,
            plane_len: p.ggsw_rows() * (p.k + 1) * p.half_n(),
            ksk_rows: p.long_dim(),
            ksk_row_len: p.ks_level * (p.n + 1),
        }
    }
}

/// `MAGIC, version u8, param name, ggsws u32, plane_len u32, ksk_rows
/// u32, ksk_row_len u32`.
pub fn write_key_header(out: &mut Vec<u8>, p: &ParamSet) {
    out.extend_from_slice(&KEY_MAGIC);
    out.push(CODEC_VERSION);
    put_short_str(out, p.name);
    let shape = KeyShape::of(p);
    put_u32(out, shape.ggsws as u32);
    put_u32(out, shape.plane_len as u32);
    put_u32(out, shape.ksk_rows as u32);
    put_u32(out, shape.ksk_row_len as u32);
}

/// Decode and validate a key header, resolving the named parameter set.
pub fn read_key_header(r: &mut Reader) -> Result<&'static ParamSet, WireError> {
    let magic = [r.u8()?, r.u8()?, r.u8()?, r.u8()?];
    if magic != KEY_MAGIC {
        return Err(WireError::Malformed(format!("bad key magic {magic:02x?}")));
    }
    let version = r.u8()?;
    if version != CODEC_VERSION {
        return Err(WireError::UnsupportedVersion { got: version });
    }
    let name = r.short_str()?;
    let p = params::by_name(&name)
        .ok_or_else(|| WireError::Malformed(format!("unknown parameter set {name:?}")))?;
    let wire_shape = KeyShape {
        ggsws: r.u32()? as usize,
        plane_len: r.u32()? as usize,
        ksk_rows: r.u32()? as usize,
        ksk_row_len: r.u32()? as usize,
    };
    let derived = KeyShape::of(p);
    if wire_shape != derived {
        return Err(WireError::Malformed(format!(
            "key shape {wire_shape:?} does not match parameter set {name} ({derived:?})"
        )));
    }
    Ok(p)
}

const CHUNK_BSK: u8 = 0;
const CHUNK_KSK: u8 = 1;

/// Streams a resident key set as a bounded sequence of chunk payloads —
/// the client side of a key upload. Each yielded buffer is one
/// self-delimiting chunk no larger than ~`chunk_bytes` (one GGSW or one
/// KSK row minimum, however large), so peak transient memory on the
/// sending side is one chunk, not the key set again.
pub struct KeyChunker<'a> {
    keys: &'a ServerKeys,
    shape: KeyShape,
    chunk_bytes: usize,
    next_ggsw: usize,
    next_ksk_row: usize,
}

impl<'a> KeyChunker<'a> {
    pub fn new(keys: &'a ServerKeys, chunk_bytes: usize) -> Self {
        Self {
            keys,
            shape: KeyShape::of(&keys.params),
            chunk_bytes: chunk_bytes.max(1),
            next_ggsw: 0,
            next_ksk_row: 0,
        }
    }

    /// Total chunks this chunker will yield (for progress reporting).
    pub fn total_chunks(&self) -> usize {
        let per_ggsw = self.shape.plane_len * 16; // re + im planes
        let ggsws_per = (self.chunk_bytes / per_ggsw).max(1);
        let bsk_chunks = self.shape.ggsws.div_ceil(ggsws_per);
        let per_row = self.shape.ksk_row_len * 8;
        let rows_per = (self.chunk_bytes / per_row).max(1);
        let ksk_chunks = self.shape.ksk_rows.div_ceil(rows_per);
        bsk_chunks + ksk_chunks
    }
}

impl Iterator for KeyChunker<'_> {
    type Item = Vec<u8>;

    fn next(&mut self) -> Option<Vec<u8>> {
        if self.next_ggsw < self.shape.ggsws {
            let per_ggsw = self.shape.plane_len * 16;
            let count =
                (self.chunk_bytes / per_ggsw).max(1).min(self.shape.ggsws - self.next_ggsw);
            let mut out = Vec::with_capacity(10 + count * per_ggsw);
            out.push(CHUNK_BSK);
            put_u32(&mut out, self.next_ggsw as u32);
            put_u32(&mut out, count as u32);
            for g in &self.keys.bsk.ggsw[self.next_ggsw..self.next_ggsw + count] {
                for &v in &g.re {
                    put_f64(&mut out, v);
                }
                for &v in &g.im {
                    put_f64(&mut out, v);
                }
            }
            self.next_ggsw += count;
            return Some(out);
        }
        if self.next_ksk_row < self.shape.ksk_rows {
            let per_row = self.shape.ksk_row_len * 8;
            let count =
                (self.chunk_bytes / per_row).max(1).min(self.shape.ksk_rows - self.next_ksk_row);
            let mut out = Vec::with_capacity(10 + count * per_row);
            out.push(CHUNK_KSK);
            put_u32(&mut out, self.next_ksk_row as u32);
            put_u32(&mut out, count as u32);
            let start = self.next_ksk_row * self.shape.ksk_row_len;
            let end = start + count * self.shape.ksk_row_len;
            for &w in &self.keys.ksk.data[start..end] {
                put_u64(&mut out, w);
            }
            self.next_ksk_row += count;
            return Some(out);
        }
        None
    }
}

/// Incremental server-side key reassembly. Allocates the final layout
/// ONCE (zeroed) from the trusted parameter set, then chunks fill rows in
/// place — the received key set is never resident twice, and no
/// allocation is sized by wire input. [`Self::finish`] refuses partial
/// transfers.
pub struct KeyAssembly {
    params: &'static ParamSet,
    shape: KeyShape,
    ggsw: Vec<FourierGgsw>,
    ggsw_filled: Vec<bool>,
    ksk_data: Vec<u64>,
    ksk_row_filled: Vec<bool>,
}

impl KeyAssembly {
    pub fn new(params: &'static ParamSet) -> Self {
        let shape = KeyShape::of(params);
        let ggsw = (0..shape.ggsws)
            .map(|_| FourierGgsw {
                re: vec![0.0; shape.plane_len],
                im: vec![0.0; shape.plane_len],
                rows: params.ggsw_rows(),
                k1: params.k + 1,
                nh: params.half_n(),
            })
            .collect();
        Self {
            params,
            shape,
            ggsw,
            ggsw_filled: vec![false; shape.ggsws],
            ksk_data: vec![0u64; shape.ksk_rows * shape.ksk_row_len],
            ksk_row_filled: vec![false; shape.ksk_rows],
        }
    }

    pub fn params(&self) -> &'static ParamSet {
        self.params
    }

    /// Consume one self-delimiting chunk from `r` (several may share one
    /// buffer; [`Self::add_chunk`] handles the one-chunk-per-frame case).
    pub fn add_chunk_from(&mut self, r: &mut Reader) -> Result<(), WireError> {
        let kind = r.u8()?;
        let start = r.u32()? as usize;
        let count = r.u32()? as usize;
        match kind {
            CHUNK_BSK => {
                if count == 0 || start + count > self.shape.ggsws {
                    return Err(WireError::Malformed(format!(
                        "bsk chunk [{start}, {start}+{count}) outside {} ggsws",
                        self.shape.ggsws
                    )));
                }
                for i in start..start + count {
                    r.fill_f64(&mut self.ggsw[i].re)?;
                    r.fill_f64(&mut self.ggsw[i].im)?;
                    self.ggsw_filled[i] = true;
                }
            }
            CHUNK_KSK => {
                if count == 0 || start + count > self.shape.ksk_rows {
                    return Err(WireError::Malformed(format!(
                        "ksk chunk [{start}, {start}+{count}) outside {} rows",
                        self.shape.ksk_rows
                    )));
                }
                let lo = start * self.shape.ksk_row_len;
                let hi = lo + count * self.shape.ksk_row_len;
                r.fill_u64(&mut self.ksk_data[lo..hi])?;
                for f in &mut self.ksk_row_filled[start..start + count] {
                    *f = true;
                }
            }
            other => {
                return Err(WireError::Malformed(format!("unknown chunk kind {other}")));
            }
        }
        Ok(())
    }

    /// Consume exactly one chunk occupying the whole buffer (one KEY_CHUNK
    /// frame body).
    pub fn add_chunk(&mut self, chunk: &[u8]) -> Result<(), WireError> {
        let mut r = Reader::new(chunk);
        self.add_chunk_from(&mut r)?;
        r.expect_eof()
    }

    /// Chunks still missing, as `(bsk_ggsws, ksk_rows)`.
    pub fn missing(&self) -> (usize, usize) {
        (
            self.ggsw_filled.iter().filter(|f| !**f).count(),
            self.ksk_row_filled.iter().filter(|f| !**f).count(),
        )
    }

    /// Finalize into a [`ServerKeys`]; a transfer with any unfilled GGSW
    /// or KSK row is malformed.
    pub fn finish(self) -> Result<ServerKeys, WireError> {
        let (bsk_missing, ksk_missing) = self.missing();
        if bsk_missing + ksk_missing != 0 {
            return Err(WireError::Malformed(format!(
                "incomplete key transfer: {bsk_missing} ggsws and {ksk_missing} ksk rows missing"
            )));
        }
        Ok(ServerKeys {
            params: self.params.clone(),
            bsk: FourierBsk { ggsw: self.ggsw },
            ksk: Ksk {
                data: self.ksk_data,
                long_dim: self.shape.ksk_rows,
                level: self.params.ks_level,
                short_len: self.params.n + 1,
            },
        })
    }
}

/// Whole-blob convenience encode (header + every chunk, concatenated) —
/// what the property tests round-trip; the serving path streams the same
/// bytes as separate frames instead.
pub fn encode_server_keys(keys: &ServerKeys, chunk_bytes: usize) -> Vec<u8> {
    let mut out = Vec::new();
    write_key_header(&mut out, &keys.params);
    for chunk in KeyChunker::new(keys, chunk_bytes) {
        out.extend_from_slice(&chunk);
    }
    out
}

/// Whole-blob decode: header, then chunks until the buffer is exhausted.
pub fn decode_server_keys(bytes: &[u8]) -> Result<ServerKeys, WireError> {
    let mut r = Reader::new(bytes);
    let p = read_key_header(&mut r)?;
    let mut asm = KeyAssembly::new(p);
    while r.remaining() > 0 {
        asm.add_chunk_from(&mut r)?;
    }
    asm.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::TEST1;
    use crate::tfhe::server_keys_bitwise_eq;

    #[test]
    fn ciphertext_roundtrip_is_bitwise() {
        let ct = LweCiphertext { data: vec![u64::MAX, 0, 7, 0x0123_4567_89AB_CDEF] };
        let mut buf = Vec::new();
        write_ciphertext(&mut buf, &ct);
        let mut r = Reader::new(&buf);
        let back = read_ciphertext(&mut r).expect("decodes");
        r.expect_eof().expect("fully consumed");
        assert_eq!(back.data, ct.data);
    }

    #[test]
    fn ciphertext_rejects_oversized_and_truncated() {
        let mut buf = Vec::new();
        put_u32(&mut buf, (MAX_CT_WORDS + 1) as u32);
        match read_ciphertext(&mut Reader::new(&buf)) {
            Err(WireError::TooLarge { len, max }) => {
                assert_eq!((len, max), (MAX_CT_WORDS + 1, MAX_CT_WORDS));
            }
            other => panic!("wanted TooLarge, got {other:?}"),
        }
        let ct = LweCiphertext { data: vec![1, 2, 3] };
        let mut buf = Vec::new();
        write_ciphertext(&mut buf, &ct);
        buf.truncate(buf.len() - 1);
        assert!(matches!(
            read_ciphertext(&mut Reader::new(&buf)),
            Err(WireError::Malformed(_))
        ));
    }

    #[test]
    fn server_keys_roundtrip_chunked_small_param() {
        let keys = crate::tfhe::keycache::get(&TEST1, 0xC0DEC).server.clone();
        // A chunk size small enough to force many chunks of both kinds.
        let blob = encode_server_keys(&keys, 64 << 10);
        let back = decode_server_keys(&blob).expect("decodes");
        assert!(server_keys_bitwise_eq(&keys, &back));
    }

    #[test]
    fn incomplete_transfer_fails_typed() {
        let keys = crate::tfhe::keycache::get(&TEST1, 0xC0DEC).server.clone();
        let mut asm = KeyAssembly::new(&TEST1);
        let mut chunks = KeyChunker::new(&keys, 64 << 10);
        let first = chunks.next().expect("at least one chunk");
        asm.add_chunk(&first).expect("valid chunk");
        assert!(matches!(asm.finish(), Err(WireError::Malformed(_))));
    }

    #[test]
    fn header_shape_mismatch_is_malformed() {
        let mut buf = Vec::new();
        write_key_header(&mut buf, &TEST1);
        // Corrupt the ggsw count (first u32 after the name).
        let name_end = KEY_MAGIC.len() + 1 + 1 + TEST1.name.len();
        buf[name_end] ^= 0xFF;
        assert!(matches!(
            read_key_header(&mut Reader::new(&buf)),
            Err(WireError::Malformed(_))
        ));
    }
}
