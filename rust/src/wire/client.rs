//! The blocking remote client: connect, upload keys, submit, decrypt at
//! home.
//!
//! A [`Client`] is single-threaded and blocking — the shape of
//! `examples/remote_client.rs` — but the protocol underneath is
//! pipelined: [`Client::send_submit`] returns a request id immediately
//! and [`Client::wait`] collects RESULTs in whatever order the server
//! finishes them, stashing out-of-order arrivals until their id is asked
//! for. Every server rejection surfaces as
//! [`WireError::Rejected`] carrying the wire [`Status`] and reason —
//! including [`Status::RegisterUnsupported`] from a key upload against a
//! single-key cluster, after which the same connection keeps submitting.

use std::collections::HashMap;
use std::io::BufReader;
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use crate::params::{self, ParamSet};
use crate::tenant::SessionId;
use crate::tfhe::{LweCiphertext, ServerKeys};

use super::codec::{
    put_str, put_u64, read_ciphertexts, write_ciphertexts, write_key_header, KeyChunker, Reader,
    DEFAULT_CHUNK_BYTES,
};
use super::proto::{
    read_frame, write_frame, Status, PROTO_VERSION, TAG_ACK, TAG_HELLO, TAG_HELLO_OK,
    TAG_KEY_BEGIN, TAG_KEY_CHUNK, TAG_KEY_COMMIT, TAG_RESULT, TAG_SUBMIT,
};
use super::WireError;

pub struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
    params: &'static ParamSet,
    next_id: u64,
    /// RESULTs that arrived while waiting for a different id.
    pending: HashMap<u64, Result<Vec<LweCiphertext>, (Status, String)>>,
}

impl Client {
    /// Connect and handshake. The HELLO_OK reply names the server's
    /// parameter set, resolved locally via [`params::by_name`] — the
    /// client then encrypts with exactly the shapes the server serves.
    pub fn connect(addr: impl ToSocketAddrs) -> Result<Client, WireError> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        let reader = BufReader::new(stream.try_clone()?);
        let mut client = Client {
            writer: stream,
            reader,
            params: &params::TEST1, // placeholder until HELLO_OK lands
            next_id: 1,
            pending: HashMap::new(),
        };
        write_frame(&mut client.writer, TAG_HELLO, &[PROTO_VERSION])?;
        let frame = client.read_one()?;
        if frame.tag == TAG_ACK {
            // The server refused the handshake (version mismatch).
            let (_, status, reason) = decode_ack(&frame.body)?;
            return Err(WireError::Rejected { status, reason });
        }
        if frame.tag != TAG_HELLO_OK {
            return Err(WireError::Malformed(format!(
                "expected HELLO_OK, got tag {}",
                frame.tag
            )));
        }
        let mut r = Reader::new(&frame.body);
        let version = r.u8()?;
        if version != PROTO_VERSION {
            return Err(WireError::UnsupportedVersion { got: version });
        }
        let name = r.short_str()?;
        r.expect_eof()?;
        client.params = params::by_name(&name).ok_or_else(|| {
            WireError::Malformed(format!("server serves unknown parameter set {name:?}"))
        })?;
        Ok(client)
    }

    /// The parameter set the server announced at handshake.
    pub fn params(&self) -> &'static ParamSet {
        self.params
    }

    /// Upload `keys` for `session`, streaming [`DEFAULT_CHUNK_BYTES`]
    /// chunks. Blocks until the server acknowledges the commit — after
    /// `Ok(())` the keys are pinned on every shard store and the session
    /// is safe to submit under from anywhere.
    pub fn upload_keys(
        &mut self,
        session: impl Into<SessionId>,
        keys: &ServerKeys,
    ) -> Result<(), WireError> {
        self.upload_keys_chunked(session, keys, DEFAULT_CHUNK_BYTES)
    }

    /// [`Self::upload_keys`] with an explicit chunk-size target (the
    /// bench sweeps this).
    pub fn upload_keys_chunked(
        &mut self,
        session: impl Into<SessionId>,
        keys: &ServerKeys,
        chunk_bytes: usize,
    ) -> Result<(), WireError> {
        let session = session.into();
        let id = self.mint_id();
        let mut body = Vec::new();
        put_u64(&mut body, id);
        put_u64(&mut body, session.0);
        write_key_header(&mut body, &keys.params);
        write_frame(&mut self.writer, TAG_KEY_BEGIN, &body)?;
        // BEGIN is acked before any material moves: capability and
        // parameter rejections cost one header frame, not a full upload.
        self.wait_ack(id)?;
        for chunk in KeyChunker::new(keys, chunk_bytes) {
            let mut body = Vec::with_capacity(8 + chunk.len());
            put_u64(&mut body, id);
            body.extend_from_slice(&chunk);
            write_frame(&mut self.writer, TAG_KEY_CHUNK, &body)?;
        }
        let mut body = Vec::new();
        put_u64(&mut body, id);
        write_frame(&mut self.writer, TAG_KEY_COMMIT, &body)?;
        self.wait_ack(id)
    }

    /// Submit and block for the result — the one-liner path.
    pub fn submit(
        &mut self,
        session: impl Into<SessionId>,
        inputs: &[LweCiphertext],
    ) -> Result<Vec<LweCiphertext>, WireError> {
        let id = self.send_submit(session, inputs, None)?;
        self.wait(id)
    }

    /// Fire one SUBMIT without waiting; returns the request id for a
    /// later [`Self::wait`]. `deadline` maps to the cluster's per-request
    /// deadline ([`Status::DeadlineExpired`] on expiry).
    pub fn send_submit(
        &mut self,
        session: impl Into<SessionId>,
        inputs: &[LweCiphertext],
        deadline: Option<Duration>,
    ) -> Result<u64, WireError> {
        let session = session.into();
        let id = self.mint_id();
        let mut body = Vec::new();
        put_u64(&mut body, id);
        put_u64(&mut body, session.0);
        put_u64(&mut body, deadline.map(|d| d.as_millis() as u64).unwrap_or(0));
        write_ciphertexts(&mut body, inputs);
        write_frame(&mut self.writer, TAG_SUBMIT, &body)?;
        Ok(id)
    }

    /// Block until request `id`'s RESULT arrives (RESULTs for other
    /// pipelined ids are stashed for their own `wait` calls).
    pub fn wait(&mut self, id: u64) -> Result<Vec<LweCiphertext>, WireError> {
        loop {
            if let Some(done) = self.pending.remove(&id) {
                return done
                    .map_err(|(status, reason)| WireError::Rejected { status, reason });
            }
            let frame = self.read_one()?;
            match frame.tag {
                TAG_RESULT => {
                    let (got, outcome) = decode_result(&frame.body)?;
                    self.pending.insert(got, outcome);
                }
                TAG_ACK => {
                    // An ACK while waiting for results is a server-side
                    // protocol complaint (e.g. BadRequest before close).
                    let (_, status, reason) = decode_ack(&frame.body)?;
                    return Err(WireError::Rejected { status, reason });
                }
                other => {
                    return Err(WireError::Malformed(format!(
                        "expected RESULT, got tag {other}"
                    )));
                }
            }
        }
    }

    fn mint_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    fn read_one(&mut self) -> Result<super::proto::Frame, WireError> {
        read_frame(&mut self.reader)?.ok_or(WireError::Disconnected)
    }

    /// Wait for the ACK of upload step `id`; RESULTs of in-flight
    /// submits arriving meanwhile are stashed, not lost.
    fn wait_ack(&mut self, id: u64) -> Result<(), WireError> {
        loop {
            let frame = self.read_one()?;
            match frame.tag {
                TAG_ACK => {
                    let (got, status, reason) = decode_ack(&frame.body)?;
                    if got != id && got != 0 {
                        return Err(WireError::Malformed(format!(
                            "ack for id {got} while waiting on {id}"
                        )));
                    }
                    if status != Status::Ok {
                        return Err(WireError::Rejected { status, reason });
                    }
                    return Ok(());
                }
                TAG_RESULT => {
                    let (got, outcome) = decode_result(&frame.body)?;
                    self.pending.insert(got, outcome);
                }
                other => {
                    return Err(WireError::Malformed(format!(
                        "expected ACK, got tag {other}"
                    )));
                }
            }
        }
    }
}

fn decode_status(r: &mut Reader) -> Result<Status, WireError> {
    let raw = r.u8()?;
    Status::from_u8(raw)
        .ok_or_else(|| WireError::Malformed(format!("unknown status code {raw}")))
}

/// ACK body: `id u64, status u8, reason str`.
fn decode_ack(body: &[u8]) -> Result<(u64, Status, String), WireError> {
    let mut r = Reader::new(body);
    let id = r.u64()?;
    let status = decode_status(&mut r)?;
    let reason = r.string()?;
    r.expect_eof()?;
    Ok((id, status, reason))
}

/// RESULT body: `id u64, status u8`, then ciphertexts (Ok) or a reason
/// string (error).
fn decode_result(
    body: &[u8],
) -> Result<(u64, Result<Vec<LweCiphertext>, (Status, String)>), WireError> {
    let mut r = Reader::new(body);
    let id = r.u64()?;
    let status = decode_status(&mut r)?;
    if status == Status::Ok {
        let cts = read_ciphertexts(&mut r)?;
        r.expect_eof()?;
        Ok((id, Ok(cts)))
    } else {
        let reason = r.string()?;
        r.expect_eof()?;
        Ok((id, Err((status, reason))))
    }
}
