//! The framed request/response protocol.
//!
//! Every message on the socket is one **frame**: `[len: u32 LE][tag:
//! u8][body: len-1 bytes]` — `len` counts the tag plus the body, so an
//! empty-body message has `len == 1`. Frames are bounded by [`MAX_FRAME`]
//! and the bound is enforced *before* the body is allocated: a hostile
//! length prefix yields a typed [`WireError::TooLarge`], never an OOM.
//!
//! ## Message flow
//!
//! ```text
//! client                               server
//!   HELLO {version}          ──▶
//!                            ◀──  HELLO_OK {version, param name}
//!   SUBMIT {id, session,     ──▶
//!           deadline_ms, cts}
//!                            ◀──  RESULT {id, status, reason | cts}
//!   KEY_BEGIN {id, session,  ──▶
//!              key header}
//!                            ◀──  ACK {id, status, reason}
//!   KEY_CHUNK {id, chunk}    ──▶      (chunks are not individually
//!   KEY_CHUNK {id, chunk}    ──▶       acked — §streaming below)
//!   KEY_COMMIT {id}          ──▶
//!                            ◀──  ACK {id, status, reason}
//! ```
//!
//! Requests are **pipelined**: every SUBMIT carries a client-chosen `id`
//! and its RESULT echoes it, so a client may keep many requests in
//! flight and RESULTs may arrive out of submission order (the server
//! bounds in-flight requests per connection; excess SUBMITs are rejected
//! with [`Status::ClusterFull`]).
//!
//! **Streaming uploads.** KEY_CHUNK frames deliberately get no per-chunk
//! acknowledgment — a WIDE10 upload is ~100 chunks and a per-chunk round
//! trip would turn one upload into 100 latency-bound exchanges. Instead
//! KEY_BEGIN is acked (capability + parameter validation happens *before*
//! any material moves), chunk errors latch server-side, and KEY_COMMIT's
//! ACK reports the first latched error if any chunk was bad.

use std::io::{Read, Write};

use crate::cluster::ClusterError;
use crate::coordinator::RequestError;
use crate::tenant::RegisterError;

use super::WireError;

/// Hard bound on one frame's `len` field. Large enough for a maximal
/// key chunk or a WIDE-width ciphertext batch, small enough that a
/// hostile prefix cannot balloon a connection thread.
pub const MAX_FRAME: usize = 8 << 20;

/// Protocol version spoken in HELLO (independent of
/// [`super::CODEC_VERSION`], which covers payload layout).
pub const PROTO_VERSION: u8 = 1;

// Frame tags. u8 on the wire; unknown tags are a typed protocol error.
pub const TAG_HELLO: u8 = 1;
pub const TAG_HELLO_OK: u8 = 2;
pub const TAG_SUBMIT: u8 = 3;
pub const TAG_RESULT: u8 = 4;
pub const TAG_KEY_BEGIN: u8 = 5;
pub const TAG_KEY_CHUNK: u8 = 6;
pub const TAG_KEY_COMMIT: u8 = 7;
pub const TAG_ACK: u8 = 8;

/// Wire status codes — the typed error surface of the protocol. Every
/// in-process rejection ([`ClusterError`], [`RequestError`],
/// [`RegisterError`]) maps onto one of these; EXPERIMENTS.md §Wire
/// tabulates the mapping.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Status {
    Ok = 0,
    /// Cluster-wide admission queue at depth ([`ClusterError::ClusterFull`]).
    ClusterFull = 1,
    /// Routed shard's own queue bound fired ([`ClusterError::ShardFull`]).
    ShardFull = 2,
    /// Cluster shut down ([`ClusterError::Stopped`]).
    Stopped = 3,
    /// Session key resolution failed — includes the pinned-keys case
    /// where registered material is gone and regeneration is refused.
    ResolveFailed = 4,
    /// Batch execution failed after retries ([`RequestError::ExecFailed`]).
    ExecFailed = 5,
    /// The request's deadline expired ([`RequestError::RequestTimeout`]).
    DeadlineExpired = 6,
    /// The serving shard died before answering ([`RequestError::ShardLost`]).
    ShardLost = 7,
    /// The frame or payload did not parse (malformed input, unknown tag,
    /// protocol-state violation). The server answers where it can and
    /// closes the connection.
    BadRequest = 8,
    /// HELLO or codec version mismatch.
    UnsupportedVersion = 9,
    /// Key upload against a cluster whose stores cannot hold per-session
    /// material ([`RegisterError::Unsupported`]) — the typed rejection
    /// that keeps `StaticKeys::register`'s panic off the network path.
    RegisterUnsupported = 10,
    /// Uploaded keys' parameter set does not match the server's
    /// ([`RegisterError::ParamMismatch`]).
    ParamMismatch = 11,
    /// QoS: the session's token bucket is empty — its rate limit is
    /// exceeded; retry after the bucket refills
    /// ([`ClusterError::Throttled`]).
    Throttled = 12,
    /// QoS: the session's fair-queue lane is at its depth bound — this
    /// tenant must shed load; other tenants are unaffected
    /// ([`ClusterError::TenantQueueFull`]).
    TenantQueueFull = 13,
}

impl Status {
    pub fn from_u8(v: u8) -> Option<Status> {
        Some(match v {
            0 => Status::Ok,
            1 => Status::ClusterFull,
            2 => Status::ShardFull,
            3 => Status::Stopped,
            4 => Status::ResolveFailed,
            5 => Status::ExecFailed,
            6 => Status::DeadlineExpired,
            7 => Status::ShardLost,
            8 => Status::BadRequest,
            9 => Status::UnsupportedVersion,
            10 => Status::RegisterUnsupported,
            11 => Status::ParamMismatch,
            12 => Status::Throttled,
            13 => Status::TenantQueueFull,
            _ => return None,
        })
    }

    pub fn as_u8(self) -> u8 {
        self as u8
    }

    pub fn from_cluster_error(e: ClusterError) -> Status {
        match e {
            ClusterError::ClusterFull => Status::ClusterFull,
            ClusterError::ShardFull => Status::ShardFull,
            ClusterError::Stopped => Status::Stopped,
            ClusterError::ResolveFailed => Status::ResolveFailed,
            ClusterError::Throttled => Status::Throttled,
            ClusterError::TenantQueueFull => Status::TenantQueueFull,
        }
    }

    pub fn from_request_error(e: &RequestError) -> Status {
        match e {
            RequestError::ExecFailed { .. } => Status::ExecFailed,
            RequestError::RequestTimeout => Status::DeadlineExpired,
            RequestError::ShardLost => Status::ShardLost,
            RequestError::ResolveFailed { .. } => Status::ResolveFailed,
        }
    }

    pub fn from_register_error(e: &RegisterError) -> Status {
        match e {
            RegisterError::Unsupported => Status::RegisterUnsupported,
            RegisterError::ParamMismatch { .. } => Status::ParamMismatch,
        }
    }
}

/// One decoded frame: its tag and body bytes.
#[derive(Debug)]
pub struct Frame {
    pub tag: u8,
    pub body: Vec<u8>,
}

/// Write one frame. The frame is assembled into one buffer and written
/// with a single `write_all`, so concurrent writers serialized by a lock
/// never interleave partial frames.
pub fn write_frame(w: &mut impl Write, tag: u8, body: &[u8]) -> Result<(), WireError> {
    let len = 1 + body.len();
    assert!(len <= MAX_FRAME, "outgoing frame of {len} bytes exceeds MAX_FRAME");
    let mut buf = Vec::with_capacity(4 + len);
    buf.extend_from_slice(&(len as u32).to_le_bytes());
    buf.push(tag);
    buf.extend_from_slice(body);
    w.write_all(&buf)?;
    w.flush()?;
    Ok(())
}

/// Read one frame. Returns `Ok(None)` on clean EOF (the peer closed
/// between frames — a normal hangup), [`WireError::Disconnected`] on EOF
/// *inside* a frame, and [`WireError::TooLarge`] — before any allocation
/// — when the length prefix exceeds [`MAX_FRAME`].
pub fn read_frame(r: &mut impl Read) -> Result<Option<Frame>, WireError> {
    let mut len_buf = [0u8; 4];
    let mut got = 0;
    while got < 4 {
        match r.read(&mut len_buf[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(None); // clean EOF between frames
                }
                return Err(WireError::Disconnected);
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(WireError::Io(e)),
        }
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_FRAME {
        return Err(WireError::TooLarge { len, max: MAX_FRAME });
    }
    if len == 0 {
        return Err(WireError::Malformed("zero-length frame (missing tag)".into()));
    }
    let eof = |e: std::io::Error| {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            WireError::Disconnected
        } else {
            WireError::Io(e)
        }
    };
    let mut tag = [0u8; 1];
    r.read_exact(&mut tag).map_err(eof)?;
    let mut body = vec![0u8; len - 1];
    r.read_exact(&mut body).map_err(eof)?;
    Ok(Some(Frame { tag: tag[0], body }))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, TAG_SUBMIT, &[1, 2, 3]).unwrap();
        let f = read_frame(&mut buf.as_slice()).unwrap().expect("one frame");
        assert_eq!(f.tag, TAG_SUBMIT);
        assert_eq!(f.body, vec![1, 2, 3]);
    }

    #[test]
    fn clean_eof_is_none_and_mid_frame_eof_is_disconnected() {
        assert!(read_frame(&mut [].as_slice()).unwrap().is_none());
        let mut buf = Vec::new();
        write_frame(&mut buf, TAG_ACK, &[9; 100]).unwrap();
        buf.truncate(buf.len() - 10);
        assert!(matches!(read_frame(&mut buf.as_slice()), Err(WireError::Disconnected)));
        // EOF inside the 4-byte length prefix is also a disconnect.
        assert!(matches!(read_frame(&mut [0u8, 1].as_slice()), Err(WireError::Disconnected)));
    }

    #[test]
    fn oversized_prefix_rejected_before_allocation() {
        let bytes = (u32::MAX).to_le_bytes();
        match read_frame(&mut bytes.as_slice()) {
            Err(WireError::TooLarge { len, max }) => {
                assert_eq!(len, u32::MAX as usize);
                assert_eq!(max, MAX_FRAME);
            }
            other => panic!("wanted TooLarge, got {other:?}"),
        }
    }

    #[test]
    fn zero_length_frame_is_malformed() {
        let bytes = 0u32.to_le_bytes();
        assert!(matches!(read_frame(&mut bytes.as_slice()), Err(WireError::Malformed(_))));
    }

    #[test]
    fn status_codes_roundtrip() {
        for v in 0..=13u8 {
            let s = Status::from_u8(v).expect("defined");
            assert_eq!(s.as_u8(), v);
        }
        assert!(Status::from_u8(14).is_none());
    }
}
