//! The TCP serving front end: an accept loop over `std::net` with one
//! thread per connection, bounded per-connection admission in front of
//! [`Cluster::submit`], and streaming key-upload assembly.
//!
//! Threading model (zero new dependencies — `std::net` + `std::thread`):
//!
//! - One **accept thread** (non-blocking listener polled every 10 ms so
//!   shutdown is prompt) spawns one **connection thread** per client.
//! - The connection thread owns the read half; the write half sits behind
//!   a mutex shared with per-request **waiter threads**, each of which
//!   blocks on one [`ClusterResponse`](crate::cluster::ClusterResponse)
//!   and writes the RESULT frame when the cluster answers. Frames are
//!   written atomically (one buffered `write_all` under the lock), so
//!   pipelined RESULTs interleave by frame, never by byte.
//! - Admission is bounded twice: the cluster's own `queue_depth` permit
//!   (surfaced as [`Status::ClusterFull`]) and a per-connection in-flight
//!   cap ([`WireServerOptions::max_inflight_per_conn`]) that stops one
//!   connection from monopolizing cluster admission or spawning unbounded
//!   waiter threads.
//!
//! Every rejection is a **typed frame**, never a panic and never a
//! silent drop: malformed input answers `BadRequest` (then closes, since
//! framing can no longer be trusted), key uploads against a single-key
//! cluster answer `RegisterUnsupported` (the connection stays usable for
//! submits), and cluster/request errors map through
//! [`Status::from_cluster_error`] / [`Status::from_request_error`].

use std::io::BufReader;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::cluster::Cluster;
use crate::tenant::SessionId;
use crate::tfhe::LweCiphertext;

use super::codec::{
    put_str, put_u64, read_ciphertexts, read_key_header, write_ciphertexts, KeyAssembly, Reader,
};
use super::proto::{
    read_frame, write_frame, Status, PROTO_VERSION, TAG_ACK, TAG_HELLO, TAG_HELLO_OK,
    TAG_KEY_BEGIN, TAG_KEY_CHUNK, TAG_KEY_COMMIT, TAG_RESULT, TAG_SUBMIT,
};
use super::WireError;

#[derive(Debug, Clone)]
pub struct WireServerOptions {
    /// In-flight SUBMITs one connection may hold before further SUBMITs
    /// are rejected with [`Status::ClusterFull`]. Also bounds waiter
    /// threads per connection.
    pub max_inflight_per_conn: usize,
}

impl Default for WireServerOptions {
    fn default() -> Self {
        Self { max_inflight_per_conn: 32 }
    }
}

/// A running TCP front end over one [`Cluster`]. Dropping without
/// [`Self::shutdown`] leaks the accept thread for the process lifetime;
/// servers embedded in tests and `serve --listen` shut down explicitly.
pub struct WireServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<TcpStream>>>,
    accept_thread: Option<JoinHandle<()>>,
}

impl WireServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and start
    /// accepting. The cluster is shared — in-process submitters keep
    /// working alongside remote ones, which is exactly what the loopback
    /// bitwise-equivalence tests exploit.
    pub fn start(
        cluster: Arc<Cluster>,
        addr: impl ToSocketAddrs,
        opts: WireServerOptions,
    ) -> std::io::Result<WireServer> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let bound = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
        let accept_thread = {
            let stop = stop.clone();
            let conns = conns.clone();
            std::thread::spawn(move || {
                let mut handles: Vec<JoinHandle<()>> = Vec::new();
                while !stop.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            if let Ok(clone) = stream.try_clone() {
                                conns
                                    .lock()
                                    .unwrap_or_else(PoisonError::into_inner)
                                    .push(clone);
                            }
                            let cluster = cluster.clone();
                            let opts = opts.clone();
                            let stop = stop.clone();
                            handles.push(std::thread::spawn(move || {
                                serve_connection(cluster, stream, opts, stop)
                            }));
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(10));
                        }
                        Err(_) => break,
                    }
                }
                for h in handles {
                    let _ = h.join();
                }
            })
        };
        Ok(WireServer { addr: bound, stop, conns, accept_thread: Some(accept_thread) })
    }

    /// The bound address (resolves the ephemeral port of `:0` binds).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, sever every live connection (unblocking their
    /// reader threads), and join the accept thread (which joins the
    /// connection threads). In-flight requests already inside the cluster
    /// still complete there; only their response frames are lost.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        for conn in self.conns.lock().unwrap_or_else(PoisonError::into_inner).drain(..) {
            let _ = conn.shutdown(Shutdown::Both);
        }
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

/// Frame-writer shared by the connection thread and its waiters.
type SharedWriter = Arc<Mutex<TcpStream>>;

fn send(writer: &SharedWriter, tag: u8, body: &[u8]) -> Result<(), WireError> {
    let mut w = writer.lock().unwrap_or_else(PoisonError::into_inner);
    write_frame(&mut *w, tag, body)
}

fn send_ack(writer: &SharedWriter, id: u64, status: Status, reason: &str) -> Result<(), WireError> {
    let mut body = Vec::new();
    put_u64(&mut body, id);
    body.push(status.as_u8());
    put_str(&mut body, reason);
    send(writer, TAG_ACK, &body)
}

fn send_result_err(
    writer: &SharedWriter,
    id: u64,
    status: Status,
    reason: &str,
) -> Result<(), WireError> {
    let mut body = Vec::new();
    put_u64(&mut body, id);
    body.push(status.as_u8());
    put_str(&mut body, reason);
    send(writer, TAG_RESULT, &body)
}

fn send_result_ok(
    writer: &SharedWriter,
    id: u64,
    cts: &[LweCiphertext],
) -> Result<(), WireError> {
    let mut body = Vec::new();
    put_u64(&mut body, id);
    body.push(Status::Ok.as_u8());
    write_ciphertexts(&mut body, cts);
    send(writer, TAG_RESULT, &body)
}

/// One in-progress key upload on a connection. Chunk failures latch here
/// instead of being acked per chunk; COMMIT reports the first failure.
struct Upload {
    id: u64,
    session: SessionId,
    asm: KeyAssembly,
    failed: Option<(Status, String)>,
}

fn serve_connection(
    cluster: Arc<Cluster>,
    stream: TcpStream,
    opts: WireServerOptions,
    stop: Arc<AtomicBool>,
) {
    // Small frames (HELLO, ACK, narrow-width RESULTs) are latency-bound:
    // don't let Nagle hold them hostage.
    let _ = stream.set_nodelay(true);
    let writer: SharedWriter = match stream.try_clone() {
        Ok(w) => Arc::new(Mutex::new(w)),
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    let inflight = Arc::new(AtomicUsize::new(0));
    let mut upload: Option<Upload> = None;
    let mut waiters: Vec<JoinHandle<()>> = Vec::new();

    while !stop.load(Ordering::SeqCst) {
        let frame = match read_frame(&mut reader) {
            Ok(Some(f)) => f,
            Ok(None) => break, // clean hangup
            Err(e @ (WireError::TooLarge { .. } | WireError::Malformed(_))) => {
                // Framing can no longer be trusted: answer typed, close.
                let _ = send_ack(&writer, 0, Status::BadRequest, &e.to_string());
                break;
            }
            Err(_) => break, // disconnect / io error
        };
        let close = handle_frame(&cluster, &writer, &opts, &inflight, &mut upload, frame, &mut waiters);
        if close.is_err() {
            break;
        }
    }
    // Reap waiter threads: each terminates once the cluster answers its
    // request (tickets never hang), even if the RESULT write then fails
    // against the closed socket.
    for w in waiters {
        let _ = w.join();
    }
}

/// Dispatch one frame. `Err(())` closes the connection (protocol-state
/// violations and undecodable bodies — the stream can't be resynced);
/// application-level rejections answer typed and keep the connection.
fn handle_frame(
    cluster: &Arc<Cluster>,
    writer: &SharedWriter,
    opts: &WireServerOptions,
    inflight: &Arc<AtomicUsize>,
    upload: &mut Option<Upload>,
    frame: super::proto::Frame,
    waiters: &mut Vec<JoinHandle<()>>,
) -> Result<(), ()> {
    let mut r = Reader::new(&frame.body);
    match frame.tag {
        TAG_HELLO => {
            let version = match r.u8().and_then(|v| r.expect_eof().map(|_| v)) {
                Ok(v) => v,
                Err(e) => return reject_close(writer, 0, &e),
            };
            if version != PROTO_VERSION {
                let _ = send_ack(
                    writer,
                    0,
                    Status::UnsupportedVersion,
                    &format!("server speaks protocol {PROTO_VERSION}, client sent {version}"),
                );
                return Err(());
            }
            let mut body = vec![PROTO_VERSION];
            super::codec::put_short_str(&mut body, cluster.plan().params.name);
            send(writer, TAG_HELLO_OK, &body).map_err(|_| ())
        }
        TAG_SUBMIT => {
            let (id, session, deadline_ms, cts) = match parse_submit(&mut r) {
                Ok(p) => p,
                Err(e) => return reject_close(writer, 0, &e),
            };
            if inflight.load(Ordering::SeqCst) >= opts.max_inflight_per_conn {
                let _ = send_result_err(
                    writer,
                    id,
                    Status::ClusterFull,
                    &format!(
                        "connection in-flight bound ({}) reached",
                        opts.max_inflight_per_conn
                    ),
                );
                return Ok(());
            }
            let submitted = if deadline_ms > 0 {
                cluster.submit_with_deadline(session, cts, Duration::from_millis(deadline_ms))
            } else {
                cluster.submit(session, cts)
            };
            match submitted {
                Err(e) => {
                    let _ = send_result_err(
                        writer,
                        id,
                        Status::from_cluster_error(e),
                        &e.to_string(),
                    );
                    Ok(())
                }
                Ok(resp) => {
                    inflight.fetch_add(1, Ordering::SeqCst);
                    let writer = writer.clone();
                    let inflight = inflight.clone();
                    waiters.push(std::thread::spawn(move || {
                        let outcome = resp.wait();
                        let _ = match &outcome {
                            Ok(cts) => send_result_ok(&writer, id, cts),
                            Err(e) => send_result_err(
                                &writer,
                                id,
                                Status::from_request_error(e),
                                &e.to_string(),
                            ),
                        };
                        inflight.fetch_sub(1, Ordering::SeqCst);
                    }));
                    Ok(())
                }
            }
        }
        TAG_KEY_BEGIN => {
            let (id, session, p) = match parse_key_begin(&mut r) {
                Ok(p) => p,
                Err(e) => return reject_close(writer, 0, &e),
            };
            if upload.is_some() {
                let _ = send_ack(writer, id, Status::BadRequest, "upload already in progress");
                return Err(());
            }
            // Capability and parameter checks happen HERE, before any
            // key material moves: a StaticKeys cluster rejects typed
            // (`StaticKeys::register`'s panic is unreachable from the
            // network), and the connection stays usable for submits.
            if !cluster.supports_register() {
                let _ = send_ack(
                    writer,
                    id,
                    Status::RegisterUnsupported,
                    "cluster serves a single key set and does not accept per-session uploads",
                );
                return Ok(());
            }
            let served = cluster.plan().params.name;
            if p.name != served {
                let _ = send_ack(
                    writer,
                    id,
                    Status::ParamMismatch,
                    &format!("uploaded keys use parameter set {}, server serves {served}", p.name),
                );
                return Ok(());
            }
            *upload = Some(Upload {
                id,
                session: SessionId(session),
                asm: KeyAssembly::new(p),
                failed: None,
            });
            send_ack(writer, id, Status::Ok, "").map_err(|_| ())
        }
        TAG_KEY_CHUNK => {
            let id = match r.u64() {
                Ok(id) => id,
                Err(e) => return reject_close(writer, 0, &e),
            };
            let Some(up) = upload.as_mut() else {
                let _ = send_ack(writer, id, Status::BadRequest, "chunk outside an upload");
                return Err(());
            };
            if up.id != id {
                let _ = send_ack(writer, id, Status::BadRequest, "chunk for a different upload");
                return Err(());
            }
            // Chunks are not individually acked (§proto); the first
            // failure latches and COMMIT reports it.
            if up.failed.is_none() {
                if let Err(e) = up.asm.add_chunk(r.rest()) {
                    up.failed = Some((Status::BadRequest, e.to_string()));
                }
            }
            Ok(())
        }
        TAG_KEY_COMMIT => {
            let id = match r.u64().and_then(|id| r.expect_eof().map(|_| id)) {
                Ok(id) => id,
                Err(e) => return reject_close(writer, 0, &e),
            };
            let Some(up) = upload.take() else {
                let _ = send_ack(writer, id, Status::BadRequest, "commit outside an upload");
                return Err(());
            };
            if up.id != id {
                let _ = send_ack(writer, id, Status::BadRequest, "commit for a different upload");
                return Err(());
            }
            if let Some((status, reason)) = up.failed {
                let _ = send_ack(writer, id, status, &reason);
                return Ok(());
            }
            let keys = match up.asm.finish() {
                Ok(k) => Arc::new(k),
                Err(e) => {
                    let _ = send_ack(writer, id, Status::BadRequest, &e.to_string());
                    return Ok(());
                }
            };
            match cluster.register_session(up.session, keys) {
                Ok(shards) => send_ack(
                    writer,
                    id,
                    Status::Ok,
                    &format!("registered on {shards} shard stores"),
                )
                .map_err(|_| ()),
                Err(e) => {
                    let _ =
                        send_ack(writer, id, Status::from_register_error(&e), &e.to_string());
                    Ok(())
                }
            }
        }
        other => {
            let _ = send_ack(writer, 0, Status::BadRequest, &format!("unknown frame tag {other}"));
            Err(())
        }
    }
}

/// Answer a body-decode failure typed and signal the caller to close.
fn reject_close(writer: &SharedWriter, id: u64, e: &WireError) -> Result<(), ()> {
    let _ = send_ack(writer, id, Status::BadRequest, &e.to_string());
    Err(())
}

/// SUBMIT body: `id u64, session u64, deadline_ms u64 (0 = none), cts`.
fn parse_submit(
    r: &mut Reader,
) -> Result<(u64, u64, u64, Vec<LweCiphertext>), WireError> {
    let id = r.u64()?;
    let session = r.u64()?;
    let deadline_ms = r.u64()?;
    let cts = read_ciphertexts(r)?;
    r.expect_eof()?;
    Ok((id, session, deadline_ms, cts))
}

/// KEY_BEGIN body: `id u64, session u64, key header`.
fn parse_key_begin(
    r: &mut Reader,
) -> Result<(u64, u64, &'static crate::params::ParamSet), WireError> {
    let id = r.u64()?;
    let session = r.u64()?;
    let p = read_key_header(r)?;
    r.expect_eof()?;
    Ok((id, session, p))
}
