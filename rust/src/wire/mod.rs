//! The network front door: serialization + framing + TCP serving.
//!
//! The paper's deployment story (§1) is a cloud accelerator clients
//! offload encrypted work to — HEAX and MATCHA both sit behind exactly
//! this kind of host interface. Everything below this module is
//! in-process; this module is the boundary where ciphertexts and key
//! material become bytes:
//!
//! - [`codec`] — versioned binary serialization for
//!   [`LweCiphertext`](crate::tfhe::LweCiphertext)s and
//!   [`ServerKeys`](crate::tfhe::ServerKeys). Key material is
//!   **chunked**: the client streams
//!   a WIDE10 key set (~185 MB of `f64`/`u64` planes) as a header plus a
//!   sequence of self-delimiting chunks (one BSK GGSW, or a block of KSK
//!   rows — the same row-granular layout `generate_seeded` produces), and
//!   the server assembles incrementally, so the full key set is never
//!   resident twice on either side of the socket.
//! - [`proto`] — the framed request/response protocol: every message is
//!   `[len: u32 LE][tag: u8][body]` with a hard frame-size bound checked
//!   *before* allocation (a hostile length prefix cannot OOM the server),
//!   and a typed [`Status`] code mapping every
//!   [`ClusterError`](crate::cluster::ClusterError) /
//!   [`RequestError`](crate::coordinator::RequestError) /
//!   [`RegisterError`](crate::tenant::RegisterError) onto the wire.
//! - [`server`] — [`WireServer`]: a `std::net::TcpListener` accept loop
//!   (zero new dependencies) with one thread per connection, bounded
//!   per-connection admission in front of
//!   [`Cluster::submit`](crate::cluster::Cluster::submit), pipelined
//!   id-tagged requests, and key-upload handling that rejects uploads
//!   typed when the cluster cannot hold them
//!   ([`Status::RegisterUnsupported`]) — `StaticKeys::register`'s panic
//!   is unreachable from the network.
//! - [`client`] — [`Client`]: the blocking remote client. Connects,
//!   learns the server's parameter set from the HELLO handshake, uploads
//!   keys chunk-by-chunk, and submits encrypted programs; every server
//!   rejection surfaces as a typed [`WireError::Rejected`].
//!
//! Uploaded keys are the one thing the server cannot regenerate, which is
//! why the upload path lands in
//! [`Cluster::register_session`](crate::cluster::Cluster::register_session):
//! pinned against LRU eviction on every shard store, broadcast so
//! non-affinity routers stay correct, and replayed across reshards.

pub mod client;
pub mod codec;
pub mod proto;
pub mod server;

pub use client::Client;
pub use codec::{KeyAssembly, KeyChunker, CODEC_VERSION};
pub use proto::{Status, MAX_FRAME};
pub use server::{WireServer, WireServerOptions};

use std::fmt;

/// Every way the wire layer fails, typed. Decode errors are values —
/// malformed or hostile input must never panic a server thread.
#[derive(Debug)]
pub enum WireError {
    /// Socket-level I/O failure.
    Io(std::io::Error),
    /// A frame or payload did not decode: truncated input, a bad
    /// magic/version, an out-of-bounds index, or trailing garbage.
    Malformed(String),
    /// A length prefix exceeded the hard bound ([`MAX_FRAME`] for frames,
    /// the per-payload bounds in [`codec`]) — rejected *before* any
    /// allocation.
    TooLarge { len: usize, max: usize },
    /// The codec version byte is not ours ([`CODEC_VERSION`]).
    UnsupportedVersion { got: u8 },
    /// The server answered with a non-OK [`Status`].
    Rejected { status: Status, reason: String },
    /// The peer closed the connection mid-exchange.
    Disconnected,
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "wire i/o: {e}"),
            WireError::Malformed(what) => write!(f, "malformed wire payload: {what}"),
            WireError::TooLarge { len, max } => {
                write!(f, "length prefix {len} exceeds bound {max}")
            }
            WireError::UnsupportedVersion { got } => {
                write!(f, "unsupported codec version {got} (this build speaks {CODEC_VERSION})")
            }
            WireError::Rejected { status, reason } => {
                write!(f, "server rejected ({status:?}): {reason}")
            }
            WireError::Disconnected => f.write_str("peer disconnected"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> Self {
        WireError::Io(e)
    }
}
