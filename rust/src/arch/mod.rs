//! The Taurus accelerator model (paper §IV): a calibrated cycle-level
//! performance model of the 4-cluster BRU/LPU machine, its heterogeneous
//! FFT units, round-robin BSK reuse, synchronization strategy, on-chip
//! buffers and HBM bandwidth — plus the Morphling-style XPU baseline used
//! by Table IV and the area/power model of Tables I/III.
//!
//! Everything is derived from the unit numbers the paper publishes
//! (512 BSK mults/cycle/BRU, FFT cluster = 32x an 8-parallel R2MDC,
//! 1 GHz, two HBM2E stacks at 819 GB/s, 12 round-robin ciphertexts per
//! cluster); a single calibration factor per unit is documented in
//! DESIGN.md §Calibration.

pub mod area;
pub mod bru;
pub mod config;
pub mod lpu;
pub mod memory;
pub mod sim;
pub mod xpu;

pub use config::{SyncStrategy, TaurusConfig};
pub use sim::{simulate, SimResult};
