//! Machine configuration (paper §IV-A / §VI-A defaults).

/// Synchronization strategy across compute clusters (paper §IV-B,
/// Observation 5).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SyncStrategy {
    /// All clusters blind-rotate in lock-step and share one BSK stream
    /// (the default: minimal bandwidth).
    Full,
    /// Clusters split into `groups` independent groups; each streams its
    /// own keys (peak bandwidth multiplies, runtime barely improves).
    Grouped(usize),
}

#[derive(Debug, Clone)]
pub struct TaurusConfig {
    /// Vector-core-like compute clusters (default 4).
    pub clusters: usize,
    /// Round-robin ciphertexts per cluster (default 12; Fig. 13b).
    pub rr_ciphertexts: usize,
    /// Clock (default 1 GHz, §VI-B).
    pub clock_ghz: f64,
    /// BRUs per cluster (two share one IFFT, Fig. 8b).
    pub brus_per_cluster: usize,
    /// Complex BSK multiplications per cycle per BRU (512, §IV-A).
    pub bsk_mults_per_cycle: u64,
    /// FFT cluster throughput in samples/cycle: "32x the throughput of the
    /// 8-parallel R2MDC" = 256 (§IV-C).
    pub fft_samples_per_cycle: u64,
    /// Effective FFT pipeline efficiency (shutter-transpose waits, stage
    /// bypass bubbles, pipeline fill). Calibrated against the paper's
    /// 0.28 ms CNN-20 single-ciphertext bootstrap latency.
    pub fft_efficiency: f64,
    /// LPU MAC throughput per cluster (4 lanes x 64 elements).
    pub lpu_macs_per_cycle: u64,
    /// Off-chip bandwidth, GB/s (two HBM2E stacks, §VI-D).
    pub hbm_bw_gbps: f64,
    /// Per-cluster GLWE accumulator buffer, KB (default 9216, Fig. 14).
    pub acc_buffer_kb: usize,
    /// Bytes per complex BSK/accumulator point: 2 x 48-bit fixed
    /// (Observation 4).
    pub complex_bytes: usize,
    pub sync: SyncStrategy,
}

impl Default for TaurusConfig {
    fn default() -> Self {
        Self {
            clusters: 4,
            rr_ciphertexts: 12,
            clock_ghz: 1.0,
            brus_per_cluster: 2,
            bsk_mults_per_cycle: 512,
            fft_samples_per_cycle: 256,
            fft_efficiency: 0.62,
            lpu_macs_per_cycle: 1024,
            hbm_bw_gbps: 819.0,
            acc_buffer_kb: 9216,
            complex_bytes: 12,
            sync: SyncStrategy::Full,
        }
    }
}

impl TaurusConfig {
    /// Ciphertexts scheduled simultaneously across clusters (48 default).
    pub fn batch_capacity(&self) -> usize {
        self.clusters * self.rr_ciphertexts
    }

    /// Effective FFT samples per cycle per cluster.
    pub fn fft_rate(&self) -> f64 {
        self.fft_samples_per_cycle as f64 * self.fft_efficiency
    }

    /// MAC rate per cluster (both BRUs).
    pub fn mac_rate(&self) -> f64 {
        (self.bsk_mults_per_cycle * self.brus_per_cluster as u64) as f64
    }

    /// Seconds per cycle.
    pub fn cycle_s(&self) -> f64 {
        1e-9 / self.clock_ghz
    }

    /// Number of independent sync groups.
    pub fn sync_groups(&self) -> usize {
        match self.sync {
            SyncStrategy::Full => 1,
            SyncStrategy::Grouped(g) => g.max(1).min(self.clusters),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = TaurusConfig::default();
        assert_eq!(c.batch_capacity(), 48);
        assert_eq!(c.clusters, 4);
        assert_eq!(c.bsk_mults_per_cycle, 512);
        assert_eq!(c.fft_samples_per_cycle, 256);
        assert!((c.hbm_bw_gbps - 819.0).abs() < 1e-9);
        assert_eq!(c.acc_buffer_kb, 9216);
        assert_eq!(c.sync_groups(), 1);
    }

    #[test]
    fn grouped_sync_clamped() {
        let mut c = TaurusConfig::default();
        c.sync = SyncStrategy::Grouped(8);
        assert_eq!(c.sync_groups(), 4);
        c.sync = SyncStrategy::Grouped(2);
        assert_eq!(c.sync_groups(), 2);
    }

    /// The default accumulator buffer holds exactly two complex-domain
    /// GLWE accumulators for each of the 12 round-robin ciphertexts at
    /// N = 32768 (the paper's default sizing, §VI-A).
    #[test]
    fn acc_buffer_sized_for_default_workloads() {
        let c = TaurusConfig::default();
        let p = crate::params::GPT2; // N = 32768, k = 1
        let per_ct = 2 * (p.k + 1) * p.half_n() * c.complex_bytes;
        let need_kb = c.rr_ciphertexts * per_ct / 1024;
        assert_eq!(need_kb, 9216);
    }
}
