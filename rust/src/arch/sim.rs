//! Batch-granularity performance simulator (the paper's "two-stage
//! approach": functional correctness is handled by `compiler::exec` /
//! `runtime`, cycle-level timing within seconds by this model, §VI-C).
//!
//! Walks a compiled schedule keeping BRU and LPU timelines per the Fig. 9
//! pipeline: KS/SE/linear ops on the LPU overlap blind rotation of the
//! previous *independent* batch; dependent batches stall the BRU.

use super::bru;
use super::config::TaurusConfig;
use super::lpu;
use super::memory::{self, Traffic};
use crate::compiler::{Compiled, Schedule};
use crate::params::ParamSet;

#[derive(Debug, Clone, Default)]
pub struct SimResult {
    pub seconds: f64,
    pub cycles: f64,
    /// BRU busy fraction (the utilization of Figs. 14/15).
    pub utilization: f64,
    /// Average and peak DRAM bandwidth over the run, GB/s.
    pub avg_bw_gbps: f64,
    pub peak_bw_gbps: f64,
    pub traffic: Traffic,
    pub batches: usize,
    pub pbs_count: usize,
    /// Key switches the schedule executes (each deduplicated KS costed
    /// once) — directly comparable with the executor's measured
    /// `ExecStats::ks_ops` per request and with `DedupStats::after`.
    pub ks_count: usize,
    /// Fraction of batch windows that were memory-bound ("bandwidth
    /// deficit", Fig. 13b).
    pub bw_deficit: f64,
    /// Amortized Fourier-BSK bytes streamed per PBS over the whole run —
    /// directly comparable with the native pipeline's measured
    /// `MetricsSnapshot::bsk_bytes_per_pbs` (key-reuse cross-check).
    pub bsk_bytes_per_pbs: f64,
}

/// Simulate one compiled program on a Taurus configuration.
pub fn simulate(c: &Compiled, cfg: &TaurusConfig) -> SimResult {
    simulate_schedule(&c.schedule, &c.params, cfg)
}

pub fn simulate_schedule(s: &Schedule, p: &ParamSet, cfg: &TaurusConfig) -> SimResult {
    let cyc = cfg.cycle_s();
    let groups = cfg.sync_groups();
    let clusters_per_group = (cfg.clusters / groups).max(1);
    let br_ct_cycles = bru::blind_rotate_cycles(p, cfg);
    let ks_cycles = lpu::keyswitch_cycles(p, cfg);
    let se_cycles = lpu::sample_extract_cycles(p, cfg);
    let lin_cycles = lpu::linear_op_cycles(p, cfg);

    // One BRU/LPU timeline per synchronization group (paper §IV-B: full
    // sync = one global timeline; grouped = independent groups each
    // streaming their own keys).
    let mut bru_free = vec![0.0f64; groups]; // cycles
    let mut lpu_free = vec![0.0f64; groups];
    let mut bru_busy = 0.0f64;
    let mut total_traffic = Traffic::default();
    let mut mem_bound_windows = 0usize;
    let mut pbs = 0usize;
    let mut ks = 0usize;
    // (start, end, demand GB/s) of each batch's stream for the concurrent
    // peak-demand sweep.
    let mut windows: Vec<(f64, f64, f64)> = Vec::with_capacity(s.batches.len());

    for batch in &s.batches {
        let cts = batch.br_ops.len();
        pbs += cts;
        ks += batch.ks_ops.len();
        // Least-loaded group takes the batch.
        let g = (0..groups).min_by(|&a, &b| bru_free[a].total_cmp(&bru_free[b])).unwrap();
        // --- LPU phase: linear ops + key switches for this batch,
        // distributed over the group's LPUs.
        let lpu_work = (batch.lin_ops.len() as f64 * lin_cycles
            + batch.ks_ops.len() as f64 * ks_cycles
            + batch.se_ops.len() as f64 * se_cycles)
            / clusters_per_group as f64;
        // KS can only start once its inputs exist; if the batch depends on
        // the previous level's BR outputs it must wait for ALL groups
        // (results may come from any of them).
        let dep_ready =
            if batch.depends_on_prev { bru_free.iter().cloned().fold(0.0, f64::max) } else { 0.0 };
        let ks_start = lpu_free[g].max(dep_ready);
        let ks_end = ks_start + lpu_work;
        lpu_free[g] = ks_end;

        // --- BRU phase: per-cluster round-robin over this batch's cts
        // (compute is total work per cluster; RR depth only affects BSK
        // restreaming, accounted in batch_traffic).
        let per_cluster = cts.div_ceil(clusters_per_group).max(1);
        let compute = per_cluster as f64 * br_ct_cycles;
        let traffic = memory::batch_traffic(p, cfg, cts);
        let mem = traffic.total() as f64 / (cfg.hbm_bw_gbps * 1e9) / cyc; // cycles
        let window = compute.max(mem);
        if mem > compute {
            mem_bound_windows += 1;
        }
        let br_start = bru_free[g].max(ks_end);
        let br_end = br_start + window;
        bru_free[g] = br_end;
        bru_busy += compute;

        total_traffic.bsk += traffic.bsk;
        total_traffic.ksk += traffic.ksk;
        total_traffic.glwe += traffic.glwe;
        total_traffic.lwe += traffic.lwe;
        total_traffic.swap += traffic.swap;
        // Demand = what the stream would need to never stall the BRU,
        // capped at what the HBM can actually deliver to one stream;
        // concurrent groups sum (Observation 5's bandwidth cost).
        let demand =
            (traffic.total() as f64 / (compute.max(1.0) * cyc) / 1e9).min(cfg.hbm_bw_gbps);
        windows.push((br_start, br_end, demand));
    }
    // Loose linear ops (pure-linear tail) on group 0.
    if !s.loose_linear.is_empty() {
        lpu_free[0] += s.loose_linear.len() as f64 * lin_cycles / clusters_per_group as f64;
    }

    // Peak concurrent bandwidth demand: sweep over window boundaries.
    let mut events: Vec<(f64, f64)> = Vec::with_capacity(2 * windows.len());
    for &(a, b, d) in &windows {
        events.push((a, d));
        events.push((b, -d));
    }
    events.sort_by(|x, y| x.0.total_cmp(&y.0).then(x.1.total_cmp(&y.1)));
    let mut cur = 0.0f64;
    let mut peak_bw = 0.0f64;
    for (_, d) in events {
        cur += d;
        peak_bw = peak_bw.max(cur);
    }

    let total_cycles = bru_free
        .iter()
        .chain(lpu_free.iter())
        .cloned()
        .fold(1.0f64, f64::max);
    let seconds = total_cycles * cyc;
    SimResult {
        seconds,
        cycles: total_cycles,
        utilization: (bru_busy / (total_cycles * groups as f64)).min(1.0),
        avg_bw_gbps: total_traffic.total() as f64 / seconds / 1e9,
        peak_bw_gbps: peak_bw,
        traffic: total_traffic,
        batches: s.batches.len(),
        pbs_count: pbs,
        ks_count: ks,
        bw_deficit: if s.batches.is_empty() {
            0.0
        } else {
            mem_bound_windows as f64 / s.batches.len() as f64
        },
        bsk_bytes_per_pbs: if pbs > 0 { total_traffic.bsk as f64 / pbs as f64 } else { 0.0 },
    }
}

/// Per-schedule-batch model predictions for cost-model drift attribution
/// ([`crate::obs::drift`]): the same walk as [`simulate_schedule`], but
/// reported per batch instead of rolled up. Counts are exactly the
/// schedule's per-request op lists (what the executor runs once per
/// request); `bsk_bytes` and `seconds` are the batch's own window cost,
/// independent of cross-batch dependency stalls.
pub fn batch_predictions(
    s: &Schedule,
    p: &ParamSet,
    cfg: &TaurusConfig,
) -> Vec<crate::obs::drift::BatchPrediction> {
    let cyc = cfg.cycle_s();
    let groups = cfg.sync_groups();
    let clusters_per_group = (cfg.clusters / groups).max(1);
    let br_ct_cycles = bru::blind_rotate_cycles(p, cfg);
    let ks_cycles = lpu::keyswitch_cycles(p, cfg);
    let se_cycles = lpu::sample_extract_cycles(p, cfg);
    let lin_cycles = lpu::linear_op_cycles(p, cfg);
    s.batches
        .iter()
        .map(|batch| {
            let cts = batch.br_ops.len();
            let lpu_work = (batch.lin_ops.len() as f64 * lin_cycles
                + batch.ks_ops.len() as f64 * ks_cycles
                + batch.se_ops.len() as f64 * se_cycles)
                / clusters_per_group as f64;
            let per_cluster = cts.div_ceil(clusters_per_group).max(1);
            let compute = per_cluster as f64 * br_ct_cycles;
            let traffic = memory::batch_traffic(p, cfg, cts);
            let mem = traffic.total() as f64 / (cfg.hbm_bw_gbps * 1e9) / cyc;
            crate::obs::drift::BatchPrediction {
                ks: batch.ks_ops.len() as u64,
                pbs: cts as u64,
                bsk_bytes: traffic.bsk,
                seconds: (lpu_work + compute.max(mem)) * cyc,
            }
        })
        .collect()
}

/// Throughput metric for design-space sweeps (Fig. 13b): bootstraps/sec at
/// steady state on a saturated independent workload.
pub fn steady_state_pbs_per_s(p: &ParamSet, cfg: &TaurusConfig) -> f64 {
    let compute = cfg.rr_ciphertexts as f64 * bru::blind_rotate_cycles(p, cfg);
    let traffic = memory::batch_traffic(p, cfg, cfg.batch_capacity());
    let mem = traffic.total() as f64 / (cfg.hbm_bw_gbps * 1e9) / cfg.cycle_s();
    let window_s = compute.max(mem) * cfg.cycle_s();
    cfg.batch_capacity() as f64 / window_s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::compile;
    use crate::ir::builder::ProgramBuilder;
    use crate::params::{GPT2, TEST1};

    fn wide(n: usize, width: usize) -> crate::ir::Program {
        let mut b = ProgramBuilder::new("wide", width);
        let xs = b.inputs(n);
        for x in xs {
            let y = b.lut_fn(x, |m| m);
            b.output(y);
        }
        b.finish()
    }

    fn chain(len: usize, width: usize) -> crate::ir::Program {
        let mut b = ProgramBuilder::new("chain", width);
        let mut x = b.input();
        for _ in 0..len {
            x = b.lut_fn(x, |m| m);
        }
        b.output(x);
        b.finish()
    }

    #[test]
    fn full_batches_beat_serial_chains() {
        let cfg = TaurusConfig::default();
        let wide_r = simulate(&compile(&wide(96, 6), &GPT2, cfg.batch_capacity()), &cfg);
        let chain_r = simulate(&compile(&chain(96, 6), &GPT2, cfg.batch_capacity()), &cfg);
        assert_eq!(wide_r.pbs_count, chain_r.pbs_count);
        assert!(
            chain_r.seconds > 10.0 * wide_r.seconds,
            "serial {} vs wide {}",
            chain_r.seconds,
            wide_r.seconds
        );
        assert!(wide_r.utilization > 0.5);
        assert!(chain_r.utilization < 0.2);
    }

    #[test]
    fn more_parallelism_does_not_slow_down() {
        let cfg = TaurusConfig::default();
        let a = simulate(&compile(&wide(48, 6), &GPT2, 48usize), &cfg);
        let b = simulate(&compile(&wide(96, 6), &GPT2, 48usize), &cfg);
        // Twice the work in about twice the time (steady-state linearity).
        let ratio = b.seconds / a.seconds;
        assert!(ratio > 1.7 && ratio < 2.3, "ratio {ratio}");
    }

    #[test]
    fn rr_sweep_has_knee_then_plateau() {
        // Fig. 13b: throughput rises with round-robin ciphertexts until the
        // BSK stream is amortized, then plateaus.
        let mut cfg = TaurusConfig::default();
        let mut last = 0.0f64;
        let mut gains = vec![];
        for rr in [2usize, 4, 8, 12, 16, 24] {
            cfg.rr_ciphertexts = rr;
            let t = steady_state_pbs_per_s(&GPT2, &cfg);
            gains.push(t / last.max(1e-9));
            last = t;
        }
        // Early steps gain, late steps plateau.
        assert!(gains[1] > 1.5, "2->4 should gain: {gains:?}");
        let tail = gains[gains.len() - 1];
        assert!(tail < 1.1, "16->24 should plateau: {gains:?}");
    }

    #[test]
    fn grouped_sync_small_speedup_big_bandwidth() {
        // Observation 5.
        let base_cfg = TaurusConfig::default();
        let prog = wide(96, 6);
        let c = compile(&prog, &GPT2, base_cfg.batch_capacity());
        let full = simulate(&c, &base_cfg);
        let mut gcfg = base_cfg.clone();
        gcfg.sync = super::super::config::SyncStrategy::Grouped(2);
        let grouped = simulate(&c, &gcfg);
        let speedup = full.seconds / grouped.seconds;
        assert!(speedup < 1.1, "grouped speedup {speedup}");
        assert!(
            grouped.peak_bw_gbps > 1.5 * full.peak_bw_gbps,
            "grouped {} vs full {}",
            grouped.peak_bw_gbps,
            full.peak_bw_gbps
        );
    }

    #[test]
    fn bandwidth_within_two_hbm_stacks() {
        // Fig. 13a: defaults stay under 819 GB/s.
        let cfg = TaurusConfig::default();
        let c = compile(&wide(192, 6), &GPT2, cfg.batch_capacity());
        let r = simulate(&c, &cfg);
        assert!(r.avg_bw_gbps < 819.0, "avg {}", r.avg_bw_gbps);
    }

    #[test]
    fn small_params_simulate_fast_and_nonzero() {
        let cfg = TaurusConfig::default();
        let c = compile(&wide(10, 3), &TEST1, cfg.batch_capacity());
        let r = simulate(&c, &cfg);
        assert!(r.seconds > 0.0 && r.seconds < 1.0);
        assert_eq!(r.pbs_count, 10);
    }

    #[test]
    fn costed_ks_count_matches_dedup() {
        // The model costs exactly the deduplicated KS set the executor
        // runs: a fanout program compiles to one shared KS per source.
        let cfg = TaurusConfig::default();
        let mut b = ProgramBuilder::new("fan", 6);
        let x = b.input();
        for k in 0..6u64 {
            let y = b.lut_fn(x, move |m| m + k);
            b.output(y);
        }
        let c = compile(&b.finish(), &GPT2, cfg.batch_capacity());
        assert_eq!(c.ks_dedup.after, 1);
        let r = simulate(&c, &cfg);
        assert_eq!(r.ks_count, c.ks_dedup.after);
        assert_eq!(r.pbs_count, 6);
    }

    #[test]
    fn batch_predictions_sum_to_the_rolled_up_sim() {
        let cfg = TaurusConfig::default();
        let c = compile(&wide(48, 6), &GPT2, cfg.batch_capacity());
        let r = simulate(&c, &cfg);
        let per_batch = batch_predictions(&c.schedule, &c.params, &cfg);
        assert_eq!(per_batch.len(), r.batches);
        let ks: u64 = per_batch.iter().map(|b| b.ks).sum();
        let pbs: u64 = per_batch.iter().map(|b| b.pbs).sum();
        let bsk: u64 = per_batch.iter().map(|b| b.bsk_bytes).sum();
        assert_eq!(ks, r.ks_count as u64);
        assert_eq!(pbs, r.pbs_count as u64);
        assert_eq!(bsk, r.traffic.bsk, "per-batch BSK streams sum to the total");
        assert!(per_batch.iter().all(|b| b.seconds > 0.0));
    }

    #[test]
    fn amortized_bsk_bytes_reported_and_batch_sensitive() {
        // Fully parallel program: one 48-ct batch amortizes the stream
        // ~48x relative to a fully serial chain of the same PBS count.
        let cfg = TaurusConfig::default();
        let wide_r = simulate(&compile(&wide(48, 6), &GPT2, cfg.batch_capacity()), &cfg);
        let chain_r = simulate(&compile(&chain(48, 6), &GPT2, cfg.batch_capacity()), &cfg);
        assert!(wide_r.bsk_bytes_per_pbs > 0.0);
        let ratio = chain_r.bsk_bytes_per_pbs / wide_r.bsk_bytes_per_pbs;
        assert!(ratio > 10.0, "serial should pay far more BSK/PBS: ratio {ratio}");
        let model =
            super::super::memory::amortized_bsk_bytes_per_pbs(&GPT2, &cfg, cfg.batch_capacity());
        let rel = (wide_r.bsk_bytes_per_pbs - model).abs() / model;
        assert!(rel < 1e-9, "sim {} vs memory model {}", wide_r.bsk_bytes_per_pbs, model);
    }
}
