//! Area/power model (paper Table I, TSMC N16 @ 1 GHz) and the
//! cross-accelerator comparison of Table III.
//!
//! Component constants are the paper's synthesized values; the model
//! scales them with the configuration (unit counts, buffer KB) so
//! design-space sweeps report area honestly.

use super::config::TaurusConfig;

#[derive(Debug, Clone)]
pub struct Component {
    pub name: &'static str,
    pub area_mm2: f64,
    pub power_w: f64,
    /// Instances per cluster (0 = global, counted once).
    pub per_cluster: usize,
}

/// Paper Table I per-component values.
///
/// Layout decoded from the table's arithmetic: each of the 4 clusters has
/// one BRU (= the seven compute units whose areas sum to the table's BRU
/// row, 12.41 mm^2), one LPU and its private acc/GLWE/LWE buffers; each
/// *pair* of clusters shares one I-FFT (Fig. 8b); the GGSW/KSK/twiddle
/// buffers and NoC are global. 4x(12.41+1.32+9.83+1.88+0.02) + 2x5.65 +
/// 3.27 = 116.5 mm^2 and the same structure gives 167.3 W — both match.
pub fn components(cfg: &TaurusConfig) -> Vec<Component> {
    let c = cfg.clusters;
    let ifft_count = c.div_ceil(2);
    // SRAM density from the table: acc buf 9.2MB = 9.83 mm^2.
    let sram_mm2_per_kb = 9.83 / 9216.0;
    let sram_w_per_kb = 3.11 / 9216.0;
    let acc_kb = cfg.acc_buffer_kb as f64;
    vec![
        Component { name: "Decomposer", area_mm2: 0.24, power_w: 0.65, per_cluster: c },
        Component { name: "2x FFT-A", area_mm2: 1.57, power_w: 2.95, per_cluster: c },
        Component { name: "FFT-B", area_mm2: 1.88, power_w: 4.12, per_cluster: c },
        Component { name: "VecMAC", area_mm2: 4.27, power_w: 8.41, per_cluster: c },
        Component { name: "Rotator", area_mm2: 0.18, power_w: 0.63, per_cluster: c },
        Component { name: "Transpose", area_mm2: 2.20, power_w: 7.16, per_cluster: c },
        Component { name: "VecMult", area_mm2: 2.06, power_w: 4.06, per_cluster: c },
        Component { name: "ModSwitch", area_mm2: 0.005, power_w: 0.005, per_cluster: c },
        Component { name: "I-FFT", area_mm2: 5.65, power_w: 18.30, per_cluster: ifft_count },
        Component {
            name: "Acc buf.",
            area_mm2: sram_mm2_per_kb * acc_kb,
            power_w: sram_w_per_kb * acc_kb,
            per_cluster: c,
        },
        Component { name: "GLWE buf. (1.5MB)", area_mm2: 1.88, power_w: 0.52, per_cluster: c },
        Component { name: "LWE buf. (24KB)", area_mm2: 0.02, power_w: 0.005, per_cluster: c },
        Component { name: "LPU", area_mm2: 1.32, power_w: 0.61, per_cluster: c },
        // Globals.
        Component { name: "GGSW buf. (0.8MB)", area_mm2: 1.22, power_w: 0.91, per_cluster: 0 },
        Component { name: "KSK buf. (0.5MB)", area_mm2: 0.50, power_w: 0.07, per_cluster: 0 },
        Component { name: "Twiddle buf. (0.8MB)", area_mm2: 1.39, power_w: 0.27, per_cluster: 0 },
        Component { name: "NoC", area_mm2: 0.16, power_w: 0.43, per_cluster: 0 },
    ]
}

/// BRU subtotal per cluster (paper: 12.41 mm^2, 28.01 W) — the compute
/// units that implement blind rotation (excl. I-FFT which is shared).
pub fn bru_subtotal(cfg: &TaurusConfig) -> (f64, f64) {
    let wanted = ["Decomposer", "2x FFT-A", "FFT-B", "VecMAC", "Rotator", "Transpose", "VecMult"];
    let mut a = 0.0;
    let mut p = 0.0;
    for comp in components(cfg) {
        if wanted.contains(&comp.name) {
            a += comp.area_mm2;
            p += comp.power_w;
        }
    }
    // Two BRUs per cluster share the listed pipeline; the table's BRU row
    // counts the per-cluster pair.
    (a, p)
}

/// Total chip area/power for a configuration.
pub fn totals(cfg: &TaurusConfig) -> (f64, f64) {
    let mut area = 0.0;
    let mut power = 0.0;
    for comp in components(cfg) {
        let mult = if comp.per_cluster == 0 { 1.0 } else { comp.per_cluster as f64 };
        area += comp.area_mm2 * mult;
        power += comp.power_w * mult;
    }
    (area, power)
}

// ---------------------------------------------------------------------------
// Table III: prior accelerators (reported + 16 nm-scaled areas from the
// paper, Stillmaker-Baas scaling) and PolyMult throughput per unit area.
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct AcceleratorRow {
    pub name: &'static str,
    pub reported_area_mm2: f64,
    pub area_16nm_mm2: f64,
    /// PolyMult throughput per unit area (the paper's Table III metric,
    /// measured at k = 1).
    pub polymult_per_area: f64,
}

/// Calibration: the paper's Table III metric for the default Taurus config
/// (4 clusters x 256 samples/cyc, 116.52 mm^2) is 17.58. We scale other
/// configurations by raw FFT sample throughput / modeled area so sweeps
/// stay honest; prior accelerators carry their published values
/// (DESIGN.md §Substitutions).
const TAURUS_T3_CALIB: f64 = 17.58 / (1024.0 / 116.52);

pub fn taurus_polymult_per_area(cfg: &TaurusConfig) -> f64 {
    let (area, _) = totals(cfg);
    let samples_per_cycle = (cfg.fft_samples_per_cycle * cfg.clusters as u64) as f64;
    TAURUS_T3_CALIB * samples_per_cycle * cfg.clock_ghz / area
}

pub fn table3_rows(cfg: &TaurusConfig) -> Vec<AcceleratorRow> {
    let (area, _) = totals(cfg);
    vec![
        AcceleratorRow { name: "Strix", reported_area_mm2: 141.37, area_16nm_mm2: 52.69, polymult_per_area: 1.21 },
        AcceleratorRow { name: "MATCHA", reported_area_mm2: 36.96, area_16nm_mm2: 25.08, polymult_per_area: 1.27 },
        AcceleratorRow { name: "Morphling", reported_area_mm2: 74.79, area_16nm_mm2: 24.95, polymult_per_area: 10.25 },
        AcceleratorRow {
            name: "Taurus",
            reported_area_mm2: area,
            area_16nm_mm2: area,
            polymult_per_area: taurus_polymult_per_area(cfg),
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_match_paper_table1() {
        let cfg = TaurusConfig::default();
        let (area, power) = totals(&cfg);
        // Paper: 116.52 mm^2, 167.30 W.
        assert!((area - 116.52).abs() / 116.52 < 0.10, "area {area}");
        assert!((power - 167.30).abs() / 167.30 < 0.15, "power {power}");
    }

    #[test]
    fn bru_subtotal_near_paper() {
        let (a, p) = bru_subtotal(&TaurusConfig::default());
        assert!((a - 12.41).abs() < 1.0, "bru area {a}");
        assert!((p - 28.01).abs() < 3.0, "bru power {p}");
    }

    #[test]
    fn area_scales_with_clusters_and_buffer() {
        let mut cfg = TaurusConfig::default();
        let (a4, _) = totals(&cfg);
        cfg.clusters = 8;
        let (a8, _) = totals(&cfg);
        assert!(a8 > 1.8 * a4 * 0.9 && a8 < 2.0 * a4, "{a4} -> {a8}");
        cfg.clusters = 4;
        cfg.acc_buffer_kb = 4608;
        let (a_small, _) = totals(&cfg);
        assert!(a_small < a4);
    }

    #[test]
    fn taurus_tops_polymult_per_area() {
        // Table III headline: Taurus has the best PolyMult/area (17.58 at
        // default config) while supporting 2^16-degree polynomials.
        let cfg = TaurusConfig::default();
        let rows = table3_rows(&cfg);
        let taurus = rows.last().unwrap().polymult_per_area;
        assert!((taurus - 17.58).abs() < 0.5, "taurus {taurus}");
        for r in &rows[..rows.len() - 1] {
            assert!(taurus > r.polymult_per_area, "vs {}", r.name);
        }
    }
}
