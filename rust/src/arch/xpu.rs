//! Morphling-style XPU baseline (paper §VI-E, Table IV): the same machine
//! with the BRU replaced by an output-stationary systolic array fed by
//! 8-parallel R2MDC FFT units, extended (as the paper did) to the larger
//! polynomial degrees of multi-bit TFHE.
//!
//! Key differences modeled (paper §III-B):
//! * FFT throughput: 4 rows x 8 samples/cycle = 32 samples/cycle vs the
//!   heterogeneous FFT cluster's 256.
//! * Horizontal reuse requires k+1 polynomials; at k=1 only 2 of 4 PEs in
//!   a row are used (50% idle) — but the FFT is the bottleneck anyway.
//! * BSK chunks pass down columns (vertical reuse over 4 rows), so the
//!   BSK streams once per 4 ciphertexts rather than once per 48 —
//!   bandwidth scales with ciphertext count / 4.

use super::config::TaurusConfig;
use super::lpu;
use crate::compiler::{Compiled, Schedule};
use crate::params::ParamSet;

#[derive(Debug, Clone)]
pub struct XpuConfig {
    /// Samples/cycle of one R2MDC FFT unit.
    pub r2mdc_samples_per_cycle: u64,
    /// Systolic rows (each with its own FFTU).
    pub rows: usize,
    /// PEs per row (horizontal reuse limit k+1).
    pub pes_per_row: usize,
    pub base: TaurusConfig,
}

impl Default for XpuConfig {
    fn default() -> Self {
        Self { r2mdc_samples_per_cycle: 8, rows: 4, pes_per_row: 4, base: TaurusConfig::default() }
    }
}

impl XpuConfig {
    /// Concurrent ciphertexts: one per systolic row, one XPU array per
    /// cluster (the Table IV variant swaps each BRU for an XPU).
    pub fn concurrent_cts(&self) -> usize {
        self.rows * self.base.clusters
    }

    /// FFT samples/cycle across the array.
    pub fn fft_rate(&self) -> f64 {
        (self.r2mdc_samples_per_cycle * self.rows as u64) as f64
    }
}

/// Blind-rotation cycles for ONE ciphertext on the XPU (it owns one row's
/// FFTU; the systolic array is FFT-fed).
pub fn blind_rotate_cycles(p: &ParamSet, x: &XpuConfig) -> f64 {
    let per_row_rate = x.r2mdc_samples_per_cycle as f64;
    let samples = ((p.ggsw_rows() + p.k + 1) * p.half_n()) as f64;
    p.n as f64 * samples / per_row_rate
}

/// Simulate a compiled schedule on the XPU variant.
pub fn simulate_xpu(c: &Compiled, x: &XpuConfig) -> super::sim::SimResult {
    simulate_schedule_xpu(&c.schedule, &c.params, x)
}

pub fn simulate_schedule_xpu(s: &Schedule, p: &ParamSet, x: &XpuConfig) -> super::sim::SimResult {
    let cfg = &x.base;
    let cyc = cfg.cycle_s();
    let br_ct = blind_rotate_cycles(p, x);
    let ks_cycles = lpu::keyswitch_cycles(p, cfg);
    let se_cycles = lpu::sample_extract_cycles(p, cfg);
    let lin_cycles = lpu::linear_op_cycles(p, cfg);
    let mut bru_free = 0.0f64;
    let mut lpu_free = 0.0f64;
    let mut busy = 0.0f64;
    let mut traffic = super::memory::Traffic::default();
    let mut peak_bw: f64 = 0.0;
    let mut mem_bound = 0usize;
    let mut pbs = 0usize;
    let mut ks = 0usize;
    for batch in &s.batches {
        let cts = batch.br_ops.len();
        pbs += cts;
        ks += batch.ks_ops.len();
        let lpu_work = (batch.lin_ops.len() as f64 * lin_cycles
            + batch.ks_ops.len() as f64 * ks_cycles
            + batch.se_ops.len() as f64 * se_cycles)
            / cfg.clusters as f64;
        let ks_start = if batch.depends_on_prev { lpu_free.max(bru_free) } else { lpu_free };
        lpu_free = ks_start + lpu_work;
        // Each cluster's array runs `rows` ciphertexts concurrently (one
        // per row, each row owning an 8-sample/cycle FFTU); waves of
        // rows x clusters.
        let waves = cts.div_ceil(x.concurrent_cts()).max(1);
        let compute = waves as f64 * br_ct;
        // BSK streams once per wave (vertical reuse covers only the rows).
        let bsk = super::memory::bsk_stream_bytes(p, cfg) * waves as u64;
        let ksk = super::memory::ksk_stream_bytes(p);
        let glwe = (cts * 2 * p.glwe_bytes()) as u64;
        let lwe = (cts * 2 * p.lwe_bytes()) as u64;
        let total = bsk + ksk + glwe + lwe;
        let mem = total as f64 / (cfg.hbm_bw_gbps * 1e9) / cyc;
        let window = compute.max(mem);
        if mem > compute {
            mem_bound += 1;
        }
        let br_start = bru_free.max(lpu_free);
        bru_free = br_start + window;
        busy += compute;
        traffic.bsk += bsk;
        traffic.ksk += ksk;
        traffic.glwe += glwe;
        traffic.lwe += lwe;
        peak_bw = peak_bw.max(total as f64 / (window * cyc) / 1e9);
    }
    let total_cycles = bru_free.max(lpu_free).max(1.0);
    super::sim::SimResult {
        seconds: total_cycles * cyc,
        cycles: total_cycles,
        utilization: (busy / total_cycles).min(1.0),
        avg_bw_gbps: traffic.total() as f64 / (total_cycles * cyc) / 1e9,
        peak_bw_gbps: peak_bw,
        traffic,
        batches: s.batches.len(),
        pbs_count: pbs,
        ks_count: ks,
        bw_deficit: if s.batches.is_empty() { 0.0 } else { mem_bound as f64 / s.batches.len() as f64 },
        bsk_bytes_per_pbs: if pbs > 0 { traffic.bsk as f64 / pbs as f64 } else { 0.0 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::sim::simulate;
    use crate::compiler::compile;
    use crate::ir::builder::ProgramBuilder;
    use crate::params::GPT2;

    fn wide(n: usize) -> crate::ir::Program {
        let mut b = ProgramBuilder::new("w", 6);
        let xs = b.inputs(n);
        for x in xs {
            let y = b.lut_fn(x, |m| m);
            b.output(y);
        }
        b.finish()
    }

    #[test]
    fn taurus_beats_xpu_by_paper_margin_on_parallel_work() {
        // Table IV: ~6.8x on throughput-rich workloads.
        let cfg = TaurusConfig::default();
        let c = compile(&wide(192), &GPT2, cfg.batch_capacity());
        let t = simulate(&c, &cfg);
        let xc = XpuConfig::default();
        let xr = simulate_xpu(&c, &xc);
        let speedup = xr.seconds / t.seconds;
        assert!(speedup > 3.0 && speedup < 10.0, "speedup {speedup}");
    }

    #[test]
    fn xpu_advantage_shrinks_on_serial_work() {
        // Table IV KNN row: only 3.2x — serial workloads leave Taurus
        // underutilized while the XPU's 4-wide rows suffer less.
        let cfg = TaurusConfig::default();
        let mut b = ProgramBuilder::new("serial", 6);
        let mut x = b.input();
        for _ in 0..20 {
            x = b.lut_fn(x, |m| m);
        }
        b.output(x);
        let c = compile(&b.finish(), &GPT2, cfg.batch_capacity());
        let t = simulate(&c, &cfg);
        let xr = simulate_xpu(&c, &XpuConfig::default());
        let serial_speedup = xr.seconds / t.seconds;
        let cpar = compile(&wide(192), &GPT2, cfg.batch_capacity());
        let par_speedup =
            simulate_xpu(&cpar, &XpuConfig::default()).seconds / simulate(&cpar, &cfg).seconds;
        assert!(
            serial_speedup < par_speedup,
            "serial {serial_speedup} vs parallel {par_speedup}"
        );
    }
}
