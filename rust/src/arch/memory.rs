//! Memory-subsystem model: HBM traffic per batch, accumulator-buffer
//! capacity and the swap/restream behaviour behind Figs. 13 and 14.

use super::config::TaurusConfig;
use crate::params::ParamSet;

/// Traffic breakdown for one scheduled batch, bytes.
#[derive(Debug, Clone, Copy, Default)]
pub struct Traffic {
    pub bsk: u64,
    pub ksk: u64,
    pub glwe: u64,
    pub lwe: u64,
    pub swap: u64,
}

impl Traffic {
    pub fn total(&self) -> u64 {
        self.bsk + self.ksk + self.glwe + self.lwe + self.swap
    }
}

/// Fourier-domain BSK bytes (what actually streams to the BRUs).
pub fn bsk_stream_bytes(p: &ParamSet, cfg: &TaurusConfig) -> u64 {
    (p.n * p.ggsw_rows() * (p.k + 1) * p.half_n() * cfg.complex_bytes) as u64
}

/// KSK bytes (torus domain, streamed to the LPUs).
pub fn ksk_stream_bytes(p: &ParamSet) -> u64 {
    p.ksk_bytes() as u64
}

/// Complex-domain accumulator bytes for one ciphertext: two GLWE
/// accumulators (ping/pong), (k+1) polys of N/2 points (§VI-A).
pub fn acc_bytes_per_ct(p: &ParamSet, cfg: &TaurusConfig) -> usize {
    2 * (p.k + 1) * p.half_n() * cfg.complex_bytes
}

/// How many round-robin ciphertexts fit in the accumulator buffer; at
/// least 1 (a single ciphertext's working set is swapped per-iteration if
/// even one doesn't fit — the Fig. 14 cliff).
pub fn resident_cts(p: &ParamSet, cfg: &TaurusConfig) -> usize {
    (cfg.acc_buffer_kb * 1024 / acc_bytes_per_ct(p, cfg)).max(1)
}

/// Traffic for one batch of `cts` ciphertexts spread over the clusters,
/// each cluster running `per_cluster` of them round-robin.
///
/// With full synchronization the BSK/KSK stream is shared by all clusters
/// (Fig. 13a: flat in cluster count); if the buffer holds fewer than
/// `per_cluster` accumulators the BSK is re-streamed `rounds` times and
/// the non-resident accumulators spill (Fig. 14).
pub fn batch_traffic(p: &ParamSet, cfg: &TaurusConfig, cts: usize) -> Traffic {
    let clusters = (cfg.clusters / cfg.sync_groups()).max(1);
    let per_cluster = cts.div_ceil(clusters).max(1);
    // In-flight ciphertexts are bounded by both the round-robin depth and
    // the accumulator-buffer residency; each extra round restreams the BSK.
    let in_flight = resident_cts(p, cfg).min(cfg.rr_ciphertexts).max(1);
    let rounds = per_cluster.div_ceil(in_flight) as u64;
    let mut t = Traffic::default();
    t.bsk = bsk_stream_bytes(p, cfg) * rounds;
    t.ksk = ksk_stream_bytes(p);
    // Each ciphertext's LUT accumulator in, result GLWE out (torus domain).
    t.glwe = (cts * 2 * p.glwe_bytes()) as u64;
    // Long LWE in and out per ciphertext.
    t.lwe = (cts * 2 * p.lwe_bytes()) as u64;
    // Non-resident accumulators spill once per round beyond the first.
    if rounds > 1 {
        let spill_cts = per_cluster.saturating_sub(in_flight);
        t.swap = (spill_cts * acc_bytes_per_ct(p, cfg) * clusters) as u64 * 2 * (rounds - 1);
    }
    t
}

/// Amortized Fourier-BSK bytes streamed per PBS for one batch of `cts`
/// ciphertexts — the model-side counterpart of the native pipeline's
/// measured `bsk_bytes_streamed / pbs` (key reuse divides the stream by
/// the in-flight batch; restreaming rounds multiply it back).
pub fn amortized_bsk_bytes_per_pbs(p: &ParamSet, cfg: &TaurusConfig, cts: usize) -> f64 {
    batch_traffic(p, cfg, cts).bsk as f64 / cts.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::{CNN20, DECISION_TREE, GPT2};

    #[test]
    fn default_buffer_fits_12_cts_at_n_32768() {
        let cfg = TaurusConfig::default();
        assert_eq!(resident_cts(&GPT2, &cfg), 12);
        // N = 65536 does NOT fit 12 (Fig. 14: swap point varies with N).
        assert!(resident_cts(&DECISION_TREE, &cfg) < 12);
        // Small N fits with room to spare.
        assert!(resident_cts(&CNN20, &cfg) > 48);
    }

    #[test]
    fn bsk_shared_flat_across_clusters() {
        // Fig. 13a: BSK/KSK bandwidth constant in cluster count, GLWE/LWE
        // linear.
        let mut cfg = TaurusConfig::default();
        let p = &GPT2;
        cfg.clusters = 2;
        let t2 = batch_traffic(p, &cfg, 2 * cfg.rr_ciphertexts);
        cfg.clusters = 8;
        let t8 = batch_traffic(p, &cfg, 8 * cfg.rr_ciphertexts);
        assert_eq!(t2.bsk, t8.bsk);
        assert_eq!(t2.ksk, t8.ksk);
        assert_eq!(t8.glwe, 4 * t2.glwe);
        assert_eq!(t8.lwe, 4 * t2.lwe);
    }

    #[test]
    fn shrinking_buffer_restreams_bsk() {
        let p = &DECISION_TREE;
        let mut cfg = TaurusConfig::default();
        let t_default = batch_traffic(p, &cfg, 48);
        cfg.acc_buffer_kb = 2048; // starve the accumulator buffer
        let t_small = batch_traffic(p, &cfg, 48);
        assert!(t_small.bsk > t_default.bsk, "BSK restreamed");
        assert!(t_small.swap > 0, "accumulators spill");
    }

    #[test]
    fn amortized_bsk_traffic_scales_inversely_with_batch() {
        // Key reuse: doubling the in-flight batch halves BSK bytes/PBS as
        // long as everything stays resident (one stream shared by all).
        let mut cfg = TaurusConfig::default();
        cfg.clusters = 1;
        cfg.rr_ciphertexts = 16;
        let p = &GPT2;
        let b1 = amortized_bsk_bytes_per_pbs(p, &cfg, 1);
        let b8 = amortized_bsk_bytes_per_pbs(p, &cfg, 8);
        assert_eq!(b1, bsk_stream_bytes(p, &cfg) as f64);
        assert!((b1 / b8 - 8.0).abs() < 1e-9, "b1/b8 = {}", b1 / b8);
    }

    #[test]
    fn grouped_sync_per_batch_traffic_unchanged() {
        // Each group streams its own keys for its own batches, so per-batch
        // volume is unchanged; the doubling appears as *concurrent demand*
        // when both groups stream at once (asserted in sim::tests).
        let p = &GPT2;
        let mut cfg = TaurusConfig::default();
        let full = batch_traffic(p, &cfg, 48);
        cfg.sync = super::super::config::SyncStrategy::Grouped(2);
        // A group owns half the clusters, so its natural batch is 24 cts.
        let grouped = batch_traffic(p, &cfg, 24);
        assert_eq!(grouped.bsk, full.bsk);
        assert_eq!(grouped.ksk, full.ksk);
        // Oversized batches on a group restream the BSK (RR depth limit).
        let oversized = batch_traffic(p, &cfg, 48);
        assert_eq!(oversized.bsk, 2 * full.bsk);
    }
}
