//! LPU timing model: key switching, sample extraction, mod switch and
//! linear ops on LWE ciphertexts (paper §IV-A).

use super::config::TaurusConfig;
use crate::params::ParamSet;

/// MACs in one key switch: kN input coefficients x ks_level digits x
/// (n+1)-element KSK rows.
pub fn ks_macs(p: &ParamSet) -> u64 {
    (p.long_dim() * p.ks_level * (p.n + 1)) as u64
}

/// Cycles for one key switch on one cluster's LPU.
pub fn keyswitch_cycles(p: &ParamSet, cfg: &TaurusConfig) -> f64 {
    ks_macs(p) as f64 / cfg.lpu_macs_per_cycle as f64
}

/// Sample extraction is a copy/negate pass over kN+1 elements.
pub fn sample_extract_cycles(p: &ParamSet, cfg: &TaurusConfig) -> f64 {
    (p.long_dim() + 1) as f64 / cfg.lpu_macs_per_cycle as f64
}

/// One linear op (add / plaintext-mul / one dot term) over a long LWE.
pub fn linear_op_cycles(p: &ParamSet, cfg: &TaurusConfig) -> f64 {
    (p.long_dim() + 1) as f64 / cfg.lpu_macs_per_cycle as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::bru;
    use crate::params::{CNN20, DECISION_TREE, GPT2, PAPER_SETS};

    #[test]
    fn keyswitch_under_a_third_of_blind_rotate() {
        // Footnote 9: "four lanes are enough to complete key-switching and
        // the associated linear operations before blind rotation finishes
        // across all tested parameter sets."
        let cfg = TaurusConfig::default();
        for p in PAPER_SETS {
            let ks = keyswitch_cycles(p, &cfg);
            let br = bru::blind_rotate_cycles(p, &cfg);
            assert!(ks < br * 0.55, "{}: ks {ks} vs br {br}", p.name);
        }
    }

    #[test]
    fn ks_second_most_expensive() {
        // §II-B: key switching usually < 10% of total runtime but far above
        // sample extraction and linear ops.
        let cfg = TaurusConfig::default();
        for p in [&CNN20, &GPT2, &DECISION_TREE] {
            assert!(keyswitch_cycles(p, &cfg) > 50.0 * sample_extract_cycles(p, &cfg));
        }
    }
}
