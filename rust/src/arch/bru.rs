//! BRU timing model: cycles for blind rotation (decompose -> FFT -> VecMAC
//! -> IFFT, Fig. 8b) of one ciphertext on one cluster.

use super::config::TaurusConfig;
use crate::params::ParamSet;

/// FFT samples streamed per blind-rotation iteration of one ciphertext:
/// forward transforms of the d(k+1) decomposed rows plus (k+1) inverse
/// transforms, each N/2 complex points.
pub fn fft_samples_per_iter(p: &ParamSet) -> u64 {
    ((p.ggsw_rows() + p.k + 1) * p.half_n()) as u64
}

/// VecMAC complex multiplications per iteration (the paper's "BSK
/// multiplications"): d(k+1) rows x (k+1) columns x N/2 bins.
pub fn mac_per_iter(p: &ParamSet) -> u64 {
    (p.ggsw_rows() * (p.k + 1) * p.half_n()) as u64
}

/// Decomposer emission per iteration: one digit per coefficient per level,
/// (k+1) polys (it streams ahead of the FFT; only a bound here).
pub fn decomp_per_iter(p: &ParamSet) -> u64 {
    (p.ggsw_rows() * p.big_n) as u64
}

/// Cycles for one full blind rotation of ONE ciphertext on one cluster
/// (n iterations, pipeline bound by the slowest unit — normally the FFT
/// cluster, Observation 3).
pub fn blind_rotate_cycles(p: &ParamSet, cfg: &TaurusConfig) -> f64 {
    let fft_c = fft_samples_per_iter(p) as f64 / cfg.fft_rate();
    let mac_c = mac_per_iter(p) as f64 / cfg.mac_rate();
    let dec_c = decomp_per_iter(p) as f64 / cfg.fft_rate(); // decomposer keeps FFT pace
    p.n as f64 * fft_c.max(mac_c).max(dec_c / 2.0)
}

/// Single-ciphertext bootstrap *latency* under round-robin sharing: the
/// ciphertext owns 1/rr of the BRU, so latency = rr x solo time (this is
/// what the paper reports as "single-ciphertext bootstrapping latency").
pub fn pbs_latency_s(p: &ParamSet, cfg: &TaurusConfig) -> f64 {
    blind_rotate_cycles(p, cfg) * cfg.rr_ciphertexts as f64 * cfg.cycle_s()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::{CNN20, CNN50, DECISION_TREE, GPT2, KNN, XGBOOST};

    #[test]
    fn latency_matches_paper_cnn20() {
        // Paper: CNN-20 single-ciphertext bootstrapping latency 0.28 ms.
        let cfg = TaurusConfig::default();
        let lat = pbs_latency_s(&CNN20, &cfg) * 1e3;
        assert!(lat > 0.1 && lat < 0.6, "CNN-20 latency {lat} ms vs paper 0.28");
    }

    #[test]
    fn latency_matches_paper_cnn50() {
        // Paper: CNN-50 0.85 ms.
        let cfg = TaurusConfig::default();
        let lat = pbs_latency_s(&CNN50, &cfg) * 1e3;
        assert!(lat > 0.3 && lat < 1.7, "CNN-50 latency {lat} ms vs paper 0.85");
    }

    #[test]
    fn high_width_latencies_in_paper_range() {
        // Paper: high-bitwidth single-ct bootstrap latencies 6.16-34.67 ms.
        let cfg = TaurusConfig::default();
        for p in [&DECISION_TREE, &GPT2, &KNN, &XGBOOST] {
            let lat = pbs_latency_s(p, &cfg) * 1e3;
            assert!(lat > 2.0 && lat < 50.0, "{}: {lat} ms", p.name);
        }
    }

    #[test]
    fn fft_bound_not_mac_bound() {
        // Observation 3/§IV design point: at k=1 the FFT cluster is the
        // bottleneck, the VecMAC has headroom.
        let cfg = TaurusConfig::default();
        for p in [&CNN20, &GPT2, &DECISION_TREE] {
            let fft_c = fft_samples_per_iter(p) as f64 / cfg.fft_rate();
            let mac_c = mac_per_iter(p) as f64 / cfg.mac_rate();
            assert!(fft_c > mac_c, "{}", p.name);
        }
    }

    #[test]
    fn cycles_scale_linearly_with_n_and_nh() {
        let cfg = TaurusConfig::default();
        let mut p2 = GPT2.clone();
        p2.n *= 2;
        assert!(
            (blind_rotate_cycles(&p2, &cfg) / blind_rotate_cycles(&GPT2, &cfg) - 2.0).abs()
                < 1e-9
        );
    }
}
