//! IR -> primitive TFHE DAG, with PBS treated as a **non-atomic** op
//! (paper Observation 6): each LUT lowers to KeySwitch -> BlindRotate ->
//! SampleExtract so later passes can share KS results across fanout.

use crate::ir::{Op, Program, ValueId};

pub type PrimId = usize;

#[derive(Debug, Clone, PartialEq)]
pub enum PrimKind {
    /// Any LPU-side linear op (add/sub/plain/dot/bivariate pack).
    Linear,
    /// Long -> short key switch of an IR value (LPU).
    KeySwitch,
    /// CMUX blind rotation against the LUT with this table hash (BRU).
    BlindRotate { table_hash: u64 },
    /// GLWE -> long LWE extraction (LPU).
    SampleExtract,
}

impl PrimKind {
    pub fn is_keyswitch(k: &PrimKind) -> bool {
        matches!(k, PrimKind::KeySwitch)
    }

    pub fn is_blind_rotate(k: &PrimKind) -> bool {
        matches!(k, PrimKind::BlindRotate { .. })
    }

    pub fn is_linear(k: &PrimKind) -> bool {
        matches!(k, PrimKind::Linear)
    }
}

#[derive(Debug, Clone)]
pub struct PrimOp {
    pub id: PrimId,
    pub kind: PrimKind,
    /// Primitive dependencies (must complete first).
    pub deps: Vec<PrimId>,
    /// IR value this primitive produces (Linear / SampleExtract), if any.
    pub value: Option<ValueId>,
    /// For KeySwitch: the IR value being switched (dedup key).
    pub src_value: Option<ValueId>,
}

#[derive(Debug, Clone, Default)]
pub struct PrimGraph {
    pub ops: Vec<PrimOp>,
    /// PBS level of each op (0 = before any bootstrap).
    pub level: Vec<usize>,
}

impl PrimGraph {
    fn push(&mut self, kind: PrimKind, deps: Vec<PrimId>, value: Option<ValueId>, src_value: Option<ValueId>) -> PrimId {
        let id = self.ops.len();
        let lvl = deps
            .iter()
            .map(|&d| self.level[d] + usize::from(PrimKind::is_blind_rotate(&self.ops[d].kind)))
            .max()
            .unwrap_or(0);
        self.ops.push(PrimOp { id, kind, deps, value, src_value });
        self.level.push(lvl);
        id
    }

    pub fn count(&self, pred: impl Fn(&PrimKind) -> bool) -> usize {
        self.ops.iter().filter(|o| pred(&o.kind)).count()
    }

    pub fn pbs_count(&self) -> usize {
        self.count(PrimKind::is_blind_rotate)
    }

    /// Verify the DAG is topologically ordered and deps are in range.
    pub fn validate(&self) -> Result<(), String> {
        for op in &self.ops {
            for &d in &op.deps {
                if d >= op.id {
                    return Err(format!("prim {} depends on later prim {d}", op.id));
                }
            }
        }
        Ok(())
    }
}

/// Lower a validated IR program.
pub fn lower(prog: &Program) -> PrimGraph {
    let mut g = PrimGraph::default();
    // Producing primitive of each IR value (None = program input, available
    // at time zero).
    let mut producer: Vec<Option<PrimId>> = vec![None; prog.nodes.len()];
    let dep_prims = |producer: &[Option<PrimId>], vals: &[ValueId]| -> Vec<PrimId> {
        let mut d: Vec<PrimId> = vals.iter().filter_map(|&v| producer[v]).collect();
        d.sort_unstable();
        d.dedup();
        d
    };
    for (i, node) in prog.nodes.iter().enumerate() {
        match node {
            Op::Input => {}
            Op::Add(..) | Op::Sub(..) | Op::AddPlain(..) | Op::MulPlain(..) | Op::Dot { .. } => {
                let deps = dep_prims(&producer, &node.deps());
                producer[i] = Some(g.push(PrimKind::Linear, deps, Some(i), None));
            }
            Op::Lut { input, table } => {
                let deps = dep_prims(&producer, &[*input]);
                let ks = g.push(PrimKind::KeySwitch, deps, None, Some(*input));
                let br = g.push(
                    PrimKind::BlindRotate { table_hash: table.hash },
                    vec![ks],
                    None,
                    None,
                );
                producer[i] = Some(g.push(PrimKind::SampleExtract, vec![br], Some(i), None));
            }
            Op::BivLut { a, b, table } => {
                // Linear pack then the usual KS -> BR -> SE.
                let deps = dep_prims(&producer, &[*a, *b]);
                let pack = g.push(PrimKind::Linear, deps, Some(i), None);
                // The packed value is node i's *intermediate*; use the IR
                // node id itself as the dedup key (each BivLut packs
                // uniquely).
                let ks = g.push(PrimKind::KeySwitch, vec![pack], None, Some(i));
                let br = g.push(
                    PrimKind::BlindRotate { table_hash: table.hash },
                    vec![ks],
                    None,
                    None,
                );
                producer[i] = Some(g.push(PrimKind::SampleExtract, vec![br], Some(i), None));
            }
        }
    }
    debug_assert!(g.validate().is_ok());
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::ProgramBuilder;

    #[test]
    fn lut_lowers_to_three_prims() {
        let mut b = ProgramBuilder::new("l", 3);
        let x = b.input();
        let y = b.lut_fn(x, |m| m);
        b.output(y);
        let g = lower(&b.finish());
        assert_eq!(g.ops.len(), 3);
        assert!(PrimKind::is_keyswitch(&g.ops[0].kind));
        assert!(PrimKind::is_blind_rotate(&g.ops[1].kind));
        assert_eq!(g.ops[2].kind, PrimKind::SampleExtract);
        assert_eq!(g.level, vec![0, 0, 1]);
    }

    #[test]
    fn levels_track_pbs_chains() {
        let mut b = ProgramBuilder::new("chain", 3);
        let x = b.input();
        let a = b.lut_fn(x, |m| m);
        let c = b.lut_fn(a, |m| m);
        b.output(c);
        let g = lower(&b.finish());
        // Second KS depends on first SE -> level 1; its BR level 1; SE 2.
        let ks2 = &g.ops[3];
        assert!(PrimKind::is_keyswitch(&ks2.kind));
        assert_eq!(g.level[3], 1);
        assert_eq!(g.level[5], 2);
    }

    #[test]
    fn linear_ops_do_not_raise_level() {
        let mut b = ProgramBuilder::new("lin", 3);
        let x = b.input();
        let y = b.input();
        let s = b.add(x, y);
        let t = b.mul_plain(s, 2);
        b.output(t);
        let g = lower(&b.finish());
        assert_eq!(g.pbs_count(), 0);
        assert!(g.level.iter().all(|&l| l == 0));
    }

    #[test]
    fn bivlut_adds_pack_linear() {
        let mut b = ProgramBuilder::new("biv", 4);
        let x = b.input();
        let y = b.input();
        let m = b.biv_lut_fn(x, y, |a, bb| a + bb);
        b.output(m);
        let g = lower(&b.finish());
        assert_eq!(g.count(PrimKind::is_linear), 1);
        assert_eq!(g.pbs_count(), 1);
    }
}
