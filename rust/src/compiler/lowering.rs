//! IR -> primitive TFHE DAG, with PBS treated as a **non-atomic** op
//! (paper Observation 6): each LUT lowers to KeySwitch -> BlindRotate ->
//! SampleExtract so later passes can share KS results across fanout.
//!
//! The graph is self-contained for execution: linear primitives carry
//! their expression payloads, blind rotations reference interned LUT
//! tables (ACC-dedup realized structurally — one table per distinct
//! hash), and `outputs` binds the program results to operands. The
//! schedule-driven executor (`compiler::exec::Engine::run_plan`) walks
//! this graph without ever consulting the source IR.

use crate::ir::{LutTable, Op, Program, ValueId};

pub type PrimId = usize;

/// Where a primitive reads a ciphertext from.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Operand {
    /// Program input slot (fresh ciphertext, available at time zero).
    Input(usize),
    /// The LWE output of another primitive.
    Prim(PrimId),
}

/// An LPU-side linear expression over long LWE ciphertexts — the payload
/// a `Linear` primitive executes.
#[derive(Debug, Clone, PartialEq)]
pub enum LinExpr {
    Add(Operand, Operand),
    Sub(Operand, Operand),
    AddPlain(Operand, u64),
    MulPlain(Operand, i64),
    Dot { inputs: Vec<Operand>, weights: Vec<i64>, bias: u64 },
    /// Bivariate pack `a * 2^(width/2) + b` (paper footnote 4).
    Pack(Operand, Operand),
}

impl LinExpr {
    /// Ciphertext operands of this expression.
    pub fn operands(&self) -> Vec<Operand> {
        match self {
            LinExpr::Add(a, b) | LinExpr::Sub(a, b) | LinExpr::Pack(a, b) => vec![*a, *b],
            LinExpr::AddPlain(a, _) | LinExpr::MulPlain(a, _) => vec![*a],
            LinExpr::Dot { inputs, .. } => inputs.clone(),
        }
    }

    /// Rewrite every operand in place (dedup id compaction).
    pub fn map_operands(&mut self, mut f: impl FnMut(Operand) -> Operand) {
        match self {
            LinExpr::Add(a, b) | LinExpr::Sub(a, b) | LinExpr::Pack(a, b) => {
                *a = f(*a);
                *b = f(*b);
            }
            LinExpr::AddPlain(a, _) | LinExpr::MulPlain(a, _) => *a = f(*a),
            LinExpr::Dot { inputs, .. } => {
                for x in inputs.iter_mut() {
                    *x = f(*x);
                }
            }
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum PrimKind {
    /// Any LPU-side linear op (add/sub/plain/dot/bivariate pack).
    Linear(LinExpr),
    /// Long -> short key switch of `src` (LPU).
    KeySwitch { src: Operand },
    /// CMUX blind rotation against the interned table at this index (BRU).
    BlindRotate { table: usize },
    /// GLWE -> long LWE extraction (LPU).
    SampleExtract,
}

impl PrimKind {
    pub fn is_keyswitch(k: &PrimKind) -> bool {
        matches!(k, PrimKind::KeySwitch { .. })
    }

    pub fn is_blind_rotate(k: &PrimKind) -> bool {
        matches!(k, PrimKind::BlindRotate { .. })
    }

    pub fn is_linear(k: &PrimKind) -> bool {
        matches!(k, PrimKind::Linear(_))
    }
}

#[derive(Debug, Clone)]
pub struct PrimOp {
    pub id: PrimId,
    pub kind: PrimKind,
    /// Primitive dependencies (must complete first).
    pub deps: Vec<PrimId>,
}

#[derive(Debug, Clone, Default)]
pub struct PrimGraph {
    pub ops: Vec<PrimOp>,
    /// PBS level of each op (0 = before any bootstrap).
    pub level: Vec<usize>,
    /// Number of program input slots (`Operand::Input` range).
    pub n_inputs: usize,
    /// Interned LUT tables, one per distinct hash (ACC-dedup).
    pub tables: Vec<LutTable>,
    /// Program outputs, bound to operands.
    pub outputs: Vec<Operand>,
}

impl PrimGraph {
    fn push(&mut self, kind: PrimKind, deps: Vec<PrimId>) -> PrimId {
        let id = self.ops.len();
        let lvl = deps
            .iter()
            .map(|&d| self.level[d] + usize::from(PrimKind::is_blind_rotate(&self.ops[d].kind)))
            .max()
            .unwrap_or(0);
        self.ops.push(PrimOp { id, kind, deps });
        self.level.push(lvl);
        id
    }

    /// Intern a LUT table, returning its index (shared per distinct hash).
    pub fn intern_table(&mut self, t: &LutTable) -> usize {
        match self.tables.iter().position(|x| x.hash == t.hash) {
            Some(i) => i,
            None => {
                self.tables.push(t.clone());
                self.tables.len() - 1
            }
        }
    }

    pub fn count(&self, pred: impl Fn(&PrimKind) -> bool) -> usize {
        self.ops.iter().filter(|o| pred(&o.kind)).count()
    }

    pub fn pbs_count(&self) -> usize {
        self.count(PrimKind::is_blind_rotate)
    }

    /// Verify the DAG is topologically ordered, deps/operands are in
    /// range, table references resolve, and every `Prim` payload operand
    /// also appears in `deps` (scheduling orders by deps while execution
    /// fetches through operands — they must agree or the executor could
    /// be handed an operand before it is computed).
    pub fn validate(&self) -> Result<(), String> {
        for op in &self.ops {
            for &d in &op.deps {
                if d >= op.id {
                    return Err(format!("prim {} depends on later prim {d}", op.id));
                }
            }
            let operand_ok = |o: Operand| -> Result<(), String> {
                match o {
                    Operand::Input(i) if i >= self.n_inputs => {
                        Err(format!("prim {} reads input {i} of {}", op.id, self.n_inputs))
                    }
                    Operand::Prim(p) if p >= op.id => {
                        Err(format!("prim {} reads later prim {p}", op.id))
                    }
                    Operand::Prim(p) if !op.deps.contains(&p) => {
                        Err(format!("prim {} reads prim {p} not in its deps", op.id))
                    }
                    _ => Ok(()),
                }
            };
            match &op.kind {
                PrimKind::Linear(e) => {
                    for o in e.operands() {
                        operand_ok(o)?;
                    }
                }
                PrimKind::KeySwitch { src } => operand_ok(*src)?,
                PrimKind::BlindRotate { table } => {
                    if *table >= self.tables.len() {
                        return Err(format!("prim {} references table {table}", op.id));
                    }
                }
                PrimKind::SampleExtract => {}
            }
        }
        for &o in &self.outputs {
            match o {
                Operand::Input(i) if i >= self.n_inputs => {
                    return Err(format!("output reads input {i} of {}", self.n_inputs));
                }
                Operand::Prim(p) if p >= self.ops.len() => {
                    return Err(format!("output reads prim {p} of {}", self.ops.len()));
                }
                _ => {}
            }
        }
        Ok(())
    }
}

/// Lower a validated IR program into a self-contained primitive graph.
pub fn lower(prog: &Program) -> PrimGraph {
    let mut g = PrimGraph::default();
    // Producing primitive of each IR value (None = program input, available
    // at time zero through its input slot).
    let mut producer: Vec<Option<PrimId>> = vec![None; prog.nodes.len()];
    let mut input_slot: Vec<usize> = vec![usize::MAX; prog.nodes.len()];
    let operand = |producer: &[Option<PrimId>], input_slot: &[usize], v: ValueId| -> Operand {
        match producer[v] {
            Some(p) => Operand::Prim(p),
            None => Operand::Input(input_slot[v]),
        }
    };
    let dep_prims = |ops: &[Operand]| -> Vec<PrimId> {
        let mut d: Vec<PrimId> = ops
            .iter()
            .filter_map(|o| match o {
                Operand::Prim(p) => Some(*p),
                Operand::Input(_) => None,
            })
            .collect();
        d.sort_unstable();
        d.dedup();
        d
    };
    for (i, node) in prog.nodes.iter().enumerate() {
        match node {
            Op::Input => {
                input_slot[i] = g.n_inputs;
                g.n_inputs += 1;
            }
            Op::Add(..) | Op::Sub(..) | Op::AddPlain(..) | Op::MulPlain(..) | Op::Dot { .. } => {
                let ops: Vec<Operand> = node
                    .deps()
                    .iter()
                    .map(|&v| operand(&producer, &input_slot, v))
                    .collect();
                let expr = match node {
                    Op::Add(..) => LinExpr::Add(ops[0], ops[1]),
                    Op::Sub(..) => LinExpr::Sub(ops[0], ops[1]),
                    Op::AddPlain(_, c) => LinExpr::AddPlain(ops[0], *c),
                    Op::MulPlain(_, c) => LinExpr::MulPlain(ops[0], *c),
                    Op::Dot { weights, bias, .. } => {
                        LinExpr::Dot { inputs: ops.clone(), weights: weights.clone(), bias: *bias }
                    }
                    _ => unreachable!(),
                };
                let deps = dep_prims(&ops);
                producer[i] = Some(g.push(PrimKind::Linear(expr), deps));
            }
            Op::Lut { input, table } => {
                let src = operand(&producer, &input_slot, *input);
                let deps = dep_prims(&[src]);
                let ks = g.push(PrimKind::KeySwitch { src }, deps);
                let ti = g.intern_table(table);
                let br = g.push(PrimKind::BlindRotate { table: ti }, vec![ks]);
                producer[i] = Some(g.push(PrimKind::SampleExtract, vec![br]));
            }
            Op::BivLut { a, b, table } => {
                // Linear pack then the usual KS -> BR -> SE. The packed
                // intermediate is the KS source (each BivLut packs
                // uniquely, so no cross-node sharing).
                let oa = operand(&producer, &input_slot, *a);
                let ob = operand(&producer, &input_slot, *b);
                let deps = dep_prims(&[oa, ob]);
                let pack = g.push(PrimKind::Linear(LinExpr::Pack(oa, ob)), deps);
                let ks = g.push(PrimKind::KeySwitch { src: Operand::Prim(pack) }, vec![pack]);
                let ti = g.intern_table(table);
                let br = g.push(PrimKind::BlindRotate { table: ti }, vec![ks]);
                producer[i] = Some(g.push(PrimKind::SampleExtract, vec![br]));
            }
        }
    }
    g.outputs = prog
        .outputs
        .iter()
        .map(|&v| operand(&producer, &input_slot, v))
        .collect();
    debug_assert!(g.validate().is_ok());
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::ProgramBuilder;

    #[test]
    fn lut_lowers_to_three_prims() {
        let mut b = ProgramBuilder::new("l", 3);
        let x = b.input();
        let y = b.lut_fn(x, |m| m);
        b.output(y);
        let g = lower(&b.finish());
        assert_eq!(g.ops.len(), 3);
        assert_eq!(g.ops[0].kind, PrimKind::KeySwitch { src: Operand::Input(0) });
        assert!(PrimKind::is_blind_rotate(&g.ops[1].kind));
        assert_eq!(g.ops[2].kind, PrimKind::SampleExtract);
        assert_eq!(g.level, vec![0, 0, 1]);
        assert_eq!(g.n_inputs, 1);
        assert_eq!(g.outputs, vec![Operand::Prim(2)]);
    }

    #[test]
    fn levels_track_pbs_chains() {
        let mut b = ProgramBuilder::new("chain", 3);
        let x = b.input();
        let a = b.lut_fn(x, |m| m);
        let c = b.lut_fn(a, |m| m);
        b.output(c);
        let g = lower(&b.finish());
        // Second KS depends on first SE -> level 1; its BR level 1; SE 2.
        let ks2 = &g.ops[3];
        assert!(PrimKind::is_keyswitch(&ks2.kind));
        assert_eq!(ks2.kind, PrimKind::KeySwitch { src: Operand::Prim(2) });
        assert_eq!(g.level[3], 1);
        assert_eq!(g.level[5], 2);
    }

    #[test]
    fn linear_ops_do_not_raise_level() {
        let mut b = ProgramBuilder::new("lin", 3);
        let x = b.input();
        let y = b.input();
        let s = b.add(x, y);
        let t = b.mul_plain(s, 2);
        b.output(t);
        let g = lower(&b.finish());
        assert_eq!(g.pbs_count(), 0);
        assert!(g.level.iter().all(|&l| l == 0));
        // Payloads reference the right operands.
        assert_eq!(
            g.ops[0].kind,
            PrimKind::Linear(LinExpr::Add(Operand::Input(0), Operand::Input(1)))
        );
        assert_eq!(g.ops[1].kind, PrimKind::Linear(LinExpr::MulPlain(Operand::Prim(0), 2)));
    }

    #[test]
    fn bivlut_adds_pack_linear() {
        let mut b = ProgramBuilder::new("biv", 4);
        let x = b.input();
        let y = b.input();
        let m = b.biv_lut_fn(x, y, |a, bb| a + bb);
        b.output(m);
        let g = lower(&b.finish());
        assert_eq!(g.count(PrimKind::is_linear), 1);
        assert_eq!(g.pbs_count(), 1);
        assert_eq!(
            g.ops[0].kind,
            PrimKind::Linear(LinExpr::Pack(Operand::Input(0), Operand::Input(1)))
        );
        assert_eq!(g.ops[1].kind, PrimKind::KeySwitch { src: Operand::Prim(0) });
    }

    #[test]
    fn tables_interned_per_distinct_hash() {
        let mut b = ProgramBuilder::new("acc", 3);
        let t = crate::ir::LutTable::from_fn(3, |m| m ^ 1);
        let xs = b.inputs(4);
        for x in xs {
            let y = b.lut(x, t.clone());
            b.output(y);
        }
        let z = b.input();
        let w = b.lut_fn(z, |m| m + 2);
        b.output(w);
        let g = lower(&b.finish());
        assert_eq!(g.pbs_count(), 5);
        assert_eq!(g.tables.len(), 2, "4x shared table + 1 distinct");
        g.validate().unwrap();
    }

    #[test]
    fn output_can_be_a_program_input() {
        let mut b = ProgramBuilder::new("id", 3);
        let x = b.input();
        b.output(x);
        let g = lower(&b.finish());
        assert_eq!(g.outputs, vec![Operand::Input(0)]);
        g.validate().unwrap();
    }
}
