//! Functional executor: runs an IR program on real ciphertexts through a
//! pluggable PBS backend (native Rust TFHE or the AOT XLA artifacts).
//! Linear ops execute on long LWE ciphertexts exactly as the LPU would.

use std::collections::HashMap;

use crate::ir::{Op, Program};
use crate::params::ParamSet;
use crate::tfhe::encoding;
use crate::tfhe::{LweCiphertext, PbsContext, ServerKeys};

/// A PBS implementation (one bootstrap, LUT polynomial pre-encoded).
pub trait PbsBackend {
    fn pbs(&mut self, ct_long: &LweCiphertext, lut_poly: &[u64]) -> LweCiphertext;
    fn params(&self) -> &ParamSet;
}

/// Native (pure-Rust) backend.
pub struct NativePbsBackend<'k> {
    pub ctx: PbsContext,
    pub keys: &'k ServerKeys,
}

impl<'k> NativePbsBackend<'k> {
    pub fn new(keys: &'k ServerKeys) -> Self {
        Self { ctx: PbsContext::new(&keys.params), keys }
    }
}

impl PbsBackend for NativePbsBackend<'_> {
    fn pbs(&mut self, ct_long: &LweCiphertext, lut_poly: &[u64]) -> LweCiphertext {
        self.ctx.pbs(ct_long, self.keys, lut_poly)
    }

    fn params(&self) -> &ParamSet {
        &self.keys.params
    }
}

impl PbsBackend for crate::runtime::XlaPbsBackend {
    fn pbs(&mut self, ct_long: &LweCiphertext, lut_poly: &[u64]) -> LweCiphertext {
        crate::runtime::XlaPbsBackend::pbs(self, ct_long, lut_poly).expect("xla pbs")
    }

    fn params(&self) -> &ParamSet {
        &self.params
    }
}

/// Program executor with an accumulator (LUT polynomial) cache — ACC-dedup
/// in action: each distinct table is encoded once and shared.
pub struct Engine<B: PbsBackend> {
    pub backend: B,
    lut_cache: HashMap<u64, Vec<u64>>,
}

impl<B: PbsBackend> Engine<B> {
    pub fn new(backend: B) -> Self {
        Self { backend, lut_cache: HashMap::new() }
    }

    /// Number of distinct accumulators encoded so far.
    pub fn cached_accumulators(&self) -> usize {
        self.lut_cache.len()
    }

    /// Execute `prog` on encrypted inputs; returns encrypted outputs.
    pub fn run(&mut self, prog: &Program, inputs: &[LweCiphertext]) -> Vec<LweCiphertext> {
        assert_eq!(inputs.len(), prog.input_count(), "input arity");
        let p = self.backend.params().clone();
        assert_eq!(p.width, prog.width, "program width must match params");
        let delta = p.delta();
        let mut vals: Vec<Option<LweCiphertext>> = vec![None; prog.nodes.len()];
        let mut next_input = 0usize;
        for (i, node) in prog.nodes.iter().enumerate() {
            let out = match node {
                Op::Input => {
                    let ct = inputs[next_input].clone();
                    next_input += 1;
                    ct
                }
                Op::Add(a, b) => {
                    let mut ct = vals[*a].clone().unwrap();
                    ct.add_assign(vals[*b].as_ref().unwrap());
                    ct
                }
                Op::Sub(a, b) => {
                    let mut ct = vals[*a].clone().unwrap();
                    ct.sub_assign(vals[*b].as_ref().unwrap());
                    ct
                }
                Op::AddPlain(a, c) => {
                    let mut ct = vals[*a].clone().unwrap();
                    ct.plain_add_assign(c.wrapping_mul(delta));
                    ct
                }
                Op::MulPlain(a, c) => {
                    let mut ct = vals[*a].clone().unwrap();
                    ct.scalar_mul_assign(*c);
                    ct
                }
                Op::Dot { inputs: xs, weights, bias } => {
                    let mut acc = LweCiphertext::trivial(bias.wrapping_mul(delta), p.long_dim());
                    for (x, &w) in xs.iter().zip(weights) {
                        if w == 0 {
                            continue;
                        }
                        let mut t = vals[*x].clone().unwrap();
                        t.scalar_mul_assign(w);
                        acc.add_assign(&t);
                    }
                    acc
                }
                Op::Lut { input, table } => {
                    let lut = self
                        .lut_cache
                        .entry(table.hash)
                        .or_insert_with(|| {
                            let vals = table.values.clone();
                            encoding::make_lut_poly(&p, move |m| vals[m as usize])
                        })
                        .clone();
                    self.backend.pbs(vals[*input].as_ref().unwrap(), &lut)
                }
                Op::BivLut { a, b, table } => {
                    // pack = x * 2^(w/2) + y, then univariate LUT.
                    let scale = encoding::bivariate_scale(&p) as i64;
                    let mut packed = vals[*a].clone().unwrap();
                    packed.scalar_mul_assign(scale);
                    packed.add_assign(vals[*b].as_ref().unwrap());
                    let lut = self
                        .lut_cache
                        .entry(table.hash)
                        .or_insert_with(|| {
                            let vals = table.values.clone();
                            encoding::make_lut_poly(&p, move |m| vals[m as usize])
                        })
                        .clone();
                    self.backend.pbs(&packed, &lut)
                }
            };
            vals[i] = Some(out);
        }
        prog.outputs.iter().map(|&o| vals[o].clone().unwrap()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::ProgramBuilder;
    use crate::ir::interp;
    use crate::params::TEST1;
    use crate::tfhe::pbs::{decrypt_message, encrypt_message};
    use crate::tfhe::SecretKeys;
    use crate::util::rng::Rng;

    fn setup() -> (SecretKeys, ServerKeys, Rng) {
        let mut rng = Rng::new(99);
        let sk = SecretKeys::generate(&TEST1, &mut rng);
        let keys = ServerKeys::generate(&sk, &mut rng);
        (sk, keys, rng)
    }

    #[test]
    fn engine_matches_plaintext_interpreter() {
        let (sk, keys, mut rng) = setup();
        let mut b = ProgramBuilder::new("mix", 3);
        let x = b.input();
        let y = b.input();
        let s = b.add(x, y);
        let d = b.mul_plain(s, 2);
        let r = b.lut_fn(d, |m| (m + 3) % 16);
        let t = b.sub(r, x);
        b.output(t);
        let prog = b.finish();

        let mut eng = Engine::new(NativePbsBackend::new(&keys));
        for (mx, my) in [(1u64, 2u64), (3, 0), (2, 2)] {
            let cts = vec![
                encrypt_message(mx, &sk, &mut rng),
                encrypt_message(my, &sk, &mut rng),
            ];
            let out = eng.run(&prog, &cts);
            let expected = interp::eval(&prog, &[mx, my]);
            let got: Vec<u64> = out.iter().map(|c| decrypt_message(c, &sk)).collect();
            assert_eq!(got, expected, "inputs ({mx},{my})");
        }
    }

    #[test]
    fn dot_with_negative_weights() {
        let (sk, keys, mut rng) = setup();
        let mut b = ProgramBuilder::new("dot", 3);
        let ins = b.inputs(3);
        let d = b.dot(ins, vec![2, -1, 1], 1);
        b.output(d);
        let prog = b.finish();
        let mut eng = Engine::new(NativePbsBackend::new(&keys));
        let msgs = [3u64, 2, 1];
        let cts: Vec<_> = msgs.iter().map(|&m| encrypt_message(m, &sk, &mut rng)).collect();
        let out = eng.run(&prog, &cts);
        // 2*3 - 2 + 1 + 1 = 6
        assert_eq!(decrypt_message(&out[0], &sk), 6);
    }

    #[test]
    fn lut_cache_shares_accumulators() {
        let (sk, keys, mut rng) = setup();
        let mut b = ProgramBuilder::new("acc", 3);
        let xs = b.inputs(4);
        let table = crate::ir::LutTable::from_fn(3, |m| m ^ 1);
        for x in xs {
            let y = b.lut(x, table.clone());
            b.output(y);
        }
        let prog = b.finish();
        let mut eng = Engine::new(NativePbsBackend::new(&keys));
        let cts: Vec<_> = (0..4).map(|m| encrypt_message(m, &sk, &mut rng)).collect();
        let out = eng.run(&prog, &cts);
        assert_eq!(eng.cached_accumulators(), 1, "one table -> one accumulator");
        for (m, ct) in out.iter().enumerate() {
            assert_eq!(decrypt_message(ct, &sk), (m as u64) ^ 1);
        }
    }

    #[test]
    fn bivariate_lut_executes() {
        let (sk, keys, mut rng) = setup();
        // width 3 -> halves of 1 bit each.
        let mut b = ProgramBuilder::new("biv", 3);
        let x = b.input();
        let y = b.input();
        let g = b.biv_lut_fn(x, y, |a, bb| a & bb);
        b.output(g);
        let prog = b.finish();
        let mut eng = Engine::new(NativePbsBackend::new(&keys));
        for (mx, my) in [(0u64, 1u64), (1, 1), (1, 0)] {
            let cts = vec![
                encrypt_message(mx, &sk, &mut rng),
                encrypt_message(my, &sk, &mut rng),
            ];
            let out = eng.run(&prog, &cts);
            assert_eq!(decrypt_message(&out[0], &sk), mx & my, "({mx},{my})");
        }
    }
}
