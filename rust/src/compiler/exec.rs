//! Functional executors over real ciphertexts through a pluggable PBS
//! backend (native Rust TFHE or the AOT XLA artifacts).
//!
//! Two paths share one [`Engine`]:
//! * [`Engine::run_plan`] / [`Engine::run_plan_batch`] — the
//!   schedule-driven executor: walks a [`CompiledPlan`]'s batches
//!   level-by-level, computing each deduplicated KeySwitch **once** and
//!   broadcasting it to its fanout, and fusing all BlindRotates that
//!   share an accumulator within a batch into one
//!   [`PbsBackend::blind_rotate_batch`] sweep (cross-node x cross-request
//!   key reuse). This is the default in the coordinator and CLI.
//! * [`Engine::run`] / [`Engine::run_batch`] — the legacy node-walking
//!   executor, kept as the naive baseline and equivalence oracle.
//!
//! Linear ops execute on long LWE ciphertexts exactly as the LPU would.

use std::collections::HashMap;
use std::sync::Arc;

use super::lowering::{LinExpr, Operand, PrimGraph, PrimId, PrimKind};
use super::CompiledPlan;
use crate::ir::{Op, Program};
use crate::obs;
use crate::obs::drift::PlanBatchProfile;
use crate::obs::hist::{Log2Histogram, StageHists};
use crate::params::ParamSet;
use crate::tfhe::encoding;
use crate::tfhe::{GlweCiphertext, LweCiphertext, PbsContext, ServerKeys};

/// A PBS backend, split into the three primitive entry points of the
/// key-switch-first pipeline (paper Fig. 3) so the schedule-driven
/// executor can drive each stage separately. `pbs` / `pbs_batch` are
/// provided compositions of the primitives.
pub trait PbsBackend {
    /// Long LWE -> short LWE key switch (LPU).
    fn keyswitch(&mut self, ct_long: &LweCiphertext) -> LweCiphertext;

    /// Blind rotation of a batch of **short** ciphertexts against ONE
    /// shared accumulator (LUT polynomial); returns one rotated GLWE per
    /// input. Backends that can fuse stream each BSK row once per call
    /// instead of once per ciphertext.
    fn blind_rotate_batch(
        &mut self,
        cts_short: &[LweCiphertext],
        lut_poly: &[u64],
    ) -> Vec<GlweCiphertext>;

    /// GLWE -> long LWE constant-coefficient extraction (LPU).
    fn sample_extract(&mut self, acc: &GlweCiphertext) -> LweCiphertext;

    fn params(&self) -> &ParamSet;

    /// Drain the backend's Fourier-BSK traffic counter (bytes streamed by
    /// blind rotations since the last call); 0 for backends that don't
    /// track it.
    fn take_bsk_bytes_streamed(&mut self) -> u64 {
        0
    }

    /// Drain the backend's per-transform FFT timing histogram (populated
    /// only while `obs::enabled`); empty for backends that don't meter
    /// their transforms.
    fn take_fft_hist(&mut self) -> Log2Histogram {
        Log2Histogram::new()
    }

    /// One full PBS: KS -> BR -> SE.
    fn pbs(&mut self, ct_long: &LweCiphertext, lut_poly: &[u64]) -> LweCiphertext {
        let short = self.keyswitch(ct_long);
        let accs = self.blind_rotate_batch(std::slice::from_ref(&short), lut_poly);
        self.sample_extract(&accs[0])
    }

    /// Batched PBS over one shared LUT: keyswitch each ciphertext, one
    /// fused blind-rotation sweep, then sample-extract each accumulator.
    fn pbs_batch(&mut self, cts: &[LweCiphertext], lut_poly: &[u64]) -> Vec<LweCiphertext> {
        let shorts: Vec<LweCiphertext> = cts.iter().map(|ct| self.keyswitch(ct)).collect();
        let accs = self.blind_rotate_batch(&shorts, lut_poly);
        accs.iter().map(|acc| self.sample_extract(acc)).collect()
    }
}

/// Engine-level execution knobs threaded from the serving layers
/// (`CoordinatorOptions` / `ClusterOptions`) into backend construction.
#[derive(Debug, Clone)]
pub struct EngineOptions {
    /// Worker threads for the native backend's column-parallel blind
    /// rotation (see `PbsContext::with_threads`). 1 = sequential; any
    /// value yields bitwise-identical ciphertexts. The XLA backend
    /// ignores this (it keeps its sequential per-ciphertext fallback).
    pub fft_threads: usize,
}

impl Default for EngineOptions {
    fn default() -> Self {
        Self { fft_threads: 1 }
    }
}

/// How the native backend holds its server keys: borrowed (the historical
/// single-key embedding used by tests and the CLI) or shared via `Arc`
/// (the multi-tenant serving path, where workers rebind the key set per
/// keyed sub-batch without rebuilding the FFT plan or scratch).
pub enum KeysRef<'k> {
    Borrowed(&'k ServerKeys),
    Shared(Arc<ServerKeys>),
}

impl std::ops::Deref for KeysRef<'_> {
    type Target = ServerKeys;

    fn deref(&self) -> &ServerKeys {
        match self {
            KeysRef::Borrowed(k) => k,
            KeysRef::Shared(k) => k,
        }
    }
}

/// Native (pure-Rust) backend.
pub struct NativePbsBackend<'k> {
    pub ctx: PbsContext,
    keys: KeysRef<'k>,
}

impl<'k> NativePbsBackend<'k> {
    pub fn new(keys: &'k ServerKeys) -> Self {
        Self::new_with(keys, &EngineOptions::default())
    }

    /// Borrowed-key backend with explicit engine options.
    pub fn new_with(keys: &'k ServerKeys, opts: &EngineOptions) -> Self {
        Self {
            ctx: PbsContext::with_threads(&keys.params, opts.fft_threads),
            keys: KeysRef::Borrowed(keys),
        }
    }

    /// The currently bound key set.
    pub fn keys(&self) -> &ServerKeys {
        &self.keys
    }
}

impl NativePbsBackend<'static> {
    /// An owning backend over shared keys — the serving workers' form,
    /// rebindable via [`Self::set_keys`].
    pub fn shared(keys: Arc<ServerKeys>) -> Self {
        Self::shared_with(keys, &EngineOptions::default())
    }

    /// Shared-key backend with explicit engine options.
    pub fn shared_with(keys: Arc<ServerKeys>, opts: &EngineOptions) -> Self {
        Self {
            ctx: PbsContext::with_threads(&keys.params, opts.fft_threads),
            keys: KeysRef::Shared(keys),
        }
    }

    /// Rebind to another tenant's key set. The FFT plan, scratch buffers,
    /// and the engine's accumulator cache are all parameter-bound and key
    /// independent, so only the key pointer changes — the per-sub-batch
    /// cost of multi-tenant serving is the rebind itself, nothing else.
    pub fn set_keys(&mut self, keys: Arc<ServerKeys>) {
        assert_eq!(
            keys.params.name, self.ctx.params.name,
            "rebinding across parameter sets would invalidate the FFT plan and scratch"
        );
        self.keys = KeysRef::Shared(keys);
    }
}

impl PbsBackend for NativePbsBackend<'_> {
    fn keyswitch(&mut self, ct_long: &LweCiphertext) -> LweCiphertext {
        self.keys.ksk.keyswitch(ct_long, &self.keys.params)
    }

    fn blind_rotate_batch(
        &mut self,
        cts_short: &[LweCiphertext],
        lut_poly: &[u64],
    ) -> Vec<GlweCiphertext> {
        self.ctx.blind_rotate_batch(cts_short, &self.keys.bsk, lut_poly)
    }

    fn sample_extract(&mut self, acc: &GlweCiphertext) -> LweCiphertext {
        acc.sample_extract(&self.keys.params)
    }

    fn params(&self) -> &ParamSet {
        &self.keys.params
    }

    fn take_bsk_bytes_streamed(&mut self) -> u64 {
        self.ctx.take_bsk_bytes_streamed()
    }

    fn take_fft_hist(&mut self) -> Log2Histogram {
        self.ctx.take_fft_hist()
    }
}

/// The XLA artifacts execute one blind rotation per invocation, so this
/// backend's `blind_rotate_batch` is a sequential loop over the shared
/// accumulator; sample extraction runs natively (it is a reshuffle).
#[cfg(feature = "xla")]
impl PbsBackend for crate::runtime::XlaPbsBackend {
    fn keyswitch(&mut self, ct_long: &LweCiphertext) -> LweCiphertext {
        crate::runtime::XlaPbsBackend::keyswitch(self, ct_long).expect("xla keyswitch")
    }

    fn blind_rotate_batch(
        &mut self,
        cts_short: &[LweCiphertext],
        lut_poly: &[u64],
    ) -> Vec<GlweCiphertext> {
        cts_short
            .iter()
            .map(|ct| {
                let flat = crate::runtime::XlaPbsBackend::blind_rotate(self, ct, lut_poly)
                    .expect("xla blind rotate");
                GlweCiphertext { data: flat, k: self.params.k, big_n: self.params.big_n }
            })
            .collect()
    }

    fn sample_extract(&mut self, acc: &GlweCiphertext) -> LweCiphertext {
        acc.sample_extract(&self.params)
    }

    fn params(&self) -> &ParamSet {
        &self.params
    }
}

/// Counters from executed work, drained by [`Engine::take_exec_stats`].
/// Both executors fill these, so plan-vs-legacy comparisons (and the
/// measured-vs-model cross-checks against `arch::sim`) read one source.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ExecStats {
    /// Key-switch invocations (one per ciphertext switched).
    pub ks_ops: u64,
    /// Blind rotations executed (one per ciphertext rotated) = PBS count.
    pub pbs_ops: u64,
    /// Fused `blind_rotate_batch` calls issued.
    pub br_calls: u64,
    /// Fourier-BSK bytes streamed by those rotations.
    pub bsk_bytes_streamed: u64,
}

/// Program executor with an accumulator (LUT polynomial) cache — ACC-dedup
/// in action: each distinct table is encoded once and shared via a cheap
/// refcounted handle.
pub struct Engine<B: PbsBackend> {
    pub backend: B,
    lut_cache: HashMap<u64, Arc<[u64]>>,
    stats: ExecStats,
    /// Per-stage timing histograms, filled only while `obs::enabled`.
    stage: StageHists,
    /// Per-schedule-batch measured profiles (index = batch index in
    /// `CompiledPlan.schedule.batches`), filled only while `obs::enabled`.
    profiles: Vec<PlanBatchProfile>,
    /// BSK bytes already drained from the backend into per-batch profiles;
    /// re-added by [`Self::take_exec_stats`] so the rolled-up counter is
    /// identical with and without profiling.
    profiled_bsk: u64,
}

/// Resolve an operand to the ciphertext of request `q`.
fn fetch<'a>(
    batch: &'a [&[LweCiphertext]],
    lwe: &'a [Option<Vec<LweCiphertext>>],
    o: Operand,
    q: usize,
) -> &'a LweCiphertext {
    match o {
        Operand::Input(i) => &batch[q][i],
        Operand::Prim(p) => &lwe[p].as_ref().expect("operand computed before use")[q],
    }
}

/// The (unique) KeySwitch dependency of a BlindRotate.
fn ks_dep(g: &PrimGraph, br: PrimId) -> PrimId {
    g.ops[br]
        .deps
        .iter()
        .copied()
        .find(|&d| PrimKind::is_keyswitch(&g.ops[d].kind))
        .expect("BlindRotate has a KeySwitch dep")
}

/// Execute one linear primitive across the whole request batch.
fn exec_linear(
    p: &ParamSet,
    g: &PrimGraph,
    id: PrimId,
    batch: &[&[LweCiphertext]],
    lwe: &mut [Option<Vec<LweCiphertext>>],
) {
    let PrimKind::Linear(expr) = &g.ops[id].kind else {
        panic!("lin_ops lists non-linear prim {id}")
    };
    let nb = batch.len();
    let delta = p.delta();
    let out: Vec<LweCiphertext> = (0..nb)
        .map(|q| match expr {
            LinExpr::Add(a, b) => {
                let mut ct = fetch(batch, lwe, *a, q).clone();
                ct.add_assign(fetch(batch, lwe, *b, q));
                ct
            }
            LinExpr::Sub(a, b) => {
                let mut ct = fetch(batch, lwe, *a, q).clone();
                ct.sub_assign(fetch(batch, lwe, *b, q));
                ct
            }
            LinExpr::AddPlain(a, c) => {
                let mut ct = fetch(batch, lwe, *a, q).clone();
                ct.plain_add_assign(c.wrapping_mul(delta));
                ct
            }
            LinExpr::MulPlain(a, c) => {
                let mut ct = fetch(batch, lwe, *a, q).clone();
                ct.scalar_mul_assign(*c);
                ct
            }
            LinExpr::Dot { inputs, weights, bias } => {
                let mut acc = LweCiphertext::trivial(bias.wrapping_mul(delta), p.long_dim());
                for (x, &w) in inputs.iter().zip(weights) {
                    if w == 0 {
                        continue;
                    }
                    let mut t = fetch(batch, lwe, *x, q).clone();
                    t.scalar_mul_assign(w);
                    acc.add_assign(&t);
                }
                acc
            }
            LinExpr::Pack(a, b) => {
                let mut ct = fetch(batch, lwe, *a, q).clone();
                ct.scalar_mul_assign(encoding::bivariate_scale(p) as i64);
                ct.add_assign(fetch(batch, lwe, *b, q));
                ct
            }
        })
        .collect();
    lwe[id] = Some(out);
}

impl<B: PbsBackend> Engine<B> {
    pub fn new(backend: B) -> Self {
        Self {
            backend,
            lut_cache: HashMap::new(),
            stats: ExecStats::default(),
            stage: StageHists::default(),
            profiles: Vec::new(),
            profiled_bsk: 0,
        }
    }

    /// Number of distinct accumulators encoded so far.
    pub fn cached_accumulators(&self) -> usize {
        self.lut_cache.len()
    }

    /// Drain the execution counters accumulated since the last call
    /// (includes the backend's BSK traffic counter — this is the ONLY
    /// engine-level drain, so traffic is never split across readers).
    pub fn take_exec_stats(&mut self) -> ExecStats {
        let mut st = std::mem::take(&mut self.stats);
        st.bsk_bytes_streamed +=
            self.backend.take_bsk_bytes_streamed() + std::mem::take(&mut self.profiled_bsk);
        st
    }

    /// Drain the per-stage timing histograms accumulated since the last
    /// call (empty unless `obs::enabled` during execution). Includes the
    /// backend's FFT-transform meter.
    pub fn take_stage_times(&mut self) -> StageHists {
        let mut st = std::mem::take(&mut self.stage);
        st.fft.merge(&self.backend.take_fft_hist());
        st
    }

    /// Drain the per-schedule-batch measured profiles accumulated since
    /// the last call (empty unless `obs::enabled` during execution).
    pub fn take_batch_profiles(&mut self) -> Vec<PlanBatchProfile> {
        std::mem::take(&mut self.profiles)
    }

    fn lut_for(&mut self, p: &ParamSet, table: &crate::ir::LutTable) -> Arc<[u64]> {
        self.lut_cache
            .entry(table.hash)
            .or_insert_with(|| {
                let vals = table.values.clone();
                Arc::from(encoding::make_lut_poly(p, move |m| vals[m as usize]))
            })
            .clone()
    }

    // ------------------------------------------------------------------
    // Schedule-driven execution (the default path).
    // ------------------------------------------------------------------

    /// Execute a compiled plan on one encrypted request.
    pub fn run_plan(&mut self, plan: &CompiledPlan, inputs: &[LweCiphertext]) -> Vec<LweCiphertext> {
        let mut outs = self.run_plan_batch_slices(plan, &[inputs]);
        outs.pop().unwrap()
    }

    /// Execute a compiled plan for a whole batch of requests. Convenience
    /// wrapper over owned per-request input vectors.
    pub fn run_plan_batch(
        &mut self,
        plan: &CompiledPlan,
        batch: &[Vec<LweCiphertext>],
    ) -> Vec<Vec<LweCiphertext>> {
        let refs: Vec<&[LweCiphertext]> = batch.iter().map(Vec::as_slice).collect();
        self.run_plan_batch_slices(plan, &refs)
    }

    /// Walk the plan's schedule batch-by-batch: linear ops, then the
    /// batch's key switches (each deduplicated KS computed ONCE and its
    /// short ciphertexts broadcast to every consuming rotation), then all
    /// blind rotations sharing an accumulator fused into one
    /// [`PbsBackend::blind_rotate_batch`] sweep spanning nodes x requests,
    /// then sample extraction. Returns one output vector per request.
    pub fn run_plan_batch_slices(
        &mut self,
        plan: &CompiledPlan,
        batch: &[&[LweCiphertext]],
    ) -> Vec<Vec<LweCiphertext>> {
        if batch.is_empty() {
            return Vec::new();
        }
        let g = &plan.graph;
        for inputs in batch {
            assert_eq!(inputs.len(), g.n_inputs, "input arity");
        }
        let p = self.backend.params().clone();
        assert_eq!(p.width, plan.program.width, "program width must match params");
        let nb = batch.len();
        // Per-primitive outputs, one ciphertext per request.
        let mut lwe: Vec<Option<Vec<LweCiphertext>>> = vec![None; g.ops.len()];
        let mut glwe: Vec<Option<Vec<GlweCiphertext>>> = vec![None; g.ops.len()];
        // One gate check per call: the disabled path below is the original
        // loop with untaken branches — no clocks, no histogram touches,
        // no per-batch BSK drains.
        let profiling = obs::enabled();
        if profiling && self.profiles.len() < plan.schedule.batches.len() {
            self.profiles.resize(plan.schedule.batches.len(), PlanBatchProfile::default());
        }
        for (bi, sb) in plan.schedule.batches.iter().enumerate() {
            let mut prof = PlanBatchProfile::default();
            for &id in &sb.lin_ops {
                exec_linear(&p, g, id, batch, &mut lwe);
            }
            let ks_span = obs::trace::start();
            for &id in &sb.ks_ops {
                if lwe[id].is_some() {
                    continue; // shared KS already computed
                }
                let PrimKind::KeySwitch { src } = &g.ops[id].kind else {
                    panic!("ks_ops lists non-KS prim {id}")
                };
                let mut outs: Vec<LweCiphertext> = Vec::with_capacity(nb);
                for q in 0..nb {
                    let t0 = obs::timer();
                    outs.push(self.backend.keyswitch(fetch(batch, &lwe, *src, q)));
                    if t0.is_some() {
                        let ns = obs::elapsed_ns(t0);
                        self.stage.keyswitch.record(ns);
                        prof.ks_ns += ns;
                    }
                }
                self.stats.ks_ops += nb as u64;
                prof.ks_calls += nb as u64;
                lwe[id] = Some(outs);
            }
            obs::trace::span("keyswitch", 0, ks_span);
            // Fuse rotations sharing an accumulator into one sweep each:
            // the BSK streams once per (table, batch) instead of once per
            // node — strictly better amortization than per-node batching.
            let mut groups: Vec<(usize, Vec<PrimId>)> = Vec::new();
            for &br in &sb.br_ops {
                let PrimKind::BlindRotate { table } = &g.ops[br].kind else {
                    panic!("br_ops lists non-BR prim {br}")
                };
                match groups.iter().position(|(t, _)| t == table) {
                    Some(i) => groups[i].1.push(br),
                    None => groups.push((*table, vec![br])),
                }
            }
            let br_span = obs::trace::start();
            for (table, brs) in &groups {
                let lut = self.lut_for(&p, &g.tables[*table]);
                let mut shorts: Vec<LweCiphertext> = Vec::with_capacity(brs.len() * nb);
                for &br in brs {
                    let ks = ks_dep(g, br);
                    shorts.extend(lwe[ks].as_ref().expect("KS before BR").iter().cloned());
                }
                let t0 = obs::timer();
                let mut accs = self.backend.blind_rotate_batch(&shorts, &lut);
                if t0.is_some() {
                    let ns = obs::elapsed_ns(t0);
                    self.stage.blind_rotate.record(ns);
                    prof.br_ns += ns;
                }
                debug_assert_eq!(accs.len(), brs.len() * nb);
                self.stats.pbs_ops += (brs.len() * nb) as u64;
                self.stats.br_calls += 1;
                prof.pbs += (brs.len() * nb) as u64;
                prof.br_calls += 1;
                // Hand each BR its accumulators without copying: split the
                // result vector from the tail (brs order = accs order).
                for &br in brs.iter().rev() {
                    glwe[br] = Some(accs.split_off(accs.len() - nb));
                }
            }
            obs::trace::span("blind_rotate", 0, br_span);
            let se_span = obs::trace::start();
            for &id in &sb.se_ops {
                let br = g.ops[id]
                    .deps
                    .iter()
                    .copied()
                    .find(|&d| PrimKind::is_blind_rotate(&g.ops[d].kind))
                    .expect("SampleExtract has a BlindRotate dep");
                // take(): each BR has exactly one SE consumer, so the
                // accumulators are freed as soon as they are extracted
                // (peak GLWE memory = one level, not the whole program).
                let accs = glwe[br].take().expect("BR before SE");
                let mut outs: Vec<LweCiphertext> = Vec::with_capacity(accs.len());
                for acc in &accs {
                    let t0 = obs::timer();
                    outs.push(self.backend.sample_extract(acc));
                    if t0.is_some() {
                        let ns = obs::elapsed_ns(t0);
                        self.stage.sample_extract.record(ns);
                        prof.se_ns += ns;
                    }
                }
                lwe[id] = Some(outs);
            }
            obs::trace::span("sample_extract", 0, se_span);
            if profiling {
                // Per-batch BSK attribution: drain the backend's counter
                // here and re-add it in take_exec_stats via profiled_bsk,
                // so the rolled-up total is unchanged by profiling.
                prof.bsk_bytes = self.backend.take_bsk_bytes_streamed();
                self.profiled_bsk += prof.bsk_bytes;
                prof.executions = 1;
                prof.requests = nb as u64;
                self.profiles[bi].merge(&prof);
            }
        }
        for &id in &plan.schedule.loose_linear {
            exec_linear(&p, g, id, batch, &mut lwe);
        }
        (0..nb)
            .map(|q| g.outputs.iter().map(|&o| fetch(batch, &lwe, o, q).clone()).collect())
            .collect()
    }

    // ------------------------------------------------------------------
    // Legacy node-walking execution (naive baseline / equivalence oracle).
    // ------------------------------------------------------------------

    /// Execute `prog` on encrypted inputs; returns encrypted outputs.
    pub fn run(&mut self, prog: &Program, inputs: &[LweCiphertext]) -> Vec<LweCiphertext> {
        let mut outs = self.run_batch_slices(prog, &[inputs]);
        outs.pop().unwrap()
    }

    /// Execute `prog` for a whole batch of requests in lockstep (see
    /// [`Self::run_batch_slices`]). Convenience wrapper over owned
    /// per-request input vectors.
    pub fn run_batch(
        &mut self,
        prog: &Program,
        batch: &[Vec<LweCiphertext>],
    ) -> Vec<Vec<LweCiphertext>> {
        let refs: Vec<&[LweCiphertext]> = batch.iter().map(Vec::as_slice).collect();
        self.run_batch_slices(prog, &refs)
    }

    /// Execute `prog` for a whole batch of requests in lockstep: every
    /// node is evaluated across the batch before moving to the next, so
    /// each `Lut`/`BivLut` node becomes ONE [`PbsBackend::pbs_batch`]
    /// call. Per-node batching only — unlike the plan path it neither
    /// shares key switches across fanout nor fuses rotations across
    /// nodes. Returns one output vector per request, in request order.
    pub fn run_batch_slices(
        &mut self,
        prog: &Program,
        batch: &[&[LweCiphertext]],
    ) -> Vec<Vec<LweCiphertext>> {
        if batch.is_empty() {
            return Vec::new();
        }
        for inputs in batch {
            assert_eq!(inputs.len(), prog.input_count(), "input arity");
        }
        let p = self.backend.params().clone();
        assert_eq!(p.width, prog.width, "program width must match params");
        let delta = p.delta();
        let nb = batch.len();
        // vals[node] = one ciphertext per request.
        let mut vals: Vec<Option<Vec<LweCiphertext>>> = vec![None; prog.nodes.len()];
        let mut next_input = 0usize;
        for (i, node) in prog.nodes.iter().enumerate() {
            let out: Vec<LweCiphertext> = match node {
                Op::Input => {
                    let idx = next_input;
                    next_input += 1;
                    batch.iter().map(|inputs| inputs[idx].clone()).collect()
                }
                Op::Add(a, b) => (0..nb)
                    .map(|q| {
                        let mut ct = vals[*a].as_ref().unwrap()[q].clone();
                        ct.add_assign(&vals[*b].as_ref().unwrap()[q]);
                        ct
                    })
                    .collect(),
                Op::Sub(a, b) => (0..nb)
                    .map(|q| {
                        let mut ct = vals[*a].as_ref().unwrap()[q].clone();
                        ct.sub_assign(&vals[*b].as_ref().unwrap()[q]);
                        ct
                    })
                    .collect(),
                Op::AddPlain(a, c) => (0..nb)
                    .map(|q| {
                        let mut ct = vals[*a].as_ref().unwrap()[q].clone();
                        ct.plain_add_assign(c.wrapping_mul(delta));
                        ct
                    })
                    .collect(),
                Op::MulPlain(a, c) => (0..nb)
                    .map(|q| {
                        let mut ct = vals[*a].as_ref().unwrap()[q].clone();
                        ct.scalar_mul_assign(*c);
                        ct
                    })
                    .collect(),
                Op::Dot { inputs: xs, weights, bias } => (0..nb)
                    .map(|q| {
                        let mut acc =
                            LweCiphertext::trivial(bias.wrapping_mul(delta), p.long_dim());
                        for (x, &w) in xs.iter().zip(weights) {
                            if w == 0 {
                                continue;
                            }
                            let mut t = vals[*x].as_ref().unwrap()[q].clone();
                            t.scalar_mul_assign(w);
                            acc.add_assign(&t);
                        }
                        acc
                    })
                    .collect(),
                Op::Lut { input, table } => {
                    let lut = self.lut_for(&p, table);
                    self.stats.ks_ops += nb as u64;
                    self.stats.pbs_ops += nb as u64;
                    self.stats.br_calls += 1;
                    self.backend.pbs_batch(vals[*input].as_ref().unwrap(), &lut)
                }
                Op::BivLut { a, b, table } => {
                    // pack = x * 2^(w/2) + y, then univariate LUT.
                    let scale = encoding::bivariate_scale(&p) as i64;
                    let packed: Vec<LweCiphertext> = (0..nb)
                        .map(|q| {
                            let mut ct = vals[*a].as_ref().unwrap()[q].clone();
                            ct.scalar_mul_assign(scale);
                            ct.add_assign(&vals[*b].as_ref().unwrap()[q]);
                            ct
                        })
                        .collect();
                    let lut = self.lut_for(&p, table);
                    self.stats.ks_ops += nb as u64;
                    self.stats.pbs_ops += nb as u64;
                    self.stats.br_calls += 1;
                    self.backend.pbs_batch(&packed, &lut)
                }
            };
            debug_assert_eq!(out.len(), nb);
            vals[i] = Some(out);
        }
        (0..nb)
            .map(|q| prog.outputs.iter().map(|&o| vals[o].as_ref().unwrap()[q].clone()).collect())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, CompileOpts};
    use crate::ir::builder::ProgramBuilder;
    use crate::ir::interp;
    use crate::params::TEST1;
    use crate::tfhe::pbs::{decrypt_message, encrypt_message};
    use crate::tfhe::SecretKeys;
    use crate::util::rng::Rng;

    fn setup() -> (SecretKeys, ServerKeys, Rng) {
        let mut rng = Rng::new(99);
        let sk = SecretKeys::generate(&TEST1, &mut rng);
        let keys = ServerKeys::generate(&sk, &mut rng);
        (sk, keys, rng)
    }

    #[test]
    fn engine_matches_plaintext_interpreter() {
        let (sk, keys, mut rng) = setup();
        let mut b = ProgramBuilder::new("mix", 3);
        let x = b.input();
        let y = b.input();
        let s = b.add(x, y);
        let d = b.mul_plain(s, 2);
        let r = b.lut_fn(d, |m| (m + 3) % 16);
        let t = b.sub(r, x);
        b.output(t);
        let prog = b.finish();

        let mut eng = Engine::new(NativePbsBackend::new(&keys));
        for (mx, my) in [(1u64, 2u64), (3, 0), (2, 2)] {
            let cts = vec![
                encrypt_message(mx, &sk, &mut rng),
                encrypt_message(my, &sk, &mut rng),
            ];
            let out = eng.run(&prog, &cts);
            let expected = interp::eval(&prog, &[mx, my]);
            let got: Vec<u64> = out.iter().map(|c| decrypt_message(c, &sk)).collect();
            assert_eq!(got, expected, "inputs ({mx},{my})");
        }
    }

    #[test]
    fn dot_with_negative_weights() {
        let (sk, keys, mut rng) = setup();
        let mut b = ProgramBuilder::new("dot", 3);
        let ins = b.inputs(3);
        let d = b.dot(ins, vec![2, -1, 1], 1);
        b.output(d);
        let prog = b.finish();
        let mut eng = Engine::new(NativePbsBackend::new(&keys));
        let msgs = [3u64, 2, 1];
        let cts: Vec<_> = msgs.iter().map(|&m| encrypt_message(m, &sk, &mut rng)).collect();
        let out = eng.run(&prog, &cts);
        // 2*3 - 2 + 1 + 1 = 6
        assert_eq!(decrypt_message(&out[0], &sk), 6);
    }

    #[test]
    fn lut_cache_shares_accumulators() {
        let (sk, keys, mut rng) = setup();
        let mut b = ProgramBuilder::new("acc", 3);
        let xs = b.inputs(4);
        let table = crate::ir::LutTable::from_fn(3, |m| m ^ 1);
        for x in xs {
            let y = b.lut(x, table.clone());
            b.output(y);
        }
        let prog = b.finish();
        let mut eng = Engine::new(NativePbsBackend::new(&keys));
        let cts: Vec<_> = (0..4).map(|m| encrypt_message(m, &sk, &mut rng)).collect();
        let out = eng.run(&prog, &cts);
        assert_eq!(eng.cached_accumulators(), 1, "one table -> one accumulator");
        for (m, ct) in out.iter().enumerate() {
            assert_eq!(decrypt_message(ct, &sk), (m as u64) ^ 1);
        }
    }

    #[test]
    fn run_batch_matches_per_request_run() {
        let (sk, keys, mut rng) = setup();
        let mut b = ProgramBuilder::new("batched", 3);
        let x = b.input();
        let y = b.input();
        let s = b.add(x, y);
        let l = b.lut_fn(s, |m| (m * 3 + 1) % 16);
        let g = b.biv_lut_fn(x, y, |a, bb| a | bb);
        let o = b.add(l, g);
        b.output(o);
        let prog = b.finish();

        let queries: Vec<(u64, u64)> = vec![(1, 0), (0, 1), (1, 1), (2, 0), (3, 1)];
        let batch: Vec<Vec<LweCiphertext>> = queries
            .iter()
            .map(|&(mx, my)| {
                vec![encrypt_message(mx, &sk, &mut rng), encrypt_message(my, &sk, &mut rng)]
            })
            .collect();

        let mut eng = Engine::new(NativePbsBackend::new(&keys));
        let batched = eng.run_batch(&prog, &batch);
        assert!(
            eng.take_exec_stats().bsk_bytes_streamed > 0,
            "traffic counter wired through"
        );
        let mut eng2 = Engine::new(NativePbsBackend::new(&keys));
        for (q, (inputs, &(mx, my))) in batch.iter().zip(&queries).enumerate() {
            let single = eng2.run(&prog, inputs);
            let exp = interp::eval(&prog, &[mx, my]);
            let got: Vec<u64> = batched[q].iter().map(|c| decrypt_message(c, &sk)).collect();
            let got_single: Vec<u64> = single.iter().map(|c| decrypt_message(c, &sk)).collect();
            assert_eq!(got, exp, "batched q={q}");
            assert_eq!(got_single, exp, "single q={q}");
        }
    }

    #[test]
    fn bivariate_lut_executes() {
        let (sk, keys, mut rng) = setup();
        // width 3 -> halves of 1 bit each.
        let mut b = ProgramBuilder::new("biv", 3);
        let x = b.input();
        let y = b.input();
        let g = b.biv_lut_fn(x, y, |a, bb| a & bb);
        b.output(g);
        let prog = b.finish();
        let mut eng = Engine::new(NativePbsBackend::new(&keys));
        for (mx, my) in [(0u64, 1u64), (1, 1), (1, 0)] {
            let cts = vec![
                encrypt_message(mx, &sk, &mut rng),
                encrypt_message(my, &sk, &mut rng),
            ];
            let out = eng.run(&prog, &cts);
            assert_eq!(decrypt_message(&out[0], &sk), mx & my, "({mx},{my})");
        }
    }

    #[test]
    fn run_plan_matches_legacy_and_interp() {
        let (sk, keys, mut rng) = setup();
        // Every op kind: linear mix, fanout LUTs, a bivariate LUT, a
        // dependent second PBS level, and a linear tail.
        let mut b = ProgramBuilder::new("plan", 3);
        let x = b.input();
        let y = b.input();
        let s = b.add(x, y);
        let l1 = b.lut_fn(s, |m| (m + 5) % 16);
        let l2 = b.lut_fn(s, |m| m ^ 3); // fanout: shares s's KS
        let t = b.sub(l1, l2);
        let g = b.biv_lut_fn(x, y, |a, bb| a.max(bb));
        let u = b.add(t, g);
        let v = b.lut_fn(u, |m| (m * 3) % 16); // second level
        let w = b.add_plain(v, 1); // linear tail
        b.output(w);
        let prog = b.finish();
        let plan = compile(&prog, &TEST1, CompileOpts::default());

        let mut eng = Engine::new(NativePbsBackend::new(&keys));
        let mut eng2 = Engine::new(NativePbsBackend::new(&keys));
        for (mx, my) in [(1u64, 0u64), (0, 1), (1, 1)] {
            let cts = vec![
                encrypt_message(mx, &sk, &mut rng),
                encrypt_message(my, &sk, &mut rng),
            ];
            let exp = interp::eval(&prog, &[mx, my]);
            let got: Vec<u64> =
                eng.run_plan(&plan, &cts).iter().map(|c| decrypt_message(c, &sk)).collect();
            let legacy: Vec<u64> =
                eng2.run(&prog, &cts).iter().map(|c| decrypt_message(c, &sk)).collect();
            assert_eq!(got, exp, "plan ({mx},{my})");
            assert_eq!(legacy, exp, "legacy ({mx},{my})");
        }
        // Measured counts equal the compiled plan's.
        let st = eng.take_exec_stats();
        assert_eq!(st.ks_ops, 3 * plan.ks_dedup.after as u64);
        assert_eq!(st.pbs_ops, 3 * plan.graph.pbs_count() as u64);
        // Legacy pays one KS per LUT node.
        let st2 = eng2.take_exec_stats();
        assert_eq!(st2.ks_ops, 3 * plan.ks_dedup.before as u64);
    }

    #[test]
    fn plan_fanout_one_keyswitch_one_fused_sweep() {
        let (sk, keys, mut rng) = setup();
        // N LUTs over one value, all sharing one table: the plan performs
        // exactly 1 key switch (legacy: N) and ONE fused rotation sweep.
        let n = 4usize;
        let table = crate::ir::LutTable::from_fn(3, |m| (m + 1) % 16);
        let mut b = ProgramBuilder::new("fan", 3);
        let x = b.input();
        for _ in 0..n {
            let y = b.lut(x, table.clone());
            b.output(y);
        }
        let prog = b.finish();
        let plan = compile(&prog, &TEST1, CompileOpts::default());
        assert_eq!(plan.ks_dedup.after, 1);

        let mut eng = Engine::new(NativePbsBackend::new(&keys));
        let m = 3u64;
        let ct = vec![encrypt_message(m, &sk, &mut rng)];
        let outs = eng.run_plan(&plan, &ct);
        let exp = interp::eval(&prog, &[m]);
        let got: Vec<u64> = outs.iter().map(|c| decrypt_message(c, &sk)).collect();
        assert_eq!(got, exp);
        let st = eng.take_exec_stats();
        assert_eq!(st.ks_ops, 1, "one KS broadcast to {n} rotations");
        assert_eq!(st.pbs_ops, n as u64);
        assert_eq!(st.br_calls, 1, "shared table -> one fused sweep");
        // The fused sweep streams the BSK once for all n rotations.
        assert!(st.bsk_bytes_streamed <= keys.bsk.bytes() as u64);

        let mut legacy = Engine::new(NativePbsBackend::new(&keys));
        let outs2 = legacy.run(&prog, &ct);
        let got2: Vec<u64> = outs2.iter().map(|c| decrypt_message(c, &sk)).collect();
        assert_eq!(got2, exp);
        let st2 = legacy.take_exec_stats();
        assert_eq!(st2.ks_ops, n as u64, "legacy pays a KS per node");
        assert_eq!(st2.br_calls, n as u64, "legacy sweeps per node");
    }

    #[test]
    fn run_plan_batch_matches_per_request() {
        let (sk, keys, mut rng) = setup();
        let mut b = ProgramBuilder::new("planbatch", 3);
        let x = b.input();
        let y = b.input();
        let s = b.add(x, y);
        let l = b.lut_fn(s, |m| (m * 5 + 2) % 16);
        let r = b.lut_fn(s, |m| m.saturating_sub(1));
        let o = b.add(l, r);
        b.output(o);
        let prog = b.finish();
        let plan = compile(&prog, &TEST1, CompileOpts::default());

        let queries: Vec<(u64, u64)> = vec![(1, 0), (2, 1), (0, 3)];
        let batch: Vec<Vec<LweCiphertext>> = queries
            .iter()
            .map(|&(mx, my)| {
                vec![encrypt_message(mx, &sk, &mut rng), encrypt_message(my, &sk, &mut rng)]
            })
            .collect();
        let mut eng = Engine::new(NativePbsBackend::new(&keys));
        let outs = eng.run_plan_batch(&plan, &batch);
        for (q, &(mx, my)) in queries.iter().enumerate() {
            let exp = interp::eval(&prog, &[mx, my]);
            let got: Vec<u64> = outs[q].iter().map(|c| decrypt_message(c, &sk)).collect();
            assert_eq!(got, exp, "q={q}");
        }
        let st = eng.take_exec_stats();
        assert_eq!(st.ks_ops, queries.len() as u64 * plan.ks_dedup.after as u64);
        assert_eq!(st.pbs_ops, queries.len() as u64 * plan.graph.pbs_count() as u64);
    }

    #[test]
    fn shared_backend_rebinds_keys_between_tenants() {
        // The multi-tenant worker pattern: ONE engine (one FFT plan, one
        // scratch set, one accumulator cache) executing consecutive
        // sub-batches under different tenants' keys via set_keys.
        let mut rng = Rng::new(101);
        let sk_a = SecretKeys::generate(&TEST1, &mut rng);
        let keys_a = std::sync::Arc::new(ServerKeys::generate(&sk_a, &mut rng));
        let sk_b = SecretKeys::generate(&TEST1, &mut rng);
        let keys_b = std::sync::Arc::new(ServerKeys::generate(&sk_b, &mut rng));

        let mut b = ProgramBuilder::new("rebind", 3);
        let x = b.input();
        let y = b.lut_fn(x, |m| (m * 3 + 1) % 16);
        b.output(y);
        let prog = b.finish();
        let plan = compile(&prog, &TEST1, CompileOpts::default());

        let mut eng = Engine::new(NativePbsBackend::shared(keys_a.clone()));
        for (m, sk, keys) in [(2u64, &sk_a, &keys_a), (5, &sk_b, &keys_b), (3, &sk_a, &keys_a)] {
            eng.backend.set_keys(keys.clone());
            let ct = vec![encrypt_message(m, sk, &mut rng)];
            let outs = eng.run_plan(&plan, &ct);
            assert_eq!(
                decrypt_message(&outs[0], sk),
                interp::eval(&prog, &[m])[0],
                "m={m} under its own tenant key"
            );
        }
        // One accumulator encoded despite three sub-batches and two key
        // sets: LUT polys are plaintext, shared across tenants.
        assert_eq!(eng.cached_accumulators(), 1);
    }

    #[test]
    fn run_plan_pure_linear_program() {
        let (sk, keys, mut rng) = setup();
        let mut b = ProgramBuilder::new("lin", 3);
        let x = b.input();
        let y = b.input();
        let s = b.add(x, y);
        let t = b.mul_plain(s, 2);
        b.output(t);
        b.output(x);
        let prog = b.finish();
        let plan = compile(&prog, &TEST1, CompileOpts::default());
        assert!(plan.schedule.batches.is_empty());
        let mut eng = Engine::new(NativePbsBackend::new(&keys));
        let cts = vec![encrypt_message(2, &sk, &mut rng), encrypt_message(1, &sk, &mut rng)];
        let outs = eng.run_plan(&plan, &cts);
        let got: Vec<u64> = outs.iter().map(|c| decrypt_message(c, &sk)).collect();
        assert_eq!(got, interp::eval(&prog, &[2, 1]));
        assert_eq!(eng.take_exec_stats().pbs_ops, 0);
    }
}
