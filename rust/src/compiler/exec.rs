//! Functional executor: runs an IR program on real ciphertexts through a
//! pluggable PBS backend (native Rust TFHE or the AOT XLA artifacts).
//! Linear ops execute on long LWE ciphertexts exactly as the LPU would.

use std::collections::HashMap;

use crate::ir::{Op, Program};
use crate::params::ParamSet;
use crate::tfhe::encoding;
use crate::tfhe::{LweCiphertext, PbsContext, ServerKeys};

/// A PBS implementation (one bootstrap, LUT polynomial pre-encoded).
pub trait PbsBackend {
    fn pbs(&mut self, ct_long: &LweCiphertext, lut_poly: &[u64]) -> LweCiphertext;

    /// Batched PBS over one shared LUT. Backends that can fuse the blind
    /// rotations (streaming each BSK row once per batch) override this;
    /// the default is the sequential fallback.
    fn pbs_batch(&mut self, cts: &[LweCiphertext], lut_poly: &[u64]) -> Vec<LweCiphertext> {
        cts.iter().map(|ct| self.pbs(ct, lut_poly)).collect()
    }

    fn params(&self) -> &ParamSet;

    /// Drain the backend's Fourier-BSK traffic counter (bytes streamed by
    /// blind rotations since the last call); 0 for backends that don't
    /// track it.
    fn take_bsk_bytes_streamed(&mut self) -> u64 {
        0
    }
}

/// Native (pure-Rust) backend.
pub struct NativePbsBackend<'k> {
    pub ctx: PbsContext,
    pub keys: &'k ServerKeys,
}

impl<'k> NativePbsBackend<'k> {
    pub fn new(keys: &'k ServerKeys) -> Self {
        Self { ctx: PbsContext::new(&keys.params), keys }
    }
}

impl PbsBackend for NativePbsBackend<'_> {
    fn pbs(&mut self, ct_long: &LweCiphertext, lut_poly: &[u64]) -> LweCiphertext {
        self.ctx.pbs(ct_long, self.keys, lut_poly)
    }

    fn pbs_batch(&mut self, cts: &[LweCiphertext], lut_poly: &[u64]) -> Vec<LweCiphertext> {
        self.ctx.pbs_batch(cts, self.keys, lut_poly)
    }

    fn params(&self) -> &ParamSet {
        &self.keys.params
    }

    fn take_bsk_bytes_streamed(&mut self) -> u64 {
        self.ctx.take_bsk_bytes_streamed()
    }
}

/// The XLA artifacts execute one blind rotation per invocation, so this
/// backend keeps the sequential `pbs_batch` fallback.
#[cfg(feature = "xla")]
impl PbsBackend for crate::runtime::XlaPbsBackend {
    fn pbs(&mut self, ct_long: &LweCiphertext, lut_poly: &[u64]) -> LweCiphertext {
        crate::runtime::XlaPbsBackend::pbs(self, ct_long, lut_poly).expect("xla pbs")
    }

    fn params(&self) -> &ParamSet {
        &self.params
    }
}

/// Program executor with an accumulator (LUT polynomial) cache — ACC-dedup
/// in action: each distinct table is encoded once and shared.
pub struct Engine<B: PbsBackend> {
    pub backend: B,
    lut_cache: HashMap<u64, Vec<u64>>,
}

impl<B: PbsBackend> Engine<B> {
    pub fn new(backend: B) -> Self {
        Self { backend, lut_cache: HashMap::new() }
    }

    /// Number of distinct accumulators encoded so far.
    pub fn cached_accumulators(&self) -> usize {
        self.lut_cache.len()
    }

    /// Drain the backend's Fourier-BSK traffic counter (see
    /// [`PbsBackend::take_bsk_bytes_streamed`]).
    pub fn take_bsk_bytes_streamed(&mut self) -> u64 {
        self.backend.take_bsk_bytes_streamed()
    }

    fn lut_for(&mut self, p: &ParamSet, table: &crate::ir::LutTable) -> Vec<u64> {
        self.lut_cache
            .entry(table.hash)
            .or_insert_with(|| {
                let vals = table.values.clone();
                encoding::make_lut_poly(p, move |m| vals[m as usize])
            })
            .clone()
    }

    /// Execute `prog` on encrypted inputs; returns encrypted outputs.
    pub fn run(&mut self, prog: &Program, inputs: &[LweCiphertext]) -> Vec<LweCiphertext> {
        let mut outs = self.run_batch_slices(prog, &[inputs]);
        outs.pop().unwrap()
    }

    /// Execute `prog` for a whole batch of requests in lockstep (see
    /// [`Self::run_batch_slices`]). Convenience wrapper over owned
    /// per-request input vectors.
    pub fn run_batch(
        &mut self,
        prog: &Program,
        batch: &[Vec<LweCiphertext>],
    ) -> Vec<Vec<LweCiphertext>> {
        let refs: Vec<&[LweCiphertext]> = batch.iter().map(Vec::as_slice).collect();
        self.run_batch_slices(prog, &refs)
    }

    /// Execute `prog` for a whole batch of requests in lockstep: every
    /// node is evaluated across the batch before moving to the next, so
    /// each `Lut`/`BivLut` node becomes ONE [`PbsBackend::pbs_batch`]
    /// call — a fused blind-rotation sweep that streams each BSK row once
    /// per batch (the paper's key-reuse schedule) instead of once per
    /// request. Returns one output vector per request, in request order.
    pub fn run_batch_slices(
        &mut self,
        prog: &Program,
        batch: &[&[LweCiphertext]],
    ) -> Vec<Vec<LweCiphertext>> {
        if batch.is_empty() {
            return Vec::new();
        }
        for inputs in batch {
            assert_eq!(inputs.len(), prog.input_count(), "input arity");
        }
        let p = self.backend.params().clone();
        assert_eq!(p.width, prog.width, "program width must match params");
        let delta = p.delta();
        let nb = batch.len();
        // vals[node] = one ciphertext per request.
        let mut vals: Vec<Option<Vec<LweCiphertext>>> = vec![None; prog.nodes.len()];
        let mut next_input = 0usize;
        for (i, node) in prog.nodes.iter().enumerate() {
            let out: Vec<LweCiphertext> = match node {
                Op::Input => {
                    let idx = next_input;
                    next_input += 1;
                    batch.iter().map(|inputs| inputs[idx].clone()).collect()
                }
                Op::Add(a, b) => (0..nb)
                    .map(|q| {
                        let mut ct = vals[*a].as_ref().unwrap()[q].clone();
                        ct.add_assign(&vals[*b].as_ref().unwrap()[q]);
                        ct
                    })
                    .collect(),
                Op::Sub(a, b) => (0..nb)
                    .map(|q| {
                        let mut ct = vals[*a].as_ref().unwrap()[q].clone();
                        ct.sub_assign(&vals[*b].as_ref().unwrap()[q]);
                        ct
                    })
                    .collect(),
                Op::AddPlain(a, c) => (0..nb)
                    .map(|q| {
                        let mut ct = vals[*a].as_ref().unwrap()[q].clone();
                        ct.plain_add_assign(c.wrapping_mul(delta));
                        ct
                    })
                    .collect(),
                Op::MulPlain(a, c) => (0..nb)
                    .map(|q| {
                        let mut ct = vals[*a].as_ref().unwrap()[q].clone();
                        ct.scalar_mul_assign(*c);
                        ct
                    })
                    .collect(),
                Op::Dot { inputs: xs, weights, bias } => (0..nb)
                    .map(|q| {
                        let mut acc =
                            LweCiphertext::trivial(bias.wrapping_mul(delta), p.long_dim());
                        for (x, &w) in xs.iter().zip(weights) {
                            if w == 0 {
                                continue;
                            }
                            let mut t = vals[*x].as_ref().unwrap()[q].clone();
                            t.scalar_mul_assign(w);
                            acc.add_assign(&t);
                        }
                        acc
                    })
                    .collect(),
                Op::Lut { input, table } => {
                    let lut = self.lut_for(&p, table);
                    self.backend.pbs_batch(vals[*input].as_ref().unwrap(), &lut)
                }
                Op::BivLut { a, b, table } => {
                    // pack = x * 2^(w/2) + y, then univariate LUT.
                    let scale = encoding::bivariate_scale(&p) as i64;
                    let packed: Vec<LweCiphertext> = (0..nb)
                        .map(|q| {
                            let mut ct = vals[*a].as_ref().unwrap()[q].clone();
                            ct.scalar_mul_assign(scale);
                            ct.add_assign(&vals[*b].as_ref().unwrap()[q]);
                            ct
                        })
                        .collect();
                    let lut = self.lut_for(&p, table);
                    self.backend.pbs_batch(&packed, &lut)
                }
            };
            debug_assert_eq!(out.len(), nb);
            vals[i] = Some(out);
        }
        (0..nb)
            .map(|q| prog.outputs.iter().map(|&o| vals[o].as_ref().unwrap()[q].clone()).collect())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::ProgramBuilder;
    use crate::ir::interp;
    use crate::params::TEST1;
    use crate::tfhe::pbs::{decrypt_message, encrypt_message};
    use crate::tfhe::SecretKeys;
    use crate::util::rng::Rng;

    fn setup() -> (SecretKeys, ServerKeys, Rng) {
        let mut rng = Rng::new(99);
        let sk = SecretKeys::generate(&TEST1, &mut rng);
        let keys = ServerKeys::generate(&sk, &mut rng);
        (sk, keys, rng)
    }

    #[test]
    fn engine_matches_plaintext_interpreter() {
        let (sk, keys, mut rng) = setup();
        let mut b = ProgramBuilder::new("mix", 3);
        let x = b.input();
        let y = b.input();
        let s = b.add(x, y);
        let d = b.mul_plain(s, 2);
        let r = b.lut_fn(d, |m| (m + 3) % 16);
        let t = b.sub(r, x);
        b.output(t);
        let prog = b.finish();

        let mut eng = Engine::new(NativePbsBackend::new(&keys));
        for (mx, my) in [(1u64, 2u64), (3, 0), (2, 2)] {
            let cts = vec![
                encrypt_message(mx, &sk, &mut rng),
                encrypt_message(my, &sk, &mut rng),
            ];
            let out = eng.run(&prog, &cts);
            let expected = interp::eval(&prog, &[mx, my]);
            let got: Vec<u64> = out.iter().map(|c| decrypt_message(c, &sk)).collect();
            assert_eq!(got, expected, "inputs ({mx},{my})");
        }
    }

    #[test]
    fn dot_with_negative_weights() {
        let (sk, keys, mut rng) = setup();
        let mut b = ProgramBuilder::new("dot", 3);
        let ins = b.inputs(3);
        let d = b.dot(ins, vec![2, -1, 1], 1);
        b.output(d);
        let prog = b.finish();
        let mut eng = Engine::new(NativePbsBackend::new(&keys));
        let msgs = [3u64, 2, 1];
        let cts: Vec<_> = msgs.iter().map(|&m| encrypt_message(m, &sk, &mut rng)).collect();
        let out = eng.run(&prog, &cts);
        // 2*3 - 2 + 1 + 1 = 6
        assert_eq!(decrypt_message(&out[0], &sk), 6);
    }

    #[test]
    fn lut_cache_shares_accumulators() {
        let (sk, keys, mut rng) = setup();
        let mut b = ProgramBuilder::new("acc", 3);
        let xs = b.inputs(4);
        let table = crate::ir::LutTable::from_fn(3, |m| m ^ 1);
        for x in xs {
            let y = b.lut(x, table.clone());
            b.output(y);
        }
        let prog = b.finish();
        let mut eng = Engine::new(NativePbsBackend::new(&keys));
        let cts: Vec<_> = (0..4).map(|m| encrypt_message(m, &sk, &mut rng)).collect();
        let out = eng.run(&prog, &cts);
        assert_eq!(eng.cached_accumulators(), 1, "one table -> one accumulator");
        for (m, ct) in out.iter().enumerate() {
            assert_eq!(decrypt_message(ct, &sk), (m as u64) ^ 1);
        }
    }

    #[test]
    fn run_batch_matches_per_request_run() {
        let (sk, keys, mut rng) = setup();
        let mut b = ProgramBuilder::new("batched", 3);
        let x = b.input();
        let y = b.input();
        let s = b.add(x, y);
        let l = b.lut_fn(s, |m| (m * 3 + 1) % 16);
        let g = b.biv_lut_fn(x, y, |a, bb| a | bb);
        let o = b.add(l, g);
        b.output(o);
        let prog = b.finish();

        let queries: Vec<(u64, u64)> = vec![(1, 0), (0, 1), (1, 1), (2, 0), (3, 1)];
        let batch: Vec<Vec<LweCiphertext>> = queries
            .iter()
            .map(|&(mx, my)| {
                vec![encrypt_message(mx, &sk, &mut rng), encrypt_message(my, &sk, &mut rng)]
            })
            .collect();

        let mut eng = Engine::new(NativePbsBackend::new(&keys));
        let batched = eng.run_batch(&prog, &batch);
        assert!(eng.take_bsk_bytes_streamed() > 0, "traffic counter wired through");
        let mut eng2 = Engine::new(NativePbsBackend::new(&keys));
        for (q, (inputs, &(mx, my))) in batch.iter().zip(&queries).enumerate() {
            let single = eng2.run(&prog, inputs);
            let exp = interp::eval(&prog, &[mx, my]);
            let got: Vec<u64> = batched[q].iter().map(|c| decrypt_message(c, &sk)).collect();
            let got_single: Vec<u64> = single.iter().map(|c| decrypt_message(c, &sk)).collect();
            assert_eq!(got, exp, "batched q={q}");
            assert_eq!(got_single, exp, "single q={q}");
        }
    }

    #[test]
    fn bivariate_lut_executes() {
        let (sk, keys, mut rng) = setup();
        // width 3 -> halves of 1 bit each.
        let mut b = ProgramBuilder::new("biv", 3);
        let x = b.input();
        let y = b.input();
        let g = b.biv_lut_fn(x, y, |a, bb| a & bb);
        b.output(g);
        let prog = b.finish();
        let mut eng = Engine::new(NativePbsBackend::new(&keys));
        for (mx, my) in [(0u64, 1u64), (1, 1), (1, 0)] {
            let cts = vec![
                encrypt_message(mx, &sk, &mut rng),
                encrypt_message(my, &sk, &mut rng),
            ];
            let out = eng.run(&prog, &cts);
            assert_eq!(decrypt_message(&out[0], &sk), mx & my, "({mx},{my})");
        }
    }
}
