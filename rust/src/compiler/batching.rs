//! Batch construction + scheduling (paper Fig. 9): ciphertexts are grouped
//! into batches of up to `capacity` (48 = 4 clusters x 12 round-robin) and
//! scheduled so KS/SE on the LPU overlaps BS on the BRU for *independent*
//! batches, while dependent consecutive batches stall the BRU.

use super::lowering::{PrimGraph, PrimId, PrimKind};

#[derive(Debug, Clone, Default)]
pub struct Batch {
    /// PBS level this batch executes at.
    pub level: usize,
    pub ks_ops: Vec<PrimId>,
    pub br_ops: Vec<PrimId>,
    pub se_ops: Vec<PrimId>,
    /// Linear ops that must run before this batch's key switches.
    pub lin_ops: Vec<PrimId>,
    /// True when this batch's KS inputs depend on the previous batch's BR
    /// outputs (Fig. 9 batches 4 -> 5): the BRU must wait.
    pub depends_on_prev: bool,
}

impl Batch {
    pub fn ciphertexts(&self) -> usize {
        self.br_ops.len()
    }
}

#[derive(Debug, Clone, Default)]
pub struct Schedule {
    pub batches: Vec<Batch>,
    pub capacity: usize,
    /// Linear ops not tied to any PBS (pure-linear program tail/head).
    pub loose_linear: Vec<PrimId>,
}

impl Schedule {
    pub fn total_pbs(&self) -> usize {
        self.batches.iter().map(|b| b.br_ops.len()).sum()
    }

    /// Distinct key switches the schedule executes (each KS appears in
    /// exactly one batch, however many BRs consume it) — equals
    /// `DedupStats::after` for the same graph.
    pub fn total_ks(&self) -> usize {
        self.batches.iter().map(|b| b.ks_ops.len()).sum()
    }

    /// Fraction of batch slots actually filled (hardware utilization upper
    /// bound; Fig. 15's driver).
    pub fn occupancy(&self) -> f64 {
        if self.batches.is_empty() {
            return 0.0;
        }
        let used: usize = self.batches.iter().map(Batch::ciphertexts).sum();
        used as f64 / (self.batches.len() * self.capacity) as f64
    }
}

/// Group the graph's PBS pipelines into level-ordered batches.
pub fn schedule(g: &PrimGraph, capacity: usize) -> Schedule {
    assert!(capacity > 0);
    // Collect BR ops by level; attach their KS (dep) and SE (consumer).
    let mut br_by_level: Vec<Vec<PrimId>> = Vec::new();
    for op in &g.ops {
        if PrimKind::is_blind_rotate(&op.kind) {
            let lvl = g.level[op.id];
            if br_by_level.len() <= lvl {
                br_by_level.resize(lvl + 1, Vec::new());
            }
            br_by_level[lvl].push(op.id);
        }
    }
    // SE consumers of each BR.
    let mut se_of_br: Vec<Option<PrimId>> = vec![None; g.ops.len()];
    for op in &g.ops {
        if op.kind == PrimKind::SampleExtract {
            for &d in &op.deps {
                if PrimKind::is_blind_rotate(&g.ops[d].kind) {
                    se_of_br[d] = Some(op.id);
                }
            }
        }
    }
    // Linear ops grouped by level (they run on the LPU between PBS levels).
    let mut lin_by_level: Vec<Vec<PrimId>> = Vec::new();
    let mut loose_linear = Vec::new();
    for op in &g.ops {
        if PrimKind::is_linear(&op.kind) {
            let lvl = g.level[op.id];
            if lvl >= br_by_level.len() {
                loose_linear.push(op.id);
            } else {
                if lin_by_level.len() <= lvl {
                    lin_by_level.resize(br_by_level.len().max(lvl + 1), Vec::new());
                }
                lin_by_level[lvl].push(op.id);
            }
        }
    }
    lin_by_level.resize(br_by_level.len(), Vec::new());

    let mut out = Schedule { batches: Vec::new(), capacity, loose_linear };
    // A KS shared by BRs in several chunks of a level is attached to the
    // first batch only: it is computed once and its result broadcast, so
    // both the executor and the cost model see exactly one occurrence.
    let mut ks_seen = vec![false; g.ops.len()];
    for (lvl, brs) in br_by_level.iter().enumerate() {
        let mut first_of_level = true;
        for chunk in brs.chunks(capacity) {
            let mut batch = Batch {
                level: lvl,
                depends_on_prev: first_of_level && lvl > 0,
                ..Default::default()
            };
            if first_of_level {
                batch.lin_ops = lin_by_level[lvl].clone();
            }
            for &br in chunk {
                // The KS feeding this BR (unique dep of BR).
                for &d in &g.ops[br].deps {
                    if PrimKind::is_keyswitch(&g.ops[d].kind) && !ks_seen[d] {
                        ks_seen[d] = true;
                        batch.ks_ops.push(d);
                    }
                }
                batch.br_ops.push(br);
                if let Some(se) = se_of_br[br] {
                    batch.se_ops.push(se);
                }
            }
            first_of_level = false;
            out.batches.push(batch);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::lowering::lower;
    use crate::compiler::dedup::dedup_keyswitch;
    use crate::ir::builder::ProgramBuilder;

    fn wide_program(n_luts: usize, width: usize) -> crate::ir::Program {
        let mut b = ProgramBuilder::new("wide", width);
        let xs = b.inputs(n_luts);
        for x in xs {
            let y = b.lut_fn(x, |m| m);
            b.output(y);
        }
        b.finish()
    }

    #[test]
    fn batches_respect_capacity() {
        let g = lower(&wide_program(100, 3));
        let s = schedule(&g, 48);
        assert_eq!(s.total_pbs(), 100);
        assert_eq!(s.batches.len(), 3); // 48 + 48 + 4
        assert!(s.batches.iter().all(|b| b.ciphertexts() <= 48));
        assert_eq!(s.batches[2].ciphertexts(), 4);
        // Independent (same-level) batches never stall the BRU.
        assert!(s.batches.iter().all(|b| !b.depends_on_prev));
    }

    #[test]
    fn dependent_levels_marked() {
        let mut b = ProgramBuilder::new("chain", 3);
        let x = b.input();
        let a = b.lut_fn(x, |m| m);
        let c = b.lut_fn(a, |m| m);
        b.output(c);
        let g = lower(&b.finish());
        let s = schedule(&g, 48);
        assert_eq!(s.batches.len(), 2);
        assert!(!s.batches[0].depends_on_prev);
        assert!(s.batches[1].depends_on_prev);
    }

    #[test]
    fn ks_ops_attached_once_after_dedup() {
        let mut b = ProgramBuilder::new("fan", 3);
        let x = b.input();
        for _ in 0..3 {
            let y = b.lut_fn(x, |m| m + 1);
            b.output(y);
        }
        let mut g = lower(&b.finish());
        dedup_keyswitch(&mut g);
        let s = schedule(&g, 48);
        assert_eq!(s.batches.len(), 1);
        assert_eq!(s.batches[0].ks_ops.len(), 1, "shared KS appears once");
        assert_eq!(s.batches[0].br_ops.len(), 3);
    }

    #[test]
    fn shared_ks_across_capacity_chunks_scheduled_once() {
        // Fanout 5 at capacity 2: three chunks at level 0 all feed off the
        // one deduplicated KS; it must be computed (and costed) once.
        let mut b = ProgramBuilder::new("fanchunk", 3);
        let x = b.input();
        for k in 0..5u64 {
            let y = b.lut_fn(x, move |m| (m + k) % 16);
            b.output(y);
        }
        let mut g = lower(&b.finish());
        dedup_keyswitch(&mut g);
        let s = schedule(&g, 2);
        assert_eq!(s.batches.len(), 3);
        assert_eq!(s.batches[0].ks_ops.len(), 1);
        assert_eq!(s.batches[1].ks_ops.len(), 0, "shared KS not re-listed");
        assert_eq!(s.batches[2].ks_ops.len(), 0);
        assert_eq!(s.total_ks(), 1);
        assert_eq!(s.total_pbs(), 5);
    }

    #[test]
    fn occupancy_reflects_padding() {
        let g = lower(&wide_program(12, 3));
        let s = schedule(&g, 48);
        assert!((s.occupancy() - 0.25).abs() < 1e-9);
    }
}
