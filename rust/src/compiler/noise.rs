//! Noise analysis pass — the role Concrete's optimizer plays in the
//! paper's toolchain (§III-B, Fig. 6): track noise variance through a
//! program and check the parameter set keeps the decryption-failure
//! probability below the target (footnote 7: p_error < 2^-40 per PBS).
//!
//! Variance model (standard TFHE analysis, torus-relative):
//! * fresh ciphertext: sigma_glwe^2 (long-dimension encryption);
//! * Add: variances add; MulPlain(c): variance x c^2; Dot: sum w_i^2;
//! * key switch: kN * l_ks * sigma_lwe^2 * E[digit^2] + gadget cutoff;
//! * blind rotation (PBS output): n * l * (k+1) * N * B^2/12 * sigma_glwe^2
//!   + gadget cutoff — independent of input noise (the refresh);
//! * mod switch (inside PBS): (n+1)/12 * (1/2N)^2 — must clear the
//!   decision boundary together with the input noise at KS time.

use crate::ir::{Op, Program};
use crate::params::ParamSet;

/// Per-program noise report.
#[derive(Debug, Clone)]
pub struct NoiseReport {
    /// Worst torus-relative stddev reaching any PBS input.
    pub worst_pbs_input_std: f64,
    /// Worst stddev on any program output.
    pub worst_output_std: f64,
    /// Decision boundary for this parameter set (half message slot).
    pub boundary: f64,
    /// Estimated per-op failure probability at the worst PBS (gaussian
    /// tail at the boundary).
    pub p_fail: f64,
    /// sigma margin (boundary / worst pre-decode std).
    pub margin_sigmas: f64,
}

impl NoiseReport {
    pub fn ok(&self, target_p_fail: f64) -> bool {
        self.p_fail <= target_p_fail
    }
}

/// Variance contributed by one PBS output (fresh, input-independent).
pub fn pbs_output_variance(p: &ParamSet) -> f64 {
    let b2 = (1u64 << (2 * p.bsk_base_log)) as f64;
    let ext = p.n as f64
        * p.bsk_level as f64
        * (p.k + 1) as f64
        * p.big_n as f64
        * (b2 / 12.0)
        * p.glwe_noise
        * p.glwe_noise;
    // Gadget cutoff: kept bits round at q/B^l.
    let cutoff = 2f64.powi(-2 * (p.bsk_base_log * p.bsk_level) as i32) / 12.0;
    ext + p.n as f64 * p.big_n as f64 * cutoff
}

/// Variance added by the key switch.
pub fn keyswitch_variance(p: &ParamSet) -> f64 {
    let b2 = (1u64 << (2 * p.ks_base_log)) as f64;
    let ks = p.long_dim() as f64 * p.ks_level as f64 * (b2 / 12.0) * p.lwe_noise * p.lwe_noise;
    let cutoff = 2f64.powi(-2 * (p.ks_base_log * p.ks_level) as i32) / 12.0 * p.long_dim() as f64;
    ks + cutoff
}

/// Mod-switch variance (to 2N).
pub fn modswitch_variance(p: &ParamSet) -> f64 {
    (p.n as f64 + 1.0) / 12.0 * (1.0 / (2.0 * p.big_n as f64)).powi(2)
}

/// Gaussian two-sided tail beyond `k` sigmas (upper bound, erfc-style).
fn tail(k: f64) -> f64 {
    // erfc(k/sqrt(2)) ~ sqrt(2/pi)/k * exp(-k^2/2) for k >~ 1.
    if k <= 0.0 {
        return 1.0;
    }
    ((2.0 / std::f64::consts::PI).sqrt() / k * (-0.5 * k * k).exp()).min(1.0)
}

/// Analyze a program under a parameter set.
pub fn analyze(prog: &Program, p: &ParamSet) -> NoiseReport {
    let fresh = p.glwe_noise * p.glwe_noise;
    let pbs_out = pbs_output_variance(p);
    let mut var = vec![0f64; prog.nodes.len()];
    let mut worst_pbs_in = 0f64;
    for (i, n) in prog.nodes.iter().enumerate() {
        var[i] = match n {
            Op::Input => fresh,
            Op::Add(a, b) | Op::Sub(a, b) => var[*a] + var[*b],
            Op::AddPlain(a, _) => var[*a],
            Op::MulPlain(a, c) => var[*a] * (*c as f64) * (*c as f64),
            Op::Dot { inputs, weights, .. } => inputs
                .iter()
                .zip(weights)
                .map(|(x, &w)| var[*x] * (w as f64) * (w as f64))
                .sum(),
            Op::Lut { input, .. } => {
                // The PBS *decision* sees input noise + KS + mod-switch.
                let at_decision = var[*input] + keyswitch_variance(p) + modswitch_variance(p);
                worst_pbs_in = worst_pbs_in.max(at_decision);
                pbs_out
            }
            Op::BivLut { a, b, .. } => {
                let scale = (1u64 << (p.width / 2)) as f64;
                let packed = var[*a] * scale * scale + var[*b];
                let at_decision = packed + keyswitch_variance(p) + modswitch_variance(p);
                worst_pbs_in = worst_pbs_in.max(at_decision);
                pbs_out
            }
        };
    }
    let worst_output = prog.outputs.iter().map(|&o| var[o]).fold(0.0, f64::max);
    // Boundary from the *program's* claimed width (half a message slot
    // including the padding bit).
    let boundary = 2f64.powi(-(prog.width as i32) - 2);
    // Outputs must decode too; the binding constraint is the larger of
    // worst PBS input and worst output.
    let worst = worst_pbs_in.max(worst_output);
    let std = worst.sqrt();
    let margin = boundary / std.max(1e-300);
    NoiseReport {
        worst_pbs_input_std: worst_pbs_in.sqrt(),
        worst_output_std: worst_output.sqrt(),
        boundary,
        p_fail: tail(margin),
        margin_sigmas: margin,
    }
}

/// Pick the cheapest paper parameter set that satisfies the program's
/// width and a failure-probability target, mirroring the paper's
/// "parameter search space" discussion (§III-B). Returns None if none fit.
pub fn select_params(prog: &Program, target_p_fail: f64) -> Option<&'static ParamSet> {
    let mut candidates: Vec<&'static ParamSet> = crate::params::PAPER_SETS.to_vec();
    candidates.sort_by_key(|p| p.bsk_mults_per_pbs());
    candidates
        .into_iter()
        .filter(|p| p.width >= prog.width)
        .find(|p| analyze(prog, p).ok(target_p_fail))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::ProgramBuilder;
    use crate::params::{GPT2, TEST1};

    fn lut_chain(width: usize, len: usize) -> Program {
        let mut b = ProgramBuilder::new("chain", width);
        let mut x = b.input();
        for _ in 0..len {
            x = b.lut_fn(x, |m| m);
        }
        b.output(x);
        b.finish()
    }

    #[test]
    fn test1_params_pass_their_own_workload() {
        // TEST1 passes its functional tests empirically; the analysis
        // must agree (p_fail well under 2^-20).
        let r = analyze(&lut_chain(TEST1.width, 3), &TEST1);
        assert!(r.margin_sigmas > 8.0, "margin {}", r.margin_sigmas);
        assert!(r.ok(2f64.powi(-20)), "p_fail {}", r.p_fail);
    }

    #[test]
    fn pbs_refreshes_noise_in_the_model() {
        // A long LUT chain must not accumulate: variance at every PBS
        // input is bounded by one PBS output + KS + MS.
        let short = analyze(&lut_chain(TEST1.width, 1), &TEST1);
        let long = analyze(&lut_chain(TEST1.width, 50), &TEST1);
        assert!(
            (long.worst_pbs_input_std / short.worst_pbs_input_std) < 1.5,
            "chains must not accumulate: {} vs {}",
            long.worst_pbs_input_std,
            short.worst_pbs_input_std
        );
    }

    #[test]
    fn linear_depth_grows_output_noise() {
        // Without a PBS, plaintext-muls compound: 2^6 on the stddev.
        let build = |depth: usize| {
            let mut b = ProgramBuilder::new("lin", TEST1.width);
            let mut x = b.input();
            for _ in 0..depth {
                x = b.mul_plain(x, 2);
            }
            b.output(x);
            b.finish()
        };
        let deep = analyze(&build(6), &TEST1);
        let shallow = analyze(&build(0), &TEST1);
        let ratio = deep.worst_output_std / shallow.worst_output_std;
        assert!((ratio - 64.0).abs() < 1.0, "ratio {ratio}");
    }

    #[test]
    fn too_wide_for_params_is_flagged() {
        // Width-9 messages on the (6-bit) GPT2 set: boundary shrinks 8x,
        // margins collapse.
        let mut prog = lut_chain(6, 2);
        prog.width = 6;
        let ok6 = analyze(&prog, &GPT2);
        assert!(ok6.ok(2f64.powi(-40)), "6-bit on gpt2 set should pass: {}", ok6.p_fail);
        // Same program claimed at width 9 (boundary 2^-11) on the same set.
        let mut prog9 = prog.clone();
        prog9.width = 9;
        for n in prog9.nodes.iter_mut() {
            if let crate::ir::Op::Lut { table, .. } = n {
                *table = crate::ir::LutTable::from_fn(9, |m| m);
            }
        }
        let r9 = analyze(&prog9, &GPT2);
        assert!(r9.margin_sigmas < ok6.margin_sigmas / 4.0);
    }

    #[test]
    fn high_width_paper_sets_meet_negligible_p_fail() {
        // Footnote 7 scale: parameters keep failures negligible. Under
        // our full-padding boundary (one bit stricter than Concrete's
        // production encoding, see cnn_sets_borderline...), the
        // high-width sets clear 2^-20; at Concrete's boundary the same
        // margins correspond to ~2^-40.
        for p in crate::params::PAPER_SETS {
            if p.big_n < 32768 {
                continue; // see cnn_sets_borderline_under_full_padding
            }
            let r = analyze(&lut_chain(p.width, 4), p);
            // Width-9 sets sit at ~4.2 sigma under the strict boundary
            // (mod-switch floor at N = 65536); one less width bit (the
            // production encoding) puts them at ~8.4 sigma ~ 2^-40.
            assert!(
                r.ok(2f64.powi(-14)),
                "{}: p_fail {} margin {}", p.name, r.p_fail, r.margin_sigmas
            );
            let mut relaxed = lut_chain(p.width - 1, 4);
            relaxed.width = p.width - 1;
            let r2 = analyze(&relaxed, p);
            assert!(r2.ok(2f64.powi(-40)), "{} relaxed: {}", p.name, r2.p_fail);
        }
    }

    #[test]
    fn cnn_sets_borderline_under_full_padding() {
        // Table II runs 6-bit CNNs at N = 2048/4096, where the mod-switch
        // stddev (~sqrt(n/12)/2N) sits ~2 sigma from our full-padding
        // boundary 2^-(w+2). Concrete's production encoding reserves less
        // headroom (its "6-bit" boundary is our width-5's), under which
        // the same sets clear >4 sigma — a documented encoding-convention
        // difference, not a broken parameter set.
        for p in [&crate::params::CNN20, &crate::params::CNN50] {
            let strict = analyze(&lut_chain(p.width, 2), p);
            assert!(strict.margin_sigmas > 1.5, "{}: {}", p.name, strict.margin_sigmas);
            let mut relaxed_prog = lut_chain(p.width - 1, 2);
            relaxed_prog.width = p.width - 1;
            let relaxed = analyze(&relaxed_prog, p);
            assert!(relaxed.margin_sigmas > 3.5, "{}: {}", p.name, relaxed.margin_sigmas);
        }
    }

    #[test]
    fn select_params_prefers_cheaper_sets() {
        let narrow = lut_chain(6, 2);
        let chosen = select_params(&narrow, 2f64.powi(-40)).expect("fit");
        assert!(chosen.width >= 6);
        // Must not pick a 9-bit giant when a 6-bit set fits.
        assert!(chosen.big_n <= 32768, "chose {}", chosen.name);
    }
}
