//! The paper's two deduplication optimizations (§V):
//!
//! * **KS-dedup** — when fanout applies multiple LUTs to the same value,
//!   the key-switch result is computed once and broadcast ("reduces
//!   key-switching operations by up to 47.12%"). Enabled by the
//!   key-switch-first order (Observation 6).
//! * **ACC-dedup** — programs apply the same LUT accumulator across many
//!   tensor elements; sharing the encoded GLWE accumulator "reduces GLWE
//!   storage requirements by 91.54%".

use std::collections::HashMap;

use super::lowering::{PrimGraph, PrimKind};
use crate::params::ParamSet;

#[derive(Debug, Clone, Default)]
pub struct DedupStats {
    pub before: usize,
    pub after: usize,
    pub bytes_before: usize,
    pub bytes_after: usize,
}

impl DedupStats {
    pub fn reduction_pct(&self) -> f64 {
        if self.before == 0 {
            0.0
        } else {
            100.0 * (self.before - self.after) as f64 / self.before as f64
        }
    }

    pub fn bytes_reduction_pct(&self) -> f64 {
        if self.bytes_before == 0 {
            0.0
        } else {
            100.0 * (self.bytes_before - self.bytes_after) as f64 / self.bytes_before as f64
        }
    }
}

/// Merge KeySwitch ops that switch the same IR value: keep the first, remap
/// all consumers of duplicates onto it. Returns before/after counts.
pub fn dedup_keyswitch(g: &mut PrimGraph) -> DedupStats {
    let before = g.count(PrimKind::is_keyswitch);
    // src_value -> canonical KS prim.
    let mut canon: HashMap<usize, usize> = HashMap::new();
    // old prim id -> replacement (identity unless a removed duplicate).
    let mut replace: Vec<usize> = (0..g.ops.len()).collect();
    for op in &g.ops {
        if let (PrimKind::KeySwitch, Some(src)) = (&op.kind, op.src_value) {
            match canon.get(&src) {
                Some(&keep) => {
                    // Only merge if the duplicate has identical deps after
                    // replacement (same producing primitive of src).
                    let keep_deps: Vec<usize> =
                        g.ops[keep].deps.iter().map(|&d| replace[d]).collect();
                    let dup_deps: Vec<usize> =
                        op.deps.iter().map(|&d| replace[d]).collect();
                    if keep_deps == dup_deps {
                        replace[op.id] = keep;
                    } else {
                        canon.insert(src, op.id);
                    }
                }
                None => {
                    canon.insert(src, op.id);
                }
            }
        }
    }
    // Rewrite deps and drop merged ops (compact ids).
    let mut new_id: Vec<Option<usize>> = vec![None; g.ops.len()];
    let mut ops = Vec::with_capacity(g.ops.len());
    let mut level = Vec::with_capacity(g.ops.len());
    for op in &g.ops {
        if replace[op.id] != op.id {
            continue; // merged away
        }
        let mut o = op.clone();
        o.deps = o
            .deps
            .iter()
            .map(|&d| new_id[replace[d]].expect("dep ordered before use"))
            .collect();
        o.deps.sort_unstable();
        o.deps.dedup();
        let id = ops.len();
        new_id[op.id] = Some(id);
        o.id = id;
        level.push(g.level[op.id]);
        ops.push(o);
    }
    g.ops = ops;
    g.level = level;
    debug_assert!(g.validate().is_ok());
    DedupStats {
        before,
        after: g.count(PrimKind::is_keyswitch),
        bytes_before: 0,
        bytes_after: 0,
    }
}

/// ACC-dedup: the GLWE accumulators (encoded LUTs) a program needs. Without
/// sharing, every blind rotation stores its own accumulator; with sharing,
/// one per distinct table. Returns counts and byte sizes.
pub fn acc_dedup_stats(g: &PrimGraph, p: &ParamSet) -> DedupStats {
    let mut distinct: HashMap<u64, usize> = HashMap::new();
    let mut total = 0usize;
    for op in &g.ops {
        if let PrimKind::BlindRotate { table_hash } = op.kind {
            *distinct.entry(table_hash).or_insert(0) += 1;
            total += 1;
        }
    }
    DedupStats {
        before: total,
        after: distinct.len(),
        bytes_before: total * p.glwe_bytes(),
        bytes_after: distinct.len() * p.glwe_bytes(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::lowering::lower;
    use crate::ir::builder::ProgramBuilder;
    use crate::params::TEST1;

    #[test]
    fn fanout_shares_one_keyswitch() {
        let mut b = ProgramBuilder::new("fan", 3);
        let x = b.input();
        let o1 = b.lut_fn(x, |m| m + 1);
        let o2 = b.lut_fn(x, |m| m + 2);
        let o3 = b.lut_fn(x, |m| m + 3);
        b.outputs(&[o1, o2, o3]);
        let mut g = lower(&b.finish());
        let stats = dedup_keyswitch(&mut g);
        assert_eq!(stats.before, 3);
        assert_eq!(stats.after, 1);
        assert_eq!(g.pbs_count(), 3, "BRs untouched");
        assert!((stats.reduction_pct() - 66.66).abs() < 0.1);
    }

    #[test]
    fn different_values_not_merged() {
        let mut b = ProgramBuilder::new("two", 3);
        let x = b.input();
        let y = b.input();
        let o1 = b.lut_fn(x, |m| m);
        let o2 = b.lut_fn(y, |m| m);
        b.outputs(&[o1, o2]);
        let mut g = lower(&b.finish());
        let stats = dedup_keyswitch(&mut g);
        assert_eq!((stats.before, stats.after), (2, 2));
    }

    #[test]
    fn sequential_luts_on_same_value_name_different_results() {
        // lut(lut(x)): the inner output is a *different* value than x, so
        // no bogus merging.
        let mut b = ProgramBuilder::new("seq", 3);
        let x = b.input();
        let a = b.lut_fn(x, |m| m);
        let c = b.lut_fn(a, |m| m);
        b.output(c);
        let mut g = lower(&b.finish());
        let stats = dedup_keyswitch(&mut g);
        assert_eq!((stats.before, stats.after), (2, 2));
        g.validate().unwrap();
    }

    #[test]
    fn acc_dedup_counts_distinct_tables() {
        let mut b = ProgramBuilder::new("acc", 3);
        let relu = crate::ir::LutTable::from_fn(3, |m| m.saturating_sub(1));
        let xs = b.inputs(10);
        for x in xs {
            let y = b.lut(x, relu.clone()); // same table 10x
            b.output(y);
        }
        let g = lower(&b.finish());
        let stats = acc_dedup_stats(&g, &TEST1);
        assert_eq!((stats.before, stats.after), (10, 1));
        assert_eq!(stats.bytes_before, 10 * TEST1.glwe_bytes());
        assert!((stats.bytes_reduction_pct() - 90.0).abs() < 1e-9);
    }
}
