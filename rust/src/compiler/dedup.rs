//! The paper's two deduplication optimizations (§V):
//!
//! * **KS-dedup** — when fanout applies multiple LUTs to the same value,
//!   the key-switch result is computed once and broadcast ("reduces
//!   key-switching operations by up to 47.12%"). Enabled by the
//!   key-switch-first order (Observation 6). The schedule-driven executor
//!   realizes the merge on real ciphertexts: each surviving KeySwitch
//!   primitive runs once and its output feeds every consumer.
//! * **ACC-dedup** — programs apply the same LUT accumulator across many
//!   tensor elements; sharing the encoded GLWE accumulator "reduces GLWE
//!   storage requirements by 91.54%". Realized structurally: the graph
//!   interns one table per distinct hash and the executor encodes each
//!   interned table once.

use std::collections::hash_map::Entry;
use std::collections::HashMap;

use super::lowering::{Operand, PrimGraph, PrimId, PrimKind};
use crate::params::ParamSet;

#[derive(Debug, Clone, Default)]
pub struct DedupStats {
    pub before: usize,
    pub after: usize,
    pub bytes_before: usize,
    pub bytes_after: usize,
}

impl DedupStats {
    pub fn reduction_pct(&self) -> f64 {
        if self.before == 0 {
            0.0
        } else {
            100.0 * (self.before - self.after) as f64 / self.before as f64
        }
    }

    pub fn bytes_reduction_pct(&self) -> f64 {
        if self.bytes_before == 0 {
            0.0
        } else {
            100.0 * (self.bytes_before - self.bytes_after) as f64 / self.bytes_before as f64
        }
    }
}

fn remap_operand(o: Operand, replace: &[usize], new_id: &[Option<usize>]) -> Operand {
    match o {
        Operand::Prim(p) => Operand::Prim(new_id[replace[p]].expect("operand ordered before use")),
        o => o,
    }
}

/// Merge KeySwitch ops that switch the same source ciphertext: keep the
/// first, remap all consumers of duplicates onto it. Returns before/after
/// counts.
pub fn dedup_keyswitch(g: &mut PrimGraph) -> DedupStats {
    let before = g.count(PrimKind::is_keyswitch);
    // Canonical KS per (source operand, replaced deps). Keying on the
    // full pair (instead of source alone with a deps guard) means a
    // mismatching entry never evicts an earlier canonical one — an
    // A,B,A pattern still merges the third occurrence into the first.
    let mut canon: HashMap<(Operand, Vec<PrimId>), PrimId> = HashMap::new();
    // old prim id -> replacement (identity unless a removed duplicate).
    let mut replace: Vec<usize> = (0..g.ops.len()).collect();
    for op in &g.ops {
        if let PrimKind::KeySwitch { src } = op.kind {
            let src_r = match src {
                Operand::Prim(p) => Operand::Prim(replace[p]),
                o => o,
            };
            let deps_r: Vec<PrimId> = op.deps.iter().map(|&d| replace[d]).collect();
            match canon.entry((src_r, deps_r)) {
                Entry::Occupied(e) => replace[op.id] = *e.get(),
                Entry::Vacant(e) => {
                    e.insert(op.id);
                }
            }
        }
    }
    // Rewrite deps + payload operands and drop merged ops (compact ids).
    let mut new_id: Vec<Option<usize>> = vec![None; g.ops.len()];
    let mut ops = Vec::with_capacity(g.ops.len());
    let mut level = Vec::with_capacity(g.ops.len());
    for op in &g.ops {
        if replace[op.id] != op.id {
            continue; // merged away
        }
        let mut o = op.clone();
        o.deps = o
            .deps
            .iter()
            .map(|&d| new_id[replace[d]].expect("dep ordered before use"))
            .collect();
        o.deps.sort_unstable();
        o.deps.dedup();
        match &mut o.kind {
            PrimKind::Linear(e) => e.map_operands(|x| remap_operand(x, &replace, &new_id)),
            PrimKind::KeySwitch { src } => *src = remap_operand(*src, &replace, &new_id),
            PrimKind::BlindRotate { .. } | PrimKind::SampleExtract => {}
        }
        let id = ops.len();
        new_id[op.id] = Some(id);
        o.id = id;
        level.push(g.level[op.id]);
        ops.push(o);
    }
    g.ops = ops;
    g.level = level;
    g.outputs = g
        .outputs
        .iter()
        .map(|&o| remap_operand(o, &replace, &new_id))
        .collect();
    debug_assert!(g.validate().is_ok());
    DedupStats {
        before,
        after: g.count(PrimKind::is_keyswitch),
        bytes_before: 0,
        bytes_after: 0,
    }
}

/// ACC-dedup: the GLWE accumulators (encoded LUTs) a program needs. Without
/// sharing, every blind rotation stores its own accumulator; with sharing,
/// one per distinct table (exactly the graph's interned table list).
/// Returns counts and byte sizes.
pub fn acc_dedup_stats(g: &PrimGraph, p: &ParamSet) -> DedupStats {
    let total = g.pbs_count();
    let distinct = g.tables.len();
    DedupStats {
        before: total,
        after: distinct,
        bytes_before: total * p.glwe_bytes(),
        bytes_after: distinct * p.glwe_bytes(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::lowering::{lower, LinExpr, PrimOp};
    use crate::ir::builder::ProgramBuilder;
    use crate::ir::LutTable;
    use crate::params::TEST1;

    #[test]
    fn fanout_shares_one_keyswitch() {
        let mut b = ProgramBuilder::new("fan", 3);
        let x = b.input();
        let o1 = b.lut_fn(x, |m| m + 1);
        let o2 = b.lut_fn(x, |m| m + 2);
        let o3 = b.lut_fn(x, |m| m + 3);
        b.outputs(&[o1, o2, o3]);
        let mut g = lower(&b.finish());
        let stats = dedup_keyswitch(&mut g);
        assert_eq!(stats.before, 3);
        assert_eq!(stats.after, 1);
        assert_eq!(g.pbs_count(), 3, "BRs untouched");
        assert!((stats.reduction_pct() - 66.66).abs() < 0.1);
        // All three BRs now depend on the single surviving KS.
        for op in &g.ops {
            if PrimKind::is_blind_rotate(&op.kind) {
                assert_eq!(op.deps, vec![0], "BR {} rewired to shared KS", op.id);
            }
        }
    }

    #[test]
    fn different_values_not_merged() {
        let mut b = ProgramBuilder::new("two", 3);
        let x = b.input();
        let y = b.input();
        let o1 = b.lut_fn(x, |m| m);
        let o2 = b.lut_fn(y, |m| m);
        b.outputs(&[o1, o2]);
        let mut g = lower(&b.finish());
        let stats = dedup_keyswitch(&mut g);
        assert_eq!((stats.before, stats.after), (2, 2));
    }

    #[test]
    fn sequential_luts_on_same_value_name_different_results() {
        // lut(lut(x)): the inner output is a *different* source than x, so
        // no bogus merging.
        let mut b = ProgramBuilder::new("seq", 3);
        let x = b.input();
        let a = b.lut_fn(x, |m| m);
        let c = b.lut_fn(a, |m| m);
        b.output(c);
        let mut g = lower(&b.finish());
        let stats = dedup_keyswitch(&mut g);
        assert_eq!((stats.before, stats.after), (2, 2));
        g.validate().unwrap();
    }

    #[test]
    fn outputs_remapped_after_compaction() {
        let mut b = ProgramBuilder::new("fan2", 3);
        let x = b.input();
        let o1 = b.lut_fn(x, |m| m + 1);
        let o2 = b.lut_fn(x, |m| m + 2);
        b.outputs(&[o1, o2]);
        let mut g = lower(&b.finish());
        dedup_keyswitch(&mut g);
        g.validate().unwrap();
        // Outputs still point at the two SampleExtract prims.
        for &o in &g.outputs {
            let Operand::Prim(p) = o else { panic!("output should be a prim") };
            assert_eq!(g.ops[p].kind, PrimKind::SampleExtract);
        }
    }

    #[test]
    fn deps_mismatch_does_not_evict_canonical_entry() {
        // Hand-built graph with an A,B,A keyswitch pattern: same source
        // operand, alternating deps (B carries an extra sequencing dep).
        // The IR cannot produce this shape (one value has one producer),
        // but graph transforms could; the old single-entry canonical map
        // let the B mismatch evict A's entry, so the third KS missed its
        // merge with the first.
        let t = LutTable::from_fn(3, |m| m);
        let lin = |id: usize, c: u64| PrimOp {
            id,
            kind: PrimKind::Linear(LinExpr::AddPlain(Operand::Input(0), c)),
            deps: vec![],
        };
        let ks = |id: usize, deps: Vec<usize>| PrimOp {
            id,
            kind: PrimKind::KeySwitch { src: Operand::Prim(0) },
            deps,
        };
        let br = |id: usize, dep: usize| PrimOp {
            id,
            kind: PrimKind::BlindRotate { table: 0 },
            deps: vec![dep],
        };
        let se = |id: usize, dep: usize| PrimOp {
            id,
            kind: PrimKind::SampleExtract,
            deps: vec![dep],
        };
        let ops = vec![
            lin(0, 1),
            lin(1, 2),
            ks(2, vec![0]), // A
            br(3, 2),
            se(4, 3),
            ks(5, vec![0, 1]), // B: same src, extra dep -> not mergeable
            br(6, 5),
            se(7, 6),
            ks(8, vec![0]), // A again: must merge with prim 2
            br(9, 8),
            se(10, 9),
        ];
        let mut g = PrimGraph {
            ops,
            level: vec![0, 0, 0, 0, 1, 0, 0, 1, 0, 0, 1],
            n_inputs: 1,
            tables: vec![t],
            outputs: vec![Operand::Prim(4), Operand::Prim(7), Operand::Prim(10)],
        };
        let stats = dedup_keyswitch(&mut g);
        assert_eq!((stats.before, stats.after), (3, 2), "A,B,A merges the repeat");
        g.validate().unwrap();
        // The third BR now depends on the first (surviving) KS.
        let last_br = g
            .ops
            .iter()
            .rev()
            .find(|o| PrimKind::is_blind_rotate(&o.kind))
            .unwrap();
        let first_ks = g
            .ops
            .iter()
            .find(|o| PrimKind::is_keyswitch(&o.kind))
            .unwrap();
        assert_eq!(last_br.deps, vec![first_ks.id]);
    }

    #[test]
    fn acc_dedup_counts_distinct_tables() {
        let mut b = ProgramBuilder::new("acc", 3);
        let relu = crate::ir::LutTable::from_fn(3, |m| m.saturating_sub(1));
        let xs = b.inputs(10);
        for x in xs {
            let y = b.lut(x, relu.clone()); // same table 10x
            b.output(y);
        }
        let g = lower(&b.finish());
        let stats = acc_dedup_stats(&g, &TEST1);
        assert_eq!((stats.before, stats.after), (10, 1));
        assert_eq!(stats.bytes_before, 10 * TEST1.glwe_bytes());
        assert!((stats.bytes_reduction_pct() - 90.0).abs() < 1e-9);
    }
}
