//! The Taurus compiler (paper §V): lowers IR programs into a primitive
//! TFHE operation DAG with the **key-switch-first** PBS split, applies the
//! two deduplication passes (KS-dedup, ACC-dedup), and schedules the
//! result into 48-ciphertext batches (Fig. 9).
//!
//! The compiled plan is THE executable artifact: the schedule-driven
//! executor ([`Engine::run_plan`]), the serving coordinator, and the
//! cycle-level architecture model (`crate::arch::sim`) all walk the same
//! [`CompiledPlan`], so measured KS/PBS counts and key traffic cross-check
//! the model exactly.

pub mod batching;
pub mod noise;
pub mod dedup;
pub mod exec;
pub mod lowering;

pub use batching::{Batch, Schedule};
pub use dedup::{acc_dedup_stats, dedup_keyswitch, DedupStats};
pub use exec::{Engine, EngineOptions, ExecStats, KeysRef, NativePbsBackend, PbsBackend};
pub use lowering::{lower, LinExpr, Operand, PrimGraph, PrimId, PrimKind, PrimOp};

use crate::ir::Program;
use crate::params::ParamSet;

/// Compile-pipeline options. `From<usize>` sets the batch capacity with
/// everything else defaulted, so `compile(&p, &params, 48usize)` reads
/// naturally at call sites that only care about capacity.
#[derive(Debug, Clone)]
pub struct CompileOpts {
    /// Schedule batch capacity (48 = 4 clusters x 12 round-robin, Fig. 9).
    pub batch_capacity: usize,
    /// Enable the KS-dedup pass (ablation hook).
    pub ks_dedup: bool,
}

impl Default for CompileOpts {
    fn default() -> Self {
        Self { batch_capacity: 48, ks_dedup: true }
    }
}

impl From<usize> for CompileOpts {
    fn from(batch_capacity: usize) -> Self {
        Self { batch_capacity, ..Self::default() }
    }
}

/// A fully compiled program: primitive DAG + schedule + stats. The graph
/// carries everything execution needs (linear payloads, interned LUT
/// tables, output bindings); `program` is retained for the legacy
/// node-walking executor and for reporting.
#[derive(Debug, Clone)]
pub struct CompiledPlan {
    pub program: Program,
    pub params: ParamSet,
    pub graph: PrimGraph,
    pub schedule: Schedule,
    pub ks_dedup: DedupStats,
    pub acc_dedup: DedupStats,
}

/// Backwards-compatible name used by the arch/baseline models.
pub type Compiled = CompiledPlan;

/// The single compile entry: lower -> KS-dedup -> ACC-dedup -> schedule.
pub fn compile(program: &Program, params: &ParamSet, opts: impl Into<CompileOpts>) -> CompiledPlan {
    let opts = opts.into();
    program.validate().expect("invalid program");
    let mut graph = lower(program);
    let ks_dedup = if opts.ks_dedup {
        dedup_keyswitch(&mut graph)
    } else {
        let n = graph.count(PrimKind::is_keyswitch);
        DedupStats { before: n, after: n, bytes_before: 0, bytes_after: 0 }
    };
    let acc_dedup = acc_dedup_stats(&graph, params);
    let schedule = batching::schedule(&graph, opts.batch_capacity);
    CompiledPlan {
        program: program.clone(),
        params: params.clone(),
        graph,
        schedule,
        ks_dedup,
        acc_dedup,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::ProgramBuilder;
    use crate::params::TEST1;

    fn smoke_program() -> Program {
        let mut b = ProgramBuilder::new("smoke", 3);
        let x = b.input();
        // Fanout: two LUTs over the same value -> KS-dedup opportunity.
        let a = b.lut_fn(x, |m| m + 1);
        let c = b.lut_fn(x, |m| m * 2);
        let s = b.add(a, c);
        let r = b.lut_fn(s, |m| m);
        b.output(r);
        b.finish()
    }

    #[test]
    fn compile_pipeline_smoke() {
        let compiled = compile(&smoke_program(), &TEST1, 48usize);
        assert_eq!(compiled.graph.pbs_count(), 3);
        assert_eq!(compiled.ks_dedup.before, 3);
        assert_eq!(compiled.ks_dedup.after, 2, "x's KS shared by two LUTs");
        assert!(compiled.schedule.batches.len() >= 2, "dependent levels split");
        // The schedule executes exactly the deduplicated KS set.
        assert_eq!(compiled.schedule.total_ks(), compiled.ks_dedup.after);
        assert_eq!(compiled.schedule.total_pbs(), compiled.graph.pbs_count());
    }

    #[test]
    fn compile_opts_ablate_ks_dedup() {
        let opts = CompileOpts { batch_capacity: 48, ks_dedup: false };
        let compiled = compile(&smoke_program(), &TEST1, opts);
        assert_eq!(compiled.ks_dedup.before, compiled.ks_dedup.after);
        assert_eq!(compiled.schedule.total_ks(), 3, "no merging when ablated");
    }

    #[test]
    fn plan_graph_is_self_contained() {
        let compiled = compile(&smoke_program(), &TEST1, CompileOpts::default());
        assert_eq!(compiled.graph.n_inputs, 1);
        assert_eq!(compiled.graph.outputs.len(), 1);
        assert_eq!(compiled.graph.tables.len(), 3, "three distinct LUTs");
        compiled.graph.validate().unwrap();
    }
}
