//! The Taurus compiler (paper §V): lowers IR programs into a primitive
//! TFHE operation DAG with the **key-switch-first** PBS split, applies the
//! two deduplication passes (KS-dedup, ACC-dedup), and schedules the
//! result into 48-ciphertext batches (Fig. 9).
//!
//! The same compiled artifact drives both the functional executor
//! ([`exec`]) and the cycle-level architecture model (`crate::arch::sim`).

pub mod batching;
pub mod noise;
pub mod dedup;
pub mod exec;
pub mod lowering;

pub use batching::{Batch, Schedule};
pub use dedup::{acc_dedup_stats, dedup_keyswitch, DedupStats};
pub use exec::{Engine, NativePbsBackend, PbsBackend};
pub use lowering::{lower, PrimGraph, PrimId, PrimKind, PrimOp};

use crate::ir::Program;
use crate::params::ParamSet;

/// A fully compiled program: primitive DAG + schedule + stats.
#[derive(Debug, Clone)]
pub struct Compiled {
    pub program: Program,
    pub params: ParamSet,
    pub graph: PrimGraph,
    pub schedule: Schedule,
    pub ks_dedup: DedupStats,
    pub acc_dedup: DedupStats,
}

/// Compile with the default pipeline: lower -> KS-dedup -> batch.
pub fn compile(program: &Program, params: &ParamSet, batch_capacity: usize) -> Compiled {
    compile_opts(program, params, batch_capacity, true)
}

/// Compile with explicit control over KS-dedup (ablation hook).
pub fn compile_opts(
    program: &Program,
    params: &ParamSet,
    batch_capacity: usize,
    enable_ks_dedup: bool,
) -> Compiled {
    program.validate().expect("invalid program");
    let mut graph = lower(program);
    let ks_dedup = if enable_ks_dedup {
        dedup_keyswitch(&mut graph)
    } else {
        DedupStats { before: graph.count(PrimKind::is_keyswitch), after: graph.count(PrimKind::is_keyswitch), bytes_before: 0, bytes_after: 0 }
    };
    let acc_dedup = acc_dedup_stats(&graph, params);
    let schedule = batching::schedule(&graph, batch_capacity);
    Compiled {
        program: program.clone(),
        params: params.clone(),
        graph,
        schedule,
        ks_dedup,
        acc_dedup,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::ProgramBuilder;
    use crate::params::TEST1;

    #[test]
    fn compile_pipeline_smoke() {
        let mut b = ProgramBuilder::new("smoke", 3);
        let x = b.input();
        // Fanout: two LUTs over the same value -> KS-dedup opportunity.
        let a = b.lut_fn(x, |m| m + 1);
        let c = b.lut_fn(x, |m| m * 2);
        let s = b.add(a, c);
        let r = b.lut_fn(s, |m| m);
        b.output(r);
        let p = b.finish();
        let compiled = compile(&p, &TEST1, 48);
        assert_eq!(compiled.graph.pbs_count(), 3);
        assert_eq!(compiled.ks_dedup.before, 3);
        assert_eq!(compiled.ks_dedup.after, 2, "x's KS shared by two LUTs");
        assert!(compiled.schedule.batches.len() >= 2, "dependent levels split");
    }
}
