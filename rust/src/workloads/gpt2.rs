//! Quantized GPT-2 inference (paper: HuggingFace pre-trained, 7-bit
//! quantization with 6-bit rounding; single-head and 12-head variants —
//! "the first accelerator to demonstrate privacy-preserving inference
//! with large language models").
//!
//! The Concrete lowering interleaves wide linear blocks (QKV projections,
//! MLP matmuls — bootstrap-free dots) with LUT stages (requantization,
//! GELU, softmax exp/reciprocal). Attention's sequential softmax
//! normalization and the residual requantization chains limit the
//! *exploitable* PBS parallelism per level to well under the machine
//! width — the structure behind the paper's GPT-2 utilization (Fig. 15).

use crate::ir::builder::ProgramBuilder;
use crate::ir::{LutTable, Program, ValueId};

/// Per-head PBS-level structure (calibrated against Table II; DESIGN.md
/// §Calibration): ~311 dependent LUT stages per head with ~18-wide
/// parallelism at 1 head, narrowing to ~11 effective when 12 heads
/// contend for the same residual stream.
pub fn gpt2(heads: usize, batch: usize) -> Program {
    let (levels, par) = if heads <= 1 { (311, 18) } else { (509 * heads, 11) };
    let width = 6;
    let mut b = ProgramBuilder::new(format!("gpt2-{heads}head"), width);
    let requant = LutTable::from_fn(width, |m| (m + 1) / 2); // 6-bit rounding
    let gelu = LutTable::from_fn(width, |m| {
        // coarse quantized GELU shape on [0, 64)
        let x = m as f64 / 8.0 - 4.0;
        let y = x / (1.0 + (-1.702 * x).exp());
        ((y + 4.0) * 8.0).clamp(0.0, 63.0) as u64
    });
    let exp_t = LutTable::from_fn(width, |m| {
        (((m as f64 / 8.0).exp()).min(63.0)) as u64
    });
    let tables = [requant, gelu, exp_t];
    for _ in 0..batch {
        let mut stream: Vec<ValueId> = b.inputs(par);
        for lvl in 0..levels {
            // Attention/MLP linear mixing over the stream (QKV/matmul row).
            let mixed: Vec<ValueId> = (0..par)
                .map(|j| {
                    let ins = vec![stream[j], stream[(j + 1) % par], stream[(j + 3) % par]];
                    let ws = vec![1, ((lvl + j) % 3) as i64 - 1, 1];
                    b.dot(ins, ws, 0)
                })
                .collect();
            // LUT stage: requant / GELU / softmax-exp in rotation.
            stream = mixed
                .iter()
                .map(|&v| b.lut(v, tables[lvl % tables.len()].clone()))
                .collect();
        }
        let ws = vec![1i64; par];
        let logit = b.dot(stream.clone(), ws, 0);
        b.output(logit);
    }
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_head_shape() {
        let p = gpt2(1, 1);
        assert_eq!(p.pbs_count(), 311 * 18);
        assert_eq!(p.pbs_depth(), 311);
        assert_eq!(p.width, 6);
    }

    #[test]
    fn twelve_head_scales_work_and_depth() {
        let p1 = gpt2(1, 1);
        let p12 = gpt2(12, 1);
        let work_ratio = p12.pbs_count() as f64 / p1.pbs_count() as f64;
        // Paper: 12-head is ~19x the CPU time of single-head (narrower
        // effective parallelism makes work grow superlinearly per level
        // count, ~12x raw PBS).
        assert!(work_ratio > 10.0 && work_ratio < 14.0, "{work_ratio}");
        assert!(p12.pbs_depth() > 10 * p1.pbs_depth());
    }

    #[test]
    fn uses_three_shared_tables() {
        use crate::compiler::{acc_dedup_stats, lower};
        let g = lower(&gpt2(1, 1));
        let stats = acc_dedup_stats(&g, &crate::params::GPT2);
        assert_eq!(stats.after, 3, "requant/GELU/exp shared");
    }
}
