//! Fig. 5: adding two 6-bit integers in three TFHE representations —
//! Boolean (ripple-carry, one PBS per gate), 5-bit (radix split + carry
//! bivariate LUT), and 8-bit (a single bootstrap-free homomorphic add).
//!
//! These run *functionally* on the native TFHE library at test scale and
//! feed both the Fig. 5 regeneration (measured wall-clock on this CPU +
//! the calibrated EPYC model) and `examples/integer_adder.rs`.

use crate::ir::builder::ProgramBuilder;
use crate::ir::Program;

/// Boolean ripple-carry adder over `bits`-bit inputs: each bit lane is a
/// separate Boolean ciphertext; every XOR/AND/OR gate costs one PBS
/// (the Fig. 2a pattern). 5 gates per full adder, `bits` full adders.
///
/// Gate inputs are combined linearly before the LUT (a + b can reach 2),
/// so the message space needs width >= 2 — the same headroom trick
/// Boolean TFHE's torus/8 gate encoding uses. `width` picks the parameter
/// family the gates run at (2 minimum).
pub fn boolean_ripple_carry_at(bits: usize, width: usize) -> Program {
    assert!(width >= 2);
    let mut b = ProgramBuilder::new("bool-adder", width);
    let a: Vec<_> = (0..bits).map(|_| b.input()).collect();
    let c: Vec<_> = (0..bits).map(|_| b.input()).collect();
    let mut carry = None;
    let mut sums = Vec::with_capacity(bits + 1);
    for i in 0..bits {
        // Gates via linear-combine + sign LUT (the TFHE gate recipe:
        // XOR(x,y) = lut(x + y) picking bit 0 etc.).
        let xor_t = crate::ir::LutTable::from_fn(width, |m| m & 1);
        let and_t = crate::ir::LutTable::from_fn(width, |m| u64::from(m >= 2));
        let s1 = b.add(a[i], c[i]);
        let x1 = b.lut(s1, xor_t.clone()); // a^b
        let g1 = b.lut(s1, and_t.clone()); // a&b
        match carry {
            None => {
                sums.push(x1);
                carry = Some(g1);
            }
            Some(cin) => {
                let s2 = b.add(x1, cin);
                let x2 = b.lut(s2, xor_t.clone()); // sum bit
                let g2 = b.lut(s2, and_t.clone()); // (a^b)&cin
                let or_in = b.add(g1, g2);
                let cout = b.lut(or_in, xor_t); // g1 ^ g2 == g1 | g2 here
                sums.push(x2);
                carry = Some(cout);
            }
        }
    }
    sums.push(carry.unwrap());
    b.outputs(&sums);
    b.finish()
}

/// Default Boolean adder (minimum message space).
pub fn boolean_ripple_carry(bits: usize) -> Program {
    boolean_ripple_carry_at(bits, 2)
}

/// Radix-split adder: both 6-bit inputs split into two radix-2^3 digits
/// carried in `width`-bit ciphertexts; the carry between digits needs one
/// bivariate LUT (paper Fig. 5 bottom-left; one PBS total).
pub fn radix_split_adder(width: usize) -> Program {
    let mut b = ProgramBuilder::new("radix-adder", width);
    let (alo, ahi) = (b.input(), b.input());
    let (blo, bhi) = (b.input(), b.input());
    let radix = 1u64 << (width / 2); // digit modulus
    let lo_sum = b.add(alo, blo); // may exceed the radix: extract carry
    let carry_t = crate::ir::LutTable::from_fn(width, move |m| m / radix);
    let low_t = crate::ir::LutTable::from_fn(width, move |m| m % radix);
    let carry = b.lut(lo_sum, carry_t);
    let lo = b.lut(lo_sum, low_t);
    let hi0 = b.add(ahi, bhi);
    let hi = b.add(hi0, carry);
    b.outputs(&[lo, hi]);
    b.finish()
}

/// Wide adder: a single homomorphic addition, no bootstrap at all (paper
/// Fig. 5 bottom-right: 0.008 ms).
pub fn wide_adder(width: usize) -> Program {
    let mut b = ProgramBuilder::new("wide-adder", width);
    let x = b.input();
    let y = b.input();
    let s = b.add(x, y);
    b.output(s);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::interp;

    #[test]
    fn boolean_adder_pbs_count() {
        let p = boolean_ripple_carry(6);
        // 2 LUTs for bit 0, 5 for each of the other 5 bits = 27.
        assert_eq!(p.pbs_count(), 27);
        assert!(p.pbs_depth() >= 6, "carry chain serializes");
    }

    #[test]
    fn boolean_adder_adds() {
        let p = boolean_ripple_carry(6);
        for (x, y) in [(11u64, 22u64), (63, 1), (0, 0), (31, 33)] {
            let mut inputs = vec![];
            for i in 0..6 {
                inputs.push((x >> i) & 1);
            }
            for i in 0..6 {
                inputs.push((y >> i) & 1);
            }
            let bits = interp::eval(&p, &inputs);
            let got: u64 = bits.iter().enumerate().map(|(i, &v)| (v & 1) << i).sum();
            assert_eq!(got, x + y, "{x}+{y}");
        }
    }

    #[test]
    fn radix_adder_adds_with_single_pbs_level() {
        let p = radix_split_adder(6); // digits of 3 bits
        assert_eq!(p.pbs_count(), 2);
        assert_eq!(p.pbs_depth(), 1);
        for (x, y) in [(11u64, 22u64), (7, 7), (0, 63), (45, 18)] {
            let d = 8;
            let out = interp::eval(&p, &[x % d, x / d, y % d, y / d]);
            let got = out[0] + d * out[1];
            assert_eq!(got % 128, (x + y) % 128, "{x}+{y}");
        }
    }

    #[test]
    fn wide_adder_is_linear_only() {
        let p = wide_adder(8);
        assert_eq!(p.pbs_count(), 0);
        assert_eq!(interp::eval(&p, &[40, 23]), vec![63]);
    }
}
