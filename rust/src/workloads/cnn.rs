//! Quantized CNN workloads (paper: Concrete-ML CNN-20 / CNN-50 [7],
//! post-training quantization, 6-bit). Each layer is a sparse linear
//! transform (dot products over the previous activations — bootstrap-free,
//! Fig. 2b step 4) followed by a quantized-ReLU LUT per neuron (step 5).

use crate::ir::builder::ProgramBuilder;
use crate::ir::{LutTable, Program, ValueId};

/// Build an `layers`-deep CNN with `neurons` activations per layer, each
/// neuron reading `taps` of the previous layer, replicated for `batch`
/// independent queries (the Fig. 15 batch dimension).
pub fn cnn(layers: usize, neurons: usize, taps: usize, batch: usize) -> Program {
    let width = 6;
    let mut b = ProgramBuilder::new(format!("cnn-{layers}"), width);
    // One shared quantized-ReLU table -> ACC-dedup shares the accumulator.
    let relu = LutTable::from_fn(width, |m| m.saturating_sub(8).min(31));
    let mut lanes: Vec<Vec<ValueId>> = Vec::with_capacity(batch);
    for _ in 0..batch {
        lanes.push(b.inputs(neurons.min(32)));
    }
    for layer in 0..layers {
        for lane in lanes.iter_mut() {
            let prev = lane.clone();
            let mut next = Vec::with_capacity(neurons);
            for j in 0..neurons {
                let t = taps.min(prev.len());
                let ins: Vec<ValueId> = (0..t).map(|i| prev[(j + i) % prev.len()]).collect();
                // Small signed PTQ weights; vary by position for realism.
                let ws: Vec<i64> = (0..t).map(|i| (((layer + j + i) % 5) as i64) - 2).collect();
                let acc = b.dot(ins, ws, (j % 4) as u64);
                next.push(b.lut(acc, relu.clone()));
            }
            *lane = next;
        }
    }
    for lane in &lanes {
        // Classifier head: sum a handful of logits.
        let outs: Vec<ValueId> = lane.iter().take(10).copied().collect();
        let ws = vec![1i64; outs.len()];
        let logit = b.dot(outs, ws, 0);
        b.output(logit);
    }
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cnn20_shape_matches_calibration() {
        let p = cnn(20, 100, 16, 1);
        assert_eq!(p.pbs_count(), 2000, "20 layers x 100 neurons");
        assert_eq!(p.pbs_depth(), 20, "one PBS level per layer");
        assert!(p.linear_count() >= 2000, "a dot per neuron");
    }

    #[test]
    fn single_shared_relu_table() {
        use crate::compiler::{acc_dedup_stats, lower};
        let p = cnn(5, 20, 8, 1);
        let g = lower(&p);
        let stats = acc_dedup_stats(&g, &crate::params::CNN20);
        assert_eq!(stats.after, 1, "ACC-dedup collapses all ReLUs");
        assert!(stats.bytes_reduction_pct() > 90.0);
    }
}
