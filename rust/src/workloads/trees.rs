//! Tree-ensemble workloads: the sklearn decision-tree classifier (9-bit,
//! Bioresponse, depth 18 / 91 nodes) and the XGBoost regressor (8-bit,
//! Ames Housing, 50 estimators x depth 4) of Table II.
//!
//! Concrete-ML lowers tree inference to sequences of encrypted
//! comparisons (LUT step functions) combined linearly — long dependent
//! chains with modest per-level parallelism, which is exactly why these
//! are the paper's low-utilization workloads (Fig. 15).

use crate::ir::builder::ProgramBuilder;
use crate::ir::{LutTable, Program, ValueId};

/// Serial comparison cascade: `levels` dependent steps, each evaluating
/// `luts_per_level` LUTs. Each frontier value is probed by TWO step
/// functions (the branch-taken and leaf-contribution tables of the same
/// node) — the fanout pattern KS-dedup exploits (§V: "multi-bit TFHE
/// programs commonly apply multiple different LUTs to the same
/// ciphertext").
fn cascade(name: &str, width: usize, levels: usize, luts_per_level: usize, batch: usize) -> Program {
    assert!(luts_per_level % 2 == 0, "paired LUTs per value");
    let parallel = luts_per_level / 2;
    let mut b = ProgramBuilder::new(name, width);
    let pt_half = 1u64 << width;
    // A few distinct threshold tables (step functions) reused across the
    // tree — ACC-dedup's target pattern.
    let tables: Vec<LutTable> = (1..=4)
        .map(|t| {
            let thr = (t as u64 * pt_half) / 5;
            LutTable::from_fn(width, move |m| u64::from(m >= thr))
        })
        .collect();
    let leaf_tables: Vec<LutTable> = (1..=4)
        .map(|t| {
            let thr = (t as u64 * pt_half) / 5;
            LutTable::from_fn(width, move |m| u64::from(m < thr) * (t as u64))
        })
        .collect();
    for _ in 0..batch {
        let mut frontier: Vec<ValueId> = b.inputs(parallel);
        for lvl in 0..levels {
            let mut next = Vec::with_capacity(parallel);
            for (j, &v) in frontier.iter().enumerate() {
                // Two LUTs on the same value share one key switch.
                let taken = b.lut(v, tables[(lvl + j) % tables.len()].clone());
                let leaf = b.lut(v, leaf_tables[(lvl + j) % leaf_tables.len()].clone());
                next.push((taken, leaf));
            }
            // Feature re-combination for the next level (kept linear).
            frontier = (0..parallel)
                .map(|j| {
                    let (a, l) = next[j];
                    let (c, _) = next[(j + 1) % parallel];
                    b.dot(vec![a, l, c], vec![2, 1, 1], 0)
                })
                .collect();
        }
        let ws = vec![1i64; frontier.len()];
        let score = b.dot(frontier.clone(), ws, 0);
        b.output(score);
    }
    b.finish()
}

/// Decision-tree classifier (paper: 18 max depth, 91 nodes, 7-bit
/// quantization run at the 9-bit parameter set).
pub fn decision_tree(levels: usize, parallel: usize, batch: usize) -> Program {
    cascade("decision_tree", 9, levels, parallel, batch)
}

/// XGBoost regressor (50 estimators x depth 4; estimators are parallel in
/// bursts but the quantized aggregation serializes between depths).
pub fn xgboost(levels: usize, parallel: usize, batch: usize) -> Program {
    cascade("xgboost", 8, levels, parallel, batch)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decision_tree_is_deep_and_narrow() {
        let p = decision_tree(75, 14, 1);
        assert_eq!(p.pbs_count(), 75 * 14);
        assert_eq!(p.pbs_depth(), 75);
        assert_eq!(p.width, 9);
    }

    #[test]
    fn xgboost_shape() {
        let p = xgboost(40, 10, 1);
        assert_eq!(p.pbs_count(), 400);
        assert_eq!(p.pbs_depth(), 40);
        assert_eq!(p.width, 8);
    }

    #[test]
    fn functional_on_test_params() {
        // The cascade structure must actually compute: run a tiny instance
        // against the plaintext interpreter through the encrypted engine.
        use crate::compiler::{Engine, NativePbsBackend};
        use crate::ir::interp;
        use crate::params::TEST1;
        use crate::tfhe::pbs::{decrypt_message, encrypt_message};
        use crate::tfhe::{SecretKeys, ServerKeys};
        use crate::util::rng::Rng;
        let prog = cascade("tiny", 3, 2, 4, 1); // 2 values x 2 LUTs per level
        let mut rng = Rng::new(5);
        let sk = SecretKeys::generate(&TEST1, &mut rng);
        let keys = ServerKeys::generate(&sk, &mut rng);
        let mut eng = Engine::new(NativePbsBackend::new(&keys));
        let inputs = [3u64, 6]; // parallel = 2 frontier values
        let cts: Vec<_> = inputs.iter().map(|&m| encrypt_message(m, &sk, &mut rng)).collect();
        let out = eng.run(&prog, &cts);
        let exp = interp::eval(&prog, &inputs);
        let got: Vec<u64> = out.iter().map(|c| decrypt_message(c, &sk)).collect();
        assert_eq!(got, exp);
    }
}
