//! KNN classifier workload (paper: sklearn-based, 3 neighbors, 30 leaves,
//! 9-bit). Distance computation is linear; the sorting network that finds
//! the nearest neighbors is a serial cascade of encrypted comparisons —
//! the paper's prototypically *serial* workload (Fig. 15: 75% utilization
//! only at batch 8).

use crate::ir::builder::ProgramBuilder;
use crate::ir::{LutTable, Program, ValueId};

/// `levels` compare-exchange stages over `lanes` distance lanes.
pub fn knn(levels: usize, lanes: usize, batch: usize) -> Program {
    let width = 9;
    let mut b = ProgramBuilder::new("knn", width);
    assert!(lanes % 2 == 0, "paired LUTs per compare-exchange");
    let lanes = lanes / 2;
    let half = 1u64 << (width - 1);
    // Compare-exchange probes the difference twice — sign and magnitude —
    // sharing one key switch (the §V KS-dedup fanout pattern).
    let sign = LutTable::from_fn(width, move |m| u64::from(m >= half));
    let magn = LutTable::from_fn(width, move |m| {
        // |centered difference| folded into [0, half); the table domain
        // spans the full padded space [0, 4*half).
        let mm = m % (2 * half);
        if mm >= half { (2 * half - mm) % half } else { mm }
    });
    for _ in 0..batch {
        // Squared-distance accumulation (linear, bootstrap-free).
        let feats = b.inputs(lanes);
        let mut dists: Vec<ValueId> = (0..lanes)
            .map(|j| {
                let ins = vec![feats[j], feats[(j + 1) % lanes]];
                b.dot(ins, vec![1, 1], (j % 8) as u64)
            })
            .collect();
        // Odd-even transposition-style selection cascade.
        for lvl in 0..levels {
            let mut next = dists.clone();
            for j in 0..lanes {
                let a = dists[j];
                let c = dists[(j + 1) % lanes];
                let diff = b.sub(a, c);
                let s = b.lut(diff, sign.clone());
                let m = b.lut(diff, magn.clone());
                // Blend back (linear approximation of the select).
                next[j] = b.dot(vec![a, s, m], vec![1, ((lvl % 2) as i64) - 1, 1], 0);
            }
            dists = next;
        }
        let ws = vec![1i64; 3.min(dists.len())];
        let vote = b.dot(dists.iter().take(3).copied().collect(), ws, 0);
        b.output(vote);
    }
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn knn_shape_matches_calibration() {
        let p = knn(31, 30, 1);
        assert_eq!(p.pbs_count(), 31 * 30);
        assert_eq!(p.pbs_depth(), 31);
        assert_eq!(p.width, 9);
    }

    #[test]
    fn batch_replicates_queries() {
        assert_eq!(knn(5, 6, 3).pbs_count(), 3 * knn(5, 6, 1).pbs_count());
    }
}
