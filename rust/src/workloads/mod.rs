//! The paper's seven evaluation workloads (Table II) as IR programs, plus
//! the Fig. 5 multi-representation adders.
//!
//! Op counts and level structure are derived from the underlying model
//! architectures and reconciled against the paper's reported CPU runtimes
//! (DESIGN.md §Calibration): the generators produce the same *shape* of
//! computation (PBS count, exploitable parallelism per level, linear-op
//! mix) that the Concrete-ML models exhibit.

pub mod adder;
pub mod cnn;
pub mod gpt2;
pub mod knn;
pub mod trees;

use crate::ir::Program;
use crate::params::{self, ParamSet};

/// A named benchmark workload: program generator + parameter set.
pub struct Workload {
    pub name: &'static str,
    pub params: &'static ParamSet,
    /// Build the IR program for `batch` concurrent queries.
    pub build: fn(batch: usize) -> Program,
    /// Paper Table II reference numbers (seconds; None = OOM).
    pub paper_cpu_s: f64,
    pub paper_gpu_s: Option<f64>,
    pub paper_taurus_ms: f64,
}

/// Table II rows, in paper order.
pub fn all() -> Vec<Workload> {
    vec![
        Workload {
            name: "CNN-20 (PTQ)",
            params: &params::CNN20,
            build: |b| cnn::cnn(20, 100, 16, b),
            paper_cpu_s: 3.85,
            paper_gpu_s: Some(6.096),
            paper_taurus_ms: 11.60,
        },
        Workload {
            name: "CNN-50 (PTQ)",
            params: &params::CNN50,
            build: |b| cnn::cnn(50, 66, 16, b),
            paper_cpu_s: 15.31,
            paper_gpu_s: Some(49.714),
            paper_taurus_ms: 74.27,
        },
        Workload {
            name: "Decision Tree",
            params: &params::DECISION_TREE,
            build: |b| trees::decision_tree(100, 8, b),
            paper_cpu_s: 645.40,
            paper_gpu_s: Some(522.2351),
            paper_taurus_ms: 409.19,
        },
        Workload {
            name: "GPT2",
            params: &params::GPT2,
            build: |b| gpt2::gpt2(1, b),
            paper_cpu_s: 1218.13,
            paper_gpu_s: Some(721.14),
            paper_taurus_ms: 860.94,
        },
        Workload {
            name: "GPT2 (12-head)",
            params: &params::GPT2_12HEAD,
            build: |b| gpt2::gpt2(12, b),
            paper_cpu_s: 23685.14,
            paper_gpu_s: None, // OOM
            paper_taurus_ms: 10649.33,
        },
        Workload {
            name: "KNN",
            params: &params::KNN,
            build: |b| knn::knn(50, 4, b),
            paper_cpu_s: 284.69,
            paper_gpu_s: Some(204.6),
            paper_taurus_ms: 306.66,
        },
        Workload {
            name: "XGBoost Reg",
            params: &params::XGBOOST,
            build: |b| trees::xgboost(222, 20, b),
            paper_cpu_s: 1793.27,
            paper_gpu_s: Some(912.11),
            paper_taurus_ms: 689.29,
        },
    ]
}

pub fn by_name(name: &str) -> Option<Workload> {
    all().into_iter().find(|w| w.name.eq_ignore_ascii_case(name) || w.params.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_workloads_build_and_validate() {
        for w in all() {
            // Heavy ones at batch 1 only; validation runs inside finish().
            let prog = (w.build)(1);
            assert!(prog.pbs_count() > 0, "{}", w.name);
            assert_eq!(prog.width, w.params.width, "{}", w.name);
        }
    }

    #[test]
    fn batching_multiplies_parallelism_not_depth() {
        let w = by_name("KNN").unwrap();
        let p1 = (w.build)(1);
        let p4 = (w.build)(4);
        assert_eq!(p4.pbs_count(), 4 * p1.pbs_count());
        assert_eq!(p4.pbs_depth(), p1.pbs_depth(), "depth unchanged by batching");
    }
}
