//! Torus arithmetic helpers and secret keys.
//!
//! The discretized torus T is represented as u64 (w = 64 fixed-point
//! fractions of [0,1), paper §II-A2); all arithmetic wraps mod 2^64.

use crate::params::ParamSet;
use crate::util::rng::Rng;

/// Torus element type alias (documentation aid).
pub type Torus = u64;

/// Interpret a torus element as a signed fraction in [-1/2, 1/2).
#[inline]
pub fn torus_to_signed_frac(x: Torus) -> f64 {
    (x as i64 as f64) / 18446744073709551616.0
}

/// Absolute distance on the torus (<= 1/2).
#[inline]
pub fn torus_distance(a: Torus, b: Torus) -> f64 {
    torus_to_signed_frac(a.wrapping_sub(b)).abs()
}

/// Client-side secrets: binary short-LWE key and binary GLWE key. The
/// "long" LWE key is the flattened GLWE key (sample-extraction order).
#[derive(Debug, Clone)]
pub struct SecretKeys {
    pub params: ParamSet,
    /// n bits (0/1 as u64).
    pub lwe: Vec<u64>,
    /// k*N bits, row-major by GLWE polynomial.
    pub glwe: Vec<u64>,
}

impl SecretKeys {
    pub fn generate(params: &ParamSet, rng: &mut Rng) -> Self {
        let lwe = (0..params.n).map(|_| rng.next_u64() & 1).collect();
        let glwe = (0..params.long_dim()).map(|_| rng.next_u64() & 1).collect();
        Self { params: params.clone(), lwe, glwe }
    }

    /// GLWE key polynomial c (length N).
    pub fn glwe_poly(&self, c: usize) -> &[u64] {
        let n = self.params.big_n;
        &self.glwe[c * n..(c + 1) * n]
    }

    /// The long (extracted) LWE key = flattened GLWE key.
    pub fn long_lwe(&self) -> &[u64] {
        &self.glwe
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::TEST1;

    #[test]
    fn keys_are_binary_and_sized() {
        let mut rng = Rng::new(1);
        let sk = SecretKeys::generate(&TEST1, &mut rng);
        assert_eq!(sk.lwe.len(), TEST1.n);
        assert_eq!(sk.glwe.len(), TEST1.long_dim());
        assert!(sk.lwe.iter().all(|&b| b <= 1));
        assert!(sk.glwe.iter().all(|&b| b <= 1));
        // Should be roughly balanced.
        let ones: u64 = sk.glwe.iter().sum();
        assert!(ones > 180 && ones < 330, "ones={ones}");
    }

    #[test]
    fn torus_distance_wraps() {
        assert!(torus_distance(u64::MAX, 0) < 1e-18);
        assert!((torus_distance(1u64 << 63, 0) - 0.5).abs() < 1e-12);
        assert!((torus_distance(1u64 << 62, 0) - 0.25).abs() < 1e-12);
    }
}
