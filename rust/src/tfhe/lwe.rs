//! LWE ciphertexts: `[a_0 .. a_{d-1}, b]` with `b = <a, s> + m + e`.
//!
//! In the key-switch-first pipeline (paper §II-B), ciphertexts at rest are
//! **long** (dimension k*N, under the extracted GLWE key); the short
//! dimension n only appears transiently between key-switch and blind
//! rotation. Linear homomorphic ops (the LPU's job) live here.

use crate::util::rng::Rng;

#[derive(Debug, Clone, PartialEq)]
pub struct LweCiphertext {
    /// a_0..a_{d-1}, b — length d+1.
    pub data: Vec<u64>,
}

impl LweCiphertext {
    /// LWE dimension d.
    pub fn dim(&self) -> usize {
        self.data.len() - 1
    }

    pub fn body(&self) -> u64 {
        *self.data.last().unwrap()
    }

    pub fn mask(&self) -> &[u64] {
        &self.data[..self.data.len() - 1]
    }

    /// Trivial (noiseless, mask-free) encryption of a torus value.
    pub fn trivial(msg_torus: u64, dim: usize) -> Self {
        let mut data = vec![0u64; dim + 1];
        data[dim] = msg_torus;
        Self { data }
    }

    /// Fresh encryption under `key` with gaussian noise `sigma`.
    pub fn encrypt(msg_torus: u64, key: &[u64], sigma: f64, rng: &mut Rng) -> Self {
        let d = key.len();
        let mut data = vec![0u64; d + 1];
        let mut b = msg_torus.wrapping_add(rng.torus_gaussian(sigma));
        for i in 0..d {
            let a = rng.next_u64();
            data[i] = a;
            b = b.wrapping_add(a.wrapping_mul(key[i]));
        }
        data[d] = b;
        Self { data }
    }

    /// Raw phase b - <a, s>.
    pub fn decrypt_phase(&self, key: &[u64]) -> u64 {
        debug_assert_eq!(key.len(), self.dim());
        let mut acc = self.body();
        for (a, s) in self.mask().iter().zip(key) {
            acc = acc.wrapping_sub(a.wrapping_mul(*s));
        }
        acc
    }

    // ---------------------------------------------------------------- LPU ops

    /// Homomorphic addition (noise adds).
    pub fn add_assign(&mut self, other: &Self) {
        debug_assert_eq!(self.data.len(), other.data.len());
        for (x, y) in self.data.iter_mut().zip(&other.data) {
            *x = x.wrapping_add(*y);
        }
    }

    pub fn sub_assign(&mut self, other: &Self) {
        for (x, y) in self.data.iter_mut().zip(&other.data) {
            *x = x.wrapping_sub(*y);
        }
    }

    /// Multiply by a small plaintext integer (noise scales by |c|).
    pub fn scalar_mul_assign(&mut self, c: i64) {
        let cu = c as u64;
        for x in self.data.iter_mut() {
            *x = x.wrapping_mul(cu);
        }
    }

    /// Add a plaintext torus constant (only the body moves).
    pub fn plain_add_assign(&mut self, msg_torus: u64) {
        let last = self.data.len() - 1;
        self.data[last] = self.data[last].wrapping_add(msg_torus);
    }

    pub fn neg_assign(&mut self) {
        for x in self.data.iter_mut() {
            *x = x.wrapping_neg();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::TEST1;
    use crate::tfhe::torus::{torus_distance, SecretKeys};
    use crate::util::prop::check;

    #[test]
    fn encrypt_decrypt_within_noise() {
        check("lwe_roundtrip", 20, |rng| {
            let sk = SecretKeys::generate(&TEST1, rng);
            let msg = (rng.below(16)) << 60;
            let ct = LweCiphertext::encrypt(msg, &sk.lwe, TEST1.lwe_noise, rng);
            let ph = ct.decrypt_phase(&sk.lwe);
            let d = torus_distance(ph, msg);
            if d > 1e-6 {
                return Err(format!("noise too large: {d}"));
            }
            Ok(())
        });
    }

    #[test]
    fn homomorphic_add_sub() {
        check("lwe_linear", 20, |rng| {
            let sk = SecretKeys::generate(&TEST1, rng);
            let m1 = (rng.below(8)) << 60;
            let m2 = (rng.below(8)) << 60;
            let mut a = LweCiphertext::encrypt(m1, &sk.lwe, TEST1.lwe_noise, rng);
            let b = LweCiphertext::encrypt(m2, &sk.lwe, TEST1.lwe_noise, rng);
            a.add_assign(&b);
            if torus_distance(a.decrypt_phase(&sk.lwe), m1.wrapping_add(m2)) > 1e-6 {
                return Err("add".into());
            }
            a.sub_assign(&b);
            if torus_distance(a.decrypt_phase(&sk.lwe), m1) > 1e-6 {
                return Err("sub".into());
            }
            Ok(())
        });
    }

    #[test]
    fn scalar_and_plain_ops() {
        let mut rng = crate::util::rng::Rng::new(3);
        let sk = SecretKeys::generate(&TEST1, &mut rng);
        let m = 2u64 << 60;
        let mut ct = LweCiphertext::encrypt(m, &sk.lwe, 0.0, &mut rng);
        ct.scalar_mul_assign(3);
        assert!(torus_distance(ct.decrypt_phase(&sk.lwe), 6u64 << 60) < 1e-9);
        ct.plain_add_assign(1u64 << 60);
        assert!(torus_distance(ct.decrypt_phase(&sk.lwe), 7u64 << 60) < 1e-9);
        ct.neg_assign();
        assert!(torus_distance(ct.decrypt_phase(&sk.lwe), (7u64 << 60).wrapping_neg()) < 1e-9);
    }

    #[test]
    fn trivial_has_no_mask() {
        let ct = LweCiphertext::trivial(42, 16);
        assert!(ct.mask().iter().all(|&a| a == 0));
        assert_eq!(ct.decrypt_phase(&vec![1u64; 16]), 42);
    }
}
