//! Gadget (signed, closest-representative) decomposition — the paper's
//! Decomposer unit (§IV-E) in software. Matches `kernels/decompose.py`
//! digit-for-digit: digit j has weight q/B^(j+1), j = 0 most significant,
//! digits balanced in [-B/2, B/2].

/// Decompose a single torus value into `level` digits, writing digit j to
/// `out[j * stride]`. The strided form lets callers produce the GGSW row
/// layout without a transpose.
#[inline]
pub fn decompose_strided(x: u64, base_log: usize, level: usize, out: &mut [i64], stride: usize) {
    let keep = base_log * level;
    debug_assert!(keep < 64);
    let rounding = 1u64 << (64 - keep - 1);
    let mut res = x.wrapping_add(rounding) >> (64 - keep);
    let half = 1i64 << (base_log - 1);
    let mask = (1u64 << base_log) - 1;
    for j in (0..level).rev() {
        let mut d = (res & mask) as i64;
        res >>= base_log;
        if d >= half {
            d -= 1i64 << base_log;
            res += 1;
        }
        out[j * stride] = d;
    }
}

/// Decompose a slice elementwise: `out[j][i]` = digit j of `x[i]`.
pub fn decompose_slice(x: &[u64], base_log: usize, level: usize, out: &mut [Vec<i64>]) {
    debug_assert_eq!(out.len(), level);
    let mut digits = vec![0i64; level];
    for (i, &v) in x.iter().enumerate() {
        decompose_strided(v, base_log, level, &mut digits, 1);
        for j in 0..level {
            out[j][i] = digits[j];
        }
    }
}

/// Recompose digits (testing): sum_j digit_j * q/B^(j+1), wrapping.
pub fn recompose(digits: &[i64], base_log: usize) -> u64 {
    let mut acc = 0u64;
    for (j, &d) in digits.iter().enumerate() {
        let w = 64 - base_log * (j + 1);
        acc = acc.wrapping_add((d as u64).wrapping_shl(w as u32));
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn decompose_recompose_within_cutoff() {
        check("decomp_roundtrip", 50, |rng| {
            for (base_log, level) in [(8usize, 3usize), (4, 6), (15, 2), (23, 1), (2, 12)] {
                let x = rng.next_u64();
                let mut d = vec![0i64; level];
                decompose_strided(x, base_log, level, &mut d, 1);
                let half = 1i64 << (base_log - 1);
                for &v in &d {
                    if v < -half || v > half {
                        return Err(format!("digit {v} out of [-{half},{half}]"));
                    }
                }
                let r = recompose(&d, base_log);
                let err = (r.wrapping_sub(x) as i64).unsigned_abs();
                let bound = 1u64 << (64 - base_log * level - 1);
                if err > bound {
                    return Err(format!(
                        "x={x} err={err} bound={bound} (B=2^{base_log}, l={level})"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn known_values() {
        // 2^63 with base 2^8, level 3: kept value rounds to 2^23 -> top
        // digit -128 with carry out (wraps) — matches the python kernel
        // test.
        let mut d = vec![0i64; 3];
        decompose_strided(1u64 << 63, 8, 3, &mut d, 1);
        assert_eq!(d, vec![-128, 0, 0]);
        decompose_strided(0, 8, 3, &mut d, 1);
        assert_eq!(d, vec![0, 0, 0]);
        decompose_strided(u64::MAX, 8, 3, &mut d, 1);
        assert_eq!(d, vec![0, 0, 0]); // rounds up to 2^64 == 0
    }

    #[test]
    fn strided_layout() {
        let mut out = vec![0i64; 6];
        decompose_strided(1u64 << 63, 8, 3, &mut out, 2);
        assert_eq!(out, vec![-128, 0, 0, 0, 0, 0]);
    }

    #[test]
    fn slice_matches_scalar() {
        let xs: Vec<u64> = (0..32).map(|i| (i as u64) << 58).collect();
        let mut out = vec![vec![0i64; xs.len()]; 3];
        decompose_slice(&xs, 8, 3, &mut out);
        for (i, &x) in xs.iter().enumerate() {
            let mut d = vec![0i64; 3];
            decompose_strided(x, 8, 3, &mut d, 1);
            for j in 0..3 {
                assert_eq!(out[j][i], d[j]);
            }
        }
    }
}
