//! GLWE ciphertexts: k mask polynomials + 1 body polynomial over
//! Z_q[X]/(X^N+1). Used for the PBS accumulator and LUT encodings
//! (paper §II-A2).

use super::fft::FftPlan;
use super::lwe::LweCiphertext;
use super::poly;
use super::torus::SecretKeys;
use crate::params::ParamSet;
use crate::util::rng::Rng;

#[derive(Debug, Clone, PartialEq)]
pub struct GlweCiphertext {
    /// (k+1) polynomials of length N, row-major; row k is the body.
    pub data: Vec<u64>,
    pub k: usize,
    pub big_n: usize,
}

impl GlweCiphertext {
    pub fn zero(k: usize, big_n: usize) -> Self {
        Self { data: vec![0; (k + 1) * big_n], k, big_n }
    }

    /// Trivial encryption: zero mask, body = msg.
    pub fn trivial(msg_poly: &[u64], k: usize) -> Self {
        let big_n = msg_poly.len();
        let mut ct = Self::zero(k, big_n);
        ct.body_mut().copy_from_slice(msg_poly);
        ct
    }

    pub fn poly(&self, c: usize) -> &[u64] {
        &self.data[c * self.big_n..(c + 1) * self.big_n]
    }

    pub fn poly_mut(&mut self, c: usize) -> &mut [u64] {
        &mut self.data[c * self.big_n..(c + 1) * self.big_n]
    }

    pub fn body(&self) -> &[u64] {
        self.poly(self.k)
    }

    pub fn body_mut(&mut self) -> &mut [u64] {
        let k = self.k;
        self.poly_mut(k)
    }

    /// Fresh encryption of a message polynomial.
    pub fn encrypt(
        msg_poly: &[u64],
        sk: &SecretKeys,
        sigma: f64,
        rng: &mut Rng,
        plan: &FftPlan,
    ) -> Self {
        let p = &sk.params;
        let mut ct = Self::zero(p.k, p.big_n);
        // body = msg + e
        for (j, b) in ct.poly_mut(p.k).iter_mut().enumerate() {
            *b = msg_poly[j].wrapping_add(rng.torus_gaussian(sigma));
        }
        // masks + body += a_c * s_c
        for c in 0..p.k {
            for j in 0..p.big_n {
                ct.data[c * p.big_n + j] = rng.next_u64();
            }
            let (masks, body) = ct.data.split_at_mut(p.k * p.big_n);
            let a = &masks[c * p.big_n..(c + 1) * p.big_n];
            poly::mul_binary_add_into(plan, a, sk.glwe_poly(c), body);
        }
        ct
    }

    /// Decrypt to the phase polynomial body - sum_c a_c * s_c.
    pub fn decrypt_phase(&self, sk: &SecretKeys, plan: &FftPlan) -> Vec<u64> {
        let p = &sk.params;
        let mut phase = self.body().to_vec();
        for c in 0..p.k {
            poly::mul_binary_sub_into(plan, self.poly(c), sk.glwe_poly(c), &mut phase);
        }
        phase
    }

    /// Extract the LWE ciphertext of the constant coefficient under the
    /// long (flattened GLWE) key — the PBS output step (paper Fig. 3 (d)).
    pub fn sample_extract(&self, params: &ParamSet) -> LweCiphertext {
        let (k, n) = (self.k, self.big_n);
        let mut data = vec![0u64; params.long_dim() + 1];
        for c in 0..k {
            let mask = self.poly(c);
            let out = &mut data[c * n..(c + 1) * n];
            out[0] = mask[0];
            for j in 1..n {
                out[j] = mask[n - j].wrapping_neg();
            }
        }
        data[params.long_dim()] = self.body()[0];
        LweCiphertext { data }
    }

    pub fn add_assign(&mut self, other: &Self) {
        poly::add_assign(&mut self.data, &other.data);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::TEST1;
    use crate::tfhe::torus::torus_distance;
    use crate::util::prop::check;

    #[test]
    fn glwe_encrypt_decrypt_roundtrip() {
        check("glwe_roundtrip", 8, |rng| {
            let sk = SecretKeys::generate(&TEST1, rng);
            let plan = FftPlan::new(TEST1.big_n);
            let msg: Vec<u64> = (0..TEST1.big_n as u64).map(|j| (j % 16) << 60).collect();
            let ct = GlweCiphertext::encrypt(&msg, &sk, TEST1.glwe_noise, rng, &plan);
            let ph = ct.decrypt_phase(&sk, &plan);
            for (got, exp) in ph.iter().zip(&msg) {
                let d = torus_distance(*got, *exp);
                if d > 1e-6 {
                    return Err(format!("noise {d}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn trivial_decrypts_exactly() {
        let mut rng = Rng::new(5);
        let sk = SecretKeys::generate(&TEST1, &mut rng);
        let plan = FftPlan::new(TEST1.big_n);
        let msg: Vec<u64> = (0..512u64).map(|j| j << 52).collect();
        let ct = GlweCiphertext::trivial(&msg, TEST1.k);
        assert_eq!(ct.decrypt_phase(&sk, &plan), msg);
    }

    #[test]
    fn sample_extract_preserves_constant_term() {
        check("sample_extract", 8, |rng| {
            let sk = SecretKeys::generate(&TEST1, rng);
            let plan = FftPlan::new(TEST1.big_n);
            let mut msg = vec![0u64; TEST1.big_n];
            msg[0] = 5u64 << 60;
            msg[1] = 9u64 << 60; // non-constant coefficients must not leak in
            let ct = GlweCiphertext::encrypt(&msg, &sk, TEST1.glwe_noise, rng, &plan);
            let lwe = ct.sample_extract(&TEST1);
            let ph = lwe.decrypt_phase(sk.long_lwe());
            if torus_distance(ph, 5u64 << 60) > 1e-6 {
                return Err("constant term lost".into());
            }
            Ok(())
        });
    }

    #[test]
    fn homomorphic_poly_add() {
        let mut rng = Rng::new(6);
        let sk = SecretKeys::generate(&TEST1, &mut rng);
        let plan = FftPlan::new(TEST1.big_n);
        let m1 = vec![1u64 << 60; TEST1.big_n];
        let m2 = vec![2u64 << 60; TEST1.big_n];
        let mut a = GlweCiphertext::encrypt(&m1, &sk, TEST1.glwe_noise, &mut rng, &plan);
        let b = GlweCiphertext::encrypt(&m2, &sk, TEST1.glwe_noise, &mut rng, &plan);
        a.add_assign(&b);
        let ph = a.decrypt_phase(&sk, &plan);
        for x in ph {
            assert!(torus_distance(x, 3u64 << 60) < 1e-6);
        }
    }
}
