//! Multi-bit message encoding and LUT (test polynomial) construction
//! (paper §II-A1: the programmability of PBS).
//!
//! Messages m in [0, 2^width) are encoded in the top bits of the torus
//! with one padding bit: mu = m * 2^(64-width-1). The padding bit keeps
//! the phase in [0, 1/2) so blind rotation never crosses the negacyclic
//! sign boundary.

use crate::params::ParamSet;

use super::poly::rotate_into;

/// Encode a message into a torus value.
#[inline]
pub fn encode(m: u64, p: &ParamSet) -> u64 {
    (m % p.plaintext_modulus()).wrapping_mul(p.delta())
}

/// Decode a torus phase back to a message (rounding).
#[inline]
pub fn decode(phase: u64, p: &ParamSet) -> u64 {
    let shifted = phase.wrapping_add(p.delta() / 2);
    (shifted >> (64 - p.width - 1)) % p.plaintext_modulus()
}

/// Build the test polynomial for a univariate LUT `f`: slots of size
/// 2N/P holding f(m)*delta, negacyclically pre-rotated by -box/2 so each
/// slot is centered on its phase (handles negative noise around m = 0).
pub fn make_lut_poly(p: &ParamSet, f: impl Fn(u64) -> u64) -> Vec<u64> {
    let pt_mod = p.plaintext_modulus();
    let box_sz = 2 * p.big_n / pt_mod as usize;
    let mut v = vec![0u64; p.big_n];
    for (j, slot) in v.iter_mut().enumerate() {
        let m = (j / box_sz) as u64 % pt_mod;
        *slot = (f(m) % pt_mod).wrapping_mul(p.delta());
    }
    let mut out = vec![0u64; p.big_n];
    rotate_into(&v, 2 * p.big_n - box_sz / 2, &mut out);
    out
}

/// A bivariate LUT g(x, y) is not TFHE-native (paper footnote 4): it is
/// realized as a linear combine `x * P_half + y` followed by a univariate
/// LUT on the packed value. Returns the univariate table for the packed
/// encoding, where x and y each use `width/2` bits.
pub fn make_bivariate_lut_poly(p: &ParamSet, g: impl Fn(u64, u64) -> u64) -> Vec<u64> {
    let half_width = p.width / 2;
    let half_mod = 1u64 << half_width;
    make_lut_poly(p, |packed| {
        let x = (packed >> half_width) % half_mod;
        let y = packed % half_mod;
        g(x, y)
    })
}

/// The scale factor to apply to `x` when packing for a bivariate LUT.
pub fn bivariate_scale(p: &ParamSet) -> u64 {
    1u64 << (p.width / 2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::{ParamSet, TEST1, TEST2, WIDE10, WIDE8};

    /// TEST1 with the width overridden — encode/decode depend only on
    /// `width`, so this covers widths without a dedicated set (width 1).
    fn at_width(width: usize) -> ParamSet {
        ParamSet { width, ..TEST1 }
    }

    /// The boundary widths: the narrowest useful width, the old
    /// functional ceiling, and both wide sets.
    fn boundary_sets() -> [ParamSet; 4] {
        [at_width(1), TEST2, WIDE8, WIDE10]
    }

    #[test]
    fn encode_decode_roundtrip() {
        for m in 0..TEST1.plaintext_modulus() {
            assert_eq!(decode(encode(m, &TEST1), &TEST1), m);
        }
    }

    #[test]
    fn roundtrip_at_width_boundaries() {
        // Exhaustive over the full padded message space at widths
        // {1, 5, 8, 10} (2048 values at width 10).
        for p in boundary_sets() {
            for m in 0..p.plaintext_modulus() {
                assert_eq!(decode(encode(m, &p), &p), m, "width {} m={m}", p.width);
            }
        }
    }

    #[test]
    fn delta_and_plaintext_modulus_extremes() {
        // Pinned values at both ends of the supported range...
        let w1 = at_width(1);
        assert_eq!(w1.plaintext_modulus(), 4);
        assert_eq!(w1.delta(), 1u64 << 62);
        assert_eq!(WIDE10.plaintext_modulus(), 2048);
        assert_eq!(WIDE10.delta(), 1u64 << 53);
        assert_eq!(WIDE8.plaintext_modulus(), 512);
        assert_eq!(WIDE8.delta(), 1u64 << 55);
        // ...and the invariant that makes wrapping arithmetic work: the
        // padded message space exactly tiles the torus.
        for p in boundary_sets() {
            assert_eq!(
                (p.delta() as u128) * (p.plaintext_modulus() as u128),
                1u128 << 64,
                "width {}",
                p.width
            );
        }
    }

    #[test]
    fn padding_bit_overflow_wraps_modulo_padded_space() {
        for p in boundary_sets() {
            let pt = p.plaintext_modulus();
            let top = pt / 2; // first value with the padding bit set
            // Values past the padded space wrap (encode reduces mod P)...
            assert_eq!(encode(pt, &p), 0, "width {}", p.width);
            assert_eq!(encode(pt + 3, &p), encode(3, &p));
            // ...while padding-bit-set values round-trip losslessly (the
            // negacyclic LUT semantics of `ir::interp` rely on this).
            assert_eq!(encode(top, &p), 1u64 << 63, "width {}: m=P/2 is torus 1/2", p.width);
            assert_eq!(decode(encode(top, &p), &p), top);
            assert_eq!(decode(encode(pt - 1, &p), &p), pt - 1);
        }
    }

    #[test]
    fn decode_rounding_boundary_is_half_delta() {
        // decode() rounds to the nearest slot: exactly half a slot above
        // encode(m) tips to m+1, one torus tick less stays at m.
        for p in boundary_sets() {
            let half = p.delta() / 2;
            for m in [0u64, 1, p.plaintext_modulus() / 2, p.plaintext_modulus() - 1] {
                let enc = encode(m, &p);
                let up = (m + 1) % p.plaintext_modulus();
                assert_eq!(decode(enc.wrapping_add(half), &p), up, "width {} m={m}", p.width);
                assert_eq!(decode(enc.wrapping_add(half - 1), &p), m, "width {} m={m}", p.width);
                assert_eq!(
                    decode(enc.wrapping_sub(half), &p),
                    m,
                    "width {} m={m}: -half rounds back up",
                    p.width
                );
            }
        }
    }

    #[test]
    fn decode_tolerates_noise() {
        let m = 5u64;
        let enc = encode(m, &TEST1);
        let noise = TEST1.delta() / 3;
        assert_eq!(decode(enc.wrapping_add(noise), &TEST1), m);
        assert_eq!(decode(enc.wrapping_sub(noise), &TEST1), m);
        // Past the boundary it flips.
        assert_ne!(decode(enc.wrapping_add(TEST1.delta()), &TEST1), m);
    }

    #[test]
    fn lut_slots_centered() {
        // With the half-box pre-rotation, index j ~ phase j on the torus:
        // the slot centered at encode(m) must hold f(m).
        let f = |m: u64| (3 * m + 1) % 16;
        let v = make_lut_poly(&TEST1, f);
        let box_sz = 2 * TEST1.big_n / 16;
        // Sample the exact slot centers in [0, N): phases m*box (m < 8).
        for m in 0..8u64 {
            let center = (m as usize) * box_sz;
            assert_eq!(v[center], encode(f(m), &TEST1), "m={m}");
        }
    }

    #[test]
    fn bivariate_packing() {
        // width 3 -> half width 1: x,y in {0,1}, packed = 2x + y.
        let g = |x: u64, y: u64| x + y;
        let v = make_bivariate_lut_poly(&TEST1, g);
        let u = make_lut_poly(&TEST1, |packed| {
            let x = (packed >> 1) & 1;
            let y = packed & 1;
            x + y
        });
        assert_eq!(v, u);
        assert_eq!(bivariate_scale(&TEST1), 2);
    }
}
