//! Key-switching key and the key-switch operation — the LPU's main job
//! (paper §IV-A), "the second most time-consuming operation" (§II-B).
//!
//! KSK[i][j] is an LWE_n encryption of s_long_i * q/B_ks^(j+1); switching
//! computes out = (0, b) - sum_ij dec_j(a_i) * KSK[i][j].

use super::decomp::decompose_strided;
use super::keygen::{self, KeygenOptions};
use super::lwe::LweCiphertext;
use super::torus::SecretKeys;
use crate::params::ParamSet;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct Ksk {
    /// kN * ks_level * (n+1), row-major (i, j, coeff).
    pub data: Vec<u64>,
    pub long_dim: usize,
    pub level: usize,
    pub short_len: usize,
}

impl Ksk {
    pub fn generate(sk: &SecretKeys, rng: &mut Rng) -> Self {
        let p = &sk.params;
        let (long_dim, level, short_len) = (p.long_dim(), p.ks_level, p.n + 1);
        let mut data = vec![0u64; long_dim * level * short_len];
        for i in 0..long_dim {
            for j in 0..level {
                let w = (64 - p.ks_base_log * (j + 1)) as u32;
                let msg = sk.long_lwe()[i].wrapping_shl(w);
                let ct = LweCiphertext::encrypt(msg, &sk.lwe, p.lwe_noise, rng);
                let off = (i * level + j) * short_len;
                data[off..off + short_len].copy_from_slice(&ct.data);
            }
        }
        Self { data, long_dim, level, short_len }
    }

    /// Seed-deterministic chunked generation (`tfhe::keygen`): long-key
    /// row i (its `ks_level` LWE encryptions) draws from its own forked
    /// RNG and rows are streamed into the flat key in chunks, optionally
    /// from worker threads — the KSK for a 10-bit set is tens of MB, and
    /// this keeps its generation both parallel and bit-reproducible.
    pub fn generate_seeded(sk: &SecretKeys, seed: u64, opts: &KeygenOptions) -> Self {
        let p = &sk.params;
        let (long_dim, level, short_len) = (p.long_dim(), p.ks_level, p.n + 1);
        // The chunk generator emits the chunk's rows as flat torus words;
        // index-ordered reassembly concatenates them into the key layout.
        let data = keygen::generate_chunks(long_dim, opts, |range| {
            let mut out = Vec::with_capacity(range.len() * level * short_len);
            for i in range {
                let mut rng = keygen::unit_rng(seed, keygen::DOMAIN_KSK, i);
                for j in 0..level {
                    let w = (64 - p.ks_base_log * (j + 1)) as u32;
                    let msg = sk.long_lwe()[i].wrapping_shl(w);
                    let ct = LweCiphertext::encrypt(msg, &sk.lwe, p.lwe_noise, &mut rng);
                    out.extend_from_slice(&ct.data);
                }
            }
            out
        });
        debug_assert_eq!(data.len(), long_dim * level * short_len);
        Self { data, long_dim, level, short_len }
    }

    #[inline]
    fn row(&self, i: usize, j: usize) -> &[u64] {
        let off = (i * self.level + j) * self.short_len;
        &self.data[off..off + self.short_len]
    }

    /// LWE_{kN} -> LWE_n.
    pub fn keyswitch(&self, ct_long: &LweCiphertext, p: &ParamSet) -> LweCiphertext {
        debug_assert_eq!(ct_long.dim(), self.long_dim);
        let mut out = vec![0u64; self.short_len];
        out[self.short_len - 1] = ct_long.body();
        let mut digits = vec![0i64; self.level];
        for (i, &a) in ct_long.mask().iter().enumerate() {
            decompose_strided(a, p.ks_base_log, self.level, &mut digits, 1);
            for (j, &d) in digits.iter().enumerate() {
                if d == 0 {
                    continue; // sparse digits are common; skip the row
                }
                let du = d as u64;
                for (o, &kk) in out.iter_mut().zip(self.row(i, j)) {
                    *o = o.wrapping_sub(du.wrapping_mul(kk));
                }
            }
        }
        LweCiphertext { data: out }
    }

    pub fn bytes(&self) -> usize {
        self.data.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::TEST1;
    use crate::tfhe::torus::torus_distance;
    use crate::util::prop::check;

    #[test]
    fn keyswitch_preserves_message() {
        check("keyswitch", 6, |rng| {
            let sk = SecretKeys::generate(&TEST1, rng);
            let ksk = Ksk::generate(&sk, rng);
            let m = rng.below(8) << 60;
            let ct = LweCiphertext::encrypt(m, sk.long_lwe(), TEST1.glwe_noise, rng);
            let short = ksk.keyswitch(&ct, &TEST1);
            if short.dim() != TEST1.n {
                return Err("wrong output dim".into());
            }
            let ph = short.decrypt_phase(&sk.lwe);
            let d = torus_distance(ph, m);
            if d > 1e-4 {
                return Err(format!("ks noise {d}"));
            }
            Ok(())
        });
    }

    #[test]
    fn seeded_ksk_is_schedule_invariant_and_functional() {
        let mut rng = Rng::new(23);
        let sk = SecretKeys::generate(&TEST1, &mut rng);
        let mono = Ksk::generate_seeded(&sk, 99, &KeygenOptions::monolithic());
        assert_eq!(mono.data.len(), TEST1.long_dim() * TEST1.ks_level * (TEST1.n + 1));
        let chunked = Ksk::generate_seeded(&sk, 99, &KeygenOptions { chunk: 37, workers: 1 });
        let parallel = Ksk::generate_seeded(&sk, 99, &KeygenOptions::with_workers(4));
        assert_eq!(mono.data, chunked.data, "chunking must not change bits");
        assert_eq!(mono.data, parallel.data, "worker split must not change bits");
        assert_ne!(mono.data, Ksk::generate_seeded(&sk, 100, &KeygenOptions::monolithic()).data);
        // And the seeded key actually switches keys correctly.
        let m = 6u64 << 60;
        let ct = LweCiphertext::encrypt(m, sk.long_lwe(), TEST1.glwe_noise, &mut rng);
        let short = mono.keyswitch(&ct, &TEST1);
        assert!(torus_distance(short.decrypt_phase(&sk.lwe), m) < 1e-4);
    }

    #[test]
    fn keyswitch_trivial_input() {
        let mut rng = Rng::new(11);
        let sk = SecretKeys::generate(&TEST1, &mut rng);
        let ksk = Ksk::generate(&sk, &mut rng);
        let ct = LweCiphertext::trivial(5u64 << 60, TEST1.long_dim());
        let short = ksk.keyswitch(&ct, &TEST1);
        // Zero mask -> all digits zero -> output is the trivial short ct.
        assert!(torus_distance(short.decrypt_phase(&sk.lwe), 5u64 << 60) < 1e-9);
    }

    #[test]
    fn keyswitch_is_linear() {
        let mut rng = Rng::new(12);
        let sk = SecretKeys::generate(&TEST1, &mut rng);
        let ksk = Ksk::generate(&sk, &mut rng);
        let m1 = 1u64 << 60;
        let m2 = 2u64 << 60;
        let a = LweCiphertext::encrypt(m1, sk.long_lwe(), TEST1.glwe_noise, &mut rng);
        let mut b = LweCiphertext::encrypt(m2, sk.long_lwe(), TEST1.glwe_noise, &mut rng);
        b.add_assign(&a);
        let sb = ksk.keyswitch(&b, &TEST1);
        assert!(torus_distance(sb.decrypt_phase(&sk.lwe), 3u64 << 60) < 1e-4);
    }
}
