//! From-scratch multi-bit TFHE library — the cryptographic substrate the
//! paper's accelerator executes, and the native CPU execution backend.
//!
//! Mirrors `python/compile/tfhe_np.py` operation-for-operation; the shared
//! conventions (torus = u64, gadget digits, GGSW row order, negacyclic
//! half-size FFT twist) are documented in `python/compile/params.py`.
//!
//! Structure follows the PBS pipeline of the paper's Fig. 3:
//! key-switching ([`ksk`]) -> mod-switch + blind rotation ([`pbs`], using
//! [`ggsw`] external products over [`fft`]) -> sample extraction.

pub mod bsk;
pub mod decomp;
pub mod encoding;
pub mod fft;
pub mod ggsw;
pub mod glwe;
pub mod keycache;
pub mod keygen;
pub mod ksk;
pub mod lwe;
pub mod parallel;
pub mod pbs;
pub mod poly;
pub mod torus;

pub use bsk::FourierBsk;
pub use encoding::{decode, encode, make_lut_poly};
pub use ggsw::{
    cmux_rotate_batch, external_product_add_batch, BatchExtProdScratch, FourierGgsw,
};
pub use glwe::GlweCiphertext;
pub use keycache::{BoundedKeyCache, CacheStats};
pub use keygen::{server_keys_bitwise_eq, KeygenOptions};
pub use fft::plan_for;
pub use ksk::Ksk;
pub use lwe::LweCiphertext;
pub use parallel::WorkerPool;
pub use pbs::{PbsContext, ServerKeys};
pub use torus::SecretKeys;
