//! Caches of deterministic seeded key sets.
//!
//! Two variants share one generation path ([`generate_entry`]):
//!
//! * [`get`] — the process-wide **unbounded** cache the test suite uses.
//!   Wide-width keygen is the dominant fixed cost of the conformance
//!   suite (a WIDE10 BSK+KSK is ~100 MB of material behind thousands of
//!   FFTs); because `ServerKeys::generate_seeded` is a pure function of
//!   `(params, seed)` — chunking and worker count cannot change the bits
//!   (`tfhe::keygen`) — the suite safely shares ONE key set per
//!   `(parameter set, seed)` across every test in the process. Entries
//!   are generated under a per-entry `OnceLock`, so two tests racing on
//!   the same width block on one generation while different widths
//!   generate concurrently. This cache grows without bound by design:
//!   its working set is the handful of test widths.
//!
//! * [`BoundedKeyCache`] — the **capacity-bounded LRU** the serving
//!   path's `tenant::SeededTenantStore` builds on. Per-tenant server keys
//!   are the same tens-of-MB entries, but a server meets an unbounded
//!   stream of tenants, so residency must be bounded and observable:
//!   the cache counts hits, misses, capacity evictions, and
//!   *regenerations* (a miss for a seed generated before — the signal
//!   that capacity is below the working set). It deliberately retains
//!   only server-side material (`Arc<ServerKeys>`), never client secret
//!   keys.

use std::collections::{HashMap, HashSet};
use std::fmt;
use std::sync::{Arc, Mutex, OnceLock};

use super::keygen::{fork_seed, KeygenOptions};
use super::pbs::ServerKeys;
use super::torus::SecretKeys;
use crate::params::ParamSet;
use crate::util::rng::Rng;

/// One cached client+server key set (the unbounded test cache keeps the
/// secret keys so tests can encrypt/decrypt; the bounded serving cache
/// does not).
pub struct CachedKeys {
    pub sk: SecretKeys,
    pub server: Arc<ServerKeys>,
}

type Slot = Arc<OnceLock<Arc<CachedKeys>>>;

fn cache() -> &'static Mutex<HashMap<(String, u64), Slot>> {
    static CACHE: OnceLock<Mutex<HashMap<(String, u64), Slot>>> = OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Seed of the secret-key RNG stream for a cache seed (domain-separated
/// from the keygen streams so `sk` and `ek` randomness never overlap).
pub fn secret_seed(seed: u64) -> u64 {
    fork_seed(seed, 0x5EC2_E7D0, 0)
}

/// Seed handed to [`ServerKeys::generate_seeded`] for a cache seed —
/// exposed so determinism tests can regenerate a cached entry through a
/// different keygen configuration and compare bitwise.
pub fn server_seed(seed: u64) -> u64 {
    fork_seed(seed, 0x5EC2_E7D1, 0)
}

/// The client-side secret keys for `(p, seed)` — the cheap half of
/// [`generate_entry`], regenerated on demand (what a tenant's *client*
/// keeps while the server store holds only the server material).
pub fn secret_keys_for(p: &ParamSet, seed: u64) -> SecretKeys {
    let mut rng = Rng::new(secret_seed(seed));
    SecretKeys::generate(p, &mut rng)
}

/// Generate the full deterministic key set for `(p, seed)` — the single
/// generation path shared by [`get`] and [`BoundedKeyCache`], so both
/// caches (and a client deriving via [`secret_keys_for`]) always agree
/// bitwise.
pub fn generate_entry(p: &ParamSet, seed: u64) -> CachedKeys {
    let sk = secret_keys_for(p, seed);
    // Spread keygen over a few workers; by construction the worker
    // count does not change the generated bits.
    let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(4);
    let server =
        ServerKeys::generate_seeded(&sk, server_seed(seed), &KeygenOptions::with_workers(workers));
    CachedKeys { sk, server: Arc::new(server) }
}

/// Fetch (generating on first use) the key set for `(p, seed)`. Returns a
/// shared handle; all callers see the identical keys, so ciphertexts
/// produced by one test decrypt under another's copy.
pub fn get(p: &ParamSet, seed: u64) -> Arc<CachedKeys> {
    let slot: Slot = {
        let mut map = cache().lock().expect("key cache poisoned");
        map.entry((p.name.to_string(), seed)).or_default().clone()
    };
    slot.get_or_init(|| Arc::new(generate_entry(p, seed))).clone()
}

/// Counters of a bounded key cache (also the `tenant::KeyStoreStats`
/// shape): how resolution traffic split between cache states.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups served from a resident entry.
    pub hits: u64,
    /// Lookups that had to generate (first touch or post-eviction).
    pub misses: u64,
    /// Entries displaced by capacity pressure (explicit `remove`s — e.g.
    /// reshard migration — are not evictions).
    pub evictions: u64,
    /// Misses for a seed generated before: the cache paid keygen twice
    /// because capacity is below the working set.
    pub regenerations: u64,
    /// Entries currently resident.
    pub resident: usize,
    /// Resident entries that are *pinned* (client-uploaded via
    /// [`BoundedKeyCache::insert_pinned`]): capacity eviction skips them
    /// because the server cannot re-derive uploaded material.
    pub pinned: usize,
}

/// Typed failure of a fallible cache lookup ([`BoundedKeyCache::try_get`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KeyCacheError {
    /// The seed was registered with externally supplied (client-uploaded)
    /// keys that are no longer resident — an explicit [`BoundedKeyCache::remove`]
    /// (reshard migration) took them. Regenerating from the seed would
    /// mint *different* bits than the client uploaded, so every result
    /// would decrypt to garbage; the lookup fails typed instead.
    RegisteredEvicted { seed: u64 },
}

impl fmt::Display for KeyCacheError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            KeyCacheError::RegisteredEvicted { seed } => write!(
                f,
                "seed {seed:#x} holds client-registered keys that are not resident; \
                 regeneration would mint different key bits (re-register the uploaded keys)"
            ),
        }
    }
}

impl std::error::Error for KeyCacheError {}

struct BoundedEntry {
    keys: Arc<ServerKeys>,
    last_used: u64,
    /// Pinned entries hold client-uploaded material the server cannot
    /// re-derive; [`BoundedInner::enforce_capacity`] never evicts them.
    pinned: bool,
}

#[derive(Default)]
struct BoundedInner {
    /// The one parameter set this instance serves, bound on first use so
    /// a seed can never silently resolve to another set's keys.
    param_name: Option<&'static str>,
    entries: HashMap<u64, BoundedEntry>,
    /// Monotone access clock for LRU ordering.
    tick: u64,
    /// Every seed whose generation/insert *completed* — distinguishes a
    /// first-touch miss from a regeneration. Recorded at insert time (not
    /// at miss time) so two threads racing on the same first touch don't
    /// count a phantom regeneration. 8 bytes per tenant ever seen: the
    /// bookkeeping that makes the capacity-pressure signal exact, ~6
    /// orders of magnitude below the key material it meters.
    seen: HashSet<u64>,
    /// Seeds whose entries were installed via [`BoundedKeyCache::insert_pinned`]
    /// — client-uploaded key material the server cannot re-derive. The
    /// marker outlives the entry itself: after an explicit `remove`
    /// (reshard migration) a lookup for the seed fails typed
    /// ([`KeyCacheError::RegisteredEvicted`]) instead of silently
    /// regenerating different bits, and a later `insert` (migration
    /// re-import) re-pins the entry.
    registered: HashSet<u64>,
    hits: u64,
    misses: u64,
    evictions: u64,
    regenerations: u64,
}

impl BoundedInner {
    fn touch(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    fn bind_param(&mut self, name: &'static str) {
        match self.param_name {
            None => self.param_name = Some(name),
            Some(bound) => assert_eq!(
                bound, name,
                "a BoundedKeyCache serves one parameter set; use one instance per set"
            ),
        }
    }

    /// Drop least-recently-used **unpinned** entries until `capacity`
    /// holds. Pinned (client-uploaded) entries are never candidates — the
    /// server cannot regenerate them, so evicting one would turn every
    /// later request for that tenant into silent garbage. When pinned
    /// entries alone exceed capacity the cache runs over budget rather
    /// than drop unrecoverable material (the residency bound applies to
    /// derivable entries; uploaded keys are client-owned residency).
    fn enforce_capacity(&mut self, capacity: usize) {
        while self.entries.len() > capacity {
            let lru = self
                .entries
                .iter()
                .filter(|(_, e)| !e.pinned)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(&k, _)| k);
            let Some(lru) = lru else {
                break; // everything resident is pinned: nothing evictable
            };
            self.entries.remove(&lru);
            self.evictions += 1;
        }
    }
}

/// Capacity-bounded LRU over seeded server-key sets, one instance per
/// parameter set (asserted). Unlike [`get`] this never grows past
/// `capacity` entries — the serving-side residency bound for per-tenant
/// key material — and it retains no secret keys.
pub struct BoundedKeyCache {
    capacity: usize,
    inner: Mutex<BoundedInner>,
}

impl BoundedKeyCache {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1, "a key cache of capacity 0 could never serve");
        Self { capacity, inner: Mutex::new(BoundedInner::default()) }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Fetch the key set for `(p, seed)`, generating on a miss. Keygen
    /// runs *outside* the lock so concurrent misses for different seeds
    /// generate in parallel; racing misses for the same seed may generate
    /// twice, but determinism makes both results bitwise-identical and
    /// the first insert wins.
    ///
    /// Panics on [`KeyCacheError::RegisteredEvicted`] — a seed that holds
    /// client-registered keys can never be served by regeneration. The
    /// serving path goes through [`Self::try_get`] and sheds the request
    /// typed instead.
    pub fn get(&self, p: &ParamSet, seed: u64) -> Arc<ServerKeys> {
        self.try_get(p, seed).unwrap_or_else(|e| panic!("BoundedKeyCache::get: {e}"))
    }

    /// Fallible [`Self::get`]: a miss for a seed whose keys were
    /// registered (client-uploaded) and explicitly removed fails with
    /// [`KeyCacheError::RegisteredEvicted`] instead of minting different
    /// bits. The failed lookup counts as neither miss nor regeneration —
    /// no keys were generated.
    pub fn try_get(&self, p: &ParamSet, seed: u64) -> Result<Arc<ServerKeys>, KeyCacheError> {
        {
            let mut g = self.inner.lock().expect("bounded key cache poisoned");
            g.bind_param(p.name);
            let tick = g.touch();
            if let Some(e) = g.entries.get_mut(&seed) {
                e.last_used = tick;
                let keys = e.keys.clone();
                g.hits += 1;
                return Ok(keys);
            }
            if g.registered.contains(&seed) {
                return Err(KeyCacheError::RegisteredEvicted { seed });
            }
            g.misses += 1;
            if g.seen.contains(&seed) {
                g.regenerations += 1;
            }
        }
        let generated = generate_entry(p, seed).server;
        let mut g = self.inner.lock().expect("bounded key cache poisoned");
        let tick = g.touch();
        g.seen.insert(seed);
        let keys = match g.entries.get_mut(&seed) {
            // A concurrent miss beat us to the insert; keep its Arc so
            // hit identity stays stable.
            Some(e) => {
                e.last_used = tick;
                e.keys.clone()
            }
            None => {
                g.entries.insert(
                    seed,
                    BoundedEntry { keys: generated.clone(), last_used: tick, pinned: false },
                );
                generated
            }
        };
        g.enforce_capacity(self.capacity);
        Ok(keys)
    }

    /// Install externally supplied keys (migration import). Counts as
    /// neither hit nor miss; may displace the LRU entry if the cache is
    /// full. A seed previously installed via [`Self::insert_pinned`]
    /// re-pins here — pinnedness survives a remove/insert migration
    /// round-trip, so uploaded keys stay unevictable on their new shard.
    pub fn insert(&self, p: &ParamSet, seed: u64, keys: Arc<ServerKeys>) {
        let mut g = self.inner.lock().expect("bounded key cache poisoned");
        g.bind_param(p.name);
        let tick = g.touch();
        g.seen.insert(seed);
        let pinned = g.registered.contains(&seed);
        g.entries.insert(seed, BoundedEntry { keys, last_used: tick, pinned });
        g.enforce_capacity(self.capacity);
    }

    /// Install client-uploaded keys and **pin** them: capacity pressure
    /// never evicts the entry ([`BoundedInner::enforce_capacity`] skips
    /// pinned entries), and once the pin marker exists a lookup after an
    /// explicit [`Self::remove`] fails typed instead of regenerating —
    /// the server has no way to re-derive uploaded material.
    pub fn insert_pinned(&self, p: &ParamSet, seed: u64, keys: Arc<ServerKeys>) {
        let mut g = self.inner.lock().expect("bounded key cache poisoned");
        g.bind_param(p.name);
        let tick = g.touch();
        g.seen.insert(seed);
        g.registered.insert(seed);
        g.entries.insert(seed, BoundedEntry { keys, last_used: tick, pinned: true });
        g.enforce_capacity(self.capacity);
    }

    /// Remove an entry deliberately (reshard migration hands it to
    /// another shard's cache). Not counted as a capacity eviction.
    /// Pinned entries ARE returned — migration must be able to move
    /// uploaded keys — but the pin *marker* stays, so a lookup on this
    /// cache between the remove and any re-insert fails typed rather
    /// than regenerating wrong bits.
    pub fn remove(&self, seed: u64) -> Option<Arc<ServerKeys>> {
        let mut g = self.inner.lock().expect("bounded key cache poisoned");
        g.entries.remove(&seed).map(|e| e.keys)
    }

    /// Resident seeds.
    pub fn resident(&self) -> Vec<u64> {
        let g = self.inner.lock().expect("bounded key cache poisoned");
        g.entries.keys().copied().collect()
    }

    pub fn stats(&self) -> CacheStats {
        let g = self.inner.lock().expect("bounded key cache poisoned");
        CacheStats {
            hits: g.hits,
            misses: g.misses,
            evictions: g.evictions,
            regenerations: g.regenerations,
            resident: g.entries.len(),
            pinned: g.entries.values().filter(|e| e.pinned).count(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::TEST1;
    use crate::tfhe::server_keys_bitwise_eq;

    #[test]
    fn cache_returns_one_shared_instance() {
        let a = get(&TEST1, 11);
        let b = get(&TEST1, 11);
        assert!(Arc::ptr_eq(&a, &b), "same (params, seed) -> same entry");
        let c = get(&TEST1, 12);
        assert!(!Arc::ptr_eq(&a, &c), "different seed -> different keys");
        // Cached keys are functional: encrypt/decrypt round-trips.
        let mut rng = Rng::new(3);
        let ct = super::super::pbs::encrypt_message(5, &a.sk, &mut rng);
        assert_eq!(super::super::pbs::decrypt_message(&ct, &b.sk), 5);
    }

    #[test]
    fn bounded_and_unbounded_caches_agree_bitwise() {
        let unbounded = get(&TEST1, 21);
        let bounded = BoundedKeyCache::new(2);
        let keys = bounded.get(&TEST1, 21);
        assert!(server_keys_bitwise_eq(&unbounded.server, &keys));
        // And the client-side derivation matches the cached sk.
        let sk = secret_keys_for(&TEST1, 21);
        let mut rng = Rng::new(9);
        let ct = super::super::pbs::encrypt_message(3, &sk, &mut rng);
        assert_eq!(super::super::pbs::decrypt_message(&ct, &unbounded.sk), 3);
    }

    #[test]
    fn bounded_cache_evicts_lru_and_counts_regenerations() {
        // Regression for the unbounded-growth satellite: capacity 2 must
        // hold exactly 2 entries through any access pattern.
        let c = BoundedKeyCache::new(2);
        let k1 = c.get(&TEST1, 1);
        let _k2 = c.get(&TEST1, 2);
        assert_eq!(
            c.stats(),
            CacheStats { hits: 0, misses: 2, evictions: 0, regenerations: 0, resident: 2, pinned: 0 }
        );

        // Touch 1 so 2 becomes the LRU, then insert 3: 2 is displaced.
        let k1_again = c.get(&TEST1, 1);
        assert!(Arc::ptr_eq(&k1, &k1_again), "hit returns the resident Arc");
        let _k3 = c.get(&TEST1, 3);
        let st = c.stats();
        assert_eq!((st.hits, st.misses, st.evictions, st.regenerations, st.resident), (1, 3, 1, 0, 2));
        let mut res = c.resident();
        res.sort_unstable();
        assert_eq!(res, vec![1, 3], "seed 2 was the LRU");

        // Re-fetching the displaced seed is a miss AND a regeneration,
        // with bitwise-identical keys (seeded determinism).
        let k2_again = c.get(&TEST1, 2);
        let st = c.stats();
        assert_eq!((st.misses, st.evictions, st.regenerations, st.resident), (4, 2, 1, 2));
        assert!(server_keys_bitwise_eq(&k2_again, &get(&TEST1, 2).server));
    }

    #[test]
    fn bounded_cache_insert_and_remove_do_not_count_as_traffic() {
        let c = BoundedKeyCache::new(2);
        let keys = c.get(&TEST1, 31);
        let moved = c.remove(31).expect("resident");
        assert!(Arc::ptr_eq(&moved, &keys));
        assert!(c.remove(31).is_none(), "already removed");
        c.insert(&TEST1, 31, moved.clone());
        let back = c.get(&TEST1, 31);
        assert!(Arc::ptr_eq(&back, &moved), "insert preserves Arc identity");
        let st = c.stats();
        // 1 generate miss + 1 hit; the remove/insert round-trip is silent
        // and the remove was not a capacity eviction.
        assert_eq!((st.hits, st.misses, st.evictions, st.regenerations), (1, 1, 0, 0));

        // Inserting past capacity (a reshard shrink funneling entries
        // into one store) LRU-displaces and counts the eviction: the
        // residency bound binds during migration imports too.
        c.insert(&TEST1, 32, moved.clone());
        c.insert(&TEST1, 33, moved.clone());
        let st = c.stats();
        assert_eq!(st.resident, 2, "capacity bound holds through inserts");
        assert_eq!(st.evictions, 1);
        let mut res = c.resident();
        res.sort_unstable();
        assert_eq!(res, vec![32, 33], "seed 31 was the LRU at the third insert");
    }

    #[test]
    fn pinned_entries_survive_lru_floods_and_never_regenerate() {
        // Regression for the silent-regeneration bug: client-uploaded
        // keys must survive arbitrary capacity pressure with the same
        // Arc, and `regenerations` must stay 0 for the pinned seed.
        let c = BoundedKeyCache::new(2);
        let uploaded = get(&TEST1, 100).server.clone();
        c.insert_pinned(&TEST1, 100, uploaded.clone());

        // Flood the LRU well past capacity with seeded tenants.
        for seed in 1..=4 {
            let _ = c.get(&TEST1, seed);
        }
        let st = c.stats();
        assert_eq!(st.pinned, 1, "the uploaded entry is still resident");
        assert_eq!(st.regenerations, 0, "no seed was generated twice");
        let resolved = c.get(&TEST1, 100);
        assert!(Arc::ptr_eq(&resolved, &uploaded), "pinned entry keeps its Arc");

        // Evictions only ever hit the unpinned seeded entries.
        assert!(c.resident().contains(&100));
        assert!(c.stats().evictions >= 1, "unpinned entries were displaced");

        // An explicit remove (reshard migration) keeps the pin marker:
        // a lookup in the gap fails typed instead of minting wrong bits.
        let moved = c.remove(100).expect("pinned entries are movable");
        assert!(Arc::ptr_eq(&moved, &uploaded));
        assert_eq!(
            c.try_get(&TEST1, 100),
            Err(KeyCacheError::RegisteredEvicted { seed: 100 }),
            "registered seed never regenerates"
        );
        let st = c.stats();
        assert_eq!(st.regenerations, 0, "the failed lookup minted nothing");

        // Re-import on the destination path (plain insert) re-pins.
        c.insert(&TEST1, 100, moved.clone());
        let back = c.get(&TEST1, 100);
        assert!(Arc::ptr_eq(&back, &moved));
        assert_eq!(c.stats().pinned, 1, "migration re-import re-pins");
    }
}
