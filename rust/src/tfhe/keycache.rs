//! Caches of deterministic seeded key sets.
//!
//! Two variants share one generation path ([`generate_entry`]):
//!
//! * [`get`] — the process-wide **unbounded** cache the test suite uses.
//!   Wide-width keygen is the dominant fixed cost of the conformance
//!   suite (a WIDE10 BSK+KSK is ~100 MB of material behind thousands of
//!   FFTs); because `ServerKeys::generate_seeded` is a pure function of
//!   `(params, seed)` — chunking and worker count cannot change the bits
//!   (`tfhe::keygen`) — the suite safely shares ONE key set per
//!   `(parameter set, seed)` across every test in the process. Entries
//!   are generated under a per-entry `OnceLock`, so two tests racing on
//!   the same width block on one generation while different widths
//!   generate concurrently. This cache grows without bound by design:
//!   its working set is the handful of test widths.
//!
//! * [`BoundedKeyCache`] — the **capacity-bounded LRU** the serving
//!   path's `tenant::SeededTenantStore` builds on. Per-tenant server keys
//!   are the same tens-of-MB entries, but a server meets an unbounded
//!   stream of tenants, so residency must be bounded and observable:
//!   the cache counts hits, misses, capacity evictions, and
//!   *regenerations* (a miss for a seed generated before — the signal
//!   that capacity is below the working set). It deliberately retains
//!   only server-side material (`Arc<ServerKeys>`), never client secret
//!   keys.

use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Mutex, OnceLock};

use super::keygen::{fork_seed, KeygenOptions};
use super::pbs::ServerKeys;
use super::torus::SecretKeys;
use crate::params::ParamSet;
use crate::util::rng::Rng;

/// One cached client+server key set (the unbounded test cache keeps the
/// secret keys so tests can encrypt/decrypt; the bounded serving cache
/// does not).
pub struct CachedKeys {
    pub sk: SecretKeys,
    pub server: Arc<ServerKeys>,
}

type Slot = Arc<OnceLock<Arc<CachedKeys>>>;

fn cache() -> &'static Mutex<HashMap<(String, u64), Slot>> {
    static CACHE: OnceLock<Mutex<HashMap<(String, u64), Slot>>> = OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Seed of the secret-key RNG stream for a cache seed (domain-separated
/// from the keygen streams so `sk` and `ek` randomness never overlap).
pub fn secret_seed(seed: u64) -> u64 {
    fork_seed(seed, 0x5EC2_E7D0, 0)
}

/// Seed handed to [`ServerKeys::generate_seeded`] for a cache seed —
/// exposed so determinism tests can regenerate a cached entry through a
/// different keygen configuration and compare bitwise.
pub fn server_seed(seed: u64) -> u64 {
    fork_seed(seed, 0x5EC2_E7D1, 0)
}

/// The client-side secret keys for `(p, seed)` — the cheap half of
/// [`generate_entry`], regenerated on demand (what a tenant's *client*
/// keeps while the server store holds only the server material).
pub fn secret_keys_for(p: &ParamSet, seed: u64) -> SecretKeys {
    let mut rng = Rng::new(secret_seed(seed));
    SecretKeys::generate(p, &mut rng)
}

/// Generate the full deterministic key set for `(p, seed)` — the single
/// generation path shared by [`get`] and [`BoundedKeyCache`], so both
/// caches (and a client deriving via [`secret_keys_for`]) always agree
/// bitwise.
pub fn generate_entry(p: &ParamSet, seed: u64) -> CachedKeys {
    let sk = secret_keys_for(p, seed);
    // Spread keygen over a few workers; by construction the worker
    // count does not change the generated bits.
    let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(4);
    let server =
        ServerKeys::generate_seeded(&sk, server_seed(seed), &KeygenOptions::with_workers(workers));
    CachedKeys { sk, server: Arc::new(server) }
}

/// Fetch (generating on first use) the key set for `(p, seed)`. Returns a
/// shared handle; all callers see the identical keys, so ciphertexts
/// produced by one test decrypt under another's copy.
pub fn get(p: &ParamSet, seed: u64) -> Arc<CachedKeys> {
    let slot: Slot = {
        let mut map = cache().lock().expect("key cache poisoned");
        map.entry((p.name.to_string(), seed)).or_default().clone()
    };
    slot.get_or_init(|| Arc::new(generate_entry(p, seed))).clone()
}

/// Counters of a bounded key cache (also the `tenant::KeyStoreStats`
/// shape): how resolution traffic split between cache states.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups served from a resident entry.
    pub hits: u64,
    /// Lookups that had to generate (first touch or post-eviction).
    pub misses: u64,
    /// Entries displaced by capacity pressure (explicit `remove`s — e.g.
    /// reshard migration — are not evictions).
    pub evictions: u64,
    /// Misses for a seed generated before: the cache paid keygen twice
    /// because capacity is below the working set.
    pub regenerations: u64,
    /// Entries currently resident.
    pub resident: usize,
}

struct BoundedEntry {
    keys: Arc<ServerKeys>,
    last_used: u64,
}

#[derive(Default)]
struct BoundedInner {
    /// The one parameter set this instance serves, bound on first use so
    /// a seed can never silently resolve to another set's keys.
    param_name: Option<&'static str>,
    entries: HashMap<u64, BoundedEntry>,
    /// Monotone access clock for LRU ordering.
    tick: u64,
    /// Every seed whose generation/insert *completed* — distinguishes a
    /// first-touch miss from a regeneration. Recorded at insert time (not
    /// at miss time) so two threads racing on the same first touch don't
    /// count a phantom regeneration. 8 bytes per tenant ever seen: the
    /// bookkeeping that makes the capacity-pressure signal exact, ~6
    /// orders of magnitude below the key material it meters.
    seen: HashSet<u64>,
    hits: u64,
    misses: u64,
    evictions: u64,
    regenerations: u64,
}

impl BoundedInner {
    fn touch(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    fn bind_param(&mut self, name: &'static str) {
        match self.param_name {
            None => self.param_name = Some(name),
            Some(bound) => assert_eq!(
                bound, name,
                "a BoundedKeyCache serves one parameter set; use one instance per set"
            ),
        }
    }

    /// Drop least-recently-used entries until `capacity` holds.
    fn enforce_capacity(&mut self, capacity: usize) {
        while self.entries.len() > capacity {
            let lru = self
                .entries
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(&k, _)| k)
                .expect("non-empty over capacity");
            self.entries.remove(&lru);
            self.evictions += 1;
        }
    }
}

/// Capacity-bounded LRU over seeded server-key sets, one instance per
/// parameter set (asserted). Unlike [`get`] this never grows past
/// `capacity` entries — the serving-side residency bound for per-tenant
/// key material — and it retains no secret keys.
pub struct BoundedKeyCache {
    capacity: usize,
    inner: Mutex<BoundedInner>,
}

impl BoundedKeyCache {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1, "a key cache of capacity 0 could never serve");
        Self { capacity, inner: Mutex::new(BoundedInner::default()) }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Fetch the key set for `(p, seed)`, generating on a miss. Keygen
    /// runs *outside* the lock so concurrent misses for different seeds
    /// generate in parallel; racing misses for the same seed may generate
    /// twice, but determinism makes both results bitwise-identical and
    /// the first insert wins.
    pub fn get(&self, p: &ParamSet, seed: u64) -> Arc<ServerKeys> {
        {
            let mut g = self.inner.lock().expect("bounded key cache poisoned");
            g.bind_param(p.name);
            let tick = g.touch();
            if let Some(e) = g.entries.get_mut(&seed) {
                e.last_used = tick;
                let keys = e.keys.clone();
                g.hits += 1;
                return keys;
            }
            g.misses += 1;
            if g.seen.contains(&seed) {
                g.regenerations += 1;
            }
        }
        let generated = generate_entry(p, seed).server;
        let mut g = self.inner.lock().expect("bounded key cache poisoned");
        let tick = g.touch();
        g.seen.insert(seed);
        let keys = match g.entries.get_mut(&seed) {
            // A concurrent miss beat us to the insert; keep its Arc so
            // hit identity stays stable.
            Some(e) => {
                e.last_used = tick;
                e.keys.clone()
            }
            None => {
                g.entries
                    .insert(seed, BoundedEntry { keys: generated.clone(), last_used: tick });
                generated
            }
        };
        g.enforce_capacity(self.capacity);
        keys
    }

    /// Install externally supplied keys (migration import / client
    /// upload). Counts as neither hit nor miss; may displace the LRU
    /// entry if the cache is full.
    pub fn insert(&self, p: &ParamSet, seed: u64, keys: Arc<ServerKeys>) {
        let mut g = self.inner.lock().expect("bounded key cache poisoned");
        g.bind_param(p.name);
        let tick = g.touch();
        g.seen.insert(seed);
        g.entries.insert(seed, BoundedEntry { keys, last_used: tick });
        g.enforce_capacity(self.capacity);
    }

    /// Remove an entry deliberately (reshard migration hands it to
    /// another shard's cache). Not counted as a capacity eviction.
    pub fn remove(&self, seed: u64) -> Option<Arc<ServerKeys>> {
        let mut g = self.inner.lock().expect("bounded key cache poisoned");
        g.entries.remove(&seed).map(|e| e.keys)
    }

    /// Resident seeds.
    pub fn resident(&self) -> Vec<u64> {
        let g = self.inner.lock().expect("bounded key cache poisoned");
        g.entries.keys().copied().collect()
    }

    pub fn stats(&self) -> CacheStats {
        let g = self.inner.lock().expect("bounded key cache poisoned");
        CacheStats {
            hits: g.hits,
            misses: g.misses,
            evictions: g.evictions,
            regenerations: g.regenerations,
            resident: g.entries.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::TEST1;
    use crate::tfhe::server_keys_bitwise_eq;

    #[test]
    fn cache_returns_one_shared_instance() {
        let a = get(&TEST1, 11);
        let b = get(&TEST1, 11);
        assert!(Arc::ptr_eq(&a, &b), "same (params, seed) -> same entry");
        let c = get(&TEST1, 12);
        assert!(!Arc::ptr_eq(&a, &c), "different seed -> different keys");
        // Cached keys are functional: encrypt/decrypt round-trips.
        let mut rng = Rng::new(3);
        let ct = super::super::pbs::encrypt_message(5, &a.sk, &mut rng);
        assert_eq!(super::super::pbs::decrypt_message(&ct, &b.sk), 5);
    }

    #[test]
    fn bounded_and_unbounded_caches_agree_bitwise() {
        let unbounded = get(&TEST1, 21);
        let bounded = BoundedKeyCache::new(2);
        let keys = bounded.get(&TEST1, 21);
        assert!(server_keys_bitwise_eq(&unbounded.server, &keys));
        // And the client-side derivation matches the cached sk.
        let sk = secret_keys_for(&TEST1, 21);
        let mut rng = Rng::new(9);
        let ct = super::super::pbs::encrypt_message(3, &sk, &mut rng);
        assert_eq!(super::super::pbs::decrypt_message(&ct, &unbounded.sk), 3);
    }

    #[test]
    fn bounded_cache_evicts_lru_and_counts_regenerations() {
        // Regression for the unbounded-growth satellite: capacity 2 must
        // hold exactly 2 entries through any access pattern.
        let c = BoundedKeyCache::new(2);
        let k1 = c.get(&TEST1, 1);
        let _k2 = c.get(&TEST1, 2);
        assert_eq!(c.stats(), CacheStats { hits: 0, misses: 2, evictions: 0, regenerations: 0, resident: 2 });

        // Touch 1 so 2 becomes the LRU, then insert 3: 2 is displaced.
        let k1_again = c.get(&TEST1, 1);
        assert!(Arc::ptr_eq(&k1, &k1_again), "hit returns the resident Arc");
        let _k3 = c.get(&TEST1, 3);
        let st = c.stats();
        assert_eq!((st.hits, st.misses, st.evictions, st.regenerations, st.resident), (1, 3, 1, 0, 2));
        let mut res = c.resident();
        res.sort_unstable();
        assert_eq!(res, vec![1, 3], "seed 2 was the LRU");

        // Re-fetching the displaced seed is a miss AND a regeneration,
        // with bitwise-identical keys (seeded determinism).
        let k2_again = c.get(&TEST1, 2);
        let st = c.stats();
        assert_eq!((st.misses, st.evictions, st.regenerations, st.resident), (4, 2, 1, 2));
        assert!(server_keys_bitwise_eq(&k2_again, &get(&TEST1, 2).server));
    }

    #[test]
    fn bounded_cache_insert_and_remove_do_not_count_as_traffic() {
        let c = BoundedKeyCache::new(2);
        let keys = c.get(&TEST1, 31);
        let moved = c.remove(31).expect("resident");
        assert!(Arc::ptr_eq(&moved, &keys));
        assert!(c.remove(31).is_none(), "already removed");
        c.insert(&TEST1, 31, moved.clone());
        let back = c.get(&TEST1, 31);
        assert!(Arc::ptr_eq(&back, &moved), "insert preserves Arc identity");
        let st = c.stats();
        // 1 generate miss + 1 hit; the remove/insert round-trip is silent
        // and the remove was not a capacity eviction.
        assert_eq!((st.hits, st.misses, st.evictions, st.regenerations), (1, 1, 0, 0));

        // Inserting past capacity (a reshard shrink funneling entries
        // into one store) LRU-displaces and counts the eviction: the
        // residency bound binds during migration imports too.
        c.insert(&TEST1, 32, moved.clone());
        c.insert(&TEST1, 33, moved.clone());
        let st = c.stats();
        assert_eq!(st.resident, 2, "capacity bound holds through inserts");
        assert_eq!(st.evictions, 1);
        let mut res = c.resident();
        res.sort_unstable();
        assert_eq!(res, vec![32, 33], "seed 31 was the LRU at the third insert");
    }
}
