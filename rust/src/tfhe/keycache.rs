//! Process-wide cache of deterministic seeded key sets.
//!
//! Wide-width keygen is the dominant fixed cost of the conformance suite
//! (a WIDE10 BSK+KSK is ~100 MB of material behind thousands of FFTs).
//! Because `ServerKeys::generate_seeded` is a pure function of
//! `(params, seed)` — chunking and worker count cannot change the bits
//! (`tfhe::keygen`) — the suite can safely share ONE key set per
//! `(parameter set, seed)` across every test in the process and pay
//! keygen once per width.
//!
//! Entries are generated under a per-entry `OnceLock`, so two tests
//! racing on the same width block on one generation while different
//! widths generate concurrently.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use super::keygen::{fork_seed, KeygenOptions};
use super::pbs::ServerKeys;
use super::torus::SecretKeys;
use crate::params::ParamSet;
use crate::util::rng::Rng;

/// One cached client+server key set.
pub struct CachedKeys {
    pub sk: SecretKeys,
    pub server: Arc<ServerKeys>,
}

type Slot = Arc<OnceLock<Arc<CachedKeys>>>;

fn cache() -> &'static Mutex<HashMap<(String, u64), Slot>> {
    static CACHE: OnceLock<Mutex<HashMap<(String, u64), Slot>>> = OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Seed of the secret-key RNG stream for a cache seed (domain-separated
/// from the keygen streams so `sk` and `ek` randomness never overlap).
pub fn secret_seed(seed: u64) -> u64 {
    fork_seed(seed, 0x5EC2_E7D0, 0)
}

/// Seed handed to [`ServerKeys::generate_seeded`] for a cache seed —
/// exposed so determinism tests can regenerate a cached entry through a
/// different keygen configuration and compare bitwise.
pub fn server_seed(seed: u64) -> u64 {
    fork_seed(seed, 0x5EC2_E7D1, 0)
}

/// Fetch (generating on first use) the key set for `(p, seed)`. Returns a
/// shared handle; all callers see the identical keys, so ciphertexts
/// produced by one test decrypt under another's copy.
pub fn get(p: &ParamSet, seed: u64) -> Arc<CachedKeys> {
    let slot: Slot = {
        let mut map = cache().lock().expect("key cache poisoned");
        map.entry((p.name.to_string(), seed)).or_default().clone()
    };
    slot.get_or_init(|| {
        let mut rng = Rng::new(secret_seed(seed));
        let sk = SecretKeys::generate(p, &mut rng);
        // Spread keygen over a few workers; by construction the worker
        // count does not change the generated bits.
        let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(4);
        let server = ServerKeys::generate_seeded(&sk, server_seed(seed), &KeygenOptions::with_workers(workers));
        Arc::new(CachedKeys { sk, server: Arc::new(server) })
    })
    .clone()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::TEST1;

    #[test]
    fn cache_returns_one_shared_instance() {
        let a = get(&TEST1, 11);
        let b = get(&TEST1, 11);
        assert!(Arc::ptr_eq(&a, &b), "same (params, seed) -> same entry");
        let c = get(&TEST1, 12);
        assert!(!Arc::ptr_eq(&a, &c), "different seed -> different keys");
        // Cached keys are functional: encrypt/decrypt round-trips.
        let mut rng = Rng::new(3);
        let ct = super::super::pbs::encrypt_message(5, &a.sk, &mut rng);
        assert_eq!(super::super::pbs::decrypt_message(&ct, &b.sk), 5);
    }
}
