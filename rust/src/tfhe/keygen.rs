//! Chunked, seed-deterministic server-key generation.
//!
//! At the wide widths (`params::WIDE8`/`WIDE10`) key material is the cost
//! that used to keep the functional path stuck at width 5: a monolithic
//! `FourierBsk::generate` walks n GGSW encryptions single-threaded, and a
//! 10-bit KSK is tens of megabytes of LWE rows. This module makes keygen
//! affordable without giving up reproducibility:
//!
//! * **Row streaming** — each GGSW row is encrypted in the torus domain,
//!   Fourier-transformed, and dropped immediately (only the planar
//!   `re[]`/`im[]` output is retained), so transient torus-domain material
//!   never exceeds one GLWE row regardless of key size.
//! * **Chunking** — the key is produced in chunks of
//!   [`KeygenOptions::chunk`] units (GGSWs for the BSK, long-key rows for
//!   the KSK). The chunk is the scheduling unit of the worker split and
//!   the granularity at which finished material lands in the output.
//! * **Per-unit RNG forking** — unit i draws from `Rng::new(mix(seed, i))`
//!   rather than one shared stream. Chunk size and worker count therefore
//!   *cannot* change a single bit of the key: monolithic, chunked, and
//!   N-worker generation are bitwise identical (regression-tested per
//!   width in `rust/tests/conformance_widths.rs`).
//! * **Rayon-free workers** — the split reuses the coordinator's plumbing
//!   style (`std::thread` + `mpsc`, see `coordinator::server`): workers
//!   claim chunk indices from an atomic counter and send finished chunks
//!   back over a channel; the parent reassembles them by index.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::channel;

use super::pbs::ServerKeys;
use crate::util::rng::Rng;

/// How key material is produced. The options change scheduling and peak
/// transient memory only — never the generated bits.
#[derive(Debug, Clone)]
pub struct KeygenOptions {
    /// Units (GGSWs / KSK long-rows) generated per chunk.
    pub chunk: usize,
    /// Worker threads; 1 = generate on the calling thread.
    pub workers: usize,
}

impl Default for KeygenOptions {
    fn default() -> Self {
        Self { chunk: 16, workers: 1 }
    }
}

impl KeygenOptions {
    /// The monolithic path: one chunk, calling thread — the baseline the
    /// determinism regression compares every other configuration against.
    pub fn monolithic() -> Self {
        Self { chunk: usize::MAX, workers: 1 }
    }

    /// Chunked with `workers` generation threads.
    pub fn with_workers(workers: usize) -> Self {
        Self { workers: workers.max(1), ..Self::default() }
    }
}

/// Domain-separated seed mixing (SplitMix64 finalizer): the child seed for
/// unit `index` of stream `domain` under a master `seed`. Every keygen
/// unit and every key component gets an independent stream, which is what
/// makes the output independent of scheduling.
pub fn fork_seed(seed: u64, domain: u64, index: u64) -> u64 {
    let mut z = seed ^ domain.rotate_left(32) ^ index.wrapping_mul(0x9e3779b97f4a7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// Stream tags for [`fork_seed`] (arbitrary distinct constants).
pub const DOMAIN_BSK: u64 = 0xB5C0_17C4;
pub const DOMAIN_KSK: u64 = 0x75C8_3D21;

/// Per-unit RNG for keygen unit `index` of stream `domain`.
pub(crate) fn unit_rng(seed: u64, domain: u64, index: usize) -> Rng {
    Rng::new(fork_seed(seed, domain, index as u64))
}

/// Produce `total` units through `gen` chunk by chunk, optionally split
/// over worker threads. `gen` receives a unit index range and returns that
/// chunk's units in order; results are reassembled by chunk index, so the
/// output is identical for every (chunk, workers) configuration as long as
/// `gen` itself only depends on the unit index (per-unit RNG forking).
pub(crate) fn generate_chunks<T, F>(total: usize, opts: &KeygenOptions, gen: F) -> Vec<T>
where
    T: Send,
    F: Fn(std::ops::Range<usize>) -> Vec<T> + Sync,
{
    let chunk = opts.chunk.clamp(1, total.max(1));
    let n_chunks = total.div_ceil(chunk).max(1);
    let chunk_range = |c: usize| c * chunk..((c + 1) * chunk).min(total);
    if opts.workers <= 1 || n_chunks == 1 {
        // Streaming but sequential: one chunk of material in flight.
        let mut out = Vec::with_capacity(total);
        for c in 0..n_chunks {
            out.extend(gen(chunk_range(c)));
        }
        return out;
    }
    let next = AtomicUsize::new(0);
    let (tx, rx) = channel::<(usize, Vec<T>)>();
    let mut slots: Vec<Option<Vec<T>>> = std::iter::repeat_with(|| None).take(n_chunks).collect();
    std::thread::scope(|s| {
        for _ in 0..opts.workers.min(n_chunks) {
            let tx = tx.clone();
            let next = &next;
            let gen = &gen;
            s.spawn(move || {
                loop {
                    let c = next.fetch_add(1, Ordering::Relaxed);
                    if c >= n_chunks {
                        break;
                    }
                    if tx.send((c, gen(chunk_range(c)))).is_err() {
                        break;
                    }
                }
            });
        }
        drop(tx);
        for (c, data) in rx {
            slots[c] = Some(data);
        }
    });
    let mut out = Vec::with_capacity(total);
    for s in slots {
        out.extend(s.expect("worker produced every chunk"));
    }
    out
}

/// Bitwise equality of two Fourier BSKs (f64 planes compared by bit
/// pattern, so the check is exact and NaN-safe).
pub fn fourier_bsk_bitwise_eq(a: &super::bsk::FourierBsk, b: &super::bsk::FourierBsk) -> bool {
    a.ggsw.len() == b.ggsw.len()
        && a.ggsw.iter().zip(&b.ggsw).all(|(x, y)| {
            (x.rows, x.k1, x.nh) == (y.rows, y.k1, y.nh)
                && x.re.len() == y.re.len()
                && x.im.len() == y.im.len()
                && x.re.iter().zip(&y.re).all(|(p, q)| p.to_bits() == q.to_bits())
                && x.im.iter().zip(&y.im).all(|(p, q)| p.to_bits() == q.to_bits())
        })
}

/// Bitwise equality of two server-key sets. This is the determinism
/// oracle: seeded chunked/monolithic/N-worker generation must agree.
pub fn server_keys_bitwise_eq(a: &ServerKeys, b: &ServerKeys) -> bool {
    a.params == b.params
        && a.ksk.data == b.ksk.data
        && (a.ksk.long_dim, a.ksk.level, a.ksk.short_len)
            == (b.ksk.long_dim, b.ksk.level, b.ksk.short_len)
        && fourier_bsk_bitwise_eq(&a.bsk, &b.bsk)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fork_seed_separates_domains_and_indices() {
        let s = 42u64;
        assert_ne!(fork_seed(s, DOMAIN_BSK, 0), fork_seed(s, DOMAIN_KSK, 0));
        assert_ne!(fork_seed(s, DOMAIN_BSK, 0), fork_seed(s, DOMAIN_BSK, 1));
        assert_eq!(fork_seed(s, DOMAIN_BSK, 7), fork_seed(s, DOMAIN_BSK, 7));
        assert_ne!(fork_seed(s, DOMAIN_BSK, 0), fork_seed(s + 1, DOMAIN_BSK, 0));
    }

    #[test]
    fn generate_chunks_is_schedule_invariant() {
        // The per-index generator makes output depend only on the index;
        // every (chunk, workers) combination must produce the same vector.
        let gen = |r: std::ops::Range<usize>| -> Vec<u64> {
            r.map(|i| unit_rng(9, DOMAIN_BSK, i).next_u64()).collect()
        };
        let total = 37;
        let baseline = generate_chunks(total, &KeygenOptions::monolithic(), gen);
        assert_eq!(baseline.len(), total);
        for (chunk, workers) in [(1, 1), (5, 1), (5, 3), (64, 4), (7, 8)] {
            let got = generate_chunks(total, &KeygenOptions { chunk, workers }, gen);
            assert_eq!(got, baseline, "chunk={chunk} workers={workers}");
        }
    }

    #[test]
    fn generate_chunks_handles_empty() {
        let gen = |r: std::ops::Range<usize>| -> Vec<u64> { r.map(|i| i as u64).collect() };
        assert!(generate_chunks(0, &KeygenOptions::default(), gen).is_empty());
        assert!(generate_chunks(0, &KeygenOptions::with_workers(4), gen).is_empty());
    }
}
