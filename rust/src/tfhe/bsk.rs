//! Bootstrapping key: n GGSW encryptions of the short-LWE key bits, kept
//! in the Fourier domain (the form the BRU streams from HBM, Fig. 7) as
//! planar re[]/im[] arrays — the layout both the scalar MAC and the
//! batched key-reuse MAC consume directly.

use super::fft::{C64, FftPlan};
use super::ggsw::FourierGgsw;
use super::glwe::GlweCiphertext;
use super::keygen::{self, KeygenOptions};
use super::torus::SecretKeys;
use crate::util::rng::Rng;

/// Fourier-domain BSK.
#[derive(Debug, Clone)]
pub struct FourierBsk {
    pub ggsw: Vec<FourierGgsw>,
}

/// Encrypt one GGSW of message bit `m` under the GLWE key.
pub fn encrypt_ggsw(m: u64, sk: &SecretKeys, rng: &mut Rng, plan: &FftPlan) -> FourierGgsw {
    let p = &sk.params;
    let (k1, nh, big_n) = (p.k + 1, p.half_n(), p.big_n);
    let rows = p.ggsw_rows();
    let mut re = vec![0.0f64; rows * k1 * nh];
    let mut im = vec![0.0f64; rows * k1 * nh];
    let mut row_f = vec![C64::default(); nh];
    let mut msg = vec![0u64; big_n];
    for c in 0..k1 {
        for j in 0..p.bsk_level {
            let w = (64 - p.bsk_base_log * (j + 1)) as u32;
            msg.iter_mut().for_each(|x| *x = 0);
            if m != 0 {
                if c < p.k {
                    // -s_c * q/B^(j+1)
                    for (dst, &s) in msg.iter_mut().zip(sk.glwe_poly(c)) {
                        *dst = s.wrapping_neg().wrapping_shl(w).wrapping_mul(m);
                    }
                } else {
                    msg[0] = m.wrapping_shl(w);
                }
            }
            let ct = GlweCiphertext::encrypt(&msg, sk, p.glwe_noise, rng, plan);
            let r = c * p.bsk_level + j;
            for cc in 0..k1 {
                plan.forward_negacyclic_torus(ct.poly(cc), &mut row_f);
                let off = (r * k1 + cc) * nh;
                for (h, z) in row_f.iter().enumerate() {
                    re[off + h] = z.re;
                    im[off + h] = z.im;
                }
            }
        }
    }
    FourierGgsw { re, im, rows, k1, nh }
}

impl FourierBsk {
    pub fn generate(sk: &SecretKeys, rng: &mut Rng, plan: &FftPlan) -> Self {
        // Iterate the key bits by reference; cloning the whole short key
        // per keygen was needless.
        let ggsw = sk.lwe.iter().map(|&bit| encrypt_ggsw(bit, sk, rng, plan)).collect();
        Self { ggsw }
    }

    /// Seed-deterministic chunked generation (`tfhe::keygen`): GGSW i
    /// draws from its own forked RNG, chunks of GGSWs are generated ->
    /// Fourier-transformed -> dropped (torus-domain material never exceeds
    /// one GLWE row), and the optional worker split cannot change the
    /// output bits. This is what makes the WIDE8/WIDE10 keys affordable
    /// and cacheable in CI.
    pub fn generate_seeded(
        sk: &SecretKeys,
        seed: u64,
        plan: &FftPlan,
        opts: &KeygenOptions,
    ) -> Self {
        let ggsw = keygen::generate_chunks(sk.params.n, opts, |range| {
            range
                .map(|i| {
                    let mut rng = keygen::unit_rng(seed, keygen::DOMAIN_BSK, i);
                    encrypt_ggsw(sk.lwe[i], sk, &mut rng, plan)
                })
                .collect()
        });
        Self { ggsw }
    }

    /// Flatten to (re, im) f64 arrays with shape [n, rows, k+1, N/2] — the
    /// exact input layout of the `blind_rotate` AOT artifact. The native
    /// pipeline keeps Fourier rows in bit-reversed order (no-permutation
    /// DIF/DIT, see fft.rs §Perf); the artifact uses jnp.fft's natural
    /// order, so each row is permuted here (build-time only) through the
    /// registry plan's precomputed table and one reused row buffer —
    /// no per-row index derivation or allocation.
    pub fn to_flat_f64(&self) -> (Vec<f64>, Vec<f64>) {
        let total: usize = self.ggsw.iter().map(|g| g.points()).sum();
        let mut re = Vec::with_capacity(total);
        let mut im = Vec::with_capacity(total);
        let Some(first) = self.ggsw.first() else {
            return (re, im);
        };
        let plan = super::fft::plan_for(first.nh * 2);
        let mut buf = vec![0.0f64; first.nh];
        for g in &self.ggsw {
            for r in 0..g.rows {
                for c in 0..g.k1 {
                    plan.bitrev_permute_f64_into(g.row_re(r, c), &mut buf);
                    re.extend_from_slice(&buf);
                    plan.bitrev_permute_f64_into(g.row_im(r, c), &mut buf);
                    im.extend_from_slice(&buf);
                }
            }
        }
        (re, im)
    }

    /// In-memory size of the Fourier BSK in bytes (2 f64 per point).
    pub fn bytes(&self) -> usize {
        self.ggsw.iter().map(|g| g.bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::TEST1;

    use super::super::keygen::fourier_bsk_bitwise_eq as bsk_bits_eq;

    #[test]
    fn seeded_bsk_is_schedule_invariant() {
        let mut rng = Rng::new(21);
        let sk = SecretKeys::generate(&TEST1, &mut rng);
        let plan = FftPlan::new(TEST1.big_n);
        let mono = FourierBsk::generate_seeded(&sk, 77, &plan, &KeygenOptions::monolithic());
        assert_eq!(mono.ggsw.len(), TEST1.n);
        let chunked =
            FourierBsk::generate_seeded(&sk, 77, &plan, &KeygenOptions { chunk: 5, workers: 1 });
        let parallel = FourierBsk::generate_seeded(&sk, 77, &plan, &KeygenOptions::with_workers(3));
        assert!(bsk_bits_eq(&mono, &chunked), "chunking must not change bits");
        assert!(bsk_bits_eq(&mono, &parallel), "worker split must not change bits");
        let reseeded = FourierBsk::generate_seeded(&sk, 78, &plan, &KeygenOptions::monolithic());
        assert!(!bsk_bits_eq(&mono, &reseeded), "different seed -> different key");
    }

    #[test]
    fn bsk_shape_and_flat_layout() {
        let mut rng = Rng::new(7);
        let sk = SecretKeys::generate(&TEST1, &mut rng);
        let plan = FftPlan::new(TEST1.big_n);
        // Only a few GGSWs to keep the test fast.
        let g = encrypt_ggsw(1, &sk, &mut rng, &plan);
        assert_eq!(g.rows, TEST1.ggsw_rows());
        assert_eq!(g.k1, TEST1.k + 1);
        assert_eq!(g.nh, TEST1.half_n());
        assert_eq!(g.points(), g.rows * g.k1 * g.nh);
        assert_eq!(g.re.len(), g.im.len());
        let bsk = FourierBsk { ggsw: vec![g.clone(), g] };
        let (re, im) = bsk.to_flat_f64();
        assert_eq!(re.len(), 2 * TEST1.ggsw_rows() * (TEST1.k + 1) * TEST1.half_n());
        assert_eq!(re.len(), im.len());
        // Flat layout is the bit-reversal permutation of each Fourier row
        // (bin 0 is fixed by the permutation; bin 1 comes from nh/2).
        let nh = TEST1.half_n();
        assert_eq!(re[0], bsk.ggsw[0].re[0]);
        assert_eq!(im[1], bsk.ggsw[0].im[nh / 2]);
        assert_eq!(bsk.bytes(), 2 * bsk.ggsw[0].bytes());
    }
}
