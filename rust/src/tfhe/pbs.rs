//! Programmable bootstrapping — the full pipeline of the paper's Fig. 3,
//! in the key-switch-first order the paper adopts (§II-B):
//!
//!   long LWE --(A) keyswitch--> short LWE --(B) mod-switch-->
//!   --(C) blind rotation--> GLWE --(D) sample extract--> long LWE
//!
//! [`PbsContext`] owns the FFT plan and all scratch so a PBS allocates
//! nothing on the hot path.

use std::sync::{Arc, Mutex, PoisonError};

use super::bsk::FourierBsk;
use super::fft::{plan_for, FftPlan};
use super::ggsw::{cmux_rotate, cmux_rotate_batch, BatchExtProdScratch, ExtProdScratch};
use super::glwe::GlweCiphertext;
use super::ksk::Ksk;
use super::lwe::LweCiphertext;
use super::parallel::{Job, WorkerPool};
use super::poly::rotate_into;
use super::torus::SecretKeys;
use crate::obs::hist::Log2Histogram;
use crate::params::ParamSet;
use crate::util::rng::Rng;

/// Server-side evaluation keys (the paper's `ek`): BSK + KSK.
pub struct ServerKeys {
    pub params: ParamSet,
    pub bsk: FourierBsk,
    pub ksk: Ksk,
}

impl ServerKeys {
    pub fn generate(sk: &SecretKeys, rng: &mut Rng) -> Self {
        let plan = plan_for(sk.params.big_n);
        Self {
            params: sk.params.clone(),
            bsk: FourierBsk::generate(sk, rng, &plan),
            ksk: Ksk::generate(sk, rng),
        }
    }

    /// Seed-deterministic generation through the chunked keygen path
    /// (`tfhe::keygen`): BSK and KSK draw from domain-separated streams of
    /// `seed`, so the result depends only on `(sk, seed)` — never on
    /// `opts`' chunking or worker count. The wide-width `KeyCache` builds
    /// on this to memoize keys across tests.
    pub fn generate_seeded(sk: &SecretKeys, seed: u64, opts: &super::keygen::KeygenOptions) -> Self {
        let plan = plan_for(sk.params.big_n);
        Self {
            params: sk.params.clone(),
            bsk: FourierBsk::generate_seeded(sk, seed, &plan, opts),
            ksk: Ksk::generate_seeded(sk, seed, opts),
        }
    }
}

/// Mod-switch a torus value to Z_{2N} with rounding.
#[inline]
pub fn modswitch(x: u64, big_n: usize) -> usize {
    let two_n = 2 * big_n;
    let shift = 64 - two_n.trailing_zeros();
    ((((x >> (shift - 1)) + 1) >> 1) as usize) % two_n
}

/// Execution context: FFT plan + scratch buffers, reusable across PBS
/// calls (one per worker thread). Tracks the Fourier-BSK bytes its blind
/// rotations stream so callers can report amortized key traffic (the
/// batched path streams each GGSW once per batch instead of once per
/// ciphertext).
pub struct PbsContext {
    pub params: ParamSet,
    /// Shared per-size plan from the process-wide registry
    /// (`fft::plan_for`): contexts and worker rebinds stop re-deriving
    /// identical twiddle tables.
    pub plan: Arc<FftPlan>,
    scratch: ExtProdScratch,
    /// Batch scratch, lazily (re)sized to the last batch width.
    batch_scratch: Option<BatchExtProdScratch>,
    rot_buf: Vec<u64>,
    bsk_bytes_streamed: u64,
    /// Worker threads for the column-parallel batched sweep (1 = fully
    /// sequential, the exact pre-parallel behavior).
    fft_threads: usize,
    /// Persistent pool, present iff `fft_threads > 1`.
    pool: Option<WorkerPool>,
    /// Per-chunk batch scratch for the parallel sweep (grow-only, like
    /// `batch_scratch`).
    chunk_scratch: Vec<BatchExtProdScratch>,
    /// FFT transform times deposited by pool workers (each job drains its
    /// thread-local meter here when observability is enabled); merged with
    /// the owning thread's meter by [`Self::take_fft_hist`].
    pool_fft: Arc<Mutex<Log2Histogram>>,
}

impl PbsContext {
    pub fn new(params: &ParamSet) -> Self {
        Self::with_threads(params, 1)
    }

    /// Context with a column-parallel blind-rotation sweep over
    /// `fft_threads` persistent workers. Thread count is a pure
    /// scheduling knob: outputs are bitwise-identical for every value.
    pub fn with_threads(params: &ParamSet, fft_threads: usize) -> Self {
        let fft_threads = fft_threads.max(1);
        Self {
            params: params.clone(),
            plan: plan_for(params.big_n),
            scratch: ExtProdScratch::new(params),
            batch_scratch: None,
            rot_buf: vec![0; params.big_n],
            bsk_bytes_streamed: 0,
            fft_threads,
            pool: (fft_threads > 1).then(|| WorkerPool::new(fft_threads)),
            chunk_scratch: Vec::new(),
            pool_fft: Arc::new(Mutex::new(Log2Histogram::new())),
        }
    }

    /// Configured worker count for the batched sweep.
    pub fn fft_threads(&self) -> usize {
        self.fft_threads
    }

    /// Reconfigure the worker count (tears down / spins up the pool).
    pub fn set_fft_threads(&mut self, fft_threads: usize) {
        let fft_threads = fft_threads.max(1);
        if fft_threads == self.fft_threads {
            return;
        }
        self.fft_threads = fft_threads;
        self.pool = (fft_threads > 1).then(|| WorkerPool::new(fft_threads));
        self.chunk_scratch.clear();
    }

    /// Whether this context's transforms take the cache-blocked schedule
    /// (plan-time property of the parameter set's polynomial size).
    pub fn blocked_fft(&self) -> bool {
        self.plan.blocked()
    }

    /// Fourier-BSK bytes read by blind rotations since construction or the
    /// last [`Self::take_bsk_bytes_streamed`].
    pub fn bsk_bytes_streamed(&self) -> u64 {
        self.bsk_bytes_streamed
    }

    /// Drain the BSK traffic counter (returns the accumulated bytes).
    pub fn take_bsk_bytes_streamed(&mut self) -> u64 {
        std::mem::take(&mut self.bsk_bytes_streamed)
    }

    /// Drain the per-transform FFT timing histogram: the calling thread's
    /// local meter (sequential-path transforms) merged with everything
    /// the blind-rotation pool workers deposited. Empty unless
    /// `obs::enabled` during execution.
    pub fn take_fft_hist(&mut self) -> Log2Histogram {
        let mut h = crate::obs::take_thread_fft();
        let mut pool = self.pool_fft.lock().unwrap_or_else(PoisonError::into_inner);
        h.merge(&std::mem::take(&mut *pool));
        h
    }

    /// Blind rotation (paper Fig. 3 (c)): returns the rotated accumulator.
    pub fn blind_rotate(
        &mut self,
        ct_short: &LweCiphertext,
        bsk: &FourierBsk,
        lut_poly: &[u64],
    ) -> GlweCiphertext {
        let p = self.params.clone();
        debug_assert_eq!(ct_short.dim(), p.n);
        let two_n = 2 * p.big_n;
        let b = modswitch(ct_short.body(), p.big_n);
        let mut acc = GlweCiphertext::zero(p.k, p.big_n);
        rotate_into(lut_poly, two_n - b, &mut self.rot_buf);
        acc.body_mut().copy_from_slice(&self.rot_buf);
        for (i, &a) in ct_short.mask().iter().enumerate() {
            let a_i = modswitch(a, p.big_n);
            if a_i != 0 {
                self.bsk_bytes_streamed += bsk.ggsw[i].bytes() as u64;
                cmux_rotate(&self.plan, &p, &bsk.ggsw[i], a_i, &mut acc, &mut self.scratch);
            }
        }
        acc
    }

    /// Batched blind rotation with the paper's key-reuse schedule: the n
    /// GGSW keys form the **outer** loop and the ciphertext batch the
    /// inner loop, so each Fourier key row is streamed once per batch step
    /// instead of once per ciphertext. All accumulators advance in
    /// lockstep over the planar SoA kernels.
    pub fn blind_rotate_batch(
        &mut self,
        cts: &[LweCiphertext],
        bsk: &FourierBsk,
        lut_poly: &[u64],
    ) -> Vec<GlweCiphertext> {
        // Batch of one: the tuned scalar path does strictly less work
        // (no planar scatter/gather, no batch scratch).
        if cts.len() == 1 {
            return vec![self.blind_rotate(&cts[0], bsk, lut_poly)];
        }
        let p = self.params.clone();
        let cols = cts.len();
        let two_n = 2 * p.big_n;
        let mut accs = Vec::with_capacity(cols);
        for ct in cts {
            debug_assert_eq!(ct.dim(), p.n);
            let b = modswitch(ct.body(), p.big_n);
            let mut acc = GlweCiphertext::zero(p.k, p.big_n);
            rotate_into(lut_poly, two_n - b, &mut self.rot_buf);
            acc.body_mut().copy_from_slice(&self.rot_buf);
            accs.push(acc);
        }
        if cols == 0 {
            return accs;
        }
        // Column-parallel sweep: chunks of the batch go to the persistent
        // pool. Bitwise-invariant vs the sequential sweep below (and
        // across thread counts), so the knob is pure scheduling.
        let nchunks = self.fft_threads.min(cols);
        if nchunks > 1 {
            self.blind_rotate_batch_parallel(cts, bsk, &p, &mut accs, nchunks);
            return accs;
        }
        // Grow-only: narrower batches reuse a wider scratch (the kernels
        // operate on a cols-sized prefix), so the dynamic batcher's
        // straggler batches don't put allocation back on the hot path.
        match &self.batch_scratch {
            Some(s) if s.cols() >= cols => {}
            _ => self.batch_scratch = Some(BatchExtProdScratch::new(&p, cols)),
        }
        let scratch = self.batch_scratch.as_mut().unwrap();
        let mut amounts = vec![0usize; cols];
        for (i, g) in bsk.ggsw.iter().enumerate() {
            let mut any_nonzero = false;
            for (b, ct) in cts.iter().enumerate() {
                amounts[b] = modswitch(ct.mask()[i], p.big_n);
                any_nonzero |= amounts[b] != 0;
            }
            if !any_nonzero {
                continue;
            }
            // Key i is read once here and applied to all `cols` columns.
            self.bsk_bytes_streamed += g.bytes() as u64;
            cmux_rotate_batch(&self.plan, &p, g, &amounts, &mut accs, scratch);
        }
        accs
    }

    /// Column-parallel key sweep over the persistent [`WorkerPool`]: the
    /// batch is split into `nchunks` contiguous column chunks, keys stay
    /// shared read-only (`bsk` is borrowed by every job), and each chunk
    /// owns disjoint accumulators plus its own FFT scratch.
    ///
    /// Bitwise-invariant across thread counts because
    /// 1. every chunk — width 1 included — runs the same planar kernels
    ///    the sequential batch sweep runs, and per-column planar
    ///    arithmetic is independent of how many columns share a call;
    /// 2. a chunk skipping a key that rotates all of *its* columns by 0
    ///    is exact — a zero-amount CMUX contributes only signed zeros
    ///    that never flip an accumulator bit;
    /// 3. partition bounds only decide which no-ops are elided.
    fn blind_rotate_batch_parallel(
        &mut self,
        cts: &[LweCiphertext],
        bsk: &FourierBsk,
        p: &ParamSet,
        accs: &mut [GlweCiphertext],
        nchunks: usize,
    ) {
        let cols = cts.len();
        // BSK traffic is accounted once over the whole batch with the
        // sequential sweep's skip rule (each live key row streams once
        // per batch from shared cache), keeping the counter identical
        // across thread counts.
        for (i, g) in bsk.ggsw.iter().enumerate() {
            if cts.iter().any(|ct| modswitch(ct.mask()[i], p.big_n) != 0) {
                self.bsk_bytes_streamed += g.bytes() as u64;
            }
        }
        // Grow-only per-chunk scratch, sized for the widest chunk.
        let max_chunk = cols.div_ceil(nchunks);
        while self.chunk_scratch.len() < nchunks {
            self.chunk_scratch.push(BatchExtProdScratch::new(p, max_chunk));
        }
        for s in self.chunk_scratch.iter_mut().take(nchunks) {
            if s.cols() < max_chunk {
                *s = BatchExtProdScratch::new(p, max_chunk);
            }
        }
        let plan = Arc::clone(&self.plan);
        let pool = self.pool.as_ref().expect("fft_threads > 1 implies a pool");
        let mut jobs: Vec<Job> = Vec::with_capacity(nchunks);
        let mut rest_accs = accs;
        let mut rest_scratch = &mut self.chunk_scratch[..nchunks];
        for c in 0..nchunks {
            let lo = cols * c / nchunks;
            let hi = cols * (c + 1) / nchunks;
            let (chunk_accs, ra) = std::mem::take(&mut rest_accs).split_at_mut(hi - lo);
            rest_accs = ra;
            let (chunk_scratch, rs) = std::mem::take(&mut rest_scratch).split_at_mut(1);
            rest_scratch = rs;
            let chunk_cts = &cts[lo..hi];
            let plan = Arc::clone(&plan);
            let pool_fft = Arc::clone(&self.pool_fft);
            jobs.push(Box::new(move || {
                let scratch = &mut chunk_scratch[0];
                let mut amounts = vec![0usize; chunk_cts.len()];
                for (i, g) in bsk.ggsw.iter().enumerate() {
                    let mut any_nonzero = false;
                    for (b, ct) in chunk_cts.iter().enumerate() {
                        amounts[b] = modswitch(ct.mask()[i], p.big_n);
                        any_nonzero |= amounts[b] != 0;
                    }
                    if !any_nonzero {
                        continue;
                    }
                    cmux_rotate_batch(&plan, p, g, &amounts, chunk_accs, scratch);
                }
                // Harvest this pool thread's FFT meter so transform times
                // survive the job (pool threads are persistent but jobs
                // are the drain boundary).
                if crate::obs::enabled() {
                    let h = crate::obs::take_thread_fft();
                    if !h.is_empty() {
                        pool_fft.lock().unwrap_or_else(PoisonError::into_inner).merge(&h);
                    }
                }
            }));
        }
        pool.run(jobs);
    }

    /// Primitive entry point A: long LWE -> short LWE key switch (LPU).
    pub fn keyswitch(&self, ct_long: &LweCiphertext, keys: &ServerKeys) -> LweCiphertext {
        keys.ksk.keyswitch(ct_long, &self.params)
    }

    /// Primitive entry point D: GLWE -> long LWE extraction (LPU).
    pub fn sample_extract(&self, acc: &GlweCiphertext) -> LweCiphertext {
        acc.sample_extract(&self.params)
    }

    /// Full PBS: the keyswitch-first composition of the primitive entry
    /// points (A keyswitch, B+C blind rotation, D sample extract).
    pub fn pbs(&mut self, ct_long: &LweCiphertext, keys: &ServerKeys, lut_poly: &[u64]) -> LweCiphertext {
        let short = self.keyswitch(ct_long, keys);
        let acc = self.blind_rotate(&short, &keys.bsk, lut_poly);
        self.sample_extract(&acc)
    }

    /// Batched PBS over one shared LUT: keyswitch each ciphertext, then run
    /// a single fused blind-rotation sweep with the BSK streamed once per
    /// batch, then sample-extract. Decrypts identically to calling
    /// [`Self::pbs`] per ciphertext.
    pub fn pbs_batch(
        &mut self,
        cts: &[LweCiphertext],
        keys: &ServerKeys,
        lut_poly: &[u64],
    ) -> Vec<LweCiphertext> {
        let shorts: Vec<LweCiphertext> =
            cts.iter().map(|ct| self.keyswitch(ct, keys)).collect();
        let accs = self.blind_rotate_batch(&shorts, &keys.bsk, lut_poly);
        accs.iter().map(|acc| self.sample_extract(acc)).collect()
    }
}

/// Convenience client-side helpers for multi-bit messages at the long
/// dimension (fresh ciphertexts enter the pipeline long, §II-B).
pub fn encrypt_message(m: u64, sk: &SecretKeys, rng: &mut Rng) -> LweCiphertext {
    let enc = super::encoding::encode(m, &sk.params);
    LweCiphertext::encrypt(enc, sk.long_lwe(), sk.params.glwe_noise, rng)
}

pub fn decrypt_message(ct: &LweCiphertext, sk: &SecretKeys) -> u64 {
    super::encoding::decode(ct.decrypt_phase(sk.long_lwe()), &sk.params)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::TEST1;
    use crate::tfhe::encoding::make_lut_poly;

    fn setup() -> (SecretKeys, ServerKeys, PbsContext, Rng) {
        let mut rng = Rng::new(2024);
        let sk = SecretKeys::generate(&TEST1, &mut rng);
        let keys = ServerKeys::generate(&sk, &mut rng);
        (sk, keys, PbsContext::new(&TEST1), rng)
    }

    #[test]
    fn modswitch_values() {
        assert_eq!(modswitch(0, 512), 0);
        assert_eq!(modswitch(1u64 << 54, 512), 1);
        assert_eq!(modswitch((1u64 << 54) - 1, 512), 1);
        assert_eq!(modswitch(1u64 << 63, 512), 512);
        assert_eq!(modswitch(u64::MAX, 512), 0);
    }

    #[test]
    fn pbs_evaluates_identity_lut() {
        let (sk, keys, mut ctx, mut rng) = setup();
        let lut = make_lut_poly(&TEST1, |m| m);
        for m in 0..8 {
            let ct = encrypt_message(m, &sk, &mut rng);
            let out = ctx.pbs(&ct, &keys, &lut);
            assert_eq!(decrypt_message(&out, &sk), m, "m={m}");
        }
    }

    #[test]
    fn pbs_evaluates_nonlinear_luts() {
        let (sk, keys, mut ctx, mut rng) = setup();
        for (name, f) in [
            ("square", (|m: u64| (m * m + 1) % 16) as fn(u64) -> u64),
            ("relu", |m| m.saturating_sub(3)),
            ("xor5", |m| m ^ 5),
        ] {
            let lut = make_lut_poly(&TEST1, f);
            for m in 0..8 {
                let ct = encrypt_message(m, &sk, &mut rng);
                let out = ctx.pbs(&ct, &keys, &lut);
                assert_eq!(decrypt_message(&out, &sk), f(m) % 16, "{name} m={m}");
            }
        }
    }

    #[test]
    fn pbs_output_is_reusable_as_input() {
        // The whole point of bootstrapping: outputs feed further PBS.
        let (sk, keys, mut ctx, mut rng) = setup();
        let inc = make_lut_poly(&TEST1, |m| (m + 1) % 16);
        let mut ct = encrypt_message(2, &sk, &mut rng);
        for _ in 0..3 {
            ct = ctx.pbs(&ct, &keys, &inc);
        }
        assert_eq!(decrypt_message(&ct, &sk), 5);
    }

    #[test]
    fn pbs_after_linear_ops() {
        // hom-add two ciphertexts then LUT the sum (the multi-bit TFHE
        // program pattern of Fig. 2(b)).
        let (sk, keys, mut ctx, mut rng) = setup();
        let double = make_lut_poly(&TEST1, |m| (2 * m) % 16);
        let mut a = encrypt_message(3, &sk, &mut rng);
        let b = encrypt_message(2, &sk, &mut rng);
        a.add_assign(&b); // 5
        let out = ctx.pbs(&a, &keys, &double);
        assert_eq!(decrypt_message(&out, &sk), 10);
    }

    #[test]
    fn pbs_batch_identity_lut_and_key_reuse_accounting() {
        let (sk, keys, mut ctx, mut rng) = setup();
        let lut = make_lut_poly(&TEST1, |m| (m + 2) % 16);
        let msgs: Vec<u64> = (0..4).collect();
        let cts: Vec<_> = msgs.iter().map(|&m| encrypt_message(m, &sk, &mut rng)).collect();

        ctx.take_bsk_bytes_streamed();
        let outs = ctx.pbs_batch(&cts, &keys, &lut);
        let batch_bytes = ctx.take_bsk_bytes_streamed();
        for (m, out) in msgs.iter().zip(&outs) {
            assert_eq!(decrypt_message(out, &sk), (m + 2) % 16, "m={m}");
        }

        // Key reuse: the batch streams the BSK once (minus the rare
        // all-zero-rotation keys), while the sequential path streams it
        // once per ciphertext.
        let full = keys.bsk.bytes() as u64;
        assert!(batch_bytes <= full, "batch {batch_bytes} > full {full}");
        assert!(batch_bytes >= full / 2, "batch {batch_bytes} suspiciously small");
        for ct in &cts {
            ctx.pbs(ct, &keys, &lut);
        }
        let seq_bytes = ctx.take_bsk_bytes_streamed();
        assert!(
            seq_bytes >= 3 * batch_bytes,
            "sequential {seq_bytes} should stream ~{}x the batch's {batch_bytes}",
            cts.len()
        );
    }

    #[test]
    fn pbs_batch_empty_and_width_change() {
        let (sk, keys, mut ctx, mut rng) = setup();
        let lut = make_lut_poly(&TEST1, |m| m);
        assert!(ctx.pbs_batch(&[], &keys, &lut).is_empty());
        // Grow-only scratch: width 5 allocates, 2 and 3 reuse a prefix of
        // the wider buffers, 1 takes the scalar fast path.
        for width in [5usize, 2, 3, 1] {
            let msgs: Vec<u64> = (0..width as u64).map(|i| i % 8).collect();
            let cts: Vec<_> = msgs.iter().map(|&m| encrypt_message(m, &sk, &mut rng)).collect();
            let outs = ctx.pbs_batch(&cts, &keys, &lut);
            for (m, out) in msgs.iter().zip(&outs) {
                assert_eq!(decrypt_message(out, &sk), *m, "width={width} m={m}");
            }
        }
    }

    #[test]
    fn blind_rotate_batch_bitwise_invariant_across_thread_counts() {
        let (sk, keys, mut ctx, mut rng) = setup();
        let lut = make_lut_poly(&TEST1, |m| (3 * m + 1) % 16);
        let msgs: Vec<u64> = (0..5).map(|i| i % 8).collect();
        let cts: Vec<_> = msgs.iter().map(|&m| encrypt_message(m, &sk, &mut rng)).collect();
        let shorts: Vec<_> = cts.iter().map(|ct| ctx.keyswitch(ct, &keys)).collect();
        let base = ctx.blind_rotate_batch(&shorts, &keys.bsk, &lut);
        let base_bytes = ctx.take_bsk_bytes_streamed();
        for threads in [2usize, 4, 8] {
            let mut ctx_t = PbsContext::with_threads(&TEST1, threads);
            assert_eq!(ctx_t.fft_threads(), threads);
            let got = ctx_t.blind_rotate_batch(&shorts, &keys.bsk, &lut);
            assert_eq!(got, base, "threads={threads}: accumulator bits drifted");
            assert_eq!(
                ctx_t.take_bsk_bytes_streamed(),
                base_bytes,
                "threads={threads}: BSK accounting must not depend on chunking"
            );
        }
        // Reconfiguring an existing context is equivalent to building one.
        ctx.set_fft_threads(4);
        let got = ctx.blind_rotate_batch(&shorts, &keys.bsk, &lut);
        assert_eq!(got, base, "set_fft_threads(4) changed bits");
        ctx.set_fft_threads(1);
        let got = ctx.blind_rotate_batch(&shorts, &keys.bsk, &lut);
        assert_eq!(got, base, "set_fft_threads(1) changed bits");
        // Parallel contexts keep end-to-end semantics: full PBS decrypts.
        let mut ctx4 = PbsContext::with_threads(&TEST1, 4);
        let outs = ctx4.pbs_batch(&cts, &keys, &lut);
        for (m, out) in msgs.iter().zip(&outs) {
            assert_eq!(decrypt_message(out, &sk), (3 * m + 1) % 16, "m={m}");
        }
    }

    #[test]
    fn pbs_refreshes_noise() {
        let (sk, keys, mut ctx, mut rng) = setup();
        let id = make_lut_poly(&TEST1, |m| m);
        // Very noisy input (but still decodable).
        let enc = super::super::encoding::encode(4, &TEST1);
        let noisy = LweCiphertext::encrypt(enc, sk.long_lwe(), 2.0f64.powi(-14), &mut rng);
        let out = ctx.pbs(&noisy, &keys, &id);
        let ph = out.decrypt_phase(sk.long_lwe());
        let err = crate::tfhe::torus::torus_distance(ph, enc);
        assert!(err < 2.0f64.powi(-9), "post-PBS noise {err}");
    }
}
