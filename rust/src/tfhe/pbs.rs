//! Programmable bootstrapping — the full pipeline of the paper's Fig. 3,
//! in the key-switch-first order the paper adopts (§II-B):
//!
//!   long LWE --(A) keyswitch--> short LWE --(B) mod-switch-->
//!   --(C) blind rotation--> GLWE --(D) sample extract--> long LWE
//!
//! [`PbsContext`] owns the FFT plan and all scratch so a PBS allocates
//! nothing on the hot path.

use super::bsk::FourierBsk;
use super::fft::FftPlan;
use super::ggsw::{cmux_rotate, ExtProdScratch};
use super::glwe::GlweCiphertext;
use super::ksk::Ksk;
use super::lwe::LweCiphertext;
use super::poly::rotate_into;
use super::torus::SecretKeys;
use crate::params::ParamSet;
use crate::util::rng::Rng;

/// Server-side evaluation keys (the paper's `ek`): BSK + KSK.
pub struct ServerKeys {
    pub params: ParamSet,
    pub bsk: FourierBsk,
    pub ksk: Ksk,
}

impl ServerKeys {
    pub fn generate(sk: &SecretKeys, rng: &mut Rng) -> Self {
        let plan = FftPlan::new(sk.params.big_n);
        Self {
            params: sk.params.clone(),
            bsk: FourierBsk::generate(sk, rng, &plan),
            ksk: Ksk::generate(sk, rng),
        }
    }
}

/// Mod-switch a torus value to Z_{2N} with rounding.
#[inline]
pub fn modswitch(x: u64, big_n: usize) -> usize {
    let two_n = 2 * big_n;
    let shift = 64 - two_n.trailing_zeros();
    ((((x >> (shift - 1)) + 1) >> 1) as usize) % two_n
}

/// Execution context: FFT plan + scratch buffers, reusable across PBS
/// calls (one per worker thread).
pub struct PbsContext {
    pub params: ParamSet,
    pub plan: FftPlan,
    scratch: ExtProdScratch,
    rot_buf: Vec<u64>,
}

impl PbsContext {
    pub fn new(params: &ParamSet) -> Self {
        Self {
            params: params.clone(),
            plan: FftPlan::new(params.big_n),
            scratch: ExtProdScratch::new(params),
            rot_buf: vec![0; params.big_n],
        }
    }

    /// Blind rotation (paper Fig. 3 (c)): returns the rotated accumulator.
    pub fn blind_rotate(
        &mut self,
        ct_short: &LweCiphertext,
        bsk: &FourierBsk,
        lut_poly: &[u64],
    ) -> GlweCiphertext {
        let p = self.params.clone();
        debug_assert_eq!(ct_short.dim(), p.n);
        let two_n = 2 * p.big_n;
        let b = modswitch(ct_short.body(), p.big_n);
        let mut acc = GlweCiphertext::zero(p.k, p.big_n);
        rotate_into(lut_poly, two_n - b, &mut self.rot_buf);
        acc.body_mut().copy_from_slice(&self.rot_buf);
        for (i, &a) in ct_short.mask().iter().enumerate() {
            let a_i = modswitch(a, p.big_n);
            if a_i != 0 {
                cmux_rotate(&self.plan, &p, &bsk.ggsw[i], a_i, &mut acc, &mut self.scratch);
            }
        }
        acc
    }

    /// Full PBS: keyswitch-first order, LUT evaluation + noise refresh.
    pub fn pbs(&mut self, ct_long: &LweCiphertext, keys: &ServerKeys, lut_poly: &[u64]) -> LweCiphertext {
        let short = keys.ksk.keyswitch(ct_long, &self.params);
        let acc = self.blind_rotate(&short, &keys.bsk, lut_poly);
        acc.sample_extract(&self.params)
    }
}

/// Convenience client-side helpers for multi-bit messages at the long
/// dimension (fresh ciphertexts enter the pipeline long, §II-B).
pub fn encrypt_message(m: u64, sk: &SecretKeys, rng: &mut Rng) -> LweCiphertext {
    let enc = super::encoding::encode(m, &sk.params);
    LweCiphertext::encrypt(enc, sk.long_lwe(), sk.params.glwe_noise, rng)
}

pub fn decrypt_message(ct: &LweCiphertext, sk: &SecretKeys) -> u64 {
    super::encoding::decode(ct.decrypt_phase(sk.long_lwe()), &sk.params)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::TEST1;
    use crate::tfhe::encoding::make_lut_poly;

    fn setup() -> (SecretKeys, ServerKeys, PbsContext, Rng) {
        let mut rng = Rng::new(2024);
        let sk = SecretKeys::generate(&TEST1, &mut rng);
        let keys = ServerKeys::generate(&sk, &mut rng);
        (sk, keys, PbsContext::new(&TEST1), rng)
    }

    #[test]
    fn modswitch_values() {
        assert_eq!(modswitch(0, 512), 0);
        assert_eq!(modswitch(1u64 << 54, 512), 1);
        assert_eq!(modswitch((1u64 << 54) - 1, 512), 1);
        assert_eq!(modswitch(1u64 << 63, 512), 512);
        assert_eq!(modswitch(u64::MAX, 512), 0);
    }

    #[test]
    fn pbs_evaluates_identity_lut() {
        let (sk, keys, mut ctx, mut rng) = setup();
        let lut = make_lut_poly(&TEST1, |m| m);
        for m in 0..8 {
            let ct = encrypt_message(m, &sk, &mut rng);
            let out = ctx.pbs(&ct, &keys, &lut);
            assert_eq!(decrypt_message(&out, &sk), m, "m={m}");
        }
    }

    #[test]
    fn pbs_evaluates_nonlinear_luts() {
        let (sk, keys, mut ctx, mut rng) = setup();
        for (name, f) in [
            ("square", (|m: u64| (m * m + 1) % 16) as fn(u64) -> u64),
            ("relu", |m| m.saturating_sub(3)),
            ("xor5", |m| m ^ 5),
        ] {
            let lut = make_lut_poly(&TEST1, f);
            for m in 0..8 {
                let ct = encrypt_message(m, &sk, &mut rng);
                let out = ctx.pbs(&ct, &keys, &lut);
                assert_eq!(decrypt_message(&out, &sk), f(m) % 16, "{name} m={m}");
            }
        }
    }

    #[test]
    fn pbs_output_is_reusable_as_input() {
        // The whole point of bootstrapping: outputs feed further PBS.
        let (sk, keys, mut ctx, mut rng) = setup();
        let inc = make_lut_poly(&TEST1, |m| (m + 1) % 16);
        let mut ct = encrypt_message(2, &sk, &mut rng);
        for _ in 0..3 {
            ct = ctx.pbs(&ct, &keys, &inc);
        }
        assert_eq!(decrypt_message(&ct, &sk), 5);
    }

    #[test]
    fn pbs_after_linear_ops() {
        // hom-add two ciphertexts then LUT the sum (the multi-bit TFHE
        // program pattern of Fig. 2(b)).
        let (sk, keys, mut ctx, mut rng) = setup();
        let double = make_lut_poly(&TEST1, |m| (2 * m) % 16);
        let mut a = encrypt_message(3, &sk, &mut rng);
        let b = encrypt_message(2, &sk, &mut rng);
        a.add_assign(&b); // 5
        let out = ctx.pbs(&a, &keys, &double);
        assert_eq!(decrypt_message(&out, &sk), 10);
    }

    #[test]
    fn pbs_refreshes_noise() {
        let (sk, keys, mut ctx, mut rng) = setup();
        let id = make_lut_poly(&TEST1, |m| m);
        // Very noisy input (but still decodable).
        let enc = super::super::encoding::encode(4, &TEST1);
        let noisy = LweCiphertext::encrypt(enc, sk.long_lwe(), 2.0f64.powi(-14), &mut rng);
        let out = ctx.pbs(&noisy, &keys, &id);
        let ph = out.decrypt_phase(sk.long_lwe());
        let err = crate::tfhe::torus::torus_distance(ph, enc);
        assert!(err < 2.0f64.powi(-9), "post-PBS noise {err}");
    }
}
