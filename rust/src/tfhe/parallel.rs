//! Persistent worker pool for data-parallel blind rotation.
//!
//! `std::thread` + `mpsc` only (no external crates), following the
//! bit-invariant split pattern of `tfhe::keygen`: the *partitioning* of
//! work across threads is never allowed to change computed bits, so the
//! pool is a pure scheduler. Workers live as long as the pool (one
//! thread spawn per `PbsContext`, not per batch) and pull jobs from a
//! shared channel.
//!
//! ## Join protocol (chaos-safe)
//!
//! [`WorkerPool::run`] wraps every job in `catch_unwind` and sends an
//! ack on a per-dispatch channel *unconditionally* — success or panic —
//! then the dispatcher blocks for exactly one ack per job and re-raises
//! the first captured panic. A job that panics or stalls therefore can
//! never deadlock the column join: delays (e.g. `serve --chaos` latency
//! spikes, which fire in `FaultyBackend` *before* the batch is
//! dispatched to the pool) only stretch the join, and panics surface on
//! the calling thread where the coordinator's existing supervision
//! handles them.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, PoisonError};
use std::thread::JoinHandle;

/// A borrowed job: the pool guarantees it finishes before `run` returns,
/// which is what makes the non-`'static` borrow sound.
pub type Job<'scope> = Box<dyn FnOnce() + Send + 'scope>;

type Task = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size pool of persistent worker threads.
pub struct WorkerPool {
    tx: Option<Sender<Task>>,
    handles: Vec<JoinHandle<()>>,
    threads: usize,
}

impl WorkerPool {
    /// Spawn `threads` workers (at least 1) sharing one job queue.
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let (tx, rx) = channel::<Task>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("fft-worker-{i}"))
                    .spawn(move || loop {
                        // Hold the receiver lock only while dequeuing so
                        // workers drain the queue concurrently.
                        let task = {
                            let guard = rx.lock().unwrap_or_else(PoisonError::into_inner);
                            guard.recv()
                        };
                        match task {
                            Ok(task) => task(),
                            Err(_) => break, // pool dropped
                        }
                    })
                    .expect("spawn fft worker")
            })
            .collect();
        Self { tx: Some(tx), handles, threads }
    }

    /// Worker count this pool was built with.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `jobs` to completion on the pool, blocking the caller until
    /// every job has finished. Jobs may borrow from the caller's stack
    /// (disjoint `&mut` chunks, shared keys): the blocking join is what
    /// makes that sound. If any job panicked, the first captured panic is
    /// re-raised here — after all jobs have completed, so no borrow ever
    /// outlives its data.
    pub fn run<'scope>(&self, jobs: Vec<Job<'scope>>) {
        let n = jobs.len();
        let (ack_tx, ack_rx) = channel::<std::thread::Result<()>>();
        let tx = self.tx.as_ref().expect("pool channel alive until drop");
        for job in jobs {
            // SAFETY: the transmute only erases the `'scope` borrow. The
            // job is queued, executed exactly once, and acked before this
            // function returns (the ack is sent even if the job panics),
            // and `run` does not return until all `n` acks arrive — so
            // every borrow the job carries strictly outlives its use.
            let job: Task = unsafe {
                std::mem::transmute::<Job<'scope>, Task>(job)
            };
            let ack = ack_tx.clone();
            tx.send(Box::new(move || {
                let result = catch_unwind(AssertUnwindSafe(job));
                let _ = ack.send(result);
            }))
            .expect("worker pool alive");
        }
        drop(ack_tx);
        let mut first_panic = None;
        for _ in 0..n {
            match ack_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(p)) => {
                    if first_panic.is_none() {
                        first_panic = Some(p);
                    }
                }
                // Acks are sent unconditionally; the senders can only all
                // drop if every worker thread exited, which cannot happen
                // while the pool is borrowed here.
                Err(_) => panic!("worker pool died mid-dispatch"),
            }
        }
        if let Some(p) = first_panic {
            resume_unwind(p);
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Closing the channel breaks every worker's recv loop.
        drop(self.tx.take());
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_borrowed_jobs_on_disjoint_chunks() {
        let pool = WorkerPool::new(4);
        assert_eq!(pool.threads(), 4);
        let mut data = vec![0u64; 64];
        let mut rest: &mut [u64] = &mut data;
        let mut jobs: Vec<Job> = Vec::new();
        let mut c = 0u64;
        while !rest.is_empty() {
            let (chunk, r) = std::mem::take(&mut rest).split_at_mut(16);
            rest = r;
            let tag = c;
            jobs.push(Box::new(move || {
                for (i, x) in chunk.iter_mut().enumerate() {
                    *x = tag * 1000 + i as u64;
                }
            }));
            c += 1;
        }
        pool.run(jobs);
        for (i, &x) in data.iter().enumerate() {
            assert_eq!(x, (i as u64 / 16) * 1000 + (i as u64 % 16));
        }
    }

    #[test]
    fn empty_dispatch_and_reuse() {
        let pool = WorkerPool::new(2);
        pool.run(Vec::new());
        let hits = AtomicUsize::new(0);
        for _ in 0..3 {
            let jobs: Vec<Job> = (0..5)
                .map(|_| {
                    Box::new(|| {
                        hits.fetch_add(1, Ordering::SeqCst);
                    }) as Job
                })
                .collect();
            pool.run(jobs);
        }
        assert_eq!(hits.load(Ordering::SeqCst), 15);
    }

    #[test]
    fn panicked_job_propagates_without_deadlock_and_pool_survives() {
        let pool = WorkerPool::new(2);
        let done = AtomicUsize::new(0);
        let jobs: Vec<Job> = (0..4)
            .map(|i| {
                let done = &done;
                Box::new(move || {
                    if i == 1 {
                        panic!("injected");
                    }
                    done.fetch_add(1, Ordering::SeqCst);
                }) as Job
            })
            .collect();
        let err = catch_unwind(AssertUnwindSafe(|| pool.run(jobs)));
        assert!(err.is_err(), "panic must re-raise on the dispatcher");
        // All non-panicking jobs still ran to completion before the join
        // released (no torn batches), and the pool remains usable.
        assert_eq!(done.load(Ordering::SeqCst), 3);
        let jobs: Vec<Job> = vec![Box::new(|| {
            done.fetch_add(10, Ordering::SeqCst);
        })];
        pool.run(jobs);
        assert_eq!(done.load(Ordering::SeqCst), 13);
    }
}
