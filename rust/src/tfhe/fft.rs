//! Negacyclic FFT over the torus — the compute hot-spot of the whole
//! library (every external product runs d(k+1) forward and k+1 inverse
//! transforms).
//!
//! Representation is the paper's "double-real" form (§IV-C): a degree-N
//! real polynomial is packed into an N/2-point complex vector
//! z_j = (p_j - i p_{j+N/2}) * twist_j with twist_j = exp(-i*pi*j/N); an
//! N/2-point complex FFT then evaluates P at the primitive 2N-th roots
//! zeta^(4k+1). Pointwise products in this domain are exact negacyclic
//! products (conjugate symmetry covers the other half of the roots).
//!
//! The hot-path transform is a no-permutation DIF/DIT pair: the forward
//! fused-radix-2^2 DIF leaves the Fourier domain bit-reversed (pointwise
//! products don't care), the inverse DIT consumes that order and emits
//! natural order — no bit-reversal pass ever runs on the request path,
//! and per-stage twiddles are stored contiguously. A classic natural-
//! order `fft_inplace`/`ifft_inplace` pair is kept for tests and key
//! export. See EXPERIMENTS.md §Perf for the measured iteration log.
//!
//! Above a plan-time size threshold ([`BLOCKED_NH_MIN`]) the same
//! butterfly network is *rescheduled* into a cache-blocked two-pass form
//! (strided residue-class tiles, then contiguous L1-sized blocks) so the
//! WIDE8/WIDE10 working sets stop thrashing L2. Blocking only reorders
//! independent butterflies — outputs are bitwise identical to the
//! monolithic sweep, which the property tests pin exactly. See
//! EXPERIMENTS.md §FFT.

/// Minimal complex type (num-complex is not in the offline registry).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct C64 {
    pub re: f64,
    pub im: f64,
}

impl C64 {
    #[inline(always)]
    pub fn new(re: f64, im: f64) -> Self {
        Self { re, im }
    }

    #[inline(always)]
    pub fn conj(self) -> Self {
        Self { re: self.re, im: -self.im }
    }

    #[inline(always)]
    pub fn mul(self, o: Self) -> Self {
        Self { re: self.re * o.re - self.im * o.im, im: self.re * o.im + self.im * o.re }
    }

    #[inline(always)]
    pub fn add(self, o: Self) -> Self {
        Self { re: self.re + o.re, im: self.im + o.im }
    }

    #[inline(always)]
    pub fn sub(self, o: Self) -> Self {
        Self { re: self.re - o.re, im: self.im - o.im }
    }

    #[inline(always)]
    pub fn scale(self, s: f64) -> Self {
        Self { re: self.re * s, im: self.im * s }
    }

    /// Multiply by -i (used by radix-4 butterflies).
    #[inline(always)]
    pub fn mul_neg_i(self) -> Self {
        Self { re: self.im, im: -self.re }
    }
}

/// Complex lengths at or above this take the cache-blocked two-pass
/// schedule on the hot-path transforms: WIDE8 (N=16384, nh=8192) and
/// WIDE10 (N=32768, nh=16384) block; TEST1/TEST2 stay monolithic (their
/// whole working set already fits in L2). See EXPERIMENTS.md §FFT for
/// the working-set arithmetic behind the threshold.
pub const BLOCKED_NH_MIN: usize = 8192;

/// Pass-2 block length cap: blocks of `<= BLOCK_B_MAX` complex points
/// (16 bytes each) occupy at most 32 KiB — half a typical L1d.
const BLOCK_B_MAX: usize = 2048;

/// Pass-1 tile working-set target in bytes (about a quarter of a
/// 256 KiB L2, leaving room for twiddles and the streamed key row).
const BLOCK_TILE_BYTES: usize = 64 * 1024;

/// Whether plans of this polynomial degree select the blocked schedule
/// (usable without building a plan, e.g. for metrics reporting).
pub fn blocked_for_poly(poly_n: usize) -> bool {
    poly_n / 2 >= BLOCKED_NH_MIN
}

/// Precomputed plan for polynomials of degree `poly_n` (complex size
/// `poly_n / 2`). Plans are cheap to build relative to keygen; callers
/// share one per polynomial size via [`plan_for`] (or cache their own,
/// see `PbsContext`).
pub struct FftPlan {
    /// Complex transform length N/2.
    pub nh: usize,
    log2_nh: u32,
    bitrev: Vec<u32>,
    /// Forward roots w^t = exp(-2*pi*i*t/nh), t < nh/2.
    w: Vec<C64>,
    /// Per-fused-stage sequential twiddles [w1_j, w2_j, w3_j] for the
    /// radix-2^2 DIF kernel (contiguous loads instead of 3 strided ones).
    w_stages: Vec<Vec<C64>>,
    /// Folding twist exp(-i*pi*j/N), j < nh.
    twist: Vec<C64>,
    /// Hot-path transforms dispatch to the blocked two-pass schedule.
    blocked: bool,
    /// Fused radix-2^2 stages run in the strided pass (pass 1) of the
    /// blocked schedule; 0 when the size is too small to split.
    block_s1: usize,
    /// Independent contiguous block length after `block_s1` fused
    /// stages: nh / 4^block_s1.
    block_b: usize,
}

impl FftPlan {
    pub fn new(poly_n: usize) -> Self {
        assert!(poly_n.is_power_of_two() && poly_n >= 4);
        let nh = poly_n / 2;
        let log2_nh = nh.trailing_zeros();
        let mut bitrev = vec![0u32; nh];
        for i in 0..nh {
            bitrev[i] = (i as u32).reverse_bits() >> (32 - log2_nh);
        }
        // Blocked-schedule split: peel fused radix-2^2 stages until the
        // residual contiguous blocks fit comfortably in L1. Small sizes
        // that never auto-block still get a genuine two-pass split so the
        // explicit `*_blocked` entry points are exercised at test sizes.
        let fused = (log2_nh / 2) as usize;
        let mut block_s1 = 0usize;
        let mut block_b = nh;
        while block_b > BLOCK_B_MAX && block_s1 < fused {
            block_b /= 4;
            block_s1 += 1;
        }
        if block_s1 == 0 && fused >= 2 {
            block_s1 = 1;
            block_b = nh / 4;
        }
        let blocked = nh >= BLOCKED_NH_MIN && block_s1 >= 1;
        let w = (0..nh / 2)
            .map(|t| {
                let ang = -2.0 * std::f64::consts::PI * t as f64 / nh as f64;
                C64::new(ang.cos(), ang.sin())
            })
            .collect();
        let twist = (0..nh)
            .map(|j| {
                let ang = -std::f64::consts::PI * j as f64 / poly_n as f64;
                C64::new(ang.cos(), ang.sin())
            })
            .collect();
        let w: Vec<C64> = w;
        let mut w_stages = Vec::new();
        let mut len = nh;
        while len >= 4 {
            let q = len / 4;
            let step = nh / len;
            let mut tw = Vec::with_capacity(3 * q);
            for j in 0..q {
                let w1 = w[j * step];
                let w2 = w[2 * j * step];
                tw.push(w1);
                tw.push(w2);
                tw.push(w1.mul(w2));
            }
            w_stages.push(tw);
            len = q;
        }
        // The fused radix-2^2 DIF consumes two radix-2 stages per pass:
        // exactly floor(log2(nh) / 2) fused stages, with one trailing
        // radix-2 stage iff log2(nh) is odd.
        assert_eq!(w_stages.len() as u32, log2_nh / 2);
        Self { nh, log2_nh, bitrev, w, w_stages, twist, blocked, block_s1, block_b }
    }

    /// Whether the hot-path transforms of this plan run the cache-blocked
    /// two-pass schedule (plan-time threshold on `nh`).
    pub fn blocked(&self) -> bool {
        self.blocked
    }

    /// Contiguous block length of the blocked schedule's second pass.
    pub fn block_len(&self) -> usize {
        self.block_b
    }

    /// Pass-1 tile width (residue classes swept together): sized so the
    /// tile's working set — `nh / block_b` groups of `tile` adjacent
    /// points, times `cols` interleaved columns — stays near
    /// [`BLOCK_TILE_BYTES`]. The tile width only reorders independent
    /// butterflies, so any value is bitwise-safe.
    fn pass1_tile(&self, cols: usize) -> usize {
        let rows = self.nh / self.block_b;
        let bytes_per_residue = rows * 16 * cols.max(1);
        (BLOCK_TILE_BYTES / bytes_per_residue.max(1)).clamp(1, self.block_b)
    }

    /// In-place forward complex FFT (DIT, natural order in/out).
    pub fn fft_inplace(&self, buf: &mut [C64]) {
        debug_assert_eq!(buf.len(), self.nh);
        // Bit-reverse permutation.
        for i in 0..self.nh {
            let j = self.bitrev[i] as usize;
            if i < j {
                buf.swap(i, j);
            }
        }
        let mut len = 2usize;
        while len <= self.nh {
            let half = len / 2;
            let step = self.nh / len;
            let mut base = 0;
            while base < self.nh {
                for j in 0..half {
                    let w = self.w[j * step];
                    let u = buf[base + j];
                    let v = buf[base + j + half].mul(w);
                    buf[base + j] = u.add(v);
                    buf[base + j + half] = u.sub(v);
                }
                base += len;
            }
            len <<= 1;
        }
    }

    /// In-place inverse complex FFT (includes the 1/nh scale).
    pub fn ifft_inplace(&self, buf: &mut [C64]) {
        for z in buf.iter_mut() {
            *z = z.conj();
        }
        self.fft_inplace(buf);
        let s = 1.0 / self.nh as f64;
        for z in buf.iter_mut() {
            *z = z.conj().scale(s);
        }
    }

    /// Forward DIF FFT: natural input -> **bit-reversed** output, no
    /// permutation pass. The TFHE pipeline only multiplies pointwise in
    /// the Fourier domain, so a consistent permutation is free speed
    /// (§Perf change 2); `bitrev_permute_copy` converts when natural
    /// order is needed (e.g. exporting the BSK to the XLA artifacts).
    ///
    /// Dispatches to the cache-blocked schedule above the plan-time
    /// threshold; both schedules run the identical butterfly network in
    /// the identical per-point order, so the choice is bitwise-invisible.
    pub fn dif_forward(&self, buf: &mut [C64]) {
        let t0 = crate::obs::timer();
        if self.blocked {
            self.dif_forward_blocked(buf);
        } else {
            self.dif_forward_monolithic(buf);
        }
        crate::obs::record_fft(t0);
    }

    /// The classic single-sweep DIF schedule: each fused stage walks the
    /// whole array before the next begins. Optimal while `nh * 16` bytes
    /// fit in L2; at WIDE8/WIDE10 every stage re-streams the array from
    /// L3/DRAM, which is what [`Self::dif_forward_blocked`] fixes.
    pub fn dif_forward_monolithic(&self, buf: &mut [C64]) {
        debug_assert_eq!(buf.len(), self.nh);
        debug_assert_eq!(self.w_stages.len() as u32, self.log2_nh / 2);
        let mut len = self.nh;
        // Fused radix-2^2 stages: identical ordering to two radix-2 DIF
        // passes, but one pass over memory and 3 twiddle mults per 4
        // points instead of 4 (§Perf change 3).
        let mut stage = 0;
        while len >= 4 {
            let q = len / 4;
            let tw = &self.w_stages[stage];
            stage += 1;
            let mut base = 0;
            while base < self.nh {
                for j in 0..q {
                    let w1 = tw[3 * j];
                    let w2 = tw[3 * j + 1];
                    let w3 = tw[3 * j + 2];
                    let a = buf[base + j];
                    let b = buf[base + j + q];
                    let c = buf[base + j + 2 * q];
                    let d = buf[base + j + 3 * q];
                    let t1 = a.add(c);
                    let t2 = b.add(d);
                    let t3 = a.sub(c);
                    let t4 = b.sub(d).mul_neg_i();
                    buf[base + j] = t1.add(t2);
                    buf[base + j + q] = t1.sub(t2).mul(w2);
                    buf[base + j + 2 * q] = t3.add(t4).mul(w1);
                    buf[base + j + 3 * q] = t3.sub(t4).mul(w3);
                }
                base += len;
            }
            len = q;
        }
        if len == 2 {
            // Final radix-2 stage for odd log2(nh); w^0 = 1, no mults.
            let mut base = 0;
            while base < self.nh {
                let a = buf[base];
                let b = buf[base + 1];
                buf[base] = a.add(b);
                buf[base + 1] = a.sub(b);
                base += 2;
            }
        }
    }

    /// Cache-blocked forward DIF — the same butterfly network as
    /// [`Self::dif_forward_monolithic`], rescheduled in two passes:
    ///
    /// * **Pass 1** runs the first `block_s1` fused stages over tiles of
    ///   index-residue classes mod `block_b`. In those stages every
    ///   butterfly's four indices share one residue (partner distances
    ///   and bases are multiples of `block_b`), so residue classes are
    ///   dependency-closed and a tile's working set is
    ///   `(nh / block_b) * tile * 16` bytes instead of `nh * 16`.
    /// * **Pass 2** finishes each contiguous `block_b`-length block
    ///   (remaining fused stages + the trailing radix-2) while it sits in
    ///   L1/L2.
    ///
    /// Within any DIF stage butterflies are independent (each point is
    /// read and written by exactly one butterfly), and the reschedule
    /// preserves the stage order seen by every index, so the float ops —
    /// and therefore the output bits — are identical to the monolithic
    /// sweep. Tests pin this bitwise.
    pub fn dif_forward_blocked(&self, buf: &mut [C64]) {
        debug_assert_eq!(buf.len(), self.nh);
        let blk = self.block_b;
        let s1 = self.block_s1;
        if s1 > 0 {
            let tile = self.pass1_tile(1);
            let mut r0 = 0;
            while r0 < blk {
                let r1 = (r0 + tile).min(blk);
                let mut len = self.nh;
                for tw in self.w_stages.iter().take(s1) {
                    let q = len / 4;
                    let mut base = 0;
                    while base < self.nh {
                        let mut m = 0;
                        while m < q {
                            for j in m + r0..m + r1 {
                                let w1 = tw[3 * j];
                                let w2 = tw[3 * j + 1];
                                let w3 = tw[3 * j + 2];
                                let a = buf[base + j];
                                let b = buf[base + j + q];
                                let c = buf[base + j + 2 * q];
                                let d = buf[base + j + 3 * q];
                                let t1 = a.add(c);
                                let t2 = b.add(d);
                                let t3 = a.sub(c);
                                let t4 = b.sub(d).mul_neg_i();
                                buf[base + j] = t1.add(t2);
                                buf[base + j + q] = t1.sub(t2).mul(w2);
                                buf[base + j + 2 * q] = t3.add(t4).mul(w1);
                                buf[base + j + 3 * q] = t3.sub(t4).mul(w3);
                            }
                            m += blk;
                        }
                        base += len;
                    }
                    len = q;
                }
                r0 = r1;
            }
        }
        for g in 0..self.nh / blk {
            let lo = g * blk;
            let mut len = blk;
            let mut stage = s1;
            while len >= 4 {
                let q = len / 4;
                let tw = &self.w_stages[stage];
                stage += 1;
                let mut base = lo;
                while base < lo + blk {
                    for j in 0..q {
                        let w1 = tw[3 * j];
                        let w2 = tw[3 * j + 1];
                        let w3 = tw[3 * j + 2];
                        let a = buf[base + j];
                        let b = buf[base + j + q];
                        let c = buf[base + j + 2 * q];
                        let d = buf[base + j + 3 * q];
                        let t1 = a.add(c);
                        let t2 = b.add(d);
                        let t3 = a.sub(c);
                        let t4 = b.sub(d).mul_neg_i();
                        buf[base + j] = t1.add(t2);
                        buf[base + j + q] = t1.sub(t2).mul(w2);
                        buf[base + j + 2 * q] = t3.add(t4).mul(w1);
                        buf[base + j + 3 * q] = t3.sub(t4).mul(w3);
                    }
                    base += len;
                }
                len = q;
            }
            if len == 2 {
                let mut base = lo;
                while base < lo + blk {
                    let a = buf[base];
                    let b = buf[base + 1];
                    buf[base] = a.add(b);
                    buf[base + 1] = a.sub(b);
                    base += 2;
                }
            }
        }
    }

    /// Inverse DIT FFT: **bit-reversed** input -> natural output, with the
    /// 1/nh scale folded in. Dispatches like [`Self::dif_forward`].
    pub fn dit_inverse(&self, buf: &mut [C64]) {
        let t0 = crate::obs::timer();
        if self.blocked {
            self.dit_inverse_blocked(buf);
        } else {
            self.dit_inverse_monolithic(buf);
        }
        crate::obs::record_fft(t0);
    }

    /// Single-sweep inverse DIT (see [`Self::dif_forward_monolithic`] for
    /// the schedule trade-off).
    pub fn dit_inverse_monolithic(&self, buf: &mut [C64]) {
        debug_assert_eq!(buf.len(), self.nh);
        let mut len = 2usize;
        while len <= self.nh {
            let half = len / 2;
            let step = self.nh / len;
            let mut base = 0;
            while base < self.nh {
                let (lo, hi) = buf[base..base + len].split_at_mut(half);
                for (j, (u, v)) in lo.iter_mut().zip(hi.iter_mut()).enumerate() {
                    let w = self.w[j * step].conj();
                    let a = *u;
                    let b = v.mul(w);
                    *u = a.add(b);
                    *v = a.sub(b);
                }
                base += len;
            }
            len <<= 1;
        }
        let s = 1.0 / self.nh as f64;
        for z in buf.iter_mut() {
            *z = z.scale(s);
        }
    }

    /// Cache-blocked inverse DIT — the mirror of
    /// [`Self::dif_forward_blocked`]: pass A finishes every stage with
    /// `len <= block_b` inside each contiguous block; pass B runs the
    /// remaining strided stages (`len > block_b`, partner distances
    /// multiples of `block_b`) over residue-class tiles. Bitwise equal to
    /// [`Self::dit_inverse_monolithic`] by the same independence argument.
    pub fn dit_inverse_blocked(&self, buf: &mut [C64]) {
        debug_assert_eq!(buf.len(), self.nh);
        let blk = self.block_b;
        for g in 0..self.nh / blk {
            let lo = g * blk;
            let mut len = 2usize;
            while len <= blk {
                let half = len / 2;
                let step = self.nh / len;
                let mut base = lo;
                while base < lo + blk {
                    let (lo_h, hi_h) = buf[base..base + len].split_at_mut(half);
                    for (j, (u, v)) in lo_h.iter_mut().zip(hi_h.iter_mut()).enumerate() {
                        let w = self.w[j * step].conj();
                        let a = *u;
                        let b = v.mul(w);
                        *u = a.add(b);
                        *v = a.sub(b);
                    }
                    base += len;
                }
                len <<= 1;
            }
        }
        if blk < self.nh {
            let tile = self.pass1_tile(1);
            let mut r0 = 0;
            while r0 < blk {
                let r1 = (r0 + tile).min(blk);
                let mut len = 2 * blk;
                while len <= self.nh {
                    let half = len / 2;
                    let step = self.nh / len;
                    let mut base = 0;
                    while base < self.nh {
                        let mut m = 0;
                        while m < half {
                            for j in m + r0..m + r1 {
                                let w = self.w[j * step].conj();
                                let a = buf[base + j];
                                let b = buf[base + j + half].mul(w);
                                buf[base + j] = a.add(b);
                                buf[base + j + half] = a.sub(b);
                            }
                            m += blk;
                        }
                        base += len;
                    }
                    len <<= 1;
                }
                r0 = r1;
            }
        }
        let s = 1.0 / self.nh as f64;
        for z in buf.iter_mut() {
            *z = z.scale(s);
        }
    }

    /// Forward negacyclic transform: signed coefficients (len N) -> Fourier
    /// domain (len N/2).
    pub fn forward_negacyclic(&self, p: &[f64], out: &mut [C64]) {
        debug_assert_eq!(p.len(), 2 * self.nh);
        debug_assert_eq!(out.len(), self.nh);
        for j in 0..self.nh {
            out[j] = C64::new(p[j], -p[j + self.nh]).mul(self.twist[j]);
        }
        self.dif_forward(out);
    }

    /// Forward transform straight from torus values (reinterpreted signed).
    pub fn forward_negacyclic_torus(&self, p: &[u64], out: &mut [C64]) {
        debug_assert_eq!(p.len(), 2 * self.nh);
        for j in 0..self.nh {
            let re = p[j] as i64 as f64;
            let im = -(p[j + self.nh] as i64 as f64);
            out[j] = C64::new(re, im).mul(self.twist[j]);
        }
        self.dif_forward(out);
    }

    /// Forward transform from i64 gadget digits.
    pub fn forward_negacyclic_i64(&self, p: &[i64], out: &mut [C64]) {
        debug_assert_eq!(p.len(), 2 * self.nh);
        for j in 0..self.nh {
            out[j] = C64::new(p[j] as f64, -(p[j + self.nh] as f64)).mul(self.twist[j]);
        }
        self.dif_forward(out);
    }

    /// Inverse negacyclic transform into torus values (rounded mod 2^64),
    /// *adding* into `out` (the blind-rotation accumulator pattern).
    /// `scratch` must have length N/2; `z` is consumed.
    pub fn inverse_negacyclic_add_torus(&self, z: &mut [C64], out: &mut [u64]) {
        debug_assert_eq!(z.len(), self.nh);
        debug_assert_eq!(out.len(), 2 * self.nh);
        self.dit_inverse(z);
        const Q: f64 = 18446744073709551616.0; // 2^64
        const INV_Q: f64 = 1.0 / Q;
        for j in 0..self.nh {
            let zz = z[j].mul(self.twist[j].conj());
            let re = zz.re - (zz.re * INV_Q).round() * Q;
            let im = -zz.im;
            let im = im - (im * INV_Q).round() * Q;
            out[j] = out[j].wrapping_add(re.round_ties_even() as i64 as u64);
            out[j + self.nh] = out[j + self.nh].wrapping_add(im.round_ties_even() as i64 as u64);
        }
    }

    // ------------------------------------------------------------------
    // Planar (structure-of-arrays) multi-column kernels — §Perf change 4.
    //
    // A planar buffer holds `cols` ciphertexts' Fourier vectors in
    // separate `re[]`/`im[]` arrays with layout [bin][col] (col fastest):
    // every butterfly and MAC becomes a contiguous stride-1 loop over the
    // batch with all twiddles/key points hoisted to scalars, which is the
    // shape LLVM auto-vectorizes. Ordering conventions are identical to
    // the scalar `dif_forward`/`dit_inverse` pair (bit-reversed Fourier
    // domain, no permutation pass), so planar columns interoperate with
    // the same bit-reversed `FourierGgsw` rows.
    // ------------------------------------------------------------------

    /// Multi-column forward DIF: `cols` interleaved columns, natural input
    /// -> bit-reversed output. `re`/`im` have length `nh * cols`, layout
    /// [bin][col]. Per-column arithmetic is op-for-op identical to
    /// [`Self::dif_forward`]. Dispatches like the scalar entry point.
    pub fn dif_forward_planar(&self, re: &mut [f64], im: &mut [f64], cols: usize) {
        let t0 = crate::obs::timer();
        if self.blocked {
            self.dif_forward_planar_blocked(re, im, cols);
        } else {
            self.dif_forward_planar_monolithic(re, im, cols);
        }
        crate::obs::record_fft(t0);
    }

    /// Single-sweep planar DIF (see [`Self::dif_forward_monolithic`]).
    pub fn dif_forward_planar_monolithic(&self, re: &mut [f64], im: &mut [f64], cols: usize) {
        debug_assert_eq!(re.len(), self.nh * cols);
        debug_assert_eq!(im.len(), self.nh * cols);
        debug_assert_eq!(self.w_stages.len() as u32, self.log2_nh / 2);
        let mut len = self.nh;
        let mut stage = 0;
        while len >= 4 {
            let q = len / 4;
            let tw = &self.w_stages[stage];
            stage += 1;
            let mut base = 0;
            while base < self.nh {
                for j in 0..q {
                    let w1 = tw[3 * j];
                    let w2 = tw[3 * j + 1];
                    let w3 = tw[3 * j + 2];
                    let i0 = (base + j) * cols;
                    let i1 = (base + j + q) * cols;
                    let i2 = (base + j + 2 * q) * cols;
                    let i3 = (base + j + 3 * q) * cols;
                    for b in 0..cols {
                        let (ar, ai) = (re[i0 + b], im[i0 + b]);
                        let (br, bi) = (re[i1 + b], im[i1 + b]);
                        let (cr, ci) = (re[i2 + b], im[i2 + b]);
                        let (dr, di) = (re[i3 + b], im[i3 + b]);
                        let (t1r, t1i) = (ar + cr, ai + ci);
                        let (t2r, t2i) = (br + dr, bi + di);
                        let (t3r, t3i) = (ar - cr, ai - ci);
                        // (b - d) * -i
                        let (t4r, t4i) = (bi - di, -(br - dr));
                        re[i0 + b] = t1r + t2r;
                        im[i0 + b] = t1i + t2i;
                        let (xr, xi) = (t1r - t2r, t1i - t2i);
                        re[i1 + b] = xr * w2.re - xi * w2.im;
                        im[i1 + b] = xr * w2.im + xi * w2.re;
                        let (yr, yi) = (t3r + t4r, t3i + t4i);
                        re[i2 + b] = yr * w1.re - yi * w1.im;
                        im[i2 + b] = yr * w1.im + yi * w1.re;
                        let (zr, zi) = (t3r - t4r, t3i - t4i);
                        re[i3 + b] = zr * w3.re - zi * w3.im;
                        im[i3 + b] = zr * w3.im + zi * w3.re;
                    }
                }
                base += len;
            }
            len = q;
        }
        if len == 2 {
            let mut base = 0;
            while base < self.nh {
                let i0 = base * cols;
                let i1 = (base + 1) * cols;
                for b in 0..cols {
                    let (ar, ai) = (re[i0 + b], im[i0 + b]);
                    let (br, bi) = (re[i1 + b], im[i1 + b]);
                    re[i0 + b] = ar + br;
                    im[i0 + b] = ai + bi;
                    re[i1 + b] = ar - br;
                    im[i1 + b] = ai - bi;
                }
                base += 2;
            }
        }
    }

    /// Cache-blocked planar DIF: the schedule of
    /// [`Self::dif_forward_blocked`] with the planar butterfly bodies of
    /// [`Self::dif_forward_planar_monolithic`] — bitwise equal to it per
    /// column. The pass-1 tile narrows with `cols` since a planar
    /// residue's footprint is `cols` times wider.
    pub fn dif_forward_planar_blocked(&self, re: &mut [f64], im: &mut [f64], cols: usize) {
        debug_assert_eq!(re.len(), self.nh * cols);
        debug_assert_eq!(im.len(), self.nh * cols);
        let blk = self.block_b;
        let s1 = self.block_s1;
        if s1 > 0 {
            let tile = self.pass1_tile(cols);
            let mut r0 = 0;
            while r0 < blk {
                let r1 = (r0 + tile).min(blk);
                let mut len = self.nh;
                for tw in self.w_stages.iter().take(s1) {
                    let q = len / 4;
                    let mut base = 0;
                    while base < self.nh {
                        let mut m = 0;
                        while m < q {
                            for j in m + r0..m + r1 {
                                let w1 = tw[3 * j];
                                let w2 = tw[3 * j + 1];
                                let w3 = tw[3 * j + 2];
                                let i0 = (base + j) * cols;
                                let i1 = (base + j + q) * cols;
                                let i2 = (base + j + 2 * q) * cols;
                                let i3 = (base + j + 3 * q) * cols;
                                for b in 0..cols {
                                    let (ar, ai) = (re[i0 + b], im[i0 + b]);
                                    let (br, bi) = (re[i1 + b], im[i1 + b]);
                                    let (cr, ci) = (re[i2 + b], im[i2 + b]);
                                    let (dr, di) = (re[i3 + b], im[i3 + b]);
                                    let (t1r, t1i) = (ar + cr, ai + ci);
                                    let (t2r, t2i) = (br + dr, bi + di);
                                    let (t3r, t3i) = (ar - cr, ai - ci);
                                    // (b - d) * -i
                                    let (t4r, t4i) = (bi - di, -(br - dr));
                                    re[i0 + b] = t1r + t2r;
                                    im[i0 + b] = t1i + t2i;
                                    let (xr, xi) = (t1r - t2r, t1i - t2i);
                                    re[i1 + b] = xr * w2.re - xi * w2.im;
                                    im[i1 + b] = xr * w2.im + xi * w2.re;
                                    let (yr, yi) = (t3r + t4r, t3i + t4i);
                                    re[i2 + b] = yr * w1.re - yi * w1.im;
                                    im[i2 + b] = yr * w1.im + yi * w1.re;
                                    let (zr, zi) = (t3r - t4r, t3i - t4i);
                                    re[i3 + b] = zr * w3.re - zi * w3.im;
                                    im[i3 + b] = zr * w3.im + zi * w3.re;
                                }
                            }
                            m += blk;
                        }
                        base += len;
                    }
                    len = q;
                }
                r0 = r1;
            }
        }
        for g in 0..self.nh / blk {
            let lo = g * blk;
            let mut len = blk;
            let mut stage = s1;
            while len >= 4 {
                let q = len / 4;
                let tw = &self.w_stages[stage];
                stage += 1;
                let mut base = lo;
                while base < lo + blk {
                    for j in 0..q {
                        let w1 = tw[3 * j];
                        let w2 = tw[3 * j + 1];
                        let w3 = tw[3 * j + 2];
                        let i0 = (base + j) * cols;
                        let i1 = (base + j + q) * cols;
                        let i2 = (base + j + 2 * q) * cols;
                        let i3 = (base + j + 3 * q) * cols;
                        for b in 0..cols {
                            let (ar, ai) = (re[i0 + b], im[i0 + b]);
                            let (br, bi) = (re[i1 + b], im[i1 + b]);
                            let (cr, ci) = (re[i2 + b], im[i2 + b]);
                            let (dr, di) = (re[i3 + b], im[i3 + b]);
                            let (t1r, t1i) = (ar + cr, ai + ci);
                            let (t2r, t2i) = (br + dr, bi + di);
                            let (t3r, t3i) = (ar - cr, ai - ci);
                            // (b - d) * -i
                            let (t4r, t4i) = (bi - di, -(br - dr));
                            re[i0 + b] = t1r + t2r;
                            im[i0 + b] = t1i + t2i;
                            let (xr, xi) = (t1r - t2r, t1i - t2i);
                            re[i1 + b] = xr * w2.re - xi * w2.im;
                            im[i1 + b] = xr * w2.im + xi * w2.re;
                            let (yr, yi) = (t3r + t4r, t3i + t4i);
                            re[i2 + b] = yr * w1.re - yi * w1.im;
                            im[i2 + b] = yr * w1.im + yi * w1.re;
                            let (zr, zi) = (t3r - t4r, t3i - t4i);
                            re[i3 + b] = zr * w3.re - zi * w3.im;
                            im[i3 + b] = zr * w3.im + zi * w3.re;
                        }
                    }
                    base += len;
                }
                len = q;
            }
            if len == 2 {
                let mut base = lo;
                while base < lo + blk {
                    let i0 = base * cols;
                    let i1 = (base + 1) * cols;
                    for b in 0..cols {
                        let (ar, ai) = (re[i0 + b], im[i0 + b]);
                        let (br, bi) = (re[i1 + b], im[i1 + b]);
                        re[i0 + b] = ar + br;
                        im[i0 + b] = ai + bi;
                        re[i1 + b] = ar - br;
                        im[i1 + b] = ai - bi;
                    }
                    base += 2;
                }
            }
        }
    }

    /// Multi-column inverse DIT: bit-reversed input -> natural output,
    /// 1/nh scale folded in. Per-column arithmetic matches
    /// [`Self::dit_inverse`]. Dispatches like the scalar entry point.
    pub fn dit_inverse_planar(&self, re: &mut [f64], im: &mut [f64], cols: usize) {
        let t0 = crate::obs::timer();
        if self.blocked {
            self.dit_inverse_planar_blocked(re, im, cols);
        } else {
            self.dit_inverse_planar_monolithic(re, im, cols);
        }
        crate::obs::record_fft(t0);
    }

    /// Single-sweep planar DIT (see [`Self::dif_forward_monolithic`]).
    pub fn dit_inverse_planar_monolithic(&self, re: &mut [f64], im: &mut [f64], cols: usize) {
        debug_assert_eq!(re.len(), self.nh * cols);
        debug_assert_eq!(im.len(), self.nh * cols);
        let mut len = 2usize;
        while len <= self.nh {
            let half = len / 2;
            let step = self.nh / len;
            let mut base = 0;
            while base < self.nh {
                for j in 0..half {
                    let w = self.w[j * step];
                    let iu = (base + j) * cols;
                    let iv = (base + j + half) * cols;
                    for b in 0..cols {
                        let (ar, ai) = (re[iu + b], im[iu + b]);
                        let (vr, vi) = (re[iv + b], im[iv + b]);
                        // v * conj(w)
                        let br = vr * w.re + vi * w.im;
                        let bi = vi * w.re - vr * w.im;
                        re[iu + b] = ar + br;
                        im[iu + b] = ai + bi;
                        re[iv + b] = ar - br;
                        im[iv + b] = ai - bi;
                    }
                }
                base += len;
            }
            len <<= 1;
        }
        let s = 1.0 / self.nh as f64;
        for x in re.iter_mut() {
            *x *= s;
        }
        for x in im.iter_mut() {
            *x *= s;
        }
    }

    /// Cache-blocked planar DIT (schedule of [`Self::dit_inverse_blocked`],
    /// planar butterfly bodies) — bitwise equal to
    /// [`Self::dit_inverse_planar_monolithic`] per column.
    pub fn dit_inverse_planar_blocked(&self, re: &mut [f64], im: &mut [f64], cols: usize) {
        debug_assert_eq!(re.len(), self.nh * cols);
        debug_assert_eq!(im.len(), self.nh * cols);
        let blk = self.block_b;
        for g in 0..self.nh / blk {
            let lo = g * blk;
            let mut len = 2usize;
            while len <= blk {
                let half = len / 2;
                let step = self.nh / len;
                let mut base = lo;
                while base < lo + blk {
                    for j in 0..half {
                        let w = self.w[j * step];
                        let iu = (base + j) * cols;
                        let iv = (base + j + half) * cols;
                        for b in 0..cols {
                            let (ar, ai) = (re[iu + b], im[iu + b]);
                            let (vr, vi) = (re[iv + b], im[iv + b]);
                            // v * conj(w)
                            let br = vr * w.re + vi * w.im;
                            let bi = vi * w.re - vr * w.im;
                            re[iu + b] = ar + br;
                            im[iu + b] = ai + bi;
                            re[iv + b] = ar - br;
                            im[iv + b] = ai - bi;
                        }
                    }
                    base += len;
                }
                len <<= 1;
            }
        }
        if blk < self.nh {
            let tile = self.pass1_tile(cols);
            let mut r0 = 0;
            while r0 < blk {
                let r1 = (r0 + tile).min(blk);
                let mut len = 2 * blk;
                while len <= self.nh {
                    let half = len / 2;
                    let step = self.nh / len;
                    let mut base = 0;
                    while base < self.nh {
                        let mut m = 0;
                        while m < half {
                            for j in m + r0..m + r1 {
                                let w = self.w[j * step];
                                let iu = (base + j) * cols;
                                let iv = (base + j + half) * cols;
                                for b in 0..cols {
                                    let (ar, ai) = (re[iu + b], im[iu + b]);
                                    let (vr, vi) = (re[iv + b], im[iv + b]);
                                    // v * conj(w)
                                    let br = vr * w.re + vi * w.im;
                                    let bi = vi * w.re - vr * w.im;
                                    re[iu + b] = ar + br;
                                    im[iu + b] = ai + bi;
                                    re[iv + b] = ar - br;
                                    im[iv + b] = ai - bi;
                                }
                            }
                            m += blk;
                        }
                        base += len;
                    }
                    len <<= 1;
                }
                r0 = r1;
            }
        }
        let s = 1.0 / self.nh as f64;
        for x in re.iter_mut() {
            *x *= s;
        }
        for x in im.iter_mut() {
            *x *= s;
        }
    }

    /// Planar forward negacyclic transform from i64 gadget digits of
    /// `cols` ciphertexts. `p` has layout [coef][col] (length N * cols);
    /// `re`/`im` get the folded, twisted, transformed columns.
    pub fn forward_negacyclic_i64_planar(
        &self,
        p: &[i64],
        re: &mut [f64],
        im: &mut [f64],
        cols: usize,
    ) {
        debug_assert_eq!(p.len(), 2 * self.nh * cols);
        for h in 0..self.nh {
            let t = self.twist[h];
            let lo = h * cols;
            let hi = (h + self.nh) * cols;
            for b in 0..cols {
                let xr = p[lo + b] as f64;
                let xi = -(p[hi + b] as f64);
                re[lo + b] = xr * t.re - xi * t.im;
                im[lo + b] = xr * t.im + xi * t.re;
            }
        }
        self.dif_forward_planar(re, im, cols);
    }

    /// Planar inverse negacyclic transform to torus values: consumes the
    /// Fourier columns and writes rounded torus coefficients to `out`
    /// (layout [coef][col], length N * cols, **overwritten**, not added —
    /// callers scatter-add into their per-ciphertext accumulators).
    /// Per-column arithmetic matches [`Self::inverse_negacyclic_add_torus`].
    pub fn inverse_negacyclic_torus_planar(
        &self,
        re: &mut [f64],
        im: &mut [f64],
        cols: usize,
        out: &mut [u64],
    ) {
        debug_assert_eq!(re.len(), self.nh * cols);
        debug_assert_eq!(out.len(), 2 * self.nh * cols);
        self.dit_inverse_planar(re, im, cols);
        const Q: f64 = 18446744073709551616.0; // 2^64
        const INV_Q: f64 = 1.0 / Q;
        for h in 0..self.nh {
            let t = self.twist[h];
            let lo = h * cols;
            let hi = (h + self.nh) * cols;
            for b in 0..cols {
                let (zr, zi) = (re[lo + b], im[lo + b]);
                // z * conj(twist)
                let zzr = zr * t.re + zi * t.im;
                let zzi = zi * t.re - zr * t.im;
                let rr = zzr - (zzr * INV_Q).round() * Q;
                let ii = -zzi;
                let ii = ii - (ii * INV_Q).round() * Q;
                out[lo + b] = rr.round_ties_even() as i64 as u64;
                out[hi + b] = ii.round_ties_even() as i64 as u64;
            }
        }
    }

    /// Permute a bit-reversed Fourier vector to natural order using the
    /// plan's table precomputed at [`Self::new`] — no per-call index
    /// derivation and no allocation, unlike the free
    /// [`bitrev_permute_copy`] (kept for odd-length test inputs).
    pub fn bitrev_permute_into(&self, src: &[C64], out: &mut [C64]) {
        debug_assert_eq!(src.len(), self.nh);
        debug_assert_eq!(out.len(), self.nh);
        for (i, &v) in src.iter().enumerate() {
            out[self.bitrev[i] as usize] = v;
        }
    }

    /// Planar (f64) counterpart of [`Self::bitrev_permute_into`], applied
    /// to `re`/`im` planes independently.
    pub fn bitrev_permute_f64_into(&self, src: &[f64], out: &mut [f64]) {
        debug_assert_eq!(src.len(), self.nh);
        debug_assert_eq!(out.len(), self.nh);
        for (i, &v) in src.iter().enumerate() {
            out[self.bitrev[i] as usize] = v;
        }
    }
}

/// Process-wide plan registry: one shared [`FftPlan`] per polynomial
/// size, behind a `OnceLock` (mirroring `tfhe::keycache`). Worker
/// threads, per-tenant backend rebinds, and key export all get the same
/// immutable twiddle tables instead of re-deriving them per context.
pub fn plan_for(poly_n: usize) -> std::sync::Arc<FftPlan> {
    use std::collections::HashMap;
    use std::sync::{Arc, Mutex, OnceLock, PoisonError};
    static REGISTRY: OnceLock<Mutex<HashMap<usize, Arc<FftPlan>>>> = OnceLock::new();
    let reg = REGISTRY.get_or_init(|| Mutex::new(HashMap::new()));
    let mut map = reg.lock().unwrap_or_else(PoisonError::into_inner);
    map.entry(poly_n).or_insert_with(|| Arc::new(FftPlan::new(poly_n))).clone()
}

/// Permute a bit-reversed Fourier vector to natural order (copy). Used
/// when exporting Fourier keys to consumers that expect natural order
/// (the XLA artifacts use jnp.fft).
pub fn bitrev_permute_copy(src: &[C64]) -> Vec<C64> {
    let n = src.len();
    debug_assert!(n.is_power_of_two());
    let log = n.trailing_zeros();
    let mut out = vec![C64::default(); n];
    for (i, &v) in src.iter().enumerate() {
        out[(i as u32).reverse_bits() as usize >> (32 - log)] = v;
    }
    out
}

/// Permute one planar (f64) bit-reversed component to natural order —
/// the SoA counterpart of [`bitrev_permute_copy`], applied to `re` and
/// `im` planes independently.
pub fn bitrev_permute_f64(src: &[f64]) -> Vec<f64> {
    let n = src.len();
    debug_assert!(n.is_power_of_two());
    let log = n.trailing_zeros();
    let mut out = vec![0.0f64; n];
    for (i, &v) in src.iter().enumerate() {
        out[(i as u32).reverse_bits() as usize >> (32 - log)] = v;
    }
    out
}

/// O(N^2) schoolbook negacyclic multiplication (test oracle).
pub fn negacyclic_mul_naive(a: &[f64], b: &[f64]) -> Vec<f64> {
    let n = a.len();
    let mut out = vec![0.0; n];
    for i in 0..n {
        for j in 0..n {
            let k = i + j;
            if k < n {
                out[k] += a[i] * b[j];
            } else {
                out[k - n] -= a[i] * b[j];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_allclose, check};
    use crate::util::rng::Rng;

    fn fft_roundtrip(nh: usize, rng: &mut Rng) -> Result<(), String> {
        let plan = FftPlan::new(2 * nh);
        let orig: Vec<C64> = (0..nh)
            .map(|_| C64::new(rng.gaussian() * 100.0, rng.gaussian() * 100.0))
            .collect();
        let mut buf = orig.clone();
        plan.fft_inplace(&mut buf);
        plan.ifft_inplace(&mut buf);
        let got: Vec<f64> = buf.iter().flat_map(|c| [c.re, c.im]).collect();
        let exp: Vec<f64> = orig.iter().flat_map(|c| [c.re, c.im]).collect();
        assert_allclose(&got, &exp, 1e-8, 1e-9)
    }

    #[test]
    fn complex_fft_roundtrip() {
        check("fft_roundtrip", 10, |rng| {
            for log in [2usize, 4, 7, 9] {
                fft_roundtrip(1 << log, rng)?;
            }
            Ok(())
        });
    }

    #[test]
    fn fft_matches_dft_small() {
        // Direct O(n^2) DFT cross-check at n=8.
        let plan = FftPlan::new(16);
        let x: Vec<C64> = (0..8).map(|i| C64::new(i as f64, (2 * i) as f64)).collect();
        let mut buf = x.clone();
        plan.fft_inplace(&mut buf);
        for k in 0..8 {
            let mut acc = C64::default();
            for (j, xj) in x.iter().enumerate() {
                let ang = -2.0 * std::f64::consts::PI * (j * k) as f64 / 8.0;
                acc = acc.add(xj.mul(C64::new(ang.cos(), ang.sin())));
            }
            assert!((acc.re - buf[k].re).abs() < 1e-9, "k={k}");
            assert!((acc.im - buf[k].im).abs() < 1e-9, "k={k}");
        }
    }

    #[test]
    fn negacyclic_convolution_matches_naive() {
        check("negacyclic_conv", 8, |rng| {
            let n = 64;
            let plan = FftPlan::new(n);
            let a: Vec<f64> = (0..n).map(|_| (rng.below(200) as f64) - 100.0).collect();
            let b: Vec<f64> = (0..n).map(|_| (rng.below(200) as f64) - 100.0).collect();
            let mut fa = vec![C64::default(); n / 2];
            let mut fb = vec![C64::default(); n / 2];
            plan.forward_negacyclic(&a, &mut fa);
            plan.forward_negacyclic(&b, &mut fb);
            for j in 0..n / 2 {
                fa[j] = fa[j].mul(fb[j]);
            }
            let mut out = vec![0u64; n];
            plan.inverse_negacyclic_add_torus(&mut fa, &mut out);
            let naive = negacyclic_mul_naive(&a, &b);
            let got: Vec<f64> = out.iter().map(|&x| x as i64 as f64).collect();
            assert_allclose(&got, &naive, 0.51, 0.0)
        });
    }

    #[test]
    fn torus_forward_matches_signed_reinterpretation() {
        let n = 32;
        let plan = FftPlan::new(n);
        let mut rng = Rng::new(4);
        let p: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
        let signed: Vec<f64> = p.iter().map(|&x| x as i64 as f64).collect();
        let mut f1 = vec![C64::default(); n / 2];
        let mut f2 = vec![C64::default(); n / 2];
        plan.forward_negacyclic_torus(&p, &mut f1);
        plan.forward_negacyclic(&signed, &mut f2);
        for (a, b) in f1.iter().zip(&f2) {
            assert!((a.re - b.re).abs() < 1e-3 && (a.im - b.im).abs() < 1e-3);
        }
    }

    #[test]
    fn inverse_add_accumulates() {
        let n = 16;
        let plan = FftPlan::new(n);
        let p: Vec<f64> = (0..n).map(|i| i as f64).collect();
        let mut f = vec![C64::default(); n / 2];
        plan.forward_negacyclic(&p, &mut f);
        let mut out = vec![5u64; n];
        plan.inverse_negacyclic_add_torus(&mut f, &mut out);
        for (i, &o) in out.iter().enumerate() {
            assert_eq!(o, 5u64.wrapping_add(i as u64), "i={i}");
        }
    }

    /// Pack `cols` complex vectors into planar [bin][col] buffers.
    fn to_planar(columns: &[Vec<C64>]) -> (Vec<f64>, Vec<f64>) {
        let cols = columns.len();
        let nh = columns[0].len();
        let mut re = vec![0.0; nh * cols];
        let mut im = vec![0.0; nh * cols];
        for (b, col) in columns.iter().enumerate() {
            for (h, z) in col.iter().enumerate() {
                re[h * cols + b] = z.re;
                im[h * cols + b] = z.im;
            }
        }
        (re, im)
    }

    #[test]
    fn planar_dif_matches_scalar_per_column() {
        check("planar_dif", 6, |rng| {
            for nh in [8usize, 64, 256] {
                let plan = FftPlan::new(2 * nh);
                let cols = 1 + rng.below_usize(5);
                let columns: Vec<Vec<C64>> = (0..cols)
                    .map(|_| {
                        (0..nh)
                            .map(|_| C64::new(rng.gaussian() * 50.0, rng.gaussian() * 50.0))
                            .collect()
                    })
                    .collect();
                let (mut re, mut im) = to_planar(&columns);
                plan.dif_forward_planar(&mut re, &mut im, cols);
                for (b, col) in columns.iter().enumerate() {
                    let mut scalar = col.clone();
                    plan.dif_forward(&mut scalar);
                    for h in 0..nh {
                        let got = (re[h * cols + b], im[h * cols + b]);
                        let exp = (scalar[h].re, scalar[h].im);
                        if (got.0 - exp.0).abs() > 1e-9 || (got.1 - exp.1).abs() > 1e-9 {
                            return Err(format!("nh={nh} col={b} bin={h}"));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn planar_dit_matches_scalar_per_column() {
        check("planar_dit", 6, |rng| {
            let nh = 128;
            let plan = FftPlan::new(2 * nh);
            let cols = 1 + rng.below_usize(4);
            let columns: Vec<Vec<C64>> = (0..cols)
                .map(|_| (0..nh).map(|_| C64::new(rng.gaussian(), rng.gaussian())).collect())
                .collect();
            let (mut re, mut im) = to_planar(&columns);
            plan.dit_inverse_planar(&mut re, &mut im, cols);
            for (b, col) in columns.iter().enumerate() {
                let mut scalar = col.clone();
                plan.dit_inverse(&mut scalar);
                for h in 0..nh {
                    if (re[h * cols + b] - scalar[h].re).abs() > 1e-12
                        || (im[h * cols + b] - scalar[h].im).abs() > 1e-12
                    {
                        return Err(format!("col={b} bin={h}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn planar_negacyclic_pipeline_matches_scalar() {
        // Digits in -> forward -> (identity in Fourier) -> inverse-to-torus
        // must match the scalar forward_negacyclic_i64 / inverse pipeline
        // column by column.
        check("planar_negacyclic", 6, |rng| {
            let n = 64;
            let nh = n / 2;
            let plan = FftPlan::new(n);
            let cols = 3usize;
            let columns: Vec<Vec<i64>> = (0..cols)
                .map(|_| (0..n).map(|_| (rng.below(512) as i64) - 256).collect())
                .collect();
            let mut p = vec![0i64; n * cols];
            for (b, col) in columns.iter().enumerate() {
                for (h, &x) in col.iter().enumerate() {
                    p[h * cols + b] = x;
                }
            }
            let mut re = vec![0.0; nh * cols];
            let mut im = vec![0.0; nh * cols];
            plan.forward_negacyclic_i64_planar(&p, &mut re, &mut im, cols);
            let mut out = vec![0u64; n * cols];
            plan.inverse_negacyclic_torus_planar(&mut re, &mut im, cols, &mut out);
            for (b, col) in columns.iter().enumerate() {
                let mut f = vec![C64::default(); nh];
                plan.forward_negacyclic_i64(col, &mut f);
                let mut exp = vec![0u64; n];
                plan.inverse_negacyclic_add_torus(&mut f, &mut exp);
                for h in 0..n {
                    let got = out[h * cols + b] as i64;
                    let want = exp[h] as i64;
                    if (got - want).unsigned_abs() > 1 {
                        return Err(format!("col={b} coef={h}: {got} vs {want}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn bitrev_permute_f64_matches_c64() {
        let src: Vec<C64> = (0..16).map(|i| C64::new(i as f64, -(i as f64))).collect();
        let re: Vec<f64> = src.iter().map(|z| z.re).collect();
        let perm_c = bitrev_permute_copy(&src);
        let perm_f = bitrev_permute_f64(&re);
        for (a, b) in perm_c.iter().zip(&perm_f) {
            assert_eq!(a.re, *b);
        }
    }

    #[test]
    fn mul_neg_i_is_rotation() {
        let z = C64::new(3.0, 4.0);
        let w = z.mul_neg_i();
        assert_eq!((w.re, w.im), (4.0, -3.0));
        let back = w.mul_neg_i().mul_neg_i().mul_neg_i();
        assert_eq!((back.re, back.im), (z.re, z.im));
    }

    /// First bin whose bits differ, if any.
    fn first_bit_diff(a: &[C64], b: &[C64]) -> Option<usize> {
        a.iter().zip(b).position(|(x, y)| {
            x.re.to_bits() != y.re.to_bits() || x.im.to_bits() != y.im.to_bits()
        })
    }

    #[test]
    fn blocked_schedule_selection_threshold() {
        // TEST1/TEST2 stay monolithic; WIDE8/WIDE10 auto-block.
        assert!(!FftPlan::new(512).blocked());
        assert!(!FftPlan::new(4096).blocked());
        assert!(FftPlan::new(16384).blocked());
        assert!(FftPlan::new(32768).blocked());
        assert!(!blocked_for_poly(4096) && blocked_for_poly(16384));
        // Pass-2 blocks stay within the L1-sized cap.
        assert!(FftPlan::new(16384).block_len() <= 2048);
        assert!(FftPlan::new(32768).block_len() <= 2048);
    }

    #[test]
    fn blocked_scalar_transforms_bitwise_match_monolithic() {
        // The blocked schedule is a pure reordering of independent
        // butterflies, so equality is exact — below, at, and above the
        // auto-blocking threshold (N = 1024 forces a two-pass split even
        // though it never auto-blocks).
        check("blocked_vs_monolithic", 3, |rng| {
            for poly_n in [1024usize, 16384, 32768] {
                let plan = FftPlan::new(poly_n);
                let nh = poly_n / 2;
                let orig: Vec<C64> = (0..nh)
                    .map(|_| C64::new(rng.gaussian() * 100.0, rng.gaussian() * 100.0))
                    .collect();
                let mut mono = orig.clone();
                plan.dif_forward_monolithic(&mut mono);
                let mut blk = orig.clone();
                plan.dif_forward_blocked(&mut blk);
                if let Some(h) = first_bit_diff(&mono, &blk) {
                    return Err(format!("dif N={poly_n} bin={h}"));
                }
                // The public entry point must agree with both no matter
                // which schedule it dispatched to.
                let mut disp = orig.clone();
                plan.dif_forward(&mut disp);
                if let Some(h) = first_bit_diff(&mono, &disp) {
                    return Err(format!("dif dispatch N={poly_n} bin={h}"));
                }
                plan.dit_inverse_monolithic(&mut mono);
                plan.dit_inverse_blocked(&mut blk);
                if let Some(h) = first_bit_diff(&mono, &blk) {
                    return Err(format!("dit N={poly_n} bin={h}"));
                }
                plan.dit_inverse(&mut disp);
                if let Some(h) = first_bit_diff(&mono, &disp) {
                    return Err(format!("dit dispatch N={poly_n} bin={h}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn blocked_planar_transforms_bitwise_match_monolithic() {
        check("blocked_vs_monolithic_planar", 3, |rng| {
            for poly_n in [1024usize, 16384, 32768] {
                let plan = FftPlan::new(poly_n);
                let nh = poly_n / 2;
                let cols = 2 + rng.below_usize(3);
                let orig_re: Vec<f64> = (0..nh * cols).map(|_| rng.gaussian() * 100.0).collect();
                let orig_im: Vec<f64> = (0..nh * cols).map(|_| rng.gaussian() * 100.0).collect();
                let (mut mre, mut mim) = (orig_re.clone(), orig_im.clone());
                plan.dif_forward_planar_monolithic(&mut mre, &mut mim, cols);
                let (mut bre, mut bim) = (orig_re.clone(), orig_im.clone());
                plan.dif_forward_planar_blocked(&mut bre, &mut bim, cols);
                if mre.iter().zip(&bre).any(|(x, y)| x.to_bits() != y.to_bits())
                    || mim.iter().zip(&bim).any(|(x, y)| x.to_bits() != y.to_bits())
                {
                    return Err(format!("planar dif N={poly_n} cols={cols}"));
                }
                plan.dit_inverse_planar_monolithic(&mut mre, &mut mim, cols);
                plan.dit_inverse_planar_blocked(&mut bre, &mut bim, cols);
                if mre.iter().zip(&bre).any(|(x, y)| x.to_bits() != y.to_bits())
                    || mim.iter().zip(&bim).any(|(x, y)| x.to_bits() != y.to_bits())
                {
                    return Err(format!("planar dit N={poly_n} cols={cols}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn plan_bitrev_methods_match_free_functions() {
        let plan = FftPlan::new(64);
        let src: Vec<C64> = (0..32).map(|i| C64::new(i as f64, -(i as f64))).collect();
        let mut out = vec![C64::default(); 32];
        plan.bitrev_permute_into(&src, &mut out);
        assert_eq!(out, bitrev_permute_copy(&src));
        let re: Vec<f64> = src.iter().map(|z| z.re).collect();
        let mut out_f = vec![0.0f64; 32];
        plan.bitrev_permute_f64_into(&re, &mut out_f);
        assert_eq!(out_f, bitrev_permute_f64(&re));
    }

    #[test]
    fn plan_registry_shares_one_plan_per_size() {
        let a = plan_for(1024);
        let b = plan_for(1024);
        assert!(std::sync::Arc::ptr_eq(&a, &b));
        let c = plan_for(2048);
        assert_eq!(c.nh, 1024);
        assert_eq!(a.nh, 512);
    }
}
