//! Negacyclic FFT over the torus — the compute hot-spot of the whole
//! library (every external product runs d(k+1) forward and k+1 inverse
//! transforms).
//!
//! Representation is the paper's "double-real" form (§IV-C): a degree-N
//! real polynomial is packed into an N/2-point complex vector
//! z_j = (p_j - i p_{j+N/2}) * twist_j with twist_j = exp(-i*pi*j/N); an
//! N/2-point complex FFT then evaluates P at the primitive 2N-th roots
//! zeta^(4k+1). Pointwise products in this domain are exact negacyclic
//! products (conjugate symmetry covers the other half of the roots).
//!
//! The hot-path transform is a no-permutation DIF/DIT pair: the forward
//! fused-radix-2^2 DIF leaves the Fourier domain bit-reversed (pointwise
//! products don't care), the inverse DIT consumes that order and emits
//! natural order — no bit-reversal pass ever runs on the request path,
//! and per-stage twiddles are stored contiguously. A classic natural-
//! order `fft_inplace`/`ifft_inplace` pair is kept for tests and key
//! export. See EXPERIMENTS.md §Perf for the measured iteration log.

/// Minimal complex type (num-complex is not in the offline registry).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct C64 {
    pub re: f64,
    pub im: f64,
}

impl C64 {
    #[inline(always)]
    pub fn new(re: f64, im: f64) -> Self {
        Self { re, im }
    }

    #[inline(always)]
    pub fn conj(self) -> Self {
        Self { re: self.re, im: -self.im }
    }

    #[inline(always)]
    pub fn mul(self, o: Self) -> Self {
        Self { re: self.re * o.re - self.im * o.im, im: self.re * o.im + self.im * o.re }
    }

    #[inline(always)]
    pub fn add(self, o: Self) -> Self {
        Self { re: self.re + o.re, im: self.im + o.im }
    }

    #[inline(always)]
    pub fn sub(self, o: Self) -> Self {
        Self { re: self.re - o.re, im: self.im - o.im }
    }

    #[inline(always)]
    pub fn scale(self, s: f64) -> Self {
        Self { re: self.re * s, im: self.im * s }
    }

    /// Multiply by -i (used by radix-4 butterflies).
    #[inline(always)]
    pub fn mul_neg_i(self) -> Self {
        Self { re: self.im, im: -self.re }
    }
}

/// Precomputed plan for polynomials of degree `poly_n` (complex size
/// `poly_n / 2`). Plans are cheap to build relative to keygen; callers
/// cache one per parameter set (see `PbsContext`).
pub struct FftPlan {
    /// Complex transform length N/2.
    pub nh: usize,
    #[allow(dead_code)]
    log2_nh: u32,
    bitrev: Vec<u32>,
    /// Forward roots w^t = exp(-2*pi*i*t/nh), t < nh/2.
    w: Vec<C64>,
    /// Per-fused-stage sequential twiddles [w1_j, w2_j, w3_j] for the
    /// radix-2^2 DIF kernel (contiguous loads instead of 3 strided ones).
    w_stages: Vec<Vec<C64>>,
    /// Folding twist exp(-i*pi*j/N), j < nh.
    twist: Vec<C64>,
}

impl FftPlan {
    pub fn new(poly_n: usize) -> Self {
        assert!(poly_n.is_power_of_two() && poly_n >= 4);
        let nh = poly_n / 2;
        let log2_nh = nh.trailing_zeros();
        let mut bitrev = vec![0u32; nh];
        for i in 0..nh {
            bitrev[i] = (i as u32).reverse_bits() >> (32 - log2_nh);
        }
        let w = (0..nh / 2)
            .map(|t| {
                let ang = -2.0 * std::f64::consts::PI * t as f64 / nh as f64;
                C64::new(ang.cos(), ang.sin())
            })
            .collect();
        let twist = (0..nh)
            .map(|j| {
                let ang = -std::f64::consts::PI * j as f64 / poly_n as f64;
                C64::new(ang.cos(), ang.sin())
            })
            .collect();
        let w: Vec<C64> = w;
        let mut w_stages = Vec::new();
        let mut len = nh;
        while len >= 4 {
            let q = len / 4;
            let step = nh / len;
            let mut tw = Vec::with_capacity(3 * q);
            for j in 0..q {
                let w1 = w[j * step];
                let w2 = w[2 * j * step];
                tw.push(w1);
                tw.push(w2);
                tw.push(w1.mul(w2));
            }
            w_stages.push(tw);
            len = q;
        }
        Self { nh, log2_nh, bitrev, w, w_stages, twist }
    }

    /// In-place forward complex FFT (DIT, natural order in/out).
    pub fn fft_inplace(&self, buf: &mut [C64]) {
        debug_assert_eq!(buf.len(), self.nh);
        // Bit-reverse permutation.
        for i in 0..self.nh {
            let j = self.bitrev[i] as usize;
            if i < j {
                buf.swap(i, j);
            }
        }
        let mut len = 2usize;
        while len <= self.nh {
            let half = len / 2;
            let step = self.nh / len;
            let mut base = 0;
            while base < self.nh {
                for j in 0..half {
                    let w = self.w[j * step];
                    let u = buf[base + j];
                    let v = buf[base + j + half].mul(w);
                    buf[base + j] = u.add(v);
                    buf[base + j + half] = u.sub(v);
                }
                base += len;
            }
            len <<= 1;
        }
    }

    /// In-place inverse complex FFT (includes the 1/nh scale).
    pub fn ifft_inplace(&self, buf: &mut [C64]) {
        for z in buf.iter_mut() {
            *z = z.conj();
        }
        self.fft_inplace(buf);
        let s = 1.0 / self.nh as f64;
        for z in buf.iter_mut() {
            *z = z.conj().scale(s);
        }
    }

    /// Forward DIF FFT: natural input -> **bit-reversed** output, no
    /// permutation pass. The TFHE pipeline only multiplies pointwise in
    /// the Fourier domain, so a consistent permutation is free speed
    /// (§Perf change 2); `bitrev_permute_copy` converts when natural
    /// order is needed (e.g. exporting the BSK to the XLA artifacts).
    pub fn dif_forward(&self, buf: &mut [C64]) {
        debug_assert_eq!(buf.len(), self.nh);
        let mut len = self.nh;
        // Fused radix-2^2 stages: identical ordering to two radix-2 DIF
        // passes, but one pass over memory and 3 twiddle mults per 4
        // points instead of 4 (§Perf change 3).
        let mut stage = 0;
        while len >= 4 {
            let q = len / 4;
            let tw = &self.w_stages[stage];
            stage += 1;
            let mut base = 0;
            while base < self.nh {
                for j in 0..q {
                    let w1 = tw[3 * j];
                    let w2 = tw[3 * j + 1];
                    let w3 = tw[3 * j + 2];
                    let a = buf[base + j];
                    let b = buf[base + j + q];
                    let c = buf[base + j + 2 * q];
                    let d = buf[base + j + 3 * q];
                    let t1 = a.add(c);
                    let t2 = b.add(d);
                    let t3 = a.sub(c);
                    let t4 = b.sub(d).mul_neg_i();
                    buf[base + j] = t1.add(t2);
                    buf[base + j + q] = t1.sub(t2).mul(w2);
                    buf[base + j + 2 * q] = t3.add(t4).mul(w1);
                    buf[base + j + 3 * q] = t3.sub(t4).mul(w3);
                }
                base += len;
            }
            len = q;
        }
        if len == 2 {
            // Final radix-2 stage for odd log2(nh); w^0 = 1, no mults.
            let mut base = 0;
            while base < self.nh {
                let a = buf[base];
                let b = buf[base + 1];
                buf[base] = a.add(b);
                buf[base + 1] = a.sub(b);
                base += 2;
            }
        }
    }

    /// Inverse DIT FFT: **bit-reversed** input -> natural output, with the
    /// 1/nh scale folded in.
    pub fn dit_inverse(&self, buf: &mut [C64]) {
        debug_assert_eq!(buf.len(), self.nh);
        let mut len = 2usize;
        while len <= self.nh {
            let half = len / 2;
            let step = self.nh / len;
            let mut base = 0;
            while base < self.nh {
                let (lo, hi) = buf[base..base + len].split_at_mut(half);
                for (j, (u, v)) in lo.iter_mut().zip(hi.iter_mut()).enumerate() {
                    let w = self.w[j * step].conj();
                    let a = *u;
                    let b = v.mul(w);
                    *u = a.add(b);
                    *v = a.sub(b);
                }
                base += len;
            }
            len <<= 1;
        }
        let s = 1.0 / self.nh as f64;
        for z in buf.iter_mut() {
            *z = z.scale(s);
        }
    }

    /// Forward negacyclic transform: signed coefficients (len N) -> Fourier
    /// domain (len N/2).
    pub fn forward_negacyclic(&self, p: &[f64], out: &mut [C64]) {
        debug_assert_eq!(p.len(), 2 * self.nh);
        debug_assert_eq!(out.len(), self.nh);
        for j in 0..self.nh {
            out[j] = C64::new(p[j], -p[j + self.nh]).mul(self.twist[j]);
        }
        self.dif_forward(out);
    }

    /// Forward transform straight from torus values (reinterpreted signed).
    pub fn forward_negacyclic_torus(&self, p: &[u64], out: &mut [C64]) {
        debug_assert_eq!(p.len(), 2 * self.nh);
        for j in 0..self.nh {
            let re = p[j] as i64 as f64;
            let im = -(p[j + self.nh] as i64 as f64);
            out[j] = C64::new(re, im).mul(self.twist[j]);
        }
        self.dif_forward(out);
    }

    /// Forward transform from i64 gadget digits.
    pub fn forward_negacyclic_i64(&self, p: &[i64], out: &mut [C64]) {
        debug_assert_eq!(p.len(), 2 * self.nh);
        for j in 0..self.nh {
            out[j] = C64::new(p[j] as f64, -(p[j + self.nh] as f64)).mul(self.twist[j]);
        }
        self.dif_forward(out);
    }

    /// Inverse negacyclic transform into torus values (rounded mod 2^64),
    /// *adding* into `out` (the blind-rotation accumulator pattern).
    /// `scratch` must have length N/2; `z` is consumed.
    pub fn inverse_negacyclic_add_torus(&self, z: &mut [C64], out: &mut [u64]) {
        debug_assert_eq!(z.len(), self.nh);
        debug_assert_eq!(out.len(), 2 * self.nh);
        self.dit_inverse(z);
        const Q: f64 = 18446744073709551616.0; // 2^64
        const INV_Q: f64 = 1.0 / Q;
        for j in 0..self.nh {
            let zz = z[j].mul(self.twist[j].conj());
            let re = zz.re - (zz.re * INV_Q).round() * Q;
            let im = -zz.im;
            let im = im - (im * INV_Q).round() * Q;
            out[j] = out[j].wrapping_add(re.round_ties_even() as i64 as u64);
            out[j + self.nh] = out[j + self.nh].wrapping_add(im.round_ties_even() as i64 as u64);
        }
    }
}

/// Permute a bit-reversed Fourier vector to natural order (copy). Used
/// when exporting Fourier keys to consumers that expect natural order
/// (the XLA artifacts use jnp.fft).
pub fn bitrev_permute_copy(src: &[C64]) -> Vec<C64> {
    let n = src.len();
    debug_assert!(n.is_power_of_two());
    let log = n.trailing_zeros();
    let mut out = vec![C64::default(); n];
    for (i, &v) in src.iter().enumerate() {
        out[(i as u32).reverse_bits() as usize >> (32 - log)] = v;
    }
    out
}

/// O(N^2) schoolbook negacyclic multiplication (test oracle).
pub fn negacyclic_mul_naive(a: &[f64], b: &[f64]) -> Vec<f64> {
    let n = a.len();
    let mut out = vec![0.0; n];
    for i in 0..n {
        for j in 0..n {
            let k = i + j;
            if k < n {
                out[k] += a[i] * b[j];
            } else {
                out[k - n] -= a[i] * b[j];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_allclose, check};
    use crate::util::rng::Rng;

    fn fft_roundtrip(nh: usize, rng: &mut Rng) -> Result<(), String> {
        let plan = FftPlan::new(2 * nh);
        let orig: Vec<C64> = (0..nh)
            .map(|_| C64::new(rng.gaussian() * 100.0, rng.gaussian() * 100.0))
            .collect();
        let mut buf = orig.clone();
        plan.fft_inplace(&mut buf);
        plan.ifft_inplace(&mut buf);
        let got: Vec<f64> = buf.iter().flat_map(|c| [c.re, c.im]).collect();
        let exp: Vec<f64> = orig.iter().flat_map(|c| [c.re, c.im]).collect();
        assert_allclose(&got, &exp, 1e-8, 1e-9)
    }

    #[test]
    fn complex_fft_roundtrip() {
        check("fft_roundtrip", 10, |rng| {
            for log in [2usize, 4, 7, 9] {
                fft_roundtrip(1 << log, rng)?;
            }
            Ok(())
        });
    }

    #[test]
    fn fft_matches_dft_small() {
        // Direct O(n^2) DFT cross-check at n=8.
        let plan = FftPlan::new(16);
        let x: Vec<C64> = (0..8).map(|i| C64::new(i as f64, (2 * i) as f64)).collect();
        let mut buf = x.clone();
        plan.fft_inplace(&mut buf);
        for k in 0..8 {
            let mut acc = C64::default();
            for (j, xj) in x.iter().enumerate() {
                let ang = -2.0 * std::f64::consts::PI * (j * k) as f64 / 8.0;
                acc = acc.add(xj.mul(C64::new(ang.cos(), ang.sin())));
            }
            assert!((acc.re - buf[k].re).abs() < 1e-9, "k={k}");
            assert!((acc.im - buf[k].im).abs() < 1e-9, "k={k}");
        }
    }

    #[test]
    fn negacyclic_convolution_matches_naive() {
        check("negacyclic_conv", 8, |rng| {
            let n = 64;
            let plan = FftPlan::new(n);
            let a: Vec<f64> = (0..n).map(|_| (rng.below(200) as f64) - 100.0).collect();
            let b: Vec<f64> = (0..n).map(|_| (rng.below(200) as f64) - 100.0).collect();
            let mut fa = vec![C64::default(); n / 2];
            let mut fb = vec![C64::default(); n / 2];
            plan.forward_negacyclic(&a, &mut fa);
            plan.forward_negacyclic(&b, &mut fb);
            for j in 0..n / 2 {
                fa[j] = fa[j].mul(fb[j]);
            }
            let mut out = vec![0u64; n];
            plan.inverse_negacyclic_add_torus(&mut fa, &mut out);
            let naive = negacyclic_mul_naive(&a, &b);
            let got: Vec<f64> = out.iter().map(|&x| x as i64 as f64).collect();
            assert_allclose(&got, &naive, 0.51, 0.0)
        });
    }

    #[test]
    fn torus_forward_matches_signed_reinterpretation() {
        let n = 32;
        let plan = FftPlan::new(n);
        let mut rng = Rng::new(4);
        let p: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
        let signed: Vec<f64> = p.iter().map(|&x| x as i64 as f64).collect();
        let mut f1 = vec![C64::default(); n / 2];
        let mut f2 = vec![C64::default(); n / 2];
        plan.forward_negacyclic_torus(&p, &mut f1);
        plan.forward_negacyclic(&signed, &mut f2);
        for (a, b) in f1.iter().zip(&f2) {
            assert!((a.re - b.re).abs() < 1e-3 && (a.im - b.im).abs() < 1e-3);
        }
    }

    #[test]
    fn inverse_add_accumulates() {
        let n = 16;
        let plan = FftPlan::new(n);
        let p: Vec<f64> = (0..n).map(|i| i as f64).collect();
        let mut f = vec![C64::default(); n / 2];
        plan.forward_negacyclic(&p, &mut f);
        let mut out = vec![5u64; n];
        plan.inverse_negacyclic_add_torus(&mut f, &mut out);
        for (i, &o) in out.iter().enumerate() {
            assert_eq!(o, 5u64.wrapping_add(i as u64), "i={i}");
        }
    }

    #[test]
    fn mul_neg_i_is_rotation() {
        let z = C64::new(3.0, 4.0);
        let w = z.mul_neg_i();
        assert_eq!((w.re, w.im), (4.0, -3.0));
        let back = w.mul_neg_i().mul_neg_i().mul_neg_i();
        assert_eq!((back.re, back.im), (z.re, z.im));
    }
}
