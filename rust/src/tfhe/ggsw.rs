//! GGSW ciphertexts (Fourier domain) and the external product — "the most
//! time-consuming operation in bootstrapping" (paper §II-B, Fig. 4), i.e.
//! the operation the BRU accelerates.

use super::decomp::decompose_strided;
use super::fft::{C64, FftPlan};
use super::glwe::GlweCiphertext;
use super::poly;
use crate::params::ParamSet;

/// One GGSW ciphertext kept in the Fourier domain: `rows x (k+1)` Fourier
/// polynomials of N/2 complex points each. Row r = c*level + j encrypts
/// m * (-s_c) * q/B^(j+1) (c < k) or m * q/B^(j+1) (c = k).
#[derive(Debug, Clone)]
pub struct FourierGgsw {
    /// rows * (k+1) * nh, row-major (r, c, h).
    pub data: Vec<C64>,
    pub rows: usize,
    pub k1: usize,
    pub nh: usize,
}

impl FourierGgsw {
    pub fn row(&self, r: usize, c: usize) -> &[C64] {
        let off = (r * self.k1 + c) * self.nh;
        &self.data[off..off + self.nh]
    }
}

/// Reused scratch for external products (no allocation on the hot path).
pub struct ExtProdScratch {
    /// level digit polynomials of one GLWE row: level * N i64.
    digits: Vec<i64>,
    /// Fourier transform of one digit row.
    row_f: Vec<C64>,
    /// Fourier accumulator, (k+1) * nh.
    acc_f: Vec<C64>,
    /// CMUX rotation difference, (k+1) * N.
    diff: Vec<u64>,
}

impl ExtProdScratch {
    pub fn new(p: &ParamSet) -> Self {
        Self {
            digits: vec![0; p.bsk_level * p.big_n],
            row_f: vec![C64::default(); p.half_n()],
            acc_f: vec![C64::default(); (p.k + 1) * p.half_n()],
            diff: vec![0; (p.k + 1) * p.big_n],
        }
    }
}

/// `acc += GGSW box glwe` — the external product, fused decompose -> FFT ->
/// MAC -> IFFT (the BRU pipeline of Fig. 8(b)).
pub fn external_product_add(
    plan: &FftPlan,
    p: &ParamSet,
    ggsw: &FourierGgsw,
    glwe_in: &[u64],
    acc: &mut GlweCiphertext,
    s: &mut ExtProdScratch,
) {
    let (k1, nh, big_n) = (p.k + 1, p.half_n(), p.big_n);
    let (bl, lvl) = (p.bsk_base_log, p.bsk_level);
    s.acc_f.iter_mut().for_each(|z| *z = C64::default());
    for c in 0..k1 {
        // Decompose polynomial c into `lvl` digit rows (strided layout).
        let src = &glwe_in[c * big_n..(c + 1) * big_n];
        for (i, &x) in src.iter().enumerate() {
            decompose_strided(x, bl, lvl, &mut s.digits[i..], big_n);
        }
        for j in 0..lvl {
            let digit_poly = &s.digits[j * big_n..(j + 1) * big_n];
            plan.forward_negacyclic_i64(digit_poly, &mut s.row_f);
            let r = c * lvl + j;
            for cc in 0..k1 {
                let brow = ggsw.row(r, cc);
                let accf = &mut s.acc_f[cc * nh..(cc + 1) * nh];
                // Fused complex MAC, iterator form (no bounds checks).
                for ((a, &x), &b) in accf.iter_mut().zip(&s.row_f).zip(brow) {
                    a.re += x.re * b.re - x.im * b.im;
                    a.im += x.re * b.im + x.im * b.re;
                }
            }
        }
    }
    for cc in 0..k1 {
        let accf = &mut s.acc_f[cc * nh..(cc + 1) * nh];
        let out = &mut acc.data[cc * big_n..(cc + 1) * big_n];
        plan.inverse_negacyclic_add_torus(accf, out);
    }
}

/// CMUX with rotation: `acc <- acc + GGSW(s) box (X^amount * acc - acc)`.
/// Selects between `acc` (s = 0) and `X^amount * acc` (s = 1) — one blind
/// rotation step.
pub fn cmux_rotate(
    plan: &FftPlan,
    p: &ParamSet,
    ggsw: &FourierGgsw,
    amount: usize,
    acc: &mut GlweCiphertext,
    s: &mut ExtProdScratch,
) {
    let big_n = p.big_n;
    for c in 0..p.k + 1 {
        poly::rotate_sub_into(
            &acc.data[c * big_n..(c + 1) * big_n],
            amount,
            &mut s.diff[c * big_n..(c + 1) * big_n],
        );
    }
    // Split borrow: diff lives in scratch; temporarily move it out.
    let diff = std::mem::take(&mut s.diff);
    external_product_add(plan, p, ggsw, &diff, acc, s);
    s.diff = diff;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::TEST1;
    use crate::tfhe::bsk::encrypt_ggsw;
    use crate::tfhe::torus::{torus_distance, SecretKeys};
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn setup(rng: &mut Rng) -> (SecretKeys, FftPlan) {
        (SecretKeys::generate(&TEST1, rng), FftPlan::new(TEST1.big_n))
    }

    #[test]
    fn ggsw_one_is_identity() {
        check("extprod_identity", 5, |rng| {
            let (sk, plan) = setup(rng);
            let g = encrypt_ggsw(1, &sk, rng, &plan);
            let msg: Vec<u64> = (0..TEST1.big_n as u64).map(|j| (j % 16) << 60).collect();
            let glwe = GlweCiphertext::encrypt(&msg, &sk, TEST1.glwe_noise, rng, &plan);
            let mut acc = GlweCiphertext::zero(TEST1.k, TEST1.big_n);
            let mut s = ExtProdScratch::new(&TEST1);
            external_product_add(&plan, &TEST1, &g, &glwe.data, &mut acc, &mut s);
            let ph = acc.decrypt_phase(&sk, &plan);
            for (got, exp) in ph.iter().zip(&msg) {
                if torus_distance(*got, *exp) > 1e-5 {
                    return Err(format!("{}", torus_distance(*got, *exp)));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn ggsw_zero_absorbs() {
        check("extprod_zero", 5, |rng| {
            let (sk, plan) = setup(rng);
            let g = encrypt_ggsw(0, &sk, rng, &plan);
            let msg = vec![3u64 << 60; TEST1.big_n];
            let glwe = GlweCiphertext::encrypt(&msg, &sk, TEST1.glwe_noise, rng, &plan);
            let mut acc = GlweCiphertext::zero(TEST1.k, TEST1.big_n);
            let mut s = ExtProdScratch::new(&TEST1);
            external_product_add(&plan, &TEST1, &g, &glwe.data, &mut acc, &mut s);
            let ph = acc.decrypt_phase(&sk, &plan);
            for got in ph {
                if torus_distance(got, 0) > 1e-5 {
                    return Err("nonzero".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn cmux_selects_between_identity_and_rotation() {
        check("cmux_select", 4, |rng| {
            let (sk, plan) = setup(rng);
            let mut msg = vec![0u64; TEST1.big_n];
            msg[0] = 7u64 << 60;
            for bit in [0u64, 1] {
                let g = encrypt_ggsw(bit, &sk, rng, &plan);
                let mut acc = GlweCiphertext::trivial(&msg, TEST1.k);
                let mut s = ExtProdScratch::new(&TEST1);
                cmux_rotate(&plan, &TEST1, &g, 3, &mut acc, &mut s);
                let ph = acc.decrypt_phase(&sk, &plan);
                // bit=0 -> msg unchanged; bit=1 -> X^3 * msg.
                let expect_idx = if bit == 0 { 0 } else { 3 };
                for (j, &v) in ph.iter().enumerate() {
                    let exp = if j == expect_idx { 7u64 << 60 } else { 0 };
                    if torus_distance(v, exp) > 1e-5 {
                        return Err(format!("bit={bit} j={j}"));
                    }
                }
            }
            Ok(())
        });
    }
}
