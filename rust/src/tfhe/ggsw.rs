//! GGSW ciphertexts (Fourier domain) and the external product — "the most
//! time-consuming operation in bootstrapping" (paper §II-B, Fig. 4), i.e.
//! the operation the BRU accelerates.
//!
//! Two execution shapes share the same key material:
//!
//! - the scalar path ([`external_product_add`] / [`cmux_rotate`]) runs one
//!   ciphertext at a time — the latency-oriented CPU baseline;
//! - the batched path ([`external_product_add_batch`] /
//!   [`cmux_rotate_batch`]) walks the GGSW **rows in the outer loop** and
//!   the ciphertext batch in the inner loop, so every Fourier key point is
//!   read once per batch step instead of once per ciphertext — the
//!   paper's key-reuse schedule ("optimizing memory bandwidth through key
//!   reuse strategies"), executed over the planar SoA kernels of
//!   [`FftPlan`].

use super::decomp::decompose_strided;
use super::fft::{C64, FftPlan};
use super::glwe::GlweCiphertext;
use super::poly;
use crate::params::ParamSet;

/// One GGSW ciphertext kept in the Fourier domain as planar (SoA)
/// `re[]`/`im[]` arrays: `rows x (k+1)` Fourier polynomials of N/2 points
/// each, row-major (r, c, h). Row r = c*level + j encrypts
/// m * (-s_c) * q/B^(j+1) (c < k) or m * q/B^(j+1) (c = k).
///
/// The planar layout is what the batched MAC streams: each key point is a
/// pair of scalar f64 loads broadcast against a contiguous batch row.
#[derive(Debug, Clone)]
pub struct FourierGgsw {
    /// rows * (k+1) * nh real parts, row-major (r, c, h).
    pub re: Vec<f64>,
    /// rows * (k+1) * nh imaginary parts, same layout.
    pub im: Vec<f64>,
    pub rows: usize,
    pub k1: usize,
    pub nh: usize,
}

impl FourierGgsw {
    pub fn row_re(&self, r: usize, c: usize) -> &[f64] {
        let off = (r * self.k1 + c) * self.nh;
        &self.re[off..off + self.nh]
    }

    pub fn row_im(&self, r: usize, c: usize) -> &[f64] {
        let off = (r * self.k1 + c) * self.nh;
        &self.im[off..off + self.nh]
    }

    /// Total Fourier points stored (rows * (k+1) * nh).
    pub fn points(&self) -> usize {
        self.re.len()
    }

    /// In-memory size in bytes (one f64 per point per plane).
    pub fn bytes(&self) -> usize {
        self.points() * 16
    }
}

/// Reused scratch for scalar external products (no allocation on the hot
/// path).
pub struct ExtProdScratch {
    /// level digit polynomials of one GLWE row: level * N i64.
    digits: Vec<i64>,
    /// Fourier transform of one digit row.
    row_f: Vec<C64>,
    /// Fourier accumulator, (k+1) * nh.
    acc_f: Vec<C64>,
    /// CMUX rotation difference, (k+1) * N.
    diff: Vec<u64>,
}

impl ExtProdScratch {
    pub fn new(p: &ParamSet) -> Self {
        Self {
            digits: vec![0; p.bsk_level * p.big_n],
            row_f: vec![C64::default(); p.half_n()],
            acc_f: vec![C64::default(); (p.k + 1) * p.half_n()],
            diff: vec![0; (p.k + 1) * p.big_n],
        }
    }
}

/// `acc += GGSW box glwe` — the external product, fused decompose -> FFT ->
/// MAC -> IFFT (the BRU pipeline of Fig. 8(b)).
pub fn external_product_add(
    plan: &FftPlan,
    p: &ParamSet,
    ggsw: &FourierGgsw,
    glwe_in: &[u64],
    acc: &mut GlweCiphertext,
    s: &mut ExtProdScratch,
) {
    let (k1, nh, big_n) = (p.k + 1, p.half_n(), p.big_n);
    let (bl, lvl) = (p.bsk_base_log, p.bsk_level);
    s.acc_f.iter_mut().for_each(|z| *z = C64::default());
    for c in 0..k1 {
        // Decompose polynomial c into `lvl` digit rows (strided layout).
        let src = &glwe_in[c * big_n..(c + 1) * big_n];
        for (i, &x) in src.iter().enumerate() {
            decompose_strided(x, bl, lvl, &mut s.digits[i..], big_n);
        }
        for j in 0..lvl {
            let digit_poly = &s.digits[j * big_n..(j + 1) * big_n];
            plan.forward_negacyclic_i64(digit_poly, &mut s.row_f);
            let r = c * lvl + j;
            for cc in 0..k1 {
                let brow = ggsw.row_re(r, cc).iter().zip(ggsw.row_im(r, cc));
                let accf = &mut s.acc_f[cc * nh..(cc + 1) * nh];
                // Fused complex MAC, iterator form (no bounds checks).
                for ((a, &x), (&br, &bi)) in accf.iter_mut().zip(&s.row_f).zip(brow) {
                    a.re += x.re * br - x.im * bi;
                    a.im += x.re * bi + x.im * br;
                }
            }
        }
    }
    for cc in 0..k1 {
        let accf = &mut s.acc_f[cc * nh..(cc + 1) * nh];
        let out = &mut acc.data[cc * big_n..(cc + 1) * big_n];
        plan.inverse_negacyclic_add_torus(accf, out);
    }
}

/// CMUX with rotation: `acc <- acc + GGSW(s) box (X^amount * acc - acc)`.
/// Selects between `acc` (s = 0) and `X^amount * acc` (s = 1) — one blind
/// rotation step.
pub fn cmux_rotate(
    plan: &FftPlan,
    p: &ParamSet,
    ggsw: &FourierGgsw,
    amount: usize,
    acc: &mut GlweCiphertext,
    s: &mut ExtProdScratch,
) {
    let big_n = p.big_n;
    for c in 0..p.k + 1 {
        poly::rotate_sub_into(
            &acc.data[c * big_n..(c + 1) * big_n],
            amount,
            &mut s.diff[c * big_n..(c + 1) * big_n],
        );
    }
    // Split borrow: diff lives in scratch; temporarily move it out.
    let diff = std::mem::take(&mut s.diff);
    external_product_add(plan, p, ggsw, &diff, acc, s);
    s.diff = diff;
}

// ---------------------------------------------------------------------------
// Batched path: one GGSW applied to a whole batch of ciphertexts.
// ---------------------------------------------------------------------------

/// Reused scratch for batched external products over up to `cols`
/// ciphertexts (narrower batches use a dense prefix of each buffer).
/// Planar buffers use [element][col] layout (col fastest) so the batch is
/// the contiguous inner dimension everywhere.
pub struct BatchExtProdScratch {
    cols: usize,
    /// Gadget digits, [level][coef][col]: level * N * cols i64.
    digits: Vec<i64>,
    /// Planar Fourier buffer for one digit row across the batch, nh * cols.
    row_re: Vec<f64>,
    row_im: Vec<f64>,
    /// Planar Fourier accumulator, (k+1) * nh * cols.
    acc_re: Vec<f64>,
    acc_im: Vec<f64>,
    /// Torus staging for the planar inverse transform, N * cols.
    inv_t: Vec<u64>,
    /// CMUX rotation differences, AoS per ciphertext: cols * (k+1) * N.
    diff: Vec<u64>,
}

impl BatchExtProdScratch {
    pub fn new(p: &ParamSet, cols: usize) -> Self {
        let (k1, nh, big_n) = (p.k + 1, p.half_n(), p.big_n);
        Self {
            cols,
            digits: vec![0; p.bsk_level * big_n * cols],
            row_re: vec![0.0; nh * cols],
            row_im: vec![0.0; nh * cols],
            acc_re: vec![0.0; k1 * nh * cols],
            acc_im: vec![0.0; k1 * nh * cols],
            inv_t: vec![0; big_n * cols],
            diff: vec![0; cols * k1 * big_n],
        }
    }

    /// Maximum batch width this scratch can serve.
    pub fn cols(&self) -> usize {
        self.cols
    }
}

/// Batched external product with key reuse:
/// `accs[b] += GGSW box glwe_in[b]` for every ciphertext b in the batch.
///
/// `glwe_in` holds `cols` stacked (k+1)*N inputs (AoS per ciphertext, the
/// layout of [`GlweCiphertext::data`]). The GGSW **rows form the outer
/// loop**: each Fourier key point is loaded once and MAC'd against the
/// contiguous batch row — BSK traffic is amortized `cols`-fold relative to
/// running [`external_product_add`] per ciphertext, and the inner loops
/// are the auto-vectorizable planar shape.
pub fn external_product_add_batch(
    plan: &FftPlan,
    p: &ParamSet,
    ggsw: &FourierGgsw,
    glwe_in: &[u64],
    accs: &mut [GlweCiphertext],
    s: &mut BatchExtProdScratch,
) {
    let cols = accs.len();
    assert!(s.cols >= cols, "scratch narrower than the batch");
    let (k1, nh, big_n) = (p.k + 1, p.half_n(), p.big_n);
    let (bl, lvl) = (p.bsk_base_log, p.bsk_level);
    debug_assert_eq!(glwe_in.len(), cols * k1 * big_n);
    s.acc_re[..k1 * nh * cols].iter_mut().for_each(|x| *x = 0.0);
    s.acc_im[..k1 * nh * cols].iter_mut().for_each(|x| *x = 0.0);
    for c in 0..k1 {
        // Decompose polynomial c of every ciphertext into the planar
        // [level][coef][col] digit layout.
        for b in 0..cols {
            let src = &glwe_in[(b * k1 + c) * big_n..(b * k1 + c + 1) * big_n];
            for (i, &x) in src.iter().enumerate() {
                decompose_strided(x, bl, lvl, &mut s.digits[i * cols + b..], big_n * cols);
            }
        }
        for j in 0..lvl {
            let dig = &s.digits[j * big_n * cols..(j + 1) * big_n * cols];
            plan.forward_negacyclic_i64_planar(
                dig,
                &mut s.row_re[..nh * cols],
                &mut s.row_im[..nh * cols],
                cols,
            );
            let r = c * lvl + j;
            for cc in 0..k1 {
                let bre = ggsw.row_re(r, cc);
                let bim = ggsw.row_im(r, cc);
                let are = &mut s.acc_re[cc * nh * cols..(cc + 1) * nh * cols];
                let aim = &mut s.acc_im[cc * nh * cols..(cc + 1) * nh * cols];
                for h in 0..nh {
                    // One key point, reused across the whole batch row.
                    let (br, bi) = (bre[h], bim[h]);
                    let off = h * cols;
                    for b in 0..cols {
                        let xr = s.row_re[off + b];
                        let xi = s.row_im[off + b];
                        are[off + b] += xr * br - xi * bi;
                        aim[off + b] += xr * bi + xi * br;
                    }
                }
            }
        }
    }
    for cc in 0..k1 {
        let are = &mut s.acc_re[cc * nh * cols..(cc + 1) * nh * cols];
        let aim = &mut s.acc_im[cc * nh * cols..(cc + 1) * nh * cols];
        plan.inverse_negacyclic_torus_planar(are, aim, cols, &mut s.inv_t[..big_n * cols]);
        for (b, acc) in accs.iter_mut().enumerate() {
            let out = acc.poly_mut(cc);
            for (h, o) in out.iter_mut().enumerate() {
                *o = o.wrapping_add(s.inv_t[h * cols + b]);
            }
        }
    }
}

/// Batched CMUX with per-ciphertext rotation amounts: one blind-rotation
/// step for the whole batch,
/// `accs[b] <- accs[b] + GGSW(s) box (X^amounts[b] * accs[b] - accs[b])`.
///
/// A zero amount contributes an exactly-zero difference (all gadget digits
/// vanish), so mixed batches stay correct with no per-column branching.
pub fn cmux_rotate_batch(
    plan: &FftPlan,
    p: &ParamSet,
    ggsw: &FourierGgsw,
    amounts: &[usize],
    accs: &mut [GlweCiphertext],
    s: &mut BatchExtProdScratch,
) {
    let (k1, big_n) = (p.k + 1, p.big_n);
    debug_assert_eq!(amounts.len(), accs.len());
    for (b, acc) in accs.iter().enumerate() {
        for c in 0..k1 {
            poly::rotate_sub_into(
                acc.poly(c),
                amounts[b],
                &mut s.diff[(b * k1 + c) * big_n..(b * k1 + c + 1) * big_n],
            );
        }
    }
    // Split borrow: diff lives in scratch; temporarily move it out.
    let diff = std::mem::take(&mut s.diff);
    external_product_add_batch(plan, p, ggsw, &diff[..accs.len() * k1 * big_n], accs, s);
    s.diff = diff;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::TEST1;
    use crate::tfhe::bsk::encrypt_ggsw;
    use crate::tfhe::torus::{torus_distance, SecretKeys};
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn setup(rng: &mut Rng) -> (SecretKeys, FftPlan) {
        (SecretKeys::generate(&TEST1, rng), FftPlan::new(TEST1.big_n))
    }

    #[test]
    fn ggsw_one_is_identity() {
        check("extprod_identity", 5, |rng| {
            let (sk, plan) = setup(rng);
            let g = encrypt_ggsw(1, &sk, rng, &plan);
            let msg: Vec<u64> = (0..TEST1.big_n as u64).map(|j| (j % 16) << 60).collect();
            let glwe = GlweCiphertext::encrypt(&msg, &sk, TEST1.glwe_noise, rng, &plan);
            let mut acc = GlweCiphertext::zero(TEST1.k, TEST1.big_n);
            let mut s = ExtProdScratch::new(&TEST1);
            external_product_add(&plan, &TEST1, &g, &glwe.data, &mut acc, &mut s);
            let ph = acc.decrypt_phase(&sk, &plan);
            for (got, exp) in ph.iter().zip(&msg) {
                if torus_distance(*got, *exp) > 1e-5 {
                    return Err(format!("{}", torus_distance(*got, *exp)));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn ggsw_zero_absorbs() {
        check("extprod_zero", 5, |rng| {
            let (sk, plan) = setup(rng);
            let g = encrypt_ggsw(0, &sk, rng, &plan);
            let msg = vec![3u64 << 60; TEST1.big_n];
            let glwe = GlweCiphertext::encrypt(&msg, &sk, TEST1.glwe_noise, rng, &plan);
            let mut acc = GlweCiphertext::zero(TEST1.k, TEST1.big_n);
            let mut s = ExtProdScratch::new(&TEST1);
            external_product_add(&plan, &TEST1, &g, &glwe.data, &mut acc, &mut s);
            let ph = acc.decrypt_phase(&sk, &plan);
            for got in ph {
                if torus_distance(got, 0) > 1e-5 {
                    return Err("nonzero".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn cmux_selects_between_identity_and_rotation() {
        check("cmux_select", 4, |rng| {
            let (sk, plan) = setup(rng);
            let mut msg = vec![0u64; TEST1.big_n];
            msg[0] = 7u64 << 60;
            for bit in [0u64, 1] {
                let g = encrypt_ggsw(bit, &sk, rng, &plan);
                let mut acc = GlweCiphertext::trivial(&msg, TEST1.k);
                let mut s = ExtProdScratch::new(&TEST1);
                cmux_rotate(&plan, &TEST1, &g, 3, &mut acc, &mut s);
                let ph = acc.decrypt_phase(&sk, &plan);
                // bit=0 -> msg unchanged; bit=1 -> X^3 * msg.
                let expect_idx = if bit == 0 { 0 } else { 3 };
                for (j, &v) in ph.iter().enumerate() {
                    let exp = if j == expect_idx { 7u64 << 60 } else { 0 };
                    if torus_distance(v, exp) > 1e-5 {
                        return Err(format!("bit={bit} j={j}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn batch_external_product_matches_scalar() {
        check("extprod_batch_vs_scalar", 3, |rng| {
            let (sk, plan) = setup(rng);
            let g = encrypt_ggsw(1, &sk, rng, &plan);
            let cols = 3usize;
            let glwes: Vec<GlweCiphertext> = (0..cols)
                .map(|b| {
                    let msg: Vec<u64> =
                        (0..TEST1.big_n as u64).map(|j| ((j + b as u64) % 16) << 60).collect();
                    GlweCiphertext::encrypt(&msg, &sk, TEST1.glwe_noise, rng, &plan)
                })
                .collect();
            let stacked: Vec<u64> = glwes.iter().flat_map(|gl| gl.data.iter().copied()).collect();
            let mut batch_accs: Vec<GlweCiphertext> =
                (0..cols).map(|_| GlweCiphertext::zero(TEST1.k, TEST1.big_n)).collect();
            let mut bs = BatchExtProdScratch::new(&TEST1, cols);
            external_product_add_batch(&plan, &TEST1, &g, &stacked, &mut batch_accs, &mut bs);
            let mut s = ExtProdScratch::new(&TEST1);
            for (b, glwe) in glwes.iter().enumerate() {
                let mut acc = GlweCiphertext::zero(TEST1.k, TEST1.big_n);
                external_product_add(&plan, &TEST1, &g, &glwe.data, &mut acc, &mut s);
                for (x, y) in acc.data.iter().zip(&batch_accs[b].data) {
                    // Same ops per column; allow the last rounding ulp.
                    if x.wrapping_sub(*y).wrapping_add(1) > 2 {
                        return Err(format!("col={b}: {x} vs {y}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn batch_cmux_selects_per_column_amounts() {
        check("cmux_batch", 3, |rng| {
            let (sk, plan) = setup(rng);
            let g = encrypt_ggsw(1, &sk, rng, &plan);
            let mut msg = vec![0u64; TEST1.big_n];
            msg[0] = 7u64 << 60;
            let amounts = [0usize, 3, 11];
            let mut accs: Vec<GlweCiphertext> =
                amounts.iter().map(|_| GlweCiphertext::trivial(&msg, TEST1.k)).collect();
            let mut bs = BatchExtProdScratch::new(&TEST1, amounts.len());
            cmux_rotate_batch(&plan, &TEST1, &g, &amounts, &mut accs, &mut bs);
            for (b, amount) in amounts.iter().enumerate() {
                let ph = accs[b].decrypt_phase(&sk, &plan);
                for (j, &v) in ph.iter().enumerate() {
                    let exp = if j == *amount { 7u64 << 60 } else { 0 };
                    if torus_distance(v, exp) > 1e-5 {
                        return Err(format!("col={b} j={j}"));
                    }
                }
            }
            Ok(())
        });
    }
}
