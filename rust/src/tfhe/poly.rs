//! Torus polynomial helpers over Z_q[X]/(X^N + 1).

use super::fft::{C64, FftPlan};

/// out += a (wrapping, elementwise).
#[inline]
pub fn add_assign(out: &mut [u64], a: &[u64]) {
    for (o, &x) in out.iter_mut().zip(a) {
        *o = o.wrapping_add(x);
    }
}

/// out -= a (wrapping, elementwise).
#[inline]
pub fn sub_assign(out: &mut [u64], a: &[u64]) {
    for (o, &x) in out.iter_mut().zip(a) {
        *o = o.wrapping_sub(x);
    }
}

/// out = -out.
#[inline]
pub fn neg_assign(out: &mut [u64]) {
    for o in out.iter_mut() {
        *o = o.wrapping_neg();
    }
}

/// Multiply by X^r (r in [0, 2N)) into `out` (negacyclic rotation):
/// out[j] = p[j - r] with a sign flip on wraparound.
pub fn rotate_into(p: &[u64], r: usize, out: &mut [u64]) {
    let n = p.len();
    debug_assert_eq!(out.len(), n);
    let r = r % (2 * n);
    let (shift, flip) = if r < n { (r, false) } else { (r - n, true) };
    // out[j] = p[j - shift] for j >= shift, -p[N + j - shift] for j < shift,
    // all negated again if flip.
    for j in 0..shift {
        let v = p[n + j - shift].wrapping_neg();
        out[j] = if flip { v.wrapping_neg() } else { v };
    }
    for j in shift..n {
        let v = p[j - shift];
        out[j] = if flip { v.wrapping_neg() } else { v };
    }
}

/// out = X^r * p - p (the CMUX difference), fused to avoid a temp.
pub fn rotate_sub_into(p: &[u64], r: usize, out: &mut [u64]) {
    let n = p.len();
    let r = r % (2 * n);
    let (shift, flip) = if r < n { (r, false) } else { (r - n, true) };
    for j in 0..shift {
        let v = p[n + j - shift].wrapping_neg();
        let v = if flip { v.wrapping_neg() } else { v };
        out[j] = v.wrapping_sub(p[j]);
    }
    for j in shift..n {
        let v = p[j - shift];
        let v = if flip { v.wrapping_neg() } else { v };
        out[j] = v.wrapping_sub(p[j]);
    }
}

/// Exact-enough torus-by-binary polynomial product via FFT (used by key
/// generation and decryption; the FFT rounding is orders of magnitude
/// below every noise floor — see DESIGN.md). `out += a * s`.
pub fn mul_binary_add_into(plan: &FftPlan, a_torus: &[u64], s_binary: &[u64], out: &mut [u64]) {
    let n = a_torus.len();
    let mut fa = vec![C64::default(); n / 2];
    let mut fs = vec![C64::default(); n / 2];
    plan.forward_negacyclic_torus(a_torus, &mut fa);
    let s_signed: Vec<f64> = s_binary.iter().map(|&b| b as f64).collect();
    plan.forward_negacyclic(&s_signed, &mut fs);
    for j in 0..n / 2 {
        fa[j] = fa[j].mul(fs[j]);
    }
    plan.inverse_negacyclic_add_torus(&mut fa, out);
}

/// `out -= a * s` for binary s.
pub fn mul_binary_sub_into(plan: &FftPlan, a_torus: &[u64], s_binary: &[u64], out: &mut [u64]) {
    let n = a_torus.len();
    let mut tmp = vec![0u64; n];
    mul_binary_add_into(plan, a_torus, s_binary, &mut tmp);
    sub_assign(out, &tmp);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    #[test]
    fn rotate_by_zero_is_identity() {
        let p: Vec<u64> = (0..8).collect();
        let mut out = vec![0u64; 8];
        rotate_into(&p, 0, &mut out);
        assert_eq!(out, p);
    }

    #[test]
    fn rotate_n_negates_and_2n_identity() {
        let p: Vec<u64> = (1..9).collect();
        let mut out = vec![0u64; 8];
        rotate_into(&p, 8, &mut out);
        let neg: Vec<u64> = p.iter().map(|x| x.wrapping_neg()).collect();
        assert_eq!(out, neg);
        rotate_into(&p, 16, &mut out);
        assert_eq!(out, p);
    }

    #[test]
    fn rotate_composes() {
        check("rotate_compose", 30, |rng| {
            let n = 32;
            let p: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            let r1 = rng.below_usize(2 * n);
            let r2 = rng.below_usize(2 * n);
            let mut a = vec![0u64; n];
            let mut b = vec![0u64; n];
            rotate_into(&p, r1, &mut a);
            rotate_into(&a, r2, &mut b);
            let mut direct = vec![0u64; n];
            rotate_into(&p, (r1 + r2) % (2 * n), &mut direct);
            if b != direct {
                return Err(format!("r1={r1} r2={r2}"));
            }
            Ok(())
        });
    }

    #[test]
    fn rotate_sub_matches_separate_ops() {
        check("rotate_sub", 30, |rng| {
            let n = 16;
            let p: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            let r = rng.below_usize(2 * n);
            let mut rot = vec![0u64; n];
            rotate_into(&p, r, &mut rot);
            let expected: Vec<u64> =
                rot.iter().zip(&p).map(|(a, b)| a.wrapping_sub(*b)).collect();
            let mut fused = vec![0u64; n];
            rotate_sub_into(&p, r, &mut fused);
            if fused != expected {
                return Err(format!("r={r}"));
            }
            Ok(())
        });
    }

    #[test]
    fn mul_binary_matches_schoolbook() {
        let mut rng = Rng::new(9);
        let n = 64;
        let plan = FftPlan::new(n);
        let a: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
        let s: Vec<u64> = (0..n).map(|_| rng.next_u64() & 1).collect();
        let mut fast = vec![0u64; n];
        mul_binary_add_into(&plan, &a, &s, &mut fast);
        // Schoolbook: sum of rotations for set bits.
        let mut exact = vec![0u64; n];
        let mut rot = vec![0u64; n];
        for (j, &bit) in s.iter().enumerate() {
            if bit == 1 {
                rotate_into(&a, j, &mut rot);
                add_assign(&mut exact, &rot);
            }
        }
        for (f, e) in fast.iter().zip(&exact) {
            let err = (f.wrapping_sub(*e) as i64).unsigned_abs();
            assert!(err < 1 << 16, "err={err}"); // ~2^-48 of the torus
        }
    }

    #[test]
    fn add_sub_neg_wrap() {
        let mut a = vec![u64::MAX, 1];
        add_assign(&mut a, &[1, 2]);
        assert_eq!(a, vec![0, 3]);
        sub_assign(&mut a, &[1, 5]);
        assert_eq!(a, vec![u64::MAX, u64::MAX.wrapping_sub(1)]);
        neg_assign(&mut a);
        assert_eq!(a, vec![1, 2]);
    }
}
