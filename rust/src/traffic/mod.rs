//! Traffic realism for multi-tenant serving: load generation, QoS
//! admission, and autoscaling.
//!
//! The cluster layer proves *mechanism* — shards, routing, admission
//! bounds, reshard, supervision. This module supplies the *policy* side
//! the ROADMAP's million-user north-star needs, in three layers that
//! compose but do not require each other:
//!
//! - [`loadgen`] — seed-deterministic Zipf-popular, bursty arrival
//!   schedules ([`LoadPlan`]): the adversarial tenant distributions the
//!   QoS and autoscaling layers are tested against, replayable from one
//!   seed like `runtime::faults` plans.
//! - [`qos`] — per-tenant token buckets ([`TokenBucket`]) and a
//!   weighted deficit-round-robin admission queue ([`DrrQueue`]), wired
//!   into `Cluster::submit` via `ClusterOptions::qos`: a hot tenant is
//!   throttled and queued on its own lane instead of starving everyone
//!   behind the shared permit pool.
//! - [`autoscale`] — a metrics-driven control loop
//!   ([`AutoscaledCluster`]) that watches backlog, worst-tenant p99 and
//!   key-cache hit rate against watermarks (with hysteresis and
//!   cooldown) and reshards the cluster live.

pub mod autoscale;
pub mod loadgen;
pub mod qos;

pub use autoscale::{
    AutoscaleController, AutoscaleDecision, AutoscaleObservation, AutoscaleOptions,
    AutoscaledCluster,
};
pub use loadgen::{ArrivalDraw, LoadEvent, LoadPlan, LoadSpec, ZipfSampler};
pub use qos::{DrrQueue, QosOptions, TokenBucket, TokenBucketSpec};
