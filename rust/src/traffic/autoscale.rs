//! Metrics-driven autoscaling: a control loop that reshards the cluster.
//!
//! The cluster already knows how to change size safely —
//! [`Cluster::reshard`] drains in-flight work, rebuilds the ring, and
//! migrates key-cache entries — but something has to *decide* when.
//! [`AutoscaledCluster`] wraps a [`Cluster`] behind a lock and runs a
//! control thread (the same shape as the PR 6 supervisor) that polls the
//! merged [`MetricsSnapshot`] and compares three pressure signals against
//! configurable watermarks:
//!
//! - **backlog per shard** — in-pipeline requests plus the fair-queue
//!   depth, divided by shard count: the primary signal, rises the moment
//!   offered load outruns drain rate;
//! - **worst-tenant p99** ([`MetricsSnapshot::worst_tenant_p99_ms`]) —
//!   catches a single tenant's tail collapsing while aggregate load looks
//!   fine;
//! - **key-cache hit rate** — a cold cache means every request pays key
//!   regeneration; more shards add store capacity.
//!
//! Decisions are deliberately sluggish: a signal must stay beyond its
//! watermark for `hysteresis` consecutive polls before the controller
//! acts, and after any reshard it holds for `cooldown_polls` — a reshard
//! drains the cluster, so the first post-reshard snapshots always look
//! idle, and an eager controller would oscillate up/down forever on that
//! artifact. The high/low watermark gap works the same way from the
//! steady-state side: load between the watermarks is a hold, never a
//! flap. The decision logic lives in the pure [`AutoscaleController`] so
//! tests drive it with synthetic observations poll by poll — no clocks,
//! no threads.
//!
//! Scale events emit obs instants (`autoscale_up` / `autoscale_down`) on
//! the flight-recorder timeline and count into the merged snapshot
//! (`autoscale_ups` / `autoscale_downs`), so a trace of a bursty run
//! shows *when* capacity moved alongside *what* the requests were doing.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, PoisonError, RwLock};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::cluster::{Cluster, ClusterError, ClusterResponse, ReshardError};
use crate::compiler::CompiledPlan;
use crate::coordinator::MetricsSnapshot;
use crate::obs;
use crate::tenant::SessionId;
use crate::tfhe::LweCiphertext;

/// Watermarks and damping for the autoscale control loop.
#[derive(Debug, Clone)]
pub struct AutoscaleOptions {
    /// Shard-count floor; scale-down never goes below it.
    pub min_shards: usize,
    /// Shard-count ceiling; scale-up never exceeds it.
    pub max_shards: usize,
    /// Backlog-per-shard above which the cluster is "hot".
    pub high_watermark: f64,
    /// Backlog-per-shard below which the cluster is "cold". Must sit
    /// strictly below `high_watermark`; the gap is the no-flap band.
    pub low_watermark: f64,
    /// Worst-tenant p99 (ms) that also marks the cluster hot; `0.0`
    /// disables the latency trigger.
    pub p99_high_ms: f64,
    /// Key-cache hit rate below which the cluster is hot (stores are
    /// thrashing); `0.0` disables the cache trigger.
    pub hit_rate_low: f64,
    /// Consecutive hot (or cold) polls required before acting.
    pub hysteresis: u32,
    /// Polls to hold after any reshard before acting again.
    pub cooldown_polls: u32,
    /// Control-loop poll interval.
    pub poll: Duration,
}

impl Default for AutoscaleOptions {
    fn default() -> Self {
        Self {
            min_shards: 1,
            max_shards: 4,
            high_watermark: 4.0,
            low_watermark: 1.0,
            p99_high_ms: 0.0,
            hit_rate_low: 0.0,
            hysteresis: 2,
            cooldown_polls: 3,
            poll: Duration::from_millis(20),
        }
    }
}

impl AutoscaleOptions {
    fn validate(&self) {
        assert!(self.min_shards >= 1, "autoscaler needs at least one shard");
        assert!(self.max_shards >= self.min_shards, "max_shards must be >= min_shards");
        assert!(
            self.high_watermark > self.low_watermark,
            "watermarks must leave a no-flap band (high > low)"
        );
        assert!(self.hysteresis >= 1, "hysteresis of 0 would act on a single noisy poll");
        assert!(self.poll > Duration::ZERO, "poll interval must be positive");
    }
}

/// One poll's worth of pressure signals, gathered from the live cluster
/// (or synthesized by tests).
#[derive(Debug, Clone, Copy)]
pub struct AutoscaleObservation {
    pub shards: usize,
    /// Requests in shard pipelines plus the fair admission queue.
    pub backlog: usize,
    /// `MetricsSnapshot::worst_tenant_p99_ms` (0.0 when no samples yet).
    pub worst_tenant_p99_ms: f64,
    /// Key-cache hits / (hits + misses); 1.0 before any key traffic.
    pub key_hit_rate: f64,
}

/// What the controller wants done after one observation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AutoscaleDecision {
    /// Reshard up to this many shards.
    Up(usize),
    /// Reshard down to this many shards.
    Down(usize),
    Hold,
}

/// The pure decision core: feed it one [`AutoscaleObservation`] per poll,
/// get back a decision. Deterministic — all damping is poll-counted, so
/// a test stepping it N times sees exactly what the control thread sees
/// over N poll intervals.
#[derive(Debug)]
pub struct AutoscaleController {
    opts: AutoscaleOptions,
    hot_streak: u32,
    cold_streak: u32,
    /// Polls since the last reshard; starts past the cooldown so a
    /// fresh controller may act as soon as hysteresis allows.
    since_action: u32,
}

impl AutoscaleController {
    pub fn new(opts: AutoscaleOptions) -> Self {
        opts.validate();
        let since_action = opts.cooldown_polls.saturating_add(1);
        Self { opts, hot_streak: 0, cold_streak: 0, since_action }
    }

    pub fn options(&self) -> &AutoscaleOptions {
        &self.opts
    }

    /// Consume one poll's observation. Streaks accumulate even during
    /// cooldown (pressure that persists through the hold acts on the
    /// first eligible poll), but no decision leaves the cooldown window.
    pub fn decide(&mut self, obs: AutoscaleObservation) -> AutoscaleDecision {
        self.since_action = self.since_action.saturating_add(1);
        let shards = obs.shards.max(1);
        let load = obs.backlog as f64 / shards as f64;
        let hot = load > self.opts.high_watermark
            || (self.opts.p99_high_ms > 0.0 && obs.worst_tenant_p99_ms > self.opts.p99_high_ms)
            || (self.opts.hit_rate_low > 0.0 && obs.key_hit_rate < self.opts.hit_rate_low);
        let cold = !hot && load < self.opts.low_watermark;
        if hot {
            self.hot_streak += 1;
            self.cold_streak = 0;
        } else if cold {
            self.cold_streak += 1;
            self.hot_streak = 0;
        } else {
            // Inside the no-flap band: both streaks reset, nothing
            // accumulates toward either direction.
            self.hot_streak = 0;
            self.cold_streak = 0;
        }
        if self.since_action <= self.opts.cooldown_polls {
            return AutoscaleDecision::Hold;
        }
        if self.hot_streak >= self.opts.hysteresis && shards < self.opts.max_shards {
            self.hot_streak = 0;
            self.cold_streak = 0;
            self.since_action = 0;
            return AutoscaleDecision::Up(shards + 1);
        }
        if self.cold_streak >= self.opts.hysteresis && shards > self.opts.min_shards {
            self.hot_streak = 0;
            self.cold_streak = 0;
            self.since_action = 0;
            return AutoscaleDecision::Down(shards - 1);
        }
        AutoscaleDecision::Hold
    }
}

fn read_cluster(l: &RwLock<Cluster>) -> std::sync::RwLockReadGuard<'_, Cluster> {
    l.read().unwrap_or_else(PoisonError::into_inner)
}

fn write_cluster(l: &RwLock<Cluster>) -> std::sync::RwLockWriteGuard<'_, Cluster> {
    l.write().unwrap_or_else(PoisonError::into_inner)
}

/// A [`Cluster`] with the autoscale control loop attached. Submissions
/// take the read lock (concurrent, cheap); a reshard takes the write
/// lock, so scaling naturally waits for in-flight `submit` calls and
/// blocks new ones for exactly the reshard's duration — the same
/// admission pause `reshard(&mut self)` always implied.
pub struct AutoscaledCluster {
    inner: Arc<RwLock<Cluster>>,
    plan: Arc<CompiledPlan>,
    stop: Arc<AtomicBool>,
    ups: Arc<AtomicU64>,
    downs: Arc<AtomicU64>,
    control: Option<JoinHandle<()>>,
}

impl AutoscaledCluster {
    /// Wrap `cluster` and start the control thread.
    pub fn start(cluster: Cluster, opts: AutoscaleOptions) -> Self {
        let controller = AutoscaleController::new(opts.clone());
        let plan = cluster.plan_handle();
        let inner = Arc::new(RwLock::new(cluster));
        let stop = Arc::new(AtomicBool::new(false));
        let ups = Arc::new(AtomicU64::new(0));
        let downs = Arc::new(AtomicU64::new(0));
        let control = {
            let inner = inner.clone();
            let stop = stop.clone();
            let ups = ups.clone();
            let downs = downs.clone();
            std::thread::spawn(move || control_loop(inner, controller, stop, ups, downs))
        };
        Self { inner, plan, stop, ups, downs, control: Some(control) }
    }

    pub fn submit(
        &self,
        session: impl Into<SessionId>,
        inputs: Vec<LweCiphertext>,
    ) -> Result<ClusterResponse, ClusterError> {
        read_cluster(&self.inner).submit(session, inputs)
    }

    pub fn submit_with_deadline(
        &self,
        session: impl Into<SessionId>,
        inputs: Vec<LweCiphertext>,
        deadline: Duration,
    ) -> Result<ClusterResponse, ClusterError> {
        read_cluster(&self.inner).submit_with_deadline(session, inputs, deadline)
    }

    /// Merged cluster metrics, with this wrapper's scale-event counters
    /// filled in.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut snap = read_cluster(&self.inner).snapshot();
        snap.autoscale_ups += self.ups.load(Ordering::SeqCst);
        snap.autoscale_downs += self.downs.load(Ordering::SeqCst);
        snap
    }

    pub fn shard_snapshots(&self) -> Vec<MetricsSnapshot> {
        read_cluster(&self.inner).shard_snapshots()
    }

    pub fn shard_count(&self) -> usize {
        read_cluster(&self.inner).shard_count()
    }

    pub fn outstanding(&self) -> usize {
        read_cluster(&self.inner).outstanding()
    }

    /// The shared compiled plan (all topologies execute the same
    /// artifact, so this never changes across reshards).
    pub fn plan(&self) -> Arc<CompiledPlan> {
        self.plan.clone()
    }

    /// `(scale_ups, scale_downs)` performed so far.
    pub fn scale_events(&self) -> (u64, u64) {
        (self.ups.load(Ordering::SeqCst), self.downs.load(Ordering::SeqCst))
    }

    /// Run `f` against the wrapped cluster (read-locked) — escape hatch
    /// for callers needing cluster APIs not mirrored here.
    pub fn with_cluster<R>(&self, f: impl FnOnce(&Cluster) -> R) -> R {
        f(&read_cluster(&self.inner))
    }

    /// Stop the control loop, then shut the cluster down (drains every
    /// in-flight request typed, same as [`Cluster::shutdown`]).
    pub fn shutdown(&mut self) {
        self.stop_control();
        write_cluster(&self.inner).shutdown();
    }

    fn stop_control(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.control.take() {
            let _ = h.join();
        }
    }
}

impl Drop for AutoscaledCluster {
    /// The control thread holds an `Arc` to the cluster; without this
    /// join an undropped wrapper would leak the loop (and the cluster)
    /// forever.
    fn drop(&mut self) {
        self.stop_control();
    }
}

fn control_loop(
    inner: Arc<RwLock<Cluster>>,
    mut controller: AutoscaleController,
    stop: Arc<AtomicBool>,
    ups: Arc<AtomicU64>,
    downs: Arc<AtomicU64>,
) {
    let poll = controller.options().poll;
    while !stop.load(Ordering::SeqCst) {
        std::thread::sleep(poll);
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let obs = {
            let c = read_cluster(&inner);
            let snap = c.snapshot();
            let key_total = snap.key_hits + snap.key_misses;
            AutoscaleObservation {
                shards: c.shard_count(),
                backlog: c.inflight() + c.fair_queue_len(),
                worst_tenant_p99_ms: snap.worst_tenant_p99_ms().map_or(0.0, |(_, p)| p),
                key_hit_rate: if key_total == 0 {
                    1.0
                } else {
                    snap.key_hits as f64 / key_total as f64
                },
            }
        };
        let target = match controller.decide(obs) {
            AutoscaleDecision::Hold => continue,
            AutoscaleDecision::Up(n) => n,
            AutoscaleDecision::Down(n) => n,
        };
        let grew = target > obs.shards;
        let result: Result<_, ReshardError> = write_cluster(&inner).reshard(target);
        if result.is_ok() {
            if grew {
                ups.fetch_add(1, Ordering::SeqCst);
                obs::trace::instant("autoscale_up", 0);
            } else {
                downs.fetch_add(1, Ordering::SeqCst);
                obs::trace::instant("autoscale_down", 0);
            }
        }
        // A failed reshard (fixed stores) is a Hold: the controller's
        // cooldown already reset, so it won't hammer the same request
        // every poll.
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(shards: usize, backlog: usize) -> AutoscaleObservation {
        AutoscaleObservation { shards, backlog, worst_tenant_p99_ms: 0.0, key_hit_rate: 1.0 }
    }

    fn controller(opts: AutoscaleOptions) -> AutoscaleController {
        AutoscaleController::new(opts)
    }

    #[test]
    fn scales_up_only_after_hysteresis_consecutive_hot_polls() {
        let mut c = controller(AutoscaleOptions { hysteresis: 3, ..Default::default() });
        // backlog 40 over 1 shard: far above the high watermark.
        assert_eq!(c.decide(obs(1, 40)), AutoscaleDecision::Hold);
        assert_eq!(c.decide(obs(1, 40)), AutoscaleDecision::Hold);
        assert_eq!(c.decide(obs(1, 40)), AutoscaleDecision::Up(2));
    }

    #[test]
    fn one_cool_poll_resets_the_hot_streak() {
        let mut c = controller(AutoscaleOptions { hysteresis: 2, ..Default::default() });
        assert_eq!(c.decide(obs(1, 40)), AutoscaleDecision::Hold);
        // Load dips into the band: streak resets, no action.
        assert_eq!(c.decide(obs(1, 2)), AutoscaleDecision::Hold);
        assert_eq!(c.decide(obs(1, 40)), AutoscaleDecision::Hold);
        assert_eq!(c.decide(obs(1, 40)), AutoscaleDecision::Up(2));
    }

    #[test]
    fn cooldown_blocks_back_to_back_reshards() {
        let mut c = controller(AutoscaleOptions {
            hysteresis: 1,
            cooldown_polls: 3,
            ..Default::default()
        });
        assert_eq!(c.decide(obs(1, 40)), AutoscaleDecision::Up(2));
        // Still hot, but inside the cooldown: held for 3 polls.
        for _ in 0..3 {
            assert_eq!(c.decide(obs(2, 40)), AutoscaleDecision::Hold);
        }
        // First post-cooldown poll acts (streak accumulated through it).
        assert_eq!(c.decide(obs(2, 40)), AutoscaleDecision::Up(3));
    }

    #[test]
    fn scales_down_when_cold_and_respects_min() {
        let mut c = controller(AutoscaleOptions {
            hysteresis: 2,
            cooldown_polls: 0,
            min_shards: 1,
            ..Default::default()
        });
        assert_eq!(c.decide(obs(3, 0)), AutoscaleDecision::Hold);
        assert_eq!(c.decide(obs(3, 0)), AutoscaleDecision::Down(2));
        assert_eq!(c.decide(obs(2, 0)), AutoscaleDecision::Hold);
        assert_eq!(c.decide(obs(2, 0)), AutoscaleDecision::Down(1));
        // At the floor: cold forever, never below min_shards.
        for _ in 0..10 {
            assert_eq!(c.decide(obs(1, 0)), AutoscaleDecision::Hold);
        }
    }

    #[test]
    fn respects_max_shards_ceiling() {
        let mut c = controller(AutoscaleOptions {
            hysteresis: 1,
            cooldown_polls: 0,
            max_shards: 2,
            ..Default::default()
        });
        assert_eq!(c.decide(obs(1, 40)), AutoscaleDecision::Up(2));
        for _ in 0..10 {
            assert_eq!(c.decide(obs(2, 40)), AutoscaleDecision::Hold);
        }
    }

    #[test]
    fn band_between_watermarks_is_a_hold_no_oscillation() {
        let mut c = controller(AutoscaleOptions {
            hysteresis: 1,
            cooldown_polls: 0,
            high_watermark: 4.0,
            low_watermark: 1.0,
            ..Default::default()
        });
        // Load of 2/shard sits inside (1, 4): both streaks stay zero.
        for _ in 0..20 {
            assert_eq!(c.decide(obs(2, 4)), AutoscaleDecision::Hold);
        }
    }

    #[test]
    fn worst_tenant_p99_triggers_scale_up_alone() {
        let mut c = controller(AutoscaleOptions {
            hysteresis: 1,
            p99_high_ms: 50.0,
            ..Default::default()
        });
        // Backlog is calm; only the tenant tail is on fire.
        let o = AutoscaleObservation {
            shards: 1,
            backlog: 2,
            worst_tenant_p99_ms: 80.0,
            key_hit_rate: 1.0,
        };
        assert_eq!(c.decide(o), AutoscaleDecision::Up(2));
    }

    #[test]
    fn cold_key_cache_triggers_scale_up_alone() {
        let mut c = controller(AutoscaleOptions {
            hysteresis: 1,
            hit_rate_low: 0.5,
            ..Default::default()
        });
        let o = AutoscaleObservation {
            shards: 1,
            backlog: 2,
            worst_tenant_p99_ms: 0.0,
            key_hit_rate: 0.2,
        };
        assert_eq!(c.decide(o), AutoscaleDecision::Up(2));
        // Disabled trigger (0.0) ignores the same signal.
        let mut c2 = controller(AutoscaleOptions { hysteresis: 1, ..Default::default() });
        assert_eq!(c2.decide(o), AutoscaleDecision::Hold);
    }

    #[test]
    #[should_panic(expected = "no-flap band")]
    fn inverted_watermarks_are_rejected() {
        let _ = AutoscaleController::new(AutoscaleOptions {
            high_watermark: 1.0,
            low_watermark: 2.0,
            ..Default::default()
        });
    }
}
