//! QoS admission primitives: per-tenant token buckets and a
//! deficit-round-robin fair queue.
//!
//! The cluster's original admission path is one shared permit counter —
//! correct, but a single FIFO: one hot tenant that submits faster than
//! the shards drain occupies every permit and every later tenant queues
//! *behind* its backlog (head-of-line blocking). This module provides the
//! two mechanisms `cluster::Cluster` composes into a fair admission
//! front:
//!
//! - [`TokenBucket`] — classic leaky-bucket rate limiting per tenant.
//!   A bucket holds at most `burst` tokens and refills at `rate_per_s`;
//!   each admitted request costs one token. The enforced invariant is
//!   *exact*: over any window of length `t`, a tenant is admitted at most
//!   `burst + rate_per_s * t` requests (acceptance test (b) of the QoS
//!   suite). Callers pass `now` explicitly, so the arithmetic is
//!   deterministic and unit-testable with synthetic clocks.
//!
//! - [`DrrQueue`] — a weighted deficit-round-robin queue over bounded
//!   per-tenant FIFOs. Every backlogged tenant sits once in an active
//!   ring; each ring visit grants `quantum * weight` units of deficit and
//!   requests cost one unit, so a tenant with 10 000 queued requests and
//!   a tenant with 2 interleave at their weight ratio instead of
//!   first-come-first-served. Order within one tenant stays FIFO. A push
//!   past the per-tenant depth bound is rejected typed (the caller maps
//!   it to `ClusterError::TenantQueueFull`) — the hot tenant's *own* lane
//!   fills; nobody else's latency does.
//!
//! [`QosOptions`] bundles the knobs the cluster plumbs from
//! `ClusterOptions` (and `serve --tenant-rate ...`). Everything here is
//! pure data structure — no threads, no locks; the cluster owns the
//! dispatcher loop that drains the queue into shards.

use std::collections::{BTreeMap, VecDeque};
use std::time::{Duration, Instant};

/// Parameters of one tenant's token bucket. `burst` is the bucket
/// capacity (max tokens held, therefore max back-to-back admissions);
/// `rate_per_s` is the steady-state refill rate.
#[derive(Debug, Clone, PartialEq)]
pub struct TokenBucketSpec {
    pub rate_per_s: f64,
    pub burst: f64,
}

impl TokenBucketSpec {
    /// Panics on non-positive rate or a burst below one token (such a
    /// bucket could never admit anything).
    pub fn new(rate_per_s: f64, burst: f64) -> Self {
        assert!(rate_per_s > 0.0, "token bucket refill rate must be positive");
        assert!(burst >= 1.0, "token bucket burst below 1 can never admit a request");
        Self { rate_per_s, burst }
    }
}

/// One tenant's bucket state. Starts full (`burst` tokens): a fresh
/// tenant may immediately spend its whole burst allowance.
#[derive(Debug)]
pub struct TokenBucket {
    spec: TokenBucketSpec,
    tokens: f64,
    last: Instant,
}

impl TokenBucket {
    pub fn new(spec: TokenBucketSpec, now: Instant) -> Self {
        Self { tokens: spec.burst, spec, last: now }
    }

    /// Refill for the elapsed time and try to spend one token. `now`
    /// earlier than the previous call refills nothing (the clock is
    /// treated as monotone). The refill saturates at `burst`, which is
    /// what makes the admitted-count bound exact: tokens never
    /// accumulate beyond one burst regardless of idle time.
    pub fn try_take(&mut self, now: Instant) -> bool {
        let dt = now.saturating_duration_since(self.last).as_secs_f64();
        self.tokens = (self.tokens + dt * self.spec.rate_per_s).min(self.spec.burst);
        self.last = now;
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }

    /// Tokens that would be available at `now` (diagnostics; does not
    /// advance the bucket).
    pub fn available(&self, now: Instant) -> f64 {
        let dt = now.saturating_duration_since(self.last).as_secs_f64();
        (self.tokens + dt * self.spec.rate_per_s).min(self.spec.burst)
    }
}

/// QoS configuration the cluster plumbs through `ClusterOptions::qos`.
/// `None` for the whole struct means QoS off — the cluster keeps its
/// original direct admission path bit-for-bit.
#[derive(Debug, Clone)]
pub struct QosOptions {
    /// Per-tenant rate limit. `None` disables throttling (fair queueing
    /// still applies).
    pub bucket: Option<TokenBucketSpec>,
    /// Bound on each tenant's FIFO in the fair admission queue; a push
    /// past it fails typed (`TenantQueueFull`).
    pub tenant_queue_depth: usize,
    /// Deficit-round-robin quantum: requests granted per ring visit per
    /// unit of weight.
    pub quantum: u32,
    /// Per-tenant scheduling weights (missing tenants weigh 1).
    pub weights: BTreeMap<u64, u32>,
    /// Dispatcher poll cadence while blocked (waiting for a free permit
    /// or sweeping cancelled entries).
    pub poll: Duration,
}

impl Default for QosOptions {
    fn default() -> Self {
        Self {
            bucket: None,
            tenant_queue_depth: 64,
            quantum: 1,
            weights: BTreeMap::new(),
            poll: Duration::from_millis(1),
        }
    }
}

impl QosOptions {
    /// Panics on degenerate configuration (asserted once at cluster
    /// construction, like the `queue_depth != Some(0)` check).
    pub fn validate(&self) {
        assert!(self.tenant_queue_depth >= 1, "a tenant queue of depth 0 could never admit");
        assert!(self.quantum >= 1, "a DRR quantum of 0 never grants service");
        assert!(self.poll > Duration::ZERO, "dispatcher poll must be positive");
    }
}

/// One tenant's lane in the DRR ring.
#[derive(Debug)]
struct Lane<T> {
    fifo: VecDeque<T>,
    /// Service units remaining in the current ring visit (0 between
    /// visits; topped up to `quantum * weight` when the visit starts).
    deficit: u64,
    weight: u64,
    in_ring: bool,
}

/// Weighted deficit-round-robin queue over bounded per-tenant FIFOs.
/// Single-threaded by design (the cluster wraps it in its own mutex):
/// `push` from submitters, `pop` from the dispatcher.
#[derive(Debug)]
pub struct DrrQueue<T> {
    quantum: u64,
    depth: usize,
    lanes: BTreeMap<u64, Lane<T>>,
    /// Tenants with a non-empty FIFO, in service order.
    ring: VecDeque<u64>,
    len: usize,
}

impl<T> DrrQueue<T> {
    pub fn new(quantum: u32, tenant_depth: usize) -> Self {
        assert!(quantum >= 1, "a DRR quantum of 0 never grants service");
        assert!(tenant_depth >= 1, "a tenant queue of depth 0 could never admit");
        Self {
            quantum: u64::from(quantum),
            depth: tenant_depth,
            lanes: BTreeMap::new(),
            ring: VecDeque::new(),
            len: 0,
        }
    }

    /// Set a tenant's scheduling weight (clamped to >= 1). Takes effect
    /// at the tenant's next ring visit.
    pub fn set_weight(&mut self, tenant: u64, weight: u32) {
        let w = u64::from(weight.max(1));
        self.lanes
            .entry(tenant)
            .or_insert_with(|| Lane { fifo: VecDeque::new(), deficit: 0, weight: 1, in_ring: false })
            .weight = w;
    }

    /// Enqueue one item on `tenant`'s lane. `Err` hands the item back
    /// when the lane is at its depth bound — only this tenant's lane is
    /// full; other tenants are unaffected.
    pub fn push(&mut self, tenant: u64, item: T) -> Result<(), T> {
        let lane = self
            .lanes
            .entry(tenant)
            .or_insert_with(|| Lane { fifo: VecDeque::new(), deficit: 0, weight: 1, in_ring: false });
        if lane.fifo.len() >= self.depth {
            return Err(item);
        }
        lane.fifo.push_back(item);
        self.len += 1;
        if !lane.in_ring {
            lane.in_ring = true;
            self.ring.push_back(tenant);
        }
        Ok(())
    }

    /// Dequeue the next item in weighted-fair order. Within one ring
    /// visit a tenant is served up to `quantum * weight` items, then the
    /// ring rotates; a tenant whose lane empties leaves the ring (and
    /// rejoins at the back on its next push).
    pub fn pop(&mut self) -> Option<(u64, T)> {
        while let Some(&tenant) = self.ring.front() {
            let lane = self.lanes.get_mut(&tenant).expect("ring tenant has a lane");
            if lane.fifo.is_empty() {
                lane.in_ring = false;
                lane.deficit = 0;
                self.ring.pop_front();
                continue;
            }
            if lane.deficit == 0 {
                // New visit: grant this tenant's full quantum.
                lane.deficit = self.quantum * lane.weight;
            }
            let item = lane.fifo.pop_front().expect("checked non-empty");
            self.len -= 1;
            lane.deficit -= 1;
            if lane.fifo.is_empty() {
                lane.in_ring = false;
                lane.deficit = 0;
                self.ring.pop_front();
            } else if lane.deficit == 0 {
                // Visit exhausted: rotate to the back of the ring.
                self.ring.pop_front();
                self.ring.push_back(tenant);
            }
            return Some((tenant, item));
        }
        None
    }

    /// Total queued items across all lanes.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Queued items on one tenant's lane.
    pub fn tenant_len(&self, tenant: u64) -> usize {
        self.lanes.get(&tenant).map_or(0, |l| l.fifo.len())
    }

    /// Remove and return everything (shutdown drain), in fair order.
    pub fn drain(&mut self) -> Vec<(u64, T)> {
        let mut out = Vec::with_capacity(self.len);
        while let Some(e) = self.pop() {
            out.push(e);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_bucket_admits_burst_then_enforces_rate_exactly() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(TokenBucketSpec::new(100.0, 5.0), t0);
        // The burst drains back-to-back...
        let burst = (0..10).filter(|_| b.try_take(t0)).count();
        assert_eq!(burst, 5, "exactly the burst allowance admits at t0");
        // ...then admission over a 100 ms window is bounded by rate * t.
        let mut admitted = 0u32;
        for ms in 1..=100u64 {
            let now = t0 + Duration::from_millis(ms);
            // Offer far more than the rate allows.
            for _ in 0..4 {
                if b.try_take(now) {
                    admitted += 1;
                }
            }
        }
        // Exact bound: burst already spent, refill is 100/s * 0.1 s = 10
        // tokens (fp slack of one token allowed below the bound).
        assert!(admitted <= 10, "admitted {admitted} > rate * elapsed");
        assert!(admitted >= 9, "refill undershoot: {admitted}");
    }

    #[test]
    fn token_bucket_refill_saturates_at_burst() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(TokenBucketSpec::new(1000.0, 3.0), t0);
        // A long idle period must not bank more than one burst.
        let later = t0 + Duration::from_secs(60);
        assert!((b.available(later) - 3.0).abs() < 1e-9);
        let granted = (0..10).filter(|_| b.try_take(later)).count();
        assert_eq!(granted, 3, "idle time never accumulates beyond the burst");
    }

    #[test]
    fn drr_interleaves_backlogged_tenants_at_quantum_granularity() {
        let mut q: DrrQueue<u32> = DrrQueue::new(2, 64);
        for i in 0..12 {
            q.push(1, 100 + i).unwrap();
        }
        for i in 0..4 {
            q.push(2, 200 + i).unwrap();
            q.push(3, 300 + i).unwrap();
        }
        let order: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|(t, _)| t).collect();
        // Quantum 2, equal weights: two from each backlogged tenant per
        // round. Tenant 1's 100x backlog cannot delay 2 and 3 beyond its
        // own quantum share.
        assert_eq!(
            order,
            vec![1, 1, 2, 2, 3, 3, 1, 1, 2, 2, 3, 3, 1, 1, 1, 1, 1, 1, 1, 1],
            "hot tenant is confined to its quantum share while others are backlogged"
        );
    }

    #[test]
    fn drr_respects_weights() {
        let mut q: DrrQueue<u32> = DrrQueue::new(1, 64);
        q.set_weight(1, 2);
        for i in 0..8 {
            q.push(1, i).unwrap();
        }
        for i in 0..4 {
            q.push(2, i).unwrap();
        }
        let order: Vec<u64> = (0..6).map(|_| q.pop().unwrap().0).collect();
        assert_eq!(order, vec![1, 1, 2, 1, 1, 2], "weight 2 earns twice the service share");
    }

    #[test]
    fn drr_bounds_each_lane_independently() {
        let mut q: DrrQueue<u32> = DrrQueue::new(1, 2);
        q.push(7, 0).unwrap();
        q.push(7, 1).unwrap();
        assert_eq!(q.push(7, 2), Err(2), "lane at depth rejects, returning the item");
        // Another tenant is unaffected by tenant 7's full lane.
        q.push(8, 9).unwrap();
        assert_eq!(q.len(), 3);
        assert_eq!(q.tenant_len(7), 2);
        assert_eq!(q.tenant_len(8), 1);
    }

    #[test]
    fn drr_lane_rejoins_ring_at_the_back_after_draining() {
        let mut q: DrrQueue<u32> = DrrQueue::new(1, 8);
        q.push(1, 10).unwrap();
        q.push(2, 20).unwrap();
        assert_eq!(q.pop(), Some((1, 10)));
        // Tenant 1 drained and left the ring; a fresh push rejoins behind
        // tenant 2.
        q.push(1, 11).unwrap();
        assert_eq!(q.pop(), Some((2, 20)));
        assert_eq!(q.pop(), Some((1, 11)));
        assert_eq!(q.pop(), None);
        assert!(q.is_empty());
    }

    #[test]
    fn drr_drain_empties_in_fair_order() {
        let mut q: DrrQueue<u32> = DrrQueue::new(1, 8);
        for i in 0..3 {
            q.push(1, i).unwrap();
            q.push(2, 10 + i).unwrap();
        }
        let drained = q.drain();
        assert_eq!(drained.len(), 6);
        assert!(q.is_empty());
        let tenants: Vec<u64> = drained.iter().map(|(t, _)| *t).collect();
        assert_eq!(tenants, vec![1, 2, 1, 2, 1, 2]);
    }

    #[test]
    #[should_panic(expected = "depth 0")]
    fn drr_rejects_zero_depth() {
        let _ = DrrQueue::<u32>::new(1, 0);
    }
}
