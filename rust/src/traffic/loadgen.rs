//! Seed-deterministic traffic generation: Zipf tenant popularity and
//! bursty arrival schedules.
//!
//! Real multi-tenant serving traffic is not uniform — a handful of hot
//! tenants dominate request volume (classically Zipf-distributed) and
//! arrivals cluster into bursts rather than a smooth stream. The QoS
//! and autoscaling layers exist precisely for that shape, so the tests
//! and benches need a generator that reproduces it *deterministically*:
//! like [`crate::runtime::faults`], an entire load trace is a pure
//! function of one seed, replayable in CI and shrinkable in bug
//! reports.
//!
//! Determinism is stronger than "same seed, same trace": every arrival's
//! random draws come from an RNG forked per *event index*
//! ([`crate::tfhe::keygen::fork_seed`], the same construction keygen
//! uses for chunk-invariant key material). Event `i`'s tenant, gap, and
//! thinning coin depend on `(seed, spec, i)` alone — never on how many
//! events were minted before it or on which thread minted it — so a
//! schedule minted in parallel chunks is bitwise-identical to the
//! sequential one (proven by `loadgen_determinism` in the QoS suite).
//!
//! The arrival process is an on/off burst model with Poisson thinning:
//! within an on-period of `burst_len` arrivals, inter-arrival gaps are
//! exponential with mean `mean_gap` (a Poisson process); between bursts
//! the schedule inserts an `off_gap` quiet period; and each arrival is
//! kept with probability `keep` (thinning a Poisson process yields a
//! Poisson process, so `keep` scales offered load without reshaping
//! it).

use std::collections::BTreeMap;
use std::time::Duration;

use crate::tenant::SessionId;
use crate::tfhe::keygen::fork_seed;
use crate::util::rng::Rng;

/// Domain tag separating loadgen RNG streams from every other
/// `fork_seed` consumer (keygen, tenant seeds, fault plans).
const DOMAIN_ARRIVAL: u64 = 0x7F1C_70AD;

/// Inverse-CDF sampler for the Zipf distribution over tenant ranks
/// `0..tenants`: rank `r` has weight `(r + 1)^-s`. Exponent `s = 0`
/// degenerates to uniform; `s` around 1 is the classic web-traffic
/// skew; larger `s` concentrates harder on the head.
#[derive(Debug, Clone)]
pub struct ZipfSampler {
    /// Cumulative normalized weights; `cdf[r]` = P(rank <= r).
    cdf: Vec<f64>,
    s: f64,
}

impl ZipfSampler {
    pub fn new(tenants: usize, s: f64) -> Self {
        assert!(tenants >= 1, "a population of 0 tenants cannot be sampled");
        assert!(s >= 0.0, "Zipf exponent must be non-negative");
        let mut cdf = Vec::with_capacity(tenants);
        let mut total = 0.0;
        for r in 0..tenants {
            total += ((r + 1) as f64).powf(-s);
            cdf.push(total);
        }
        for c in &mut cdf {
            *c /= total;
        }
        // Pin the tail so a uniform draw of exactly 1.0 - eps always
        // lands inside the support.
        *cdf.last_mut().expect("tenants >= 1") = 1.0;
        Self { cdf, s }
    }

    /// Number of ranks in the population.
    pub fn tenants(&self) -> usize {
        self.cdf.len()
    }

    pub fn exponent(&self) -> f64 {
        self.s
    }

    /// Analytic probability of rank `r` (for empirical-vs-analytic
    /// tolerance tests).
    pub fn pmf(&self, rank: usize) -> f64 {
        assert!(rank < self.cdf.len());
        if rank == 0 {
            self.cdf[0]
        } else {
            self.cdf[rank] - self.cdf[rank - 1]
        }
    }

    /// Draw one rank (one `uniform()` consumed — the fixed draw count is
    /// what keeps per-index forked streams aligned).
    pub fn sample(&self, rng: &mut Rng) -> u64 {
        let u = rng.uniform();
        let r = self.cdf.partition_point(|&c| c < u);
        r.min(self.cdf.len() - 1) as u64
    }
}

/// Shape of a generated load trace. The schedule is a pure function of
/// `(seed, spec)`.
#[derive(Debug, Clone)]
pub struct LoadSpec {
    /// Tenant population size; sessions are ranks `0..tenants`.
    pub tenants: usize,
    /// Zipf popularity exponent (0 = uniform).
    pub zipf_s: f64,
    /// Arrivals drawn before thinning.
    pub events: usize,
    /// Mean exponential inter-arrival gap within an on-burst.
    pub mean_gap: Duration,
    /// Arrivals per on-period; 0 disables off-gaps (one endless burst).
    pub burst_len: usize,
    /// Quiet gap inserted between consecutive bursts.
    pub off_gap: Duration,
    /// Poisson thinning: probability each drawn arrival is kept.
    pub keep: f64,
}

impl Default for LoadSpec {
    fn default() -> Self {
        Self {
            tenants: 8,
            zipf_s: 1.0,
            events: 64,
            mean_gap: Duration::from_millis(1),
            burst_len: 16,
            off_gap: Duration::from_millis(10),
            keep: 1.0,
        }
    }
}

impl LoadSpec {
    fn validate(&self) {
        assert!(self.tenants >= 1, "loadgen needs at least one tenant");
        assert!(self.keep > 0.0 && self.keep <= 1.0, "thinning probability must be in (0, 1]");
    }
}

/// One scheduled arrival: a request for `session` offered at offset
/// `at` from the trace start.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LoadEvent {
    pub at: Duration,
    pub session: SessionId,
}

/// The random draws of one event index, before schedule assembly.
/// Exposed so determinism tests can mint draws for disjoint index
/// ranges on different threads and compare against the sequential
/// schedule.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ArrivalDraw {
    pub session: SessionId,
    /// Exponential gap since the previous arrival (before off-gap
    /// insertion).
    pub gap: Duration,
    /// Thinning outcome: `false` means the arrival is dropped (its gap
    /// still advances the clock — thinning removes points from the
    /// process, it does not compress time).
    pub kept: bool,
}

/// A fully materialized load trace.
#[derive(Debug, Clone)]
pub struct LoadPlan {
    seed: u64,
    spec: LoadSpec,
    events: Vec<LoadEvent>,
}

impl LoadPlan {
    /// The per-index draw function: event `i`'s randomness comes from
    /// `fork_seed(seed, DOMAIN_ARRIVAL, i)` alone, in a fixed draw
    /// order (tenant, gap, thinning coin).
    pub fn draw(sampler: &ZipfSampler, seed: u64, spec: &LoadSpec, index: u64) -> ArrivalDraw {
        let mut rng = Rng::new(fork_seed(seed, DOMAIN_ARRIVAL, index));
        let session = SessionId(sampler.sample(&mut rng));
        // Inverse-CDF exponential; 1 - u is in (0, 1] so the log is
        // finite.
        let u = rng.uniform();
        let gap = spec.mean_gap.as_secs_f64() * -(1.0 - u).ln();
        let kept = rng.uniform() < spec.keep;
        ArrivalDraw { session, gap: Duration::from_secs_f64(gap), kept }
    }

    /// Materialize the whole schedule for `(seed, spec)`.
    pub fn from_seed(seed: u64, spec: &LoadSpec) -> Self {
        spec.validate();
        let sampler = ZipfSampler::new(spec.tenants, spec.zipf_s);
        let mut at = Duration::ZERO;
        let mut events = Vec::new();
        for i in 0..spec.events as u64 {
            if spec.burst_len > 0 && i > 0 && i % spec.burst_len as u64 == 0 {
                at += spec.off_gap;
            }
            let d = Self::draw(&sampler, seed, spec, i);
            at += d.gap;
            if d.kept {
                events.push(LoadEvent { at, session: d.session });
            }
        }
        Self { seed, spec: spec.clone(), events }
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn spec(&self) -> &LoadSpec {
        &self.spec
    }

    /// Kept arrivals in time order.
    pub fn events(&self) -> &[LoadEvent] {
        &self.events
    }

    /// Requests per session across the trace.
    pub fn tenant_histogram(&self) -> BTreeMap<u64, u64> {
        let mut h = BTreeMap::new();
        for e in &self.events {
            *h.entry(e.session.0).or_insert(0u64) += 1;
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zipf_cdf_is_monotone_and_normalized() {
        let z = ZipfSampler::new(100, 1.2);
        assert_eq!(z.tenants(), 100);
        let mut prev = 0.0;
        let mut total = 0.0;
        for r in 0..100 {
            let p = z.pmf(r);
            assert!(p > 0.0);
            assert!(p <= prev || r == 0, "pmf must be non-increasing in rank");
            prev = p;
            total += p;
        }
        assert!((total - 1.0).abs() < 1e-9);
    }

    #[test]
    fn zipf_zero_exponent_is_uniform() {
        let z = ZipfSampler::new(10, 0.0);
        for r in 0..10 {
            assert!((z.pmf(r) - 0.1).abs() < 1e-9);
        }
    }

    #[test]
    fn zipf_empirical_matches_analytic_within_tolerance() {
        let z = ZipfSampler::new(64, 1.2);
        let mut rng = Rng::new(0x51AB);
        let n = 100_000u64;
        let mut counts = vec![0u64; 64];
        for _ in 0..n {
            counts[z.sample(&mut rng) as usize] += 1;
        }
        // Head ranks have plenty of mass; 10% relative tolerance is
        // generous at n = 100k and pins gross CDF bugs.
        for r in 0..8 {
            let emp = counts[r] as f64 / n as f64;
            let ana = z.pmf(r);
            assert!(
                (emp - ana).abs() / ana < 0.10,
                "rank {r}: empirical {emp:.5} vs analytic {ana:.5}"
            );
        }
        // And the whole-population mass balances.
        assert_eq!(counts.iter().sum::<u64>(), n);
    }

    #[test]
    fn load_plan_is_a_pure_function_of_the_seed() {
        let spec = LoadSpec { events: 200, keep: 0.8, ..LoadSpec::default() };
        let a = LoadPlan::from_seed(7, &spec);
        let b = LoadPlan::from_seed(7, &spec);
        assert_eq!(a.events(), b.events(), "same seed must replay the identical trace");
        let c = LoadPlan::from_seed(8, &spec);
        assert_ne!(a.events(), c.events(), "distinct seeds must diverge");
        // Thinning dropped some arrivals but kept the clock honest.
        assert!(a.events().len() < 200);
        assert!(a.events().len() > 100);
    }

    #[test]
    fn arrivals_are_time_ordered_with_off_gaps_between_bursts() {
        let spec = LoadSpec {
            events: 48,
            burst_len: 16,
            off_gap: Duration::from_millis(50),
            mean_gap: Duration::from_micros(100),
            ..LoadSpec::default()
        };
        let plan = LoadPlan::from_seed(3, &spec);
        let ev = plan.events();
        assert!(ev.windows(2).all(|w| w[0].at <= w[1].at), "schedule must be time-ordered");
        // The off-gap dominates the tiny in-burst gaps, so the trace
        // spans at least the two inserted quiet periods.
        assert!(ev.last().unwrap().at >= Duration::from_millis(100));
    }

    #[test]
    fn per_index_draws_are_independent_of_mint_order() {
        let spec = LoadSpec::default();
        let sampler = ZipfSampler::new(spec.tenants, spec.zipf_s);
        // Drawing index 5 cold equals drawing it after 0..5.
        let cold = LoadPlan::draw(&sampler, 42, &spec, 5);
        for i in 0..5 {
            let _ = LoadPlan::draw(&sampler, 42, &spec, i);
        }
        let warm = LoadPlan::draw(&sampler, 42, &spec, 5);
        assert_eq!(cold, warm);
    }
}
