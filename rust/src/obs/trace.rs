//! Flight-recorder tracing: bounded per-thread ring buffers of trace
//! events, exportable as Chrome trace-event JSON (`chrome://tracing`,
//! Perfetto).
//!
//! Every recording thread owns one ring (registered globally on first
//! use) and writes to it without contending with other recorders; at the
//! ring's capacity the oldest events are overwritten — a crash or a
//! long soak always leaves the *most recent* window of activity, which is
//! the flight-recorder contract. [`drain`] collects every ring into one
//! timestamp-sorted event list.
//!
//! Event vocabulary on the serving path:
//! - async `b`/`e` pairs named `request`, keyed by the per-request trace
//!   id minted at admission — the cross-thread request lifetime;
//! - duration (`X`) spans on worker threads: `exec_batch` around each
//!   keyed sub-batch, and `keyswitch`/`blind_rotate`/`sample_extract`
//!   stage spans per schedule batch inside it;
//! - instant (`i`) events for request terminals (`served`, `exec_failed`,
//!   `timeout`, …) and fault/supervision activity (`fault_panic`,
//!   `fault_delay`, `fault_resolve`, `worker_respawn`, `retry`,
//!   `redirect`, `shard_restart`).
//!
//! Recording is gated by [`super::enabled`] at every entry point; the
//! disabled path is a single relaxed atomic load.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, PoisonError};
use std::time::Instant;

use crate::util::json::{arr, num, obj, s, JsonValue};

/// Default per-thread ring capacity (events). At ~48 bytes/event this is
/// under 1 MiB per recording thread.
pub const RING_CAPACITY: usize = 16384;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// Duration event (`ph: "X"`); `dur_ns` is meaningful.
    Span,
    /// Thread-scoped instant (`ph: "i"`).
    Instant,
    /// Async begin (`ph: "b"`), keyed by the trace id.
    AsyncBegin,
    /// Async end (`ph: "e"`), keyed by the trace id.
    AsyncEnd,
}

#[derive(Debug, Clone, Copy)]
pub struct TraceEvent {
    pub name: &'static str,
    pub kind: EventKind,
    /// Request trace id (0 = not request-scoped, e.g. stage spans).
    pub trace: u64,
    /// Nanoseconds since the recorder epoch.
    pub ts_ns: u64,
    /// Span duration (0 for non-span events).
    pub dur_ns: u64,
    /// Recorder thread id (process-local, dense).
    pub tid: u64,
}

/// One thread's bounded event buffer; overwrites oldest at capacity.
struct Ring {
    tid: u64,
    events: Vec<TraceEvent>,
    head: usize,
    cap: usize,
    dropped: u64,
}

impl Ring {
    fn new(tid: u64, cap: usize) -> Self {
        Self { tid, events: Vec::new(), head: 0, cap, dropped: 0 }
    }

    fn push(&mut self, mut ev: TraceEvent) {
        ev.tid = self.tid;
        if self.events.len() < self.cap {
            self.events.push(ev);
        } else {
            self.events[self.head] = ev;
            self.head = (self.head + 1) % self.cap;
            self.dropped += 1;
        }
    }

    /// Events in recording order; leaves the ring empty.
    fn take(&mut self) -> Vec<TraceEvent> {
        let head = std::mem::take(&mut self.head);
        let mut evs = std::mem::take(&mut self.events);
        evs.rotate_left(head);
        evs
    }
}

static EPOCH: OnceLock<Instant> = OnceLock::new();
static NEXT_TID: AtomicU64 = AtomicU64::new(1);
static REGISTRY: Mutex<Vec<Arc<Mutex<Ring>>>> = Mutex::new(Vec::new());

thread_local! {
    static LOCAL: RefCell<Option<Arc<Mutex<Ring>>>> = const { RefCell::new(None) };
}

/// Pin the recorder epoch (idempotent). Called by [`super::enable`] so
/// every timestamp taken afterwards is relative to one instant.
pub(super) fn init_epoch() {
    let _ = EPOCH.get_or_init(Instant::now);
}

fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

fn now_ns() -> u64 {
    // `duration_since` saturates to zero for pre-epoch instants.
    u64::try_from(Instant::now().duration_since(epoch()).as_nanos()).unwrap_or(u64::MAX)
}

fn with_ring(f: impl FnOnce(&mut Ring)) {
    LOCAL.with(|slot| {
        let mut slot = slot.borrow_mut();
        let ring = slot.get_or_insert_with(|| {
            let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
            let ring = Arc::new(Mutex::new(Ring::new(tid, RING_CAPACITY)));
            REGISTRY.lock().unwrap_or_else(PoisonError::into_inner).push(ring.clone());
            ring
        });
        f(&mut ring.lock().unwrap_or_else(PoisonError::into_inner));
    });
}

/// Start a span timer: `Some(now)` when tracing is enabled, `None`
/// otherwise. Pair with [`span`].
#[inline]
pub fn start() -> Option<Instant> {
    if super::enabled() {
        Some(Instant::now())
    } else {
        None
    }
}

/// Record a duration span begun at `started` (no-op when `None`, i.e.
/// when tracing was disabled at [`start`] time).
pub fn span(name: &'static str, trace: u64, started: Option<Instant>) {
    let Some(t0) = started else { return };
    let dur_ns = u64::try_from(t0.elapsed().as_nanos()).unwrap_or(u64::MAX);
    let ts_ns = u64::try_from(t0.duration_since(epoch()).as_nanos()).unwrap_or(u64::MAX);
    with_ring(|r| {
        r.push(TraceEvent { name, kind: EventKind::Span, trace, ts_ns, dur_ns, tid: 0 })
    });
}

/// Record a thread-scoped instant event.
pub fn instant(name: &'static str, trace: u64) {
    if !super::enabled() {
        return;
    }
    let ts_ns = now_ns();
    with_ring(|r| {
        r.push(TraceEvent { name, kind: EventKind::Instant, trace, ts_ns, dur_ns: 0, tid: 0 })
    });
}

/// Begin the async (cross-thread) span for `trace`.
pub fn async_begin(name: &'static str, trace: u64) {
    if !super::enabled() || trace == 0 {
        return;
    }
    let ts_ns = now_ns();
    with_ring(|r| {
        r.push(TraceEvent { name, kind: EventKind::AsyncBegin, trace, ts_ns, dur_ns: 0, tid: 0 })
    });
}

/// End the async span for `trace`.
pub fn async_end(name: &'static str, trace: u64) {
    if !super::enabled() || trace == 0 {
        return;
    }
    let ts_ns = now_ns();
    with_ring(|r| {
        r.push(TraceEvent { name, kind: EventKind::AsyncEnd, trace, ts_ns, dur_ns: 0, tid: 0 })
    });
}

/// Collect and clear every thread's ring; events come back sorted by
/// timestamp. Rings of finished threads are included (the registry keeps
/// them alive), so nothing recorded before a worker exited is lost.
pub fn drain() -> Vec<TraceEvent> {
    let rings = REGISTRY.lock().unwrap_or_else(PoisonError::into_inner);
    let mut all = Vec::new();
    for ring in rings.iter() {
        all.extend(ring.lock().unwrap_or_else(PoisonError::into_inner).take());
    }
    all.sort_by_key(|e| e.ts_ns);
    all
}

/// Discard all buffered events and reset overflow counters (ring
/// registrations persist). Test isolation helper.
pub fn reset() {
    let rings = REGISTRY.lock().unwrap_or_else(PoisonError::into_inner);
    for ring in rings.iter() {
        let mut r = ring.lock().unwrap_or_else(PoisonError::into_inner);
        r.take();
        r.dropped = 0;
    }
}

/// Total events overwritten (flight-recorder overflow) across all rings
/// since the last [`reset`].
pub fn dropped() -> u64 {
    REGISTRY
        .lock()
        .unwrap_or_else(PoisonError::into_inner)
        .iter()
        .map(|r| r.lock().unwrap_or_else(PoisonError::into_inner).dropped)
        .sum()
}

/// Serialize events as Chrome trace-event JSON (object format:
/// `{"traceEvents": [...]}`), loadable in `chrome://tracing` / Perfetto.
pub fn chrome_trace_json(events: &[TraceEvent]) -> JsonValue {
    let mut evs = Vec::with_capacity(events.len());
    for e in events {
        let mut fields = vec![
            ("name", s(e.name)),
            ("pid", num(1.0)),
            ("tid", num(e.tid as f64)),
            ("ts", num(e.ts_ns as f64 / 1000.0)),
        ];
        match e.kind {
            EventKind::Span => {
                fields.push(("ph", s("X")));
                fields.push(("dur", num(e.dur_ns as f64 / 1000.0)));
            }
            EventKind::Instant => {
                fields.push(("ph", s("i")));
                fields.push(("s", s("t")));
            }
            EventKind::AsyncBegin => {
                fields.push(("ph", s("b")));
                fields.push(("cat", s("request")));
                fields.push(("id", num(e.trace as f64)));
            }
            EventKind::AsyncEnd => {
                fields.push(("ph", s("e")));
                fields.push(("cat", s("request")));
                fields.push(("id", num(e.trace as f64)));
            }
        }
        if e.trace != 0 {
            fields.push(("args", obj(vec![("trace", num(e.trace as f64))])));
        }
        evs.push(obj(fields));
    }
    obj(vec![("traceEvents", arr(evs)), ("displayTimeUnit", s("ms"))])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(name: &'static str, ts: u64) -> TraceEvent {
        TraceEvent { name, kind: EventKind::Instant, trace: 0, ts_ns: ts, dur_ns: 0, tid: 0 }
    }

    #[test]
    fn ring_overwrites_oldest_at_capacity() {
        let mut r = Ring::new(7, 4);
        for i in 0..6u64 {
            r.push(ev("e", i));
        }
        assert_eq!(r.dropped, 2);
        let evs = r.take();
        let ts: Vec<u64> = evs.iter().map(|e| e.ts_ns).collect();
        assert_eq!(ts, vec![2, 3, 4, 5], "oldest two overwritten, order preserved");
        assert!(evs.iter().all(|e| e.tid == 7), "ring stamps its thread id");
        assert!(r.take().is_empty(), "take drains");
    }

    #[test]
    fn chrome_json_shape() {
        let events = [
            TraceEvent {
                name: "request",
                kind: EventKind::AsyncBegin,
                trace: 3,
                ts_ns: 1500,
                dur_ns: 0,
                tid: 1,
            },
            TraceEvent {
                name: "exec_batch",
                kind: EventKind::Span,
                trace: 0,
                ts_ns: 2000,
                dur_ns: 4000,
                tid: 2,
            },
            TraceEvent {
                name: "request",
                kind: EventKind::AsyncEnd,
                trace: 3,
                ts_ns: 9000,
                dur_ns: 0,
                tid: 1,
            },
        ];
        let json = chrome_trace_json(&events);
        let text = json.to_string();
        let parsed = JsonValue::parse(&text).expect("trace JSON must parse");
        let evs = parsed.get("traceEvents").unwrap().as_array().unwrap();
        assert_eq!(evs.len(), 3);
        assert_eq!(evs[0].get("ph").unwrap().as_str(), Some("b"));
        assert_eq!(evs[0].get("id").unwrap().as_f64(), Some(3.0));
        assert_eq!(evs[1].get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(evs[1].get("dur").unwrap().as_f64(), Some(4.0));
        assert_eq!(evs[1].get("ts").unwrap().as_f64(), Some(2.0));
        assert_eq!(evs[2].get("ph").unwrap().as_str(), Some("e"));
    }
}
