//! Zero-dependency observability for the serving stack: flight-recorder
//! tracing ([`trace`]), mergeable per-stage timing histograms ([`hist`]),
//! and cost-model drift attribution ([`drift`]).
//!
//! Everything is gated on one process-wide atomic flag: when
//! [`enabled`] is false (the default), every hook on the hot path is a
//! single relaxed load and an untaken branch — no clocks are read, no
//! ring buffers or histograms are touched, no trace ids are minted
//! (requests carry id 0), and ciphertext outputs plus every
//! `MetricsSnapshot` counter are bitwise-identical to a build without
//! the hooks. `serve` (and any harness that wants the data) opts in with
//! [`enable`].

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::Instant;

pub mod drift;
pub mod hist;
pub mod trace;

use hist::Log2Histogram;

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_TRACE_ID: AtomicU64 = AtomicU64::new(1);

/// Turn observability on process-wide (tracing, stage timing, per-batch
/// attribution). Pins the trace epoch first so every subsequent
/// timestamp shares one origin.
pub fn enable() {
    trace::init_epoch();
    ENABLED.store(true, Ordering::Relaxed);
}

/// Turn observability off. Already-buffered trace events stay in their
/// rings until [`trace::drain`]/[`trace::reset`].
pub fn disable() {
    ENABLED.store(false, Ordering::Relaxed);
}

/// The hot-path gate: one relaxed atomic load.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Mint a per-request trace id. Returns 0 (the "untraced" id) while
/// observability is disabled, so the disabled path allocates nothing.
#[inline]
pub fn next_trace_id() -> u64 {
    if enabled() {
        NEXT_TRACE_ID.fetch_add(1, Ordering::Relaxed)
    } else {
        0
    }
}

/// Start a stage timer: `Some(now)` when enabled, `None` otherwise.
/// The disabled path never reads the clock.
#[inline]
pub fn timer() -> Option<Instant> {
    if enabled() {
        Some(Instant::now())
    } else {
        None
    }
}

/// Nanoseconds elapsed since a [`timer`] start (0 when it was disabled).
#[inline]
pub fn elapsed_ns(started: Option<Instant>) -> u64 {
    match started {
        Some(t0) => u64::try_from(t0.elapsed().as_nanos()).unwrap_or(u64::MAX),
        None => 0,
    }
}

// --- FFT transform meter -------------------------------------------------
//
// Fourier transforms run on whatever thread dispatches them: the worker
// thread on the sequential path, pool threads on the parallel blind
// rotation path. Each thread accumulates transform times into its own
// local histogram (no contention), and the owners harvest: `PbsContext`
// drains the worker's local histogram at `take_fft_hist`, and each pool
// job drains its thread's histogram into the context's shared collector
// when it finishes.

thread_local! {
    static FFT_HIST: RefCell<Log2Histogram> = RefCell::new(Log2Histogram::new());
}

/// Record one Fourier-transform dispatch begun at `started` (no-op when
/// `None`). Called by the FFT plan's dispatch entry points.
#[inline]
pub fn record_fft(started: Option<Instant>) {
    let Some(t0) = started else { return };
    let ns = u64::try_from(t0.elapsed().as_nanos()).unwrap_or(u64::MAX);
    FFT_HIST.with(|h| h.borrow_mut().record(ns));
}

/// Drain the calling thread's FFT histogram.
pub fn take_thread_fft() -> Log2Histogram {
    FFT_HIST.with(|h| std::mem::take(&mut *h.borrow_mut()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_paths_are_inert() {
        // Not serialized against other tests that may enable obs, so only
        // assert the disabled-value contracts that hold regardless of
        // later state.
        if !enabled() {
            assert_eq!(next_trace_id(), 0, "disabled minting must return the untraced id");
            assert!(timer().is_none());
        }
        assert_eq!(elapsed_ns(None), 0);
        record_fft(None); // must not touch the thread-local
    }

    #[test]
    fn thread_fft_meter_drains_per_thread() {
        std::thread::spawn(|| {
            FFT_HIST.with(|h| h.borrow_mut().record(100));
            let h = take_thread_fft();
            assert_eq!(h.count(), 1);
            assert!(take_thread_fft().is_empty(), "drained");
        })
        .join()
        .unwrap();
    }
}
