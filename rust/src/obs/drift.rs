//! Cost-model drift attribution: per-schedule-batch measured execution
//! profiles versus `arch::sim` predictions.
//!
//! The engine walks `CompiledPlan.schedule.batches` in exactly the order
//! the cycle model costs them, so attribution aligns by batch index: the
//! engine accumulates one [`PlanBatchProfile`] per schedule batch
//! (success-only — failed worker batches record nothing, matching the
//! metrics counters), `arch::sim::batch_predictions` produces one
//! [`BatchPrediction`] per batch from the identical schedule walk, and
//! [`attribute`] joins them. KS and PBS counts must match *exactly* on
//! the fault-free subset (the schedule is the single source of truth for
//! both sides); BSK bytes and stage time are ratios — that divergence is
//! the drift signal (e.g. measured BSK falling below `predicted x
//! requests` is the batching key-reuse the model prices per-request).

/// Measured totals for one schedule batch, accumulated across every
/// successful execution of the plan.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PlanBatchProfile {
    /// Times this schedule batch executed (one per worker sub-batch).
    pub executions: u64,
    /// Total requests those executions carried (sum of sub-batch widths).
    pub requests: u64,
    /// `PbsBackend::keyswitch` calls.
    pub ks_calls: u64,
    /// Blind rotations performed (PBS count, i.e. `br_ops x width`).
    pub pbs: u64,
    /// Fused `blind_rotate_batch` sweeps.
    pub br_calls: u64,
    /// Fourier-BSK bytes streamed by this batch's sweeps.
    pub bsk_bytes: u64,
    /// Wall nanoseconds inside keyswitch calls.
    pub ks_ns: u64,
    /// Wall nanoseconds inside blind-rotation sweeps.
    pub br_ns: u64,
    /// Wall nanoseconds inside sample-extract calls.
    pub se_ns: u64,
}

impl PlanBatchProfile {
    pub fn merge(&mut self, other: &Self) {
        self.executions += other.executions;
        self.requests += other.requests;
        self.ks_calls += other.ks_calls;
        self.pbs += other.pbs;
        self.br_calls += other.br_calls;
        self.bsk_bytes += other.bsk_bytes;
        self.ks_ns += other.ks_ns;
        self.br_ns += other.br_ns;
        self.se_ns += other.se_ns;
    }

    /// Total measured stage time.
    pub fn total_ns(&self) -> u64 {
        self.ks_ns + self.br_ns + self.se_ns
    }
}

/// Merge per-batch profile vectors index-wise (shard/worker roll-up).
pub fn merge_profiles(into: &mut Vec<PlanBatchProfile>, other: &[PlanBatchProfile]) {
    if into.len() < other.len() {
        into.resize(other.len(), PlanBatchProfile::default());
    }
    for (a, b) in into.iter_mut().zip(other.iter()) {
        a.merge(b);
    }
}

/// What the cycle model predicts for one schedule batch, **per request**
/// (one program execution's ciphertexts through that batch).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct BatchPrediction {
    /// Keyswitches the schedule lists for this batch.
    pub ks: u64,
    /// Blind rotations (PBS) the schedule lists for this batch.
    pub pbs: u64,
    /// BSK bytes the memory model streams for this batch's window.
    pub bsk_bytes: u64,
    /// Modeled accelerator seconds for this batch's window.
    pub seconds: f64,
}

/// One row of the drift report: measured vs model for one schedule batch.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DriftRow {
    pub batch: usize,
    pub executions: u64,
    pub requests: u64,
    pub measured_ks: u64,
    /// `prediction.ks x requests`.
    pub predicted_ks: u64,
    pub ks_exact: bool,
    pub measured_pbs: u64,
    pub predicted_pbs: u64,
    pub pbs_exact: bool,
    pub measured_bsk_bytes: u64,
    /// `prediction.bsk_bytes x requests` (the model prices the stream
    /// per request; batching amortizes it — ratios below 1.0 are the
    /// key-reuse win).
    pub predicted_bsk_bytes: u64,
    pub bsk_ratio: f64,
    pub measured_ns: u64,
    /// `prediction.seconds x requests`, in ns (accelerator model time —
    /// the measured/model ratio is the CPU-vs-Taurus gap per batch).
    pub predicted_ns: f64,
    pub time_ratio: f64,
}

fn ratio(measured: f64, predicted: f64) -> f64 {
    if predicted > 0.0 {
        measured / predicted
    } else {
        0.0
    }
}

/// Join measured profiles with model predictions by batch index. Both
/// sides come from the same `CompiledPlan.schedule`, so the lengths agree
/// whenever any traffic was profiled; batches that never executed (or a
/// length mismatch from a mixed-plan merge) yield rows with zero
/// measured traffic rather than a panic.
pub fn attribute(measured: &[PlanBatchProfile], predicted: &[BatchPrediction]) -> Vec<DriftRow> {
    let zero = PlanBatchProfile::default();
    predicted
        .iter()
        .enumerate()
        .map(|(i, pred)| {
            let m = measured.get(i).unwrap_or(&zero);
            let predicted_ks = pred.ks * m.requests;
            let predicted_pbs = pred.pbs * m.requests;
            let predicted_bsk_bytes = pred.bsk_bytes * m.requests;
            let predicted_ns = pred.seconds * 1e9 * m.requests as f64;
            DriftRow {
                batch: i,
                executions: m.executions,
                requests: m.requests,
                measured_ks: m.ks_calls,
                predicted_ks,
                ks_exact: m.ks_calls == predicted_ks,
                measured_pbs: m.pbs,
                predicted_pbs,
                pbs_exact: m.pbs == predicted_pbs,
                measured_bsk_bytes: m.bsk_bytes,
                predicted_bsk_bytes,
                bsk_ratio: ratio(m.bsk_bytes as f64, predicted_bsk_bytes as f64),
                measured_ns: m.total_ns(),
                predicted_ns,
                time_ratio: ratio(m.total_ns() as f64, predicted_ns),
            }
        })
        .collect()
}

/// True when every row's KS and PBS counts match the model exactly — the
/// invariant the conformance suite asserts on fault-free traffic.
pub fn counts_exact(rows: &[DriftRow]) -> bool {
    rows.iter().all(|r| r.ks_exact && r.pbs_exact)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn attribute_joins_by_index_and_scales_by_requests() {
        let measured = vec![
            PlanBatchProfile {
                executions: 2,
                requests: 4,
                ks_calls: 8,
                pbs: 12,
                br_calls: 2,
                bsk_bytes: 1000,
                ks_ns: 10,
                br_ns: 20,
                se_ns: 30,
            },
            PlanBatchProfile::default(),
        ];
        let predicted = vec![
            BatchPrediction { ks: 2, pbs: 3, bsk_bytes: 1000, seconds: 1e-6 },
            BatchPrediction { ks: 1, pbs: 1, bsk_bytes: 500, seconds: 1e-6 },
        ];
        let rows = attribute(&measured, &predicted);
        assert_eq!(rows.len(), 2);
        assert!(rows[0].ks_exact && rows[0].pbs_exact);
        assert_eq!(rows[0].predicted_ks, 8);
        assert_eq!(rows[0].predicted_bsk_bytes, 4000);
        assert!((rows[0].bsk_ratio - 0.25).abs() < 1e-12, "amortized stream shows as < 1");
        assert_eq!(rows[0].measured_ns, 60);
        // Batch 1 never executed: zero measured, zero predicted totals.
        assert_eq!(rows[1].requests, 0);
        assert!(rows[1].ks_exact, "0 == 0 x requests");
        assert!(counts_exact(&rows));
    }

    #[test]
    fn count_mismatch_is_flagged_not_fatal() {
        let measured = vec![PlanBatchProfile { requests: 2, ks_calls: 3, ..Default::default() }];
        let predicted = vec![BatchPrediction { ks: 2, ..Default::default() }];
        let rows = attribute(&measured, &predicted);
        assert!(!rows[0].ks_exact);
        assert!(!counts_exact(&rows));
    }

    #[test]
    fn profiles_merge_index_wise_with_resize() {
        let mut a = vec![PlanBatchProfile { requests: 1, ..Default::default() }];
        let b = vec![
            PlanBatchProfile { requests: 2, ..Default::default() },
            PlanBatchProfile { ks_calls: 5, ..Default::default() },
        ];
        merge_profiles(&mut a, &b);
        assert_eq!(a.len(), 2);
        assert_eq!(a[0].requests, 3);
        assert_eq!(a[1].ks_calls, 5);
    }
}
