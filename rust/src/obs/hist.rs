//! Mergeable log2-bucket latency histograms.
//!
//! A [`Log2Histogram`] holds exact event *counts* in 64 power-of-two
//! nanosecond buckets (bucket `i` covers `[2^i, 2^(i+1))` ns), so it is
//! fixed-memory no matter how many events it records and — unlike the raw
//! sample vectors the percentile metrics use — two histograms merge by
//! bucket-wise addition into exactly the histogram a single recorder
//! would have produced. That composability is what lets per-worker,
//! per-shard, and per-cluster stage timings roll up without any sampling
//! loss in the *counts* (the quantile values themselves are quantized to
//! bucket resolution: a factor-of-two band, reported at the bucket's
//! geometric midpoint).

use std::time::Duration;

pub const BUCKETS: usize = 64;

/// Exact-count histogram over log2 nanosecond buckets.
#[derive(Clone, PartialEq, Eq)]
pub struct Log2Histogram {
    counts: [u64; BUCKETS],
}

// [u64; 64] has no std Default; spell it out.
impl Default for Log2Histogram {
    fn default() -> Self {
        Self { counts: [0; BUCKETS] }
    }
}

impl std::fmt::Debug for Log2Histogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Log2Histogram(n={}", self.count())?;
        for (i, &c) in self.counts.iter().enumerate() {
            if c > 0 {
                write!(f, ", 2^{i}ns:{c}")?;
            }
        }
        f.write_str(")")
    }
}

impl Log2Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    /// Bucket index for a nanosecond value (0 ns lands in bucket 0).
    fn bucket(ns: u64) -> usize {
        if ns == 0 {
            0
        } else {
            ns.ilog2() as usize
        }
    }

    pub fn record(&mut self, ns: u64) {
        self.counts[Self::bucket(ns)] += 1;
    }

    pub fn record_duration(&mut self, d: Duration) {
        // Saturates at the top bucket for durations past u64 nanoseconds
        // (~584 years) — irrelevant in practice, but never panics.
        self.record(u64::try_from(d.as_nanos()).unwrap_or(u64::MAX));
    }

    /// Bucket-wise addition: exactly the histogram one recorder seeing
    /// both event streams would have produced.
    pub fn merge(&mut self, other: &Self) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += *b;
        }
    }

    pub fn count(&self) -> u64 {
        self.counts.iter().sum()
    }

    pub fn is_empty(&self) -> bool {
        self.counts.iter().all(|&c| c == 0)
    }

    /// Quantile value in nanoseconds at bucket resolution (the covering
    /// bucket's geometric midpoint, `1.5 * 2^i`); 0.0 when empty. `p` in
    /// [0, 100].
    pub fn percentile(&self, p: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        // Rank of the p-th percentile event (1-based), clamped to range.
        let rank = ((p / 100.0) * total as f64).ceil().max(1.0) as u64;
        let rank = rank.min(total);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return 1.5 * (1u64 << i) as f64;
            }
        }
        unreachable!("rank is clamped to the total count")
    }

    /// The non-empty buckets as `(log2_ns, count)` pairs (for JSON
    /// emission and reports).
    pub fn nonzero_buckets(&self) -> Vec<(u32, u64)> {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (i as u32, c))
            .collect()
    }
}

/// One histogram per serving-path stage. Engines fill the execution
/// stages; the coordinator's metrics add queueing on top and merge
/// worker-level sets into the shard set (and shard sets into the cluster
/// set) bucket-wise.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct StageHists {
    /// Admission-to-dispatch wait, one event per served request.
    pub queue: Log2Histogram,
    /// One event per `PbsBackend::keyswitch` call.
    pub keyswitch: Log2Histogram,
    /// One event per fused `blind_rotate_batch` sweep.
    pub blind_rotate: Log2Histogram,
    /// One event per `sample_extract` call.
    pub sample_extract: Log2Histogram,
    /// One event per Fourier-transform dispatch (forward or inverse,
    /// harvested from the worker thread and the blind-rotation pool).
    pub fft: Log2Histogram,
}

impl StageHists {
    pub fn merge(&mut self, other: &Self) {
        self.queue.merge(&other.queue);
        self.keyswitch.merge(&other.keyswitch);
        self.blind_rotate.merge(&other.blind_rotate);
        self.sample_extract.merge(&other.sample_extract);
        self.fft.merge(&other.fft);
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
            && self.keyswitch.is_empty()
            && self.blind_rotate.is_empty()
            && self.sample_extract.is_empty()
            && self.fft.is_empty()
    }

    /// `(name, histogram)` pairs in pipeline order (for tables/JSON).
    pub fn named(&self) -> [(&'static str, &Log2Histogram); 5] {
        [
            ("queue", &self.queue),
            ("keyswitch", &self.keyswitch),
            ("blind_rotate", &self.blind_rotate),
            ("sample_extract", &self.sample_extract),
            ("fft_transform", &self.fft),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn bucketing_covers_the_edges() {
        let mut h = Log2Histogram::new();
        h.record(0); // bucket 0
        h.record(1); // bucket 0
        h.record(2); // bucket 1
        h.record(3); // bucket 1
        h.record(4); // bucket 2
        h.record(u64::MAX); // bucket 63
        assert_eq!(h.count(), 6);
        let nz = h.nonzero_buckets();
        assert_eq!(nz, vec![(0, 2), (1, 2), (2, 1), (63, 1)]);
    }

    #[test]
    fn percentile_empty_single_and_duplicates() {
        let h = Log2Histogram::new();
        assert_eq!(h.percentile(50.0), 0.0);

        let mut one = Log2Histogram::new();
        one.record(1000); // bucket 9 -> midpoint 768
        for p in [0.0, 50.0, 99.0, 100.0] {
            assert_eq!(one.percentile(p), 1.5 * 512.0);
        }

        // Duplicate-heavy: 99 events in one bucket, 1 far above.
        let mut dup = Log2Histogram::new();
        for _ in 0..99 {
            dup.record(100); // bucket 6
        }
        dup.record(1 << 20); // bucket 20
        assert_eq!(dup.percentile(50.0), 1.5 * 64.0);
        assert_eq!(dup.percentile(99.0), 1.5 * 64.0);
        assert_eq!(dup.percentile(100.0), 1.5 * (1u64 << 20) as f64);
    }

    #[test]
    fn merge_equals_concatenated_recording() {
        let mut rng = Rng::new(17);
        let samples: Vec<u64> = (0..500).map(|_| rng.below(1 << 30)).collect();
        let mut whole = Log2Histogram::new();
        let mut left = Log2Histogram::new();
        let mut right = Log2Histogram::new();
        for (i, &ns) in samples.iter().enumerate() {
            whole.record(ns);
            if i % 3 == 0 {
                left.record(ns);
            } else {
                right.record(ns);
            }
        }
        let mut merged = left.clone();
        merged.merge(&right);
        assert_eq!(merged, whole, "merge must equal one recorder seeing every event");
        assert_eq!(merged.count(), 500);
        for p in [1.0, 25.0, 50.0, 90.0, 99.0] {
            assert_eq!(merged.percentile(p), whole.percentile(p));
        }
    }

    #[test]
    fn stage_set_merges_field_wise() {
        let mut a = StageHists::default();
        let mut b = StageHists::default();
        a.queue.record(10);
        b.queue.record(20);
        b.keyswitch.record(30);
        a.merge(&b);
        assert_eq!(a.queue.count(), 2);
        assert_eq!(a.keyswitch.count(), 1);
        assert!(!a.is_empty());
        assert_eq!(a.named()[0].0, "queue");
    }
}
