//! Plaintext reference interpreter: the functional spec of a program.
//! Values live in Z_{2^(width+1)} (the encoded message space including the
//! padding bit) — exactly what encrypt -> execute -> decrypt computes.
//!
//! LUTs follow TFHE's true negacyclic semantics: for inputs with the
//! padding bit set (m >= P/2), PBS returns -f(m - P/2) — programs are
//! expected to keep live values inside [0, P/2), but the interpreter is
//! bit-faithful either way so it can oracle the encrypted engine.

use super::{Op, Program};

/// Negacyclic LUT application: f(m) for m < P/2, -f(m - P/2) otherwise.
fn lut_apply(table: &[u64], m: u64, p: u64) -> u64 {
    let half = p / 2;
    if m < half {
        table[m as usize] % p
    } else {
        (p - table[(m - half) as usize] % p) % p
    }
}

/// Evaluate `prog` on plaintext inputs (in program order of `Op::Input`).
pub fn eval(prog: &Program, inputs: &[u64]) -> Vec<u64> {
    let p = 1u64 << (prog.width + 1);
    let mut vals = vec![0u64; prog.nodes.len()];
    let mut next_input = 0;
    for (i, n) in prog.nodes.iter().enumerate() {
        vals[i] = match n {
            Op::Input => {
                let v = inputs[next_input] % p;
                next_input += 1;
                v
            }
            Op::Add(a, b) => (vals[*a] + vals[*b]) % p,
            Op::Sub(a, b) => (vals[*a] + p - vals[*b]) % p,
            Op::AddPlain(a, c) => (vals[*a] + c) % p,
            Op::MulPlain(a, c) => {
                let v = (vals[*a] as i128) * (*c as i128);
                v.rem_euclid(p as i128) as u64
            }
            Op::Dot { inputs: xs, weights, bias } => {
                let mut acc = *bias as i128;
                for (x, w) in xs.iter().zip(weights) {
                    acc += (vals[*x] as i128) * (*w as i128);
                }
                acc.rem_euclid(p as i128) as u64
            }
            Op::Lut { input, table } => lut_apply(&table.values, vals[*input] % p, p),
            Op::BivLut { a, b, table } => {
                // Faithful to the encrypted engine: pack = a * 2^(w/2) + b
                // without masking (ciphertext values cannot be masked);
                // callers must keep both operands below 2^(w/2).
                let half = prog.width / 2;
                let packed = ((vals[*a] << half) + vals[*b]) % p;
                lut_apply(&table.values, packed, p)
            }
        };
    }
    prog.outputs.iter().map(|&o| vals[o]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::LutTable;

    #[test]
    fn wrapping_semantics() {
        let prog = Program {
            name: "w".into(),
            width: 3, // P = 16
            nodes: vec![Op::Input, Op::MulPlain(0, -1), Op::AddPlain(1, 20)],
            outputs: vec![2],
        };
        // -3 + 20 = 17 = 1 mod 16
        assert_eq!(eval(&prog, &[3]), vec![1]);
    }

    #[test]
    fn lut_indexes_modulo() {
        let t = LutTable::from_fn(3, |m| 15 - m);
        let prog = Program {
            name: "l".into(),
            width: 3,
            nodes: vec![Op::Input, Op::Lut { input: 0, table: t }],
            outputs: vec![1],
        };
        assert_eq!(eval(&prog, &[0]), vec![15]);
        assert_eq!(eval(&prog, &[18]), vec![13]); // 18 mod 16 = 2
    }

    #[test]
    fn lut_negacyclic_past_padding_bit() {
        let t = LutTable::from_fn(3, |m| m + 3);
        let prog = Program {
            name: "pad".into(),
            width: 3,
            nodes: vec![Op::Input, Op::Lut { input: 0, table: t }],
            outputs: vec![1],
        };
        // m = 8 = P/2: padding bit set -> -f(0) = -(3) = 13 mod 16.
        assert_eq!(eval(&prog, &[8]), vec![13]);
        // m = 9 -> -f(1) = -4 = 12.
        assert_eq!(eval(&prog, &[9]), vec![12]);
    }
}
