//! Integer tensor IR — the FHELinAlg-like program representation the
//! compiler consumes (paper §V: "we process programs in MLIR's FHELinAlg
//! dialect"). A program is a DAG of integer-valued nodes; the only
//! PBS-requiring op is the (univariate or bivariate) LUT, everything else
//! is linear and bootstrap-free (the multi-bit TFHE structure of Fig. 2b).

pub mod bigint;
pub mod builder;
pub mod interp;

use std::collections::hash_map::DefaultHasher;
use std::hash::{Hash, Hasher};
use std::sync::Arc;

/// Node index within a [`Program`].
pub type ValueId = usize;

/// A lookup table: the function values f(0..2^(width+1)) (pre-encoding).
/// Tables are hash-identified so ACC-dedup can share accumulators.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LutTable {
    pub values: Arc<Vec<u64>>,
    pub hash: u64,
}

impl LutTable {
    pub fn new(values: Vec<u64>) -> Self {
        let mut h = DefaultHasher::new();
        values.hash(&mut h);
        Self { values: Arc::new(values), hash: h.finish() }
    }

    pub fn from_fn(width: usize, f: impl Fn(u64) -> u64) -> Self {
        let p = 1u64 << (width + 1);
        Self::new((0..p).map(|m| f(m) % p).collect())
    }
}

/// IR operations. `Plain` operands are compile-time constants.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// Encrypted program input.
    Input,
    /// Homomorphic addition of two ciphertexts (LPU, no PBS).
    Add(ValueId, ValueId),
    /// Homomorphic subtraction (LPU).
    Sub(ValueId, ValueId),
    /// Add a plaintext constant (LPU).
    AddPlain(ValueId, u64),
    /// Multiply by a small plaintext constant (LPU).
    MulPlain(ValueId, i64),
    /// Linear combination sum_i w_i * x_i (+ bias) — one LPU pass; this is
    /// how matmul/conv rows lower (paper Fig. 2b step 4).
    Dot { inputs: Vec<ValueId>, weights: Vec<i64>, bias: u64 },
    /// Univariate LUT via PBS (paper Fig. 2b step 5).
    Lut { input: ValueId, table: LutTable },
    /// Bivariate LUT: linear pack (x * 2^(w/2) + y) then univariate LUT
    /// (paper footnote 4). Costs one PBS.
    BivLut { a: ValueId, b: ValueId, table: LutTable },
}

impl Op {
    /// Ciphertext operands of this op.
    pub fn deps(&self) -> Vec<ValueId> {
        match self {
            Op::Input => vec![],
            Op::Add(a, b) | Op::Sub(a, b) => vec![*a, *b],
            Op::AddPlain(a, _) | Op::MulPlain(a, _) => vec![*a],
            Op::Dot { inputs, .. } => inputs.clone(),
            Op::Lut { input, .. } => vec![*input],
            Op::BivLut { a, b, .. } => vec![*a, *b],
        }
    }

    /// Does this op require a bootstrap?
    pub fn needs_pbs(&self) -> bool {
        matches!(self, Op::Lut { .. } | Op::BivLut { .. })
    }
}

/// A compiled-from-frontend FHE program.
#[derive(Debug, Clone, Default)]
pub struct Program {
    pub name: String,
    /// Message width in bits (excluding padding).
    pub width: usize,
    pub nodes: Vec<Op>,
    pub outputs: Vec<ValueId>,
}

impl Program {
    /// Number of PBS operations (the runtime-dominating count, §II-B).
    pub fn pbs_count(&self) -> usize {
        self.nodes.iter().filter(|n| n.needs_pbs()).count()
    }

    /// Number of linear (LPU-only) ops.
    pub fn linear_count(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| !n.needs_pbs() && !matches!(n, Op::Input))
            .count()
    }

    pub fn input_count(&self) -> usize {
        self.nodes.iter().filter(|n| matches!(n, Op::Input)).count()
    }

    /// Validate the DAG: deps precede uses, outputs exist, LUT tables sized.
    pub fn validate(&self) -> Result<(), String> {
        let p = 1usize << (self.width + 1);
        for (i, n) in self.nodes.iter().enumerate() {
            for d in n.deps() {
                if d >= i {
                    return Err(format!("node {i} depends on later node {d}"));
                }
            }
            match n {
                Op::Lut { table, .. } | Op::BivLut { table, .. } => {
                    if table.values.len() != p {
                        return Err(format!(
                            "node {i}: table len {} != {p}",
                            table.values.len()
                        ));
                    }
                }
                Op::Dot { inputs, weights, .. } => {
                    if inputs.len() != weights.len() {
                        return Err(format!("node {i}: dot arity mismatch"));
                    }
                }
                _ => {}
            }
        }
        for &o in &self.outputs {
            if o >= self.nodes.len() {
                return Err(format!("output {o} out of range"));
            }
        }
        Ok(())
    }

    /// Longest PBS-to-PBS dependency chain (critical path in bootstraps);
    /// determines how much batching can help (paper Fig. 15).
    pub fn pbs_depth(&self) -> usize {
        let mut depth = vec![0usize; self.nodes.len()];
        for (i, n) in self.nodes.iter().enumerate() {
            let d = n.deps().iter().map(|&x| depth[x]).max().unwrap_or(0);
            depth[i] = d + if n.needs_pbs() { 1 } else { 0 };
        }
        self.outputs.iter().map(|&o| depth[o]).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lut_table_hash_dedups() {
        let a = LutTable::from_fn(3, |m| m + 1);
        let b = LutTable::from_fn(3, |m| m + 1);
        let c = LutTable::from_fn(3, |m| m + 2);
        assert_eq!(a.hash, b.hash);
        assert_ne!(a.hash, c.hash);
        assert_eq!(a.values.len(), 16);
    }

    #[test]
    fn validate_catches_forward_refs() {
        let prog = Program {
            name: "bad".into(),
            width: 3,
            nodes: vec![Op::Add(1, 1), Op::Input],
            outputs: vec![0],
        };
        assert!(prog.validate().is_err());
    }

    #[test]
    fn counts_and_depth() {
        let t = LutTable::from_fn(3, |m| m);
        let prog = Program {
            name: "p".into(),
            width: 3,
            nodes: vec![
                Op::Input,                              // 0
                Op::Input,                              // 1
                Op::Add(0, 1),                          // 2
                Op::Lut { input: 2, table: t.clone() }, // 3
                Op::Lut { input: 3, table: t.clone() }, // 4
                Op::MulPlain(4, 2),                     // 5
            ],
            outputs: vec![5],
        };
        prog.validate().unwrap();
        assert_eq!(prog.pbs_count(), 2);
        assert_eq!(prog.linear_count(), 2);
        assert_eq!(prog.input_count(), 2);
        assert_eq!(prog.pbs_depth(), 2);
    }
}
