//! Radix-decomposed big integers — the generalization of the paper's
//! Fig. 5 middle representation: integers wider than one ciphertext's
//! message space are held as base-2^(w/2) digit vectors, with carries
//! resolved by LUTs. This is how Concrete represents 8/16-bit integers on
//! narrow parameter sets and what the paper's "wider representations need
//! fewer PBS" tradeoff is measured against.

use super::builder::ProgramBuilder;
use super::{LutTable, ValueId};

/// A big integer as little-endian digits of `digit_bits` each, every digit
/// in its own ciphertext (digit value < 2^digit_bits, stored in a width
/// 2*digit_bits message space so sums/carries fit before normalization).
#[derive(Debug, Clone)]
pub struct RadixInt {
    pub digits: Vec<ValueId>,
    pub digit_bits: usize,
}

impl RadixInt {
    pub fn bits(&self) -> usize {
        self.digits.len() * self.digit_bits
    }
}

/// Builder extensions for radix arithmetic. The builder's program width
/// must be >= 2*digit_bits (headroom for one addition before carry
/// normalization).
pub struct RadixOps<'a> {
    pub b: &'a mut ProgramBuilder,
    pub digit_bits: usize,
    carry_table: LutTable,
    low_table: LutTable,
}

impl<'a> RadixOps<'a> {
    pub fn new(b: &'a mut ProgramBuilder, digit_bits: usize) -> Self {
        let width = b.width();
        assert!(width >= 2 * digit_bits, "need carry headroom: width {width} < 2x{digit_bits}");
        let radix = 1u64 << digit_bits;
        let carry_table = LutTable::from_fn(width, move |m| m / radix);
        let low_table = LutTable::from_fn(width, move |m| m % radix);
        Self { b, digit_bits, carry_table, low_table }
    }

    /// Fresh encrypted input of `n_digits` digits.
    pub fn input(&mut self, n_digits: usize) -> RadixInt {
        RadixInt { digits: self.b.inputs(n_digits), digit_bits: self.digit_bits }
    }

    /// Full addition with carry propagation: 2 PBS per digit (carry +
    /// low), depth = #digits (the ripple structure of Fig. 5 mid-left).
    pub fn add(&mut self, x: &RadixInt, y: &RadixInt) -> RadixInt {
        assert_eq!(x.digit_bits, self.digit_bits);
        assert_eq!(x.digits.len(), y.digits.len());
        let mut out = Vec::with_capacity(x.digits.len() + 1);
        let mut carry: Option<ValueId> = None;
        for (&xd, &yd) in x.digits.iter().zip(&y.digits) {
            let mut s = self.b.add(xd, yd);
            if let Some(c) = carry {
                s = self.b.add(s, c);
            }
            // Two LUTs over the same sum share one key switch (KS-dedup).
            carry = Some(self.b.lut(s, self.carry_table.clone()));
            out.push(self.b.lut(s, self.low_table.clone()));
        }
        out.push(carry.unwrap());
        RadixInt { digits: out, digit_bits: self.digit_bits }
    }

    /// Multiply by a small plaintext constant then renormalize digits.
    pub fn mul_plain(&mut self, x: &RadixInt, c: u64) -> RadixInt {
        assert!(c < (1u64 << self.digit_bits), "constant must fit one digit");
        let mut out = Vec::with_capacity(x.digits.len() + 1);
        let mut carry: Option<ValueId> = None;
        for &xd in &x.digits {
            let mut s = self.b.mul_plain(xd, c as i64);
            if let Some(cy) = carry {
                s = self.b.add(s, cy);
            }
            carry = Some(self.b.lut(s, self.carry_table.clone()));
            out.push(self.b.lut(s, self.low_table.clone()));
        }
        out.push(carry.unwrap());
        RadixInt { digits: out, digit_bits: self.digit_bits }
    }

    /// Decompose a plaintext into digits (host-side helper for tests).
    pub fn encode(&self, v: u64, n_digits: usize) -> Vec<u64> {
        let radix = 1u64 << self.digit_bits;
        (0..n_digits).map(|i| (v >> (i * self.digit_bits)) % radix).collect()
    }

    /// Recompose digit values (host-side).
    pub fn decode(&self, digits: &[u64]) -> u64 {
        digits
            .iter()
            .enumerate()
            .map(|(i, &d)| d << (i * self.digit_bits))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::interp;

    #[test]
    fn radix_add_matches_integers() {
        // width 3 (TEST1-compatible) -> 1-bit digits with headroom... use
        // digit_bits=1 so carries fit: sums reach 3 < 2^(w-1)=4.
        let mut b = ProgramBuilder::new("radd", 3);
        let mut ops = RadixOps::new(&mut b, 1);
        let x = ops.input(6);
        let y = ops.input(6);
        let z = ops.add(&x, &y);
        let outs = z.digits.clone();
        let (digit_bits, enc) = (ops.digit_bits, ());
        let _ = (digit_bits, enc);
        b.outputs(&outs);
        let prog = b.finish();
        for (xv, yv) in [(11u64, 22u64), (63, 63), (0, 5), (42, 21)] {
            let mut inputs: Vec<u64> = (0..6).map(|i| (xv >> i) & 1).collect();
            inputs.extend((0..6).map(|i| (yv >> i) & 1));
            let out = interp::eval(&prog, &inputs);
            let got: u64 = out.iter().enumerate().map(|(i, &d)| d << i).sum();
            assert_eq!(got, xv + yv, "{xv}+{yv}");
        }
    }

    #[test]
    fn radix_add_wide_digits() {
        // width 6 -> 3-bit digits: a 9-bit integer in 3 ciphertexts.
        let mut b = ProgramBuilder::new("radd6", 6);
        let mut ops = RadixOps::new(&mut b, 3);
        let x = ops.input(3);
        let y = ops.input(3);
        let z = ops.add(&x, &y);
        let outs = z.digits.clone();
        b.outputs(&outs);
        let prog = b.finish();
        for (xv, yv) in [(357u64, 123u64), (511, 511), (8, 504)] {
            let mut inputs: Vec<u64> = (0..3).map(|i| (xv >> (3 * i)) & 7).collect();
            inputs.extend((0..3).map(|i| (yv >> (3 * i)) & 7));
            let out = interp::eval(&prog, &inputs);
            let got: u64 = out.iter().enumerate().map(|(i, &d)| d << (3 * i)).sum();
            assert_eq!(got, xv + yv, "{xv}+{yv}");
        }
    }

    #[test]
    fn mul_plain_with_carries() {
        let mut b = ProgramBuilder::new("rmul", 6);
        let mut ops = RadixOps::new(&mut b, 3);
        let x = ops.input(3);
        let z = ops.mul_plain(&x, 5);
        let outs = z.digits.clone();
        b.outputs(&outs);
        let prog = b.finish();
        for xv in [100u64, 7, 511] {
            let inputs: Vec<u64> = (0..3).map(|i| (xv >> (3 * i)) & 7).collect();
            let out = interp::eval(&prog, &inputs);
            let got: u64 = out.iter().enumerate().map(|(i, &d)| d << (3 * i)).sum();
            assert_eq!(got, 5 * xv, "5*{xv}");
        }
    }

    #[test]
    fn pbs_cost_shows_width_tradeoff() {
        // Observation 2 quantified by the library itself: fewer, wider
        // digits need fewer bootstraps for the same logical addition.
        let cost = |width: usize, digit_bits: usize, n_digits: usize| {
            let mut b = ProgramBuilder::new("c", width);
            let mut ops = RadixOps::new(&mut b, digit_bits);
            let x = ops.input(n_digits);
            let y = ops.input(n_digits);
            let z = ops.add(&x, &y);
            let outs = z.digits.clone();
            b.outputs(&outs);
            b.finish().pbs_count()
        };
        let narrow = cost(3, 1, 12); // 12-bit integer, 1-bit digits
        let wide = cost(8, 4, 3); // 12-bit integer, 4-bit digits
        assert!(narrow > 3 * wide, "narrow {narrow} vs wide {wide}");
    }

    #[test]
    fn encode_decode_roundtrip() {
        let mut b = ProgramBuilder::new("ed", 6);
        let ops = RadixOps::new(&mut b, 3);
        let d = ops.encode(357, 3);
        assert_eq!(d, vec![5, 4, 5]);
        assert_eq!(ops.decode(&d), 357);
    }

    #[test]
    #[should_panic(expected = "carry headroom")]
    fn headroom_enforced() {
        let mut b = ProgramBuilder::new("bad", 3);
        let _ = RadixOps::new(&mut b, 2); // needs width >= 4
    }
}
