//! Fluent builder for IR programs (what the workload generators and
//! examples use as the "frontend").

use super::{LutTable, Op, Program, ValueId};

#[derive(Debug, Default)]
pub struct ProgramBuilder {
    prog: Program,
}

impl ProgramBuilder {
    pub fn new(name: impl Into<String>, width: usize) -> Self {
        Self {
            prog: Program { name: name.into(), width, nodes: vec![], outputs: vec![] },
        }
    }

    fn push(&mut self, op: Op) -> ValueId {
        self.prog.nodes.push(op);
        self.prog.nodes.len() - 1
    }

    pub fn width(&self) -> usize {
        self.prog.width
    }

    pub fn input(&mut self) -> ValueId {
        self.push(Op::Input)
    }

    pub fn inputs(&mut self, count: usize) -> Vec<ValueId> {
        (0..count).map(|_| self.input()).collect()
    }

    pub fn add(&mut self, a: ValueId, b: ValueId) -> ValueId {
        self.push(Op::Add(a, b))
    }

    pub fn sub(&mut self, a: ValueId, b: ValueId) -> ValueId {
        self.push(Op::Sub(a, b))
    }

    pub fn add_plain(&mut self, a: ValueId, c: u64) -> ValueId {
        self.push(Op::AddPlain(a, c))
    }

    pub fn mul_plain(&mut self, a: ValueId, c: i64) -> ValueId {
        self.push(Op::MulPlain(a, c))
    }

    pub fn dot(&mut self, inputs: Vec<ValueId>, weights: Vec<i64>, bias: u64) -> ValueId {
        assert_eq!(inputs.len(), weights.len());
        self.push(Op::Dot { inputs, weights, bias })
    }

    pub fn lut(&mut self, input: ValueId, table: LutTable) -> ValueId {
        self.push(Op::Lut { input, table })
    }

    pub fn lut_fn(&mut self, input: ValueId, f: impl Fn(u64) -> u64) -> ValueId {
        let t = LutTable::from_fn(self.prog.width, f);
        self.lut(input, t)
    }

    pub fn biv_lut(&mut self, a: ValueId, b: ValueId, table: LutTable) -> ValueId {
        self.push(Op::BivLut { a, b, table })
    }

    pub fn biv_lut_fn(&mut self, a: ValueId, b: ValueId, g: impl Fn(u64, u64) -> u64) -> ValueId {
        let w = self.prog.width;
        let half = w / 2;
        let half_mod = 1u64 << half;
        let t = LutTable::from_fn(w, |packed| g((packed >> half) % half_mod, packed % half_mod));
        self.biv_lut(a, b, t)
    }

    /// ReLU with a cutoff at `zero_point` (quantized-DNN style).
    pub fn relu(&mut self, input: ValueId, zero_point: u64) -> ValueId {
        self.lut_fn(input, move |m| m.saturating_sub(zero_point))
    }

    /// Matrix-vector product: rows of `weights` dot the `inputs` vector.
    pub fn matvec(&mut self, inputs: &[ValueId], weights: &[Vec<i64>], biases: &[u64]) -> Vec<ValueId> {
        weights
            .iter()
            .zip(biases)
            .map(|(row, &b)| self.dot(inputs.to_vec(), row.clone(), b))
            .collect()
    }

    pub fn output(&mut self, v: ValueId) {
        self.prog.outputs.push(v);
    }

    pub fn outputs(&mut self, vs: &[ValueId]) {
        self.prog.outputs.extend_from_slice(vs);
    }

    pub fn finish(self) -> Program {
        self.prog.validate().expect("builder produced invalid program");
        self.prog
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::interp::eval;

    #[test]
    fn build_and_eval_small_program() {
        let mut b = ProgramBuilder::new("t", 3);
        let x = b.input();
        let y = b.input();
        let s = b.add(x, y);
        let r = b.relu(s, 3);
        b.output(r);
        let p = b.finish();
        assert_eq!(eval(&p, &[1, 1]), vec![0]); // relu(2-3)=0
        assert_eq!(eval(&p, &[4, 2]), vec![3]); // relu(6-3)=3
    }

    #[test]
    fn matvec_builds_dots() {
        let mut b = ProgramBuilder::new("mv", 4);
        let ins = b.inputs(3);
        let outs = b.matvec(&ins, &[vec![1, 2, 3], vec![-1, 0, 1]], &[0, 5]);
        b.outputs(&outs);
        let p = b.finish();
        // [1,1,1] -> [6, 5] (mod 32)
        assert_eq!(eval(&p, &[1, 1, 1]), vec![6, 5]);
    }

    #[test]
    fn bivariate_lut_packs_halves() {
        let mut b = ProgramBuilder::new("biv", 4); // half width 2
        let x = b.input();
        let y = b.input();
        let m = b.biv_lut_fn(x, y, |a, bb| a.max(bb));
        b.output(m);
        let p = b.finish();
        assert_eq!(eval(&p, &[2, 3]), vec![3]);
        assert_eq!(eval(&p, &[3, 1]), vec![3]);
    }
}
