//! Published constants for prior TFHE ASICs (paper Table III sources:
//! Strix [MICRO'23], MATCHA [DAC'22], Morphling [HPCA'24]), used by the
//! Table III regeneration and the Table IV context.

#[derive(Debug, Clone)]
pub struct PriorAccel {
    pub name: &'static str,
    pub process_nm: u32,
    pub reported_area_mm2: f64,
    /// Stillmaker-Baas scaled to 16 nm (paper's scaling).
    pub area_16nm_mm2: f64,
    /// Paper Table III metric.
    pub polymult_per_area: f64,
    /// Maximum supported polynomial degree.
    pub max_poly_degree: usize,
    /// Maximum practical message width (bits).
    pub max_width: usize,
}

pub const STRIX: PriorAccel = PriorAccel {
    name: "Strix",
    process_nm: 28,
    reported_area_mm2: 141.37,
    area_16nm_mm2: 52.69,
    polymult_per_area: 1.21,
    max_poly_degree: 8192,
    max_width: 4,
};

pub const MATCHA: PriorAccel = PriorAccel {
    name: "MATCHA",
    process_nm: 16,
    reported_area_mm2: 36.96,
    area_16nm_mm2: 25.08,
    polymult_per_area: 1.27,
    max_poly_degree: 1024,
    max_width: 1,
};

pub const MORPHLING: PriorAccel = PriorAccel {
    name: "Morphling",
    process_nm: 28,
    reported_area_mm2: 74.79,
    area_16nm_mm2: 24.95,
    polymult_per_area: 10.25,
    max_poly_degree: 4096,
    max_width: 5,
};

pub const ALL: [&PriorAccel; 3] = [&STRIX, &MATCHA, &MORPHLING];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn taurus_uniquely_supports_ten_bits() {
        // Paper: 2^16-degree polynomials enable 10-bit programs vs the
        // previous 5-bit limitation.
        for a in ALL {
            assert!(a.max_poly_degree < 65536, "{}", a.name);
            assert!(a.max_width < 10, "{}", a.name);
        }
    }

    #[test]
    fn scaled_areas_match_paper() {
        assert_eq!(STRIX.area_16nm_mm2, 52.69);
        assert_eq!(MORPHLING.area_16nm_mm2, 24.95);
        assert_eq!(MATCHA.area_16nm_mm2, 25.08);
    }
}
