//! CPU cost model for TFHE-rs/Concrete-style execution.
//!
//! Per-PBS time scales with the FFT work n * (d(k+1) + k + 1) * N/2 *
//! log2(N/2); the effective per-core rate is calibrated against the
//! paper's Table II CPU column (AMD EPYC 7R13, 48 Zen3 cores) — see
//! DESIGN.md §Calibration. Program-level times account for the workload's
//! exploitable parallelism via the compiled schedule.

use crate::compiler::Compiled;
use crate::params::ParamSet;

#[derive(Debug, Clone)]
pub struct CpuPlatform {
    pub name: &'static str,
    pub cores: usize,
    /// Effective per-core FLOP rate on the TFHE FFT hot loop (calibrated;
    /// includes memory-bandwidth pressure at full occupancy).
    pub core_gflops: f64,
    /// IPC / frequency scaling vs the 7R13 baseline.
    pub ipc_factor: f64,
    /// Total memory bandwidth (caps multi-core scaling when the working
    /// set — BSK + KSK — spills the L3), GB/s.
    pub mem_bw_gbps: f64,
    pub tdp_w: f64,
}

/// Paper baseline: AMD EPYC 7R13, 48 cores @ 3.4 GHz, DDR4-3200.
pub const EPYC_7R13: CpuPlatform = CpuPlatform {
    name: "EPYC 7R13 (48c)",
    cores: 48,
    core_gflops: 2.1,
    ipc_factor: 1.0,
    mem_bw_gbps: 204.8,
    tdp_w: 270.0,
};

/// Paper §VI-D: dual EPYC 9654 (192 cores, 921.6 GB/s, AVX-512, +13% IPC).
pub const DUAL_EPYC_9654: CpuPlatform = CpuPlatform {
    name: "2x EPYC 9654 (192c)",
    cores: 192,
    core_gflops: 2.1,
    ipc_factor: 1.13 * 1.6, // IPC uplift x AVX-512 width benefit
    mem_bw_gbps: 921.6,
    tdp_w: 800.0,
};

/// FLOPs of one PBS (FFT-dominated blind rotation + key switch).
pub fn pbs_flops(p: &ParamSet) -> f64 {
    let nh = p.half_n() as f64;
    let log = nh.log2();
    let fft = p.n as f64 * (p.ggsw_rows() + p.k + 1) as f64 * nh * log * 6.0;
    let mac = p.n as f64 * (p.ggsw_rows() * (p.k + 1)) as f64 * nh * 4.0;
    let ks = (p.long_dim() * p.ks_level * (p.n + 1)) as f64 * 2.0;
    fft + mac + ks
}

/// Single-core, single-PBS latency.
pub fn pbs_seconds_single_core(p: &ParamSet, cpu: &CpuPlatform) -> f64 {
    pbs_flops(p) / (cpu.core_gflops * 1e9 * cpu.ipc_factor)
}

/// Bytes each PBS must pull through the memory system (BSK once — the L3
/// cannot hold the multi-bit keys, the paper's §I bottleneck).
pub fn pbs_bytes(p: &ParamSet) -> f64 {
    (p.bsk_bytes() + p.ksk_bytes()) as f64
}

/// PBS counts per dependency level (the CPU is not bound by the
/// accelerator's 48-ciphertext batch granularity — it exploits the full
/// level width up to its core count).
pub fn level_widths(c: &Compiled) -> Vec<usize> {
    let mut widths: Vec<usize> = Vec::new();
    for batch in &c.schedule.batches {
        if widths.len() <= batch.level {
            widths.resize(batch.level + 1, 0);
        }
        widths[batch.level] += batch.br_ops.len();
    }
    widths
}

/// Wall-clock for a compiled program: per-level parallelism, with
/// per-core compute vs shared-bandwidth ceilings.
pub fn program_seconds(c: &Compiled, cpu: &CpuPlatform) -> f64 {
    let p = &c.params;
    let t_pbs = pbs_seconds_single_core(p, cpu);
    let mut total = 0.0;
    for cts in level_widths(c) {
        let cts = cts.max(1);
        let par = cts.min(cpu.cores) as f64;
        let compute = cts as f64 * t_pbs / par;
        // All `par` cores stream their own BSK working set concurrently.
        let mem = par * pbs_bytes(p) * (cts as f64 / par) / (cpu.mem_bw_gbps * 1e9);
        total += compute.max(mem);
    }
    total
}

/// Throughput-mode PBS/s for Fig. 16-style normalized comparisons.
pub fn pbs_per_second(p: &ParamSet, cpu: &CpuPlatform) -> f64 {
    let t = pbs_seconds_single_core(p, cpu);
    let compute_rate = cpu.cores as f64 / t;
    let mem_rate = cpu.mem_bw_gbps * 1e9 / pbs_bytes(p);
    compute_rate.min(mem_rate)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::{CNN20, DECISION_TREE, GPT2};

    #[test]
    fn pbs_costs_scale_with_width() {
        // §I: 6-bit LUTs are >4x slower than 4-bit on CPU; our N=2048 ->
        // N=65536 jump should be far larger than 4x.
        let small = pbs_seconds_single_core(&CNN20, &EPYC_7R13);
        let big = pbs_seconds_single_core(&DECISION_TREE, &EPYC_7R13);
        assert!(big / small > 10.0, "{small} vs {big}");
        // Order of magnitude: tens of ms for N=2048 at 6 bits.
        assert!(small > 0.01 && small < 0.3, "CNN20 pbs {small}s");
    }

    #[test]
    fn dual_9654_faster_but_sublinear() {
        // Fig. 16: 192 cores + 4.5x bandwidth gives well under 4x per-PBS
        // program speedup on bandwidth-bound workloads.
        let base = pbs_per_second(&GPT2, &EPYC_7R13);
        let big = pbs_per_second(&GPT2, &DUAL_EPYC_9654);
        let speedup = big / base;
        assert!(speedup > 2.0 && speedup < 10.0, "speedup {speedup}");
    }

    #[test]
    fn wide_param_pbs_latency_in_calibrated_range() {
        // The effective per-core rate already folds in the L3-spill
        // bandwidth pressure the paper describes (§I); at N = 65536 a
        // single-core PBS lands at several seconds, consistent with the
        // 645 s Table II decision-tree runtime at ~10-20x parallelism.
        let t = pbs_seconds_single_core(&DECISION_TREE, &EPYC_7R13);
        assert!(t > 4.0 && t < 20.0, "DT pbs {t}s");
        // Keys alone exceed any L3 (the §I memory argument).
        assert!(pbs_bytes(&DECISION_TREE) > 1e9);
    }
}
