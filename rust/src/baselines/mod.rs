//! Calibrated CPU/GPU cost models and prior-accelerator constants —
//! the comparison columns of Table II and Fig. 16.
//!
//! All models are anchored on the paper's own published measurements
//! (DESIGN.md §Substitutions): the comparison is about *ratios across
//! platforms on identical op-count workloads*, which anchoring preserves.

pub mod cpu_model;
pub mod gpu_model;
pub mod prior_accel;

pub use cpu_model::{CpuPlatform, DUAL_EPYC_9654, EPYC_7R13};
pub use gpu_model::{GpuPlatform, DUAL_A5000};
